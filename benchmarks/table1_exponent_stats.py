"""Paper Table 1: BF16 KV exponent statistics across model families.

For each family we harvest real KV-cache activations from this repo's model
implementations (bench-scale configs, synthetic corpus) and report top-8 /
top-16 coverage, exponent entropy, and the realized SplitZip compression
ratio.  Expected structure (paper): top-16 > 99%, entropy ~3 bits, CR ~1.32.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import bench_config, generate_kv_bits, pooled_bits
from repro.core import codebook as cbm
from repro.core import wire

MODELS = [
    ("qwen3-moe-30b-a3b", "Qwen-MoE"),
    ("qwen3-32b", "Qwen"),
    ("llama3.2-3b", "Llama"),
    ("smollm-135m", "Llama-small"),
    ("minicpm3-4b", "MLA"),
    ("mamba2-2.7b", "SSM"),
]


def run(emit) -> None:
    for arch, family in MODELS:
        cfg = bench_config(arch)
        kv = generate_kv_bits(cfg, seq=256, batch=4)
        bits = pooled_bits(kv)
        hist = cbm.exponent_histogram(bits)
        top8 = cbm.topk_coverage(hist, 8)
        top16 = cbm.topk_coverage(hist, 16)
        ent = cbm.exponent_entropy(hist)
        cb = cbm.codebook_from_histogram(hist, k=16)
        _, stats = wire.encode(bits, cb)
        emit("table1", f"{arch}", dict(
            family=family, top8=round(top8, 4), top16=round(top16, 4),
            entropy_bits=round(ent, 3), realized_cr=round(stats.ratio, 4),
            escape_rate=round(stats.escape_rate, 5)))
