"""Paper Fig. 4: transmission-time breakdown on Qwen3-32B, batch 16, at
sequence lengths 2K / 16K / 64K under the RoCE 4x200G configuration
(700 Gb/s effective -> 87.5 GB/s).  Expected: compressed transfer dominates
at long context; encode/decode shares shrink as payload grows relative to
fixed overheads.

Paper-internal consistency note (EXPERIMENTS.md §Reproduction): the paper's
stated native times imply an effective link of ~155 GB/s (not the stated
87.5), and its 5.7%/1.4% encode/decode shares imply the codec ran sharded
across the serving GPUs (aggregate ≈ n_gpu x 613 GB/s).  Both knobs are
exposed here: the `stated` rows use the paper's stated constants (single-GPU
codec, 87.5 GB/s); the `fitted` rows use link_bw/codec_parallelism fitted to
the paper's own Fig. 4 numbers, and reproduce them closely.
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import bench_config, generate_kv_bits, pooled_bits
from repro.configs.base import get_config
from repro.core import codebook as cbm
from repro.core.pipeline import CodecProfile, pipelined_transfer_time
from repro.serving.transfer import TransferConfig, transfer_report

N_CHUNKS = 8  # pipelined-engine granularity (TransferPlan n_chunks)

FIXED = 5e-3  # per-transfer fixed cost at batch granularity

# (label, effective link bandwidth, codec parallelism)
SETTINGS = (
    ("stated", 87.5e9, 1),    # paper's stated constants, single-GPU codec
    ("fitted", 155e9, 8),     # fitted to the paper's own Fig. 4 numbers
)

PAPER_FIG4 = {2048: (56.5, 53.1), 16384: (441.4, 353.8), 65536: (1749.3, 1397.0)}


def run(emit) -> None:
    cfg = get_config("qwen3-32b")
    bits = pooled_bits(generate_kv_bits(bench_config("qwen3-32b"),
                                        seq=256, batch=2))
    cb = cbm.calibrate([bits], k=16)
    # measured rho via the byte-exact host backend of the codec registry
    be = TransferConfig(codebook=cb, backend="wire").get_backend()
    ct = be.encode(jnp.asarray(bits), cb)
    rho = be.raw_bytes(ct) / float(be.wire_bytes(ct))
    bpt = cfg.num_layers * 2 * cfg.num_kv_heads * cfg.head_dim * 2
    for label, link_bw, par in SETTINGS:
        profile = CodecProfile(g_enc=613.3e9 * par, g_dec=2181.8e9 * par,
                               ratio=rho, link_bw=link_bw,
                               fixed_overhead_s=FIXED)
        for seq in (2048, 16384, 65536):
            raw = float(bpt) * seq * 16
            rep = transfer_report(raw, raw / rho, profile)
            total = rep.t_splitzip
            # chunked pipelined engine: encode/transfer/decode overlap
            t_pipe = pipelined_transfer_time(raw, profile, N_CHUNKS)
            row = dict(
                t_native_ms=round(rep.t_native * 1e3, 2),
                t_splitzip_ms=round(total * 1e3, 2),
                t_pipelined_ms=round(t_pipe * 1e3, 2),
                frac_encode=round(rep.t_encode / total, 4),
                frac_transfer=round(rep.t_transfer / total, 4),
                frac_decode=round(rep.t_decode / total, 4),
                speedup=round(rep.speedup, 4),
                speedup_pipelined=round(rep.t_native / t_pipe, 4))
            if label == "fitted":
                row["paper_native_ms"], row["paper_splitzip_ms"] = PAPER_FIG4[seq]
            emit("fig4", f"{label}/seq{seq}", row)
