"""Paper Table 6: explicit escape positions (Top-16) vs sentinel (Top-15).

Expected: sentinel's ratio is marginally higher (no position bytes) but its
decode path is irregular (in-stream sentinel detection + rank/merge) and
much slower — the paper measures 3.5x.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_config, generate_kv_bits, gbps, pooled_bits, time_fn
from repro.core import codebook as cbm
from repro.core import codec as C


def run(emit) -> None:
    cfg = bench_config("qwen3-32b")
    bits = pooled_bits(generate_kv_bits(cfg, seq=512, batch=4))
    nbytes = bits.nbytes
    cb = cbm.calibrate([bits], k=16)
    x = jax.lax.bitcast_convert_type(jnp.asarray(bits), jnp.bfloat16)

    enc16 = jax.jit(lambda v: C.encode(v, cb, cap=256))
    ct = enc16(x)
    dec16 = jax.jit(C.decode)
    assert bool(jnp.all(jax.lax.bitcast_convert_type(dec16(ct), jnp.uint16)
                        == jnp.asarray(bits)))
    cb15 = cbm.Codebook(fmt="bf16", exponents=cb.exponents[:15])
    enc15 = jax.jit(lambda v: C.encode_sentinel(v, cb, cap=256))
    st = enc15(x)
    dec15 = jax.jit(C.decode_sentinel)
    assert bool(jnp.all(jax.lax.bitcast_convert_type(dec15(st), jnp.uint16)
                        == jnp.asarray(bits)))

    t_e16, _ = time_fn(lambda: enc16(x), repeats=5)
    t_d16, _ = time_fn(lambda: dec16(ct), repeats=5)
    t_e15, _ = time_fn(lambda: enc15(x), repeats=5)
    t_d15, _ = time_fn(lambda: dec15(st), repeats=5)

    esc16 = float(jnp.sum(ct.esc_count)) / ct.n_padded
    esc15 = float(jnp.sum(st.esc_count)) / st.sign_mantissa.shape[0]
    emit("table6", "top16-pos", dict(
        coverage=round(cbm.coverage(cb, bits), 5), escape_rate=round(esc16, 5),
        ratio=round(nbytes / float(C.compressed_bytes(ct)), 4),
        enc_gbps=round(gbps(nbytes, t_e16), 3),
        dec_gbps=round(gbps(nbytes, t_d16), 3)))
    emit("table6", "top15-sentinel", dict(
        coverage=round(cbm.coverage(cb15, bits), 5), escape_rate=round(esc15, 5),
        ratio=round(nbytes / float(C.sentinel_bytes(st)), 4),
        enc_gbps=round(gbps(nbytes, t_e15), 3),
        dec_gbps=round(gbps(nbytes, t_d15), 3)))
    emit("table6", "derived", dict(
        decode_slowdown_sentinel=round(t_d15 / t_d16, 2)))
