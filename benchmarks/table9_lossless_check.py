"""Paper Table 9 (Appendix C): end-to-end losslessness across context lengths.

Generate through the compressed PD boundary and compare against the
uncompressed pipeline: text (token ids) must match exactly, max logit diff
must be 0.0, reconstruction errors 0.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_config
from repro.configs.base import ShapeConfig
from repro.core import codebook as cbm
from repro.models import model as M
from repro.serving.engine import DisaggregatedEngine

CONTEXTS = [32, 64, 128, 256]


def run(emit) -> None:
    cfg = bench_config("qwen3-32b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    # calibrate once (paper §3.3) on a short prefill
    shape = ShapeConfig("t9", seq_len=64, global_batch=2, kind="prefill")
    prompt = {k: v for k, v in M.make_inputs(cfg, shape, seq=64).items()
              if k != "labels"}
    _, st = M.prefill(params, prompt, cfg, max_seq=64)
    leaves = [np.asarray(jax.lax.bitcast_convert_type(x, jnp.uint16)).ravel()
              for x in jax.tree.leaves(st.cache) if x.dtype == jnp.bfloat16]
    cb = cbm.calibrate(leaves, k=16)

    for ctx in CONTEXTS:
        prompt = {k: v for k, v in
                  M.make_inputs(cfg, shape, seq=ctx).items() if k != "labels"}
        n_new = 8
        eng_c = DisaggregatedEngine(cfg, params, cb, compress=True)
        eng_n = DisaggregatedEngine(cfg, params, cb, compress=False)

        pre_c = eng_c.prefill(prompt, max_seq=ctx + n_new + 1)
        pre_n = eng_n.prefill(prompt, max_seq=ctx + n_new + 1)
        state_c = eng_c.transfer(pre_c.state)
        state_n = eng_n.transfer(pre_n.state)
        logit_diff = float(jnp.max(jnp.abs(
            pre_c.last_logits.astype(jnp.float32)
            - pre_n.last_logits.astype(jnp.float32))))
        toks_c = eng_c.decode(pre_c.first_token, state_c, n_new)
        toks_n = eng_n.decode(pre_n.first_token, state_n, n_new)
        # reconstruction errors: compare cache bits after transfer
        errors = 0
        for a, b in zip(jax.tree.leaves(state_c.cache),
                        jax.tree.leaves(state_n.cache)):
            if a.dtype == jnp.bfloat16:
                errors += int(jnp.sum(
                    jax.lax.bitcast_convert_type(a, jnp.uint16)
                    != jax.lax.bitcast_convert_type(b, jnp.uint16)))
        emit("table9", f"ctx{ctx}", dict(
            text_match=bool(jnp.all(toks_c == toks_n)),
            max_logit_diff=logit_diff,
            reconstruction_errors=errors))
