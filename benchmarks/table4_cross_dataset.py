"""Paper Table 4: cross-dataset calibration coverage (A->B vs B->B).

Dataset A is the default synthetic corpus ("wikitext-like"); the evaluation
"domains" vary the corpus statistics the way HumanEval / GSM8K / MMLU / PTB
vary text: token distribution sharpness and repetition structure.  Expected:
A->B coverage stays > 99% and nearly matches oracle B->B calibration.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import bench_config, generate_kv_bits, pooled_bits
from repro.core import codebook as cbm
from repro.training.data import DataConfig

DOMAINS = {
    "wikitext2": DataConfig(seed=0, zipf_a=1.2, repeat_p=0.25),
    "humaneval": DataConfig(seed=1, zipf_a=1.05, repeat_p=0.45),  # code: repetitive
    "gsm8k": DataConfig(seed=2, zipf_a=1.35, repeat_p=0.35),      # math: narrow
    "mmlu": DataConfig(seed=3, zipf_a=1.15, repeat_p=0.15),       # broad QA
    "ptb": DataConfig(seed=4, zipf_a=1.3, repeat_p=0.2),
}

MODELS = ["qwen3-32b", "llama3.2-3b", "qwen3-moe-30b-a3b"]


def run(emit) -> None:
    for arch in MODELS:
        cfg = bench_config(arch)
        bits_by_domain = {
            name: pooled_bits(generate_kv_bits(cfg, seq=256, batch=4,
                                               data_cfg=dc))
            for name, dc in DOMAINS.items()}
        cb_a = cbm.calibrate([bits_by_domain["wikitext2"]], k=16)
        for name, bits in bits_by_domain.items():
            a_to_b = cbm.coverage(cb_a, bits)
            cb_b = cbm.calibrate([bits], k=16)
            b_to_b = cbm.coverage(cb_b, bits)
            emit("table4", f"{arch}/{name}", dict(
                a_to_b=round(a_to_b, 5), b_to_b=round(b_to_b, 5),
                gap=round(b_to_b - a_to_b, 6)))
