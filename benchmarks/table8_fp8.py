"""Paper Table 8 (Appendix B): SplitZip on FP8 KV caches.

E4M3 top-8 / E5M2 top-8 / E5M2 top-16, reporting coverage, ratio vs native
FP8, ratio vs BF16, escape rate, and codec throughput.  Expected structure:
E4M3 top-8 *expands* (ratio < 1); E5M2 top-16 is the best FP8 setting.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_config, generate_kv_bits, gbps, pooled_bits, time_fn
from repro.core import codebook as cbm
from repro.core import fp8 as F8
from repro.core import wire


def _to_fp8_bits(bf16_bits: np.ndarray, fmt: str) -> np.ndarray:
    x = np.asarray(jax.lax.bitcast_convert_type(jnp.asarray(bf16_bits),
                                                jnp.bfloat16))
    dt = jnp.float8_e5m2 if fmt == "fp8_e5m2" else jnp.float8_e4m3fn
    x8 = jnp.asarray(x).astype(dt)
    return np.asarray(jax.lax.bitcast_convert_type(x8, jnp.uint8))


def run(emit) -> None:
    cfg = bench_config("qwen3-32b")
    bf16_bits = pooled_bits(generate_kv_bits(cfg, seq=512, batch=4))
    for var in F8.VARIANTS:
        bits8 = _to_fp8_bits(bf16_bits, var.fmt)
        cb = cbm.calibrate([bits8], k=var.k, fmt=var.fmt)
        payload, stats = wire.encode(bits8, cb)
        assert np.array_equal(wire.decode(payload), bits8)
        t_enc, _ = time_fn(lambda: wire.encode(bits8, cb), repeats=3)
        t_dec, _ = time_fn(lambda: wire.decode(payload), repeats=3)
        ratio_fp8 = stats.ratio
        ratio_bf16 = ratio_fp8 * 2.0  # fp8 already halves bf16
        emit("table8", f"{var.fmt}-top{var.k}", dict(
            coverage=round(cbm.coverage(cb, bits8), 5),
            ratio_vs_fp8=round(ratio_fp8, 4),
            ratio_vs_bf16=round(ratio_bf16, 4),
            escape_rate=round(stats.escape_rate, 5),
            enc_gbps=round(gbps(bits8.nbytes, t_enc), 3),
            dec_gbps=round(gbps(bits8.nbytes, t_dec), 3)))
