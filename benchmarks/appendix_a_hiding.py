"""Paper Appendix A: codec-hiding bandwidth threshold B_hide = min(G)/rho,
and the chunked-pipeline overlap schedule's steady-state behaviour."""

from __future__ import annotations

from repro.core.pipeline import (ChunkSchedule, CodecProfile, hiding_bandwidth,
                                 pipelined_transfer_time, stage_times)


def run(emit) -> None:
    p = CodecProfile(g_enc=613.3e9, g_dec=2181.8e9, ratio=1.324, link_bw=87.5e9)
    emit("appendixA", "b_hide", dict(
        b_hide_gbps=round(hiding_bandwidth(p) / 1e9, 1),
        paper_value=463.2))
    # pipeline overlap: at link <= B_hide the pipelined time ≈ pure transfer
    s = 1e9
    for bw in (12.5e9, 50e9, 87.5e9, 463.2e9, 900e9):
        pp = CodecProfile(p.g_enc, p.g_dec, p.ratio, bw)
        t_pipe = pipelined_transfer_time(s, pp, n_chunks=16)
        t_xfer = stage_times(s, pp)[1]
        emit("appendixA", f"bw{int(bw/1e9)}gbps", dict(
            pipelined_ms=round(t_pipe * 1e3, 3),
            pure_transfer_ms=round(t_xfer * 1e3, 3),
            codec_exposed=round(max(0.0, t_pipe / t_xfer - 1.0), 4),
            hidden=bool(bw <= hiding_bandwidth(pp))))
    sched = ChunkSchedule(4).stages()
    emit("appendixA", "schedule", dict(stages=len(sched), triples=str(sched[:4])))
