"""Paper Fig. 5 (§4.3.6): layer-wise coverage under one shared Top-16
codebook (separate K-cache and V-cache codebooks), Qwen3-32B-class model.

Expected: K codebook stable across layers (all > 99%); V codebook shows a
small low-coverage tail in early layers but median stays ~99.9%.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import bench_config, generate_kv_bits
from repro.core import codebook as cbm


def run(emit) -> None:
    cfg = bench_config("qwen3-32b", layers=16)
    kv = generate_kv_bits(cfg, seq=512, batch=2)
    k_bits = kv["cache/k"] if "cache/k" in kv else kv[[n for n in kv if n.endswith("k")][0]]
    v_bits = kv[[n for n in kv if n.endswith("v")][0]]

    for name, tensor in (("K", k_bits), ("V", v_bits)):
        # shared codebook from the aggregate distribution across all layers
        cb = cbm.calibrate([tensor], k=16)
        covs = [cbm.coverage(cb, tensor[l]) for l in range(tensor.shape[0])]
        emit("fig5", f"{name}-cache", dict(
            layers=len(covs),
            min_coverage=round(min(covs), 5),
            median_coverage=round(float(np.median(covs)), 5),
            layers_above_99=sum(1 for c in covs if c > 0.99),
            worst_layer=int(np.argmin(covs))))
