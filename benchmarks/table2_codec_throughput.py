"""Paper Table 2: codec throughput + ratio comparison.

CPU-hosted measurements (this container; TPU is the lowering target, so the
paper's absolute H200 GB/s are NOT comparable — the meaningful reproduction
is the *ordering and structure*: SplitZip's fixed-length design beats
variable-length (Huffman) and general-purpose (deflate/cascaded) codecs on
the encode+decode path, and the sentinel variant loses decode throughput).

Codecs measured:
  splitzip-wire   : numpy wire codec (production host path)
  splitzip-jax    : jitted in-graph codec (the XLA/TPU path, run on CPU)
  splitzip-kernel : Pallas kernels in interpret mode (correctness path;
                    interpret-mode timing is reported but flagged)
  top15-sentinel  : ZipServ-class fixed coding (ablation twin of Table 6)
  huffman-exp     : DFloat11/ZipNN-class exponent Huffman
  deflate         : zlib level 1 (nvCOMP-LZ4-class)
  cascaded        : byte-plane + delta + entropy stage (nvCOMP-Cascaded-class)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (CodecResult, bench_config, cascaded_roundtrip,
                               deflate_roundtrip, generate_kv_bits, gbps,
                               huffman_exponent_roundtrip, pooled_bits, time_fn)
from repro.core import codebook as cbm
from repro.core import codec as C
from repro.core import wire

WORKLOAD_ELEMS = 1 << 22  # 8 MiB of bf16 — CPU-scale stand-in for the 256MB


def _workload() -> np.ndarray:
    cfg = bench_config("qwen3-32b")
    kv = generate_kv_bits(cfg, seq=512, batch=4)
    bits = pooled_bits(kv)
    reps = int(np.ceil(WORKLOAD_ELEMS / bits.size))
    return np.tile(bits, reps)[:WORKLOAD_ELEMS]


def run(emit) -> None:
    bits = _workload()
    nbytes = bits.nbytes
    cb = cbm.calibrate([bits], k=16)
    results = []

    # --- splitzip wire (numpy host path) -----------------------------------
    payload, stats = wire.encode(bits, cb)
    assert np.array_equal(wire.decode(payload), bits)
    t_enc, s_enc = time_fn(lambda: wire.encode(bits, cb), repeats=5)
    t_dec, s_dec = time_fn(lambda: wire.decode(payload), repeats=5)
    results.append(CodecResult("splitzip-wire", stats.ratio,
                               gbps(nbytes, t_enc), gbps(nbytes, t_dec)))

    # --- splitzip in-graph (jitted XLA path) --------------------------------
    x = jax.lax.bitcast_convert_type(jnp.asarray(bits), jnp.bfloat16)
    enc_j = jax.jit(lambda v: C.encode(v, cb))
    ct = enc_j(x)
    dec_j = jax.jit(C.decode)
    y = dec_j(ct)
    assert bool(jnp.all(jax.lax.bitcast_convert_type(y, jnp.uint16)
                        == jnp.asarray(bits)))
    t_enc, _ = time_fn(lambda: enc_j(x), repeats=5)
    t_dec, _ = time_fn(lambda: dec_j(ct), repeats=5)
    results.append(CodecResult("splitzip-jax", float(C.compression_ratio(ct)),
                               gbps(nbytes, t_enc), gbps(nbytes, t_dec)))

    # --- top-15 + sentinel (ZipServ-class) ----------------------------------
    enc_s = jax.jit(lambda v: C.encode_sentinel(v, cb))
    st = enc_s(x)
    dec_s = jax.jit(C.decode_sentinel)
    ys = dec_s(st)
    assert bool(jnp.all(jax.lax.bitcast_convert_type(ys, jnp.uint16)
                        == jnp.asarray(bits)))
    ratio_s = nbytes / float(C.sentinel_bytes(st))
    t_enc, _ = time_fn(lambda: enc_s(x), repeats=5)
    t_dec, _ = time_fn(lambda: dec_s(st), repeats=5)
    results.append(CodecResult("top15-sentinel", ratio_s,
                               gbps(nbytes, t_enc), gbps(nbytes, t_dec)))

    # --- huffman exponents (DFloat11-class) ---------------------------------
    enc_h, dec_h, ratio_h = huffman_exponent_roundtrip(bits)
    sub_bytes = min(bits.size, 1 << 18) * 2  # the timed window
    t_enc, _ = time_fn(enc_h, repeats=3, warmup=1)
    t_dec, _ = time_fn(dec_h, repeats=3, warmup=1)
    results.append(CodecResult("huffman-exp", ratio_h,
                               gbps(sub_bytes, t_enc), gbps(sub_bytes, t_dec)))

    # --- deflate / cascaded ---------------------------------------------------
    for name, builder in [("deflate", deflate_roundtrip),
                          ("cascaded", cascaded_roundtrip)]:
        enc_f, dec_f, ratio_f = builder(bits)
        t_enc, _ = time_fn(enc_f, repeats=3, warmup=1)
        t_dec, _ = time_fn(dec_f, repeats=3, warmup=1)
        results.append(CodecResult(name, ratio_f,
                                   gbps(nbytes, t_enc), gbps(nbytes, t_dec)))

    fastest_other_enc = max(r.enc_gbps for r in results
                            if not r.name.startswith("splitzip"))
    for r in results:
        emit("table2", r.name, dict(
            ratio=round(r.ratio, 4), enc_gbps=round(r.enc_gbps, 3),
            dec_gbps=round(r.dec_gbps, 3)))
    sz = next(r for r in results if r.name == "splitzip-wire")
    emit("table2", "derived", dict(
        splitzip_enc_vs_fastest_other=round(sz.enc_gbps / fastest_other_enc, 2),
        note="CPU-hosted; paper structure check, not absolute H200 numbers"))
