"""Paper Table 2: codec throughput + ratio comparison.

CPU-hosted measurements (this container; TPU is the lowering target, so the
paper's absolute H200 GB/s are NOT comparable — the meaningful reproduction
is the *ordering and structure*: SplitZip's fixed-length design beats
variable-length (Huffman) and general-purpose (deflate/cascaded) codecs on
the encode+decode path, and the sentinel variant loses decode throughput).

Codecs measured:
  splitzip-wire   : numpy wire codec (production host path)
  splitzip-xla    : jitted in-graph codec (the XLA/TPU path, run on CPU)
  splitzip-pallas : Pallas kernels in interpret mode (correctness path;
                    interpret-mode timing is reported but flagged)
  top15-sentinel  : ZipServ-class fixed coding (ablation twin of Table 6)
  huffman-exp     : DFloat11/ZipNN-class exponent Huffman
  deflate         : zlib level 1 (nvCOMP-LZ4-class)
  cascaded        : byte-plane + delta + entropy stage (nvCOMP-Cascaded-class)

The three SplitZip rows are driven through the codec-backend registry
(``TransferConfig.backend`` -> :mod:`repro.core.backend`), the same dispatch
the serving engine uses — a backend added to the registry shows up here with
zero benchmark changes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (CodecResult, bench_config, cascaded_roundtrip,
                               deflate_roundtrip, generate_kv_bits, gbps,
                               huffman_exponent_roundtrip, pooled_bits, time_fn)
from repro.core import codebook as cbm
from repro.core import codec as C
from repro.serving.transfer import TransferConfig

SPLITZIP_BACKENDS = ("wire", "xla", "pallas")

WORKLOAD_ELEMS = 1 << 22  # 8 MiB of bf16 — CPU-scale stand-in for the 256MB


def _workload() -> np.ndarray:
    cfg = bench_config("qwen3-32b")
    kv = generate_kv_bits(cfg, seq=512, batch=4)
    bits = pooled_bits(kv)
    reps = int(np.ceil(WORKLOAD_ELEMS / bits.size))
    return np.tile(bits, reps)[:WORKLOAD_ELEMS]


def run(emit) -> None:
    bits = _workload()
    nbytes = bits.nbytes
    cb = cbm.calibrate([bits], k=16)
    results = []

    # --- splitzip via the codec-backend registry ---------------------------
    x = jax.lax.bitcast_convert_type(jnp.asarray(bits), jnp.bfloat16)
    for bname in SPLITZIP_BACKENDS:
        be = TransferConfig(codebook=cb, backend=bname).get_backend()
        if be.jittable:
            enc_f = jax.jit(lambda v, _be=be: _be.encode(v, cb))
            dec_f = jax.jit(lambda c, _be=be: _be.decode(c))
        else:
            enc_f = lambda v, _be=be: _be.encode(v, cb)
            dec_f = lambda c, _be=be: _be.decode(c)
        ct = enc_f(x)
        y = dec_f(ct)
        assert bool(jnp.all(jax.lax.bitcast_convert_type(
            jnp.asarray(y).reshape(-1), jnp.uint16) == jnp.asarray(bits)))
        ratio = be.raw_bytes(ct) / float(be.wire_bytes(ct))
        t_enc, _ = time_fn(lambda: enc_f(x), repeats=5)
        t_dec, _ = time_fn(lambda: dec_f(ct), repeats=5)
        results.append(CodecResult(f"splitzip-{bname}", ratio,
                                   gbps(nbytes, t_enc), gbps(nbytes, t_dec)))

    # --- top-15 + sentinel (ZipServ-class) ----------------------------------
    enc_s = jax.jit(lambda v: C.encode_sentinel(v, cb))
    st = enc_s(x)
    dec_s = jax.jit(C.decode_sentinel)
    ys = dec_s(st)
    assert bool(jnp.all(jax.lax.bitcast_convert_type(ys, jnp.uint16)
                        == jnp.asarray(bits)))
    ratio_s = nbytes / float(C.sentinel_bytes(st))
    t_enc, _ = time_fn(lambda: enc_s(x), repeats=5)
    t_dec, _ = time_fn(lambda: dec_s(st), repeats=5)
    results.append(CodecResult("top15-sentinel", ratio_s,
                               gbps(nbytes, t_enc), gbps(nbytes, t_dec)))

    # --- huffman exponents (DFloat11-class) ---------------------------------
    enc_h, dec_h, ratio_h = huffman_exponent_roundtrip(bits)
    sub_bytes = min(bits.size, 1 << 18) * 2  # the timed window
    t_enc, _ = time_fn(enc_h, repeats=3, warmup=1)
    t_dec, _ = time_fn(dec_h, repeats=3, warmup=1)
    results.append(CodecResult("huffman-exp", ratio_h,
                               gbps(sub_bytes, t_enc), gbps(sub_bytes, t_dec)))

    # --- deflate / cascaded ---------------------------------------------------
    for name, builder in [("deflate", deflate_roundtrip),
                          ("cascaded", cascaded_roundtrip)]:
        enc_f, dec_f, ratio_f = builder(bits)
        t_enc, _ = time_fn(enc_f, repeats=3, warmup=1)
        t_dec, _ = time_fn(dec_f, repeats=3, warmup=1)
        results.append(CodecResult(name, ratio_f,
                                   gbps(nbytes, t_enc), gbps(nbytes, t_dec)))

    fastest_other_enc = max(r.enc_gbps for r in results
                            if not r.name.startswith("splitzip"))
    for r in results:
        emit("table2", r.name, dict(
            ratio=round(r.ratio, 4), enc_gbps=round(r.enc_gbps, 3),
            dec_gbps=round(r.dec_gbps, 3)))
    sz = next(r for r in results if r.name == "splitzip-wire")
    emit("table2", "derived", dict(
        splitzip_enc_vs_fastest_other=round(sz.enc_gbps / fastest_other_enc, 2),
        note="CPU-hosted; paper structure check, not absolute H200 numbers"))
