"""Paper Table 2: codec throughput + ratio comparison.

CPU-hosted measurements (this container; TPU is the lowering target, so the
paper's absolute H200 GB/s are NOT comparable — the meaningful reproduction
is the *ordering and structure*: SplitZip's fixed-length design beats
variable-length (Huffman) and general-purpose (deflate/cascaded) codecs on
the encode+decode path, and the sentinel variant loses decode throughput).

Codecs measured:
  splitzip-wire           : numpy wire codec (production host path)
  splitzip-xla            : jitted in-graph codec (the XLA/TPU path, on CPU)
  splitzip-pallas         : fused single-pass Pallas kernels (interpret mode)
  splitzip-pallas-2stage  : pre-fusion dense kernel + XLA escape passes (A/B)
  top15-sentinel          : ZipServ-class fixed coding (Table 6 ablation twin)
  huffman-exp             : DFloat11/ZipNN-class exponent Huffman
  deflate                 : zlib level 1 (nvCOMP-LZ4-class)
  cascaded                : byte-plane + delta + entropy (nvCOMP-Cascaded)

The SplitZip rows are driven through the codec-backend registry
(``TransferConfig.backend`` -> :mod:`repro.core.backend`), the same dispatch
the serving engine uses — a backend added to the registry shows up here with
zero benchmark changes.

Beyond timing, the fused-vs-two-stage pair is a STRUCTURAL regression gate:
the lowered programs are inspected and the benchmark fails loudly if the
fused path stops being a single ``pallas_call`` per direction or grows an
XLA scatter tail (the launch-count / HBM-traffic property the fusion
exists for — interpret-mode wall-clock on CPU does not measure it).

A ``BENCH_codec.json`` snapshot (ratios, GB/s, launch structure) is written
next to this file so the codec-path perf trajectory is tracked PR over PR.
Set ``SPLITZIP_BENCH_SMOKE=1`` for the CI smoke mode: tiny synthetic
workload, SplitZip rows + structural assertions only.

Every run (smoke included) also serializes the SplitZip rows as CALIBRATED
CODEC PROFILES (``repro.core.profile``) to
``benchmarks/results/profiles.json`` — the measured ``g_enc``/``g_dec``/
``ratio`` per backend that the scheduler sweeps (``fig2_e2e_serving.py``)
and the serve launcher (``--profile measured``) load instead of the paper's
hand-entered H200 constants.  Provenance (workload size, repeats, smoke vs
full) travels with each entry.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (CodecResult, bench_config, cascaded_roundtrip,
                               deflate_roundtrip, generate_kv_bits, gbps,
                               huffman_exponent_roundtrip, pooled_bits, time_fn)
from repro.core import backend as B
from repro.core import codebook as cbm
from repro.core import codec as C
from repro.core.profile import CalibratedProfile, save_profiles
from repro.serving.plan import TransferPlan
from repro.serving.transfer import TransferConfig, transfer_cache_chunked

SPLITZIP_BACKENDS = ("wire", "xla", "pallas")

WORKLOAD_ELEMS = 1 << 22  # 8 MiB of bf16 — CPU-scale stand-in for the 256MB
SMOKE = bool(int(os.environ.get("SPLITZIP_BENCH_SMOKE", "0")))
SMOKE_ELEMS = 1 << 16

SNAPSHOT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_codec.json")
PROFILES_PATH = os.path.join(os.path.dirname(__file__), "results",
                             "profiles.json")


def _workload() -> np.ndarray:
    if SMOKE:
        # synthetic bf16-ish bits, no model prefill: exponents concentrated
        # on a top-16 band like real KV (keeps the smoke run seconds-scale)
        rng = np.random.default_rng(0)
        x = rng.standard_normal(SMOKE_ELEMS) * np.exp(rng.standard_normal(
            SMOKE_ELEMS))
        return np.asarray(jax.lax.bitcast_convert_type(
            jnp.asarray(x.astype(np.float32), dtype=jnp.bfloat16), jnp.uint16))
    cfg = bench_config("qwen3-32b")
    kv = generate_kv_bits(cfg, seq=512, batch=4)
    bits = pooled_bits(kv)
    reps = int(np.ceil(WORKLOAD_ELEMS / bits.size))
    return np.tile(bits, reps)[:WORKLOAD_ELEMS]


def _count_primitives(fn, *args) -> dict:
    """jaxpr-level structure of a codec call: pallas_call launches and
    full-stream scatter ops (the two-stage tail the fusion removes)."""
    names = []

    def walk(j):
        for eqn in j.eqns:
            names.append(eqn.primitive.name)
            for sub in eqn.params.values():
                if hasattr(sub, "jaxpr"):
                    walk(sub.jaxpr)
    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return {
        "pallas_calls": names.count("pallas_call"),
        "scatter_ops": sum(1 for p in names if p.startswith("scatter")),
        "total_primitives": len(names),
    }


def _hlo_scatter_count(fn, *args) -> int:
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return txt.count(" scatter(") + txt.count(" scatter.")


def _launch_structure(x, cb) -> dict:
    """Assert + report the fused path's single-launch structure vs two-stage."""
    be_f = B.PallasBackend()
    be_t = B.PallasBackend(fused=False)
    ct = be_f.encode(x, cb)
    out = {}
    for tag, be in (("fused", be_f), ("2stage", be_t)):
        enc = _count_primitives(lambda v, _be=be: _be.encode(v, cb), x)
        dec = _count_primitives(lambda c, _be=be: _be.decode(c), ct)
        dec["hlo_scatters"] = _hlo_scatter_count(
            lambda c, _be=be: _be.decode(c), ct)
        out[tag] = {"encode": enc, "decode": dec}
    # the acceptance assertions: one launch per direction, no scatter tail
    assert out["fused"]["encode"]["pallas_calls"] == 1, out
    assert out["fused"]["decode"]["pallas_calls"] == 1, out
    assert out["fused"]["encode"]["scatter_ops"] == 0, out
    assert out["fused"]["decode"]["scatter_ops"] == 0, out
    assert out["fused"]["decode"]["hlo_scatters"] == 0, out
    # ...and the contrast that makes the A/B meaningful
    assert out["2stage"]["decode"]["scatter_ops"] >= 1, out
    assert out["2stage"]["encode"]["scatter_ops"] >= 1, out
    return out


def _measure_backend(name: str, be, x, cb, bits, nbytes, repeats) -> CodecResult:
    if be.jittable:
        enc_f = jax.jit(lambda v, _be=be: _be.encode(v, cb))
        dec_f = jax.jit(lambda c, _be=be: _be.decode(c))
    else:
        enc_f = lambda v, _be=be: _be.encode(v, cb)
        dec_f = lambda c, _be=be: _be.decode(c)
    ct = enc_f(x)
    y = dec_f(ct)
    assert bool(jnp.all(jax.lax.bitcast_convert_type(
        jnp.asarray(y).reshape(-1), jnp.uint16) == jnp.asarray(bits)))
    ratio = be.raw_bytes(ct) / float(be.wire_bytes(ct))
    t_enc, _ = time_fn(lambda: enc_f(x), repeats=repeats)
    t_dec, _ = time_fn(lambda: dec_f(ct), repeats=repeats)
    return CodecResult(name, ratio, gbps(nbytes, t_enc), gbps(nbytes, t_dec))


def _planned_vs_legacy_transfer(x, cb, nbytes, repeats) -> dict:
    """Plan/execute API vs the one-shot shim on the chunked local engine.

    The shim rebuilds the TransferPlan (route resolution, segmentation,
    capacity schedule) on EVERY call; the session builds it once and reuses
    it — the compile-once/run-many win of the plan API, measured on the same
    bit-exact pipeline.  Also reports the per-call wire bytes so the row
    doubles as a ratio regression gate."""
    cache = {"kv": x}
    tc = TransferConfig(codebook=cb, backend="xla", n_chunks=8)
    sess = TransferPlan.build(cache, tc).session()

    def _planned():
        out = sess.transfer(cache)
        jax.block_until_ready(jax.tree.leaves(out))

    def _legacy():
        out, _ = transfer_cache_chunked(cache, tc)
        jax.block_until_ready(jax.tree.leaves(out))

    _planned(); _legacy()   # warmup (jit caches shared: same shapes)
    t_planned, _ = time_fn(_planned, repeats=repeats)
    t_legacy, _ = time_fn(_legacy, repeats=repeats)
    stats = sess.last_stats
    return dict(
        planned_gbps=round(gbps(nbytes, t_planned), 3),
        legacy_gbps=round(gbps(nbytes, t_legacy), 3),
        planned_vs_legacy=round(t_legacy / max(t_planned, 1e-12), 3),
        n_chunks=len(stats.chunk_wire_bytes),
        wire_ratio=round(nbytes / max(stats.wire_bytes, 1.0), 4),
        retries=stats.n_retries)


def _wire_verify_overhead(x, cb, nbytes, repeats) -> dict:
    """Checksum-frame verification cost on the production host path: the
    same SZ02 payload decoded with per-frame Fletcher-32 verification on vs
    off.  The delta is the receiver-side integrity tax the ``verify=`` knob
    buys — the sender always writes the frames since SZ02, so encode pays
    once unconditionally and only the decode choice is a knob."""
    be = B.get_backend("wire")
    bev = B.get_backend("wire-verify")
    ct = be.encode(x, cb)
    be.decode(ct); bev.decode(ct)           # warmup
    t_off, _ = time_fn(lambda: be.decode(ct), repeats=repeats)
    t_on, _ = time_fn(lambda: bev.decode(ct), repeats=repeats)
    return dict(dec_gbps_verify_off=round(gbps(nbytes, t_off), 3),
                dec_gbps_verify_on=round(gbps(nbytes, t_on), 3),
                verify_overhead=round(t_on / max(t_off, 1e-12), 3))


def run(emit) -> None:
    bits = _workload()
    nbytes = bits.nbytes
    cb = cbm.calibrate([bits], k=16)
    repeats = 2 if SMOKE else 5
    results = []

    # --- splitzip via the codec-backend registry ---------------------------
    x = jax.lax.bitcast_convert_type(jnp.asarray(bits), jnp.bfloat16)
    for bname in SPLITZIP_BACKENDS:
        be = TransferConfig(codebook=cb, backend=bname).get_backend()
        results.append(_measure_backend(
            f"splitzip-{bname}", be, x, cb, bits, nbytes, repeats))
    # the A/B twin: pre-fusion two-stage structure, same stream layout
    results.append(_measure_backend(
        "splitzip-pallas-2stage", B.PallasBackend(fused=False), x, cb, bits,
        nbytes, repeats))

    # --- calibrated codec profiles (repro.core.profile) ---------------------
    # serialize the measured SplitZip rows so the scheduler sweeps and the
    # serve launcher run from THESE numbers instead of paper constants
    source = "table2-smoke" if SMOKE else "table2"
    cals = [CalibratedProfile.from_throughput(
                r.name.split("-", 1)[1], "bf16", r.enc_gbps, r.dec_gbps,
                r.ratio, workload_elems=int(bits.size), repeats=repeats,
                source=source)
            for r in results
            if r.name in {f"splitzip-{b}" for b in SPLITZIP_BACKENDS}]
    profiles_path = save_profiles(cals, PROFILES_PATH)
    emit("table2", "calibrated-profiles", dict(
        path=os.path.relpath(profiles_path), n=len(cals), source=source))

    # --- planned vs legacy transfer (plan/execute API regression row) -------
    transfer_row = _planned_vs_legacy_transfer(x, cb, nbytes, repeats)
    emit("table2", "transfer-planned-vs-legacy", transfer_row)

    # --- wire integrity: verified-decode overhead (ISSUE 7) -----------------
    verify_row = _wire_verify_overhead(x, cb, nbytes, repeats)
    emit("table2", "wire-verify-overhead", verify_row)

    # --- fused launch structure (the property the fusion exists for) --------
    structure = _launch_structure(x, cb)
    emit("table2", "launch-structure", dict(
        fused_enc_launches=structure["fused"]["encode"]["pallas_calls"],
        fused_dec_launches=structure["fused"]["decode"]["pallas_calls"],
        fused_dec_scatters=structure["fused"]["decode"]["scatter_ops"],
        twostage_dec_scatters=structure["2stage"]["decode"]["scatter_ops"],
        fused_enc_primitives=structure["fused"]["encode"]["total_primitives"],
        twostage_enc_primitives=structure["2stage"]["encode"][
            "total_primitives"]))

    if not SMOKE:
        # --- top-15 + sentinel (ZipServ-class) ------------------------------
        enc_s = jax.jit(lambda v: C.encode_sentinel(v, cb))
        st = enc_s(x)
        dec_s = jax.jit(C.decode_sentinel)
        ys = dec_s(st)
        assert bool(jnp.all(jax.lax.bitcast_convert_type(ys, jnp.uint16)
                            == jnp.asarray(bits)))
        ratio_s = nbytes / float(C.sentinel_bytes(st))
        t_enc, _ = time_fn(lambda: enc_s(x), repeats=5)
        t_dec, _ = time_fn(lambda: dec_s(st), repeats=5)
        results.append(CodecResult("top15-sentinel", ratio_s,
                                   gbps(nbytes, t_enc), gbps(nbytes, t_dec)))

        # --- huffman exponents (DFloat11-class) -----------------------------
        enc_h, dec_h, ratio_h = huffman_exponent_roundtrip(bits)
        sub_bytes = min(bits.size, 1 << 18) * 2  # the timed window
        t_enc, _ = time_fn(enc_h, repeats=3, warmup=1)
        t_dec, _ = time_fn(dec_h, repeats=3, warmup=1)
        results.append(CodecResult("huffman-exp", ratio_h,
                                   gbps(sub_bytes, t_enc), gbps(sub_bytes, t_dec)))

        # --- deflate / cascaded ---------------------------------------------
        for name, builder in [("deflate", deflate_roundtrip),
                              ("cascaded", cascaded_roundtrip)]:
            enc_f, dec_f, ratio_f = builder(bits)
            t_enc, _ = time_fn(enc_f, repeats=3, warmup=1)
            t_dec, _ = time_fn(dec_f, repeats=3, warmup=1)
            results.append(CodecResult(name, ratio_f,
                                       gbps(nbytes, t_enc), gbps(nbytes, t_dec)))

    for r in results:
        emit("table2", r.name, dict(
            ratio=round(r.ratio, 4), enc_gbps=round(r.enc_gbps, 3),
            dec_gbps=round(r.dec_gbps, 3)))
    fused = next(r for r in results if r.name == "splitzip-pallas")
    twostage = next(r for r in results if r.name == "splitzip-pallas-2stage")
    derived = dict(
        fused_vs_2stage_enc=round(fused.enc_gbps / max(twostage.enc_gbps,
                                                       1e-9), 3),
        fused_vs_2stage_dec=round(fused.dec_gbps / max(twostage.dec_gbps,
                                                       1e-9), 3),
        note=("interpret-mode wall clock: the structural columns "
              "(launches/scatters) carry the TPU claim, not CPU GB/s"))
    if not SMOKE:
        fastest_other_enc = max(r.enc_gbps for r in results
                                if not r.name.startswith("splitzip"))
        sz = next(r for r in results if r.name == "splitzip-wire")
        derived["splitzip_enc_vs_fastest_other"] = round(
            sz.enc_gbps / fastest_other_enc, 2)
    emit("table2", "derived", derived)

    if SMOKE:
        # smoke runs are structural gates on tiny data; never overwrite the
        # tracked full-workload snapshot with incomparable numbers
        emit("table2", "snapshot", dict(skipped="smoke mode"))
        return
    snapshot = {
        "workload_elems": int(bits.size),
        "launch_structure": structure,
        "transfer": transfer_row,
        "wire_verify": verify_row,
        "codecs": {r.name: dict(ratio=round(r.ratio, 4),
                                enc_gbps=round(r.enc_gbps, 3),
                                dec_gbps=round(r.dec_gbps, 3))
                   for r in results},
        "derived": derived,
    }
    with open(SNAPSHOT_PATH, "w") as f:
        json.dump(snapshot, f, indent=1, sort_keys=True)
        f.write("\n")
    emit("table2", "snapshot", dict(path=os.path.relpath(SNAPSHOT_PATH)))
