"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run [--only table2,fig3]`` prints CSV lines
``table,row,key=value,...`` and writes benchmarks/results/benchmarks.json.
"""

from __future__ import annotations

import argparse
import importlib
import json
import os
import time
import traceback

MODULES = [
    "table1_exponent_stats",
    "table2_codec_throughput",
    "table3_topk_ablation",
    "table4_cross_dataset",
    "table5_granularity",
    "table6_escape_metadata",
    "table7_precalibration",
    "table8_fp8",
    "table9_lossless_check",
    "fig2_e2e_serving",
    "fig3_transfer_sweeps",
    "fig4_breakdown",
    "fig5_layerwise",
    "fig6_resident_capacity",
    "appendix_a_hiding",
    # needs 8 host devices: run as its own process (CI --only xpod_chunked);
    # skips gracefully inside a full in-process sweep
    "xpod_chunked_smoke",
    # bulk-data plane: checkpoint round-trip + 2-pod ring_reduce
    # (CI --only bulkplane; the ring leg skips below 2 host devices)
    "bulkplane_smoke",
]

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "results",
                            "benchmarks.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated module substrings to run")
    args = ap.parse_args()
    only = [s for s in args.only.split(",") if s]

    rows = []

    def emit(table: str, row: str, values: dict) -> None:
        rows.append({"table": table, "row": row, **values})
        kv = ",".join(f"{k}={v}" for k, v in values.items())
        print(f"{table},{row},{kv}", flush=True)

    failures = 0
    for name in MODULES:
        if only and not any(s in name for s in only):
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            mod.run(emit)
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()}", flush=True)

    os.makedirs(os.path.dirname(RESULTS_PATH), exist_ok=True)
    with open(RESULTS_PATH, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# wrote {len(rows)} rows -> {RESULTS_PATH}; {failures} module failures")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
