"""Paper-style Fig. 6 (ISSUE 8): decode-worker capacity and attention step
throughput with compressed-resident KV.

Two claims, measured against the repo's own pool/kernel (models/kvpool.py,
kernels/splitzip_attention.py):

1. **Capacity** — max concurrent sequences at a fixed decode-worker HBM
   budget.  The compressed-resident footprint comes from the pool's OWN
   page accounting (``KVPool.page_bytes`` — dense streams + escape
   metadata — plus the always-allocated raw tail page and page tables);
   the raw footprint is the bf16 cache.  At >=4096-token context the paged
   format holds >=1.25x the sequences of raw residency.
2. **Step throughput** — one fused-attention decode step over compressed
   pages vs rehydrate-then-attend over the same admitted state.  CPU
   interpret-mode wall clock (table2's standing caveat applies: the
   structural win — no full-cache decompress materialization — carries the
   accelerator claim; CPU numbers are shape-level evidence, not GB/s).

The ``resident`` section is MERGED into ``benchmarks/BENCH_codec.json``
(read-modify-write: table2 owns the rest of the snapshot and overwrites the
file wholesale, so this module must never write anything but its own key).

Standalone: ``python -m benchmarks.fig6_resident_capacity``; smoke via
``SPLITZIP_BENCH_SMOKE=1`` (tiny context, no snapshot write).
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_fn
from repro.configs.base import get_config
from repro.core import codebook as cbm
from repro.core.backend import resolve_backend
from repro.models import kvcache as KC
from repro.models import kvpool as KVP
from repro.models import model as M
from repro.serving.plan import TransferConfig, TransferPlan
from repro.serving.session import encode_leaves

SMOKE = bool(int(os.environ.get("SPLITZIP_BENCH_SMOKE", "0")))
SNAPSHOT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_codec.json")

HBM_BYTES = 16 << 30          # per-decode-worker KV budget
CONTEXT = 4096                # tokens per resident sequence (paper regime)


def _capacity_row(arch: str) -> dict:
    """Sequences-at-fixed-HBM from the pool's real page accounting."""
    cfg = get_config(arch)
    chunk = 1024
    # geometry only: shapes drive every byte count, so one-token-deep
    # abstract leaves suffice — no giant cache materialization
    cache = jax.eval_shape(
        lambda: KC.init_cache(cfg, 1, max(CONTEXT, 8 * chunk)))
    tp = KVP.tokens_per_page_for(cache, chunk)
    ctx = -(-CONTEXT // tp) * tp

    raw_per_seq = comp_per_seq = 0
    for key, leaf in cache.items():
        m = int(np.prod(leaf.shape[3:])) if len(leaf.shape) > 3 else 1
        L = leaf.shape[0]
        itemsize = jnp.dtype(leaf.dtype).itemsize
        raw_per_seq += L * ctx * m * itemsize
        pe = tp * m
        cap = max(8, pe // KVP.ESC_SLOT_PER_ELEMS)
        page_bytes = pe + pe // 2 + cap * 3 + 4
        pages = ctx // tp
        tail = tp * m * itemsize                   # raw growth page
        table = pages * 4
        comp_per_seq += L * (pages * page_bytes + tail + table)

    seqs_raw = HBM_BYTES // raw_per_seq
    seqs_comp = HBM_BYTES // comp_per_seq
    return dict(
        arch=arch, context=ctx, tokens_per_page=tp,
        raw_mib_per_seq=round(raw_per_seq / 2**20, 2),
        resident_mib_per_seq=round(comp_per_seq / 2**20, 2),
        max_seqs_raw=int(seqs_raw), max_seqs_resident=int(seqs_comp),
        capacity_ratio=round(seqs_comp / max(1, seqs_raw), 4))


def _throughput_row() -> dict:
    """Fused step over pages vs rehydrate-then-attend, same admitted state."""
    cfg = get_config("smollm-135m").reduced()
    S = 128 if SMOKE else 512
    B = 2
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S - 8)), jnp.int32)
    _, st0 = M.prefill(params, {"tokens": toks}, cfg, max_seq=S)
    bits = np.concatenate(
        [np.asarray(jax.lax.bitcast_convert_type(v, jnp.uint16)).ravel()
         for v in st0.cache.values()])
    cb = cbm.calibrate(bits, k=16)
    backend = resolve_backend("xla", require_jittable=True)
    pool = KVP.KVPool.for_cache(st0.cache, cb, backend, chunk=1024,
                                page_bytes=2048)
    tc = TransferConfig(codebook=cb, chunk=1024, backend="xla")
    comp, _ = encode_leaves(TransferPlan.build(st0.cache, tc), st0.cache)
    rs = pool.admit_from_wire(comp, st0.cache_len)
    tok = jnp.zeros((B, 1), jnp.int32)

    fused = jax.jit(lambda t, s: M.resident_decode_step(
        params, t, s, cfg, interpret=True)[0])

    def rehydrated_step(t, s):
        cache = pool.rehydrate(s)                  # full-cache decompress
        st = KC.DecodeState(cache=cache, cache_len=s.cache_len)
        return M.decode_step(params, t, st, cfg)[0]

    rehydrate = jax.jit(rehydrated_step)
    reps = 2 if SMOKE else 5
    t_fused, _ = time_fn(lambda: jax.block_until_ready(fused(tok, rs)),
                         repeats=reps, warmup=1)
    t_reh, _ = time_fn(lambda: jax.block_until_ready(rehydrate(tok, rs)),
                       repeats=reps, warmup=1)
    return dict(
        context=S, batch=B,
        fused_step_ms=round(t_fused * 1e3, 3),
        rehydrate_step_ms=round(t_reh * 1e3, 3),
        fused_vs_rehydrate=round(t_reh / max(t_fused, 1e-12), 4),
        note="CPU interpret-mode wall clock; structural claim is "
             "zero full-cache decompress in the fused path")


def run(emit) -> None:
    caps = [_capacity_row(a) for a in ("qwen3-32b", "smollm-135m")]
    for row in caps:
        emit("fig6", f"capacity/{row['arch']}", dict(row))
    thr = _throughput_row()
    emit("fig6", "step_throughput", dict(thr))

    head = caps[0]
    assert head["capacity_ratio"] >= 1.25, (
        f"resident capacity ratio {head['capacity_ratio']} < 1.25 at "
        f"{head['context']}-token context")

    if SMOKE:
        emit("fig6", "snapshot", dict(skipped="smoke mode"))
        return
    # merge (never overwrite) the shared snapshot
    snapshot = {}
    if os.path.exists(SNAPSHOT_PATH):
        with open(SNAPSHOT_PATH) as f:
            snapshot = json.load(f)
    snapshot["resident"] = {
        "hbm_gib": HBM_BYTES >> 30,
        "capacity": {row["arch"]: {k: v for k, v in row.items()
                                   if k != "arch"} for row in caps},
        "step_throughput": thr,
    }
    with open(SNAPSHOT_PATH, "w") as f:
        json.dump(snapshot, f, indent=1, sort_keys=True)
        f.write("\n")
    emit("fig6", "snapshot", dict(path=os.path.relpath(SNAPSHOT_PATH)))


def main() -> None:
    def emit(table, row, values):
        kv = ",".join(f"{k}={v}" for k, v in values.items())
        print(f"{table},{row},{kv}", flush=True)

    run(emit)


if __name__ == "__main__":
    main()
