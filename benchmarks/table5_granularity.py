"""Paper Table 5: calibration granularity (per-tensor / per-token /
per-channel).  Expected: finer granularity gains ~0.06% coverage but loses
orders of magnitude of throughput (many small codebooks, irregular access)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_config, generate_kv_bits, gbps, time_fn
from repro.configs.base import get_config
from repro.core import codebook as cbm
from repro.core import wire


def run(emit) -> None:
    cfg = bench_config("qwen3-32b")
    kv = generate_kv_bits(cfg, seq=256, batch=2)
    # one representative K tensor: (L, B, S, H, D) -> (tokens, channels)
    name = next(iter(kv))
    t = kv[name]
    t2 = t.reshape(-1, t.shape[-2] * t.shape[-1]) if t.ndim >= 2 else t.reshape(-1, 1)
    t2 = t2[: 2048]                                  # bounded token count
    nbytes = t2.nbytes

    # per-tensor
    cb = cbm.calibrate([t2], k=16)
    payload, stats = wire.encode(t2, cb)
    t_enc, _ = time_fn(lambda: wire.encode(t2, cb), repeats=3)
    t_dec, _ = time_fn(lambda: wire.decode(payload), repeats=3)
    emit("table5", "per-tensor", dict(
        coverage=round(cbm.coverage(cb, t2), 5), ratio=round(stats.ratio, 4),
        enc_gbps=round(gbps(nbytes, t_enc), 4),
        dec_gbps=round(gbps(nbytes, t_dec), 4)))

    # per-token / per-channel: many small codebooks, encoded slice-by-slice
    for label, axis in [("per-token", 0), ("per-channel", 1)]:
        books = cbm.calibrate_per_axis(t2, axis=axis, k=16)
        n = t2.shape[axis]
        covs = []
        payloads = []

        def enc_all():
            out = []
            for i in range(n):
                sl = np.take(t2, i, axis=axis)
                out.append(wire.encode(sl, books[i])[0])
            return out

        payloads = enc_all()

        def dec_all():
            return [wire.decode(p) for p in payloads]

        for i in range(n):
            covs.append(cbm.coverage(books[i], np.take(t2, i, axis=axis)))
        total_payload = sum(len(p) for p in payloads)
        t_enc, _ = time_fn(enc_all, repeats=1, warmup=1)
        t_dec, _ = time_fn(dec_all, repeats=1, warmup=1)
        emit("table5", label, dict(
            coverage=round(float(np.mean(covs)), 5),
            ratio=round(t2.nbytes / total_payload, 4),
            enc_gbps=round(gbps(nbytes, t_enc), 4),
            dec_gbps=round(gbps(nbytes, t_dec), 4)))

    # --- resident page-size sweep (ISSUE 8) --------------------------------
    # Granularity of the compressed-resident pool: small pages waste escape
    # metadata (cap floor) and page-table entries and lengthen the kernel's
    # sequential page walk; large pages waste HBM in the half-empty tail
    # page every growing sequence holds.  The sweep justifies
    # kvpool.DEFAULT_PAGE_BYTES (32 KiB): capacity at a 4096-token context
    # is within ~1% of the best page size while the per-page decode tile
    # stays VMEM-sized.
    from repro.models import kvpool as KVP

    full = get_config("qwen3-32b")
    m = full.num_kv_heads * full.head_dim        # elems/token, one leaf
    ctx = 4096
    cache_geom = {
        "k": jax.ShapeDtypeStruct((full.num_layers, 1, ctx,
                                   full.num_kv_heads, full.head_dim),
                                  jnp.bfloat16)}
    for kib in (4, 8, 16, 32, 64, 128):
        tp = KVP.tokens_per_page_for(cache_geom, 1024, kib * 1024)
        bpt = KVP.bytes_per_token_resident(m, tp)
        # per-sequence: full pages + table + the half-full tail page (raw)
        pages = ctx // tp
        per_seq = pages * bpt * tp + pages * 4 + tp * m * 2 / 2
        raw_seq = ctx * m * 2
        emit("table5", f"page/{kib}KiB", dict(
            tokens_per_page=tp,
            bytes_per_token=round(bpt, 3),
            tail_waste_pct=round(100 * (tp * m) / (ctx * m * 2 / 2), 3),
            capacity_ratio=round(raw_seq / per_seq, 4),
            default=int(kib * 1024 == KVP.DEFAULT_PAGE_BYTES)))
