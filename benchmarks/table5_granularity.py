"""Paper Table 5: calibration granularity (per-tensor / per-token /
per-channel).  Expected: finer granularity gains ~0.06% coverage but loses
orders of magnitude of throughput (many small codebooks, irregular access)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import bench_config, generate_kv_bits, gbps, time_fn
from repro.core import codebook as cbm
from repro.core import wire


def run(emit) -> None:
    cfg = bench_config("qwen3-32b")
    kv = generate_kv_bits(cfg, seq=256, batch=2)
    # one representative K tensor: (L, B, S, H, D) -> (tokens, channels)
    name = next(iter(kv))
    t = kv[name]
    t2 = t.reshape(-1, t.shape[-2] * t.shape[-1]) if t.ndim >= 2 else t.reshape(-1, 1)
    t2 = t2[: 2048]                                  # bounded token count
    nbytes = t2.nbytes

    # per-tensor
    cb = cbm.calibrate([t2], k=16)
    payload, stats = wire.encode(t2, cb)
    t_enc, _ = time_fn(lambda: wire.encode(t2, cb), repeats=3)
    t_dec, _ = time_fn(lambda: wire.decode(payload), repeats=3)
    emit("table5", "per-tensor", dict(
        coverage=round(cbm.coverage(cb, t2), 5), ratio=round(stats.ratio, 4),
        enc_gbps=round(gbps(nbytes, t_enc), 4),
        dec_gbps=round(gbps(nbytes, t_dec), 4)))

    # per-token / per-channel: many small codebooks, encoded slice-by-slice
    for label, axis in [("per-token", 0), ("per-channel", 1)]:
        books = cbm.calibrate_per_axis(t2, axis=axis, k=16)
        n = t2.shape[axis]
        covs = []
        payloads = []

        def enc_all():
            out = []
            for i in range(n):
                sl = np.take(t2, i, axis=axis)
                out.append(wire.encode(sl, books[i])[0])
            return out

        payloads = enc_all()

        def dec_all():
            return [wire.decode(p) for p in payloads]

        for i in range(n):
            covs.append(cbm.coverage(books[i], np.take(t2, i, axis=axis)))
        total_payload = sum(len(p) for p in payloads)
        t_enc, _ = time_fn(enc_all, repeats=1, warmup=1)
        t_dec, _ = time_fn(dec_all, repeats=1, warmup=1)
        emit("table5", label, dict(
            coverage=round(float(np.mean(covs)), 5),
            ratio=round(t2.nbytes / total_payload, 4),
            enc_gbps=round(gbps(nbytes, t_enc), 4),
            dec_gbps=round(gbps(nbytes, t_dec), 4)))
