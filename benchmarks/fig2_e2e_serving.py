"""Paper Fig. 2: end-to-end serving sweeps (TTFT / request throughput) with
SplitZip enabled vs native, via the disaggregated scheduler.

Expected: gains grow with sequence length as transfer dominates TTFT;
slight slowdowns in the small-payload regime from fixed codec overheads.
"""

from __future__ import annotations

from repro.configs.base import get_config
from repro.core.pipeline import CodecProfile
from repro.serving.scheduler import (DisaggregatedScheduler, Request,
                                     SchedulerConfig, summarize)

LINK_BW = 25e9


def _run(seq: int, batch: int, compress: bool) -> dict:
    cfg = get_config("qwen3-32b")
    bpt = cfg.num_layers * 2 * cfg.num_kv_heads * cfg.head_dim * 2
    sched = DisaggregatedScheduler(SchedulerConfig(
        max_prefill_batch=batch,
        kv_bytes_per_token=bpt,
        prefill_time_per_token=1e-6,
        decode_time_per_step=5e-3,
        profile=CodecProfile(g_enc=613.3e9, g_dec=2181.8e9, ratio=1.324,
                             link_bw=LINK_BW, fixed_overhead_s=1e-4),
        compress=compress))
    for i in range(64):
        sched.submit(Request(rid=i, arrival=i * 2e-3, prompt_len=seq,
                             max_new_tokens=64))
    return summarize(sched.run())


def run(emit) -> None:
    for batch, seqs in ((1, (512, 4096, 32768, 131072)),
                        (16, (128, 1024, 8192, 65536))):
        for seq in seqs:
            with_c = _run(seq, batch, True)
            without = _run(seq, batch, False)
            emit("fig2", f"b{batch}/seq{seq}", dict(
                ttft_speedup=round(without["mean_ttft_s"]
                                   / max(with_c["mean_ttft_s"], 1e-12), 4),
                reqs_speedup=round(with_c["throughput_req_s"]
                                   / max(without["throughput_req_s"], 1e-12), 4)))
