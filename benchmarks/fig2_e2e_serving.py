"""Paper Fig. 2: end-to-end serving sweeps (TTFT / request throughput) with
SplitZip enabled vs native, via the disaggregated scheduler.

The scheduler is plan-aware (ISSUE 4): per prompt-length bucket it builds a
real :class:`~repro.serving.plan.TransferPlan` from the arch config's actual
cache structure (qwen3-32b k/v leaves) and charges every transfer through
``plan.estimate_time`` — the same plan objects the execution path runs — so
the Fig. 2 numbers flow through the codec's real routing/segmentation, not a
hand-rolled equal-chunk byte model.

Expected: gains grow with sequence length as transfer dominates TTFT;
slight slowdowns in the small-payload regime from fixed codec overheads.

``SPLITZIP_BENCH_SMOKE=1`` (CI): a reduced sweep that still exercises the
plan-aware admission path end to end and asserts bucket plans were built.
"""

from __future__ import annotations

import os

from repro.configs.base import get_config
from repro.core.pipeline import CodecProfile
from repro.serving.plan import TransferPlan
from repro.serving.scheduler import (DisaggregatedScheduler, Request,
                                     SchedulerConfig, summarize)

LINK_BW = 25e9
SMOKE = bool(int(os.environ.get("SPLITZIP_BENCH_SMOKE", "0")))


def _run(seq: int, batch: int, compress: bool, n_requests: int) -> dict:
    cfg = get_config("qwen3-32b")
    sched = DisaggregatedScheduler(SchedulerConfig(
        max_prefill_batch=batch,
        arch=cfg,                       # bucket plans from the REAL cache
        prefill_time_per_token=1e-6,    # structure (k/v bf16 leaves)
        decode_time_per_step=5e-3,
        profile=CodecProfile(g_enc=613.3e9, g_dec=2181.8e9, ratio=1.324,
                             link_bw=LINK_BW, fixed_overhead_s=1e-4),
        compress=compress))
    for i in range(n_requests):
        sched.submit(Request(rid=i, arrival=i * 2e-3, prompt_len=seq,
                             max_new_tokens=64))
    out = summarize(sched.run())
    # the plan-aware path must actually have been exercised: one reused
    # TransferPlan per prompt-length bucket, built from the arch cache
    assert sched.plans and all(isinstance(p, TransferPlan)
                               for p in sched.plans.values())
    return out


def run(emit) -> None:
    if SMOKE:
        sweeps = ((1, (4096, 32768)), (16, (1024, 8192)))
        n_requests = 8
    else:
        sweeps = ((1, (512, 4096, 32768, 131072)),
                  (16, (128, 1024, 8192, 65536)))
        n_requests = 64
    for batch, seqs in sweeps:
        for seq in seqs:
            with_c = _run(seq, batch, True, n_requests)
            without = _run(seq, batch, False, n_requests)
            emit("fig2", f"b{batch}/seq{seq}", dict(
                ttft_speedup=round(without["mean_ttft_s"]
                                   / max(with_c["mean_ttft_s"], 1e-12), 4),
                reqs_speedup=round(with_c["throughput_req_s"]
                                   / max(without["throughput_req_s"], 1e-12), 4)))
