"""Paper Fig. 2: end-to-end serving sweeps (TTFT / request throughput) with
SplitZip enabled vs native, via the disaggregated scheduler.

The scheduler is plan-aware (ISSUE 4): per prompt-length bucket it builds a
real :class:`~repro.serving.plan.TransferPlan` from the arch config's actual
cache structure (qwen3-32b k/v leaves) and charges every transfer through
``plan.estimate_time`` — the same plan objects the execution path runs — so
the Fig. 2 numbers flow through the codec's real routing/segmentation, not a
hand-rolled equal-chunk byte model.

Since ISSUE 5 the cost model is measurement-driven too: the
:class:`~repro.core.pipeline.CodecProfile` is loaded from the CALIBRATED
``benchmarks/results/profiles.json`` that ``table2_codec_throughput.py``
writes from real codec runs (``repro.core.profile``); when no calibration
exists yet, a small workload is measured on the spot and cached there.  The
profile's provenance string is emitted with the sweep.

CPU-hosted absolute GB/s are not comparable to the paper's H200 numbers
(table2's standing caveat), so the sweep is TIME-DILATED into the paper's
regime: the link bandwidth and every simulation time constant scale by the
measured-to-paper encoder ratio, preserving the paper's codec-to-link
proportions while the measured profile supplies the enc:dec:ratio shape.
Reported speedups are unit-free; absolute times are emitted in
paper-equivalent milliseconds (dilation divided back out).

The link is policy-driven (``repro.serving.policy``): ``run`` sweeps the
registered admission policies (FIFO, shortest-transfer-first, EDF,
speculative admission) over a mixed-length contended trace and reports
mean/p99 TTFT per policy next to the classic compressed-vs-native rows.

Standalone: ``python -m benchmarks.fig2_e2e_serving [--policy sjf]``
restricts the sweep to one policy (CI runs ``--policy sjf`` in smoke mode).

``--chaos`` (ISSUE 7) runs the fault-injection smoke instead of the sweeps:
a contended mixed-length trace on two decode workers under a seeded
:class:`~repro.serving.faults.FaultPlan` — one decode worker killed mid-run,
a link brownout over the middle of the trace, and deliberately-infeasible
deadlines on part of the trace under the ``edf-shed`` policy.  Fault timing
is derived from a fault-free dry run's measured makespan so the kill lands
mid-run at ANY dilation.  The run must complete with every request terminal
in exactly one state, nonzero shed AND failover counters, and conserved
link accounting — CI fails otherwise.

Expected: gains grow with sequence length as transfer dominates TTFT;
slight slowdowns in the small-payload regime from fixed codec overheads;
SJF trades the longest prompts' tail for mean TTFT on mixed traces.

``SPLITZIP_BENCH_SMOKE=1`` (CI): a reduced sweep that still exercises the
plan-aware admission path end to end and asserts bucket plans were built.
"""

from __future__ import annotations

import argparse
import os

from repro.configs.base import get_config
from repro.models.kvpool import bytes_per_token_resident
from repro.core.profile import (PAPER_G_ENC, CalibratedProfile,
                                resolve_calibration)
from repro.serving.cluster import ClusterConfig, LinkSpec
from repro.serving.faults import FaultPlan, LinkBrownout, WorkerKill
from repro.serving.plan import TransferPlan
from repro.serving.policy import available_policies
from repro.serving.scheduler import (DisaggregatedScheduler, Request,
                                     SchedulerConfig, summarize)
from repro.serving.traces import TraceConfig, generate_trace

#: the Fig. 2 operating point: the paper pairs its H200 encoder with a
#: 25 GB/s (200GbE-class) link, i.e. g_enc/B ≈ 24.5 — that PROPORTION is
#: what defines the regime, not the absolute GB/s
PAPER_LINK_BW = 25e9
SMOKE = bool(int(os.environ.get("SPLITZIP_BENCH_SMOKE", "0")))
PROFILES_PATH = os.path.join(os.path.dirname(__file__), "results",
                             "profiles.json")


def _calibration() -> CalibratedProfile:
    """The calibrated xla-backend measurement from ``profiles.json``
    (written by the table2 benchmark); measures a small workload on the
    spot — and caches it there — when no calibration exists yet.  Same
    resolution (and same schema-mismatch strictness) as
    ``--profile measured``: one code path, ``resolve_calibration``."""
    return resolve_calibration(PROFILES_PATH, backend="xla",
                               source="fig2-on-demand")


def _profile_and_dilation():
    """(CodecProfile, dilation): the measured codec time-dilated into the
    paper's regime.  ``dilation`` is how much slower the measured encoder is
    than the paper's; the link and every sim time constant scale by it, so
    speedups are regime-faithful and absolute times divide back out."""
    cal = _calibration()
    dil = PAPER_G_ENC / cal.g_enc
    profile = cal.profile(PAPER_LINK_BW / dil, fixed_overhead_s=1e-4 * dil)
    return profile, dil


def _sched(batch: int, compress: bool, profile, dil: float,
           policy: str = "fifo", slo_s=None,
           admit_latency_s: float = 0.0) -> DisaggregatedScheduler:
    cfg = get_config("qwen3-32b")
    return DisaggregatedScheduler(SchedulerConfig(
        max_prefill_batch=batch,
        arch=cfg,                       # bucket plans from the REAL cache
        prefill_time_per_token=1e-6 * dil,  # structure (k/v bf16 leaves)
        decode_time_per_step=5e-3 * dil,
        profile=profile,
        compress=compress,
        policy=policy,
        slo_s=slo_s,
        admit_latency_s=admit_latency_s))


def _run(seq: int, batch: int, compress: bool, n_requests: int,
         profile, dil: float) -> dict:
    sched = _sched(batch, compress, profile, dil)
    for i in range(n_requests):
        sched.submit(Request(rid=i, arrival=i * 2e-3 * dil, prompt_len=seq,
                             max_new_tokens=64))
    out = summarize(sched.run())
    # the plan-aware path must actually have been exercised: one reused
    # TransferPlan per prompt-length bucket, built from the arch cache
    assert sched.plans and all(isinstance(p, TransferPlan)
                               for p in sched.plans.values())
    return out


def _run_policy(policy: str, profile, dil: float, n_requests: int) -> dict:
    """One contended mixed-length trace under ``policy``: long and short
    prompts interleave so link ordering actually matters."""
    # one decode step of slot-setup cost: the wait 'spec' overlaps with
    # the transfer (with 0 latency a single FIFO link makes spec == fifo)
    sched = _sched(batch=4, compress=True, profile=profile, dil=dil,
                   policy=policy, slo_s=2.0 * dil,
                   admit_latency_s=5e-3 * dil)
    lens = (65536, 1024, 8192, 2048)
    for i in range(n_requests):
        sched.submit(Request(rid=i, arrival=i * 1e-3 * dil,
                             prompt_len=lens[i % len(lens)],
                             max_new_tokens=16))
    return summarize(sched.run())


def _chaos_trace(n: int, dil: float) -> list:
    """Contended mixed-length trace where every 4th request carries a
    provably-infeasible deadline (far below any possible transfer + decode
    step), so ``edf-shed`` MUST shed it and serve the rest."""
    lens = (65536, 1024, 8192, 2048)
    reqs = []
    for i in range(n):
        r = Request(rid=i, arrival=i * 1e-3 * dil,
                    prompt_len=lens[i % len(lens)], max_new_tokens=16)
        if i % 4 == 3:
            r.deadline = r.arrival + 1e-6 * dil
        reqs.append(r)
    return reqs


def _chaos_sched(profile, dil: float, faults, heartbeat_s: float):
    cfg = get_config("qwen3-32b")
    return DisaggregatedScheduler(SchedulerConfig(
        max_prefill_batch=4, arch=cfg,
        prefill_time_per_token=1e-6 * dil,
        decode_time_per_step=5e-3 * dil,
        profile=profile, compress=True, policy="edf-shed",
        n_decode_workers=2, faults=faults,
        heartbeat_timeout_s=heartbeat_s))


def run_chaos(emit) -> None:
    """The fault-injection smoke: seeded chaos over the contended trace.

    Raises (CI-fatal) unless the run completes with every request terminal
    in exactly one of completed/shed/failed-over, nonzero shed AND failover
    counters, and link accounting conserved across the failovers."""
    profile, dil = _profile_and_dilation()
    n = 16 if SMOKE else 64

    # fault-free dry run: measure the trace's natural makespan so the
    # brownout lands mid-run whatever the calibration dilation is
    dry = _chaos_sched(profile, dil, None, heartbeat_s=1.0)
    for r in _chaos_trace(n, dil):
        dry.submit(r)
    span = max(r.finish_time for r in dry.run())
    brown = LinkBrownout(start=0.2 * span, stop=0.6 * span, factor=0.5)

    # brownout-only rehearsal: the event engine is deterministic and a kill
    # changes nothing before it fires, so this run's timing is IDENTICAL to
    # the chaos run up to the kill — placing the kill (and its detection
    # point) inside a decode-residency interval observed here guarantees a
    # resident is caught on the dead worker, at any dilation
    reh = _chaos_sched(profile, dil, FaultPlan(seed=7, brownouts=(brown,)),
                       heartbeat_s=1.0)
    for r in _chaos_trace(n, dil):
        reh.submit(r)
    occ = [(r.admit_time, r.finish_time) for r in reh.run()
           if r.worker == 0 and r.state == "completed"]
    assert occ, "rehearsal put no request on decode worker 0"
    a, b = max(occ, key=lambda ab: ab[1] - ab[0])
    heartbeat_s = (b - a) * 0.1             # detection at a + 0.35*(b-a) < b

    plan = FaultPlan(
        seed=7, corrupt_p=0.01,
        worker_kills=(WorkerKill(worker=0, at=a + (b - a) * 0.25),),
        brownouts=(brown,))
    sched = _chaos_sched(profile, dil, plan, heartbeat_s=heartbeat_s)
    for r in _chaos_trace(n, dil):
        sched.submit(r)
    done = sched.run()

    assert len(done) == n, f"{n - len(done)} requests not terminal"
    bad = [r.rid for r in done
           if r.state not in ("completed", "shed", "failed-over")]
    assert not bad, f"requests without terminal state: {bad}"
    assert sched.sheds > 0, "chaos trace shed nothing"
    assert sched.failovers > 0, "worker kill caused no failover"
    ivals = sorted(i for r in done for i in r.link_history)
    drift = abs(sched.link_busy_s - sum(b - a for a, b in ivals))
    assert drift < 1e-9, f"link accounting drifted by {drift}"
    assert all(b <= a + 1e-12 for (_, b), (a, _) in zip(ivals, ivals[1:])), \
        "link occupancy intervals overlap"

    out = summarize(done)
    emit("fig2", "chaos", dict(
        n=n, served=out["n"], n_shed=int(out["n_shed"]),
        n_failed_over=int(out["n_failed_over"]),
        n_retries=int(out["n_retries"]),
        mean_ttft_ms=round(out["mean_ttft_s"] / dil * 1e3, 3),
        link_conserved=1))


# --- fleet sweep (ISSUE 10) -------------------------------------------------

def _fleet_cluster(prefix_cache: bool) -> ClusterConfig:
    """The benchmark topology: 2 prefill x 3 decode over two heterogeneous
    links (a full-rate FIFO link and a half-rate SJF link), transfer-aware
    routing, and an optionally-enabled per-worker prefix directory."""
    return ClusterConfig(
        n_prefill=2, n_decode=3,
        links=(LinkSpec(policy="fifo"),
               LinkSpec(policy="sjf", bw_scale=0.5)),
        router="transfer-aware",
        prefix_cache_bytes=(64 * (1 << 30)) if prefix_cache else None)


def _fleet_sched(profile, dil: float, cluster: ClusterConfig,
                 faults=None, heartbeat_s: float = 1.0):
    cfg = get_config("qwen3-32b")
    return DisaggregatedScheduler(SchedulerConfig(
        max_prefill_batch=4, arch=cfg,
        prefill_time_per_token=1e-6 * dil,
        decode_time_per_step=5e-3 * dil,
        profile=profile, compress=True,
        cluster=cluster, faults=faults,
        heartbeat_timeout_s=heartbeat_s))


def _fleet_trace(n: int, dil: float, warm: bool) -> list:
    """A seeded multi-tenant trace, time-dilated into the sim's regime.
    ``warm`` turns on shared-prefix sessions (the agentic/multi-turn shape);
    the cold variant keeps everything else identical."""
    reqs = generate_trace(TraceConfig(
        seed=11, n_requests=n, session_p=0.6 if warm else 0.0,
        prompt_max=2048, max_open_sessions=6))
    for r in reqs:
        r.arrival *= dil
        if r.deadline is not None:
            r.deadline *= dil
    return reqs


def _fleet_run(n: int, profile, dil: float, *, warm: bool,
               prefix_cache: bool, faults=None, heartbeat_s: float = 1.0):
    sched = _fleet_sched(profile, dil, _fleet_cluster(prefix_cache),
                         faults=faults, heartbeat_s=heartbeat_s)
    for r in _fleet_trace(n, dil, warm):
        sched.submit(r)
    done = sched.run()
    assert len(done) == n, f"{n - len(done)} requests not terminal"
    _assert_links_conserved(sched, done)
    return sched, done


def _assert_links_conserved(sched, done) -> None:
    """Per-link conservation: each link's busy counter equals the sum of the
    disjoint occupancy intervals its transfers actually held."""
    per = [[] for _ in range(len(sched.link_busy_by_link))]
    for r in done:
        for li, iv in zip(r.link_ids, r.link_history):
            per[li].append(iv)
    for li, ivals in enumerate(per):
        ivals.sort()
        drift = abs(sched.link_busy_by_link[li]
                    - sum(b - a for a, b in ivals))
        assert drift < 1e-9, f"link {li} accounting drifted by {drift}"
        assert all(b <= a + 1e-12
                   for (_, b), (a, _) in zip(ivals, ivals[1:])), \
            f"link {li} occupancy intervals overlap"


def run_fleet(emit) -> None:
    """The N x M fleet sweep: warm (shared-prefix) vs cold traces on the
    heterogeneous two-link topology, self-asserting that prefix-aware delta
    transfer moves fewer wire bytes on the warm trace."""
    profile, dil = _profile_and_dilation()
    n = 24 if SMOKE else 96

    warm_on, done_w = _fleet_run(n, profile, dil, warm=True,
                                 prefix_cache=True)
    warm_off, _ = _fleet_run(n, profile, dil, warm=True, prefix_cache=False)
    cold_on, _ = _fleet_run(n, profile, dil, warm=False, prefix_cache=True)

    assert warm_on.prefix_hit_bytes > 0, \
        "shared-prefix trace produced no prefix hits"
    assert cold_on.prefix_hit_bytes == 0, \
        "cold trace must not hit the prefix cache"
    assert warm_on.transfer_bytes < warm_off.transfer_bytes, \
        "prefix-delta transfer did not reduce wire bytes on the warm trace"
    # hits are counted at full raw size, so on + hits == off exactly
    total_on = warm_on.transfer_bytes + warm_on.prefix_hit_bytes
    assert abs(total_on - warm_off.transfer_bytes) \
        <= 1e-6 * warm_off.transfer_bytes, \
        "prefix accounting does not decompose (shipped + hit != full)"

    out = summarize(done_w)
    for row, sched in (("fleet/warm", warm_on), ("fleet/warm_nocache",
                                                 warm_off),
                       ("fleet/cold", cold_on)):
        emit("fig2", row, dict(
            n=n, transfer_gib=round(sched.transfer_bytes / (1 << 30), 4),
            prefix_hit_gib=round(sched.prefix_hit_bytes / (1 << 30), 4),
            link0_busy_s=round(sched.link_busy_by_link[0] / dil, 4),
            link1_busy_s=round(sched.link_busy_by_link[1] / dil, 4)))
    emit("fig2", "fleet/summary", dict(
        mean_ttft_ms=round(out["mean_ttft_s"] / dil * 1e3, 3),
        p99_ttft_ms=round(out["p99_ttft_s"] / dil * 1e3, 3),
        wire_saved_pct=round(100.0 * warm_on.prefix_hit_bytes
                             / max(total_on, 1e-12), 2)))


def run_fleet_chaos(emit) -> None:
    """Fleet chaos: a prefill-worker kill, a decode-worker kill, and a
    brownout pinned to ONE of the two links, over the warm fleet trace.
    Self-asserting: every request terminal, both failover tiers exercised,
    per-link conservation, and traffic visibly shifted off the browned link."""
    profile, dil = _profile_and_dilation()
    n = 24 if SMOKE else 96

    # fault-free dry run: natural makespan + a decode-residency interval on
    # worker 0 and the first prefill batch's in-flight window, so every
    # fault lands where it must at ANY calibration dilation
    dry, done_dry = _fleet_run(n, profile, dil, warm=True, prefix_cache=True)
    span = max(r.finish_time for r in done_dry)
    first_arr = min(r.arrival for r in done_dry)
    first_pd = min(r.prefill_done for r in done_dry)
    occ = [(r.admit_time, r.finish_time) for r in done_dry
           if r.worker == 0 and r.state == "completed"]
    assert occ, "dry run put no request on decode worker 0"
    a, b = max(occ, key=lambda ab: ab[1] - ab[0])
    heartbeat_s = min((b - a), (first_pd - first_arr)) * 0.1

    plan = FaultPlan(
        seed=13,
        worker_kills=(
            WorkerKill(worker=0, at=first_arr + (first_pd - first_arr) * 0.25,
                       role="prefill"),
            WorkerKill(worker=0, at=a + (b - a) * 0.25, role="decode")),
        brownouts=(LinkBrownout(start=0.2 * span, stop=0.7 * span,
                                factor=0.25, link=1),))
    sched, done = _fleet_run(n, profile, dil, warm=True, prefix_cache=True,
                             faults=plan, heartbeat_s=heartbeat_s)

    bad = [r.rid for r in done
           if r.state not in ("completed", "shed", "failed-over")]
    assert not bad, f"requests without terminal state: {bad}"
    assert sched.prefill_failovers > 0, \
        "prefill-worker kill re-routed nothing"
    assert sched.failovers > 0, "decode-worker kill caused no failover"
    assert sched.link_busy_by_link[0] > sched.link_busy_by_link[1], \
        "brownout on link 1 did not shift traffic to link 0"

    out = summarize(done)
    emit("fig2", "fleet_chaos", dict(
        n=n, served=out["n"], n_shed=int(out["n_shed"]),
        n_failed_over=int(out["n_failed_over"]),
        prefill_failovers=sched.prefill_failovers,
        link0_busy_s=round(sched.link_busy_by_link[0] / dil, 4),
        link1_busy_s=round(sched.link_busy_by_link[1] / dil, 4),
        links_conserved=1))


def run(emit, policy: str | None = None) -> None:
    profile, dil = _profile_and_dilation()
    emit("fig2", "profile", dict(source=profile.source,
                                 g_enc_gbps=round(profile.g_enc / 1e9, 4),
                                 g_dec_gbps=round(profile.g_dec / 1e9, 4),
                                 ratio=round(profile.ratio, 4),
                                 dilation=round(dil, 1)))
    if SMOKE:
        sweeps = ((1, (4096, 32768)), (16, (1024, 8192)))
        n_requests = 8
    else:
        sweeps = ((1, (512, 4096, 32768, 131072)),
                  (16, (128, 1024, 8192, 65536)))
        n_requests = 64
    for batch, seqs in sweeps:
        for seq in seqs:
            with_c = _run(seq, batch, True, n_requests, profile, dil)
            without = _run(seq, batch, False, n_requests, profile, dil)
            emit("fig2", f"b{batch}/seq{seq}", dict(
                ttft_speedup=round(without["mean_ttft_s"]
                                   / max(with_c["mean_ttft_s"], 1e-12), 4),
                reqs_speedup=round(with_c["throughput_req_s"]
                                   / max(without["throughput_req_s"], 1e-12), 4)))

    # --- HBM-derived decode capacity (ISSUE 8) -----------------------------
    # At a fixed decode-worker HBM budget the slot budget is derived from
    # the resident KV footprint (SchedulerConfig.derived_decode_slots):
    # 'raw' sizes a slot by the bf16 cache (2 B/elem), 'compressed' by the
    # paged SplitZip format (kvpool.bytes_per_token_resident — 1.5 B/elem
    # dense streams + page escape metadata).  Under contention the extra
    # slots turn directly into request throughput.
    cfg_arch = get_config("qwen3-32b")
    m_tok = (cfg_arch.num_layers * 2
             * cfg_arch.num_kv_heads * cfg_arch.head_dim)
    raw_bpt = 2.0 * m_tok
    comp_bpt = bytes_per_token_resident(m_tok, 1024)
    hbm = 16 << 30                       # 16 GiB/worker reserved for KV
    n_cap = 24 if SMOKE else 96
    caps = {}
    for label, bpt in (("raw", raw_bpt), ("compressed", comp_bpt)):
        sched = _sched(batch=4, compress=True, profile=profile, dil=dil)
        sched.cfg.hbm_bytes_per_worker = hbm
        sched.cfg.resident_bytes_per_token = bpt
        sched.cfg.slot_tokens = 8192
        sched.max_decode_slots = sched.cfg.derived_decode_slots()
        for i in range(n_cap):
            sched.submit(Request(rid=i, arrival=i * 1e-4 * dil,
                                 prompt_len=4096, max_new_tokens=32))
        caps[label] = (sched.max_decode_slots, summarize(sched.run()))
    (slots_r, out_r), (slots_c, out_c) = caps["raw"], caps["compressed"]
    emit("fig2", "resident_capacity", dict(
        hbm_gib=hbm >> 30, slot_tokens=8192,
        slots_raw=slots_r, slots_compressed=slots_c,
        slots_ratio=round(slots_c / max(1, slots_r), 4),
        reqs_speedup=round(out_c["throughput_req_s"]
                           / max(out_r["throughput_req_s"], 1e-12), 4)))

    # --- admission-policy sweep (ISSUE 5) ----------------------------------
    policies = (policy,) if policy else available_policies()
    n_policy = 16 if SMOKE else 64
    for name in policies:
        out = _run_policy(name, profile, dil, n_policy)
        # paper-equivalent times: the dilation divided back out
        emit("fig2", f"policy/{name}", dict(
            mean_ttft_ms=round(out["mean_ttft_s"] / dil * 1e3, 3),
            p99_ttft_ms=round(out["p99_ttft_s"] / dil * 1e3, 3),
            req_s=round(out["throughput_req_s"] * dil, 3)))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--policy", default=None, choices=available_policies(),
                    help="restrict the admission-policy sweep to one policy")
    ap.add_argument("--chaos", action="store_true",
                    help="run the seeded fault-injection smoke instead of "
                         "the sweeps (asserts shed/failover counters)")
    ap.add_argument("--fleet", action="store_true",
                    help="run the N x M fleet sweep (multi-tenant traces, "
                         "prefix-aware delta transfer); with --chaos, the "
                         "fleet fault-injection smoke")
    args = ap.parse_args(argv)

    def emit(table: str, row: str, values: dict) -> None:
        kv = ",".join(f"{k}={v}" for k, v in values.items())
        print(f"{table},{row},{kv}", flush=True)

    if args.fleet and args.chaos:
        run_fleet_chaos(emit)
    elif args.fleet:
        run_fleet(emit)
    elif args.chaos:
        run_chaos(emit)
    else:
        run(emit, policy=args.policy)


if __name__ == "__main__":
    main()
