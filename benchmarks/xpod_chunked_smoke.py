"""Cross-pod chunked transfer smoke: the planned mesh path on a CPU mesh.

Exercises the acceptance property of the plan/execute API: a
``TransferPlan`` executed on a multi-pod mesh with ``n_chunks > 1`` (per-
chunk ``lax.ppermute``, double-buffered inside ``shard_map``) reproduces
``transfer_cache_cross_pod`` semantics bit-identically to the whole-tensor
path, and the per-chunk collectives move the same compressed payload (HLO
collective-permute bytes are compared).

CI runs this with ``SPLITZIP_BENCH_SMOKE=1`` (tiny cache) as
``python -m benchmarks.run --only xpod_chunked`` — its own process, so the
host-device override below takes effect before jax initializes.  In a full
benchmark sweep where jax already initialized with < 8 devices, the module
reports a skip instead of failing.
"""

from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8").strip()

import jax                                                        # noqa: E402
import jax.numpy as jnp                                           # noqa: E402
import numpy as np                                                # noqa: E402

SMOKE = bool(int(os.environ.get("SPLITZIP_BENCH_SMOKE", "0")))


def run(emit) -> None:
    if jax.device_count() < 8:
        emit("xpod_chunked", "skipped",
             dict(reason=f"needs 8 host devices, have {jax.device_count()} "
                         "(run as its own process)"))
        return

    from repro.analysis.roofline import collective_bytes_from_hlo
    from repro.core import codebook as cbm
    from repro.launch.mesh import make_mesh
    from repro.serving.plan import TransferConfig, TransferPlan

    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    rng = np.random.default_rng(0)
    seq = 64 if SMOKE else 256

    def kv_like(shape):
        x = rng.normal(size=shape) * rng.choice([0.25, 1.0, 4.0], size=shape)
        return jnp.asarray(x, dtype=jnp.bfloat16)

    cache = {"k": kv_like((2, 4, seq, 2, 16)), "v": kv_like((2, 4, seq, 2, 16)),
             "ssm": jnp.asarray(rng.normal(size=(2, 4, 32)), jnp.float32)}
    cb = cbm.calibrate(
        [np.asarray(jax.lax.bitcast_convert_type(cache["k"], jnp.uint16))],
        k=16)

    def run_one(n_chunks):
        tc = TransferConfig(codebook=cb, chunk=256, cap=16, n_chunks=n_chunks,
                            compress_fp32=True)
        sess = TransferPlan.build(cache, tc, mesh=mesh).session()
        out = sess.transfer(cache)
        colls = collective_bytes_from_hlo(sess.lower_hlo(cache))
        return out, colls["collective-permute"]

    whole, whole_bytes = run_one(1)
    piped, piped_bytes = run_one(4)

    def bits(t):
        return [np.asarray(jax.lax.bitcast_convert_type(
            x, jnp.uint16 if x.dtype.itemsize == 2 else jnp.uint32))
            for x in jax.tree.leaves(t)]

    exact_in = all(np.array_equal(a, b) for a, b in zip(bits(cache), bits(piped)))
    exact_whole = all(np.array_equal(a, b)
                      for a, b in zip(bits(whole), bits(piped)))
    assert exact_in, "chunked mesh transfer must be bit-exact vs input"
    assert exact_whole, "chunked mesh transfer must match whole-tensor path"

    emit("xpod_chunked", "parity", dict(
        bit_exact_vs_input=exact_in, bit_exact_vs_whole_tensor=exact_whole,
        whole_permute_bytes=int(whole_bytes),
        chunked_permute_bytes=int(piped_bytes),
        n_chunks=4, mesh="pod2,data2,model2"))
