"""Shared benchmark infrastructure: KV-activation generation, timing, and
baseline codecs (paper §4.1 comparison set, reimplemented as algorithms).

All KV tensors are authentic model activations: we run the repo's own model
implementations (bench-scale configs of the right family) over the synthetic
corpus and harvest the caches — the same tensors the serving path transfers.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig, get_config
from repro.models import model as M
from repro.training.data import DataConfig, SyntheticTokenStream

# ---------------------------------------------------------------------------
# KV generation
# ---------------------------------------------------------------------------


def bench_config(arch: str, layers: int = 8) -> ArchConfig:
    """Mid-size same-family config: rich enough statistics, CPU-friendly."""
    full = get_config(arch)
    red = full.reduced()
    return dataclasses.replace(
        red, name=full.name + "-bench",
        num_layers=min(layers, full.num_layers)
        if red.hybrid is None else 3,
        d_model=256,
        num_heads=8 if red.num_heads else 0,
        num_kv_heads=4 if red.num_kv_heads else 0,
        head_dim=32,
        d_ff=512,
        vocab_size=min(full.vocab_size, 2048),
    )


def generate_kv_bits(cfg: ArchConfig, seq: int = 256, batch: int = 4,
                     seed: int = 0, data_cfg: DataConfig = DataConfig()
                     ) -> Dict[str, np.ndarray]:
    """Run prefill over the synthetic corpus; return {leaf_name: u16 bits}."""
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    shape = ShapeConfig("bench", seq_len=seq, global_batch=batch, kind="prefill")
    stream = SyntheticTokenStream(cfg, shape, data_cfg)
    batch_data = {k: v for k, v in stream.batch_at(0).items() if k != "labels"}
    if cfg.encoder_only:
        # encoder output is the shipped artifact
        logits, _, _ = M.forward(params, {**batch_data,
                                          "labels": jnp.zeros((batch, seq), jnp.int32)},
                                 cfg, kv_block=128)
        return {"encoder_out": np.asarray(jax.lax.bitcast_convert_type(
            logits.astype(jnp.bfloat16), jnp.uint16))}
    _, state = M.prefill(params, batch_data, cfg, max_seq=seq, kv_block=128)
    out = {}
    flat = jax.tree_util.tree_flatten_with_path(state.cache)[0]
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if leaf.dtype == jnp.bfloat16:
            out[name] = np.asarray(jax.lax.bitcast_convert_type(leaf, jnp.uint16))
    return out


def pooled_bits(kv: Dict[str, np.ndarray]) -> np.ndarray:
    return np.concatenate([v.ravel() for v in kv.values()])


# ---------------------------------------------------------------------------
# timing
# ---------------------------------------------------------------------------

def time_fn(fn: Callable[[], object], repeats: int = 5, warmup: int = 2
            ) -> Tuple[float, float]:
    """Returns (mean_seconds, std_seconds) over ``repeats`` runs."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())  # handles arbitrary pytrees + host values
        times.append(time.perf_counter() - t0)
    return float(np.mean(times)), float(np.std(times))


def gbps(nbytes: int, seconds: float) -> float:
    return nbytes / max(seconds, 1e-12) / 1e9


# ---------------------------------------------------------------------------
# baseline codecs (paper §4.1): algorithms reimplemented, CPU-hosted
# ---------------------------------------------------------------------------

def deflate_roundtrip(bits: np.ndarray):
    """General-purpose LZ+Huffman (zlib) — the nvCOMP-LZ4-class baseline."""
    import zlib
    raw = bits.tobytes()
    comp = zlib.compress(raw, level=1)

    def enc():
        return zlib.compress(raw, level=1)

    def dec():
        return zlib.decompress(comp)

    ratio = len(raw) / len(comp)
    return enc, dec, ratio


def cascaded_roundtrip(bits: np.ndarray):
    """nvCOMP-Cascaded-style: byte-plane split + delta + zlib entropy stage."""
    import zlib
    lo = (bits & 0xFF).astype(np.uint8)
    hi = (bits >> 8).astype(np.uint8)

    def enc():
        d_hi = np.diff(hi.ravel(), prepend=hi.ravel()[:1])
        return zlib.compress(lo.tobytes(), 1), zlib.compress(d_hi.tobytes(), 1)

    c_lo, c_hi = enc()

    def dec():
        lo2 = np.frombuffer(zlib.decompress(c_lo), np.uint8)
        d_hi2 = np.frombuffer(zlib.decompress(c_hi), np.uint8)
        hi2 = np.cumsum(d_hi2.astype(np.uint8), dtype=np.uint8)
        return (hi2.astype(np.uint16) << 8) | lo2

    ratio = bits.nbytes / (len(c_lo) + len(c_hi))
    return enc, dec, ratio


def build_huffman(freqs: Dict[int, int]) -> Dict[int, str]:
    """Canonical Huffman codebook (DFloat11/ZipNN-class exponent coder)."""
    import heapq
    heap = [(f, i, {s: ""}) for i, (s, f) in enumerate(freqs.items()) if f > 0]
    heap = [(f, i, d) for f, i, d in heap]
    heapq.heapify(heap)
    counter = len(heap)
    if len(heap) == 1:
        _, _, d = heap[0]
        return {s: "0" for s in d}
    while len(heap) > 1:
        f1, _, d1 = heapq.heappop(heap)
        f2, _, d2 = heapq.heappop(heap)
        merged = {s: "0" + c for s, c in d1.items()}
        merged.update({s: "1" + c for s, c in d2.items()})
        heapq.heappush(heap, (f1 + f2, counter, merged))
        counter += 1
    return heap[0][2]


def huffman_exponent_roundtrip(bits: np.ndarray):
    """DFloat11-style: Huffman-coded exponents + raw sign/mantissa bytes.

    Encode is table-driven numpy (variable-length pack via bit counting);
    decode walks the bitstream sequentially — the sequential dependency the
    paper identifies as the GPU parallelism blocker."""
    from repro.core.codebook import extract_exponents, extract_sign_mantissa, reassemble
    e = extract_exponents(bits)
    a = extract_sign_mantissa(bits)
    freqs = {int(v): int(c) for v, c in zip(*np.unique(e, return_counts=True))}
    book = build_huffman(freqs)
    lens = np.zeros(256, np.int64)
    for s, c in book.items():
        lens[s] = len(c)

    def enc():
        # vectorized size computation + python bit pack (encode cost dominated
        # by the bitstream assembly, as in CPU-side DFloat11)
        code_strs = [book[int(v)] for v in e[: min(e.size, 1 << 18)]]
        return "".join(code_strs)

    stream = enc()
    total_bits = int(lens[e].sum())

    def dec():
        # sequential prefix walk (decode a bounded window for timing)
        inv = {c: s for s, c in book.items()}
        out = []
        cur = ""
        for ch in stream[: 1 << 18]:
            cur += ch
            if cur in inv:
                out.append(inv[cur])
                cur = ""
        return out

    ratio = bits.nbytes / (a.nbytes + total_bits / 8)
    return enc, dec, ratio


@dataclasses.dataclass
class CodecResult:
    name: str
    ratio: float
    enc_gbps: float
    dec_gbps: float
    enc_std: float = 0.0
    dec_std: float = 0.0
    lossless_verified: bool = True
