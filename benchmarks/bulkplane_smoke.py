"""Bulk-data plane smoke: checkpoint save/restore + 2-pod ring_reduce.

Exercises the two executors the refactor added to ``TransferSession``:

* **persistent** — a train-state pytree (bf16 params, fp32 optimizer
  moments, int step) round-trips through ``session.save``/``session.load``
  bit-exactly via ``distributed/checkpoint.Checkpointer``, and a corrupted
  frame falls back to the previous step (the fallback is driven by
  Fletcher-32 + ``WireIntegrityError``, not ad-hoc hashing).
* **collective** — ``compressed_cross_pod_mean`` rides
  ``session.ring_reduce`` on a 2-pod CPU mesh and matches the ``jnp.mean``
  all-reduce bitwise, with plan-derived wire accounting.

CI runs this with ``SPLITZIP_BENCH_SMOKE=1`` as
``python -m benchmarks.run --only bulkplane`` — its own process, so the
host-device override below takes effect before jax initializes.
"""

from __future__ import annotations

import os
import shutil
import tempfile

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8").strip()

import jax                                                        # noqa: E402
import jax.numpy as jnp                                           # noqa: E402
import numpy as np                                                # noqa: E402

SMOKE = bool(int(os.environ.get("SPLITZIP_BENCH_SMOKE", "0")))


def run(emit) -> None:
    from repro.distributed import checkpoint as CKPT
    from repro.launch.mesh import make_mesh
    from repro.training import grad_compress as GC

    rng = np.random.default_rng(0)
    dim = 128 if SMOKE else 512

    # -- persistent executor: checkpoint round-trip + corruption fallback ----
    state = {"params": {"w": jnp.asarray(rng.normal(size=(dim, dim)),
                                         jnp.bfloat16)},
             "opt": {"m": jnp.asarray(rng.normal(size=(dim, dim)),
                                      jnp.float32)},
             "step": jnp.asarray(1, jnp.int32)}
    d = tempfile.mkdtemp(prefix="bulkplane_")
    try:
        ck = CKPT.Checkpointer(d)
        ck.save(1, state, extra={"arch": "bench"})
        ck.save(2, state)
        tree, _, step = ck.restore(state)
        rt_exact = step == 2 and all(
            np.asarray(a).tobytes() == np.asarray(b).tobytes()
            for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(state)))
        target = os.path.join(d, "step_0000000002")
        fname = max((f for f in os.listdir(target) if f.endswith(".szc")),
                    key=lambda f: os.path.getsize(os.path.join(target, f)))
        blob = bytearray(open(os.path.join(target, fname), "rb").read())
        blob[len(blob) // 2] ^= 0x55
        open(os.path.join(target, fname), "wb").write(bytes(blob))
        tree, _, step = ck.restore(state)
        fb_exact = step == 1 and all(
            np.asarray(a).tobytes() == np.asarray(b).tobytes()
            for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(state)))
        assert rt_exact, "checkpoint round-trip must be bit-exact"
        assert fb_exact, "corrupted step must fall back bit-exactly"
        emit("bulkplane", "checkpoint", dict(
            roundtrip_bit_exact=rt_exact, fallback_bit_exact=fb_exact,
            verify_failures=int(ck.stats.verify_failures),
            wire_bytes=int(ck.stats.wire_bytes)))
    finally:
        shutil.rmtree(d, ignore_errors=True)

    # -- collective executor: 2-pod compressed ring all-reduce ---------------
    if jax.device_count() < 2:
        emit("bulkplane", "ring_skipped",
             dict(reason=f"needs 2 host devices, have {jax.device_count()}"))
        return
    mesh = make_mesh((2,), ("pod",))
    # small-integer bf16: fp32 ring sums are exact in any hop order
    grads = {"w": jnp.asarray(rng.integers(-8, 8, size=(2, dim, dim)),
                              jnp.bfloat16),
             "b": jnp.asarray(rng.integers(-8, 8, size=(2, dim)),
                              jnp.bfloat16)}
    cb = GC.calibrate_on_grads(jax.tree.map(lambda g: g[0], grads))
    ref = jax.tree.map(lambda g: jnp.mean(g.astype(jnp.float32), axis=0)
                       .astype(g.dtype), grads)
    out = GC.compressed_cross_pod_mean(grads, mesh, codebook=cb)
    ring_exact = all(np.asarray(out[k]).tobytes() == np.asarray(ref[k]).tobytes()
                     for k in ref)
    assert ring_exact, "ring_reduce must match jnp.mean bitwise"
    s = GC.last_stats
    emit("bulkplane", "ring_reduce", dict(
        bit_exact_vs_mean=ring_exact, n_pod=2,
        wire_bytes=int(s.wire_bytes),
        raw_ring_fallbacks=int(s.raw_refetches),
        analytic_wire_bytes=int(GC.cross_pod_wire_bytes(
            jax.tree.map(lambda g: g[0], grads), n_pod=2))))
