"""Paper Table 7: pre-calibrated vs dynamic (per-call) Top-16 codebook.

Expected: identical ratio/escape rate; decode unchanged; encode much slower
with the online histogram + top-k pass in the loop.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_config, generate_kv_bits, gbps, pooled_bits, time_fn
from repro.core import codebook as cbm
from repro.core import codec as C


def run(emit) -> None:
    cfg = bench_config("qwen3-32b")
    bits = pooled_bits(generate_kv_bits(cfg, seq=512, batch=4))
    nbytes = bits.nbytes
    x = jax.lax.bitcast_convert_type(jnp.asarray(bits), jnp.bfloat16)
    cb = cbm.calibrate([bits], k=16)

    enc_pre = jax.jit(lambda v: C.encode(v, cb, cap=256))
    ct = enc_pre(x)
    dec_pre = jax.jit(C.decode)

    enc_dyn = jax.jit(lambda v: C.encode_with_dynamic_codebook(v, cap=256))
    streams, dcb = enc_dyn(x)
    y = C.decode_with_dynamic_codebook(streams, dcb, x.shape, "bfloat16")
    assert bool(jnp.all(jax.lax.bitcast_convert_type(y, jnp.uint16)
                        == jnp.asarray(bits)))

    t_ep, _ = time_fn(lambda: enc_pre(x), repeats=5)
    t_dp, _ = time_fn(lambda: dec_pre(ct), repeats=5)
    t_ed, _ = time_fn(lambda: enc_dyn(x), repeats=5)

    esc_pre = float(jnp.sum(ct.esc_count)) / ct.n_padded
    esc_dyn = float(jnp.sum(streams[4])) / streams[0].shape[0]
    emit("table7", "pre-calibrated", dict(
        ratio=round(nbytes / float(C.compressed_bytes(ct)), 4),
        escape_rate=round(esc_pre, 5),
        enc_gbps=round(gbps(nbytes, t_ep), 3),
        dec_gbps=round(gbps(nbytes, t_dp), 3)))
    emit("table7", "dynamic", dict(
        escape_rate=round(esc_dyn, 5),
        enc_gbps=round(gbps(nbytes, t_ed), 3),
        enc_slowdown=round(t_ed / t_ep, 2)))
