"""Paper Fig. 3: KV transfer time across sequence-length / batch-size sweeps
(Llama-3-8B-class and Qwen3-30B-A3B) — native vs SplitZip vs theoretical opt.

The per-token KV byte counts come from the FULL assigned configs (real cache
geometry); the compression ratio comes from the measured escape rate on this
repo's harvested KV activations; transfer times use the Appendix-A additive
model at the paper's RDMA-class link bandwidth.  Expected: speedup grows with
payload, saturating at 1.27-1.32x, approaching the theoretical rho.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import bench_config, generate_kv_bits, pooled_bits
from repro.configs.base import get_config
from repro.core import codebook as cbm
from repro.core import wire
from repro.core.pipeline import CodecProfile
from repro.serving.transfer import transfer_report

LINK_BW = 25e9        # 200 Gb/s RDMA-class per-transfer effective bandwidth
FIXED_OVERHEAD = 2e-4  # launch/registration overhead (short-payload regime)

SWEEPS = {
    "seq_b1": [(s, 1) for s in (512, 2048, 8192, 32768, 131072)],
    "seq_b16": [(s, 16) for s in (128, 1024, 8192, 65536)],
    "batch_s1024": [(1024, b) for b in (1, 16, 64, 256)],
    "batch_s32768": [(32768, b) for b in (1, 16, 128)],
}


def kv_bytes_per_token(cfg) -> int:
    if cfg.mla is not None:
        per = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        return cfg.num_layers * per * 2
    return cfg.num_layers * 2 * cfg.num_kv_heads * cfg.head_dim * 2


def measured_ratio(arch: str) -> float:
    bits = pooled_bits(generate_kv_bits(bench_config(arch), seq=256, batch=2))
    cb = cbm.calibrate([bits], k=16)
    _, stats = wire.encode(bits, cb)
    return stats.ratio


def run(emit) -> None:
    for arch in ("llama3.2-3b", "qwen3-moe-30b-a3b"):
        cfg = get_config(arch)
        rho = measured_ratio(arch)
        bpt = kv_bytes_per_token(cfg)
        profile = CodecProfile(g_enc=613.3e9, g_dec=2181.8e9, ratio=rho,
                               link_bw=LINK_BW, fixed_overhead_s=FIXED_OVERHEAD)
        for sweep, points in SWEEPS.items():
            for seq, batch in points:
                raw = float(bpt) * seq * batch
                rep = transfer_report(raw, raw / rho, profile)
                emit("fig3", f"{arch}/{sweep}/s{seq}_b{batch}", dict(
                    raw_gb=round(raw / 1e9, 4),
                    t_native_ms=round(rep.t_native * 1e3, 3),
                    t_splitzip_ms=round(rep.t_splitzip * 1e3, 3),
                    speedup=round(rep.speedup, 4),
                    theoretical_opt=round(rho, 4)))
