"""Paper Table 3: Top-8 3-bit vs Top-16 4-bit exponent coding.

Expected structure: top-8 coverage collapses (92% vs 99.8%), escape rate
~50x higher, compression ratio drops toward 1.0, decode slows down.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_config, generate_kv_bits, gbps, pooled_bits, time_fn
from repro.core import codebook as cbm
from repro.core import wire


def run(emit) -> None:
    cfg = bench_config("qwen3-32b")
    bits = pooled_bits(generate_kv_bits(cfg, seq=512, batch=4))
    hist = cbm.exponent_histogram(bits)
    for k, code_bits in [(8, 3), (16, 4)]:
        cb = cbm.codebook_from_histogram(hist, k=k)
        payload, stats = wire.encode(bits, cb)
        assert np.array_equal(wire.decode(payload), bits)
        t_enc, _ = time_fn(lambda: wire.encode(bits, cb), repeats=3)
        t_dec, _ = time_fn(lambda: wire.decode(payload), repeats=3)
        emit("table3", f"top{k}", dict(
            code_bits=code_bits,
            coverage=round(cbm.coverage(cb, bits), 5),
            escape_rate=round(stats.escape_rate, 5),
            ratio=round(stats.ratio, 4),
            enc_gbps=round(gbps(bits.nbytes, t_enc), 3),
            dec_gbps=round(gbps(bits.nbytes, t_dec), 3)))
