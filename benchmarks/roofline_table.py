"""Render the §Roofline markdown table from cached dry-run results.

Usage:  PYTHONPATH=src python -m benchmarks.roofline_table [--variant base]
        [--multi-pod] [--arch ...] [--shape ...]

Reads benchmarks/results/dryrun/*.json (produced by repro.launch.dryrun) and
prints one row per live cell: the three roofline terms (seconds), dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, per-chip memory, and whether the cell fits
v5e HBM (16 GiB).
"""

from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")

V5E_HBM = 16 * 2 ** 30


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.0f}µs"
    if x < 1:
        return f"{x * 1e3:.1f}ms"
    return f"{x:.2f}s"


def load(variant: str = "base", multi_pod: bool = False,
         arch: str = "", shape: str = ""):
    pod = "pod2" if multi_pod else "pod1"
    rows = []
    for f in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        r = json.load(open(f))
        parts = r["cell"].split("__")
        if len(parts) != 4:
            continue
        a, s, p, v = parts
        if v != variant or p != pod:
            continue
        if arch and a != arch:
            continue
        if shape and s != shape:
            continue
        rows.append(r)
    return rows


def markdown(rows, show_collectives: bool = False) -> str:
    out = ["| arch | shape | t_comp | t_mem | t_coll | bound | frac "
           "| useful | GiB/chip | fits v5e |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            a, s = r["cell"].split("__")[:2]
            out.append(f"| {a} | {s} | — | — | — | skip | — | — | — | "
                       f"{r.get('reason', '')[:40]} |")
            continue
        rl = r["roofline"]
        mem = (r["memory"].get("peak_bytes") or 0)
        a, s = r["cell"].split("__")[:2]
        out.append(
            f"| {a} | {s} | {fmt_s(rl['t_compute'])} | {fmt_s(rl['t_memory'])} "
            f"| {fmt_s(rl['t_collective'])} | {rl['bottleneck'][:4]} "
            f"| {rl['roofline_fraction']:.3f} | {rl['useful_flops_ratio']:.2f} "
            f"| {mem / 2 ** 30:.2f} | {'YES' if mem <= V5E_HBM else 'NO'} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="base")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    args = ap.parse_args()
    rows = load(args.variant, args.multi_pod, args.arch, args.shape)
    print(markdown(rows))
    ok = [r for r in rows if r["status"] == "ok"]
    if ok:
        fits = sum(1 for r in ok
                   if (r["memory"].get("peak_bytes") or 0) <= V5E_HBM)
        print(f"\n{len(ok)} cells, {fits} fit 16 GiB/chip")


if __name__ == "__main__":
    main()
