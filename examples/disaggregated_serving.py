"""End-to-end PD-disaggregated serving driver with SplitZip KV transfer.

This is the paper's deployment setting at example scale: a prefill worker
runs the prompt batch, the produced KV cache crosses the PD boundary through
the SplitZip codec (compress -> wire -> decompress, bit-exact), and a decode
worker generates tokens from the transferred cache.

Three parts:
  1. serve a batch of requests through the DisaggregatedEngine and verify the
     generation is IDENTICAL with and without compression (paper Table 9),
  2. report the achieved wire ratio vs the paper's 1.324x,
  3. drive the continuous-batching scheduler with a Poisson request trace and
     compare TTFT / request throughput native-vs-SplitZip under a 400GbE
     link profile (paper Fig. 2 analogue), then sweep the pluggable link
     policies (FIFO / shortest-transfer-first / EDF / speculative) over the
     same trace.

Run:  PYTHONPATH=src python examples/disaggregated_serving.py [--arch smollm-135m]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, get_config
from repro.core import codebook as cbm
from repro.core.profile import paper_profile
from repro.models import model as M
from repro.serving.engine import DisaggregatedEngine
from repro.serving.policy import available_policies
from repro.serving.scheduler import (DisaggregatedScheduler, Request,
                                     summarize)


def calibrate_from_model(params, cfg, shape) -> cbm.Codebook:
    """Offline calibration pass (paper §3.3): run one prefill, histogram the
    produced KV-cache exponents, take the top-16."""
    batch = M.make_inputs(cfg, shape, key=jax.random.PRNGKey(1))
    _, state = M.prefill(params, batch, cfg, max_seq=shape.seq_len + 32)
    leaves = [np.asarray(jax.lax.bitcast_convert_type(l, jnp.uint16)).ravel()
              for l in jax.tree.leaves(state.cache) if l.dtype == jnp.bfloat16]
    return cbm.calibrate(leaves, k=16, fmt="bf16")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()   # CPU-scale, same family
    shape = ShapeConfig("serve", seq_len=args.prompt_len,
                        global_batch=args.batch, kind="prefill")
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    print(f"arch={args.arch} (reduced: {cfg.num_layers}L d={cfg.d_model}), "
          f"batch={args.batch}, prompt={args.prompt_len}, "
          f"new_tokens={args.new_tokens}")

    # --- 1) offline codebook calibration -------------------------------------
    cb = calibrate_from_model(params, cfg, shape)
    print(f"calibrated top-16 exponent codebook: {cb.exponents}")

    # --- 2) serve the same batch with and without SplitZip -------------------
    batch = M.make_inputs(cfg, shape, key=jax.random.PRNGKey(2))
    max_seq = args.prompt_len + args.new_tokens + 8

    eng_raw = DisaggregatedEngine(cfg, params, cb, compress=False)
    eng_sz = DisaggregatedEngine(cfg, params, cb, compress=True)
    t0 = time.time()
    out_raw = eng_raw.generate(batch, args.new_tokens, max_seq=max_seq)
    t_raw = time.time() - t0
    t0 = time.time()
    out_sz = eng_sz.generate(batch, args.new_tokens, max_seq=max_seq)
    t_sz = time.time() - t0

    identical = bool(jnp.all(out_raw == out_sz))
    print(f"\ngenerated ids (first request): {np.asarray(out_sz[0])[:12]} ...")
    print(f"compressed == uncompressed generation: {identical} "
          f"(paper Table 9: lossless => zero output difference)")
    assert identical, "SplitZip must be bit-exact end to end"
    # the engine resolved its per-leaf policy ONCE into a TransferPlan and
    # ran every transfer through the cached TransferSession:
    print(eng_sz.describe_plan())
    print(f"wire ratio achieved: {eng_sz.stats.transfer_ratio:.3f}x "
          f"(paper: 1.324x; theoretical limit 1.333x)")
    print(f"codec escape-capacity ok: {eng_sz.stats.codec_ok}  "
          f"[CPU wall-times raw={t_raw:.2f}s splitzip={t_sz:.2f}s — "
          f"codec cost is GPU/TPU-hidden in deployment, see Appendix A]")

    # --- 3) continuous-batching scheduler under a 400GbE profile -------------
    # Codec profile uses the paper's H200 numbers (repro.core.profile — run
    # benchmarks/table2_codec_throughput.py for machine-calibrated ones) with
    # THIS run's achieved ratio; the link is 400GbE (50 GB/s), the regime
    # Fig. 2 targets.  The scheduler is plan-aware: eng_sz hands its
    # already-resolved TransferPlan (the object the session executes)
    # straight to the admission engine via scheduler_config(), so the
    # sweep's transfer charges flow through the real routing table — and its
    # OBSERVED codec retries feed back as the scheduler's per-bucket
    # overflow priors; eng_raw has no plan (compression off), so the
    # scheduler builds all-raw bucket plans from its TransferConfig —
    # native link cost, same API.
    prof = paper_profile(link_bw=50e9,
                         ratio=float(eng_sz.stats.transfer_ratio),
                         fixed_overhead_s=2e-4)
    kv_bytes_tok = int(eng_sz.stats.raw_cache_bytes
                       // (args.batch * max_seq))

    def trace():
        rng = np.random.default_rng(0)   # fresh stream: every sweep leg and
        t, reqs = 0.0, []                # policy sees the IDENTICAL trace
        for i in range(256):
            t += float(rng.exponential(0.004))
            reqs.append(Request(rid=i, arrival=t,
                                prompt_len=int(rng.choice([8192, 32768, 65536])),
                                max_new_tokens=64))
        return reqs

    results = {}
    for name, eng in [("native", eng_raw), ("splitzip", eng_sz)]:
        sched = DisaggregatedScheduler(eng.scheduler_config(
            prof, max_prefill_batch=8, max_decode_slots=64,
            kv_bytes_per_token=kv_bytes_tok * 256))  # paper-like KV/token
        for r in trace():
            sched.submit(r)
        results[name] = summarize(sched.run())

    n, s = results["native"], results["splitzip"]
    print(f"\nscheduler sweep (256 requests, long prompts, 400GbE, "
          f"profile: {prof.source}):")
    print(f"  native  : TTFT {n['mean_ttft_s'] * 1e3:8.1f} ms   "
          f"req/s {n['throughput_req_s']:.2f}")
    print(f"  splitzip: TTFT {s['mean_ttft_s'] * 1e3:8.1f} ms   "
          f"req/s {s['throughput_req_s']:.2f}")
    print(f"  TTFT speedup {n['mean_ttft_s'] / s['mean_ttft_s']:.3f}x "
          f"(paper Fig. 2: up to 1.303x), req-throughput "
          f"{s['throughput_req_s'] / n['throughput_req_s']:.3f}x "
          f"(paper: up to 1.233x)")

    # --- 4) link-policy sweep over the same compressed trace -----------------
    # The link dispatch point is pluggable (repro.serving.policy): same
    # engine plan, same trace, different ordering of the PD link.  SJF
    # trades the longest prompts' tail for mean TTFT; EDF honors per-request
    # TTFT deadlines; 'spec' overlaps the decode-slot wait with transfer.
    print("\nlink-policy sweep (same trace, SplitZip path):")
    for pol in available_policies():
        sched = DisaggregatedScheduler(eng_sz.scheduler_config(
            prof, max_prefill_batch=8, max_decode_slots=64,
            kv_bytes_per_token=kv_bytes_tok * 256,
            policy=pol, slo_s=0.5))
        for r in trace():
            sched.submit(r)
        out = summarize(sched.run())
        print(f"  {pol:5s}: mean TTFT {out['mean_ttft_s'] * 1e3:8.1f} ms   "
              f"p99 {out['p99_ttft_s'] * 1e3:8.1f} ms   "
              f"req/s {out['throughput_req_s']:.2f}")


if __name__ == "__main__":
    main()
