"""SplitZip quickstart: calibrate -> encode -> transfer -> decode, bit-exact.

Walks the paper's core pipeline (§3.2-3.3) on a KV-shaped BF16 tensor:

  1. offline calibration of the top-16 exponent codebook,
  2. in-graph encode (dense 4-bit codes + sparse escape stream),
  3. byte accounting against the paper's size model B = N(3/2) + 3M,
  4. bit-exact decode (dense LUT path + sparse overwrite),
  5. the same roundtrip through the Pallas TPU kernels (interpret on CPU),
  6. the variable-length wire format used off-graph (checkpoints, RPC).

Steps 2-6 all go through the pluggable codec-backend registry
(``repro.core.backend``: ``auto`` / ``xla`` / ``pallas`` / ``wire``) — the
same dispatch the serving engine uses via ``TransferConfig.backend``.  The
``auto`` entry picks the fused Pallas kernels on TPU, the XLA reference
elsewhere.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codebook as cbm
from repro.core import codec
from repro.core.backend import get_backend
from repro.core.pipeline import hiding_bandwidth, speedup
from repro.core.profile import paper_profile


def main():
    rng = np.random.default_rng(0)

    # --- a KV-cache-shaped activation tensor (layers x B x S x kvh x hd) ----
    # Mixture of scales mimics real KV value spread (paper Table 1: exponent
    # entropy ~3 bits, top-16 coverage > 99%).
    shape = (4, 2, 256, 4, 64)
    x = rng.normal(size=shape) * rng.choice([0.1, 0.5, 1.0, 3.0], size=shape)
    kv = jnp.asarray(x, dtype=jnp.bfloat16)
    kv_bits = jax.lax.bitcast_convert_type(kv, jnp.uint16)

    # --- 1) one-time offline calibration (paper §3.3) ------------------------
    calib = np.asarray(kv_bits).ravel()[: kv.size // 4]  # small calib sample
    cb = cbm.calibrate([calib], k=16, fmt="bf16")
    hist = cbm.exponent_histogram(np.asarray(kv_bits))
    print(f"codebook (top-16 exponents): {cb.exponents}")
    print(f"exponent entropy : {cbm.exponent_entropy(hist):.2f} bits  "
          f"(paper Table 1: 2.89-3.59 bits)")
    print(f"top-16 coverage  : {100 * cbm.coverage(cb, np.asarray(kv_bits)):.2f}%")

    # --- 2) in-graph encode (jittable, shardable) — backend 'auto' -----------
    # 'auto' is the hardware dispatch entry: the fused Pallas kernels on TPU,
    # the pure-XLA reference elsewhere (so this script is portable as-is).
    be_xla = get_backend("auto")
    print(f"\nbackend 'auto' resolved to: {be_xla.name!r} "
          f"(jax default backend: {jax.default_backend()})")
    ct = jax.jit(lambda t: be_xla.encode(t, cb))(kv)
    n, m = kv.size, int(jnp.sum(ct.esc_count))
    got = float(be_xla.wire_bytes(ct))
    model = n * 1.5 + 3 * m
    print(f"\nencoded: N={n} elements, M={m} escapes "
          f"(rate {m / n:.4%}, capacity ok={bool(be_xla.ok(ct))})")
    print(f"bytes: raw={2 * n}  compressed={got:.0f}  "
          f"(paper model N(3/2)+3M = {model:.0f})")
    print(f"compression ratio: {float(codec.compression_ratio(ct)):.3f}x "
          f"(paper: 1.324x on Qwen3-32B; limit 4/3 = {4 / 3:.3f}x)")

    # --- 3) bit-exact decode --------------------------------------------------
    y = jax.jit(be_xla.decode)(ct)
    same = bool(jnp.all(kv_bits == jax.lax.bitcast_convert_type(y, jnp.uint16)))
    print(f"bit-exact roundtrip (backend {be_xla.name!r}): {same}")
    assert same

    # --- 4) the fused Pallas TPU kernel path (interpret=True on CPU) ---------
    # One pallas_call per direction: escape compaction happens inside the
    # encode kernel, sparse correction inside the decode kernel.
    be_pl = get_backend("pallas")
    y_k = be_pl.decode(be_pl.encode(kv, cb))
    same_k = bool(jnp.all(kv_bits == jax.lax.bitcast_convert_type(y_k, jnp.uint16)))
    print(f"bit-exact roundtrip (backend 'pallas', fused): {same_k}")
    assert same_k

    # --- 5) variable-length wire format (off-graph) — backend 'wire' ---------
    be_w = get_backend("wire")
    ct_w = be_w.encode(kv, cb)
    back = be_w.decode(ct_w)
    assert np.array_equal(np.asarray(jax.lax.bitcast_convert_type(back, jnp.uint16)),
                          np.asarray(kv_bits))
    print(f"\nwire format: {be_w.raw_bytes(ct_w) / be_w.wire_bytes(ct_w):.3f}x "
          f"over {int(be_w.wire_bytes(ct_w))} bytes "
          f"(escape rate {ct_w.stats.escape_rate:.4%}) — bit-exact")

    # --- 6) when does the codec pay off? (paper Appendix A) ------------------
    # 400GbE link + the paper's H200 codec constants (repro.core.profile —
    # the ONE place they live; 'measured' profiles come from the table2
    # benchmark's profiles.json)
    prof = paper_profile(link_bw=50e9)
    print(f"\nAppendix A: B_hide = {hiding_bandwidth(prof) / 1e9:.1f} GB/s "
          f"(paper: ~463.2 GB/s)")
    s = 1 << 30
    print(f"additive speedup on a 1 GiB KV transfer over 400GbE: "
          f"{speedup(s, prof):.2f}x  (pipelined: "
          f"{speedup(s, prof, pipelined=True):.2f}x)")


if __name__ == "__main__":
    main()
