"""SplitZip quickstart: calibrate -> encode -> transfer -> decode, bit-exact.

Walks the paper's core pipeline (§3.2-3.3) on a KV-shaped BF16 tensor:

  1. offline calibration of the top-16 exponent codebook,
  2. in-graph encode (dense 4-bit codes + sparse escape stream),
  3. byte accounting against the paper's size model B = N(3/2) + 3M,
  4. bit-exact decode (dense LUT path + sparse overwrite),
  5. the same roundtrip through the Pallas TPU kernels (interpret on CPU),
  6. the variable-length wire format used off-graph (checkpoints, RPC).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codebook as cbm
from repro.core import codec, wire
from repro.core.pipeline import CodecProfile, hiding_bandwidth, speedup
from repro.kernels import ops as kops


def main():
    rng = np.random.default_rng(0)

    # --- a KV-cache-shaped activation tensor (layers x B x S x kvh x hd) ----
    # Mixture of scales mimics real KV value spread (paper Table 1: exponent
    # entropy ~3 bits, top-16 coverage > 99%).
    shape = (4, 2, 256, 4, 64)
    x = rng.normal(size=shape) * rng.choice([0.1, 0.5, 1.0, 3.0], size=shape)
    kv = jnp.asarray(x, dtype=jnp.bfloat16)
    kv_bits = jax.lax.bitcast_convert_type(kv, jnp.uint16)

    # --- 1) one-time offline calibration (paper §3.3) ------------------------
    calib = np.asarray(kv_bits).ravel()[: kv.size // 4]  # small calib sample
    cb = cbm.calibrate([calib], k=16, fmt="bf16")
    hist = cbm.exponent_histogram(np.asarray(kv_bits))
    print(f"codebook (top-16 exponents): {cb.exponents}")
    print(f"exponent entropy : {cbm.exponent_entropy(hist):.2f} bits  "
          f"(paper Table 1: 2.89-3.59 bits)")
    print(f"top-16 coverage  : {100 * cbm.coverage(cb, np.asarray(kv_bits)):.2f}%")

    # --- 2) in-graph encode (jittable, shardable) ----------------------------
    ct = jax.jit(lambda t: codec.encode(t, cb), static_argnums=())(kv)
    n, m = kv.size, int(jnp.sum(ct.esc_count))
    got = float(codec.compressed_bytes(ct))
    model = n * 1.5 + 3 * m
    print(f"\nencoded: N={n} elements, M={m} escapes "
          f"(rate {m / n:.4%}, capacity ok={bool(ct.ok)})")
    print(f"bytes: raw={2 * n}  compressed={got:.0f}  "
          f"(paper model N(3/2)+3M = {model:.0f})")
    print(f"compression ratio: {float(codec.compression_ratio(ct)):.3f}x "
          f"(paper: 1.324x on Qwen3-32B; limit 4/3 = {4 / 3:.3f}x)")

    # --- 3) bit-exact decode --------------------------------------------------
    y = jax.jit(codec.decode)(ct)
    same = bool(jnp.all(kv_bits == jax.lax.bitcast_convert_type(y, jnp.uint16)))
    print(f"bit-exact roundtrip (XLA codec): {same}")
    assert same

    # --- 4) the Pallas TPU kernel path (interpret=True on CPU) ---------------
    ct_k = kops.encode(kv, cb)
    y_k = kops.decode(ct_k)
    same_k = bool(jnp.all(kv_bits == jax.lax.bitcast_convert_type(y_k, jnp.uint16)))
    print(f"bit-exact roundtrip (Pallas kernels): {same_k}")
    assert same_k

    # --- 5) variable-length wire format (off-graph) --------------------------
    payload, stats = wire.encode(np.asarray(kv_bits).ravel(), cb)
    back = wire.decode(payload)
    assert np.array_equal(back, np.asarray(kv_bits).ravel())
    print(f"\nwire format: {stats.ratio:.3f}x over {len(payload)} bytes "
          f"(escape rate {stats.escape_rate:.4%}) — bit-exact")

    # --- 6) when does the codec pay off? (paper Appendix A) ------------------
    prof = CodecProfile(g_enc=613.3e9, g_dec=2181.8e9, ratio=1.324,
                        link_bw=50e9)  # 400GbE, paper's measured codec
    print(f"\nAppendix A: B_hide = {hiding_bandwidth(prof) / 1e9:.1f} GB/s "
          f"(paper: ~463.2 GB/s)")
    s = 1 << 30
    print(f"additive speedup on a 1 GiB KV transfer over 400GbE: "
          f"{speedup(s, prof):.2f}x  (pipelined: "
          f"{speedup(s, prof, pipelined=True):.2f}x)")


if __name__ == "__main__":
    main()
