"""Cross-pod KV-cache transfer on a multi-pod device mesh (scaled down).

The production dry-run uses a (pod=2, data=16, model=16) mesh; here we build
the same topology at (pod=2, data=2, model=2) on 8 simulated host devices so
the *distribution semantics* run for real on CPU:

  - prefill pod (pod 0) holds a sharded KV cache,
  - a ``TransferPlan`` resolves the per-leaf codec routes + chunking ONCE,
  - its ``TransferSession`` encodes each shard locally (codec is pointwise
    => fully parallel across the mesh) and moves the compressed streams
    across the pod axis via `lax.ppermute` inside `shard_map` (the DCN hop
    in production) — whole-tensor, or per-chunk with double-buffering when
    the plan has ``n_chunks > 1``,
  - decode pod (pod 1) decompresses its shards; result is bit-exact.

The wire-byte reduction (~1/1.324) is visible in the lowered HLO
collective-permute operand sizes — printed at the end, this is exactly what
the roofline's collective term measures.

NOTE: must run as its own process (device count is fixed at jax init).
Run:  PYTHONPATH=src python examples/multipod_transfer.py
"""

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8").strip()

import jax                                                        # noqa: E402
import jax.numpy as jnp                                           # noqa: E402
import numpy as np                                                # noqa: E402

from repro.core import codebook as cbm                            # noqa: E402
from repro.launch.mesh import make_mesh                           # noqa: E402
from repro.serving.plan import TransferConfig, TransferPlan       # noqa: E402
from repro.analysis.roofline import collective_bytes_from_hlo     # noqa: E402


def main():
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    print(f"mesh: {dict(mesh.shape)} on {mesh.devices.size} host devices")

    # a KV-cache pytree, sharded (data, model) within each pod
    rng = np.random.default_rng(0)
    def kv_like(shape):
        x = rng.normal(size=shape) * rng.choice([0.25, 1.0, 4.0], size=shape)
        return jnp.asarray(x, dtype=jnp.bfloat16)

    cache = {"k": kv_like((4, 8, 256, 4, 32)),   # (layers, B, S, kvh, hd)
             "v": kv_like((4, 8, 256, 4, 32))}

    cb = cbm.calibrate(
        [np.asarray(jax.lax.bitcast_convert_type(cache["k"], jnp.uint16))],
        k=16)

    def xfer(tc):
        # build once (policy resolution), execute through the session; the
        # same session would serve every subsequent transfer of this model
        sess = TransferPlan.build(cache, tc, mesh=mesh).session()
        moved = sess.transfer(cache)
        same = jax.tree.all(jax.tree.map(
            lambda a, b: bool(jnp.all(
                jax.lax.bitcast_convert_type(a, jnp.uint16)
                == jax.lax.bitcast_convert_type(b, jnp.uint16))),
            cache, moved))
        assert same, "cross-pod transfer must be bit-exact"
        hlo = sess.lower_hlo(cache)
        return collective_bytes_from_hlo(hlo)["collective-permute"]

    raw_b = xfer(TransferConfig(codebook=cb, enabled=False))
    chunked_b = xfer(TransferConfig(codebook=cb, chunk=1024, cap=64))
    global_b = xfer(TransferConfig(codebook=cb, layout="global"))
    # the pipelined mesh path: per-chunk ppermute, double-buffered; bit-exact
    # and byte-identical accounting to the whole-tensor collective
    piped_b = xfer(TransferConfig(codebook=cb, chunk=1024, cap=64, n_chunks=4))

    print("cross-pod transfers bit-exact: True (all four modes)")
    print(f"collective-permute bytes on the pod axis (per device):")
    print(f"  native raw                : {raw_b:>9} (1.000x)")
    print(f"  SplitZip chunked (paper)  : {chunked_b:>9} "
          f"({raw_b / chunked_b:.3f}x) — static per-chunk escape buffers")
    print(f"  SplitZip global (ours)    : {global_b:>9} "
          f"({raw_b / global_b:.3f}x) — two-level escape compaction")
    print(f"  SplitZip pipelined (ours) : {piped_b:>9} "
          f"({raw_b / piped_b:.3f}x) — 4 per-chunk ppermutes, "
          f"double-buffered")
    print(f"paper's variable-length wire ratio: 1.324x; in-graph static "
          f"buffers pay capacity padding, which the global layout removes")


if __name__ == "__main__":
    main()
