"""Fault-tolerant training with SplitZip-compressed checkpoints.

Demonstrates the training-side substrate around the paper's codec:

  1. train a reduced-config model with the sharded AdamW train step,
  2. checkpoint every K steps — bf16 leaves go through the SplitZip *wire*
     codec (lossless, ~25% smaller checkpoints),
  3. simulate a node failure mid-run (process "dies"),
  4. restart, restore the latest checkpoint, continue to the target step,
  5. verify the resumed run reaches bit-identical state vs an uninterrupted
     run (deterministic data pipeline + deterministic step).

Also shows the beyond-paper trick: SplitZip-compressed cross-pod gradient
all-reduce (lossless => zero convergence impact, unlike lossy compression).

Run:  PYTHONPATH=src python examples/train_resume.py [--arch llama3.2-3b]
"""

import argparse
import shutil
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig, get_config
from repro.distributed import checkpoint as CKPT
from repro.training import optimizer as OPT
from repro.training import train_step as TS
from repro.training.data import SyntheticTokenStream


def run_training(cfg, shape, steps, ckpt_dir=None, ckpt_every=4,
                 die_at=None, resume=False, grad_compress=False):
    """Train to `steps`; optionally die at `die_at`, optionally resume."""
    opt_cfg = OPT.AdamWConfig(lr=1e-3, total_steps=steps, warmup_steps=2)
    step_fn = jax.jit(TS.make_train_step(cfg, opt_cfg,
                                         grad_compress=grad_compress,
                                         kv_block=shape.seq_len))
    data = SyntheticTokenStream(cfg, shape)

    state = TS.init_state(cfg, jax.random.PRNGKey(0))
    start = 0
    if resume and ckpt_dir and CKPT.latest_step(ckpt_dir) is not None:
        state, extra, start = CKPT.restore(ckpt_dir, state)
        print(f"  [restart] resumed from step {start} "
              f"({extra.get('arch', '?')})")

    for step in range(start, steps):
        batch = data.batch_at(step)           # deterministic per step
        state, metrics = step_fn(state, batch)
        print(f"  step {step:3d}  loss {float(metrics['loss']):.4f}  "
              f"gnorm {float(metrics['grad_norm']):.3f}")
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            CKPT.save(ckpt_dir, step + 1, state, extra={"arch": cfg.name})
        if die_at is not None and step + 1 == die_at:
            print(f"  [failure injected] node died after step {step}")
            return None
    return state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    shape = ShapeConfig("cli", seq_len=32, global_batch=4, kind="train")
    print(f"training {args.arch} (reduced) for {args.steps} steps, "
          f"checkpoint every 4\n")

    workdir = tempfile.mkdtemp(prefix="splitzip_ckpt_")
    try:
        # -- reference: uninterrupted run -------------------------------------
        print("reference run (no failure):")
        ref = run_training(cfg, shape, args.steps)

        # -- failure at step 6, restart, resume from step 4 --------------------
        print("\nfaulty run (dies after step 6):")
        run_training(cfg, shape, args.steps, ckpt_dir=workdir, die_at=6)
        print("restarted process:")
        rec = run_training(cfg, shape, args.steps, ckpt_dir=workdir,
                           resume=True)

        same = jax.tree.all(jax.tree.map(
            lambda a, b: bool(jnp.all(a == b)), ref.params, rec.params))
        print(f"\nresumed params bit-identical to uninterrupted run: {same}")
        assert same, "deterministic resume must reproduce the reference run"

        # -- checkpoint compression accounting ---------------------------------
        step = CKPT.latest_step(workdir)
        comp = CKPT.checkpoint_bytes(workdir, step)
        raw = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(rec))
        print(f"checkpoint bytes: {comp} vs raw {raw} "
              f"({raw / comp:.3f}x smaller via SplitZip wire codec)")

        # -- lossless compressed gradient sync ---------------------------------
        print("\nwith SplitZip-compressed gradient all-reduce "
              "(lossless => identical math):")
        gc = run_training(cfg, shape, 3, grad_compress=True)
        plain = run_training(cfg, shape, 3)
        same_g = jax.tree.all(jax.tree.map(
            lambda a, b: bool(jnp.all(a == b)), gc.params, plain.params))
        print(f"grad-compressed run bit-identical to plain run: {same_g}")
    finally:
        shutil.rmtree(workdir, ignore_errors=True)


if __name__ == "__main__":
    main()
