"""Router registry: place prefilled requests on (link, decode-worker) pairs.

Mirrors the :mod:`repro.serving.policy` link-policy registry: small
stateless strategy objects behind ``register_router`` / ``get_router`` /
``available_routers``, cached as singletons.  A router's one job is
:meth:`Router.place`: given a prefilled request and a read-only *view* of
the scheduler, pick the link the transfer rides and (optionally) pin the
decode worker it lands on.

The view duck-types the scheduler and exposes, at minimum:

* ``view.cluster`` — the resolved :class:`~repro.serving.cluster.ClusterConfig`
* ``view.est_transfer_s(req, link)`` — plan-estimated transfer seconds for
  this request's uncached suffix on that link (prefix-delta aware)
* ``view.link_backlog_s(link)`` — queued + in-flight estimated seconds
* ``view.decode_load(worker)`` — resident + pinned-inbound request count
* ``view.decode_alive(worker)`` — detector's view of the worker
* ``view.rr_next(n)`` — scheduler-owned round-robin counter (state lives on
  the scheduler, NOT the cached router singleton, so separate runs with
  equal seeds stay deterministic)
* ``view.cfg`` — the ``SchedulerConfig`` (for ``decode_time_per_step``)

``place`` returns ``(link_id, decode_id)``; ``decode_id == -1`` defers the
worker choice to admission time (the PR-6 least-loaded-alive path), which
is exactly what the ``legacy`` router does to keep the degenerate 1x1
topology bit-identical to the pre-fleet scheduler.

Routers must be deterministic pure functions of the view (no wall clock,
no RNG, no mutable state on the instance) — the property harness in
``tests/test_fleet.py`` replays shuffled submissions and requires
identical placements.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Tuple


class Router:
    """Base placement policy; subclasses override :meth:`place`."""

    name = "base"

    def place(self, req, view) -> Tuple[int, int]:
        raise NotImplementedError

    def _alive_decodes(self, view) -> List[int]:
        alive = [w for w in range(view.cluster.n_decode)
                 if view.decode_alive(w)]
        # with every worker detected-dead, placement still has to put the
        # request somewhere; revival/failover sorts it out later
        return alive or list(range(view.cluster.n_decode))


_REGISTRY: Dict[str, Callable[[], Router]] = {}
_INSTANCES: Dict[str, Router] = {}


def register_router(name: str, factory: Callable[[], Router]) -> None:
    _REGISTRY[name] = factory
    _INSTANCES.pop(name, None)


def get_router(name: str) -> Router:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown router {name!r}; available: {sorted(_REGISTRY)}")
    if name not in _INSTANCES:
        _INSTANCES[name] = _REGISTRY[name]()
    return _INSTANCES[name]


def available_routers() -> List[str]:
    return sorted(_REGISTRY)


class LegacyRouter(Router):
    """Pre-fleet semantics: everything on link 0, decode worker chosen at
    admission time (least-loaded-alive).  Computes nothing — the degenerate
    1-link topology must be bit-identical to the PR-6 scheduler, so this
    router must not touch any float path."""

    name = "legacy"

    def place(self, req, view) -> Tuple[int, int]:
        return 0, -1


class TransferAwareRouter(Router):
    """Default fleet router: minimize plan-estimated transfer time plus
    current queue depth over every (link, decode) pair.

    cost(link, worker) = est_transfer_s(req, link) + link_backlog_s(link)
                       + decode_load(worker) * decode_time_per_step

    ``est_transfer_s`` is prefix-delta aware (a warm session costs only its
    uncached suffix on workers holding its prefix), so this router is also
    what makes prefix affinity fall out for free: the warm worker's transfer
    term shrinks, pulling the session back to its cache.  Ties break on
    (cost, link_id, decode_id) — fully deterministic."""

    name = "transfer-aware"

    def place(self, req, view) -> Tuple[int, int]:
        step = view.cfg.decode_time_per_step
        best = None
        for wid in self._alive_decodes(view):
            decode_cost = view.decode_load(wid) * step
            for li in range(view.cluster.n_links):
                cost = (view.est_transfer_s(req, li, wid)
                        + view.link_backlog_s(li) + decode_cost)
                key = (cost, li, wid)
                if best is None or key < best:
                    best = key
        return best[1], best[2]


class RoundRobinRouter(Router):
    """Cycle decode workers (skipping detected-dead ones) and links
    independently.  The counters live on the scheduler (``view.rr_next``)."""

    name = "round-robin"

    def place(self, req, view) -> Tuple[int, int]:
        alive = self._alive_decodes(view)
        wid = alive[view.rr_next("decode") % len(alive)]
        li = view.rr_next("link") % view.cluster.n_links
        return li, wid


class LeastLoadedRouter(Router):
    """Pin the least-loaded alive decode worker at routing time; take the
    link with the smallest backlog.  Differs from ``legacy`` in that the
    choice is made (and pinned) when the transfer is routed, not deferred
    to admission."""

    name = "least-loaded"

    def place(self, req, view) -> Tuple[int, int]:
        wid = min(self._alive_decodes(view),
                  key=lambda w: (view.decode_load(w), w))
        li = min(range(view.cluster.n_links),
                 key=lambda l: (view.link_backlog_s(l), l))
        return li, wid


register_router("legacy", LegacyRouter)
register_router("transfer-aware", TransferAwareRouter)
register_router("round-robin", RoundRobinRouter)
register_router("least-loaded", LeastLoadedRouter)
