"""Seeded multi-tenant trace generator for fleet-scale serving scenarios.

"Rethinking KV Cache Compression" (PAPERS.md) argues single-number,
single-workload claims fall apart under workload diversity; this module
makes diversity cheap to synthesize and exactly reproducible:

* **Bursty arrivals** — a Poisson process over burst *epochs* (exponential
  gaps) with bounded-Pareto burst sizes, so load arrives in heavy-tailed
  clumps rather than a smooth stream.
* **Heavy-tailed prompt lengths** — bounded Pareto via inverse-CDF, the
  standard model for LLM prompt-length distributions.
* **SLO classes** — each request draws a :class:`TenantClass` (weighted),
  which sets its deadline (``arrival + slo_s``) and output-budget range;
  `edf` / `edf-shed` link policies and the fleet router see real deadline
  diversity.
* **Shared-prefix sessions** — with probability ``session_p`` a request
  continues an open session: its prompt is the session's full history plus
  a fresh follow-up, and ``prefix_len`` marks the shared prefix so the
  scheduler's prefix-aware delta transfer has something to hit.  This is
  the agentic/multi-turn shape that motivates delta transfer at all.

Everything is driven by one ``numpy`` ``default_rng(seed)``; equal configs
produce bit-identical traces on every platform we test (the property
harness in ``tests/test_fleet.py`` depends on this).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.serving.scheduler import Request


@dataclasses.dataclass(frozen=True)
class TenantClass:
    """One service class: arrival weight, SLO, and output-length range."""

    name: str
    weight: float
    slo_s: float
    new_tokens: Tuple[int, int]  # inclusive [lo, hi] max_new_tokens range

    def __post_init__(self):
        if self.weight <= 0.0:
            raise ValueError("TenantClass.weight must be > 0")
        lo, hi = self.new_tokens
        if not (1 <= lo <= hi):
            raise ValueError("TenantClass.new_tokens must satisfy 1 <= lo <= hi")


# Interactive chat (tight TTFT, short outputs), standard API traffic, and
# offline batch (loose SLO, long generations) — the three-class split used
# by the service-aware serving literature (KVServe et al., PAPERS.md).
DEFAULT_TENANTS: Tuple[TenantClass, ...] = (
    TenantClass("interactive", weight=0.5, slo_s=0.4, new_tokens=(4, 32)),
    TenantClass("standard", weight=0.35, slo_s=1.5, new_tokens=(16, 96)),
    TenantClass("batch", weight=0.15, slo_s=8.0, new_tokens=(64, 256)),
)


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Knobs for :func:`generate_trace`; every field has a sane default so
    tests can override just what a scenario varies."""

    seed: int = 0
    n_requests: int = 64
    # arrivals: exponential gaps between bursts, bounded-Pareto burst sizes
    mean_burst_gap_s: float = 0.05
    burst_alpha: float = 1.2
    max_burst: int = 8
    burst_spread_s: float = 0.005   # uniform jitter of arrivals inside a burst
    # bounded-Pareto prompt lengths
    prompt_alpha: float = 1.1
    prompt_min: int = 16
    prompt_max: int = 2048
    tenants: Tuple[TenantClass, ...] = DEFAULT_TENANTS
    # shared-prefix sessions: probability a request continues an open
    # session rather than opening a new one; follow-up turns append
    # [lo, hi] fresh tokens onto the session history
    session_p: float = 0.0
    followup_tokens: Tuple[int, int] = (16, 128)
    max_open_sessions: int = 8

    def __post_init__(self):
        if self.n_requests < 1:
            raise ValueError("n_requests must be >= 1")
        if not (0.0 <= self.session_p <= 1.0):
            raise ValueError("session_p must be in [0, 1]")
        if not (1 <= self.prompt_min <= self.prompt_max):
            raise ValueError("prompt bounds must satisfy 1 <= min <= max")
        if not self.tenants:
            raise ValueError("at least one TenantClass is required")


def _bounded_pareto(rng: np.random.Generator, alpha: float, lo: float,
                    hi: float) -> float:
    """One bounded-Pareto draw on [lo, hi] via inverse CDF."""
    u = float(rng.random())
    la, ha = lo ** alpha, hi ** alpha
    return (-(u * ha - u * la - ha) / (ha * la)) ** (-1.0 / alpha)


def generate_trace(cfg: TraceConfig) -> List[Request]:
    """Synthesize a seeded multi-tenant trace as scheduler ``Request``s.

    Requests come back sorted by arrival with ``rid`` assigned in arrival
    order (ties broken by generation order), ready for ``Scheduler.submit``.
    Session continuations carry ``session >= 0`` and ``prefix_len`` equal to
    the history already shipped for that session; fresh requests (and all
    requests when ``session_p == 0``) carry ``session == -1``."""
    rng = np.random.default_rng(cfg.seed)
    lo_t, hi_t = cfg.followup_tokens
    weights = np.asarray([t.weight for t in cfg.tenants], dtype=np.float64)
    weights = weights / weights.sum()

    # (arrival, gen_order, prompt, new_tokens, deadline, tenant, sid, prefix)
    rows = []
    # open sessions: sid -> total tokens resident after the last turn
    open_sessions: "dict[int, int]" = {}
    next_sid = 0
    t = 0.0
    made = 0
    while made < cfg.n_requests:
        t += float(rng.exponential(cfg.mean_burst_gap_s))
        burst = int(_bounded_pareto(rng, cfg.burst_alpha, 1.0,
                                    float(cfg.max_burst)))
        burst = min(max(1, burst), cfg.n_requests - made)
        for _ in range(burst):
            arrival = t + float(rng.uniform(0.0, cfg.burst_spread_s))
            tenant = cfg.tenants[int(rng.choice(len(cfg.tenants), p=weights))]
            new_tokens = int(rng.integers(tenant.new_tokens[0],
                                          tenant.new_tokens[1] + 1))
            sid, prefix = -1, 0
            if (cfg.session_p > 0.0 and open_sessions
                    and float(rng.random()) < cfg.session_p):
                # continue the least-recently-extended open session
                sid = min(open_sessions)
                prefix = open_sessions.pop(sid)
                prompt = prefix + int(rng.integers(lo_t, hi_t + 1))
            else:
                prompt = int(round(_bounded_pareto(
                    rng, cfg.prompt_alpha, float(cfg.prompt_min),
                    float(cfg.prompt_max))))
                prompt = min(max(cfg.prompt_min, prompt), cfg.prompt_max)
                if cfg.session_p > 0.0:
                    sid = next_sid
                    next_sid += 1
            if sid >= 0:
                # after this turn the session's resident history is the
                # prompt plus everything it may generate
                open_sessions[sid] = prompt + new_tokens
                while len(open_sessions) > cfg.max_open_sessions:
                    open_sessions.pop(min(open_sessions))
            rows.append((arrival, made, prompt, new_tokens,
                         arrival + tenant.slo_s, tenant.name, sid, prefix))
            made += 1

    rows.sort(key=lambda r: (r[0], r[1]))
    out = []
    for rid, (arrival, _, prompt, new_tokens, deadline, tname, sid,
              prefix) in enumerate(rows):
        out.append(Request(
            rid=rid, arrival=arrival, prompt_len=prompt,
            max_new_tokens=new_tokens, deadline=deadline,
            session=sid, prefix_len=prefix, tenant=tname))
    return out
