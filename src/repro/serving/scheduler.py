"""Event-driven, plan-aware request scheduler for disaggregated serving.

Pure-Python admission engine around the jitted prefill/transfer/decode steps:
requests arrive with a prompt length and a max-new-tokens budget; the
scheduler assembles prefill batches, serializes the produced caches over the
PD link fabric, admits transferred requests into decode slots, and retires
finished requests.  Timing is simulated with the analytic codec/link profile
so the same scheduler drives both the real CPU execution (tiny configs,
tests) and the paper-scale what-if sweeps (Fig. 2 analogue).

Transfer time is charged from a real :class:`~repro.serving.plan.TransferPlan`
— the same object the execution path runs — via ``plan.estimate_time``: the
3-stage flowshop recurrence over the plan's ACTUAL segment sizes (chunked
granularity), additive accounting (tensor granularity), or the native link
cost (compression disabled -> all-raw routes).  Plans are built once per
prompt-length bucket from the arch config's cache structure (or a synthetic
bf16 structure derived from ``kv_bytes_per_token``) and reused across every
request of that bucket, mirroring ``DisaggregatedEngine._session_for``'s
compile-once/run-many contract; ``SchedulerConfig.plan`` accepts an engine's
already-resolved plan directly.  Expected capacity-schedule retries and raw
fallbacks (``overflow_p``) inflate the charged encode attempts and ship the
fallback fraction at full link cost.

The simulation is an event queue (prefill-done, transfer-done, decode-step)
over a CLUSTER of resources (ISSUE 10 — the fleet generalization of the
original 1x1x1 pipe; :class:`~repro.serving.cluster.ClusterConfig`):

* **prefill workers** — ``cluster.n_prefill`` workers, each batching up to
  ``max_prefill_batch`` arrived requests, one batch in flight per worker;
* **links** — ``cluster.links`` heterogeneous trunk paths, each with its own
  link policy (:mod:`repro.serving.policy` — fifo / sjf / edf / edf-shed /
  spec) and a per-link :class:`CodecProfile` derived from the configured
  profile by the link's ``bw_scale``.  Each request occupies exactly one
  link per transfer (``link_start`` .. ``transfer_done``); which queued
  request gets an idle link is that link's policy.  Every policy preserves
  the single-occupancy and conservation invariants — per link
  (``link_busy_by_link``) and in total (``link_busy_s``);
* **decode workers** — ``cluster.n_decode`` workers sharing the global slot
  budget (ceil-split per worker), continuous batching in lockstep steps of
  ``decode_time_per_step``.  Transferred requests wait in an explicit
  admission queue until their worker has a slot AND join at a step
  boundary, so TTFT reflects link and decode-worker occupancy.  Under a
  ``spec`` link policy the request holding that link may pre-claim a slot
  left over after the admission queue drains.

**Routing** (ISSUE 10): a :class:`~repro.serving.router.Router` from the
router registry places each prefilled request on a (link, decode-worker)
pair; the default ``transfer-aware`` router minimizes plan-estimated
transfer time + current queue depth over every pair.  A config WITHOUT an
explicit ``cluster`` resolves (:func:`~repro.serving.cluster.resolve_cluster`)
to the degenerate 1-prefill/1-link topology under the ``legacy`` router
(link 0, decode worker deferred to admission-time least-loaded-alive) and
reproduces the pre-fleet scheduler bit-identically — pinned by
``tests/test_fleet.py``.

**Prefix-aware delta transfer** (ISSUE 10): with
``cluster.prefix_cache_bytes`` set, a per-decode-worker
:class:`~repro.serving.cluster.PrefixDirectory` tracks which session
prefixes are resident where; a multi-turn request routed back to a worker
holding its prefix ships only the uncached suffix tokens (charged via the
same ``plan.estimate_time``, counted in ``prefix_hit_bytes``), and the
transfer-aware router's cost term shrinks accordingly — prefix affinity
falls out of the cost model instead of being a special case.  The
execution-path twin is :class:`repro.serving.session.PrefixIndex` (byte-
exact segment reuse); this is the capacity/timing model of the same idea.

**Failure semantics** (ISSUE 7, extended to the fleet in ISSUE 10): decode
AND prefill workers are watched by per-tier
:class:`~repro.distributed.fault_tolerance.FailureDetector` instances
driven by the sim clock — live workers heartbeat at every event, so deaths
surface with real ``heartbeat_timeout_s`` detection latency.  A
:class:`~repro.serving.faults.FaultPlan` (``SchedulerConfig.faults``)
injects worker kills (either tier, via ``WorkerKill.role``) and per-link
brownouts (``LinkBrownout.link``):

* a dead DECODE worker's resident requests **fail over** — the compressed
  cache is re-sent (a fresh, conserved link occupancy charged via
  ``plan.estimate_time``) after a capped exponential backoff, re-routed and
  re-admitted on a surviving worker, keeping tokens already emitted.
  Requests whose cache had landed on the dead worker but were still
  awaiting admission fail over the same way; requests merely ROUTED to it
  whose transfer had not begun are silently re-routed (their cache never
  left the prefill side).  ``SchedulerConfig.on_failover`` fires per actual
  re-send so an attached engine can re-send the real cached stream
  (``DisaggregatedEngine.resend_cache``).  Each request's ``link_history``
  (+ parallel ``link_ids``) records every occupancy so conservation stays
  checkable across failures, and exhausted failover budgets shed loudly;
* a dead PREFILL worker's in-flight batch is cancelled at detection and its
  requests re-queued (by original arrival order) for a surviving prefill
  worker — counted in ``prefill_failovers``; tokens are conserved;
* a **brownout** stretches in-flight transfers on the affected link(s) to
  the piecewise-integrated wall clock of the degraded rate (occupancy =
  what the link was held);
* shedding-enabled link policies (``'edf-shed'``, or
  ``shed_infeasible=True``) drop queued requests that PROVABLY cannot meet
  their deadline.

Every request drains terminal in exactly one state — ``'completed'``,
``'failed-over'``, or ``'shed'`` — and :func:`summarize` reports the
failure-plane counts next to the latency statistics.

Expected codec overflow is charged per prompt-length bucket:
``overflow_priors`` (e.g. calibrated from a real engine's observed
``EngineStats.chunk_retries`` via ``DisaggregatedEngine.overflow_priors``)
overrides the scalar ``overflow_p`` bucket by bucket, and
``TransferPlan.estimate_time`` walks the capacity schedule in expectation
with that per-bucket prior.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.codebook import DEFAULT_BF16_CODEBOOK
from repro.core.pipeline import CodecProfile
from repro.distributed.fault_tolerance import FailureDetector, FaultConfig
from repro.models.kvcache import init_cache
from repro.serving.cluster import ClusterConfig, PrefixDirectory, resolve_cluster
from repro.serving.faults import FaultPlan, resolve_faults
from repro.serving.plan import TransferConfig, TransferPlan
from repro.serving.policy import LinkPolicy, get_policy
from repro.serving.router import Router, get_router


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float
    prompt_len: int
    max_new_tokens: int
    # TTFT deadline (absolute time) for deadline-aware policies; +inf means
    # no SLO — the 'edf' policy then falls back to SchedulerConfig.slo_s
    deadline: float = math.inf
    # filled in by the pipeline:
    prefill_done: float = -1.0
    link_start: float = -1.0         # single link occupancy: [link_start,
    transfer_done: float = -1.0      #                         transfer_done)
    admit_time: float = -1.0         # admitted into a decode slot
    first_token_time: float = -1.0   # TTFT
    finish_time: float = -1.0
    tokens_out: int = 0
    # --- failure semantics (ISSUE 7) ---
    # terminal state, set exactly once when the request leaves the system:
    # 'completed' (served, no failover), 'failed-over' (served, but at least
    # one decode-worker death forced a cache re-fetch), 'shed' (dropped —
    # deadline provably infeasible, or failover budget exhausted)
    state: str = ""
    worker: int = -1                 # decode-worker assignment (-1: none yet)
    failovers: int = 0               # decode-worker deaths survived
    retries: int = 0                 # re-fetch transfers dispatched
    # EVERY link occupancy this request was charged, [link_start,
    # transfer_done) per element — failover re-fetches append here, so
    # conservation (link_busy_s == sum of all intervals, intervals pairwise
    # disjoint) stays checkable across failures
    link_history: List[Tuple[float, float]] = dataclasses.field(
        default_factory=list)
    # --- fleet fields (ISSUE 10) ---
    # multi-turn/agentic traffic: session >= 0 groups turns; prefix_len is
    # the token prefix already shipped for this session in earlier turns
    # (the delta-transfer hit candidate); tenant labels the SLO class
    session: int = -1
    prefix_len: int = 0
    tenant: str = ""
    # decode worker this request was ROUTED to (-1: deferred to admission —
    # the legacy router); which link carried each link_history interval
    pinned: int = -1
    link_ids: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class SchedulerConfig:
    max_prefill_batch: int = 8
    # flat decode-slot budget; superseded by the HBM-derived capacity below
    # whenever ``hbm_bytes_per_worker`` is set (ISSUE 8: the capacity win of
    # compressed-resident KV must reach the admission engine, not stay a
    # codec-side ratio)
    max_decode_slots: int = 64
    prefill_time_per_token: float = 2e-6     # model-dependent sim constant
    decode_time_per_step: float = 2e-3
    kv_bytes_per_token: int = 0              # sizes synthetic bucket plans
    profile: Optional[CodecProfile] = None   # codec/link profile
    compress: bool = True
    n_chunks: int = 1                        # segments per bucket plan
    # --- plan-aware admission (ROADMAP: "Plan-aware scheduler admission") ---
    # a pre-resolved plan (e.g. DisaggregatedEngine.plan): charged for every
    # request, byte-scaled by prompt_len * kv_bytes_per_token
    plan: Optional[TransferPlan] = None
    # build per-bucket plans from this arch's real cache structure instead of
    # the synthetic kv_bytes_per_token stream
    arch: Optional[ArchConfig] = None
    # codec policy for bucket plans (codebook/backend/layout/caps); enabled is
    # ANDed with ``compress``, n_chunks is overridden by ``n_chunks`` above
    transfer_config: Optional[TransferConfig] = None
    bucket_tokens: int = 1024                # prompt-length bucket granularity
    # expected per-attempt escape-overflow probability: walks the plan's
    # geometric capacity schedule in expectation (extra encode attempts +
    # raw-fallback fraction at full link cost)
    overflow_p: float = 0.0
    # per-bucket overflow priors (bucket tokens -> probability), overriding
    # the scalar ``overflow_p`` for buckets they cover.  Calibrate from a
    # real engine's observed retries: DisaggregatedEngine.overflow_priors()
    overflow_priors: Optional[Dict[int, float]] = None
    # link/admission policy registry key (repro.serving.policy):
    # 'fifo' (default) | 'sjf' | 'edf' | 'spec' — used for the single link
    # of the degenerate topology; an explicit ``cluster`` carries per-link
    # policies instead
    policy: str = "fifo"
    # default TTFT SLO (seconds after arrival) for deadline-aware policies
    # when a Request carries no explicit deadline
    slo_s: Optional[float] = None
    # decode-slot setup cost (KV-block allocation, buffer pinning) paid
    # between slot grant and the slot being decodable.  This is the wait a
    # speculative policy overlaps with the transfer: a slot claimed during
    # the transfer has its setup done by transfer_done, a slot granted at
    # transfer_done pays it afterwards
    admit_latency_s: float = 0.0
    # --- failure semantics (ISSUE 7) ---
    # decode workers sharing max_decode_slots (ceil-split per worker); a
    # worker's death fails its resident requests over to the survivors.
    # Legacy knob: superseded by ``cluster`` (resolve_cluster is the one
    # reader); keyword construction stays supported
    n_decode_workers: int = 1
    # injected fault plan: None | registry name | FaultPlan
    # (repro.serving.faults) — worker kills and link brownouts act here;
    # chunk-level faults act in the TransferSession execution path
    faults: Union[None, str, FaultPlan] = None
    # heartbeat lapse after which the FailureDetector declares a worker
    # (either tier) dead (failure DETECTION latency: requests on a killed
    # worker keep "decoding" until detection, exactly as deployed)
    heartbeat_timeout_s: float = 0.05
    # capped exponential backoff between a detected failure and the re-fetch
    # dispatch: retry k waits min(retry_backoff_s * 2**(k-1),
    # retry_backoff_max_s)
    retry_backoff_s: float = 0.01
    retry_backoff_max_s: float = 1.0
    # failover budget: a request whose worker dies more than this many times
    # is shed instead of retried forever
    max_refetches: int = 4
    # overload shedding of deadline-infeasible queued requests: None defers
    # to the policy's ``sheds`` default ('edf-shed' sheds, others don't);
    # True/False forces it either way
    shed_infeasible: Optional[bool] = None
    # --- HBM-derived decode capacity (ISSUE 8) ---
    # per-decode-worker HBM budget reserved for resident KV.  None keeps the
    # flat ``max_decode_slots``; set, the global slot budget becomes
    # floor(hbm / (resident_bytes_per_token * slot_tokens)) per worker,
    # summed over the fleet — so a compressed-resident deployment's measured
    # footprint ratio (KVPool.resident_ratio) translates directly into more
    # admitted sequences at the same HBM
    hbm_bytes_per_worker: Optional[int] = None
    # measured resident KV footprint per token per sequence: for
    # resident='compressed' use the pool's accounting
    # (KVPool.hbm_bytes / tokens, or bytes_per_token_resident); for
    # resident='raw' the raw cache bytes-per-token.  Required (and > 0)
    # whenever hbm_bytes_per_worker is set.
    resident_bytes_per_token: Optional[float] = None
    # per-slot KV reservation: the max context a resident sequence may grow
    # to while holding its slot
    slot_tokens: int = 4096
    # --- fleet topology (ISSUE 10) ---
    # explicit N-prefill x M-decode topology over heterogeneous links with a
    # registry router; None resolves to the degenerate legacy pipe
    # (repro.serving.cluster.resolve_cluster)
    cluster: Optional[ClusterConfig] = None
    # fired once per ACTUAL failover re-send dispatch (budget not exhausted)
    # with the failing-over Request — the hook an attached engine uses to
    # re-send the real cached compressed stream (resend_cache), so the
    # modeled re-fetch charge and the execution-path bytes stay one event
    on_failover: Optional[Callable[["Request"], None]] = None

    def derived_decode_slots(self) -> int:
        """The effective global decode-slot budget: ``max_decode_slots``
        verbatim, or — when an HBM budget is configured — the number of
        ``slot_tokens``-context sequences whose resident KV fits it."""
        n_decode = resolve_cluster(self).n_decode
        if self.hbm_bytes_per_worker is None:
            return self.max_decode_slots
        bpt = self.resident_bytes_per_token
        if bpt is None or bpt <= 0:
            raise ValueError(
                "hbm_bytes_per_worker needs resident_bytes_per_token > 0 "
                "(measure it: KVPool.hbm_bytes()/tokens for "
                "resident='compressed', raw cache bytes/token otherwise)")
        per_slot = bpt * max(1, self.slot_tokens)
        per_worker = int(self.hbm_bytes_per_worker // per_slot)
        if per_worker < 1:
            # flooring to 1 here would quietly over-commit the stated HBM
            # budget; surface the misconfiguration instead
            raise ValueError(
                f"hbm_bytes_per_worker={self.hbm_bytes_per_worker} fits no "
                f"slot_tokens={self.slot_tokens} sequence at "
                f"resident_bytes_per_token={bpt:g} "
                f"(one slot needs {per_slot:.0f} bytes)")
        return per_worker * n_decode


# same-timestamp event ordering: complete work before starting new work
_PRIO_ARRIVAL, _PRIO_PREFILL, _PRIO_TRANSFER, _PRIO_STEP = range(4)


class DisaggregatedScheduler:
    """Event-driven PD scheduler with a SplitZip-compressed transfer stage."""

    def __init__(self, cfg: SchedulerConfig):
        if (cfg.plan is not None and cfg.profile is not None
                and cfg.kv_bytes_per_token <= 0):
            # scale = 1.0 here would silently charge every prompt length the
            # plan's build-time bytes — a flat, wrong transfer curve
            raise ValueError(
                "SchedulerConfig.plan needs kv_bytes_per_token > 0 to scale "
                "the plan's bytes to each request's prompt length")
        self.cfg = cfg
        self.cluster: ClusterConfig = resolve_cluster(cfg)
        # resolved once: flat max_decode_slots, or the HBM-derived capacity
        # when the config carries a per-worker HBM budget (ISSUE 8)
        self.max_decode_slots = cfg.derived_decode_slots()
        self.router: Router = get_router(self.cluster.router)
        # one link policy per link; ``policy`` stays the link-0 alias for
        # the degenerate topology's single pipe
        self.link_policies: List[LinkPolicy] = [
            get_policy(spec.policy) for spec in self.cluster.links]
        self.policy: LinkPolicy = self.link_policies[0]
        # per-link codec/link profiles: the configured profile verbatim when
        # bw_scale == 1 (same OBJECT — the degenerate topology's float path
        # is bit-identical), else link_bw rescaled.  Heterogeneity is always
        # expressed against the one calibrated profile; no constants here.
        self._profiles: List[Optional[CodecProfile]] = [
            cfg.profile if (cfg.profile is None or spec.bw_scale == 1.0)
            else dataclasses.replace(
                cfg.profile, link_bw=cfg.profile.link_bw * spec.bw_scale)
            for spec in self.cluster.links]
        self.faults: Optional[FaultPlan] = resolve_faults(cfg.faults)
        # (sort-key, rid, Request) heaps: deterministic under any submission
        # interleaving — ties always break on rid.  Transfer queues are
        # plain per-link lists: each link's policy picks its minimum-key
        # member at dispatch time (policy keys end with rid, so picks stay
        # deterministic too).
        self.pending: List[Tuple[float, int, Request]] = []      # by arrival
        self.xfer_queues: List[List[Request]] = [
            [] for _ in self.cluster.links]                      # policy-ordered
        self.admit_queue: List[Tuple[float, int, Request]] = []  # by transfer_done
        self.decoding: List[Request] = []
        self.done: List[Request] = []
        self.plans: Dict[int, TransferPlan] = {}   # bucket tokens -> plan
        self.link_busy_s = 0.0                     # total charged link time
        self.link_busy_by_link: List[float] = [0.0] * self.cluster.n_links
        # failure counters (surfaced by summarize via the done list too)
        self.sheds = 0
        self.failovers = 0
        self.retries = 0
        self.prefill_failovers = 0     # requests re-queued off dead prefill
        # prefix-aware delta transfer (ISSUE 10): modeled bytes saved/spent
        self.prefix_hit_bytes = 0.0
        self.transfer_bytes = 0.0
        self.prefix_dir: Optional[PrefixDirectory] = (
            PrefixDirectory(self.cluster.n_decode,
                            self.cluster.prefix_cache_bytes)
            if self.cluster.prefix_cache_bytes is not None else None)
        self._events: List[Tuple[float, int, int, tuple]] = []
        self._seq = 0
        self._prefill_busy: List[bool] = [False] * self.cluster.n_prefill
        # the batch a prefill worker is computing (re-queued if it dies) and
        # its epoch (bumped on death: cancels the stale prefill_done event)
        self._prefill_batch: List[Optional[List[Request]]] = (
            [None] * self.cluster.n_prefill)
        self._prefill_epoch: List[int] = [0] * self.cluster.n_prefill
        self._link_busy: List[bool] = [False] * self.cluster.n_links
        self._link_req: List[Optional[Request]] = (
            [None] * self.cluster.n_links)     # in-flight transfer per link
        self._link_end: List[float] = [0.0] * self.cluster.n_links
        self._step_inflight = False
        self._rr: Dict[str, int] = {}              # round-robin router state
        self._dur_cache: Dict[Tuple[int, int], float] = {}  # (link, tokens)
        # fleet health: the SAME FailureDetector the training plane uses
        # (distributed/fault_tolerance.py), one per tier, driven by the sim
        # clock.  Workers heartbeat at every event unless a FaultPlan kill
        # has them down; deaths surface through newly_dead() with real
        # detection latency (heartbeat_timeout_s)
        self._now = 0.0
        self.detector = FailureDetector(
            self.cluster.n_decode,
            FaultConfig(heartbeat_timeout_s=cfg.heartbeat_timeout_s),
            clock=lambda: self._now)
        self.prefill_detector = FailureDetector(
            self.cluster.n_prefill,
            FaultConfig(heartbeat_timeout_s=cfg.heartbeat_timeout_s),
            clock=lambda: self._now)
        if self.faults is not None:
            eps = max(1e-9, cfg.heartbeat_timeout_s * 1e-6)
            for k in self.faults.worker_kills:
                bound = (self.cluster.n_decode if k.role == "decode"
                         else self.cluster.n_prefill)
                if k.worker >= bound:
                    continue
                # wake events guarantee the death is detected (and the
                # revival observed) even across an otherwise-idle heap
                self._push(k.at + cfg.heartbeat_timeout_s + eps,
                           _PRIO_ARRIVAL, ("wake",))
                if k.revive_at is not None:
                    self._push(k.revive_at, _PRIO_ARRIVAL, ("wake",))

    def submit(self, req: Request):
        # TTFT is defined by the first decoded token, so every served request
        # decodes at least one step; a non-positive budget is clamped rather
        # than looping forever in the drain (regression: ISSUE 4)
        if req.max_new_tokens < 1:
            req.max_new_tokens = 1
        self._push(req.arrival, _PRIO_ARRIVAL, ("arrival", req))

    # -- plan-aware transfer charging ---------------------------------------
    def _bucket(self, prompt_len: int) -> int:
        b = max(1, self.cfg.bucket_tokens)
        return max(b, -(-prompt_len // b) * b)

    def _bucket_plan(self, bucket: int) -> TransferPlan:
        """Resolve the bucket's TransferPlan once, reuse for every request of
        the bucket (compile-once/run-many, as the engine does per cache
        structure)."""
        plan = self.plans.get(bucket)
        if plan is None:
            tc = self.cfg.transfer_config or TransferConfig(
                codebook=DEFAULT_BF16_CODEBOOK)
            tc = dataclasses.replace(tc, enabled=tc.enabled and self.cfg.compress,
                                     n_chunks=self.cfg.n_chunks)
            if self.cfg.arch is not None:
                structure = jax.eval_shape(
                    lambda: init_cache(self.cfg.arch, 1, bucket))
            else:
                n = max(1, (bucket * self.cfg.kv_bytes_per_token) // 2)
                structure = {"kv": jax.ShapeDtypeStruct((n,), jnp.bfloat16)}
            plan = TransferPlan.build(structure, tc)
            self.plans[bucket] = plan
        return plan

    def _overflow_prior(self, prompt_len: int) -> float:
        """The expected per-attempt overflow probability for this request's
        bucket: the per-bucket prior when one is calibrated (engine-observed
        ``chunk_retries`` -> ``DisaggregatedEngine.overflow_priors``), else
        the scalar ``overflow_p``."""
        if self.cfg.overflow_priors:
            return self.cfg.overflow_priors.get(self._bucket(prompt_len),
                                                self.cfg.overflow_p)
        return self.cfg.overflow_p

    def _transfer_duration(self, link: int, tokens: int) -> float:
        """One occupancy of ``link`` shipping ``tokens`` tokens of KV,
        charged via ``plan.estimate_time`` on the link's profile: flowshop
        over the plan's actual segments (chunked), additive (tensor), native
        link cost (all-raw), with expected capacity-schedule retries under
        the bucket's overflow prior.  ``tokens`` is the DELTA a prefix-aware
        transfer actually ships (== prompt_len on cold paths).  Memoized per
        (link, tokens) — link policies (e.g. shortest-transfer-first) and
        the router evaluate it for every candidate at every dispatch."""
        cached = self._dur_cache.get((link, tokens))
        if cached is not None:
            return cached
        p = self._profiles[link]
        if p is None:
            return 0.0
        if self.cfg.plan is not None:
            plan = self.cfg.plan
            ref = plan.raw_bytes()
            scale = (float(tokens * self.cfg.kv_bytes_per_token) / ref
                     if ref > 0 else 1.0)
        else:
            if self.cfg.arch is None and self.cfg.kv_bytes_per_token <= 0:
                return 0.0
            bucket = self._bucket(tokens)
            plan = self._bucket_plan(bucket)
            if self.cfg.kv_bytes_per_token > 0:
                scale = (float(tokens * self.cfg.kv_bytes_per_token)
                         / plan.raw_bytes())
            else:
                scale = tokens / bucket
        dur = plan.estimate_time(p, scale=scale,
                                 overflow_p=self._overflow_prior(tokens))
        self._dur_cache[(link, tokens)] = dur
        return dur

    # -- prefix-aware delta transfer (ISSUE 10) ------------------------------
    def _token_bytes(self, r: Request) -> float:
        """Modeled raw KV bytes per token for this request — the unit behind
        the prefix directory's capacity accounting and the hit/transfer byte
        counters (0.0 when the config carries no byte scale at all)."""
        if self.cfg.kv_bytes_per_token > 0:
            return float(self.cfg.kv_bytes_per_token)
        if self.cfg.arch is not None:
            bucket = self._bucket(r.prompt_len)
            return self._bucket_plan(bucket).raw_bytes() / bucket
        return 0.0

    def _xfer_tokens(self, r: Request, wid: int) -> int:
        """Tokens this request must actually ship to decode worker ``wid``:
        the full prompt, minus the session prefix already resident there
        (never below 1 — a turn always appends fresh tokens).  Cold paths
        (no directory, no session, no pinned worker) ship everything."""
        if self.prefix_dir is None or r.session < 0 or wid < 0:
            return r.prompt_len
        hit = min(self.prefix_dir.hit_tokens(wid, r.session),
                  r.prefix_len, r.prompt_len)
        return max(1, r.prompt_len - hit)

    def _note_resident(self, wid: int, r: Request, tokens: int) -> None:
        """The session's resident prefix on ``wid`` now spans ``tokens``."""
        if self.prefix_dir is None or r.session < 0 or wid < 0:
            return
        self.prefix_dir.insert(wid, r.session, tokens, self._token_bytes(r))

    # -- router view (duck-typed read surface for Router.place) --------------
    def est_transfer_s(self, r: Request, link: int, wid: int) -> float:
        """Plan-estimated seconds to ship this request's uncached suffix to
        ``wid`` over ``link`` — the router's transfer term."""
        return self._transfer_duration(link, self._xfer_tokens(r, wid))

    def link_backlog_s(self, link: int) -> float:
        """Estimated seconds of work ahead of a new arrival on ``link``:
        the in-flight transfer's remaining wall clock plus every queued
        request's estimated occupancy."""
        busy = max(0.0, self._link_end[link] - self._now) \
            if self._link_busy[link] else 0.0
        return busy + sum(
            self._transfer_duration(link, self._xfer_tokens(q, q.pinned))
            for q in self.xfer_queues[link])

    def decode_load(self, wid: int) -> int:
        """Resident + inbound (routed-but-not-admitted) requests on ``wid``
        — the router's queue-depth term."""
        n = sum(1 for r in self.decoding if r.worker == wid)
        n += sum(1 for _, _, r in self.admit_queue
                 if r.pinned == wid and r.worker < 0)
        for q in self.xfer_queues:
            n += sum(1 for r in q if r.pinned == wid)
        n += sum(1 for r in self._link_req
                 if r is not None and r.pinned == wid and r.worker < 0)
        return n

    def decode_alive(self, wid: int) -> bool:
        return self.detector.workers[wid].alive

    def rr_next(self, kind: str) -> int:
        """Scheduler-owned round-robin counters (router singletons are
        stateless so equal-seed runs stay deterministic)."""
        v = self._rr.get(kind, 0)
        self._rr[kind] = v + 1
        return v

    def _route(self, t: float, r: Request) -> None:
        """Place ``r`` on a (link, decode) pair and queue its transfer."""
        li, wid = self.router.place(r, self)
        r.pinned = wid
        self.xfer_queues[li].append(r)

    # -- the event loop ------------------------------------------------------
    def _push(self, t: float, prio: int, payload: tuple) -> None:
        heapq.heappush(self._events, (t, prio, self._seq, payload))
        self._seq += 1

    def run(self) -> List[Request]:
        """Drain all submitted requests; returns them with timings filled.
        Every returned request is terminal in exactly one state:
        ``'completed'``, ``'failed-over'`` (served despite a decode-worker
        death), or ``'shed'`` (dropped — infeasible deadline or exhausted
        failover budget)."""
        while self._events:
            t = self._events[0][0]
            self._now = t
            # fleet health first: live workers heartbeat at every event
            # time, so the detectors' view lags reality by at most the
            # heartbeat timeout — real detection latency, simulated
            self._heartbeat_alive(t)
            # complete EVERY event at this timestamp before dispatching new
            # work, so resource assignment never depends on heap-push order
            while self._events and self._events[0][0] == t:
                payload = heapq.heappop(self._events)[3]
                self._handle(t, payload)
            for wid in self.detector.newly_dead():
                self._on_worker_death(t, wid)
            for pw in self.prefill_detector.newly_dead():
                self._on_prefill_death(t, pw)
            self._dispatch(t)
        stranded = (len(self.pending) + sum(map(len, self.xfer_queues))
                    + len(self.admit_queue) + len(self.decoding))
        if stranded:
            # e.g. max_decode_slots == 0 or every decode worker permanently
            # dead: admission can never happen and the event heap drains
            # with requests still queued — fail loudly instead of returning
            # a silently partial done list
            raise RuntimeError(
                f"{stranded} request(s) never completed (check "
                "max_decode_slots/max_prefill_batch > 0 and that at least "
                "one worker per tier survives the fault plan)")
        return self.done

    # -- worker fleets -------------------------------------------------------
    def _worker_down(self, wid: int, t: float, role: str = "decode") -> bool:
        """Is worker ``wid`` of ``role`` kill-silenced (not heartbeating)?"""
        if self.faults is None:
            return False
        return any(k.worker == wid and k.role == role and k.at <= t
                   and (k.revive_at is None or t < k.revive_at)
                   for k in self.faults.worker_kills)

    def _heartbeat_alive(self, t: float) -> None:
        for wid in self.detector.workers:
            if not self._worker_down(wid, t, "decode"):
                self.detector.heartbeat(wid)
        for pw in self.prefill_detector.workers:
            if not self._worker_down(pw, t, "prefill"):
                self.prefill_detector.heartbeat(pw)

    def _slots_per_worker(self) -> int:
        return -(-self.max_decode_slots // self.cluster.n_decode)

    def _pick_worker(self) -> Optional[int]:
        """Least-loaded ALIVE decode worker with a free slot (ties break to
        the lowest id), respecting the global ``max_decode_slots`` budget.
        None when no worker can take a request right now."""
        if len(self.decoding) >= self.max_decode_slots:
            return None
        per = self._slots_per_worker()
        loads = {w.worker_id: 0 for w in self.detector.workers.values()
                 if w.alive}
        for r in self.decoding:
            if r.worker in loads:
                loads[r.worker] += 1
        cands = [(load, wid) for wid, load in loads.items() if load < per]
        return min(cands)[1] if cands else None

    def _grant_worker(self, r: Request) -> Optional[int]:
        """The decode worker ``r`` may occupy right now, or None.  A routed
        (pinned) request only ever lands on its pinned worker — its cache is
        being shipped THERE; an unpinned request takes the legacy
        least-loaded-alive pick."""
        if r.pinned < 0:
            return self._pick_worker()
        if len(self.decoding) >= self.max_decode_slots:
            return None
        wid = r.pinned
        if not self.detector.workers[wid].alive:
            return None
        load = sum(1 for q in self.decoding if q.worker == wid)
        return wid if load < self._slots_per_worker() else None

    def _fail_over(self, t: float, r: Request) -> None:
        """The decode-side copy of ``r``'s cache is gone (worker death after
        its transfer completed): charge a failover, and either re-send —
        capped-backoff refetch, re-routed on wake — or shed when the budget
        is exhausted.  Fires ``cfg.on_failover`` per actual re-send so an
        attached engine re-ships the real cached stream."""
        r.worker = -1
        r.failovers += 1
        self.failovers += 1
        if r.failovers > self.cfg.max_refetches:
            self._shed(t, r)
            return
        backoff = min(self.cfg.retry_backoff_s * 2.0 ** (r.failovers - 1),
                      self.cfg.retry_backoff_max_s)
        r.retries += 1
        self.retries += 1
        r.admit_time = -1.0
        r.transfer_done = -1.0
        r.link_start = -1.0
        r.pinned = -1
        if self.cfg.on_failover is not None:
            self.cfg.on_failover(r)
        self._push(t + backoff, _PRIO_ARRIVAL, ("refetch", r))

    def _on_worker_death(self, t: float, wid: int) -> None:
        """Decode worker ``wid`` declared dead: its resident decode state
        and prefix cache are gone.  Requests whose transfer had completed
        (resident, or still queued for admission) FAIL OVER — their
        compressed cache is re-sent (a fresh link occupancy at the same
        ``plan.estimate_time`` charge) after a capped exponential backoff,
        then re-routed to a surviving worker; tokens already emitted are
        kept (they were already streamed).  Requests merely ROUTED here
        whose transfer never started are silently re-routed (nothing was
        lost).  Speculative slot-holders merely lose the slot.  A request
        whose failover budget is exhausted is shed — terminal, never
        silent."""
        if self.prefix_dir is not None:
            self.prefix_dir.drop_worker(wid)
        for r in list(self.decoding):
            if r.worker != wid:
                continue
            self.decoding.remove(r)
            r.worker = -1
            if r.transfer_done < 0:          # speculative hold: no cache lost
                r.admit_time = -1.0
                continue
            self._fail_over(t, r)
        # cache landed on the dead worker but the slot grant hadn't happened
        lost = sorted(k for k in self.admit_queue if k[2].pinned == wid)
        if lost:
            self.admit_queue = [k for k in self.admit_queue
                                if k[2].pinned != wid]
            heapq.heapify(self.admit_queue)
            for _, _, r in lost:
                self._fail_over(t, r)
        # routed here but the transfer never started: the cache is still on
        # the prefill side — re-route, no failover charged
        for li in range(self.cluster.n_links):
            moved = [r for r in self.xfer_queues[li] if r.pinned == wid]
            if not moved:
                continue
            self.xfer_queues[li] = [r for r in self.xfer_queues[li]
                                    if r.pinned != wid]
            for r in moved:
                self._route(t, r)
        # in-flight transfers TO the dead worker are handled at their
        # transfer_done (the dead-destination check there)

    def _on_prefill_death(self, t: float, pw: int) -> None:
        """Prefill worker ``pw`` declared dead mid-batch: bump its epoch
        (cancels the pending ``prefill_done`` event) and re-queue the
        in-flight requests by their original arrival order for a surviving
        worker.  Nothing downstream existed yet — no link or decode state to
        clean up, tokens conserved by construction."""
        self._prefill_epoch[pw] += 1
        batch = self._prefill_batch[pw]
        self._prefill_batch[pw] = None
        self._prefill_busy[pw] = False
        if not batch:
            return
        for r in batch:
            self.prefill_failovers += 1
            heapq.heappush(self.pending, (r.arrival, r.rid, r))

    def _shed_enabled(self, link: int) -> bool:
        if self.cfg.shed_infeasible is not None:
            return self.cfg.shed_infeasible
        return self.link_policies[link].sheds

    def _shed(self, t: float, r: Request) -> None:
        r.state = "shed"
        r.finish_time = t
        self.sheds += 1
        self.done.append(r)

    def _shed_infeasible(self, t: float) -> None:
        """Drop queued requests that PROVABLY cannot meet their deadline:
        even dispatching right now — nominal transfer, then one decode step
        — lands past it.  Only guaranteed losses are shed, so the shed set
        is minimal (any work-conserving policy misses exactly these) and
        the freed link time can only help the survivors."""
        for li in range(self.cluster.n_links):
            if not self.xfer_queues[li] or not self._shed_enabled(li):
                continue
            keep = []
            for r in self.xfer_queues[li]:
                dl = self.link_policies[li].deadline_of(r, self.cfg)
                if (dl != math.inf
                        and t + self._transfer_duration(
                            li, self._xfer_tokens(r, r.pinned))
                        + self.cfg.decode_time_per_step > dl):
                    self._shed(t, r)
                else:
                    keep.append(r)
            self.xfer_queues[li] = keep

    def _handle(self, t: float, payload: tuple) -> None:
        """Complete one event: move the request to the next queue and free
        the resource it held.  Resource (re)assignment happens afterwards in
        :meth:`_dispatch`, once every same-timestamp event has drained."""
        kind = payload[0]
        if kind == "arrival":
            r = payload[1]
            heapq.heappush(self.pending, (r.arrival, r.rid, r))
        elif kind == "prefill_done":
            batch, pw, epoch = payload[1], payload[2], payload[3]
            if epoch != self._prefill_epoch[pw]:
                return   # the worker died mid-batch; requests were re-queued
            self._prefill_busy[pw] = False
            self._prefill_batch[pw] = None
            for r in batch:
                r.prefill_done = t
                self._route(t, r)
        elif kind == "transfer_done":
            r, li = payload[1], payload[2]
            r.transfer_done = t
            r.link_history.append((r.link_start, t))
            r.link_ids.append(li)
            self._link_busy[li] = False
            self._link_req[li] = None
            if r.pinned >= 0 and not self.detector.workers[r.pinned].alive:
                # the cache landed on a worker already declared dead: the
                # bytes are lost — full failover (re-send on wake)
                self._fail_over(t, r)
            elif r.admit_time < 0:
                # speculatively admitted requests (policy 'spec') already
                # hold their decode slot; everyone else queues for admission
                self._note_resident(r.pinned, r, r.prompt_len)
                heapq.heappush(self.admit_queue, (t, r.rid, r))
            else:
                self._note_resident(r.worker, r, r.prompt_len)
        elif kind == "refetch":
            # failover backoff elapsed: the compressed cache is re-routed
            # (the old placement may be dead) and re-enters a transfer
            # queue, competing under that link's normal policy
            self._route(t, payload[1])
        elif kind == "decode_step":
            self._finish_step(t, payload[1])
        # 'wake': no state change — the event exists to force a scheduler
        # pass (heartbeat sweep + death detection) at a fault-plan instant

    def _next_for_link(self, li: int) -> Request:
        """Link ``li``'s policy pick: minimum ``link_key`` over its queued
        requests (keys end with rid — deterministic under ties)."""
        pol = self.link_policies[li]
        r = min(self.xfer_queues[li],
                key=lambda q: pol.link_key(
                    q, self._transfer_duration(
                        li, self._xfer_tokens(q, q.pinned)), self.cfg))
        # remove by identity, not list.remove: Request is an eq-by-value
        # dataclass, so two field-identical requests would otherwise have one
        # dispatched twice and the other silently dropped
        for i, q in enumerate(self.xfer_queues[li]):
            if q is r:
                del self.xfer_queues[li][i]
                break
        return r

    def _dispatch(self, t: float) -> None:
        """Start whatever each idle resource can pick up at time ``t``.

        This is the policy's dispatch point: each idle link takes its
        policy-minimal queued request, the decode fleet drains the
        admission queue into free slots (completed transfers always first),
        and — only under a speculative link policy — that link's in-flight
        transfer may claim a slot that is STILL free after that drain."""
        for pw in range(self.cluster.n_prefill):
            if not self.pending:
                break
            if (self._prefill_busy[pw]
                    or not self.prefill_detector.workers[pw].alive):
                continue
            batch = []
            while self.pending and len(batch) < self.cfg.max_prefill_batch:
                batch.append(heapq.heappop(self.pending)[2])
            dur = (max(r.prompt_len for r in batch)
                   * self.cfg.prefill_time_per_token)
            self._prefill_busy[pw] = True
            self._prefill_batch[pw] = batch
            self._push(t + dur, _PRIO_PREFILL,
                       ("prefill_done", batch, pw, self._prefill_epoch[pw]))
        self._shed_infeasible(t)
        for li in range(self.cluster.n_links):
            if self._link_busy[li] or not self.xfer_queues[li]:
                continue
            r = self._next_for_link(li)
            r.link_start = t
            tokens = self._xfer_tokens(r, r.pinned)
            dur = self._transfer_duration(li, tokens)
            end = t + dur
            if self.faults is not None:
                # link brownout: the same bytes at the degraded piecewise
                # rate — the link is HELD for the full wall-clock interval,
                # so occupancy stays conserved (link_busy_s == Σ intervals)
                end = self.faults.link_wall_clock(t, dur, li)
            self.link_busy_s += end - t
            self.link_busy_by_link[li] += end - t
            bpt = self._token_bytes(r)
            self.transfer_bytes += tokens * bpt
            if tokens < r.prompt_len:
                self.prefix_hit_bytes += (r.prompt_len - tokens) * bpt
            self._link_busy[li] = True
            self._link_req[li] = r
            self._link_end[li] = end
            self._push(end, _PRIO_TRANSFER, ("transfer_done", r, li))
        overflow = []    # pinned requests whose worker is momentarily full
        while self.admit_queue:
            r = self.admit_queue[0][2]
            w = self._grant_worker(r)
            if w is None:
                if r.pinned < 0:
                    # unpinned head blocked == every alive worker is at
                    # capacity (or the global budget is) — strict
                    # head-of-line, exactly the legacy admission order
                    break
                overflow.append(heapq.heappop(self.admit_queue))
                continue
            heapq.heappop(self.admit_queue)
            r.admit_time = t
            r.worker = w
            self.decoding.append(r)
        for item in overflow:
            heapq.heappush(self.admit_queue, item)
        for li in range(self.cluster.n_links):
            r = self._link_req[li]
            if (r is None or not self.link_policies[li].speculative
                    or r.admit_time >= 0):
                continue
            # speculative admission: the transferring request pre-claims a
            # LEFTOVER slot (never outranks a completed transfer above), so
            # its decode-slot wait overlaps its transfer
            w = self._grant_worker(r)
            if w is not None:
                r.admit_time = t
                r.worker = w
                self.decoding.append(r)
        # the decode worker only ticks when some slot can actually produce a
        # token: a population of purely speculative slot-holders (transfers
        # still in flight) must not start the lockstep clock early, or a
        # misaligned step boundary would DELAY their first token
        if (not self._step_inflight
                and any(r.transfer_done >= 0 for r in self.decoding)):
            self._step_inflight = True
            self._push(t + self.cfg.decode_time_per_step, _PRIO_STEP,
                       ("decode_step", t))

    def _finish_step(self, t: float, step_start: float) -> None:
        """One lockstep decode step [step_start, t] completed: every slot
        that was READY by step_start gains a token — ready means the
        transfer completed AND the slot's setup (``admit_latency_s`` after
        the grant) finished.  Later joiners start with the next step;
        speculative slot-holders whose transfer is still pending produce
        nothing.  Finished requests retire and free their slots."""
        self._step_inflight = False
        lat = self.cfg.admit_latency_s
        for r in list(self.decoding):
            if r.admit_time > step_start or r.admit_time + lat > step_start:
                continue   # not granted / slot setup still running
            if r.transfer_done < 0 or r.transfer_done > step_start:
                continue   # speculative hold: cache not on this worker yet
            r.tokens_out += 1
            if r.first_token_time < 0:
                r.first_token_time = t
            if r.tokens_out >= r.max_new_tokens:
                r.finish_time = t
                r.state = "failed-over" if r.failovers else "completed"
                # the retiring session's KV (prompt + generation) stays
                # resident until evicted — the next turn's delta baseline
                self._note_resident(r.worker, r,
                                    r.prompt_len + r.tokens_out)
                self.decoding.remove(r)
                self.done.append(r)


def summarize(done: List[Request]) -> Dict[str, float]:
    """Aggregate a drained run.  Latency/throughput statistics cover SERVED
    requests only (``completed`` + ``failed-over``) — a shed request has no
    TTFT and averaging it in would reward shedding; the failure-plane
    outcome counts sit alongside so nothing disappears from the report."""
    if not done:
        return {}
    served = [r for r in done if r.state != "shed"]
    counts = {
        "n_shed": float(len(done) - len(served)),
        "n_failed_over": float(sum(1 for r in served
                                   if r.state == "failed-over")),
        "n_failovers": float(sum(r.failovers for r in done)),
        "n_retries": float(sum(r.retries for r in done)),
    }
    if not served:
        return {"n": 0, **counts}
    ttfts = sorted(r.first_token_time - r.arrival for r in served)
    n = len(ttfts)
    # nearest-rank (ceil) quantile: 1-based rank ceil(q*n); the old floor
    # index int(q*(n-1)) underestimated the tail for small n
    p99 = ttfts[min(n - 1, max(0, math.ceil(0.99 * n) - 1))]
    total_tokens = sum(r.tokens_out for r in served)
    makespan = (max(r.finish_time for r in served)
                - min(r.arrival for r in served))
    return {
        "n": len(served),
        "mean_ttft_s": sum(ttfts) / n,
        "p99_ttft_s": p99,
        "throughput_tok_s": total_tokens / makespan if makespan > 0 else 0.0,
        "throughput_req_s": len(served) / makespan if makespan > 0 else 0.0,
        **counts,
    }
