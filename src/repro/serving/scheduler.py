"""Request scheduler for disaggregated serving (continuous batching).

Pure-Python orchestration around the jitted prefill/transfer/decode steps:
requests arrive with a prompt length and a max-new-tokens budget; the
scheduler assembles prefill batches (padded to a bucket), hands the produced
caches to the transfer engine, admits transferred requests into decode slots,
and retires finished requests.  Timing is simulated with the analytic codec /
link profile so the same scheduler drives both the real CPU execution (tiny
configs, tests) and the paper-scale what-if sweeps (Fig. 2 analogue).

The transfer-time model follows the engine's granularity setting:
``n_chunks == 1`` uses the additive whole-tensor accounting (paper Fig. 4),
``n_chunks > 1`` uses the chunked steady-state pipeline (paper Appendix A),
matching ``transfer_cache_chunked``'s ChunkSchedule overlap.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Callable, Dict, List, Optional

from repro.core.pipeline import (CodecProfile, additive_transfer_time,
                                 native_transfer_time, pipelined_transfer_time)


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float
    prompt_len: int
    max_new_tokens: int
    # filled in by the pipeline:
    prefill_done: float = -1.0
    transfer_done: float = -1.0
    first_token_time: float = -1.0   # TTFT
    finish_time: float = -1.0
    tokens_out: int = 0


@dataclasses.dataclass
class SchedulerConfig:
    max_prefill_batch: int = 8
    max_decode_slots: int = 64
    prefill_time_per_token: float = 2e-6     # model-dependent sim constant
    decode_time_per_step: float = 2e-3
    kv_bytes_per_token: int = 0              # set from the arch config
    profile: Optional[CodecProfile] = None   # codec/link profile
    compress: bool = True
    # transfer-granularity model: 1 => additive whole-tensor accounting
    # (paper Fig. 4); >1 => chunked pipeline, encode/transfer/decode overlap
    # (paper Appendix A; matches transfer_cache_chunked's ChunkSchedule)
    n_chunks: int = 1


class DisaggregatedScheduler:
    """Event-driven PD scheduler with a SplitZip-compressed transfer stage."""

    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.pending: deque[Request] = deque()
        self.transferring: List[Request] = []
        self.decoding: List[Request] = []
        self.done: List[Request] = []
        self.t_prefill = 0.0   # prefill worker busy-until
        self.t_link = 0.0      # transfer link busy-until
        self.t_decode = 0.0    # decode worker busy-until

    def submit(self, req: Request):
        self.pending.append(req)

    def _transfer_time(self, prompt_len: int) -> float:
        bytes_ = prompt_len * self.cfg.kv_bytes_per_token
        p = self.cfg.profile
        if p is None or bytes_ == 0:
            return 0.0
        if self.cfg.compress:
            if self.cfg.n_chunks > 1:
                return pipelined_transfer_time(bytes_, p, self.cfg.n_chunks)
            return additive_transfer_time(bytes_, p)
        return native_transfer_time(bytes_, p)

    def run(self) -> List[Request]:
        """Drain all requests; returns completed requests with timings."""
        while self.pending or self.transferring or self.decoding:
            # 1) prefill stage: batch up to max_prefill_batch pending requests
            if self.pending:
                batch = []
                while self.pending and len(batch) < self.cfg.max_prefill_batch:
                    batch.append(self.pending.popleft())
                start = max(self.t_prefill, max(r.arrival for r in batch))
                dur = max(r.prompt_len for r in batch) * self.cfg.prefill_time_per_token
                self.t_prefill = start + dur
                for r in batch:
                    r.prefill_done = self.t_prefill
                    self.transferring.append(r)

            # 2) transfer stage: serialize on the link, per request
            still = []
            for r in sorted(self.transferring, key=lambda r: r.prefill_done):
                start = max(self.t_link, r.prefill_done)
                dur = self._transfer_time(r.prompt_len)
                self.t_link = start + dur
                r.transfer_done = self.t_link
                if len(self.decoding) < self.cfg.max_decode_slots:
                    r.first_token_time = r.transfer_done + self.cfg.decode_time_per_step
                    self.decoding.append(r)
                else:
                    still.append(r)
            self.transferring = still

            # 3) decode stage: step all active slots until the shortest finishes
            if self.decoding:
                steps = min(r.max_new_tokens - r.tokens_out for r in self.decoding)
                self.t_decode = max(self.t_decode,
                                    max(r.transfer_done for r in self.decoding))
                self.t_decode += steps * self.cfg.decode_time_per_step
                for r in list(self.decoding):
                    r.tokens_out += steps
                    if r.tokens_out >= r.max_new_tokens:
                        r.finish_time = self.t_decode
                        self.decoding.remove(r)
                        self.done.append(r)
        return self.done


def summarize(done: List[Request]) -> Dict[str, float]:
    if not done:
        return {}
    ttfts = [r.first_token_time - r.arrival for r in done]
    total_tokens = sum(r.tokens_out for r in done)
    makespan = max(r.finish_time for r in done) - min(r.arrival for r in done)
    return {
        "n": len(done),
        "mean_ttft_s": sum(ttfts) / len(ttfts),
        "p99_ttft_s": sorted(ttfts)[int(0.99 * (len(ttfts) - 1))],
        "throughput_tok_s": total_tokens / makespan if makespan > 0 else 0.0,
        "throughput_req_s": len(done) / makespan if makespan > 0 else 0.0,
    }
