"""Prefill worker: runs the prompt, produces the cache the PD boundary ships.

In the disaggregated deployment this code runs on the prefill pod; the jitted
``prefill_step`` is the unit of work per prompt batch, and its output cache is
handed to the transfer engine (serving/transfer.py) — compressed with
SplitZip — before any decode work can start (the paper's critical path).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.models.kvcache import DecodeState


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PrefillOutput:
    """What the prefill worker emits per batch."""
    first_token: jax.Array        # (B,) greedy first generated token
    last_logits: jax.Array        # (B, V)
    state: DecodeState            # the cache to transfer


def prefill_step(params, batch: Dict, cfg: ArchConfig, *,
                 max_seq: Optional[int] = None, kv_block: int = 1024
                 ) -> PrefillOutput:
    last_logits, state = M.prefill(params, batch, cfg, max_seq=max_seq,
                                   kv_block=kv_block)
    if cfg.encoder_only:
        # encode-and-ship: "first_token" is the argmax unit per frame start
        first = jnp.argmax(last_logits[:, 0], axis=-1).astype(jnp.int32) \
            if last_logits.ndim == 3 else jnp.zeros((last_logits.shape[0],), jnp.int32)
        return PrefillOutput(first_token=first, last_logits=last_logits[:, -1]
                             if last_logits.ndim == 3 else last_logits,
                             state=state)
    first = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)
    return PrefillOutput(first_token=first, last_logits=last_logits, state=state)


def make_prefill_fn(cfg: ArchConfig, max_seq: Optional[int] = None,
                    kv_block: int = 1024):
    """Jit-wrapped prefill step (static model config baked in)."""
    @jax.jit
    def fn(params, batch):
        return prefill_step(params, batch, cfg, max_seq=max_seq,
                            kv_block=kv_block)
    return fn
