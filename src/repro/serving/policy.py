"""Pluggable link/admission policies for the disaggregated scheduler.

The PR-4 event engine made the PD link an explicit resource with a single
dispatch point: when the link goes idle, ONE request is picked from the
transfer queue and occupies it for exactly one interval.  That dispatch
point is where service-aware ordering of compressed KV transfers lives —
KVServe (arXiv 2605.13734) shows it materially shifts tail TTFT — and this
module makes it pluggable without touching the event loop's accounting
invariants (link conservation, single occupancy, deterministic tie-breaks).

A policy answers two questions:

1. **Link ordering** (:meth:`LinkPolicy.link_key`): given the requests
   whose prefill has completed, which one gets the idle link next?  The
   scheduler calls ``link_key(req, est_transfer_s, cfg)`` for every queued
   request and dispatches the minimum.  Keys MUST end with ``req.rid`` so
   ties break deterministically under any submission interleaving (the
   event engine's determinism test covers every registered policy).
2. **Speculative admission** (:attr:`LinkPolicy.speculative`): may the
   request currently occupying the link pre-claim a free decode slot
   *while its transfer is still in flight*?  This overlaps the decode-slot
   wait with the transfer; the first token still cannot be produced before
   ``transfer_done`` (the decode step loop skips slots whose transfer is
   pending), and completed requests waiting in the admission queue always
   have priority over a speculative claim, so admission never starves a
   ready request.

Built-in policies:

``fifo``
    Strict FIFO by prefill completion — the PR-4 default, bit-identical
    to the pre-policy scheduler.
``sjf``
    Shortest-transfer-first: orders the link by the plan-estimated
    transfer duration.  Lowers mean TTFT on mixed prompt lengths at the
    cost of the longest transfers' tail (classic SJF trade, pinned by
    ``tests/test_policy.py``).
``edf``
    Earliest-deadline-first on ``Request.deadline`` (fall back to
    ``arrival + cfg.slo_s`` when the request carries none, and to FIFO
    order when neither exists).  For simultaneously-released requests this
    is Jackson's rule: it minimizes maximum lateness, so any set of
    deadlines FIFO can meet, EDF meets too.
``edf-shed``
    EDF plus overload shedding: queued requests that provably cannot meet
    their deadline (immediate dispatch would still land past it) are
    dropped at the dispatch point with terminal state ``'shed'`` instead
    of burning link time on a guaranteed SLO miss.  The shed set is
    minimal — only requests no work-conserving policy could save.
``spec``
    FIFO link ordering plus speculative decode admission (see above).

Out-of-tree policies register with :func:`register_policy`; the scheduler
resolves ``SchedulerConfig.policy`` through :func:`get_policy`, mirroring
the codec-backend registry (:mod:`repro.core.backend`).

Run ``python -m pydoc repro.serving.policy`` for this page.
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING, Callable, Dict, Tuple

if TYPE_CHECKING:  # only for annotations: scheduler imports this module
    from repro.serving.scheduler import Request, SchedulerConfig


class LinkPolicy:
    """Abstract link/admission policy.  Subclasses set ``name`` and
    override :meth:`link_key`; set ``speculative = True`` to enable
    speculative decode admission (see the module docstring for the exact
    semantics and invariants)."""

    name: str = "abstract"
    #: May the in-flight transfer pre-claim a free decode slot?
    speculative: bool = False
    #: Shed queued requests that provably cannot meet their deadline?  The
    #: scheduler drops such requests at the link dispatch point (terminal
    #: state 'shed') instead of burning link time on a guaranteed SLO miss;
    #: ``SchedulerConfig.shed_infeasible`` overrides this default either way.
    sheds: bool = False

    def link_key(self, req: "Request", est_transfer_s: float,
                 cfg: "SchedulerConfig") -> Tuple:
        """Sort key for the idle-link dispatch: the queued request with the
        MINIMUM key gets the link.  ``est_transfer_s`` is the plan-estimated
        transfer duration for this request (``plan.estimate_time`` through
        the scheduler's bucket/engine plan — the same charge the link will
        actually take).  Keys must end with ``req.rid`` for determinism."""
        raise NotImplementedError

    def deadline_of(self, req: "Request", cfg: "SchedulerConfig") -> float:
        """The effective deadline: the request's own, else ``arrival +
        cfg.slo_s``, else +inf (no deadline pressure)."""
        if req.deadline != math.inf:
            return req.deadline
        if cfg.slo_s is not None:
            return req.arrival + cfg.slo_s
        return math.inf


class FifoPolicy(LinkPolicy):
    """Strict FIFO by prefill completion (the PR-4 scheduler's behaviour)."""

    name = "fifo"

    def link_key(self, req, est_transfer_s, cfg):
        return (req.prefill_done, req.rid)


class ShortestTransferFirstPolicy(LinkPolicy):
    """Shortest-transfer-first (SJF on the link): the queued request with
    the smallest plan-estimated transfer duration goes next.  Mean/median
    TTFT improves on mixed prompt lengths; the longest transfers pay the
    tail (they can be overtaken while queued, never once on the link —
    dispatch is non-preemptive)."""

    name = "sjf"

    def link_key(self, req, est_transfer_s, cfg):
        return (est_transfer_s, req.prefill_done, req.rid)


class EarliestDeadlinePolicy(LinkPolicy):
    """SLO-aware EDF: order the link by effective deadline
    (``Request.deadline``, else ``arrival + cfg.slo_s``).  Deadline ties
    (including the no-deadline +inf case) fall back to FIFO order, so an
    EDF scheduler with no deadlines anywhere degenerates to ``fifo``."""

    name = "edf"

    def link_key(self, req, est_transfer_s, cfg):
        return (self.deadline_of(req, cfg), req.prefill_done, req.rid)


class SheddingEDFPolicy(EarliestDeadlinePolicy):
    """EDF link ordering + overload shedding: queued requests whose deadline
    is provably infeasible (even an IMMEDIATE dispatch — transfer now, first
    decode step right after — would land past it) are shed at the dispatch
    point.  Because only provably-lost requests are dropped, the shed set is
    minimal: every request this policy sheds misses its deadline under ANY
    work-conserving policy, and the link time it frees can only help the
    survivors (pinned against FIFO by ``tests/test_fault_tolerance.py``)."""

    name = "edf-shed"
    sheds = True


class SpeculativeAdmissionPolicy(FifoPolicy):
    """FIFO link ordering + speculative decode admission: the request
    holding the link may claim a decode slot left over AFTER the admission
    queue drains, so its slot wait overlaps its transfer.  Link accounting
    is untouched — occupancy conservation holds bit-identically to FIFO
    (pinned by ``tests/test_policy.py``)."""

    name = "spec"
    speculative = True


# ---------------------------------------------------------------------------
# registry (mirrors repro.core.backend)
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], LinkPolicy]] = {}
_INSTANCES: Dict[str, LinkPolicy] = {}


def register_policy(name: str, factory: Callable[[], LinkPolicy]) -> None:
    """Register a link/admission policy under ``name`` (later wins)."""
    _REGISTRY[name] = factory
    _INSTANCES.pop(name, None)


def get_policy(name: str) -> LinkPolicy:
    """Resolve a policy name to its (cached) instance."""
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown link policy {name!r}; available: {available_policies()}")
    if name not in _INSTANCES:
        _INSTANCES[name] = _REGISTRY[name]()
    return _INSTANCES[name]


def available_policies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


register_policy("fifo", FifoPolicy)
register_policy("sjf", ShortestTransferFirstPolicy)
register_policy("edf", EarliestDeadlinePolicy)
register_policy("edf-shed", SheddingEDFPolicy)
register_policy("spec", SpeculativeAdmissionPolicy)
