"""TransferSession: executes a resolved :class:`TransferPlan` many times.

One session == one (plan, execution-target) pair.  ``send(cache)`` runs the
prefill-side work (encode + the wire hop), ``recv()`` the decode-side work,
``transfer(cache)`` fuses both; ``last_stats`` carries per-call accounting.
All serving consumers (``DisaggregatedEngine``, launchers, benchmarks,
examples) go through this API — the free functions in
:mod:`repro.serving.transfer` are deprecation shims over a one-shot plan.

Five execution paths, selected by the plan and the entry point:

* **local / tensor** (``mesh=None, n_chunks == 1``): per-leaf encode ->
  hand-off -> decode, per-tensor raw fallback, geometric capacity retries.
* **local / chunked** (``mesh=None, n_chunks > 1``): the pipelined engine —
  ``ChunkSchedule`` drives encode of chunk t / ship of t-1 / decode of t-2
  over the plan's precomputed codec-chunk-aligned segments, with fp32 hi
  halves folded into the stream and per-chunk retries + raw fallback.
* **mesh** (``mesh=``): the same two granularities traced inside
  ``shard_map`` over the 'pod' axis.  ``n_chunks > 1`` ships each chunk with
  its own ``lax.ppermute`` and holds at most two chunks in flight
  (double-buffering: encode of chunk t is issued while chunk t-1's permute
  and chunk t-2's decode are outstanding), so the overlap is structural in
  the traced program, not just modeled.  In-graph execution cannot branch on
  the concrete ``ok`` flag, so the mesh path encodes once at plan capacity;
  overflow is detected off-graph exactly as on the whole-tensor path.
* **persistent** (``save(path)`` / ``load(path)``): per-leaf SZ02 wire
  frames on disk plus a plan-derived JSON manifest
  (docs/wire_format.md §9).  Loads re-verify Fletcher-32 per file AND the
  payload's own integrity-frame table; mismatches re-fetch down the plan's
  retry budget and raise :class:`~repro.core.wire.WireIntegrityError` when
  the corruption is persistent.  distributed/checkpoint.py is a thin
  wrapper over this executor.
* **collective** (``ring_reduce(stacked)``): grad_compress's rotating-ring
  ppermute exchange over compressed streams, traced inside ``shard_map``
  over the plan's pod axis with the mesh executor's bit-pinned permutes.
  training/grad_compress.py is a thin wrapper over this executor.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from repro.core.backend import CodecBackend, WireCompressed, get_backend
from repro.core.pipeline import ChunkSchedule
from repro.core.wire import WireIntegrityError, WireStats, fletcher32
from repro.serving.faults import FaultChannel, resolve_faults
from repro.serving.plan import TransferPlan, TransferStats, leaf_key

_WIRE_INT = {1: jnp.uint8, 2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint64}

# persistent-executor manifest (docs/wire_format.md §9)
PERSIST_MANIFEST = "manifest.json"
PERSIST_FORMAT = "szpersist-1"

# hard ceiling on wire attempts per unit (initial ship + re-fetches).  The
# default FaultPlan stops randomized faults at max_attempt=8, so only an
# explicitly-persistent adversarial plan can reach this — and then the
# session fails LOUDLY instead of decoding garbage or spinning forever.
_MAX_WIRE_ATTEMPTS = 32


class TransferIntegrityError(RuntimeError):
    """A wire unit could not be delivered intact within the attempt budget —
    every capacity-schedule re-fetch and the terminal raw re-fetches all
    failed verification.  Raised instead of ever decoding corrupt bytes."""


def _backend_for(comp_obj, be: CodecBackend) -> CodecBackend:
    """Resolve the backend that can actually decode ``comp_obj``.

    Wire payloads decode only with the wire backend, in-graph
    CompressedTensors only with a jittable one (xla and pallas share the
    stream layout, so either decodes either).  A mismatched backend is
    corrected instead of crashing with an opaque AttributeError."""
    from repro.core.backend import WireCompressed
    if isinstance(comp_obj, WireCompressed):
        return be if be.name == "wire" else get_backend("wire")
    return be if be.jittable else get_backend("xla")


def _permute_leaf(x: jax.Array, axis_name: str, src: int, dst: int) -> jax.Array:
    """ppermute with the payload pinned to its exact bit width.

    XLA CPU (and some TPU paths) upcast small-float collectives — doubling
    the wire bytes and silently defeating the codec.  Bitcasting to a
    same-width integer type before the collective guarantees the HLO moves
    exactly the bytes we account for; the roundtrip is a bitcast, hence
    lossless."""
    if jnp.issubdtype(x.dtype, jnp.floating) and x.dtype.itemsize in _WIRE_INT:
        w = _WIRE_INT[x.dtype.itemsize]
        y = jax.lax.ppermute(jax.lax.bitcast_convert_type(x, w), axis_name,
                             perm=[(src, dst)])
        return jax.lax.bitcast_convert_type(y, x.dtype)
    return jax.lax.ppermute(x, axis_name, perm=[(src, dst)])


# ---------------------------------------------------------------------------
# per-leaf encode/decode (tensor granularity; also the mesh whole-tensor body)
# ---------------------------------------------------------------------------

def _encode_scheduled(plan: TransferPlan, x, codebook, n: int, cap: int,
                      *, scheduled: bool):
    """Encode ``x`` down the plan's geometric capacity schedule.

    Returns ``(ct, ok, extra_attempts)``.  ``scheduled=False`` (one-shot
    shims, in-graph tracing) encodes once at plan capacity and leaves ``ok``
    traced — the schedule's concrete ``ok`` branch is host-side control
    flow."""
    tc = plan.tc
    ct = plan.backend.encode(x, codebook, chunk=tc.chunk, cap=cap,
                             layout=tc.layout)
    if not scheduled:
        return ct, plan.backend.ok(ct), 0
    if bool(plan.backend.ok(ct)):
        return ct, True, 0
    extra = 0
    for be, layout, c in plan.schedule_for(n, cap)[1:]:
        extra += 1
        ct = be.encode(x, codebook, chunk=tc.chunk, cap=c, layout=layout)
        if bool(be.ok(ct)):
            return ct, True, extra
    return ct, False, extra


def _record_unit(stats: Optional[TransferStats], key: str, ok: bool,
                 extra: int) -> None:
    if stats is None:
        return
    stats.leaf_ok[key] = ok
    stats.chunk_retried.append(extra > 0)
    stats.chunk_retry_steps.append(extra)


def encode_leaves(plan: TransferPlan, cache, *, scheduled: bool = True,
                  stats: Optional[TransferStats] = None) -> Tuple[Dict, Dict]:
    """Per-leaf route execution -> (comp, raw) in the legacy key convention:
    ``comp[key]`` holds splitzip/fp8 streams, ``comp[key + '#hi']`` the fp32
    hi half, ``raw[key + '#lo']`` its raw lo half, ``raw[key]`` passthrough
    (including the raw fallback of units whose capacity schedule exhausted).

    ``scheduled=False`` is the one-shot / in-graph mode: single encode at
    plan capacity, streams kept regardless of the (traced) ``ok`` flag."""
    tc = plan.tc
    be = plan.backend
    comp: Dict[str, object] = {}
    raw: Dict[str, jax.Array] = {}
    flat = jax.tree_util.tree_flatten_with_path(cache)[0]
    for (path, leaf), r in zip(flat, plan.routes):
        key = r.key
        if r.route == "splitzip":
            ct, ok, extra = _encode_scheduled(plan, leaf, tc.codebook,
                                              r.n_elements, r.cap,
                                              scheduled=scheduled)
            if scheduled and not bool(ok):
                raw[key] = leaf
                if stats is not None:
                    stats.leaf_wire_bytes[key] = r.raw_bytes
                _record_unit(stats, key, False, extra)
            else:
                comp[key] = ct
                if stats is not None:
                    stats.leaf_wire_bytes[key] = float(be.wire_bytes(ct))
                _record_unit(stats, key, True, extra)
        elif r.route == "fp32_hilo":
            u = jax.lax.bitcast_convert_type(leaf, jnp.uint32)
            hi = (u >> 16).astype(jnp.uint16)
            lo = (u & 0xFFFF).astype(jnp.uint16)
            ct, ok, extra = _encode_scheduled(plan, hi, tc.codebook,
                                              r.n_elements, r.cap,
                                              scheduled=scheduled)
            if scheduled and not bool(ok):
                # an overflowed hi half means the WHOLE fp32 leaf ships raw
                raw[key] = leaf
                if stats is not None:
                    stats.leaf_wire_bytes[key] = r.raw_bytes
                _record_unit(stats, key, False, extra)
            else:
                comp[key + "#hi"] = ct
                raw[key + "#lo"] = lo
                if stats is not None:
                    stats.leaf_wire_bytes[key] = float(be.wire_bytes(ct))
                    stats.fp32_lo_wire_bytes += 2.0 * r.n_elements
                _record_unit(stats, key, True, extra)
        elif r.route == "fp8":
            ct, ok, extra = _encode_scheduled(plan, leaf, plan.fp8_codebook,
                                              r.n_elements, r.cap,
                                              scheduled=scheduled)
            if scheduled and not bool(ok):
                raw[key] = leaf
                if stats is not None:
                    stats.fp8_wire_bytes += r.raw_bytes
                _record_unit(stats, key, False, extra)
            else:
                comp[key] = ct
                if stats is not None:
                    stats.fp8_wire_bytes += float(be.wire_bytes(ct))
                _record_unit(stats, key, True, extra)
        else:
            raw[key] = leaf
            if stats is not None:
                stats.raw_passthrough_bytes += r.raw_bytes
    return comp, raw


def decode_leaves(comp: Dict, raw: Dict, structure, backend: str = "xla"):
    """Inverse of :func:`encode_leaves` against the original pytree structure.
    Per-object backend dispatch (:func:`_backend_for`) tolerates a
    ``backend=`` argument that doesn't match what produced ``comp``."""
    be = get_backend(backend)
    flat, treedef = jax.tree_util.tree_flatten_with_path(structure)
    leaves = []
    for path, leaf in flat:
        key = leaf_key(path)
        if key in comp:
            ct = comp[key]
            leaves.append(jnp.asarray(
                _backend_for(ct, be).decode(ct)).reshape(leaf.shape))
        elif key + "#hi" in comp:  # fp32 hi/lo split
            ct = comp[key + "#hi"]
            hi = jnp.asarray(
                _backend_for(ct, be).decode(ct)).reshape(leaf.shape)
            lo = raw[key + "#lo"]
            u = (hi.astype(jnp.uint32) << 16) | lo.astype(jnp.uint32)
            leaves.append(jax.lax.bitcast_convert_type(u, jnp.float32))
        else:
            leaves.append(raw[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# prefix-delta index (transfer_delta)
# ---------------------------------------------------------------------------

def _host_bits(x) -> np.ndarray:
    """Flat byte view of any array-like, on host.  Sender-shadow comparison
    runs in the BIT domain, not the numeric one — NaN payloads, negative
    zeros, and denormals all compare exactly."""
    return np.ascontiguousarray(np.asarray(x)).view(np.uint8).reshape(-1)


@dataclasses.dataclass
class _PrefixEntry:
    """One session's resident cache, seen from both ends of the wire:
    sender-side bit shadows (what to compare the next turn against) and
    receiver-side objects (what a hit re-uses without any wire traffic)."""

    stream: np.ndarray                   # sender u16 shadow of fold_stream
    seg_bits: List[jax.Array]            # receiver decoded bits per segment
    side_shadow: Dict[str, np.ndarray]   # "<fam>:<key>" -> sender host bits
    side_obj: Dict[str, object]          # "<fam>:<key>" -> receiver object
    nbytes: float                        # raw-byte footprint (LRU accounting)


class PrefixIndex:
    """LRU-by-bytes map of session id -> :class:`_PrefixEntry`.

    This is the execution-side twin of the scheduler's sim-side
    ``PrefixDirectory``: where the directory *models* residency in token
    counts, this index *holds* the actual receiver objects and the sender
    shadows that :meth:`TransferSession.transfer_delta` compares against.
    ``capacity_bytes=None`` means unbounded; otherwise least-recently-used
    sessions are dropped until the raw-byte footprint fits (a single entry
    larger than the whole budget is dropped immediately — residency must
    never exceed the stated HBM envelope)."""

    def __init__(self, capacity_bytes: Optional[float] = None):
        if capacity_bytes is not None and capacity_bytes <= 0:
            raise ValueError("capacity_bytes must be positive (or None)")
        self.capacity_bytes = capacity_bytes
        self.evictions = 0
        self._entries: "OrderedDict[object, _PrefixEntry]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def sessions(self):
        return list(self._entries)

    @property
    def resident_bytes(self) -> float:
        return sum(e.nbytes for e in self._entries.values())

    def get(self, session_id) -> Optional[_PrefixEntry]:
        e = self._entries.get(session_id)
        if e is not None:
            self._entries.move_to_end(session_id)
        return e

    def put(self, session_id, entry: _PrefixEntry) -> None:
        self._entries[session_id] = entry
        self._entries.move_to_end(session_id)
        if self.capacity_bytes is None:
            return
        while self._entries and self.resident_bytes > self.capacity_bytes:
            self._entries.popitem(last=False)
            self.evictions += 1

    def drop(self, session_id) -> None:
        self._entries.pop(session_id, None)

    def clear(self) -> None:
        self._entries.clear()


# ---------------------------------------------------------------------------
# the session
# ---------------------------------------------------------------------------

class TransferSession:
    """Run a :class:`TransferPlan` repeatedly: ``send``/``recv`` or the fused
    ``transfer``.  Accumulates ``calls``/``total_wire_bytes``; per-call
    accounting is in ``last_stats`` (None on the mesh path, whose wire bytes
    are read from the lowered HLO — see analysis/roofline.py).

    **Wire integrity** (``verify=True`` and/or ``faults=``): every wire
    object — pipeline chunks, tensor-path leaves, sidecars — ships inside a
    Fletcher-32 checksum frame over a :class:`~repro.serving.faults.
    FaultChannel`.  With ``verify`` on, a mismatched or dropped frame is
    re-fetched through the plan's capacity-retry machinery (re-encode at the
    next schedule step, re-ship with the fault coordinate re-keyed), with
    the unit's RAW bits as the terminal re-fetch; corrupt bytes are never
    decoded, and exhaustion raises :class:`TransferIntegrityError` instead
    of degrading silently.  ``faults=`` injects a seeded
    :class:`~repro.serving.faults.FaultPlan` into the channel so all of this
    is testable on CPU.  Local paths only — the mesh path's wire is a traced
    collective with no host frame to checksum."""

    def __init__(self, plan: TransferPlan, *, faults=None,
                 verify: bool = False, retain_last: bool = False):
        self.plan = plan
        self.verify = verify
        self.retain_last = retain_last
        self.faults = resolve_faults(faults)
        if plan.mesh is not None and (verify or self.faults is not None):
            raise ValueError(
                "verify/faults run on the host wire hop; the mesh path's "
                "collective permute has no host-side frame to checksum")
        # the checksum-framed wire: active whenever faults are injected or
        # verification is on, so the happy path pays nothing
        self._channel = (FaultChannel(self._object_checksum, self.faults)
                         if (verify or self.faults is not None) else None)
        self.last_stats: Optional[TransferStats] = None
        self.calls = 0
        self.total_wire_bytes = 0.0
        self._uid = 0         # per-send transfer id (fault-plan keying)
        self._injected_seen = 0
        self._staged = None   # in-flight payload between send() and recv()
        # failover re-send: the pristine encoded payload of the most recent
        # tensor-path send, kept only under retain_last (see resend_last)
        self._retained = None
        # prefix-delta state: session-id -> _PrefixEntry (see transfer_delta)
        self._prefix_index: Optional[PrefixIndex] = None
        # executor closures, built on first use: a mesh plan may only ever
        # run the collective executor (ring specs don't fit the send/recv
        # out_specs convention), so neither shard_map is constructed eagerly
        self._mesh_fn = None
        self._ring_fns = {}         # frozenset(raw-forced leaf idx) -> fn
        self._ring_routes = None    # per-participant routes for the ring

    def _object_checksum(self, obj) -> int:
        """Fletcher-32 over any wire object — compressed (backend leaves or
        host payload bytes) or a raw array."""
        return _backend_for(obj, self.plan.backend).checksum(obj)

    # -- public API ----------------------------------------------------------
    def send(self, cache, check: bool = True) -> None:
        """Prefill-side half: encode every routed leaf and put the payload on
        the (simulated or collective) wire.  Call ``recv`` to complete.
        ``check=False`` skips the structure validation for callers that
        already ran ``plan.matches`` themselves (one pytree walk saved per
        call on the hot path)."""
        if self._staged is not None:
            raise RuntimeError("send() called twice without recv()")
        if check:
            self._check_structure(cache)
        self._uid += 1
        if self.plan.mesh is not None:
            self._staged = ("mesh", cache)
        elif self.plan.granularity == "chunked":
            self._staged = ("chunked", self._send_chunked(cache))
        else:
            self._staged = ("tensor", self._send_tensor(cache))

    def _set_verify(self, verify: Optional[bool]) -> None:
        """Per-call ``verify=`` knob: None keeps the session default."""
        if verify is None:
            return
        if verify and self._channel is None:
            raise ValueError(
                "this session shipped unframed payloads (no checksums on the "
                "wire); build it with plan.session(verify=True) or faults=")
        self.verify = bool(verify)

    def recv(self, select_dst: bool = True, verify: Optional[bool] = None):
        """Decode-side half: returns the reassembled cache pytree.
        ``verify=True`` enforces the checksum frames shipped by ``send``
        (re-fetch on mismatch; see class docs), ``verify=False`` delivers
        without enforcement, None keeps the session default."""
        if self._staged is None:
            raise RuntimeError("recv() called before send()")
        self._set_verify(verify)
        kind, payload = self._staged
        self._staged = None
        if kind == "mesh":
            out = self._run_mesh(payload, select_dst=select_dst)
        elif kind == "chunked":
            out = self._recv_chunked(payload)
        else:
            out = self._recv_tensor(payload)
        self._account()
        return out

    def transfer(self, cache, select_dst: bool = True, check: bool = True,
                 verify: Optional[bool] = None):
        """Fused send + recv.  The local chunked path interleaves the stages
        on the explicit ``ChunkSchedule`` (encode t / ship t-1 / decode t-2),
        exactly the ordering deployment wall-clock overlaps; the result is
        bit-identical to split send()+recv().  ``verify=`` as on ``recv``."""
        self._set_verify(verify)
        if self.plan.mesh is None and self.plan.granularity == "chunked":
            if self._staged is not None:
                raise RuntimeError("transfer() called with a send() pending")
            if check:
                self._check_structure(cache)
            self._uid += 1
            out = self._transfer_chunked_interleaved(cache)
            self._account()
            return out
        self.send(cache, check=check)
        return self.recv(select_dst=select_dst)

    def transfer_compressed(self, cache, check: bool = True,
                            verify: Optional[bool] = None):
        """Tensor-path transfer that STOPS at the compressed streams.

        Resident-KV admission consumes the received ``CompressedTensor``s
        directly (``models/kvpool.KVPool.admit_from_wire``) — the decode
        worker never rehydrates the stream it is about to keep compressed.
        Returns ``(comp, raw)`` in the ``encode_leaves`` key convention;
        leaves that fell back to raw (escape overflow, un-routed dtypes)
        appear in ``raw`` and make the batch inadmissible for residency.

        Only the local tensor path qualifies: chunked and mesh granularities
        re-segment leaves, so their wire streams are not page-addressable."""
        if self.plan.mesh is not None or self.plan.granularity == "chunked":
            raise ValueError(
                "transfer_compressed requires the local tensor path "
                "(mesh=None, n_chunks == 1); use transfer() and raw "
                "residency for segmented transfers")
        self._set_verify(verify)
        self.send(cache, check=check)
        _, payload = self._staged
        self._staged = None
        comp, raw, structure, pristine_comp, pristine_raw = payload
        if self._channel is not None:
            comp, raw = self._deliver_tensor(comp, raw, structure,
                                             pristine_comp, pristine_raw)
        self._account()
        return comp, raw

    def resend_last(self, verify: Optional[bool] = None):
        """Re-ship the most recent tensor-path transfer from its retained
        encoded payload — the decode-worker-failover path.

        When the destination worker dies after the wire hop completed, the
        prefill side still holds the pristine compressed streams of the last
        ``send`` (kept under ``retain_last=True``); re-sending them to the
        replacement worker costs one wire hop, not a re-encode.  Returns the
        decoded cache, bit-identical to the original transfer's result;
        ``last_stats`` / ``total_wire_bytes`` account the repeated hop like
        any other call.  Tensor granularity only — chunked/mesh payloads are
        not retained (their streams are re-segmented per transfer)."""
        if self.plan.mesh is not None or self.plan.granularity == "chunked":
            raise ValueError(
                "resend_last requires the local tensor path (mesh=None, "
                "n_chunks == 1); chunked/mesh transfers are not retained")
        if self._retained is None:
            raise RuntimeError(
                "no retained transfer to re-send; build the session with "
                "retain_last=True and complete a transfer first")
        if self._staged is not None:
            raise RuntimeError("resend_last() called with a send() pending")
        self._set_verify(verify)
        comp, raw, cache = self._retained
        be = self.plan.backend
        stats = TransferStats(chunk_wire_bytes=[], chunk_ok=[],
                              raw_passthrough_bytes=0.0, n_elements=0)
        for r in self.plan.routes:
            key = r.key
            if key in comp:
                nbytes = float(_backend_for(comp[key], be)
                               .wire_bytes(comp[key]))
                if r.route == "fp8":
                    stats.fp8_wire_bytes += nbytes
                else:
                    stats.leaf_wire_bytes[key] = nbytes
                stats.leaf_ok[key] = True
            elif key + "#hi" in comp:
                hi = comp[key + "#hi"]
                stats.leaf_wire_bytes[key] = float(
                    _backend_for(hi, be).wire_bytes(hi))
                stats.fp32_lo_wire_bytes += 2.0 * r.n_elements
                stats.leaf_ok[key] = True
            elif r.route == "raw":
                stats.raw_passthrough_bytes += r.raw_bytes
            else:
                # a leaf that fell back to raw on the original encode
                if r.route == "fp8":
                    stats.fp8_wire_bytes += r.raw_bytes
                else:
                    stats.leaf_wire_bytes[key] = r.raw_bytes
                stats.leaf_ok[key] = False
        self.last_stats = stats
        self._uid += 1
        if self._channel is not None:
            comp_f = {k: self._channel.ship(v, self._uid, ci, 0)
                      for ci, (k, v) in enumerate(comp.items())}
            raw_f = {k: self._channel.ship(v, self._uid, len(comp) + ci, 0)
                     for ci, (k, v) in enumerate(raw.items())}
            comp_d, raw_d = self._deliver_tensor(comp_f, raw_f, cache,
                                                 comp, raw)
        else:
            comp_d, raw_d = comp, raw
        out = decode_leaves(comp_d, raw_d, cache,
                            backend=self.plan.tc.backend)
        self._account()
        return out

    # -- prefix-delta transfer ----------------------------------------------
    def enable_prefix_cache(self,
                            capacity_bytes: Optional[float] = None
                            ) -> PrefixIndex:
        """Attach a :class:`PrefixIndex` so :meth:`transfer_delta` can skip
        segments the destination already holds.  Chunked local path only —
        delta granularity IS the plan's codec-aligned segmentation.  Returns
        the index (idempotent; the first capacity wins)."""
        if self.plan.mesh is not None or self.plan.granularity != "chunked":
            raise ValueError(
                "prefix-delta transfer rides the chunked local path "
                "(mesh=None, n_chunks > 1); build the plan with "
                "granularity='chunked'")
        if self._prefix_index is None:
            self._prefix_index = PrefixIndex(capacity_bytes)
        return self._prefix_index

    def transfer_delta(self, cache, session_id, *, check: bool = True,
                       verify: Optional[bool] = None):
        """Prefix-aware transfer: ship only the segments (and sidecars) that
        CHANGED since this session id's last transfer.

        The sender compares each segment of the folded stream bit-for-bit
        against its retained shadow of the previous turn; an identical
        segment costs zero wire bytes — the receiver re-uses the decoded
        bits it already holds — and its raw size lands in
        ``last_stats.prefix_hit_bytes`` (deliberately excluded from
        ``wire_bytes``).  Changed segments run the normal chunked machinery:
        capacity-schedule retries, checksum framing, verified re-fetches.
        Sidecars (fp32 lo halves, fp8 leaves, raw passthrough) delta the
        same way on whole-object bit equality.  The result is bit-identical
        to a full ``transfer`` of the same cache; a cold session id degrades
        to exactly a full transfer.  Requires :meth:`enable_prefix_cache`."""
        if self._prefix_index is None:
            raise RuntimeError(
                "prefix cache not enabled; call enable_prefix_cache() first")
        if self._staged is not None:
            raise RuntimeError("transfer_delta() called with a send() "
                               "pending")
        self._set_verify(verify)
        if check:
            self._check_structure(cache)
        self._uid += 1
        plan = self.plan
        stats = self._new_chunked_stats()
        stream, lo, fp8, raw = plan.fold_stream(cache)
        host_stream = np.asarray(stream)
        entry = self._prefix_index.get(session_id)

        # pipelined stream: per-segment sender-shadow comparison
        bits: List[jax.Array] = []
        for i, seg in enumerate(plan.segments):
            if entry is not None and np.array_equal(
                    host_stream[seg.start:seg.stop],
                    entry.stream[seg.start:seg.stop]):
                bits.append(entry.seg_bits[i])
                stats.prefix_hit_bytes += seg.raw_bytes
                # chunk_wire_bytes[i] stays 0.0: nothing crossed the wire
            else:
                p = self._wire_hop(stream, i, self._encode_chunk(stream, i),
                                   stats)
                bits.append(self._chunk_out(stream, i, p, stats))

        # sidecars: whole-object bit equality against the shadow
        lo_out: Dict[str, object] = {}
        fp8_dec: Dict[str, object] = {}
        raw_out: Dict[str, object] = {}
        miss_lo: Dict[str, object] = {}
        miss_fp8: Dict[str, object] = {}
        miss_raw: Dict[str, object] = {}

        def _side_hit(fam: str, key: str, sender_obj) -> bool:
            if entry is None:
                return False
            shadow = entry.side_shadow.get(f"{fam}:{key}")
            return (shadow is not None
                    and np.array_equal(_host_bits(sender_obj), shadow))

        for r in plan.routes:
            k = r.key
            if r.route == "fp32_hilo":
                if _side_hit("lo", k, lo[k]):
                    lo_out[k] = entry.side_obj[f"lo:{k}"]
                    stats.prefix_hit_bytes += 2.0 * r.n_elements
                else:
                    miss_lo[k] = lo[k]
                    stats.fp32_lo_wire_bytes += 2.0 * r.n_elements
            elif r.route == "fp8":
                if _side_hit("fp8", k, fp8[k]):
                    fp8_dec[k] = entry.side_obj[f"fp8:{k}"]
                    stats.prefix_hit_bytes += r.raw_bytes
                else:
                    ct, ok, extra = _encode_scheduled(
                        plan, fp8[k], plan.fp8_codebook, r.n_elements, r.cap,
                        scheduled=True)
                    _record_unit(stats, k, bool(ok), extra)
                    stats.fp8_wire_bytes += (
                        float(plan.backend.wire_bytes(ct)) if ok
                        else r.raw_bytes)
                    miss_fp8[k] = ct if ok else fp8[k]
            elif r.route == "raw":
                if _side_hit("raw", k, raw[k]):
                    raw_out[k] = entry.side_obj[f"raw:{k}"]
                    stats.prefix_hit_bytes += r.raw_bytes
                else:
                    miss_raw[k] = raw[k]
                    stats.raw_passthrough_bytes += r.raw_bytes

        if self._channel is not None:
            lo_f, fp8_f, raw_f = self._ship_sidecars(miss_lo, miss_fp8,
                                                     miss_raw)
            miss_lo, miss_fp8, miss_raw = self._deliver_sidecars(
                lo_f, fp8_f, raw_f, (miss_lo, miss_fp8, miss_raw), stats)
        lo_out.update(miss_lo)
        raw_out.update(miss_raw)
        for k, p in miss_fp8.items():
            if isinstance(p, (jax.Array, np.ndarray)):  # raw fallback leaf
                fp8_dec[k] = jnp.asarray(p)
            else:
                fp8_dec[k] = _backend_for(p, plan.backend).decode(p)

        bits_out = (jnp.concatenate(bits) if len(bits) > 1 else bits[0])
        out = plan.unfold_stream(bits_out, lo_out, fp8_dec, raw_out)

        # refresh the shadow + receiver objects for the NEXT turn
        shadow: Dict[str, np.ndarray] = {}
        side_obj: Dict[str, object] = {}
        nbytes = 2.0 * host_stream.size
        for r in plan.routes:
            k = r.key
            if r.route == "fp32_hilo":
                shadow[f"lo:{k}"] = _host_bits(lo[k]).copy()
                side_obj[f"lo:{k}"] = lo_out[k]
                nbytes += 2.0 * r.n_elements
            elif r.route == "fp8":
                shadow[f"fp8:{k}"] = _host_bits(fp8[k]).copy()
                side_obj[f"fp8:{k}"] = fp8_dec[k]
                nbytes += r.raw_bytes
            elif r.route == "raw":
                shadow[f"raw:{k}"] = _host_bits(raw[k]).copy()
                side_obj[f"raw:{k}"] = raw_out[k]
                nbytes += r.raw_bytes
        self._prefix_index.put(session_id, _PrefixEntry(
            stream=host_stream.copy(), seg_bits=list(bits),
            side_shadow=shadow, side_obj=side_obj, nbytes=nbytes))

        self.last_stats = stats
        self._account()
        return out

    def lower_hlo(self, cache) -> str:
        """Post-SPMD HLO of the mesh program on ``cache``: the
        collective-permute operand sizes are the actual wire bytes."""
        if self.plan.mesh is None:
            raise ValueError("lower_hlo is only meaningful for mesh plans")
        if self._mesh_fn is None:
            self._mesh_fn = self._build_mesh_fn()
        leaves = jax.tree_util.tree_leaves(cache)
        return jax.jit(self._mesh_fn).lower(*leaves).compile().as_text()

    # -- persistent executor -------------------------------------------------
    def save(self, path: str, tree, *, extra: Optional[Dict] = None,
             check: bool = True) -> str:
        """Write ``tree`` to ``path`` as one SZ02 wire frame per routed leaf
        plus a plan-derived JSON manifest (docs/wire_format.md §9).

        Routes execute exactly as on the wire: 'splitzip' leaves become SZ02
        payloads (with their embedded Fletcher-32 integrity sections), fp32
        hi/lo leaves an SZ02 hi-half payload followed by the raw lo bytes,
        'fp8' leaves an SZ02 payload under the fp8 codebook, 'raw' leaves
        their exact bytes.  Atomicity rule: everything is written into a
        temp directory next to ``path`` and renamed into place, so a
        directory named ``path`` is either absent or complete.  Returns
        ``path``; per-call accounting in ``last_stats``."""
        if self.plan.mesh is not None:
            raise ValueError("save/load run on host files; build the plan "
                             "with mesh=None")
        if check:
            self._check_structure(tree)
        self._uid += 1
        plan, tc = self.plan, self.plan.tc
        wire_be = get_backend("wire")
        stats = TransferStats(chunk_wire_bytes=[], chunk_ok=[],
                              raw_passthrough_bytes=0.0,
                              n_elements=plan.stream_len)
        flat = jax.tree_util.tree_flatten_with_path(tree)[0]
        parent = os.path.dirname(os.path.abspath(path)) or "."
        os.makedirs(parent, exist_ok=True)
        tmp = tempfile.mkdtemp(dir=parent, prefix=".tmp_persist_")
        entries = []
        try:
            for i, ((_, leaf), r) in enumerate(zip(flat, plan.routes)):
                fname = f"leaf_{i:05d}.szc"
                payload, tail = b"", b""
                if r.route == "splitzip":
                    ct = wire_be.encode(leaf, tc.codebook, chunk=tc.chunk)
                    payload = ct.payload
                    stats.leaf_wire_bytes[r.key] = float(len(payload))
                    stats.leaf_ok[r.key] = True
                elif r.route == "fp32_hilo":
                    u = jax.lax.bitcast_convert_type(leaf, jnp.uint32)
                    hi = jax.lax.bitcast_convert_type(
                        (u >> 16).astype(jnp.uint16), jnp.bfloat16)
                    ct = wire_be.encode(hi, tc.codebook, chunk=tc.chunk)
                    payload = ct.payload
                    tail = np.asarray((u & 0xFFFF).astype(jnp.uint16)).tobytes()
                    stats.leaf_wire_bytes[r.key] = float(len(payload))
                    stats.leaf_ok[r.key] = True
                    stats.fp32_lo_wire_bytes += float(len(tail))
                elif r.route == "fp8":
                    ct = wire_be.encode(leaf, plan.fp8_codebook, chunk=tc.chunk)
                    payload = ct.payload
                    stats.fp8_wire_bytes += float(len(payload))
                    stats.leaf_ok[r.key] = True
                else:
                    tail = np.asarray(leaf).tobytes()
                    stats.raw_passthrough_bytes += float(len(tail))
                blob = payload + tail
                with open(os.path.join(tmp, fname), "wb") as f:
                    f.write(blob)
                entries.append({
                    "key": r.key, "file": fname, "route": r.route,
                    "shape": list(r.shape), "dtype": r.dtype,
                    "sz_bytes": len(payload),
                    "checksum": int(fletcher32(np.frombuffer(blob, np.uint8))),
                })
            manifest = {"format": PERSIST_FORMAT,
                        "codebook": {"fmt": tc.codebook.fmt,
                                     "exponents": list(tc.codebook.exponents)},
                        "extra": extra or {}, "leaves": entries}
            with open(os.path.join(tmp, PERSIST_MANIFEST), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(path):
                shutil.rmtree(path)
            os.rename(tmp, path)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self.last_stats = stats
        self._account()
        return path

    def load(self, path: str) -> Tuple[object, Dict]:
        """Read a :meth:`save` directory back into the plan's pytree,
        bit-exactly.  Returns ``(tree, extra)``.

        Every leaf file is verified twice: Fletcher-32 over the file bytes
        against the manifest, then the SZ02 payload's own integrity-frame
        table during decode.  A mismatch (or an injected ``faults=`` frame
        fault) re-fetches the file down the plan's retry budget
        (``retry_doublings + 1`` re-reads, counted in
        ``last_stats.refetches``); persistent corruption raises
        :class:`~repro.core.wire.WireIntegrityError` — the caller
        (distributed/checkpoint.py) falls back to the previous step."""
        if self.plan.mesh is not None:
            raise ValueError("save/load run on host files; build the plan "
                             "with mesh=None")
        plan, tc = self.plan, self.plan.tc
        self._uid += 1
        with open(os.path.join(path, PERSIST_MANIFEST)) as f:
            manifest = json.load(f)
        entries = manifest["leaves"]
        if manifest.get("format") != PERSIST_FORMAT:
            raise ValueError(f"unknown persistent format "
                             f"{manifest.get('format')!r} at {path}")
        if len(entries) != len(plan.routes):
            raise ValueError(
                f"{path} holds {len(entries)} leaves; this plan expects "
                f"{len(plan.routes)} — rebuild the plan for the structure")
        wire_ver = get_backend("wire-verify")
        stats = TransferStats(chunk_wire_bytes=[], chunk_ok=[],
                              raw_passthrough_bytes=0.0,
                              n_elements=plan.stream_len)
        leaves = []
        for i, (r, meta) in enumerate(zip(plan.routes, entries)):
            if (meta["key"] != r.key or meta["route"] != r.route
                    or tuple(meta["shape"]) != r.shape
                    or meta["dtype"] != r.dtype):
                raise ValueError(
                    f"leaf {i} ({meta['key']!r}) does not match the plan "
                    f"route {r.key!r}; structure drifted since save")
            try:
                blob = self._read_verified(os.path.join(path, meta["file"]),
                                           meta, i, stats)
            except WireIntegrityError:
                # Publish the partial accounting (verify failures, re-fetch
                # bytes burned on the abandoned candidate) before bubbling up
                # to the fallback policy in distributed/checkpoint.py.
                stats.leaf_ok[r.key] = False
                self.last_stats = stats
                self._account()
                raise
            sz = meta["sz_bytes"]
            if r.route == "splitzip":
                ct = self._persist_comp(blob[:sz], r, tc.codebook.fmt,
                                        r.dtype)
                leaves.append(jnp.asarray(wire_ver.decode(ct)))
                stats.leaf_wire_bytes[r.key] = float(sz)
                stats.leaf_ok[r.key] = True
            elif r.route == "fp32_hilo":
                ct = self._persist_comp(blob[:sz], r, tc.codebook.fmt,
                                        "bfloat16")
                hi = jax.lax.bitcast_convert_type(
                    jnp.asarray(wire_ver.decode(ct)), jnp.uint16)
                lo = np.frombuffer(blob[sz:], np.uint16).reshape(r.shape)
                u = ((hi.astype(jnp.uint32) << 16)
                     | jnp.asarray(lo).astype(jnp.uint32))
                leaves.append(jax.lax.bitcast_convert_type(u, jnp.float32))
                stats.leaf_wire_bytes[r.key] = float(sz)
                stats.leaf_ok[r.key] = True
                stats.fp32_lo_wire_bytes += float(len(blob) - sz)
            elif r.route == "fp8":
                ct = self._persist_comp(blob[:sz], r, plan.fp8_codebook.fmt,
                                        r.dtype)
                leaves.append(jnp.asarray(wire_ver.decode(ct)))
                stats.fp8_wire_bytes += float(sz)
                stats.leaf_ok[r.key] = True
            else:
                arr = np.frombuffer(blob, dtype=jnp.dtype(r.dtype))
                leaves.append(jnp.asarray(arr.reshape(r.shape)))
                stats.raw_passthrough_bytes += float(len(blob))
        tree = jax.tree_util.tree_unflatten(plan.treedef, leaves)
        self.last_stats = stats
        self._account()
        return tree, manifest.get("extra", {})

    @staticmethod
    def _persist_comp(payload: bytes, r, fmt: str, dtype: str) -> WireCompressed:
        stats = WireStats(n_elements=r.n_elements, n_escapes=0,
                          payload_bytes=len(payload),
                          raw_bytes=int(r.raw_bytes))
        return WireCompressed(payload=payload, shape=r.shape, dtype=dtype,
                              fmt=fmt, stats=stats)

    def _read_verified(self, fpath: str, meta: Dict, ci: int,
                       stats: TransferStats) -> bytes:
        """One leaf file off disk, Fletcher-verified against the manifest,
        optionally through the session's :class:`FaultChannel` (so injected
        wire faults exercise the re-fetch path on CPU).  Re-reads follow the
        plan's capacity-schedule length — ``retry_doublings + 1`` re-fetches
        — then raise :class:`WireIntegrityError` with the leaf index."""
        budget = self.plan.tc.retry_doublings + 2
        for attempt in range(budget):
            with open(fpath, "rb") as f:
                blob = f.read()
            intact = True
            if self._channel is not None:
                frame = self._channel.ship(
                    jnp.asarray(np.frombuffer(blob, np.uint8)),
                    self._uid, ci, attempt)
                payload, intact = self._channel.deliver(frame)
                stats.fault_delay_s += frame.delay_s
                blob = (np.asarray(payload).tobytes()
                        if payload is not None else b"")
            if intact and fletcher32(np.frombuffer(blob, np.uint8)) == \
                    meta["checksum"]:
                return blob
            stats.verify_failures += 1
            if attempt + 1 < budget:
                stats.refetches += 1
                stats.refetch_wire_bytes += float(len(blob))
        raise WireIntegrityError((ci,))

    # -- collective executor (compressed ring all-reduce) --------------------
    def ring_reduce(self, stacked, *, axis: str = "pod", mean: bool = True,
                    ratio: Optional[float] = None, check: bool = True):
        """Rotating-ring compressed all-reduce over ``axis``: each
        participant's pod-partial contribution circles the ring as a
        compressed stream ((n_pod - 1) hops, decode + fp32 accumulate per
        hop), exactly grad_compress's exchange but planned, routed, and
        accounted here.  Input leaves carry a leading ``axis`` dimension
        (sharded ``P(axis)``); output leaves drop it and are replicated.

        In-graph execution cannot branch on escape overflow, so every hop
        also emits an ``ok`` flag; a leaf whose compressed hops overflowed
        anywhere on the ring is re-run on a raw (bit-pinned) ring — the one
        overflow story: detected off-graph, healed by the raw fallback,
        recorded in ``last_stats.leaf_ok``.  In-graph wire bytes live in
        the lowered HLO (``lower_hlo``); for host-side reports
        ``last_stats`` carries the plan's analytic estimate via
        :meth:`TransferPlan.collective_wire_bytes` — pass ``ratio`` (a
        calibrated profile's codec ratio) to price the compressed hops,
        else they're counted raw."""
        plan = self.plan
        if plan.mesh is None or axis not in plan.mesh.shape:
            raise ValueError(f"ring_reduce needs a mesh plan with a "
                             f"{axis!r} axis")
        if check:
            self._check_structure(stacked)
        self._uid += 1
        n_pod = plan.mesh.shape[axis]
        expected = n_pod * (n_pod - 1)      # ok hops per leaf, psum'd
        leaves = jax.tree_util.tree_leaves(stacked)
        fn = self._ring_fns.get(frozenset())
        if fn is None:
            fn = self._ring_fns.setdefault(
                frozenset(), self._build_ring_fn(axis, mean, frozenset()))
        out, oks = fn(*leaves)
        failed = frozenset(j for j, ok in enumerate(oks)
                           if int(ok) != expected)
        if failed:
            fb = self._ring_fns.get(failed)
            if fb is None:
                fb = self._ring_fns.setdefault(
                    failed, self._build_ring_fn(axis, mean, failed))
            out, _ = fb(*leaves)
        self.last_stats = self._ring_stats(axis, ratio, failed)
        self._account()
        return jax.tree_util.tree_unflatten(plan.treedef, out)

    def _ring_participant_routes(self, axis: str):
        """Per-participant routes: the plan was built over ``axis``-stacked
        leaves, so re-resolve on the stripped shapes (the per-hop payloads)
        — this is where ``tc.min_compress_elems`` bites."""
        if self._ring_routes is None:
            n = self.plan.mesh.shape[axis]
            local = []
            for r in self.plan.routes:
                if not r.shape or r.shape[0] % n:
                    raise ValueError(
                        f"ring_reduce leaf {r.key!r} has no leading "
                        f"{axis}-divisible dimension (shape {r.shape})")
                local.append(jax.ShapeDtypeStruct(
                    (r.shape[0] // n,) + r.shape[1:], jnp.dtype(r.dtype)))
            lp = TransferPlan.build(
                jax.tree_util.tree_unflatten(self.plan.treedef, local),
                self.plan.tc, granularity="tensor")
            self._ring_routes = lp.routes
        return self._ring_routes

    def _build_ring_fn(self, axis: str, mean: bool, force_raw: frozenset):
        from jax.sharding import PartitionSpec as P
        plan, tc = self.plan, self.plan.tc
        n_pod = plan.mesh.shape[axis]
        routes = self._ring_participant_routes(axis)
        for r in routes:
            if r.route == "fp32_hilo":
                raise ValueError(
                    "ring_reduce does not take the fp32 hi/lo route (build "
                    "the gradient plan with compress_fp32=False); fp32 "
                    "leaves ship raw, bit-pinned")
        perm = [(i, (i + 1) % n_pod) for i in range(n_pod)]

        def ring(x, codebook, cap, compress):
            # bit-pinned rotate-and-accumulate; encode/decode per hop keeps
            # only the compressed stream on the wire.  ``ok`` counts hops
            # whose escape capacity held — the traced flag the host checks.
            acc = x.astype(jnp.float32)
            rotating = x
            ok = jnp.int32(0)
            for _ in range(n_pod - 1):
                if compress:
                    ct = plan.backend.encode(rotating, codebook,
                                             chunk=tc.chunk, cap=cap,
                                             layout=tc.layout)
                    ok = ok + plan.backend.ok(ct).astype(jnp.int32)
                    moved = jax.tree.map(
                        lambda s: jax.lax.ppermute(s, axis, perm), ct)
                    rotating = jnp.asarray(
                        plan.backend.decode(moved)).reshape(x.shape)
                else:
                    ok = ok + 1
                    w = _WIRE_INT.get(x.dtype.itemsize)
                    if jnp.issubdtype(x.dtype, jnp.floating) and w is not None:
                        y = jax.lax.ppermute(
                            jax.lax.bitcast_convert_type(rotating, w),
                            axis, perm)
                        rotating = jax.lax.bitcast_convert_type(y, x.dtype)
                    else:
                        rotating = jax.lax.ppermute(rotating, axis, perm)
                acc = acc + rotating.astype(jnp.float32)
            return acc, jax.lax.psum(ok, axis)

        def body(*leaves_flat):
            out, oks = [], []
            for j, (lf, r) in enumerate(zip(leaves_flat, routes)):
                x = lf[0]    # local slice of the stacked leaf, leading dim 1
                if r.route == "splitzip" and j not in force_raw:
                    total, ok = ring(x, tc.codebook, r.cap, True)
                elif r.route == "fp8" and j not in force_raw:
                    total, ok = ring(x, plan.fp8_codebook, r.cap, True)
                else:
                    total, ok = ring(x, None, 0, False)
                if mean:
                    total = total / n_pod
                out.append(total.astype(x.dtype))
                oks.append(ok)
            return tuple(out), tuple(oks)

        n_leaves = self.plan.treedef.num_leaves
        specs = lambda s: tuple(s for _ in range(n_leaves))
        return shard_map(body, mesh=plan.mesh,
                         in_specs=specs(P(axis)),
                         out_specs=(specs(P()), specs(P())),
                         check_vma=False)

    def _ring_stats(self, axis: str, ratio: Optional[float],
                    failed: frozenset = frozenset()) -> TransferStats:
        """Analytic per-call accounting for the collective executor (the
        traced HLO is the ground truth; this is the host-side estimate all
        consumers report through)."""
        n_pod = self.plan.mesh.shape[axis]
        hops = n_pod - 1
        routes = self._ring_participant_routes(axis)
        stats = TransferStats(chunk_wire_bytes=[], chunk_ok=[],
                              raw_passthrough_bytes=0.0,
                              n_elements=sum(r.n_elements for r in routes
                                             if r.route != "raw"))
        rho = ratio if ratio is not None else 1.0
        for j, r in enumerate(routes):
            if r.route == "raw":
                stats.raw_passthrough_bytes += r.raw_bytes * hops
            elif j in failed:
                # overflowed: the wasted compressed attempt shipped, then
                # the raw re-run (charged as a raw re-fetch)
                stats.leaf_wire_bytes[r.key] = r.raw_bytes / rho * hops
                stats.leaf_ok[r.key] = False
                stats.refetches += 1
                stats.raw_refetches += 1
                stats.refetch_wire_bytes += r.raw_bytes * hops
            elif r.route == "fp8":
                stats.fp8_wire_bytes += r.raw_bytes / rho * hops
                stats.leaf_ok[r.key] = True
            else:
                stats.leaf_wire_bytes[r.key] = r.raw_bytes / rho * hops
                stats.leaf_ok[r.key] = True
        return stats

    # -- reshard hop (elastic scaling) ---------------------------------------
    def reshard(self, tree, dst_shardings, *, check: bool = True,
                verify: Optional[bool] = None):
        """One elastic reshard hop: encode every routed leaf to splitzip
        streams, ship them through this session's wire (integrity framing
        and re-fetches included when the session carries ``verify=`` /
        ``faults=``), decode, and place the result on ``dst_shardings``
        (a pytree of shardings matching ``tree``; see
        ``distributed/elastic.reshard``).  Bit-exact end to end."""
        if self.plan.mesh is not None:
            raise ValueError(
                "reshard ships host-staged streams (the old mesh may not "
                "exist anymore); build the plan with mesh=None")
        self._set_verify(verify)
        self.send(tree, check=check)
        out = self.recv()
        if dst_shardings is not None:
            out = jax.device_put(out, dst_shardings)
        return out

    # -- internals -----------------------------------------------------------
    def _check_structure(self, cache) -> None:
        if not self.plan.matches(cache):
            raise ValueError(
                "cache structure does not match this TransferPlan; rebuild "
                "the plan for the new structure (TransferPlan.build)")

    def _account(self) -> None:
        self.calls += 1
        if self.last_stats is not None:
            if self._channel is not None:
                # per-call slice of the channel's running fault counter
                self.last_stats.faults_injected = (self._channel.injected
                                                   - self._injected_seen)
                self._injected_seen = self._channel.injected
            self.total_wire_bytes += self.last_stats.wire_bytes

    # -- local / tensor ------------------------------------------------------
    def _send_tensor(self, cache):
        stats = TransferStats(chunk_wire_bytes=[], chunk_ok=[],
                              raw_passthrough_bytes=0.0, n_elements=0)
        comp, raw = encode_leaves(self.plan, cache, scheduled=True,
                                  stats=stats)
        if self.retain_last:
            # pristine (pre-framing) payload: a decode-worker failover can
            # re-ship the exact encoded streams without re-running the codec
            self._retained = (comp, raw, cache)
        self.last_stats = stats
        if self._channel is None:
            return comp, raw, cache, None, None
        # frame every wire object; keep the pristine dicts sender-side so a
        # verified re-fetch can re-ship the exact same object
        comp_f = {k: self._channel.ship(v, self._uid, ci, 0)
                  for ci, (k, v) in enumerate(comp.items())}
        raw_f = {k: self._channel.ship(v, self._uid, len(comp) + ci, 0)
                 for ci, (k, v) in enumerate(raw.items())}
        return comp_f, raw_f, cache, comp, raw

    def _recv_tensor(self, payload):
        comp, raw, structure, pristine_comp, pristine_raw = payload
        if self._channel is not None:
            comp, raw = self._deliver_tensor(comp, raw, structure,
                                             pristine_comp, pristine_raw)
        return decode_leaves(comp, raw, structure,
                             backend=self.plan.tc.backend)

    def _deliver_tensor(self, comp_f, raw_f, structure, pristine_comp,
                        pristine_raw):
        """Unframe + verify every tensor-path entry.  A compressed entry
        whose re-ships exhaust the retry budget falls back to the whole
        ORIGINAL leaf shipped raw (mirroring the encode-overflow fallback);
        raw entries re-ship themselves until intact."""
        stats = self.last_stats
        leaves = {leaf_key(p): leaf for p, leaf in
                  jax.tree_util.tree_flatten_with_path(structure)[0]}
        comp: Dict[str, object] = {}
        raw: Dict[str, object] = {}
        ci = 0
        for key, frame in comp_f.items():
            base = key[:-3] if key.endswith("#hi") else key
            obj, fell_raw = self._deliver_entry(
                frame, ci, stats, resend=pristine_comp[key],
                raw_payload=leaves[base])
            if fell_raw:
                raw[base] = obj      # whole leaf ships raw; lo sidecar unused
            else:
                comp[key] = obj
            ci += 1
        for key, frame in raw_f.items():
            obj, _ = self._deliver_entry(frame, ci, stats,
                                         resend=pristine_raw[key],
                                         raw_payload=pristine_raw[key])
            raw.setdefault(key, obj)
            ci += 1
        return comp, raw

    def _deliver_entry(self, frame, ci: int, stats: TransferStats, *,
                       resend, raw_payload):
        """``(payload, used_raw_fallback)`` for one framed wire entry.

        Verified mode re-fetches on mismatch/drop: ``retry_doublings + 1``
        re-ships of the staged compressed object (each attempt re-keys the
        fault plan, so injected faults re-roll), then the raw payload as the
        terminal re-fetch — itself verified and retried, failing loud past
        ``_MAX_WIRE_ATTEMPTS``.  Unverified mode delivers whatever arrived
        (corruption flows through undetected — the hazard ``verify=``
        closes); only a full drop heals from the staged raw payload."""
        payload, intact = self._channel.deliver(frame)
        stats.fault_delay_s += frame.delay_s
        if not self.verify:
            if payload is None:      # dropped in flight: heal from the
                return raw_payload, True  # staged raw payload, raw-routed
            return payload, False
        is_raw = resend is raw_payload
        attempt = 1
        while not intact:
            stats.verify_failures += 1
            if attempt >= _MAX_WIRE_ATTEMPTS:
                raise TransferIntegrityError(
                    f"wire entry {ci}: integrity not established after "
                    f"{attempt} attempts (raw re-fetches included)")
            if attempt <= self.plan.tc.retry_doublings + 1:
                obj, is_raw = resend, resend is raw_payload
            else:
                obj, is_raw = raw_payload, True
            stats.refetches += 1
            stats.raw_refetches += int(is_raw)
            stats.refetch_wire_bytes += self._object_wire_bytes(obj, is_raw)
            frame = self._channel.ship(obj, self._uid, ci, attempt)
            payload, intact = self._channel.deliver(frame)
            stats.fault_delay_s += frame.delay_s
            attempt += 1
        return payload, is_raw

    def _object_wire_bytes(self, obj, is_raw: bool) -> float:
        if is_raw or isinstance(obj, (jax.Array, np.ndarray)):
            a = np.asarray(obj)
            return float(a.size * a.dtype.itemsize)
        return float(_backend_for(obj, self.plan.backend).wire_bytes(obj))

    # -- local / chunked -----------------------------------------------------
    def _encode_chunk(self, stream, i: int):
        """Encode segment ``i`` at base capacity (schedule step 0)."""
        seg = self.plan.segments[i]
        tc = self.plan.tc
        return self.plan.backend.encode(
            stream[seg.start:seg.stop], tc.codebook, chunk=tc.chunk,
            cap=seg.cap, layout=tc.layout)

    def _ship_chunk(self, stream, i: int, ct, stats: TransferStats):
        """The wire hop for chunk ``i``: walk the remaining capacity schedule
        on overflow, then raw fallback.  Returns the in-flight payload
        (compressed object, or None when the chunk ships its raw bits)."""
        plan, tc = self.plan, self.plan.tc
        seg = plan.segments[i]
        be = plan.backend
        ok = bool(be.ok(ct))
        extra = 0
        if not ok:
            for rbe, layout, cap in plan.schedule_for(seg.n_elements,
                                                      seg.cap)[1:]:
                extra += 1
                ct2 = rbe.encode(stream[seg.start:seg.stop], tc.codebook,
                                 chunk=tc.chunk, cap=cap, layout=layout)
                if bool(rbe.ok(ct2)):
                    ct, ok = ct2, True
                    break
        stats.chunk_retried[i] = extra > 0
        stats.chunk_retry_steps[i] = extra
        stats.chunk_ok[i] = ok
        stats.chunk_wire_bytes[i] = (float(be.wire_bytes(ct)) if ok
                                     else seg.raw_bytes)
        return ct if ok else None

    def _decode_chunk(self, stream, i: int, payload):
        """Receiver side: straight to the shipped bit stream (``decode_bits``
        — the fused pallas decode emits these bits from its single kernel)."""
        seg = self.plan.segments[i]
        if payload is None:      # raw fallback: the original bits shipped
            return stream[seg.start:seg.stop]
        if isinstance(payload, (jax.Array, np.ndarray)):
            # explicit raw bits (fault-channel mode ships them for real)
            return jnp.asarray(payload).reshape(-1)
        be = _backend_for(payload, self.plan.backend)
        return jnp.asarray(be.decode_bits(payload)).reshape(-1)

    def _wire_hop(self, stream, i: int, ct, stats: TransferStats):
        """Chunk ``i``'s full send side: the capacity-schedule walk, then the
        checksum-framed channel when active.  Under a channel the raw
        fallback ships its EXPLICIT bits — the local-slice shortcut would
        make the wire hop unfalsifiable under fault injection."""
        p = self._ship_chunk(stream, i, ct, stats)
        if self._channel is None:
            return p
        seg = self.plan.segments[i]
        payload = p if p is not None else stream[seg.start:seg.stop]
        return self._channel.ship(payload, self._uid, i, 0)

    def _chunk_out(self, stream, i: int, p, stats: TransferStats):
        if self._channel is None:
            return self._decode_chunk(stream, i, p)
        return self._deliver_chunk(stream, i, p, stats)

    def _deliver_chunk(self, stream, i: int, frame, stats: TransferStats):
        """Receiver side of chunk ``i`` under an active channel.  Verified
        mode routes a mismatched/dropped frame through the REMAINING capacity
        schedule — re-encode at the next step, re-ship with the attempt
        re-keyed so injected faults re-roll — and past the schedule's end
        re-fetches the chunk's raw bits (also verified).  Never hands corrupt
        bytes to the decoder; fails loud past ``_MAX_WIRE_ATTEMPTS``."""
        seg = self.plan.segments[i]
        tc = self.plan.tc
        payload, intact = self._channel.deliver(frame)
        stats.fault_delay_s += frame.delay_s
        if not self.verify:
            # unverified: corruption flows through; a drop falls back to the
            # local-slice shortcut (visible only in channel.injected)
            return self._decode_chunk(stream, i, payload)
        sched = self.plan.schedule_for(seg.n_elements, seg.cap)
        attempt = 1
        while not intact:
            stats.verify_failures += 1
            if attempt >= _MAX_WIRE_ATTEMPTS:
                raise TransferIntegrityError(
                    f"chunk {i}: integrity not established after "
                    f"{attempt} attempts (raw re-fetches included)")
            if attempt < len(sched):
                be, layout, cap = sched[attempt]
                ct = be.encode(stream[seg.start:seg.stop], tc.codebook,
                               chunk=tc.chunk, cap=cap, layout=layout)
                if bool(be.ok(ct)):
                    obj, nbytes, is_raw = ct, float(be.wire_bytes(ct)), False
                else:
                    obj, nbytes, is_raw = (stream[seg.start:seg.stop],
                                           seg.raw_bytes, True)
            else:
                obj, nbytes, is_raw = (stream[seg.start:seg.stop],
                                       seg.raw_bytes, True)
            stats.refetches += 1
            stats.raw_refetches += int(is_raw)
            stats.refetch_wire_bytes += nbytes
            frame = self._channel.ship(obj, self._uid, i, attempt)
            payload, intact = self._channel.deliver(frame)
            stats.fault_delay_s += frame.delay_s
            attempt += 1
        return self._decode_chunk(stream, i, payload)

    def _chunked_sidecars(self, cache, stats: TransferStats):
        """Everything outside the pipelined stream: fold the stream, encode
        fp8 sidecar leaves, count lo halves + raw passthrough."""
        plan = self.plan
        stream, lo, fp8, raw = plan.fold_stream(cache)
        fp8_payload: Dict[str, object] = {}
        for r in plan.routes:
            if r.route == "fp32_hilo":
                stats.fp32_lo_wire_bytes += 2.0 * r.n_elements
            elif r.route == "fp8":
                ct, ok, extra = _encode_scheduled(
                    plan, fp8[r.key], plan.fp8_codebook, r.n_elements, r.cap,
                    scheduled=True)
                _record_unit(stats, r.key, bool(ok), extra)
                stats.fp8_wire_bytes += (float(plan.backend.wire_bytes(ct))
                                         if ok else r.raw_bytes)
                fp8_payload[r.key] = ct if ok else fp8[r.key]
            elif r.route == "raw":
                stats.raw_passthrough_bytes += r.raw_bytes
        return stream, lo, fp8_payload, raw

    def _new_chunked_stats(self) -> TransferStats:
        n = self.plan.n_chunks
        return TransferStats(
            chunk_wire_bytes=[0.0] * n, chunk_ok=[True] * n,
            raw_passthrough_bytes=0.0, n_elements=self.plan.stream_len,
            chunk_retried=[False] * n, chunk_retry_steps=[0] * n)

    def _ship_sidecars(self, lo, fp8_payload, raw):
        """Frame the non-pipelined wire objects (lo halves, fp8 sidecars,
        raw passthrough).  Chunk-index keying continues past the pipeline
        chunks so every fault coordinate stays unique within the transfer."""
        framed = {}
        ci = self.plan.n_chunks
        for name, d in (("lo", lo), ("fp8", fp8_payload), ("raw", raw)):
            framed[name] = {k: self._channel.ship(v, self._uid, ci + j, 0)
                            for j, (k, v) in enumerate(d.items())}
            ci += len(d)
        return framed["lo"], framed["fp8"], framed["raw"]

    def _deliver_sidecars(self, lo_f, fp8_f, raw_f, pristine, stats):
        """Unframe + verify the sidecars; a faulted sidecar re-ships its
        pristine object (it IS the terminal payload — no cheaper encoding
        below it) until intact."""
        out = []
        ci = self.plan.n_chunks
        for frames, orig in zip((lo_f, fp8_f, raw_f), pristine):
            d = {}
            for j, (k, frame) in enumerate(frames.items()):
                d[k], _ = self._deliver_entry(frame, ci + j, stats,
                                              resend=orig[k],
                                              raw_payload=orig[k])
            out.append(d)
            ci += len(frames)
        return out

    def _send_chunked(self, cache):
        stats = self._new_chunked_stats()
        stream, lo, fp8_payload, raw = self._chunked_sidecars(cache, stats)
        in_flight = [self._wire_hop(stream, i, self._encode_chunk(stream, i),
                                    stats)
                     for i in range(self.plan.n_chunks)]
        self.last_stats = stats
        if self._channel is None:
            return stream, in_flight, lo, fp8_payload, raw, None
        pristine = (lo, fp8_payload, raw)
        lo_f, fp8_f, raw_f = self._ship_sidecars(lo, fp8_payload, raw)
        return stream, in_flight, lo_f, fp8_f, raw_f, pristine

    def _recv_chunked(self, payload):
        stream, in_flight, lo, fp8_payload, raw, pristine = payload
        stats = self.last_stats
        decoded = [self._chunk_out(stream, i, p, stats)
                   for i, p in enumerate(in_flight)]
        if self._channel is not None:
            lo, fp8_payload, raw = self._deliver_sidecars(
                lo, fp8_payload, raw, pristine, stats)
        return self._reassemble(decoded, lo, fp8_payload, raw)

    def _reassemble(self, decoded_bits: List[jax.Array], lo, fp8_payload, raw):
        plan = self.plan
        bits_out = (jnp.concatenate(decoded_bits) if len(decoded_bits) > 1
                    else decoded_bits[0])
        fp8_dec = {}
        for r in plan.routes:
            if r.route == "fp8":
                p = fp8_payload[r.key]
                if isinstance(p, jax.Array):   # raw fallback leaf
                    fp8_dec[r.key] = p
                else:
                    fp8_dec[r.key] = _backend_for(p, plan.backend).decode(p)
        return plan.unfold_stream(bits_out, lo, fp8_dec, raw)

    def _transfer_chunked_interleaved(self, cache):
        """The fused chunked path on the explicit overlap schedule: at step t
        encode chunk t, ship chunk t-1, decode chunk t-2."""
        stats = self._new_chunked_stats()
        stream, lo, fp8_payload, raw = self._chunked_sidecars(cache, stats)
        n = self.plan.n_chunks
        encoded: Dict[int, object] = {}
        in_flight: Dict[int, object] = {}
        decoded: Dict[int, jax.Array] = {}
        for enc_i, xfer_i, dec_i in ChunkSchedule(n).stages():
            if 0 <= enc_i < n:
                encoded[enc_i] = self._encode_chunk(stream, enc_i)
            if 0 <= xfer_i < n:
                in_flight[xfer_i] = self._wire_hop(
                    stream, xfer_i, encoded.pop(xfer_i), stats)
            if 0 <= dec_i < n:
                decoded[dec_i] = self._chunk_out(
                    stream, dec_i, in_flight.pop(dec_i), stats)
        if self._channel is not None:
            lo_f, fp8_f, raw_f = self._ship_sidecars(lo, fp8_payload, raw)
            lo, fp8_payload, raw = self._deliver_sidecars(
                lo_f, fp8_f, raw_f, (lo, fp8_payload, raw), stats)
        self.last_stats = stats
        return self._reassemble([decoded[i] for i in range(n)], lo,
                                fp8_payload, raw)

    # -- mesh ----------------------------------------------------------------
    def _build_mesh_fn(self):
        plan = self.plan
        tc = plan.tc
        treedef = plan.treedef

        def body(*leaves_flat):
            local = jax.tree_util.tree_unflatten(treedef, leaves_flat)
            # a plan over the LOCAL shard structure: shapes inside shard_map
            # are the per-shard views, so segmentation/routing re-resolves
            # here (trace-time only — once per compilation, not per call)
            lp = TransferPlan.build(local, tc, granularity=plan.granularity)
            perm = lambda x: _permute_leaf(x, "pod", plan.src_pod,
                                           plan.dst_pod)
            if lp.granularity == "chunked":
                out = self._mesh_chunked_body(lp, local, perm)
            else:
                comp, raw = encode_leaves(lp, local, scheduled=False)
                moved_comp = jax.tree.map(perm, comp)
                moved_raw = jax.tree.map(perm, raw)
                out = decode_leaves(moved_comp, moved_raw, local,
                                    backend=tc.backend)
            # fresh leading 'pod' axis: index dst_pod holds the decoded
            # cache, index src_pod whatever the non-receiving pod decodes
            # from its zero-filled streams
            return tuple(x[None] for x in jax.tree_util.tree_leaves(out))

        from jax.sharding import PartitionSpec as P
        out_specs = tuple(P("pod", *s) for s in plan.in_specs)
        return shard_map(body, mesh=plan.mesh, in_specs=plan.in_specs,
                         out_specs=out_specs, check_vma=False)

    def _mesh_chunked_body(self, lp: TransferPlan, local, perm):
        """Per-chunk collective with double-buffering: at any schedule step
        at most two chunks are live between stages (t-1 permuting, t-2
        decoding) while chunk t encodes."""
        tc = lp.tc
        be = lp.backend
        stream, lo, fp8, raw = lp.fold_stream(local)
        n = lp.n_chunks
        encoded: Dict[int, object] = {}
        in_flight: Dict[int, object] = {}
        decoded: Dict[int, jax.Array] = {}
        for enc_i, xfer_i, dec_i in ChunkSchedule(n).stages():
            if 0 <= enc_i < n:
                seg = lp.segments[enc_i]
                encoded[enc_i] = be.encode(
                    stream[seg.start:seg.stop], tc.codebook,
                    chunk=tc.chunk, cap=seg.cap, layout=tc.layout)
            if 0 <= xfer_i < n:
                in_flight[xfer_i] = jax.tree.map(perm, encoded.pop(xfer_i))
            if 0 <= dec_i < n:
                decoded[dec_i] = jnp.asarray(
                    be.decode_bits(in_flight.pop(dec_i))).reshape(-1)
        bits_out = (jnp.concatenate([decoded[i] for i in range(n)])
                    if n > 1 else decoded[0] if n else
                    jnp.zeros((0,), jnp.uint16))
        fp8_dec = {}
        for r in lp.routes:
            if r.route == "fp8":
                ct = be.encode(fp8[r.key], lp.fp8_codebook, chunk=tc.chunk,
                               cap=r.cap, layout=tc.layout)
                fp8_dec[r.key] = be.decode(jax.tree.map(perm, ct))
        lo_m = {k: perm(v) for k, v in lo.items()}
        raw_m = {k: perm(v) for k, v in raw.items()}
        return lp.unfold_stream(bits_out, lo_m, fp8_dec, raw_m)

    def _run_mesh(self, cache, select_dst: bool = True):
        plan = self.plan
        if self._mesh_fn is None:
            self._mesh_fn = self._build_mesh_fn()
        leaves = jax.tree_util.tree_leaves(cache)
        moved = self._mesh_fn(*leaves)
        self.last_stats = None   # mesh wire bytes live in the HLO (roofline)
        if select_dst:
            # convenience view for eager callers (tests/examples).  Inside a
            # jit this slice forces GSPMD to bounce the DECODED cache back
            # across the pod axis — production consumers keep the cache
            # pod-resident: select_dst=False and read index dst_pod locally.
            moved = tuple(x[plan.dst_pod] for x in moved)
        return jax.tree_util.tree_unflatten(plan.treedef, moved)
