"""Seeded, injectable fault plans for the transfer data plane.

"Rethinking Key-Value Cache Compression Techniques" argues that happy-path,
single-number evaluation is exactly where serving claims fall apart; this
module makes every failure mode of the PD transfer path injectable and
deterministic, so the fault-tolerance layer (wire integrity + re-fetch in
:mod:`repro.serving.session`, worker failover + shedding in
:mod:`repro.serving.scheduler`) is unit-testable on CPU.

A :class:`FaultPlan` describes WHAT goes wrong:

* **chunk faults** on the simulated wire — ``corrupt`` (bits flipped in the
  shipped payload), ``drop`` (payload lost), ``delay`` (payload late) — both
  as seeded rates (``corrupt_p``/``drop_p``) and as explicit per-chunk
  injections (``corrupt_chunks=(2,)`` corrupts chunk 2 of every transfer's
  first attempt);
* **worker kills** — decode worker ``w`` dies at time ``t`` (optionally
  revives), detected by the scheduler's
  :class:`~repro.distributed.fault_tolerance.FailureDetector` after its
  heartbeat timeout;
* **link brownouts** — the PD link runs at ``factor`` of its bandwidth over
  ``[start, stop)``.

Randomized faults are drawn from a counter-based hash of ``(seed, uid,
chunk, attempt)`` — NOT from stateful RNG — so a seeded plan is a pure
function: the same transfer sees the same faults regardless of execution
order, retries re-roll (attempt is part of the key), and two runs of one
plan are bit-identical (pinned by ``tests/test_fault_tolerance.py``).

Named plans register like codec backends and link policies
(:func:`register_fault_plan` / :func:`get_fault_plan`); consumers accept
``None | str | FaultPlan`` through :func:`resolve_faults`.  The built-in
``chaos`` plan is the acceptance scenario: 1% chunk corruption, one decode
worker killed mid-run, a link brownout interval.

:class:`FaultChannel` is the execution-side companion: it frames chunk
payloads with a Fletcher-32 checksum at ship time, applies the plan's chunk
faults, and verifies frames at delivery — the piece
:class:`~repro.serving.session.TransferSession` threads its wire hop
through.

Run ``python -m pydoc repro.serving.faults`` for this page.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple, Union

import jax
import numpy as np

from repro.core.backend import WireCompressed
from repro.core.wire import fletcher32

# ---------------------------------------------------------------------------
# deterministic per-(seed, uid, chunk, attempt) randomness
# ---------------------------------------------------------------------------

_SPLITMIX_GAMMA = 0x9E3779B97F4A7C15
_MASK64 = (1 << 64) - 1


def _splitmix64(x: int) -> int:
    """One splitmix64 scramble round — the counter-based hash behind every
    randomized fault draw (stateless, so fault plans are pure functions)."""
    x = (x + _SPLITMIX_GAMMA) & _MASK64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK64
    return (x ^ (x >> 31)) & _MASK64


def _unit_draw(seed: int, uid: int, chunk: int, attempt: int, salt: int) -> float:
    """Uniform [0, 1) draw keyed by the full fault coordinate."""
    h = seed & _MASK64
    for part in (uid, chunk, attempt, salt):
        h = _splitmix64(h ^ (part & _MASK64))
    return h / float(1 << 64)


# ---------------------------------------------------------------------------
# fault descriptors
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WorkerKill:
    """Worker ``worker`` of tier ``role`` ('decode' or 'prefill') stops
    heartbeating at ``at`` (sim seconds); ``revive_at`` restores it
    (None == permanent death)."""

    worker: int
    at: float
    revive_at: Optional[float] = None
    role: str = "decode"

    def __post_init__(self):
        if self.role not in ("decode", "prefill"):
            raise ValueError("WorkerKill.role must be 'decode' or 'prefill'")


@dataclasses.dataclass(frozen=True)
class LinkBrownout:
    """A PD link delivers at ``factor`` (0 < factor <= 1) of its nominal
    bandwidth over ``[start, stop)`` — congestion, not an outage.  ``link``
    selects one link of a multi-link fleet; None degrades every link (the
    pre-fleet behavior, and what a fabric-wide event looks like)."""

    start: float
    stop: float
    factor: float = 0.5
    link: Optional[int] = None

    def __post_init__(self):
        if not (0.0 < self.factor <= 1.0):
            raise ValueError("brownout factor must be in (0, 1]")
        if self.stop <= self.start:
            raise ValueError("brownout interval must be non-empty")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, declarative description of what goes wrong.

    Chunk-fault resolution order for transfer ``uid``, chunk ``i``, attempt
    ``a``: explicit injections first (``corrupt_chunks``/``drop_chunks``/
    ``delay_chunks`` — attempt 0 only, so a single re-fetch clears them,
    unless ``persistent_attempts`` extends them), then the seeded rates
    (re-rolled per attempt).  ``max_attempt`` caps randomized faults so an
    adversarial rate cannot starve the terminal raw re-fetch forever."""

    seed: int = 0
    # seeded chunk-fault rates (per chunk, per attempt)
    corrupt_p: float = 0.0
    drop_p: float = 0.0
    delay_p: float = 0.0
    delay_s: float = 0.0                 # injected latency per delayed chunk
    # explicit injections: chunk indices faulted on attempts < persistent_attempts
    corrupt_chunks: Tuple[int, ...] = ()
    drop_chunks: Tuple[int, ...] = ()
    delay_chunks: Tuple[int, ...] = ()
    persistent_attempts: int = 1
    # randomized faults stop at this attempt (the raw re-fetch must be able
    # to terminate; explicit injections are bounded by persistent_attempts)
    max_attempt: int = 8
    # scheduler-plane faults
    worker_kills: Tuple[WorkerKill, ...] = ()
    brownouts: Tuple[LinkBrownout, ...] = ()

    # -- chunk faults --------------------------------------------------------
    def chunk_fault(self, uid: int, chunk: int, attempt: int) -> Optional[str]:
        """'corrupt' | 'drop' | 'delay' | None for this fault coordinate."""
        if attempt < self.persistent_attempts:
            if chunk in self.corrupt_chunks:
                return "corrupt"
            if chunk in self.drop_chunks:
                return "drop"
            if chunk in self.delay_chunks:
                return "delay"
        if attempt >= self.max_attempt:
            return None
        if (self.corrupt_p > 0.0
                and _unit_draw(self.seed, uid, chunk, attempt, 1) < self.corrupt_p):
            return "corrupt"
        if (self.drop_p > 0.0
                and _unit_draw(self.seed, uid, chunk, attempt, 2) < self.drop_p):
            return "drop"
        if (self.delay_p > 0.0
                and _unit_draw(self.seed, uid, chunk, attempt, 3) < self.delay_p):
            return "delay"
        return None

    # -- link faults ---------------------------------------------------------
    def link_rate(self, t: float, link: int = 0) -> float:
        """Fractional bandwidth of ``link`` at sim time ``t`` (1.0 ==
        nominal).  Brownouts pinned to another link don't apply; overlapping
        applicable brownouts compound multiplicatively."""
        rate = 1.0
        for b in self.brownouts:
            if b.link is not None and b.link != link:
                continue
            if b.start <= t < b.stop:
                rate *= b.factor
        return rate

    def link_wall_clock(self, start: float, busy_s: float,
                        link: int = 0) -> float:
        """Wall-clock completion time of a transfer needing ``busy_s``
        seconds of NOMINAL link time when dispatched at ``start`` on
        ``link``: integrates the brownout-degraded rate piecewise, so the
        occupancy interval the scheduler charges is exactly the wall clock
        the link was held."""
        if busy_s <= 0.0:
            return start
        edges = sorted({e for b in self.brownouts
                        if b.link is None or b.link == link
                        for e in (b.start, b.stop) if e > start})
        t, left = start, busy_s
        for edge in edges:
            rate = self.link_rate(t, link)
            span = edge - t
            if left <= span * rate:
                return t + left / rate
            left -= span * rate
            t = edge
        return t + left / self.link_rate(t, link)

    def describe(self) -> str:
        parts = []
        if self.corrupt_p or self.corrupt_chunks:
            parts.append(f"corrupt(p={self.corrupt_p}, "
                         f"chunks={self.corrupt_chunks})")
        if self.drop_p or self.drop_chunks:
            parts.append(f"drop(p={self.drop_p}, chunks={self.drop_chunks})")
        if self.delay_p or self.delay_chunks:
            parts.append(f"delay(p={self.delay_p}, +{self.delay_s}s)")
        parts.extend(f"kill({k.role[0]}{k.worker}@{k.at}"
                     + (f", revive@{k.revive_at})" if k.revive_at is not None
                        else ")") for k in self.worker_kills)
        parts.extend(f"brownout("
                     + (f"link{b.link}, " if b.link is not None else "")
                     + f"[{b.start},{b.stop}) x{b.factor})"
                     for b in self.brownouts)
        return f"FaultPlan[seed={self.seed}: " + (", ".join(parts) or "none") + "]"


# ---------------------------------------------------------------------------
# the checksum-framed wire hop
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Frame:
    """One chunk payload on the simulated wire: the (possibly fault-mutated)
    compressed object plus the Fletcher-32 tag the SENDER computed over the
    pristine payload.  ``payload is None`` == dropped in flight."""

    payload: object
    tag: int
    delay_s: float = 0.0


def _corrupt_payload(payload, salt: int):
    """Flip one bit in the payload's first array leaf (or payload bytes for
    host wire objects) — the smallest corruption a checksum must catch."""
    if isinstance(payload, WireCompressed):
        buf = bytearray(payload.payload)
        pos = _splitmix64(salt) % max(1, len(buf))
        buf[pos] ^= 1 << (_splitmix64(salt + 1) % 8)
        return dataclasses.replace(payload, payload=bytes(buf))
    leaves, treedef = jax.tree_util.tree_flatten(payload)
    arrays = [i for i, leaf in enumerate(leaves) if np.asarray(leaf).size > 0]
    if not arrays:
        return payload
    # hit the LARGEST leaf: compressed objects carry capacity-padded escape
    # arrays whose dead tail would absorb the flip without observable effect
    i = max(arrays, key=lambda j: np.asarray(leaves[j]).nbytes)
    host = np.array(np.asarray(leaves[i]))            # writable copy
    flat = host.reshape(-1).view(np.uint8)
    pos = _splitmix64(salt + 1) % flat.size
    flat[pos] ^= np.uint8(1 << (_splitmix64(salt + 2) % 8))
    leaves[i] = type(leaves[i])(host) if isinstance(leaves[i], np.ndarray) \
        else jax.numpy.asarray(host)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class FaultChannel:
    """The simulated wire between prefill and decode: frames chunk payloads
    with a checksum, applies a :class:`FaultPlan`'s chunk faults in flight,
    and verifies frames on delivery.

    With ``plan=None`` the channel is transparent (checksum framing only),
    so the verify path is exercisable without any injected fault."""

    def __init__(self, checksum: Callable[[object], int],
                 plan: Optional[FaultPlan] = None):
        self.checksum = checksum
        self.plan = plan
        self.injected = 0            # faults applied on this channel
        self.injected_delay_s = 0.0

    def ship(self, payload, uid: int, chunk: int, attempt: int) -> Frame:
        """Sender side: tag the pristine payload, then let the plan mutate
        it in flight."""
        tag = self.checksum(payload)
        delay = 0.0
        if self.plan is not None:
            fault = self.plan.chunk_fault(uid, chunk, attempt)
            if fault == "corrupt":
                salt = (self.plan.seed << 8) ^ _splitmix64(
                    (uid << 20) ^ (chunk << 8) ^ attempt)
                payload = _corrupt_payload(payload, salt)
                self.injected += 1
            elif fault == "drop":
                payload = None
                self.injected += 1
            elif fault == "delay":
                delay = self.plan.delay_s
                self.injected += 1
                self.injected_delay_s += delay
        return Frame(payload=payload, tag=tag, delay_s=delay)

    def deliver(self, frame: Frame) -> Tuple[object, bool]:
        """Receiver side: ``(payload, intact)``.  A dropped frame or a tag
        mismatch is NOT an error here — the session routes it through the
        retry machinery; this only refuses to hand garbage up unlabeled."""
        if frame.payload is None:
            return None, False
        return frame.payload, self.checksum(frame.payload) == frame.tag


# ---------------------------------------------------------------------------
# registry (mirrors repro.core.backend / repro.serving.policy)
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], FaultPlan]] = {}


def register_fault_plan(name: str, factory: Callable[[], FaultPlan]) -> None:
    """Register a named fault plan (later wins)."""
    _REGISTRY[name] = factory


def get_fault_plan(name: str) -> FaultPlan:
    if name not in _REGISTRY:
        raise KeyError(f"unknown fault plan {name!r}; "
                       f"available: {available_fault_plans()}")
    return _REGISTRY[name]()


def available_fault_plans() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_faults(faults: Union[None, str, FaultPlan]) -> Optional[FaultPlan]:
    """``None | registry name | FaultPlan`` -> the plan (None == fault-free)."""
    if faults is None or isinstance(faults, FaultPlan):
        return faults
    return get_fault_plan(faults)


# the acceptance scenario (ISSUE 7): 1% of chunks corrupted, one decode
# worker killed mid-run, the link browned out over an interval.  Times are
# in the dilated sim regime fig2 runs (seconds-scale traces).
register_fault_plan("chaos", lambda: FaultPlan(
    seed=7, corrupt_p=0.01,
    worker_kills=(WorkerKill(worker=1, at=0.35),),
    brownouts=(LinkBrownout(start=0.2, stop=0.6, factor=0.5),)))
# wire-integrity stress: heavy corruption + drops, every failure recoverable
register_fault_plan("lossy-wire", lambda: FaultPlan(
    seed=11, corrupt_p=0.2, drop_p=0.05))
