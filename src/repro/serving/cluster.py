"""Fleet topology for the disaggregated scheduler (ISSUE 10).

The PR-4/PR-6 event engine modeled ONE prefill worker, ONE link, ONE decode
worker (the decode side grew into a slot-sharing fleet in PR 6, but the
prefill and link sides stayed single).  Production is a cluster: N prefill
workers x M decode workers joined by heterogeneous links, with a router
placing each request on a (prefill, link, decode) triple.  This module is
the topology's single source of truth:

* :class:`LinkSpec` — one trunk path between the prefill and decode tiers:
  its link/admission policy (:mod:`repro.serving.policy` registry key) and a
  bandwidth scale applied to the scheduler's :class:`CodecProfile` (so a
  heterogeneous fabric — e.g. one NVLink-class and one Ethernet-class path —
  is expressed against ONE calibrated profile instead of hard-coded
  constants, which CI greps ban outside ``repro/core/profile.py``).
* :class:`ClusterConfig` — the N x M topology plus the router registry key
  (:mod:`repro.serving.router`) and the per-decode-worker prefix-cache
  budget that enables prefix-aware delta transfer.
* :func:`resolve_cluster` — normalizes a ``SchedulerConfig`` into a
  ``ClusterConfig``.  This function is the ONLY place allowed to read the
  legacy ``n_decode_workers`` field (CI grep guard): every other module
  sees worker counts through the resolved cluster, so the topology cannot
  fork into per-module interpretations.
* :class:`PrefixDirectory` — the scheduler-side per-decode-worker LRU of
  resident session prefixes (token counts; the execution-side byte-exact
  index is :class:`repro.serving.session.PrefixIndex`).

A ``SchedulerConfig`` without an explicit ``cluster`` resolves to the
degenerate topology (1 prefill x 1 link x however many decode workers the
legacy field says, router ``'legacy'``) and reproduces the pre-fleet
scheduler bit-identically — pinned by ``tests/test_fleet.py``.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LinkSpec:
    """One prefill->decode trunk path.

    ``policy`` is a link/admission policy registry key
    (:mod:`repro.serving.policy`); ``bw_scale`` multiplies the scheduler
    profile's ``link_bw`` for transfers charged on THIS link (1.0 == the
    calibrated profile verbatim — the scheduler then reuses the profile
    object, so the degenerate topology's float path is bit-identical)."""

    policy: str = "fifo"
    bw_scale: float = 1.0

    def __post_init__(self):
        if not (self.bw_scale > 0.0):
            raise ValueError("LinkSpec.bw_scale must be > 0")


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    """N prefill workers x M decode workers over heterogeneous links.

    ``router`` names the placement policy (:mod:`repro.serving.router`)
    that assigns each prefilled request a (link, decode-worker) pair;
    the default ``'transfer-aware'`` minimizes plan-estimated transfer
    time + current queue depth.  ``prefix_cache_bytes`` is the per-decode-
    worker budget for resident session prefixes (None disables
    prefix-aware delta transfer)."""

    n_prefill: int = 1
    n_decode: int = 1
    links: Tuple[LinkSpec, ...] = (LinkSpec(),)
    router: str = "transfer-aware"
    prefix_cache_bytes: Optional[float] = None

    def __post_init__(self):
        if self.n_prefill < 1 or self.n_decode < 1:
            raise ValueError("a cluster needs at least one prefill and one "
                             "decode worker")
        if not self.links:
            raise ValueError("a cluster needs at least one link")

    @property
    def n_links(self) -> int:
        return len(self.links)


def resolve_cluster(cfg) -> ClusterConfig:
    """``SchedulerConfig`` -> its resolved :class:`ClusterConfig`.

    An explicit ``cfg.cluster`` wins.  Without one, the legacy single-pipe
    topology is synthesized: 1 prefill worker, 1 link running
    ``cfg.policy``, ``cfg.n_decode_workers`` decode workers, and the
    ``'legacy'`` router (link 0, decode worker chosen at admission time by
    least-loaded-alive — the exact PR-6 semantics).  This is the only
    reader of the legacy worker-count field."""
    cluster = getattr(cfg, "cluster", None)
    if cluster is not None:
        return cluster
    return ClusterConfig(
        n_prefill=1,
        n_decode=max(1, cfg.n_decode_workers),
        links=(LinkSpec(policy=cfg.policy),),
        router="legacy",
        prefix_cache_bytes=None)


class PrefixDirectory:
    """Scheduler-side model of each decode worker's resident prefix cache.

    Maps ``(worker, session) -> resident tokens`` with per-worker LRU
    eviction under ``capacity_bytes`` (None == unbounded).  The scheduler
    charges a session's next transfer only for the uncached suffix tokens;
    a worker's death drops its whole directory (the resident KV died with
    it).  Deterministic: eviction order is insertion/touch order — no
    clocks, no hashing of unordered containers."""

    def __init__(self, n_workers: int, capacity_bytes: Optional[float] = None):
        self.capacity_bytes = capacity_bytes
        self._per_worker: Dict[int, "OrderedDict[int, Tuple[int, float]]"] = {
            w: OrderedDict() for w in range(n_workers)}
        self.evictions = 0

    def hit_tokens(self, worker: int, session: int) -> int:
        """Resident tokens for ``session`` on ``worker`` (0 == cold).
        Pure lookup — no LRU touch: placement cost probes must not reorder
        eviction."""
        d = self._per_worker.get(worker)
        if d is None or session not in d:
            return 0
        return d[session][0]

    def insert(self, worker: int, session: int, tokens: int,
               bytes_per_token: float) -> None:
        """Record ``session``'s resident prefix on ``worker`` (touches LRU),
        then evict least-recently-used sessions past the byte budget."""
        d = self._per_worker.get(worker)
        if d is None:
            return
        d[session] = (int(tokens), float(tokens) * bytes_per_token)
        d.move_to_end(session)
        if self.capacity_bytes is None:
            return
        total = sum(b for _, b in d.values())
        while total > self.capacity_bytes and len(d) > 1:
            _, (_, freed) = d.popitem(last=False)
            self.evictions += 1
            total -= freed
        if total > self.capacity_bytes and d:
            # a single resident prefix larger than the whole budget cannot
            # be cached either — dropping it keeps the model honest
            d.popitem(last=False)
            self.evictions += 1

    def drop_worker(self, worker: int) -> None:
        d = self._per_worker.get(worker)
        if d is not None:
            d.clear()

    def resident_bytes(self, worker: int) -> float:
        d = self._per_worker.get(worker)
        return sum(b for _, b in d.values()) if d else 0.0
