"""Disaggregated serving engine: prefill worker -> SplitZip transfer -> decode
worker, as one orchestrated pipeline.

Two operating modes:

* **local** (tests, examples, CPU): both workers in-process; the transfer is a
  real compress -> (simulated wire) -> decompress roundtrip through the
  in-graph codec, so bit-exactness of the whole serving path is checked
  end-to-end (paper Table 9).
* **mesh** (dry-run, TPU): the transfer runs a mesh-targeted ``TransferPlan``
  (shard_map + per-chunk ppermute over the pod axis); prefill/decode are
  pjit'd with the sharding policy.

The transfer stage is the plan/execute API: the engine builds ONE
:class:`~repro.serving.plan.TransferPlan` per cache structure (per-leaf codec
routes, chunk segmentation, capacity schedule resolved once) and executes it
through a cached :class:`~repro.serving.session.TransferSession` on every
``transfer`` call.  ``n_chunks == 1`` runs the whole-tensor granularity,
``n_chunks > 1`` the chunked pipelined engine; both are bit-exact by
construction, and per-chunk wire bytes / capacity-schedule retry steps land
in ``EngineStats``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.codebook import Codebook
from repro.core.pipeline import CodecProfile
from repro.models import model as M
from repro.models.kvcache import DecodeState, cache_bytes
from repro.serving import transfer as T
from repro.serving.plan import TransferPlan
from repro.serving.session import TransferSession
from repro.serving.decode import decode_loop
from repro.serving.prefill import prefill_step


@dataclasses.dataclass
class EngineStats:
    raw_cache_bytes: float = 0.0
    wire_bytes: float = 0.0
    prefill_calls: int = 0
    decode_tokens: int = 0
    codec_ok: bool = True
    # per-chunk wire bytes, one entry per pipeline chunk per transfer call
    # (chunked mode only; the whole-tensor path leaves this empty)
    chunk_wire_bytes: List[float] = dataclasses.field(default_factory=list)
    # units (chunks/tensors) re-encoded on the geometric capacity schedule
    chunk_retries: int = 0
    # total extra encode attempts across the schedule (cap -> 2cap -> 4cap ->
    # layout='global'); > chunk_retries when a unit needed several steps
    chunk_retry_steps: int = 0
    # fp32 hi/lo route: raw lo mantissa halves shipped alongside the stream
    fp32_lo_wire_bytes: float = 0.0
    # encoded units (chunks + leaves) that went down the capacity schedule —
    # the denominator for the observed overflow probability
    encoded_units: int = 0
    # per-prompt-length overflow observations: cache_len -> [units, retried].
    # DisaggregatedEngine.overflow_priors() buckets these into the
    # scheduler's per-bucket overflow_p priors
    overflow_obs: Dict[int, List[int]] = dataclasses.field(default_factory=dict)
    # wire-integrity path (verify=True / faults= engines): checksum
    # mismatches seen, re-fetches issued, and re-fetches that shipped raw
    verify_failures: int = 0
    refetches: int = 0
    raw_refetches: int = 0
    faults_injected: int = 0
    # compressed-resident KV (resident="compressed"): batches admitted into
    # the paged pool without rehydration, batches demoted to raw residency
    # (unsupported stream/family, escape overflow, pool exhaustion), and the
    # pool's HBM footprint vs what the same cache costs raw-resident
    resident_admits: int = 0
    resident_demotions: int = 0
    resident_hbm_bytes: float = 0.0
    resident_raw_bytes: float = 0.0
    # failover plane: retained-payload re-sends issued after a decode-worker
    # death (retain_for_failover=True engines)
    failover_resends: int = 0
    # prefix-delta transfer: raw bytes the destination already held and the
    # wire therefore never carried (excluded from wire_bytes by construction)
    prefix_hit_bytes: float = 0.0

    @property
    def resident_ratio(self) -> float:
        """raw-resident / compressed-resident HBM bytes — the decode-worker
        capacity multiplier (fig6)."""
        return self.resident_raw_bytes / max(self.resident_hbm_bytes, 1.0)

    @property
    def transfer_ratio(self) -> float:
        return self.raw_cache_bytes / max(self.wire_bytes, 1.0)

    @property
    def observed_overflow_p(self) -> float:
        """Fraction of encoded units whose FIRST attempt overflowed — the
        maximum-likelihood estimate of the per-attempt overflow probability
        the scheduler's capacity-schedule expectation model takes."""
        if self.encoded_units <= 0:
            return 0.0
        return self.chunk_retries / self.encoded_units


class DisaggregatedEngine:
    """Local-mode PD engine with a real compressed transfer stage."""

    def __init__(self, cfg: ArchConfig, params, codebook: Codebook,
                 *, compress: bool = True, chunk: int = 1024, cap: int = 64,
                 backend: str = "xla", n_chunks: int = 1,
                 compress_fp32: bool = False,
                 profile: Optional[CodecProfile] = None,
                 verify: bool = False, faults=None,
                 resident: str = "raw", page_bytes: Optional[int] = None,
                 retain_for_failover: bool = False,
                 prefix_cache_bytes: Optional[float] = None):
        if resident not in ("raw", "compressed"):
            raise ValueError(f"resident={resident!r}: expected 'raw' or "
                             "'compressed'")
        if resident == "compressed":
            # the pool consumes page-addressable in-graph streams: whole
            # tensors (chunked transfer re-segments leaves) from a jittable
            # backend, with compression actually on
            if n_chunks != 1:
                raise ValueError("resident='compressed' requires n_chunks=1 "
                                 "(chunked streams are not page-addressable)")
            if not compress:
                raise ValueError("resident='compressed' requires compress=True")
        if retain_for_failover and n_chunks != 1:
            raise ValueError("retain_for_failover requires n_chunks=1 (only "
                             "tensor-path payloads are retained)")
        if prefix_cache_bytes is not None:
            if n_chunks <= 1:
                raise ValueError("prefix_cache_bytes requires n_chunks > 1 "
                                 "(delta granularity is the chunked "
                                 "segmentation)")
            if not compress:
                raise ValueError("prefix_cache_bytes requires compress=True")
        self.cfg = cfg
        self.params = params
        self.tc = T.TransferConfig(codebook=codebook, chunk=chunk, cap=cap,
                                   enabled=compress, backend=backend,
                                   n_chunks=n_chunks,
                                   compress_fp32=compress_fp32)
        self.profile = profile
        # wire-integrity knobs, passed through to every TransferSession:
        # verify=True checksum-verifies each wire hop (re-fetch on failure),
        # faults injects a seeded FaultPlan (repro.serving.faults)
        self.verify = verify
        self.faults = faults
        self.resident = resident
        self.page_bytes = page_bytes
        self.retain_for_failover = retain_for_failover
        self.prefix_cache_bytes = prefix_cache_bytes
        self.stats = EngineStats()
        self._session: Optional[TransferSession] = None
        self._pool = None   # KVPool of the last admitted batch

    # -- plan/session caching ------------------------------------------------
    def _session_for(self, cache) -> TransferSession:
        """Build the TransferPlan once per cache structure; reuse its session
        for every subsequent transfer (compile-once / run-many).  One
        ``plan.matches`` walk per call doubles as the session's structure
        validation (the transfer below passes ``check=False``)."""
        if self._session is None or not self._session.plan.matches(cache):
            self._session = TransferPlan.build(cache, self.tc).session(
                verify=self.verify, faults=self.faults,
                retain_last=self.retain_for_failover)
            if self.prefix_cache_bytes is not None:
                self._session.enable_prefix_cache(self.prefix_cache_bytes)
        return self._session

    @property
    def plan(self) -> Optional[TransferPlan]:
        return self._session.plan if self._session is not None else None

    def describe_plan(self) -> str:
        """The resolved per-leaf routing table (empty before first transfer)."""
        return self.plan.describe() if self.plan is not None else "(no plan yet)"

    def overflow_priors(self, bucket_tokens: int = 1024) -> Dict[int, float]:
        """Per-bucket overflow priors from THIS engine's observed retries.

        ``EngineStats.overflow_obs`` accumulates, per transferred cache
        length, how many encoded units walked the capacity schedule and how
        many needed at least one re-encode; bucketing those observations at
        the scheduler's granularity yields the per-bucket per-attempt
        overflow probability ``SchedulerConfig.overflow_priors`` feeds into
        ``TransferPlan.estimate_time`` (ROADMAP: "per-bucket overflow
        priors").  Buckets with no observations are simply absent — the
        scheduler falls back to its scalar ``overflow_p`` for them."""
        b = max(1, bucket_tokens)
        agg: Dict[int, List[int]] = {}
        for length, (units, retried) in self.stats.overflow_obs.items():
            bucket = max(b, -(-length // b) * b)
            acc = agg.setdefault(bucket, [0, 0])
            acc[0] += units
            acc[1] += retried
        return {bucket: retried / units
                for bucket, (units, retried) in agg.items() if units > 0}

    def scheduler_config(self, profile: Optional[CodecProfile] = None,
                         **overrides) -> "SchedulerConfig":
        """A :class:`~repro.serving.scheduler.SchedulerConfig` whose admission
        engine charges transfers through THIS engine's transfer policy: the
        already-resolved :class:`TransferPlan` when one exists (the same
        object the session executes — the scheduler's numbers then flow
        through the real transfer path's plan), else per-bucket plans built
        from the engine's ``TransferConfig``.  ``profile`` defaults to the
        engine's profile; observed codec overflow feeds back as the
        scheduler's expected-retry model (scalar ``overflow_p`` plus the
        per-bucket ``overflow_priors`` when the engine has per-length
        observations); any other ``SchedulerConfig`` field passes through
        ``overrides``."""
        from repro.serving.scheduler import SchedulerConfig
        kw = dict(profile=profile if profile is not None else self.profile,
                  plan=self.plan, transfer_config=self.tc,
                  compress=self.tc.enabled,
                  n_chunks=max(1, self.tc.n_chunks),
                  overflow_p=self.stats.observed_overflow_p)
        kw.update(overrides)
        if "overflow_priors" not in overrides and self.stats.overflow_obs:
            kw["overflow_priors"] = self.overflow_priors(
                kw.get("bucket_tokens", SchedulerConfig.bucket_tokens))
        return SchedulerConfig(**kw)

    # -- the three pipeline stages ------------------------------------------
    def prefill(self, batch: Dict, max_seq: Optional[int] = None):
        out = prefill_step(self.params, batch, self.cfg, max_seq=max_seq)
        self.stats.prefill_calls += 1
        return out

    def transfer(self, state: DecodeState,
                 session_id: Optional[int] = None) -> DecodeState:
        """Compress -> ship -> decompress.  Bit-exact by construction.

        Escape-capacity overflow (``ok == False``) walks the plan's geometric
        capacity schedule and then triggers the raw fallback — per tensor on
        the whole-tensor path, per chunk on the pipelined path — so
        losslessness is unconditional even on adversarial activation
        distributions, and the accounting charges raw bytes for exactly the
        payload that actually shipped raw.

        ``session_id`` (with ``prefix_cache_bytes`` configured) routes the
        call through the prefix-delta path: segments the destination already
        holds for that session never cross the wire, and their raw size lands
        in ``EngineStats.prefix_hit_bytes``."""
        raw = T.raw_wire_bytes(state.cache)
        self.stats.raw_cache_bytes += raw
        if not self.tc.enabled or not state.cache:
            self.stats.wire_bytes += raw
            return state
        sess = self._session_for(state.cache)
        if self.resident == "compressed":
            return self._transfer_resident(sess, state)
        if session_id is not None and self.prefix_cache_bytes is not None:
            cache = sess.transfer_delta(state.cache, session_id, check=False)
        else:
            cache = sess.transfer(state.cache, check=False)
        self._absorb_transfer_stats(sess.last_stats, state)
        return DecodeState(cache=cache, cache_len=state.cache_len)

    def resend_cache(self, state: DecodeState) -> DecodeState:
        """Failover re-send: re-ship the last transfer's retained payload to
        a replacement decode worker (``retain_for_failover=True`` engines).

        The scheduler's ``on_failover`` hook calls this when a decode worker
        dies after its transfer completed — the prefill side re-ships the
        pristine compressed streams (one wire hop, no re-encode) and the
        rebuilt state is bit-identical to what the dead worker held."""
        if not self.tc.enabled or not state.cache:
            return state
        sess = self._session_for(state.cache)
        cache = sess.resend_last()
        self.stats.failover_resends += 1
        self.stats.raw_cache_bytes += T.raw_wire_bytes(state.cache)
        self._absorb_transfer_stats(sess.last_stats, state)
        return DecodeState(cache=cache, cache_len=state.cache_len)

    def _absorb_transfer_stats(self, cstats, state: DecodeState) -> None:
        self.stats.wire_bytes += cstats.wire_bytes
        self.stats.codec_ok &= cstats.all_ok
        self.stats.chunk_retries += cstats.n_retries
        self.stats.chunk_retry_steps += cstats.n_retry_steps
        self.stats.fp32_lo_wire_bytes += cstats.fp32_lo_wire_bytes
        self.stats.prefix_hit_bytes += cstats.prefix_hit_bytes
        self.stats.verify_failures += cstats.verify_failures
        self.stats.refetches += cstats.refetches
        self.stats.raw_refetches += cstats.raw_refetches
        self.stats.faults_injected += cstats.faults_injected
        # overflow observations: units that walked the capacity schedule on
        # this call, keyed by the transferred prompt length — the raw
        # material for the scheduler's per-bucket overflow priors
        units = len(cstats.chunk_retried)
        if units:
            self.stats.encoded_units += units
            lens = jnp.asarray(state.cache_len)
            length = int(jnp.max(lens)) if lens.size else 0
            obs = self.stats.overflow_obs.setdefault(length, [0, 0])
            obs[0] += units
            obs[1] += cstats.n_retries
        if self.tc.n_chunks > 1:
            self.stats.chunk_wire_bytes.extend(cstats.chunk_wire_bytes)

    def resident_tokens_per_page(self, batch: int = 1) -> int:
        """Page granularity the pool will use for this arch (max_seq must be
        a multiple; ``generate`` rounds up automatically)."""
        from repro.models import kvcache as KC
        from repro.models import kvpool as KVP
        cache = jax.eval_shape(
            lambda: KC.init_cache(self.cfg, batch, 8 * self.tc.chunk))
        return KVP.tokens_per_page_for(
            cache, self.tc.chunk, self.page_bytes or KVP.DEFAULT_PAGE_BYTES)

    def _transfer_resident(self, sess, state: DecodeState):
        """Admit the wire streams into a paged pool — no rehydration.

        Any inadmissible stream (raw-fallback leaf, layout/codebook drift,
        page-escape overflow, non-page-aligned max_seq) demotes THIS batch to
        raw residency: the already-received streams decode once
        (rehydrate-then-reference fallback) and decode runs the classic
        path.  Losslessness is unconditional either way."""
        from repro.core.backend import resolve_backend
        from repro.models import kvpool as KVP
        from repro.serving.session import decode_leaves

        comp, raw = sess.transfer_compressed(state.cache, check=False)
        self._absorb_transfer_stats(sess.last_stats, state)
        try:
            pool = KVP.KVPool.for_cache(
                state.cache, self.tc.codebook,
                resolve_backend(self.tc.backend, require_jittable=True),
                chunk=self.tc.chunk,
                page_bytes=self.page_bytes or KVP.DEFAULT_PAGE_BYTES)
            rst = pool.admit_from_wire(comp, state.cache_len)
        except KVP.ResidencyError:
            self.stats.resident_demotions += 1
            cache = decode_leaves(comp, raw, state.cache,
                                  backend=self.tc.backend)
            return DecodeState(cache=cache, cache_len=state.cache_len)
        self._pool = pool
        self.stats.resident_admits += 1
        self.stats.resident_hbm_bytes += pool.hbm_bytes()
        self.stats.resident_raw_bytes += pool.raw_bytes()
        return rst

    def decode(self, first_token: jax.Array, state, num_steps: int
               ) -> jax.Array:
        from repro.models.kvpool import ResidentState
        from repro.serving.decode import resident_decode_loop
        if isinstance(state, ResidentState):
            toks, _, demoted = resident_decode_loop(
                self.params, first_token, state, self._pool, self.cfg,
                num_steps)
            self.stats.resident_demotions += int(demoted)
        else:
            toks, _ = decode_loop(self.params, first_token, state, self.cfg,
                                  num_steps)
        self.stats.decode_tokens += int(toks.size)
        return toks

    # -- end-to-end ----------------------------------------------------------
    def generate(self, batch: Dict, num_steps: int,
                 max_seq: Optional[int] = None) -> jax.Array:
        """prompt batch -> (B, 1 + num_steps) generated ids (greedy)."""
        if self.resident == "compressed":
            # pages are fixed-size: pad the cache to a page multiple.  The
            # default max_seq (prompt + first token + decode steps) must be
            # derived HERE — prefill's own default (the raw prompt length)
            # is almost never page-aligned and would demote every batch.
            tp = self.resident_tokens_per_page()
            if max_seq is None:
                max_seq = batch["tokens"].shape[1] + 1 + num_steps
            max_seq = -(-max_seq // tp) * tp
        pre = self.prefill(batch, max_seq=max_seq)
        state = self.transfer(pre.state)
        toks = self.decode(pre.first_token, state, num_steps)
        return jnp.concatenate([pre.first_token[:, None], toks], axis=1)

    def transfer_report(self) -> Optional[T.TransferReport]:
        if self.profile is None:
            return None
        return T.transfer_report(self.stats.raw_cache_bytes,
                                 self.stats.wire_bytes, self.profile,
                                 n_chunks=self.tc.n_chunks, plan=self.plan)
