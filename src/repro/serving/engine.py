"""Disaggregated serving engine: prefill worker -> SplitZip transfer -> decode
worker, as one orchestrated pipeline.

Two operating modes:

* **local** (tests, examples, CPU): both workers in-process; the transfer is a
  real compress -> (simulated wire) -> decompress roundtrip through the
  in-graph codec, so bit-exactness of the whole serving path is checked
  end-to-end (paper Table 9).
* **mesh** (dry-run, TPU): the transfer runs `transfer_cache_cross_pod`
  (shard_map + ppermute over the pod axis); prefill/decode are pjit'd with
  the sharding policy.

The codec implementation is selected via the ``backend`` registry key
(``xla`` | ``pallas`` | ``wire`` — see :mod:`repro.core.backend`) and the
transfer granularity via ``n_chunks``: 1 reproduces the additive
whole-tensor path, >1 runs the chunked pipelined engine
(``transfer_cache_chunked``), which records per-chunk wire bytes in
``EngineStats.chunk_wire_bytes``.  Both paths are bit-exact by construction.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.codebook import Codebook
from repro.core.pipeline import CodecProfile
from repro.models import model as M
from repro.models.kvcache import DecodeState, cache_bytes
from repro.serving import transfer as T
from repro.serving.decode import decode_loop
from repro.serving.prefill import prefill_step


@dataclasses.dataclass
class EngineStats:
    raw_cache_bytes: float = 0.0
    wire_bytes: float = 0.0
    prefill_calls: int = 0
    decode_tokens: int = 0
    codec_ok: bool = True
    # per-chunk wire bytes, one entry per pipeline chunk per transfer call
    # (chunked mode only; the whole-tensor path leaves this empty)
    chunk_wire_bytes: List[float] = dataclasses.field(default_factory=list)
    # chunks re-encoded at doubled escape capacity (adaptive capacity)
    chunk_retries: int = 0

    @property
    def transfer_ratio(self) -> float:
        return self.raw_cache_bytes / max(self.wire_bytes, 1.0)


class DisaggregatedEngine:
    """Local-mode PD engine with a real compressed transfer stage."""

    def __init__(self, cfg: ArchConfig, params, codebook: Codebook,
                 *, compress: bool = True, chunk: int = 1024, cap: int = 64,
                 backend: str = "xla", n_chunks: int = 1,
                 profile: Optional[CodecProfile] = None):
        self.cfg = cfg
        self.params = params
        self.tc = T.TransferConfig(codebook=codebook, chunk=chunk, cap=cap,
                                   enabled=compress, backend=backend,
                                   n_chunks=n_chunks)
        self.profile = profile
        self.stats = EngineStats()

    # -- the three pipeline stages ------------------------------------------
    def prefill(self, batch: Dict, max_seq: Optional[int] = None):
        out = prefill_step(self.params, batch, self.cfg, max_seq=max_seq)
        self.stats.prefill_calls += 1
        return out

    def transfer(self, state: DecodeState) -> DecodeState:
        """Compress -> ship -> decompress.  Bit-exact by construction.

        Escape-capacity overflow (``ok == False``) triggers the raw fallback —
        per tensor on the whole-tensor path, per chunk on the pipelined path —
        so losslessness is unconditional even on adversarial activation
        distributions, and the accounting charges raw bytes for exactly the
        payload that actually shipped raw."""
        raw = T.raw_wire_bytes(state.cache)
        self.stats.raw_cache_bytes += raw
        if not self.tc.enabled or not state.cache:
            self.stats.wire_bytes += raw
            return state
        if self.tc.n_chunks > 1:
            return self._transfer_chunked(state)
        be = self.tc.get_backend()
        comp, rawleaves = T.compress_cache(state.cache, self.tc)
        self.stats.wire_bytes += float(
            T.compressed_wire_bytes(comp, rawleaves, backend=self.tc.backend))
        self.stats.codec_ok &= all(bool(be.ok(ct)) for ct in comp.values())
        # raw fallback for overflowed tensors (detected via the ok flag; in
        # the mesh path this is the off-graph re-fetch — see DESIGN.md §2)
        overflowed = {k for k, ct in comp.items() if not bool(be.ok(ct))}
        if overflowed:
            flat = jax.tree_util.tree_flatten_with_path(state.cache)[0]
            originals = {T.leaf_key(p): leaf for p, leaf in flat}
            comp = {k: v for k, v in comp.items() if k not in overflowed}
            rawleaves = dict(rawleaves)
            for k in overflowed:
                # an overflowed fp32 hi-half means the whole fp32 leaf ships
                # raw: drop its lo-half entry and restore the original leaf
                base = k[:-3] if k.endswith("#hi") else k
                rawleaves.pop(base + "#lo", None)
                rawleaves[base] = originals[base]
        cache = T.decompress_cache(comp, rawleaves, state.cache,
                                   backend=self.tc.backend)
        return DecodeState(cache=cache, cache_len=state.cache_len)

    def _transfer_chunked(self, state: DecodeState) -> DecodeState:
        """Pipelined transfer: per-chunk encode/ship/decode via ChunkSchedule."""
        cache, cstats = T.transfer_cache_chunked(state.cache, self.tc)
        self.stats.wire_bytes += cstats.wire_bytes
        self.stats.chunk_wire_bytes.extend(cstats.chunk_wire_bytes)
        self.stats.chunk_retries += cstats.n_retries
        self.stats.codec_ok &= cstats.all_ok
        return DecodeState(cache=cache, cache_len=state.cache_len)

    def decode(self, first_token: jax.Array, state: DecodeState,
               num_steps: int) -> jax.Array:
        toks, _ = decode_loop(self.params, first_token, state, self.cfg, num_steps)
        self.stats.decode_tokens += int(toks.size)
        return toks

    # -- end-to-end ----------------------------------------------------------
    def generate(self, batch: Dict, num_steps: int,
                 max_seq: Optional[int] = None) -> jax.Array:
        """prompt batch -> (B, 1 + num_steps) generated ids (greedy)."""
        pre = self.prefill(batch, max_seq=max_seq)
        state = self.transfer(pre.state)
        toks = self.decode(pre.first_token, state, num_steps)
        return jnp.concatenate([pre.first_token[:, None], toks], axis=1)

    def transfer_report(self) -> Optional[T.TransferReport]:
        if self.profile is None:
            return None
        return T.transfer_report(self.stats.raw_cache_bytes,
                                 self.stats.wire_bytes, self.profile,
                                 n_chunks=self.tc.n_chunks)
