"""TransferPlan: compile-once / run-many policy resolution for bulk transfer.

The PD transfer path used to re-decide per-leaf policy (bf16 vs fp32 vs fp8,
chunking, escape capacity, local vs mesh execution) on every call, in three
divergent entry points.  A :class:`TransferPlan` resolves all of it ONCE per
model from the *structure* (shapes + dtypes — abstract values work), and a
:class:`~repro.serving.session.TransferSession` then executes the plan many
times.  KVServe-style service-aware connectors and ZipServ-style
hardware-aware dispatch both make this argument: policy is a property of the
model + deployment, not of the individual transfer.

The structure is ANY pytree, not just a KV cache: train states, optimizer
states, and pod-partial gradient trees build plans the same way, which is
what lets checkpointing (persistent executor), elastic resharding, and the
compressed gradient ring (collective executor) all ride the one planned,
verified, accounted byte-moving core.

Per-leaf routing table (resolved at build time):

  bf16 leaf                    -> 'splitzip'   : the calibrated exponent codec
                                  via the backend registry; folded into the
                                  chunked bit stream when ``n_chunks > 1``.
  fp32 leaf (compress_fp32)    -> 'fp32_hilo'  : hi/lo u16 split; the hi half
                                  has the BF16 bit layout so the SAME codebook
                                  compresses it (folded into the chunked
                                  stream too); the lo mantissa half ships raw
                                  but is counted on the wire.
  float8 leaf                  -> 'fp8'        : e5m2 repack — bitcast to the
                                  u8 container and encoded under the e5m2
                                  exponent codebook (``tc.fp8_codebook`` or a
                                  default normal-band book); lossless for any
                                  float8 bits, ratio suffers only if the
                                  codebook band is off.
  everything else              -> 'raw'        : dtype-exact passthrough.

Capacity policy: each encoded unit (tensor or pipeline chunk) gets a
geometric retry schedule ``cap -> 2*cap -> 4*cap -> layout='global'``
(:meth:`repro.core.backend.CodecBackend.capacity_schedule`), replacing the
old single 2x retry; exhaustion still means the unconditional raw fallback.

Execution target: ``mesh=None`` plans run the local pipelined loop;
``mesh=`` plans run per-chunk ``lax.ppermute`` over the 'pod' axis with
double-buffering inside ``shard_map`` (n_chunks == 1 degenerates to the
whole-tensor collective).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import codec as C
from repro.core.backend import CodecBackend, get_backend, resolve_backend
from repro.core.codebook import Codebook
from repro.core.pipeline import (CodecProfile, degraded_stage_times,
                                 expected_schedule_attempts,
                                 flowshop_makespan)


@dataclasses.dataclass(frozen=True)
class TransferConfig:
    codebook: Codebook
    chunk: int = C.DEFAULT_CHUNK
    cap: int = C.DEFAULT_CAP
    enabled: bool = True          # False => native raw-bytes baseline
    compress_fp32: bool = False   # fp32 hi/lo-split codec toggle
    layout: str = "chunked"       # 'chunked' (paper) | 'global' (beyond-paper)
    global_budget: float = 0.01   # escape-capacity budget for layout='global'
    backend: str = "xla"          # codec backend registry key (core/backend.py)
    n_chunks: int = 1             # >1 => chunked pipelined transfer engine
    # codebook for the fp8 'e5m2 repack' route; None => default normal band
    fp8_codebook: Optional[Codebook] = None
    # geometric capacity schedule: number of cap doublings before the
    # layout='global' last resort (0 disables retries entirely)
    retry_doublings: int = 2
    retry_global_budget: float = 0.05
    # route threshold: encoded routes need at least this many elements —
    # smaller leaves ship raw (codec framing would not pay for itself).
    # Gradient plans set this to grad_compress.MIN_COMPRESS_ELEMS.
    min_compress_elems: int = 0

    def get_backend(self) -> CodecBackend:
        return get_backend(self.backend)


# default 'e5m2 repack' codebook: the 16-exponent band around the e5m2 bias
# (15), covering normal activations; escapes handle the rest losslessly
FP8_DEFAULT_CODEBOOK = Codebook(fmt="fp8_e5m2", exponents=tuple(range(8, 24)))


def leaf_key(path) -> str:
    """Canonical pytree-path -> string key.  Compression, wire accounting,
    segmentation, and reassembly all index by this; it must stay one
    definition or decompression silently misroutes leaves."""
    return "/".join(str(getattr(k, "key", k)) for k in path)


def _is_float8(dtype) -> bool:
    return str(jnp.dtype(dtype)).startswith("float8")


def _resolve_cap(tc: TransferConfig, n: int) -> int:
    cap = tc.cap
    if tc.layout == "global" and cap == C.DEFAULT_CAP:
        cap = C.default_global_cap(n, tc.global_budget)
    return cap


# ---------------------------------------------------------------------------
# per-leaf routes and per-chunk segments
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LeafRoute:
    """One leaf's resolved transfer policy."""

    key: str
    shape: Tuple[int, ...]
    dtype: str
    route: str                    # 'splitzip' | 'fp32_hilo' | 'fp8' | 'raw'
    cap: int = 0                  # level-0 escape capacity (encoded routes)

    @property
    def n_elements(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def raw_bytes(self) -> float:
        return float(self.n_elements * jnp.dtype(self.dtype).itemsize)


@dataclasses.dataclass(frozen=True)
class SegmentSpec:
    """One pipeline chunk of the folded u16 bit stream: a contiguous,
    codec-chunk-aligned [start, stop) element range with its resolved
    level-0 escape capacity."""

    start: int
    stop: int
    cap: int

    @property
    def n_elements(self) -> int:
        return self.stop - self.start

    @property
    def raw_bytes(self) -> float:
        return 2.0 * self.n_elements


@dataclasses.dataclass
class TransferStats:
    """Per-transfer accounting emitted by a :class:`TransferSession` run.

    Chunked executions fill the ``chunk_*`` lists (one entry per pipeline
    chunk); whole-tensor executions fill ``leaf_wire_bytes``/``leaf_ok``
    (one entry per encoded leaf).  Either way ``wire_bytes``/``all_ok``
    give the engine a uniform view."""

    chunk_wire_bytes: List[float]   # wire bytes actually shipped per chunk
    chunk_ok: List[bool]            # escape capacity held for this chunk?
    raw_passthrough_bytes: float    # unrouted leaves shipped outside the pipe
    n_elements: int                 # u16 elements routed through the pipe
    # chunks whose first encode overflowed and were re-encoded on the
    # geometric capacity schedule (chunk_ok reflects the final attempt)
    chunk_retried: List[bool] = dataclasses.field(default_factory=list)
    # extra encode attempts per chunk (0 == first encode held); the full
    # geometric schedule is cap -> 2cap -> 4cap -> layout='global'
    chunk_retry_steps: List[int] = dataclasses.field(default_factory=list)
    # whole-tensor execution: per-leaf accounting (raw-fallback applied)
    leaf_wire_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    leaf_ok: Dict[str, bool] = dataclasses.field(default_factory=dict)
    # fp32 hi/lo route: raw lo mantissa halves counted on the wire (chunked
    # executions fold the hi halves into chunk_wire_bytes)
    fp32_lo_wire_bytes: float = 0.0
    # fp8 route: sidecar-encoded float8 leaves' wire bytes
    fp8_wire_bytes: float = 0.0
    # wire-integrity path (verify=True sessions / injected faults): checksum
    # mismatches + drops observed, re-fetches issued, re-fetches that shipped
    # the unit's raw bits, and the extra bytes those re-fetches put on the
    # wire (chunk_*/leaf_* keep their first-ship meaning)
    verify_failures: int = 0
    refetches: int = 0
    raw_refetches: int = 0
    refetch_wire_bytes: float = 0.0
    # injected-fault bookkeeping (FaultChannel): faults applied this call and
    # wire latency added by 'delay' faults
    faults_injected: int = 0
    fault_delay_s: float = 0.0
    # prefix-aware delta transfer (ISSUE 10): raw bytes of segments/sidecars
    # NOT shipped because the receiver's prefix index already held them
    # bit-identically.  Deliberately excluded from ``wire_bytes`` — that
    # property stays "bytes actually on the wire", and the saving is the gap
    # between raw_bytes and it
    prefix_hit_bytes: float = 0.0

    @property
    def wire_bytes(self) -> float:
        return (sum(self.chunk_wire_bytes) + sum(self.leaf_wire_bytes.values())
                + self.raw_passthrough_bytes + self.fp32_lo_wire_bytes
                + self.fp8_wire_bytes + self.refetch_wire_bytes)

    @property
    def all_ok(self) -> bool:
        return all(self.chunk_ok) and all(self.leaf_ok.values())

    @property
    def n_retries(self) -> int:
        """Units (chunks/leaves) that needed at least one re-encode."""
        return sum(self.chunk_retried)

    @property
    def n_retry_steps(self) -> int:
        """Total extra encode attempts across the capacity schedule."""
        return sum(self.chunk_retry_steps)


# back-compat alias: the chunked engine's stats type predates the plan API
ChunkedTransferStats = TransferStats


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TransferPlan:
    """A resolved, leaf-aware transfer program.  Build once per model with
    :meth:`build`, execute many times through :meth:`session`."""

    tc: TransferConfig
    treedef: Any
    routes: Tuple[LeafRoute, ...]
    backend: CodecBackend
    segments: Tuple[SegmentSpec, ...]   # chunked-granularity stream cuts
    stream_len: int                     # u16 elements folded into the stream
    mesh: Optional[Mesh] = None
    src_pod: int = 0
    dst_pod: int = 1
    in_specs: Optional[Tuple[P, ...]] = None

    # -- construction --------------------------------------------------------
    @classmethod
    def build(cls, cache_structure, tc: TransferConfig,
              mesh: Optional[Mesh] = None, *, specs=None,
              src_pod: int = 0, dst_pod: int = 1,
              granularity: Optional[str] = None) -> "TransferPlan":
        """Resolve the full per-leaf policy from shapes + dtypes.

        ``cache_structure`` is ANY pytree — a KV cache, a train/optimizer
        state, or a pod-partial gradient tree — holding concrete arrays or
        ShapeDtypeStructs: only ``.shape``/``.dtype`` are read, so plans can
        be built from abstract states (dry-run) or inside a trace (shapes
        are static).  Leaves below ``tc.min_compress_elems`` elements route
        'raw' regardless of dtype.

        ``granularity`` forces 'chunked' (segment even when ``n_chunks ==
        1``) or 'tensor'; None picks 'chunked' iff ``tc.n_chunks > 1``."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(cache_structure)
        backend = resolve_backend(tc.backend, require_jittable=mesh is not None)
        if mesh is not None and "pod" not in mesh.shape:
            raise ValueError("mesh execution needs a 'pod' mesh axis")

        routes: List[LeafRoute] = []
        stream_len = 0
        for path, leaf in flat:
            key = leaf_key(path)
            shape, dtype = tuple(leaf.shape), jnp.dtype(leaf.dtype)
            n = int(np.prod(shape)) if shape else 1
            if n < tc.min_compress_elems:
                routes.append(LeafRoute(key, shape, str(dtype), "raw"))
                continue
            if dtype == jnp.bfloat16 and tc.enabled:
                route = LeafRoute(key, shape, str(dtype), "splitzip",
                                  cap=_resolve_cap(tc, n))
                stream_len += n
            elif dtype == jnp.float32 and tc.enabled and tc.compress_fp32:
                route = LeafRoute(key, shape, str(dtype), "fp32_hilo",
                                  cap=_resolve_cap(tc, n))
                stream_len += n                     # the folded hi half
            elif _is_float8(dtype) and tc.enabled:
                route = LeafRoute(key, shape, str(dtype), "fp8",
                                  cap=_resolve_cap(tc, n))
            else:
                route = LeafRoute(key, shape, str(dtype), "raw")
            routes.append(route)

        if granularity is None:
            granularity = "chunked" if tc.n_chunks > 1 else "tensor"
        segments: List[SegmentSpec] = []
        if granularity == "chunked" and stream_len and tc.enabled:
            per = -(-stream_len // max(1, tc.n_chunks))        # ceil split
            per = max(tc.chunk, -(-per // tc.chunk) * tc.chunk)  # align up
            for start in range(0, stream_len, per):
                stop = min(start + per, stream_len)
                segments.append(SegmentSpec(start, stop,
                                            _resolve_cap(tc, stop - start)))

        in_specs = None
        if mesh is not None:
            if specs is not None:
                in_specs = tuple(jax.tree_util.tree_leaves(
                    specs, is_leaf=lambda x: isinstance(x, P)))
            else:
                in_specs = tuple(cls._default_leaf_spec(leaf, mesh)
                                 for _, leaf in flat)
        return cls(tc=tc, treedef=treedef, routes=tuple(routes),
                   backend=backend, segments=tuple(segments),
                   stream_len=stream_len, mesh=mesh, src_pod=src_pod,
                   dst_pod=dst_pod, in_specs=in_specs)

    @staticmethod
    def _default_leaf_spec(x, mesh: Mesh) -> P:
        # cache leaves: (L, B, S, ...) — batch over data, replicated over
        # pod/model (the host-staged value; prefill pod is the logical owner)
        spec = [None] * len(x.shape)
        if len(x.shape) >= 2 and x.shape[1] % mesh.shape["data"] == 0:
            spec[1] = "data"
        return P(*spec)

    # -- derived views -------------------------------------------------------
    @property
    def granularity(self) -> str:
        return "chunked" if len(self.segments) > 0 else "tensor"

    @property
    def n_chunks(self) -> int:
        return len(self.segments)

    @property
    def fp8_codebook(self) -> Codebook:
        return self.tc.fp8_codebook or FP8_DEFAULT_CODEBOOK

    def route_map(self) -> Dict[str, LeafRoute]:
        return {r.key: r for r in self.routes}

    def matches(self, cache) -> bool:
        """Does ``cache`` have exactly the structure this plan was built for?"""
        flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
        if treedef != self.treedef or len(flat) != len(self.routes):
            return False
        return all(tuple(leaf.shape) == r.shape
                   and str(jnp.dtype(leaf.dtype)) == r.dtype
                   for (_, leaf), r in zip(flat, self.routes))

    def schedule_for(self, n: int, cap: int) -> Tuple[Tuple[CodecBackend, str, int], ...]:
        """The geometric capacity schedule for one encoded unit of ``n``
        elements (see ``CodecBackend.capacity_schedule``)."""
        return self.backend.capacity_schedule(
            self.tc.layout, cap, n, doublings=self.tc.retry_doublings,
            global_budget=self.tc.retry_global_budget)

    def raw_bytes(self) -> float:
        return float(sum(r.raw_bytes for r in self.routes))

    def chunk_raw_bytes(self, scale: float = 1.0) -> List[float]:
        """Raw byte size of each pipeline chunk, as actually segmented.
        ``scale`` shrinks/grows every segment proportionally (the scheduler's
        per-prompt-length byte scaling within a bucket plan)."""
        return [s.raw_bytes * scale for s in self.segments]

    def byte_split(self, scale: float = 1.0) -> Tuple[float, float, float]:
        """(stream_bytes, fp8_sidecar_bytes, incompressible_bytes) under the
        route table: stream = bf16 bits + fp32 hi halves (codec ratio
        applies), fp8 sidecars compress outside the pipe, incompressible =
        raw passthrough + fp32 lo halves (full link cost — no ratio).
        ``scale`` multiplies every class (per-prompt-length scaling)."""
        stream = 2.0 * self.stream_len
        fp8 = out = 0.0
        for r in self.routes:
            if r.route == "fp8":
                fp8 += r.raw_bytes
            elif r.route == "fp32_hilo":
                out += 2.0 * r.n_elements           # the raw lo half
            elif r.route == "raw":
                out += r.raw_bytes
        return stream * scale, fp8 * scale, out * scale

    def collective_wire_bytes(self, ratio: float, n_hops: int,
                              scale: float = 1.0) -> float:
        """Analytic wire bytes for a ring collective over this plan: each of
        the ``n_hops`` hops ships the routed stream at the codec ``ratio``
        (a calibrated/paper :class:`~repro.core.pipeline.CodecProfile`
        ratio — NOT a hard-coded guess) plus the incompressible bytes at
        full cost.  ``scale`` evaluates a per-participant slice of the plan
        (e.g. ``1/n_pod`` when the plan was built over pod-stacked leaves).
        The lowered HLO's ppermute operand sizes are the ground truth this
        estimates (analysis/roofline.py reads those)."""
        stream, fp8, out = self.byte_split(scale)
        return ((stream + fp8) / max(ratio, 1e-9) + out) * n_hops

    def expected_attempts(self, overflow_p: float) -> Tuple[float, float]:
        """``(expected encode attempts per unit, raw-fallback fraction)``
        under THIS plan's geometric capacity schedule when each attempt
        independently overflows with probability ``overflow_p``.  The
        schedule length is read off a representative encoded unit — the
        first segment (chunked) or the largest encoded leaf (tensor)."""
        if overflow_p <= 0.0:
            return 1.0, 0.0
        if self.segments:
            n, cap = self.segments[0].n_elements, self.segments[0].cap
        else:
            enc = [r for r in self.routes if r.route != "raw"]
            if not enc:
                return 1.0, 0.0
            big = max(enc, key=lambda r: r.n_elements)
            n, cap = big.n_elements, big.cap
        return expected_schedule_attempts(len(self.schedule_for(n, cap)),
                                          overflow_p)

    def estimate_time(self, profile: CodecProfile, *, scale: float = 1.0,
                      overflow_p: float = 0.0) -> float:
        """Plan-aware a-priori transfer time for ONE execution: the flowshop
        recurrence over the plan's ACTUAL segment sizes (tensor granularity:
        additive), charging the codec ratio only on routed bytes —
        incompressible sidecars (lo halves, raw passthrough) pay full link
        cost.

        ``scale`` evaluates the plan at a different payload size (the
        scheduler charges requests of one prompt-length bucket off one plan);
        ``overflow_p`` walks the capacity schedule in expectation: encode
        re-attempts inflate the encode stage and the exhausted fraction
        ships raw at full link bandwidth."""
        stream, fp8, out = self.byte_split(scale)
        attempts, raw_frac = self.expected_attempts(overflow_p)
        # fp8 sidecars walk the same capacity schedule: their exhausted
        # fraction also ships raw at full link cost
        t_side = (fp8 * ((1.0 - raw_frac) / (profile.ratio * profile.link_bw)
                         + raw_frac / profile.link_bw)
                  + out / profile.link_bw)
        if self.granularity == "chunked":
            times = [degraded_stage_times(s, profile, attempts=attempts,
                                          raw_frac=raw_frac)
                     for s in self.chunk_raw_bytes(scale)]
            return (flowshop_makespan(times) + profile.fixed_overhead_s
                    + t_side)
        t_enc, t_xfer, t_dec = degraded_stage_times(stream, profile,
                                                    attempts=attempts,
                                                    raw_frac=raw_frac)
        t_enc += attempts * fp8 / profile.g_enc      # fp8 sidecars are
        t_dec += (1.0 - raw_frac) * fp8 / profile.g_dec  # codec-touched too
        return t_enc + t_xfer + t_dec + t_side + profile.fixed_overhead_s

    def describe(self) -> str:
        """Human-readable routing table (serve launcher / docs)."""
        counts: Dict[str, int] = {}
        bytes_: Dict[str, float] = {}
        for r in self.routes:
            counts[r.route] = counts.get(r.route, 0) + 1
            bytes_[r.route] = bytes_.get(r.route, 0.0) + r.raw_bytes
        target = ("local" if self.mesh is None
                  else f"mesh(pod {self.src_pod}->{self.dst_pod})")
        lines = [f"TransferPlan[{self.granularity}, backend={self.backend.name}, "
                 f"target={target}, n_chunks={max(1, self.n_chunks)}]"]
        for route in ("splitzip", "fp32_hilo", "fp8", "raw"):
            if route in counts:
                lines.append(f"  {route:10s}: {counts[route]:3d} leaves, "
                             f"{bytes_[route] / 2**20:8.2f} MiB raw")
        if self.segments:
            lines.append(f"  segments  : {self.n_chunks} x "
                         f"~{self.segments[0].n_elements} u16 elems "
                         f"(cap {self.segments[0].cap})")
        return "\n".join(lines)

    # -- stream folding (chunked granularity) --------------------------------
    def fold_stream(self, cache) -> Tuple[jax.Array, Dict, Dict, Dict]:
        """Flatten every routed leaf into ONE u16 bit stream in route order:
        bf16 leaves contribute their container bits, fp32 leaves their hi
        halves (lo halves returned separately, shipped raw).  Returns
        ``(stream, lo_halves, fp8_leaves, raw_leaves)``."""
        flat = jax.tree_util.tree_flatten_with_path(cache)[0]
        parts: List[jax.Array] = []
        lo: Dict[str, jax.Array] = {}
        fp8: Dict[str, jax.Array] = {}
        raw: Dict[str, jax.Array] = {}
        for (path, leaf), r in zip(flat, self.routes):
            if r.route == "splitzip":
                parts.append(jax.lax.bitcast_convert_type(
                    leaf, jnp.uint16).reshape(-1))
            elif r.route == "fp32_hilo":
                u = jax.lax.bitcast_convert_type(leaf, jnp.uint32).reshape(-1)
                parts.append((u >> 16).astype(jnp.uint16))
                lo[r.key] = (u & 0xFFFF).astype(jnp.uint16)
            elif r.route == "fp8":
                fp8[r.key] = leaf
            else:
                raw[r.key] = leaf
        if not parts:
            stream = jnp.zeros((0,), jnp.uint16)
        else:
            stream = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
        return stream, lo, fp8, raw

    def unfold_stream(self, bits_out: jax.Array, lo: Dict, fp8_decoded: Dict,
                      raw: Dict):
        """Inverse of :meth:`fold_stream` against the plan's structure."""
        leaves, off = [], 0
        for r in self.routes:
            n = r.n_elements
            if r.route == "splitzip":
                leaves.append(jax.lax.bitcast_convert_type(
                    bits_out[off:off + n].reshape(r.shape), jnp.bfloat16))
                off += n
            elif r.route == "fp32_hilo":
                hi = bits_out[off:off + n].astype(jnp.uint32)
                u = (hi << 16) | lo[r.key].astype(jnp.uint32)
                leaves.append(jax.lax.bitcast_convert_type(
                    u.reshape(r.shape), jnp.float32))
                off += n
            elif r.route == "fp8":
                leaves.append(jnp.asarray(fp8_decoded[r.key]).reshape(r.shape))
            else:
                leaves.append(raw[r.key])
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    # -- session -------------------------------------------------------------
    def session(self, *, faults=None, verify: bool = False,
                retain_last: bool = False) -> "TransferSession":
        """``faults`` is ``None | registry name | FaultPlan`` (see
        :mod:`repro.serving.faults`); ``verify=True`` checksum-verifies every
        wire hop and routes failures through the capacity-retry machinery;
        ``retain_last=True`` keeps the last transfer's pristine compressed
        payloads sender-side so a decode-worker failover can re-send them
        (``TransferSession.resend_last``) without re-encoding."""
        from repro.serving.session import TransferSession
        return TransferSession(self, faults=faults, verify=verify,
                               retain_last=retain_last)
