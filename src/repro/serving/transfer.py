"""KV-cache transfer (the paper's setting) — DEPRECATION SHIMS + accounting.

The transfer API is now a compile-once/run-many pair:

    plan = TransferPlan.build(cache_structure, tc, mesh=...)  # policy, ONCE
    sess = plan.session()
    out  = sess.transfer(cache)                                # execute, MANY

:class:`repro.serving.plan.TransferPlan` resolves, per leaf, the codec route
(bf16 -> splitzip backend; fp32 -> hi/lo split folded into the chunked
stream; float8 -> e5m2 repack; else raw), the chunk segmentation (codec-
chunk-aligned, precomputed), the capacity policy (geometric retry schedule
``cap -> 2cap -> 4cap -> layout='global'``), and the execution target (local
pipelined loop vs per-chunk ``lax.ppermute`` with double-buffering inside
``shard_map``).  :class:`repro.serving.session.TransferSession` executes it:
``send``/``recv``/``transfer``.  All serving consumers
(``DisaggregatedEngine``, launch/serve.py, benchmarks, examples) go through
the session — CI greps that ``src/repro/serving`` and ``src/repro/launch``
never call the free functions below directly.

This module keeps those historical entry points — ``compress_cache`` /
``decompress_cache`` (whole-tensor), ``transfer_cache_chunked`` (local
pipelined), ``transfer_cache_cross_pod`` (mesh) — as THIN SHIMS that build a
one-shot plan and run it, so out-of-tree callers keep working; new code
should hold a plan and reuse its session.  The analytic accounting
(``transfer_report``, ``compressed_wire_bytes``, ``raw_wire_bytes``) also
lives here; the :class:`~repro.core.pipeline.CodecProfile` it takes should
come from :mod:`repro.core.profile` (calibrated ``profiles.json`` or the
paper constants) rather than hand-entered throughput numbers — the
scheduler itself charges transfers through ``TransferPlan.estimate_time``,
not through anything in this module.

Losslessness is unconditional on every path: escape-capacity overflow
(``ok == False``) walks the plan's capacity schedule and then falls back to
the raw payload per unit (tensor or chunk), so adversarial activation
distributions degrade to raw-speed transfer, never to corruption.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.backend import get_backend
from repro.core.pipeline import CodecProfile, flowshop_makespan
# re-exports: the plan/session API is the canonical surface; these names
# stay importable from repro.serving.transfer for existing callers
from repro.serving.plan import (ChunkedTransferStats, TransferConfig,
                                TransferPlan, TransferStats, leaf_key)
from repro.serving.session import (TransferSession, _backend_for,
                                   _permute_leaf, decode_leaves,
                                   encode_leaves)

__all__ = [
    "TransferConfig", "TransferPlan", "TransferSession", "TransferStats",
    "ChunkedTransferStats", "leaf_key", "compress_cache", "decompress_cache",
    "compressed_wire_bytes", "raw_wire_bytes", "split_cache_segments",
    "transfer_cache_chunked", "transfer_cache_cross_pod", "TransferReport",
    "transfer_report",
]


# ---------------------------------------------------------------------------
# whole-tensor shims (deprecated: hold a TransferPlan/TransferSession instead)
# ---------------------------------------------------------------------------

def compress_cache(cache: Dict, tc: TransferConfig) -> Tuple[Dict, Dict]:
    """DEPRECATED shim: one-shot plan + per-leaf encode (no retry schedule).

    Returns (compressed pytree, passthrough pytree).  Each routed leaf
    becomes a CompressedTensor (bf16 via the plan's splitzip route; fp32
    with ``compress_fp32`` as ``#hi``/``#lo`` halves; float8 via the e5m2
    repack route).  New code: ``TransferPlan.build(...).session()``."""
    plan = TransferPlan.build(cache, tc)
    return encode_leaves(plan, cache, scheduled=False)


def decompress_cache(comp: Dict, raw: Dict, structure: Dict,
                     backend: str = "xla") -> Dict:
    """DEPRECATED shim: inverse of :func:`compress_cache` against the
    original pytree structure (see :func:`repro.serving.session.decode_leaves`
    for the per-object backend dispatch)."""
    return decode_leaves(comp, raw, structure, backend=backend)


def compressed_wire_bytes(comp: Dict, raw: Dict,
                          backend: str = "xla") -> jax.Array:
    """Total wire bytes with the per-tensor raw fallback applied: a tensor
    whose escape capacity overflowed (``ok == False``) is charged raw bytes,
    because that is what the engine actually ships for it."""
    be = get_backend(backend)
    total = jnp.zeros((), jnp.float32)
    for ct in comp.values():
        b = _backend_for(ct, be)
        total = total + jnp.where(b.ok(ct),
                                  jnp.asarray(b.wire_bytes(ct), jnp.float32),
                                  jnp.float32(b.raw_bytes(ct)))
    for leaf in raw.values():
        total = total + leaf.size * leaf.dtype.itemsize
    return total


def raw_wire_bytes(cache: Dict) -> float:
    return float(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache)))


# ---------------------------------------------------------------------------
# chunked / cross-pod shims
# ---------------------------------------------------------------------------

def split_cache_segments(cache: Dict, n_chunks: int, align: int
                         ) -> Tuple[List[jax.Array], List[Tuple[str, tuple]], Dict]:
    """DEPRECATED shim: flatten every bf16 leaf into one u16 bit stream and
    cut it into at most ``n_chunks`` ``align``-aligned segments.  The plan
    now owns segmentation (``TransferPlan.segments`` + ``fold_stream``, which
    also folds fp32 hi halves); this keeps the historical bf16-only view."""
    bits_parts, metas, raw = [], [], {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
        key = leaf_key(path)
        if leaf.dtype == jnp.bfloat16:
            bits_parts.append(
                jax.lax.bitcast_convert_type(leaf, jnp.uint16).reshape(-1))
            metas.append((key, tuple(leaf.shape)))
        else:
            raw[key] = leaf
    if not bits_parts:
        return [], metas, raw
    stream = jnp.concatenate(bits_parts) if len(bits_parts) > 1 else bits_parts[0]
    n = stream.shape[0]
    per = -(-n // max(1, n_chunks))          # ceil split
    per = max(align, -(-per // align) * align)  # align up to the codec chunk
    segments = [stream[i:i + per] for i in range(0, n, per)]
    return segments, metas, raw


def transfer_cache_chunked(cache: Dict, tc: TransferConfig
                           ) -> Tuple[Dict, TransferStats]:
    """DEPRECATED shim: one-shot plan through the local pipelined engine.

    Equivalent to ``TransferPlan.build(cache, tc).session().transfer(cache)``
    — per-chunk encode/ship/decode on the ``ChunkSchedule`` overlap, the
    geometric capacity schedule on overflow, raw fallback after exhaustion,
    and bit-exact reassembly.  Returns ``(cache, stats)``."""
    sess = TransferPlan.build(cache, tc, granularity="chunked").session()
    out = sess.transfer(cache)
    stats = sess.last_stats
    if stats is not None and not stats.chunk_wire_bytes and tc.n_chunks > 1:
        # structure with nothing to fold (or compression disabled): report
        # the historical raw-chunk accounting for the bf16 stream
        segments, _, _ = split_cache_segments(cache, tc.n_chunks, tc.chunk)
        stats = dataclasses.replace(
            stats,
            chunk_wire_bytes=[float(s.shape[0] * 2) for s in segments],
            chunk_ok=[True] * len(segments),
            chunk_retried=[False] * len(segments),
            chunk_retry_steps=[0] * len(segments),
            raw_passthrough_bytes=stats.raw_passthrough_bytes
            - float(sum(s.shape[0] * 2 for s in segments)),
            n_elements=int(sum(s.shape[0] for s in segments)))
    return out, stats


def transfer_cache_cross_pod(
    cache: Dict,
    mesh: Mesh,
    tc: TransferConfig,
    src_pod: int = 0,
    dst_pod: int = 1,
    return_hlo: bool = False,
    specs=None,
    select_dst: bool = True,
):
    """DEPRECATED shim: one-shot mesh plan (shard_map + ppermute over 'pod').

    Equivalent to ``TransferPlan.build(cache, tc, mesh=mesh, specs=specs,
    src_pod=..., dst_pod=...).session().transfer(cache)``.  ``tc.n_chunks >
    1`` ships per-chunk streams with double-buffered ppermutes; the result
    is bit-identical to the whole-tensor collective."""
    sess = TransferPlan.build(cache, tc, mesh=mesh, specs=specs,
                              src_pod=src_pod, dst_pod=dst_pod).session()
    out = sess.transfer(cache, select_dst=select_dst)
    if return_hlo:
        # post-SPMD HLO: the collective-permute operand sizes here are the
        # actual wire bytes (compressed when tc.enabled)
        return out, sess.lower_hlo(cache)
    return out


# ---------------------------------------------------------------------------
# analytic transfer report (paper Fig. 3 / Fig. 4 accounting)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TransferReport:
    raw_bytes: float
    wire_bytes: float
    t_native: float
    t_splitzip: float
    t_encode: float
    t_transfer: float
    t_decode: float

    @property
    def ratio(self) -> float:
        return self.raw_bytes / max(self.wire_bytes, 1.0)

    @property
    def speedup(self) -> float:
        return self.t_native / max(self.t_splitzip, 1e-12)


def transfer_report(raw_bytes: float, wire_bytes: float,
                    profile: CodecProfile, n_chunks: int = 1,
                    plan: Optional[TransferPlan] = None) -> TransferReport:
    """Analytic accounting from MEASURED wire bytes: additive
    encode + compressed transfer + decode (Fig. 4) when ``n_chunks == 1``,
    chunked steady-state pipeline (Appendix A) when ``n_chunks > 1``.

    With ``plan=`` the pipeline term splits the MEASURED totals across
    chunks in the plan's ACTUAL segment proportions (short tail chunk
    included) and runs the flowshop recurrence — still a function of the
    measured raw/wire bytes, so it stays consistent when the totals
    accumulate over many engine calls and when raw fallbacks inflate the
    wire bytes (``plan.estimate_time`` is the single-transfer a-priori
    estimate instead)."""
    t_enc = raw_bytes / profile.g_enc
    t_dec = raw_bytes / profile.g_dec
    t_xfer = wire_bytes / profile.link_bw
    if plan is not None and plan.granularity == "chunked":
        seg = plan.chunk_raw_bytes()
        fracs = [s / sum(seg) for s in seg]
        t_total = flowshop_makespan(
            [(f * t_enc, f * t_xfer, f * t_dec) for f in fracs]
        ) + profile.fixed_overhead_s
    elif n_chunks > 1:
        per = [t / n_chunks for t in (t_enc, t_xfer, t_dec)]
        t_total = sum(per) + (n_chunks - 1) * max(per) + profile.fixed_overhead_s
    else:
        t_total = t_enc + t_xfer + t_dec + profile.fixed_overhead_s
    return TransferReport(
        raw_bytes=raw_bytes,
        wire_bytes=wire_bytes,
        t_native=raw_bytes / profile.link_bw + profile.fixed_overhead_s,
        t_splitzip=t_total,
        t_encode=t_enc, t_transfer=t_xfer, t_decode=t_dec,
    )
