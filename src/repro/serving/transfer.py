"""KV-cache transfer engine with SplitZip compression (the paper's setting).

The PD boundary on a TPU mesh: prefill workers live on pod 0, decode workers
on pod 1 of the (pod, data, model) mesh.  ``transfer_compressed`` maps the
in-graph SplitZip codec over every bf16 cache leaf, moves the *compressed
streams* across the pod axis with ``lax.ppermute`` inside ``shard_map``, and
decodes on the receiving pod.  fp32 recurrent states (SSM/RG-LRU) ship raw
(see DESIGN.md; a beyond-paper fp32 codec variant is tracked separately).

Losslessness is unconditional: each tensor's ``ok`` flag (escape-capacity
overflow) selects compressed vs raw payload per tensor, so adversarial
activation distributions degrade to raw-speed transfer, never to corruption.

Codec selection is pluggable: every encode/decode in this module goes through
the :mod:`repro.core.backend` registry (``TransferConfig.backend`` — ``auto``,
``xla``, ``pallas``, or ``wire``), never through a codec module directly.
On the chunked path decompression uses ``decode_bits`` — the fused Pallas
decode kernel emits exactly the bit stream the pipe ships, so no
reshape/bitcast tail runs between decode and reassembly.  Transfer
granularity is pluggable too: ``TransferConfig.n_chunks > 1`` switches from
whole-tensor encode→ship→decode to the chunked pipelined engine
(``transfer_cache_chunked``), which drives ``ChunkSchedule`` so encode of
chunk *t* overlaps transfer of *t−1* and decode of *t−2*, with a per-chunk
raw fallback preserving unconditional losslessness.

Byte accounting for the roofline reads the ppermute operand sizes straight
from the lowered HLO (analysis/roofline.py); the analytic model here
(`transfer_report`) mirrors the paper's Fig. 3/4 accounting.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import codec as C
from repro.core.backend import CodecBackend, get_backend
from repro.core.codebook import Codebook
from repro.core.pipeline import (ChunkSchedule, CodecProfile,
                                 additive_transfer_time, native_transfer_time,
                                 pipelined_transfer_time)


@dataclasses.dataclass(frozen=True)
class TransferConfig:
    codebook: Codebook
    chunk: int = C.DEFAULT_CHUNK
    cap: int = C.DEFAULT_CAP
    enabled: bool = True          # False => native raw-bytes baseline
    compress_fp32: bool = False   # beyond-paper fp32-state codec toggle
    layout: str = "chunked"       # 'chunked' (paper) | 'global' (beyond-paper)
    global_budget: float = 0.01   # escape-capacity budget for layout='global'
    backend: str = "xla"          # codec backend registry key (core/backend.py)
    n_chunks: int = 1             # >1 => chunked pipelined transfer engine

    def get_backend(self) -> CodecBackend:
        return get_backend(self.backend)


def leaf_key(path) -> str:
    """Canonical pytree-path -> string key.  Compression, wire accounting,
    segmentation, and reassembly all index by this; it must stay one
    definition or decompression silently misroutes leaves."""
    return "/".join(str(getattr(k, "key", k)) for k in path)


def _backend_for(comp_obj, be: CodecBackend) -> CodecBackend:
    """Resolve the backend that can actually decode ``comp_obj``.

    Guards the split compress/decompress API: wire payloads decode only with
    the wire backend, in-graph CompressedTensors only with a jittable one
    (xla and pallas share the stream layout, so either decodes either).  A
    mismatched ``backend=`` argument is corrected instead of crashing with
    an opaque AttributeError."""
    from repro.core.backend import WireCompressed
    if isinstance(comp_obj, WireCompressed):
        return be if be.name == "wire" else get_backend("wire")
    return be if be.jittable else get_backend("xla")


# ---------------------------------------------------------------------------
# single-process codec application over a cache pytree
# ---------------------------------------------------------------------------

def compress_cache(cache: Dict, tc: TransferConfig) -> Tuple[Dict, Dict]:
    """Returns (compressed pytree, passthrough pytree of non-bf16 leaves).

    Each bf16 leaf becomes a CompressedTensor (pytree, jit-transparent).

    ``compress_fp32`` (beyond-paper): an fp32 leaf splits into hi/lo u16
    halves; the hi half has the BF16 bit layout (sign + exp8 + mantissa7),
    so the SAME calibrated exponent codebook compresses it, while the lo
    mantissa half ships raw — lossless fp32 at ratio 32/(16/rho+16) ≈ 1.14x.
    This is what makes SplitZip useful for fp32 recurrent state transfer
    (SSM/RG-LRU caches), where the paper's bf16-only codec gives zero."""
    be = tc.get_backend()
    comp, raw = {}, {}
    flat = jax.tree_util.tree_flatten_with_path(cache)[0]
    for path, leaf in flat:
        key = leaf_key(path)
        def _cap(n):
            cap = tc.cap
            if tc.layout == "global" and cap == C.DEFAULT_CAP:
                cap = C.default_global_cap(n, tc.global_budget)
            return cap
        if leaf.dtype == jnp.bfloat16 and tc.enabled:
            comp[key] = be.encode(leaf, tc.codebook, chunk=tc.chunk,
                                  cap=_cap(leaf.size), layout=tc.layout)
        elif leaf.dtype == jnp.float32 and tc.enabled and tc.compress_fp32:
            u = jax.lax.bitcast_convert_type(leaf, jnp.uint32)
            hi = (u >> 16).astype(jnp.uint16)   # bf16-layout bits
            lo = (u & 0xFFFF).astype(jnp.uint16)
            comp[key + "#hi"] = be.encode(hi, tc.codebook, chunk=tc.chunk,
                                          cap=_cap(hi.size), layout=tc.layout)
            raw[key + "#lo"] = lo
        else:
            raw[key] = leaf
    return comp, raw


def decompress_cache(comp: Dict, raw: Dict, structure: Dict,
                     backend: str = "xla") -> Dict:
    """Inverse of compress_cache against the original pytree structure.
    Per-object backend dispatch (``_backend_for``) tolerates a ``backend=``
    argument that doesn't match what actually produced ``comp``."""
    be = get_backend(backend)
    flat, treedef = jax.tree_util.tree_flatten_with_path(structure)
    leaves = []
    for path, leaf in flat:
        key = leaf_key(path)
        if key in comp:
            ct = comp[key]
            leaves.append(_backend_for(ct, be).decode(ct).reshape(leaf.shape))
        elif key + "#hi" in comp:  # fp32 hi/lo split
            ct = comp[key + "#hi"]
            hi = _backend_for(ct, be).decode(ct).reshape(leaf.shape)
            lo = raw[key + "#lo"]
            u = (hi.astype(jnp.uint32) << 16) | lo.astype(jnp.uint32)
            leaves.append(jax.lax.bitcast_convert_type(u, jnp.float32))
        else:
            leaves.append(raw[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def compressed_wire_bytes(comp: Dict, raw: Dict,
                          backend: str = "xla") -> jax.Array:
    """Total wire bytes with the per-tensor raw fallback applied: a tensor
    whose escape capacity overflowed (``ok == False``) is charged raw bytes,
    because that is what the engine actually ships for it."""
    be = get_backend(backend)
    total = jnp.zeros((), jnp.float32)
    for ct in comp.values():
        b = _backend_for(ct, be)
        total = total + jnp.where(b.ok(ct),
                                  jnp.asarray(b.wire_bytes(ct), jnp.float32),
                                  jnp.float32(b.raw_bytes(ct)))
    for leaf in raw.values():
        total = total + leaf.size * leaf.dtype.itemsize
    return total


def raw_wire_bytes(cache: Dict) -> float:
    return float(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache)))


# ---------------------------------------------------------------------------
# cross-pod transfer (shard_map + ppermute over the 'pod' axis)
# ---------------------------------------------------------------------------

_WIRE_INT = {2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint64}


def _permute_leaf(x: jax.Array, axis_name: str, src: int, dst: int) -> jax.Array:
    """ppermute with the payload pinned to its exact bit width.

    XLA CPU (and some TPU paths) upcast bf16 collectives to f32 — doubling the
    wire bytes and silently defeating the codec.  Bitcasting to a same-width
    integer type before the collective guarantees the HLO moves exactly the
    bytes we account for; the roundtrip is a bitcast, hence lossless."""
    if jnp.issubdtype(x.dtype, jnp.floating) and x.dtype.itemsize in _WIRE_INT:
        w = _WIRE_INT[x.dtype.itemsize]
        y = jax.lax.ppermute(jax.lax.bitcast_convert_type(x, w), axis_name,
                             perm=[(src, dst)])
        return jax.lax.bitcast_convert_type(y, x.dtype)
    return jax.lax.ppermute(x, axis_name, perm=[(src, dst)])


def transfer_cache_cross_pod(
    cache: Dict,
    mesh: Mesh,
    tc: TransferConfig,
    src_pod: int = 0,
    dst_pod: int = 1,
    return_hlo: bool = False,
    specs=None,
    select_dst: bool = True,
):
    """Move a cache pytree from src_pod to dst_pod, compressed on the wire.

    Inside shard_map over the 'pod' axis: encode locally on the source pod,
    ppermute only the *compressed streams* (the collective bytes visible in
    HLO are the compressed payload), decode on the destination pod.  The
    data/model sharding of each leaf is preserved end-to-end.
    """
    if "pod" not in mesh.shape:
        raise ValueError("transfer_cache_cross_pod needs a 'pod' mesh axis")
    if not get_backend(tc.backend).jittable:
        raise ValueError(
            f"backend {tc.backend!r} is host-side and cannot run inside "
            "shard_map; use a jittable backend ('xla', 'pallas')")
    n_pod = mesh.shape["pod"]

    def leaf_spec(x):
        # cache leaves: (L, B, S, ...) — batch over data, replicated over
        # pod/model (the host-staged value; prefill pod is the logical owner)
        spec = [None] * x.ndim
        if x.ndim >= 2 and x.shape[1] % mesh.shape["data"] == 0:
            spec[1] = "data"
        return P(*spec)

    # per-leaf inner function: runs per pod-shard with pod axis bound.
    # Output gets a fresh leading 'pod' axis so each pod's post-transfer view
    # is explicit: index dst_pod holds the decoded cache, index src_pod holds
    # whatever the non-receiving pod decodes from its zero-filled streams.
    def body(*leaves_flat):
        treedef = jax.tree_util.tree_structure(cache)
        local = jax.tree_util.tree_unflatten(treedef, leaves_flat)
        comp, raw = compress_cache(local, tc)
        moved_comp = jax.tree.map(
            lambda x: _permute_leaf(x, "pod", src_pod, dst_pod), comp)
        moved_raw = jax.tree.map(
            lambda x: _permute_leaf(x, "pod", src_pod, dst_pod), raw)
        out = decompress_cache(moved_comp, moved_raw, local, backend=tc.backend)
        return tuple(x[None] for x in jax.tree.leaves(out))

    leaves = jax.tree.leaves(cache)
    if specs is not None:  # caller-provided (e.g. the sharding policy's
        in_specs = tuple(jax.tree.leaves(specs,
                                         is_leaf=lambda x: isinstance(x, P)))
    else:
        in_specs = tuple(leaf_spec(x) for x in leaves)
    out_specs = tuple(P("pod", *s) for s in in_specs)
    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=False)
    moved = fn(*leaves)
    if select_dst:
        # convenience view for eager callers (tests/examples).  Inside a jit
        # this slice forces GSPMD to bounce the DECODED cache back across the
        # pod axis — production consumers (and the dry-run) keep the cache
        # pod-resident: pass select_dst=False and read index dst_pod locally.
        moved = tuple(x[dst_pod] for x in moved)
    out = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(cache), moved)
    if return_hlo:
        # post-SPMD HLO: the collective-permute operand sizes here are the
        # actual wire bytes (compressed when tc.enabled)
        hlo = jax.jit(fn).lower(*leaves).compile().as_text()
        return out, hlo
    return out


# ---------------------------------------------------------------------------
# chunked pipelined transfer engine (paper Appendix A made concrete)
#
# The whole-tensor path above is additive: encode the entire cache, ship it,
# decode it.  The paper's headline claim is that the codec keeps up with KV
# production, so encode/transfer/decode can be OVERLAPPED: split the cache
# into n_chunks contiguous byte-range segments and drive them through
# ChunkSchedule — at step t the engine encodes chunk t, transfers chunk t-1,
# decodes chunk t-2.  Locally the stages execute in schedule order (the
# overlap is a wall-clock property of the deployment link, modeled by
# pipelined_transfer_time); what this engine makes real is the per-chunk
# data path: segmentation, per-chunk encode/ship/decode, per-chunk ok/raw
# fallback, per-chunk wire accounting, and bit-exact reassembly.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ChunkedTransferStats:
    """Per-chunk accounting emitted by ``transfer_cache_chunked``."""

    chunk_wire_bytes: List[float]   # wire bytes actually shipped per chunk
    chunk_ok: List[bool]            # escape capacity held for this chunk?
    raw_passthrough_bytes: float    # non-bf16 leaves shipped outside the pipe
    n_elements: int                 # bf16 elements routed through the pipe
    # chunks whose first encode overflowed and were re-encoded once at
    # doubled capacity (adaptive capacity; chunk_ok reflects the retry result)
    chunk_retried: List[bool] = dataclasses.field(default_factory=list)

    @property
    def wire_bytes(self) -> float:
        return sum(self.chunk_wire_bytes) + self.raw_passthrough_bytes

    @property
    def all_ok(self) -> bool:
        return all(self.chunk_ok)

    @property
    def n_retries(self) -> int:
        return sum(self.chunk_retried)


def split_cache_segments(cache: Dict, n_chunks: int, align: int
                         ) -> Tuple[List[jax.Array], List[Tuple[str, tuple]], Dict]:
    """Flatten every bf16 leaf into one u16 bit stream and cut it into at
    most ``n_chunks`` contiguous segments, each aligned to ``align`` elements
    (the codec chunk) except the last.  Returns (segments, leaf metadata for
    reassembly, raw passthrough leaves)."""
    bits_parts, metas, raw = [], [], {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
        key = leaf_key(path)
        if leaf.dtype == jnp.bfloat16:
            bits_parts.append(
                jax.lax.bitcast_convert_type(leaf, jnp.uint16).reshape(-1))
            metas.append((key, tuple(leaf.shape)))
        else:
            raw[key] = leaf
    if not bits_parts:
        return [], metas, raw
    stream = jnp.concatenate(bits_parts) if len(bits_parts) > 1 else bits_parts[0]
    n = stream.shape[0]
    per = -(-n // max(1, n_chunks))          # ceil split
    per = max(align, -(-per // align) * align)  # align up to the codec chunk
    segments = [stream[i:i + per] for i in range(0, n, per)]
    return segments, metas, raw


def _reassemble_cache(bits_out: jax.Array, metas, raw: Dict,
                      structure: Dict) -> Dict:
    """Inverse of split_cache_segments: slice the decoded bit stream back
    into leaves and restore the original pytree structure."""
    decoded, off = {}, 0
    for key, shape in metas:
        n = int(np.prod(shape)) if shape else 1
        decoded[key] = jax.lax.bitcast_convert_type(
            bits_out[off:off + n].reshape(shape), jnp.bfloat16)
        off += n
    flat, treedef = jax.tree_util.tree_flatten_with_path(structure)
    leaves = []
    for path, leaf in flat:
        key = leaf_key(path)
        leaves.append(decoded[key] if key in decoded else raw[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def transfer_cache_chunked(cache: Dict, tc: TransferConfig
                           ) -> Tuple[Dict, ChunkedTransferStats]:
    """Chunked pipelined compress → ship → decompress of a cache pytree.

    Drives ``ChunkSchedule(n).stages()``: each schedule step encodes one
    chunk, "transfers" the previous one (local mode: accounting + payload
    hand-off; the mesh path ships these same per-chunk streams), and decodes
    the one before that — straight to the shipped bit stream via
    ``decode_bits`` (the fused pallas backend emits these bits from its
    single decode kernel).  A chunk whose escape capacity overflows is
    re-encoded ONCE at doubled capacity (adaptive capacity — recovers
    heavy-tailed chunks; recorded in ``ChunkedTransferStats.chunk_retried``)
    and only then falls back to shipping its raw bits, so the reassembled
    cache is bit-identical to the input unconditionally.
    """
    be = tc.get_backend()
    segments, metas, raw = split_cache_segments(cache, tc.n_chunks, tc.chunk)
    raw_pass = float(sum(x.size * x.dtype.itemsize for x in raw.values()))
    if not segments or not tc.enabled:
        # nothing to compress (or baseline mode): every chunk ships raw bits
        stats = ChunkedTransferStats(
            chunk_wire_bytes=[float(s.shape[0] * 2) for s in segments],
            chunk_ok=[True] * len(segments),
            raw_passthrough_bytes=raw_pass,
            n_elements=int(sum(s.shape[0] for s in segments)),
            chunk_retried=[False] * len(segments))
        return cache, stats

    def _cap(n):
        cap = tc.cap
        if tc.layout == "global" and cap == C.DEFAULT_CAP:
            cap = C.default_global_cap(n, tc.global_budget)
        return cap

    n_seg = len(segments)
    encoded: Dict[int, object] = {}
    in_flight: Dict[int, object] = {}
    decoded_bits: Dict[int, jax.Array] = {}
    wire_per_chunk: List[float] = [0.0] * n_seg
    ok_per_chunk: List[bool] = [True] * n_seg
    retried_per_chunk: List[bool] = [False] * n_seg

    for enc_i, xfer_i, dec_i in ChunkSchedule(n_seg).stages():
        if 0 <= enc_i < n_seg:
            encoded[enc_i] = be.encode(
                segments[enc_i], tc.codebook, chunk=tc.chunk,
                cap=_cap(segments[enc_i].shape[0]), layout=tc.layout)
        if 0 <= xfer_i < n_seg:
            ct = encoded.pop(xfer_i)
            okx = bool(be.ok(ct))
            if not okx:
                # adaptive capacity: one re-encode at doubled cap recovers
                # the ratio on heavy-tailed chunks before the raw fallback
                # (for_retry lets a backend swap in a structure that can
                # actually use the doubled budget, e.g. fused-global pallas)
                ct2 = be.for_retry(tc.layout).encode(
                    segments[xfer_i], tc.codebook, chunk=tc.chunk,
                    cap=2 * _cap(segments[xfer_i].shape[0]), layout=tc.layout)
                retried_per_chunk[xfer_i] = True
                if bool(be.ok(ct2)):
                    ct, okx = ct2, True
            ok_per_chunk[xfer_i] = okx
            wire_per_chunk[xfer_i] = (
                float(be.wire_bytes(ct)) if okx
                else float(segments[xfer_i].shape[0] * 2))  # raw u16 fallback
            # the wire hop: compressed streams (or raw bits) leave the
            # prefill side here; in local mode this is a hand-off
            in_flight[xfer_i] = ct if okx else None
        if 0 <= dec_i < n_seg:
            ct = in_flight.pop(dec_i)
            if ct is None:  # raw fallback: the original bits were shipped
                decoded_bits[dec_i] = segments[dec_i]
            else:
                # decode straight to the bit stream the pipe ships — the
                # fused pallas path emits these bits from its single kernel
                decoded_bits[dec_i] = jnp.asarray(
                    be.decode_bits(ct)).reshape(-1)

    bits_out = jnp.concatenate([decoded_bits[i] for i in range(n_seg)]) \
        if n_seg > 1 else decoded_bits[0]
    out = _reassemble_cache(bits_out, metas, raw, cache)
    stats = ChunkedTransferStats(
        chunk_wire_bytes=wire_per_chunk, chunk_ok=ok_per_chunk,
        raw_passthrough_bytes=raw_pass,
        n_elements=int(sum(s.shape[0] for s in segments)),
        chunk_retried=retried_per_chunk)
    return out, stats


# ---------------------------------------------------------------------------
# analytic transfer report (paper Fig. 3 / Fig. 4 accounting)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TransferReport:
    raw_bytes: float
    wire_bytes: float
    t_native: float
    t_splitzip: float
    t_encode: float
    t_transfer: float
    t_decode: float

    @property
    def ratio(self) -> float:
        return self.raw_bytes / max(self.wire_bytes, 1.0)

    @property
    def speedup(self) -> float:
        return self.t_native / max(self.t_splitzip, 1e-12)


def transfer_report(raw_bytes: float, wire_bytes: float,
                    profile: CodecProfile, n_chunks: int = 1) -> TransferReport:
    """Analytic accounting from MEASURED wire bytes: additive
    encode + compressed transfer + decode (Fig. 4) when ``n_chunks == 1``,
    chunked steady-state pipeline (Appendix A: fill + (n-1)·bottleneck +
    drain) when ``n_chunks > 1`` — matching what the engine actually ran."""
    t_enc = raw_bytes / profile.g_enc
    t_dec = raw_bytes / profile.g_dec
    t_xfer = wire_bytes / profile.link_bw
    if n_chunks > 1:
        per = [t / n_chunks for t in (t_enc, t_xfer, t_dec)]
        t_total = sum(per) + (n_chunks - 1) * max(per) + profile.fixed_overhead_s
    else:
        t_total = t_enc + t_xfer + t_dec + profile.fixed_overhead_s
    return TransferReport(
        raw_bytes=raw_bytes,
        wire_bytes=wire_bytes,
        t_native=raw_bytes / profile.link_bw + profile.fixed_overhead_s,
        t_splitzip=t_total,
        t_encode=t_enc, t_transfer=t_xfer, t_decode=t_dec,
    )
