"""KV-cache transfer engine with SplitZip compression (the paper's setting).

The PD boundary on a TPU mesh: prefill workers live on pod 0, decode workers
on pod 1 of the (pod, data, model) mesh.  ``transfer_compressed`` maps the
in-graph SplitZip codec over every bf16 cache leaf, moves the *compressed
streams* across the pod axis with ``lax.ppermute`` inside ``shard_map``, and
decodes on the receiving pod.  fp32 recurrent states (SSM/RG-LRU) ship raw
(see DESIGN.md; a beyond-paper fp32 codec variant is tracked separately).

Losslessness is unconditional: each tensor's ``ok`` flag (escape-capacity
overflow) selects compressed vs raw payload per tensor, so adversarial
activation distributions degrade to raw-speed transfer, never to corruption.

Byte accounting for the roofline reads the ppermute operand sizes straight
from the lowered HLO (analysis/roofline.py); the analytic model here
(`transfer_report`) mirrors the paper's Fig. 3/4 accounting.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import codec as C
from repro.core.codebook import Codebook
from repro.core.pipeline import CodecProfile, additive_transfer_time, native_transfer_time


@dataclasses.dataclass(frozen=True)
class TransferConfig:
    codebook: Codebook
    chunk: int = C.DEFAULT_CHUNK
    cap: int = C.DEFAULT_CAP
    enabled: bool = True          # False => native raw-bytes baseline
    compress_fp32: bool = False   # beyond-paper fp32-state codec toggle
    layout: str = "chunked"       # 'chunked' (paper) | 'global' (beyond-paper)
    global_budget: float = 0.01   # escape-capacity budget for layout='global'


# ---------------------------------------------------------------------------
# single-process codec application over a cache pytree
# ---------------------------------------------------------------------------

def compress_cache(cache: Dict, tc: TransferConfig) -> Tuple[Dict, Dict]:
    """Returns (compressed pytree, passthrough pytree of non-bf16 leaves).

    Each bf16 leaf becomes a CompressedTensor (pytree, jit-transparent).

    ``compress_fp32`` (beyond-paper): an fp32 leaf splits into hi/lo u16
    halves; the hi half has the BF16 bit layout (sign + exp8 + mantissa7),
    so the SAME calibrated exponent codebook compresses it, while the lo
    mantissa half ships raw — lossless fp32 at ratio 32/(16/rho+16) ≈ 1.14x.
    This is what makes SplitZip useful for fp32 recurrent state transfer
    (SSM/RG-LRU caches), where the paper's bf16-only codec gives zero."""
    comp, raw = {}, {}
    flat = jax.tree_util.tree_flatten_with_path(cache)[0]
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", k)) for k in path)
        def _cap(n):
            cap = tc.cap
            if tc.layout == "global" and cap == C.DEFAULT_CAP:
                cap = C.default_global_cap(n, tc.global_budget)
            return cap
        if leaf.dtype == jnp.bfloat16 and tc.enabled:
            comp[key] = C.encode(leaf, tc.codebook, chunk=tc.chunk,
                                 cap=_cap(leaf.size), layout=tc.layout)
        elif leaf.dtype == jnp.float32 and tc.enabled and tc.compress_fp32:
            u = jax.lax.bitcast_convert_type(leaf, jnp.uint32)
            hi = (u >> 16).astype(jnp.uint16)   # bf16-layout bits
            lo = (u & 0xFFFF).astype(jnp.uint16)
            comp[key + "#hi"] = C.encode(hi, tc.codebook, chunk=tc.chunk,
                                         cap=_cap(hi.size), layout=tc.layout)
            raw[key + "#lo"] = lo
        else:
            raw[key] = leaf
    return comp, raw


def decompress_cache(comp: Dict, raw: Dict, structure: Dict) -> Dict:
    """Inverse of compress_cache against the original pytree structure."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(structure)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", k)) for k in path)
        if key in comp:
            leaves.append(C.decode(comp[key]).reshape(leaf.shape))
        elif key + "#hi" in comp:  # fp32 hi/lo split
            hi = C.decode(comp[key + "#hi"]).reshape(leaf.shape)
            lo = raw[key + "#lo"]
            u = (hi.astype(jnp.uint32) << 16) | lo.astype(jnp.uint32)
            leaves.append(jax.lax.bitcast_convert_type(u, jnp.float32))
        else:
            leaves.append(raw[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def compressed_wire_bytes(comp: Dict, raw: Dict) -> jax.Array:
    total = jnp.zeros((), jnp.float32)
    for ct in comp.values():
        # per-tensor fallback: raw bytes if the escape buffer overflowed
        total = total + jnp.where(C.compressed_bytes(ct) * 0 + ct.ok,
                                  C.compressed_bytes(ct),
                                  jnp.float32(C.raw_bytes(ct)))
    for leaf in raw.values():
        total = total + leaf.size * leaf.dtype.itemsize
    return total


def raw_wire_bytes(cache: Dict) -> float:
    return float(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache)))


# ---------------------------------------------------------------------------
# cross-pod transfer (shard_map + ppermute over the 'pod' axis)
# ---------------------------------------------------------------------------

_WIRE_INT = {2: jnp.uint16, 4: jnp.uint32, 8: jnp.uint64}


def _permute_leaf(x: jax.Array, axis_name: str, src: int, dst: int) -> jax.Array:
    """ppermute with the payload pinned to its exact bit width.

    XLA CPU (and some TPU paths) upcast bf16 collectives to f32 — doubling the
    wire bytes and silently defeating the codec.  Bitcasting to a same-width
    integer type before the collective guarantees the HLO moves exactly the
    bytes we account for; the roundtrip is a bitcast, hence lossless."""
    if jnp.issubdtype(x.dtype, jnp.floating) and x.dtype.itemsize in _WIRE_INT:
        w = _WIRE_INT[x.dtype.itemsize]
        y = jax.lax.ppermute(jax.lax.bitcast_convert_type(x, w), axis_name,
                             perm=[(src, dst)])
        return jax.lax.bitcast_convert_type(y, x.dtype)
    return jax.lax.ppermute(x, axis_name, perm=[(src, dst)])


def transfer_cache_cross_pod(
    cache: Dict,
    mesh: Mesh,
    tc: TransferConfig,
    src_pod: int = 0,
    dst_pod: int = 1,
    return_hlo: bool = False,
    specs=None,
    select_dst: bool = True,
):
    """Move a cache pytree from src_pod to dst_pod, compressed on the wire.

    Inside shard_map over the 'pod' axis: encode locally on the source pod,
    ppermute only the *compressed streams* (the collective bytes visible in
    HLO are the compressed payload), decode on the destination pod.  The
    data/model sharding of each leaf is preserved end-to-end.
    """
    if "pod" not in mesh.shape:
        raise ValueError("transfer_cache_cross_pod needs a 'pod' mesh axis")
    n_pod = mesh.shape["pod"]

    def leaf_spec(x):
        # cache leaves: (L, B, S, ...) — batch over data, replicated over
        # pod/model (the host-staged value; prefill pod is the logical owner)
        spec = [None] * x.ndim
        if x.ndim >= 2 and x.shape[1] % mesh.shape["data"] == 0:
            spec[1] = "data"
        return P(*spec)

    # per-leaf inner function: runs per pod-shard with pod axis bound.
    # Output gets a fresh leading 'pod' axis so each pod's post-transfer view
    # is explicit: index dst_pod holds the decoded cache, index src_pod holds
    # whatever the non-receiving pod decodes from its zero-filled streams.
    def body(*leaves_flat):
        treedef = jax.tree_util.tree_structure(cache)
        local = jax.tree_util.tree_unflatten(treedef, leaves_flat)
        comp, raw = compress_cache(local, tc)
        moved_comp = jax.tree.map(
            lambda x: _permute_leaf(x, "pod", src_pod, dst_pod), comp)
        moved_raw = jax.tree.map(
            lambda x: _permute_leaf(x, "pod", src_pod, dst_pod), raw)
        out = decompress_cache(moved_comp, moved_raw, local)
        return tuple(x[None] for x in jax.tree.leaves(out))

    leaves = jax.tree.leaves(cache)
    if specs is not None:  # caller-provided (e.g. the sharding policy's
        in_specs = tuple(jax.tree.leaves(specs,
                                         is_leaf=lambda x: isinstance(x, P)))
    else:
        in_specs = tuple(leaf_spec(x) for x in leaves)
    out_specs = tuple(P("pod", *s) for s in in_specs)
    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_vma=False)
    moved = fn(*leaves)
    if select_dst:
        # convenience view for eager callers (tests/examples).  Inside a jit
        # this slice forces GSPMD to bounce the DECODED cache back across the
        # pod axis — production consumers (and the dry-run) keep the cache
        # pod-resident: pass select_dst=False and read index dst_pod locally.
        moved = tuple(x[dst_pod] for x in moved)
    out = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(cache), moved)
    if return_hlo:
        # post-SPMD HLO: the collective-permute operand sizes here are the
        # actual wire bytes (compressed when tc.enabled)
        hlo = jax.jit(fn).lower(*leaves).compile().as_text()
        return out, hlo
    return out


# ---------------------------------------------------------------------------
# analytic transfer report (paper Fig. 3 / Fig. 4 accounting)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TransferReport:
    raw_bytes: float
    wire_bytes: float
    t_native: float
    t_splitzip: float
    t_encode: float
    t_transfer: float
    t_decode: float

    @property
    def ratio(self) -> float:
        return self.raw_bytes / max(self.wire_bytes, 1.0)

    @property
    def speedup(self) -> float:
        return self.t_native / max(self.t_splitzip, 1e-12)


def transfer_report(raw_bytes: float, wire_bytes: float,
                    profile: CodecProfile) -> TransferReport:
    """Additive accounting: encode + compressed transfer + decode (Fig. 4)."""
    t_enc = raw_bytes / profile.g_enc
    t_dec = raw_bytes / profile.g_dec
    t_xfer = wire_bytes / profile.link_bw
    return TransferReport(
        raw_bytes=raw_bytes,
        wire_bytes=wire_bytes,
        t_native=raw_bytes / profile.link_bw + profile.fixed_overhead_s,
        t_splitzip=t_enc + t_xfer + t_dec + profile.fixed_overhead_s,
        t_encode=t_enc, t_transfer=t_xfer, t_decode=t_dec,
    )
