"""Decode worker: consumes a (transferred) cache and generates tokens.

``decode_loop`` runs N greedy steps with ``lax.scan`` so the whole generation
is one XLA program; ``serve_step`` is the single-token unit the dry-run
lowers for the decode_* shape cells.
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.models.kvcache import DecodeState


def serve_step(params, tokens: jax.Array, state: DecodeState, cfg: ArchConfig
               ) -> Tuple[jax.Array, DecodeState]:
    """One decode step: (B, 1) tokens -> ((B, V) logits, new state).
    This is the function the decode-shape dry-run cells lower."""
    return M.decode_step(params, tokens, state, cfg)


def decode_loop(params, first_token: jax.Array, state: DecodeState,
                cfg: ArchConfig, num_steps: int) -> Tuple[jax.Array, DecodeState]:
    """Greedy generation of ``num_steps`` tokens as a single scan program."""

    def step(carry, _):
        tok, st = carry
        logits, st = M.decode_step(params, tok[:, None], st, cfg)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (nxt, st), nxt

    (_, final_state), toks = jax.lax.scan(
        step, (first_token, state), None, length=num_steps)
    return toks.T, final_state  # (B, num_steps)


def make_decode_fn(cfg: ArchConfig, num_steps: int):
    @jax.jit
    def fn(params, first_token, state):
        return decode_loop(params, first_token, state, cfg, num_steps)
    return fn
