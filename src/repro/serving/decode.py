"""Decode worker: consumes a (transferred) cache and generates tokens.

``decode_loop`` runs N greedy steps with ``lax.scan`` so the whole generation
is one XLA program; ``serve_step`` is the single-token unit the dry-run
lowers for the decode_* shape cells.
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model as M
from repro.models.kvcache import DecodeState


def serve_step(params, tokens: jax.Array, state: DecodeState, cfg: ArchConfig
               ) -> Tuple[jax.Array, DecodeState]:
    """One decode step: (B, 1) tokens -> ((B, V) logits, new state).
    This is the function the decode-shape dry-run cells lower."""
    return M.decode_step(params, tokens, state, cfg)


def decode_loop(params, first_token: jax.Array, state: DecodeState,
                cfg: ArchConfig, num_steps: int) -> Tuple[jax.Array, DecodeState]:
    """Greedy generation of ``num_steps`` tokens as a single scan program."""

    def step(carry, _):
        tok, st = carry
        logits, st = M.decode_step(params, tok[:, None], st, cfg)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (nxt, st), nxt

    (_, final_state), toks = jax.lax.scan(
        step, (first_token, state), None, length=num_steps)
    return toks.T, final_state  # (B, num_steps)


def make_decode_fn(cfg: ArchConfig, num_steps: int):
    @jax.jit
    def fn(params, first_token, state):
        return decode_loop(params, first_token, state, cfg, num_steps)
    return fn


def resident_decode_loop(params, first_token: jax.Array, state, pool,
                         cfg: ArchConfig, num_steps: int, *,
                         interpret: bool = True):
    """Greedy generation over a compressed-resident cache.

    A Python loop of one reused jitted step (page tables and tails are
    fixed-shape, so every step hits the same executable) with a host-side
    tail recompression between steps: rows whose raw tail page filled are
    flushed into fresh compressed pages through the registered backend
    (``KVPool.flush_full_tails``).  The jitted step itself never touches the
    codec — the fused kernel decodes pages in-register.

    Escape overflow or pool exhaustion during a flush demotes the WHOLE
    batch: the pool rehydrates (bit-exact) to a raw ``DecodeState`` and the
    remaining steps run the classic decode loop.  Returns ``(tokens (B, N),
    final_state, demoted)``."""
    from repro.models.kvpool import ResidencyError

    @jax.jit
    def step_fn(p, tok, st):
        return M.resident_decode_step(p, tok, st, cfg, interpret=interpret)

    tok = first_token
    toks = []
    st = state
    for i in range(num_steps):
        logits, st = step_fn(params, tok[:, None], st)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks.append(tok)
        try:
            st = pool.flush_full_tails(st)
        except ResidencyError:
            cache = pool.rehydrate(st)
            dst = DecodeState(cache=cache, cache_len=st.cache_len)
            remaining = num_steps - (i + 1)
            if remaining:
                rest, dst = decode_loop(params, tok, dst, cfg, remaining)
                toks.extend(rest[:, j] for j in range(rest.shape[1]))
            return jnp.stack(toks, axis=1), dst, True
    return jnp.stack(toks, axis=1), st, False
