"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms per (arch × shape × mesh), TPU v5e constants:

    compute    = HLO_FLOPs_global    / (chips × 197e12 FLOP/s)
    memory     = HLO_bytes_global    / (chips × 819e9  B/s)
    collective = collective_bytes    / (chips × 50e9   B/s per link)

``cost_analysis()`` on an SPMD executable reports the PER-DEVICE partitioned
module; we scale by chip count for the global numbers (verified in
tests/test_roofline.py against an analytic matmul).  collective_bytes comes
from parsing the post-partitioning HLO: summing operand bytes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import numpy as np

# TPU v5e (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "tuple": 0, "token": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RX = re.compile(r"\b([a-z]+[0-9]*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind from post-SPMD HLO text.

    Heuristic per op kind: all-reduce/collective-permute/all-to-all move the
    operand (== result) size; all-gather's operand is the smallest shape on
    the line; reduce-scatter's operand is the largest."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.search(r"=\s*\S*\s*(" + "|".join(_COLLECTIVES) + r")(-start|-done)?\(", ls)
        if not m:
            continue
        kind = m.group(1)
        if m.group(2) == "-done":
            continue  # avoid double counting async pairs
        shapes = _SHAPE_RX.findall(ls)
        if not shapes:
            continue
        sizes = [_shape_bytes(dt, dims) for dt, dims in shapes]
        result = sizes[0]
        operands = sizes[1:] or sizes[:1]
        if kind == "all-gather":
            moved = min(operands + [result])
        elif kind == "reduce-scatter":
            moved = max(operands + [result])
        else:
            moved = result
        out[kind] += moved
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_global: float
    bytes_global: float
    collective_bytes_per_chip: float
    collectives_detail: Dict[str, int]
    model_flops: float
    peak_memory_bytes_per_chip: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.flops_global / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.bytes_global / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        return self.model_flops / max(self.flops_global, 1.0)

    @property
    def roofline_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """compute-term / bound: 1.0 == perfectly compute-bound (ideal)."""
        return self.t_compute / max(self.roofline_time, 1e-30)

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips, "flops_global": self.flops_global,
            "bytes_global": self.bytes_global,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "collectives_detail": self.collectives_detail,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "peak_memory_bytes_per_chip": self.peak_memory_bytes_per_chip,
        }


def model_flops_estimate(cfg, shape) -> float:
    """6·N·D (dense) or 6·N_active·D for training; 2·N·D per generated/
    prefilled token for inference (decode: one token per sequence)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one new token per sequence (+ attention over the cache, which
    # 2·N·D does not count — that's fine, this is the "useful" floor)
    return 2.0 * n * shape.global_batch


def build_report(arch: str, shape_cfg, mesh_desc: str, chips: int,
                 cost: Dict, hlo_text: str, cfg,
                 memory_stats: Optional[Dict] = None,
                 colls: Optional[Dict[str, float]] = None) -> RooflineReport:
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    if colls is None:
        colls = collective_bytes_from_hlo(hlo_text)
    coll_per_chip = float(sum(colls.values()))
    return RooflineReport(
        arch=arch, shape=shape_cfg.name, mesh=mesh_desc, chips=chips,
        flops_global=flops_dev * chips,
        bytes_global=bytes_dev * chips,
        collective_bytes_per_chip=coll_per_chip,
        collectives_detail=colls,
        model_flops=model_flops_estimate(cfg, shape_cfg),
        peak_memory_bytes_per_chip=(memory_stats or {}).get("peak_bytes"),
    )
