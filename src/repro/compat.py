"""Cross-version jax compatibility shims.

The repo targets a range of jax releases: on recent jax ``shard_map`` is a
top-level export (``jax.shard_map``) whose replication check is spelled
``check_vma``; on older releases it lives in ``jax.experimental.shard_map``
and the same knob is spelled ``check_rep``.  Everything in this repo that
needs ``shard_map`` imports it from here and always writes the modern
``check_vma=...`` spelling; the shim maps it to whatever the installed jax
understands.
"""

from __future__ import annotations

import inspect

try:  # jax >= 0.6: top-level export
    from jax import shard_map as _shard_map
except ImportError:  # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

_SHARD_MAP_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, **kwargs):
    """``jax.shard_map`` with the ``check_vma``/``check_rep`` rename papered
    over (pass either; the installed jax receives the one it knows)."""
    for new, old in (("check_vma", "check_rep"),):
        if new in kwargs and new not in _SHARD_MAP_PARAMS:
            kwargs[old] = kwargs.pop(new)
        elif old in kwargs and old not in _SHARD_MAP_PARAMS:
            kwargs[new] = kwargs.pop(old)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
