"""Offline calibration of SplitZip exponent codebooks (paper §3.3).

Calibration extracts all exponent values from representative tensors, counts
their frequencies, selects the top-K exponents, and builds three tables:

* ``encode_table``  — raw exponent value (0..2**ebits-1) -> K-bit code, with
  escapes marked (membership folded in: code is only valid where
  ``member_table`` is True).
* ``decode_table``  — K-bit code -> raw exponent value.
* ``member_table``  — raw exponent value -> bool (is it in the codebook?).

The codebook is a frozen, hashable dataclass so it can be closed over by
``jax.jit``-ed functions as a static argument or baked in as constants.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Iterable, Sequence

import numpy as np

# Number formats SplitZip understands.  ``ebits``/``mbits`` exclude the sign.
FORMATS = {
    "bf16": dict(bits=16, ebits=8, mbits=7, npdtype=np.uint16),
    "fp8_e5m2": dict(bits=8, ebits=5, mbits=2, npdtype=np.uint8),
    "fp8_e4m3": dict(bits=8, ebits=4, mbits=3, npdtype=np.uint8),
}


def _spec(fmt: str) -> dict:
    if fmt not in FORMATS:
        raise ValueError(f"unknown format {fmt!r}; expected one of {sorted(FORMATS)}")
    return FORMATS[fmt]


def extract_exponents(bits: np.ndarray, fmt: str = "bf16") -> np.ndarray:
    """Raw-bit tensor -> exponent field (paper §3.2 `e_i = (x>>7)&0xff`)."""
    s = _spec(fmt)
    bits = np.asarray(bits).view(s["npdtype"]).ravel()
    return ((bits >> s["mbits"]) & ((1 << s["ebits"]) - 1)).astype(np.int32)


def extract_sign_mantissa(bits: np.ndarray, fmt: str = "bf16") -> np.ndarray:
    """Raw-bit tensor -> exact sign+mantissa byte (`a_i` in the paper)."""
    s = _spec(fmt)
    bits = np.asarray(bits).view(s["npdtype"]).ravel()
    sign_shift = s["ebits"]  # sign sits above the exponent field
    sign = (bits >> sign_shift) & (1 << s["mbits"])  # sign moved to bit mbits
    # Pack sign into the bit right above the mantissa so a_i fits mbits+1 bits.
    return (sign | (bits & ((1 << s["mbits"]) - 1))).astype(np.uint8)


def reassemble(sign_mantissa: np.ndarray, exponents: np.ndarray, fmt: str = "bf16") -> np.ndarray:
    """Inverse of (extract_sign_mantissa, extract_exponents): bit-exact."""
    s = _spec(fmt)
    a = sign_mantissa.astype(np.uint32)
    e = exponents.astype(np.uint32)
    mant_mask = (1 << s["mbits"]) - 1
    sign = (a >> s["mbits"]) & 1
    out = (sign << (s["bits"] - 1)) | (e << s["mbits"]) | (a & mant_mask)
    return out.astype(s["npdtype"])


def exponent_histogram(bits: np.ndarray, fmt: str = "bf16") -> np.ndarray:
    """Counts over the full exponent range (2**ebits bins)."""
    s = _spec(fmt)
    e = extract_exponents(bits, fmt)
    return np.bincount(e, minlength=1 << s["ebits"]).astype(np.int64)


def exponent_entropy(hist: np.ndarray) -> float:
    """Shannon entropy (bits) of an exponent histogram (paper Table 1)."""
    total = hist.sum()
    if total == 0:
        return 0.0
    p = hist[hist > 0] / total
    return float(-(p * np.log2(p)).sum())


def topk_coverage(hist: np.ndarray, k: int) -> float:
    """Fraction of mass covered by the k most frequent exponents."""
    total = hist.sum()
    if total == 0:
        return 1.0
    return float(np.sort(hist)[::-1][:k].sum() / total)


@dataclasses.dataclass(frozen=True)
class Codebook:
    """A calibrated top-K exponent codebook (paper §3.3).

    ``exponents`` is the tuple of the K most frequent exponent values, in
    descending frequency order; code ``j`` decodes to ``exponents[j]``.
    """

    fmt: str
    exponents: tuple  # length K, each in [0, 2**ebits)

    # -- derived sizes ------------------------------------------------------
    @property
    def k(self) -> int:
        return len(self.exponents)

    @property
    def code_bits(self) -> int:
        return max(1, int(np.ceil(np.log2(max(2, self.k)))))

    @property
    def ebits(self) -> int:
        return _spec(self.fmt)["ebits"]

    @property
    def mbits(self) -> int:
        return _spec(self.fmt)["mbits"]

    @property
    def container_bits(self) -> int:
        return _spec(self.fmt)["bits"]

    # -- tables --------------------------------------------------------------
    def encode_table(self) -> np.ndarray:
        """exponent value -> code (escapes get code 0, the dummy code)."""
        table = np.zeros(1 << self.ebits, dtype=np.int32)
        for code, e in enumerate(self.exponents):
            table[e] = code
        return table

    def member_table(self) -> np.ndarray:
        table = np.zeros(1 << self.ebits, dtype=bool)
        for e in self.exponents:
            table[e] = True
        return table

    def decode_table(self) -> np.ndarray:
        """code -> exponent value, padded to 2**code_bits entries."""
        table = np.zeros(1 << self.code_bits, dtype=np.int32)
        for code, e in enumerate(self.exponents):
            table[code] = e
        return table

    # -- persistence ----------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({"fmt": self.fmt, "exponents": list(map(int, self.exponents))})

    @staticmethod
    def from_json(s: str) -> "Codebook":
        d = json.loads(s)
        return Codebook(fmt=d["fmt"], exponents=tuple(d["exponents"]))


# The uncalibrated bf16 fallback: the 16-exponent normal-activation band
# below the bias.  Every consumer that needs a codebook before (or without)
# a calibration pass — serve/dryrun launchers, the scheduler's analytic
# bucket plans, gradient compression — must share THIS object so the default
# band can never silently diverge between them.
DEFAULT_BF16_CODEBOOK = Codebook(fmt="bf16", exponents=tuple(range(112, 128)))


def calibrate(
    tensors: Iterable[np.ndarray],
    k: int = 16,
    fmt: str = "bf16",
    ensure_zero: bool = True,
) -> Codebook:
    """One-time offline calibration (paper §3.3).

    ``tensors`` are raw-bit views (u16 for bf16, u8 for fp8) or arrays whose
    byte view matches the format; all exponents are pooled into one histogram
    and the top-``k`` most frequent exponents become the codebook.

    ``ensure_zero`` guarantees exponent 0 is in the codebook even when absent
    from the calibration sample: production caches carry structural zeros
    (padded slots, masked positions) whose exponent field is 0, and an
    uncovered zero-run explodes the escape rate.  (A deployment detail the
    paper doesn't discuss; costs at most the k-th most frequent exponent.)
    """
    s = _spec(fmt)
    hist = np.zeros(1 << s["ebits"], dtype=np.int64)
    for t in tensors:
        hist += exponent_histogram(t, fmt)
    return codebook_from_histogram(hist, k=k, fmt=fmt, ensure_zero=ensure_zero)


def codebook_from_histogram(hist: np.ndarray, k: int = 16, fmt: str = "bf16",
                            ensure_zero: bool = True) -> Codebook:
    order = np.argsort(hist, kind="stable")[::-1]  # descending frequency
    top = [int(e) for e in order[:k]]
    if ensure_zero and 0 not in top:
        top[-1] = 0
    return Codebook(fmt=fmt, exponents=tuple(top))


def coverage(cb: Codebook, bits: np.ndarray) -> float:
    """Fraction of elements of ``bits`` whose exponent is in the codebook."""
    e = extract_exponents(bits, cb.fmt)
    return float(cb.member_table()[e].mean()) if e.size else 1.0


def escape_rate(cb: Codebook, bits: np.ndarray) -> float:
    return 1.0 - coverage(cb, bits)


def calibrate_per_axis(
    tensor_bits: np.ndarray,
    axis: int,
    k: int = 16,
    fmt: str = "bf16",
) -> list:
    """Fine-grained calibration for the paper's granularity ablation (§4.3.3).

    Returns one Codebook per slice along ``axis`` (per-token or per-channel).
    Deliberately slow — the ablation's point is that this loses orders of
    magnitude of throughput for ~0.06% coverage gain.
    """
    tensor_bits = np.asarray(tensor_bits)
    n = tensor_bits.shape[axis]
    books = []
    for i in range(n):
        sl = np.take(tensor_bits, i, axis=axis)
        books.append(calibrate([sl], k=k, fmt=fmt))
    return books
