"""Pluggable SplitZip codec backends (ZipServ-style hardware-aware dispatch).

One logical codec, several physical implementations.  Every serving-path
consumer (transfer engine, ``DisaggregatedEngine``, cross-pod transfer,
benchmarks, examples) selects its implementation through this registry via
``TransferConfig.backend`` instead of importing a codec module directly, so
adding a real GPU/TPU backend later is a registration, not a refactor.

Built-in backends:

  xla     : the pure-jnp reference codec (:mod:`repro.core.codec`) — jittable,
            shardable, runs anywhere XLA runs.
  pallas  : the single-pass fused Pallas kernels (:mod:`repro.kernels.ops`):
            one ``pallas_call`` per encode/decode, escape compaction and
            sparse correction fused in-kernel.  ``PallasBackend(fused=False)``
            selects the pre-fusion two-stage structure (dense kernel + XLA
            escape passes, :mod:`repro.kernels.twostage`) for A/B runs.
            Compiles to Mosaic on TPU; runs in ``interpret=True`` mode on
            CPU, which is how parity is validated in this container.
  wire    : the host numpy codec (:mod:`repro.core.wire`) — true
            variable-length byte serialization.  Not jittable (host-side
            bytes), but unconditionally lossless: the wire format has no
            escape-capacity limit, so ``ok`` is always True.
  auto    : hardware dispatch (ROADMAP "real multi-backend dispatch"):
            resolves to ``pallas`` when ``jax.default_backend() == "tpu"``,
            else ``xla``.  The default for examples and launchers.

Interface contract: ``encode`` returns an opaque per-backend compressed
object; ``decode`` inverts it bit-exactly; ``decode_bits`` yields the flat
container bit stream without the reshape/bitcast tail (what the chunked
transfer engine ships); ``ok``/``wire_bytes``/``raw_bytes`` give the
transfer engine a uniform view for the per-tensor raw-fallback accounting
(``jnp.where(ok, wire_bytes, raw_bytes)``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec as C
from repro.core import wire as W
from repro.core.codebook import FORMATS, Codebook


class CodecBackend:
    """Abstract codec backend.  Subclasses set ``name`` and ``jittable``."""

    name: str = "abstract"
    #: True when encode/decode are traceable (usable inside jit / shard_map).
    jittable: bool = False

    def encode(self, x: jax.Array, codebook: Codebook, *,
               chunk: int = C.DEFAULT_CHUNK, cap: int = C.DEFAULT_CAP,
               layout: str = "chunked") -> Any:
        raise NotImplementedError

    def decode(self, comp: Any) -> jax.Array:
        raise NotImplementedError

    def decode_bits(self, comp: Any) -> jax.Array:
        """Decode to the flat container bit stream (u16/u8, n_elements long).

        The chunked transfer engine consumes bit streams, not shaped floats;
        backends that can stop before the reshape + bitcast tail override
        this.  The fallback re-bitcasts the decoded tensor (free in-graph)."""
        decoded = self.decode(comp)
        return C.to_bits(jnp.asarray(decoded), comp.fmt).reshape(-1)

    def ok(self, comp: Any):
        """Did the compressed form stay within capacity (lossless as-is)?"""
        raise NotImplementedError

    def wire_bytes(self, comp: Any):
        """Exact variable-length wire bytes for this tensor (when ok)."""
        raise NotImplementedError

    def raw_bytes(self, comp: Any) -> float:
        """Uncompressed bytes of the original tensor (the fallback cost)."""
        raise NotImplementedError

    def checksum(self, comp: Any) -> int:
        """Fletcher-32 integrity tag over the compressed object's host bytes.

        This is the cheap per-chunk checksum the fault-tolerance layer
        (:mod:`repro.serving.faults`) frames wire payloads with: computed by
        the sender after encode, recomputed by the receiver before decode,
        a mismatch routes the chunk through the retry machinery instead of
        silently decoding garbage.  Leaf order is the pytree order, so the
        tag is deterministic for a given compressed object."""
        leaves = jax.tree_util.tree_leaves(comp)
        return W.fletcher32(b"".join(
            np.ascontiguousarray(np.asarray(leaf)).tobytes()
            for leaf in leaves))

    def for_retry(self, layout: str) -> "CodecBackend":
        """Backend for the adaptive-capacity re-encode of an overflowed chunk.

        Default: the backend itself (growing ``cap`` is enough).  Backends
        whose capacity is bounded by something other than ``cap`` override
        this to hand the retry to a structure that can actually use the
        grown budget."""
        return self

    def capacity_schedule(self, layout: str, cap: int, n: int, *,
                          doublings: int = 2, global_budget: float = 0.05
                          ) -> Tuple[Tuple["CodecBackend", str, int], ...]:
        """Plan-time geometric retry schedule for one tensor/chunk of ``n``
        elements: ``(backend, layout, cap)`` attempts, tried in order until
        one encode's ``ok`` holds; exhaustion means the raw fallback.

        The default is ``cap -> 2*cap -> 4*cap -> layout='global'``: two
        doublings of the level-0 capacity, then a last-resort switch to the
        global layout whose single escape pool (sized by ``global_budget``)
        absorbs heavy-tailed chunks that per-chunk buffers cannot.  Each step
        routes through :meth:`for_retry` so a backend whose capacity is bound
        elsewhere (e.g. the fused kernel's per-chunk buffer) swaps in a
        structure that can actually use the grown budget.

        ``doublings=0`` disables retries entirely (single base attempt, no
        global last resort) — for callers that want fail-fast-to-raw
        latency bounds on the hot path."""
        steps = [(self, layout, cap)]
        if doublings <= 0:
            return tuple(steps)
        be, c = self, cap
        for _ in range(doublings):
            c *= 2
            be = be.for_retry(layout)
            steps.append((be, layout, c))
        gcap = max(C.default_global_cap(n, global_budget), 2 * c)
        steps.append((be.for_retry("global"), "global", gcap))
        return tuple(steps)


class _InGraphBackend(CodecBackend):
    """Shared accounting for backends producing ``CompressedTensor`` pytrees."""

    jittable = True

    def ok(self, comp: C.CompressedTensor):
        return comp.ok

    def wire_bytes(self, comp: C.CompressedTensor):
        return C.compressed_bytes(comp)

    def raw_bytes(self, comp: C.CompressedTensor) -> float:
        return C.raw_bytes(comp)


class XlaBackend(_InGraphBackend):
    """Pure-jnp reference codec: broadcast-compare encode, one-hot decode."""

    name = "xla"

    def encode(self, x, codebook, *, chunk=C.DEFAULT_CHUNK, cap=C.DEFAULT_CAP,
               layout="chunked"):
        return C.encode(x, codebook, chunk=chunk, cap=cap, layout=layout)

    def decode(self, comp):
        return C.decode(comp)

    def decode_bits(self, comp):
        return C.decode_to_bits(comp)


class PallasBackend(_InGraphBackend):
    """Single-pass fused Pallas kernels (interpret mode off-TPU).

    ``fused=True`` (default): one ``pallas_call`` per encode/decode with
    in-kernel escape compaction / sparse correction.  ``fused=False``: the
    pre-fusion two-stage structure (dense kernel + XLA escape passes), kept
    for A/B benchmarking — same stream layout, bit-identical output.
    """

    name = "pallas"

    def __init__(self, interpret: bool | None = None, fused: bool = True):
        # None => auto: compiled on TPU, interpreted elsewhere (kernels/ops.py)
        self.interpret = interpret
        self.fused = fused

    def encode(self, x, codebook, *, chunk=C.DEFAULT_CHUNK, cap=C.DEFAULT_CAP,
               layout="chunked"):
        from repro.kernels import ops as kops
        return kops.encode(x, codebook, chunk=chunk, cap=cap, layout=layout,
                           interpret=self.interpret, fused=self.fused)

    def decode(self, comp):
        from repro.kernels import ops as kops
        return kops.decode(comp, interpret=self.interpret, fused=self.fused)

    def decode_bits(self, comp):
        from repro.kernels import ops as kops
        return kops.decode_bits(comp, interpret=self.interpret,
                                fused=self.fused)

    def for_retry(self, layout):
        if layout == "global" and self.fused:
            # A level-1 (per-chunk kernel buffer) overflow cannot be cleared
            # by doubling the TOTAL cap — the fused kernel pins its per-chunk
            # cap at MAX_FUSED_CAP.  Retry through the two-stage structure,
            # which compacts globally with no level-1 bound; the stream
            # layout is identical, so either path decodes the result.
            return PallasBackend(interpret=self.interpret, fused=False)
        return self


@dataclasses.dataclass(frozen=True)
class WireCompressed:
    """Host-side compressed tensor: the true variable-length byte payload."""

    payload: bytes
    shape: tuple
    dtype: str
    fmt: str
    stats: W.WireStats


class WireBackend(CodecBackend):
    """Host numpy wire codec — byte-exact serialization, no capacity limit.

    ``verify=True`` checks every payload's integrity-frame table before
    decoding (``repro.core.wire.decode(verify=True)``), raising
    :class:`~repro.core.wire.WireIntegrityError` on corruption.  The verify
    pass is one linear Fletcher-32 sweep; its cost is pinned as the
    ``wire_verify`` row in ``BENCH_codec.json``."""

    name = "wire"
    jittable = False

    def __init__(self, verify: bool = False):
        self.verify = verify

    def encode(self, x, codebook, *, chunk=C.DEFAULT_CHUNK, cap=C.DEFAULT_CAP,
               layout="chunked"):
        # cap/layout are in-graph concerns: the wire format's escape arrays
        # are exactly M entries, so capacity never applies.
        fmt = codebook.fmt
        bits = np.asarray(C.to_bits(jnp.asarray(x), fmt)).ravel()
        payload, stats = W.encode(bits, codebook, chunk=chunk)
        return WireCompressed(payload=payload, shape=tuple(np.shape(x)),
                              dtype=str(jnp.asarray(x).dtype), fmt=fmt,
                              stats=stats)

    def decode(self, comp: WireCompressed) -> jax.Array:
        bits = jnp.asarray(W.decode(comp.payload, verify=self.verify)
                           ).reshape(comp.shape)
        return C.from_bits(bits, jnp.dtype(comp.dtype))

    def checksum(self, comp: WireCompressed) -> int:
        return W.fletcher32(comp.payload)

    def ok(self, comp: WireCompressed) -> bool:
        return True  # variable-length format: unconditionally lossless

    def wire_bytes(self, comp: WireCompressed) -> float:
        return float(comp.stats.payload_bytes)

    def raw_bytes(self, comp: WireCompressed) -> float:
        n = int(np.prod(comp.shape)) if comp.shape else 1
        return n * FORMATS[comp.fmt]["bits"] / 8.0


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], CodecBackend]] = {}
_INSTANCES: Dict[str, CodecBackend] = {}


def register_backend(name: str, factory: Callable[[], CodecBackend]) -> None:
    """Register a codec backend under ``name`` (later wins, instances reset)."""
    _REGISTRY[name] = factory
    _INSTANCES.pop(name, None)


def get_backend(name: str) -> CodecBackend:
    """Resolve a backend name to its (cached) instance."""
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown codec backend {name!r}; available: {available_backends()}")
    if name not in _INSTANCES:
        _INSTANCES[name] = _REGISTRY[name]()
    return _INSTANCES[name]


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_backend(name: str, *, require_jittable: bool = False) -> CodecBackend:
    """Plan-time backend resolution: one registry lookup per
    :class:`~repro.serving.plan.TransferPlan` build instead of one per
    transfer call.  ``require_jittable`` rejects host-side backends up front
    (mesh execution traces the codec inside ``shard_map``)."""
    be = get_backend(name)
    if require_jittable and not be.jittable:
        raise ValueError(
            f"backend {name!r} is host-side and cannot run inside "
            "shard_map; use a jittable backend ('xla', 'pallas')")
    return be


def _auto_backend() -> CodecBackend:
    """Hardware dispatch: fused Pallas kernels on TPU, XLA reference elsewhere.

    Resolved (and cached) at first ``get_backend("auto")`` call — the JAX
    default backend is fixed per process, so the resolution is stable.  A GPU
    (Triton/CUDA) backend would slot in here via ``register_backend``.
    """
    return PallasBackend() if jax.default_backend() == "tpu" else XlaBackend()


register_backend("xla", XlaBackend)
register_backend("pallas", PallasBackend)
register_backend("wire", WireBackend)
# integrity-checking wire decode: every payload's frame table is verified
# before the body is parsed (WireIntegrityError on corruption)
register_backend("wire-verify", lambda: WireBackend(verify=True))
register_backend("auto", _auto_backend)
