"""Calibrated codec profiles: measure the real codec, serialize, reload.

The scheduler's end-to-end numbers (Fig. 2 TTFT / request-throughput
speedups) are only as good as the :class:`~repro.core.pipeline.CodecProfile`
they are charged with.  Until ISSUE 5 those profiles were hand-entered paper
constants (H200 datasheet numbers copied into every launcher); ZipServ
(arXiv 2603.17435) makes the obvious counter-argument — calibrate the cost
model from *measured* codec throughput on the deployment's actual hardware
and the what-if sweeps start tracking reality.

This module is that calibration subsystem:

* :meth:`CalibratedProfile.measure` runs the REAL codec — the same
  backend-registry dispatch (:mod:`repro.core.backend`) the serving path
  uses — over a synthetic KV-shaped workload and records encode/decode
  throughput plus the achieved compression ratio, with provenance
  (backend, format, workload size, repeats).
* :func:`save_profiles` / :func:`load_profiles` serialize a set of
  calibrated profiles to JSON (``benchmarks/results/profiles.json`` by
  convention; ``benchmarks/table2_codec_throughput.py`` writes one on every
  run, including CI smoke mode).
* :func:`resolve_profile` is the single entry point launchers and
  benchmarks use to turn a profile *source* (``"paper"``, ``"measured"``,
  or a ``profiles.json`` path) plus a link bandwidth into a concrete
  :class:`CodecProfile`.  The paper's datasheet constants live HERE and
  nowhere else — ``src/repro/serving`` and ``src/repro/launch`` are kept
  free of hard-coded throughput numbers by a CI grep guard.

Example — calibrate once, drive the scheduler from the measurement::

    from repro.core.profile import CalibratedProfile, resolve_profile

    cal = CalibratedProfile.measure(backend="xla")       # runs the codec
    save_profiles([cal], "benchmarks/results/profiles.json")
    ...
    prof = resolve_profile("benchmarks/results/profiles.json",
                           link_bw=50e9, backend="xla")
    cfg = SchedulerConfig(profile=prof, ...)
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, Iterable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backend import get_backend
from repro.core.codebook import Codebook, calibrate
from repro.core.pipeline import CodecProfile

# ---------------------------------------------------------------------------
# the paper's datasheet constants — the ONE place they are allowed to live
# ---------------------------------------------------------------------------

#: Paper §4.1 measured H200 codec throughput (bytes/s vs uncompressed bytes).
PAPER_G_ENC = 613.3e9
#: Paper §4.1 measured H200 decompression throughput.
PAPER_G_DEC = 2181.8e9
#: Paper Table 2 compression ratio on Qwen3-32B KV caches.
PAPER_RATIO = 1.324

#: Default on-disk location for calibrated profiles, relative to the repo
#: root (launchers are documented to run from there); override with the
#: ``SPLITZIP_PROFILES`` environment variable or an explicit ``path=``.
DEFAULT_PROFILES_PATH = os.environ.get(
    "SPLITZIP_PROFILES", os.path.join("benchmarks", "results", "profiles.json"))

PROFILES_SCHEMA_VERSION = 1


def paper_profile(link_bw: float, *, ratio: float = PAPER_RATIO,
                  fixed_overhead_s: float = 0.0) -> CodecProfile:
    """The paper's H200 codec numbers under a caller-chosen link bandwidth.

    This is the documented fallback when no calibrated ``profiles.json``
    exists (fresh checkout, no benchmark run yet) — provenance is recorded
    as ``"paper-h200"`` so downstream reports can say which cost model they
    were computed under."""
    return CodecProfile(g_enc=PAPER_G_ENC, g_dec=PAPER_G_DEC, ratio=ratio,
                        link_bw=link_bw, fixed_overhead_s=fixed_overhead_s,
                        source="paper-h200")


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------

def _synthetic_kv_bits(n: int, seed: int = 0) -> np.ndarray:
    """KV-like bf16 bits: exponents concentrated on a top-16 band (the same
    synthetic workload shape the table2 smoke benchmark uses)."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n) * np.exp(rng.standard_normal(n))
    return np.asarray(jax.lax.bitcast_convert_type(
        jnp.asarray(x.astype(np.float32), dtype=jnp.bfloat16), jnp.uint16))


def _time(fn, repeats: int, warmup: int) -> float:
    """Mean wall-clock seconds of ``fn`` (blocks on async jax results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return float(np.mean(times))


@dataclasses.dataclass(frozen=True)
class CalibratedProfile:
    """One backend/format's measured codec characteristics + provenance.

    The codec half of a :class:`~repro.core.pipeline.CodecProfile`: encode
    and decode throughput in bytes/s (against uncompressed bytes, the
    convention every analytic model in :mod:`repro.core.pipeline` uses) and
    the achieved compression ratio.  The link bandwidth is deliberately NOT
    part of a calibration — the codec is a property of the machine, the link
    a property of the deployment — so :meth:`profile` takes it as an
    argument when materializing a :class:`CodecProfile`.

    ``workload_elems``/``repeats``/``source`` record how the numbers were
    obtained; they travel through ``profiles.json`` so a scheduler sweep can
    always answer "calibrated from what?"."""

    backend: str          # codec backend registry key ('xla', 'pallas', ...)
    fmt: str              # container format measured ('bf16', 'fp8_e5m2')
    g_enc: float          # encode throughput, bytes/s vs uncompressed
    g_dec: float          # decode throughput, bytes/s vs uncompressed
    ratio: float          # achieved compression ratio on the workload
    workload_elems: int   # elements in the measured workload
    repeats: int          # timed repetitions averaged
    source: str = "measured"

    @property
    def key(self) -> str:
        """Registry key inside ``profiles.json``: ``backend/fmt``."""
        return f"{self.backend}/{self.fmt}"

    def profile(self, link_bw: float,
                fixed_overhead_s: float = 0.0) -> CodecProfile:
        """Materialize a :class:`CodecProfile` under ``link_bw`` (bytes/s)."""
        return CodecProfile(g_enc=self.g_enc, g_dec=self.g_dec,
                            ratio=self.ratio, link_bw=link_bw,
                            fixed_overhead_s=fixed_overhead_s,
                            source=f"{self.source}:{self.key}")

    @classmethod
    def measure(cls, backend: str = "xla",
                shapes: Sequence[Tuple[int, ...]] = ((1 << 16,),), *,
                codebook: Optional[Codebook] = None,
                repeats: int = 3, warmup: int = 1,
                seed: int = 0, source: str = "measured") -> "CalibratedProfile":
        """Run the real codec through the backend registry and time it.

        ``shapes`` lists the tensor shapes to measure over (aggregate
        throughput across all of them, so a mix of KV-leaf shapes measures
        the same work the serving path does).  The codebook defaults to a
        calibration on the workload itself — the production setup, where the
        offline top-16 calibration precedes deployment.

        Returns a :class:`CalibratedProfile`; serialize a batch of them with
        :func:`save_profiles`."""
        be = get_backend(backend)
        total_bytes = 0.0
        total_wire = 0.0
        t_enc_total = 0.0
        t_dec_total = 0.0
        workload_elems = 0
        for shape in shapes:
            n = int(np.prod(shape))
            bits = _synthetic_kv_bits(n, seed=seed)
            cb = codebook or calibrate([bits], k=16)
            x = jax.lax.bitcast_convert_type(
                jnp.asarray(bits), jnp.bfloat16).reshape(shape)
            if be.jittable:
                enc = jax.jit(lambda v, _be=be, _cb=cb: _be.encode(v, _cb))
                dec = jax.jit(lambda c, _be=be: _be.decode(c))
            else:
                enc = lambda v, _be=be, _cb=cb: _be.encode(v, _cb)
                dec = lambda c, _be=be: _be.decode(c)
            ct = enc(x)
            nbytes = float(bits.nbytes)
            total_bytes += nbytes
            total_wire += float(be.wire_bytes(ct))
            workload_elems += n
            t_enc_total += _time(lambda: enc(x), repeats, warmup)
            t_dec_total += _time(lambda: dec(ct), repeats, warmup)
        return cls(backend=be.name, fmt=(codebook.fmt if codebook else "bf16"),
                   g_enc=total_bytes / max(t_enc_total, 1e-12),
                   g_dec=total_bytes / max(t_dec_total, 1e-12),
                   ratio=total_bytes / max(total_wire, 1.0),
                   workload_elems=workload_elems, repeats=repeats,
                   source=source)

    @classmethod
    def from_throughput(cls, backend: str, fmt: str, enc_gbps: float,
                        dec_gbps: float, ratio: float, *,
                        workload_elems: int, repeats: int,
                        source: str = "measured") -> "CalibratedProfile":
        """Build from already-measured GB/s numbers (the table2 benchmark
        measures with its own harness and serializes through this)."""
        return cls(backend=backend, fmt=fmt, g_enc=enc_gbps * 1e9,
                   g_dec=dec_gbps * 1e9, ratio=ratio,
                   workload_elems=workload_elems, repeats=repeats,
                   source=source)


# ---------------------------------------------------------------------------
# serialization
# ---------------------------------------------------------------------------

def save_profiles(profiles: Iterable[CalibratedProfile],
                  path: Optional[str] = None) -> str:
    """Serialize calibrated profiles to JSON (keyed ``backend/fmt``; later
    entries with the same key win).  Returns the path written."""
    path = path or DEFAULT_PROFILES_PATH
    payload = {"version": PROFILES_SCHEMA_VERSION,
               "profiles": {p.key: dataclasses.asdict(p) for p in profiles}}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def load_profiles(path: Optional[str] = None) -> Dict[str, CalibratedProfile]:
    """Load ``profiles.json`` -> ``{key: CalibratedProfile}``.

    Raises ``FileNotFoundError`` when the file doesn't exist and
    ``ValueError`` on a schema-version mismatch — callers that want the
    measure-on-miss behaviour go through :func:`resolve_profile`."""
    path = path or DEFAULT_PROFILES_PATH
    with open(path) as f:
        payload = json.load(f)
    if payload.get("version") != PROFILES_SCHEMA_VERSION:
        raise ValueError(
            f"profiles file {path!r} has schema version "
            f"{payload.get('version')!r}, expected {PROFILES_SCHEMA_VERSION}; "
            "re-run benchmarks/table2_codec_throughput.py to regenerate")
    return {k: CalibratedProfile(**v)
            for k, v in payload.get("profiles", {}).items()}


def _pick(profiles: Dict[str, CalibratedProfile], backend: Optional[str],
          fmt: str) -> CalibratedProfile:
    if backend is not None and backend != "auto":
        key = f"{backend}/{fmt}"
        if key not in profiles:
            raise KeyError(
                f"no calibrated profile for {key!r}; available: "
                f"{sorted(profiles)} — re-run the table2 benchmark or pass "
                "--profile paper")
        return profiles[key]
    # unspecified / 'auto': prefer the XLA reference measurement, else any
    # entry of the requested format, deterministically
    for key in (f"xla/{fmt}",):
        if key in profiles:
            return profiles[key]
    matches = sorted(k for k in profiles if k.endswith(f"/{fmt}"))
    if not matches:
        raise KeyError(f"no calibrated profile of format {fmt!r}; "
                       f"available: {sorted(profiles)}")
    return profiles[matches[0]]


def resolve_calibration(path: Optional[str] = None, *,
                        backend: Optional[str] = None, fmt: str = "bf16",
                        source: str = "measured-on-demand") -> CalibratedProfile:
    """The load-or-measure resolution behind ``--profile measured``: load the
    ``backend/fmt`` entry from ``path`` (default
    :data:`DEFAULT_PROFILES_PATH`); when the file or the entry doesn't exist
    yet, measure a small workload NOW, merge it into the file, and return it.

    A schema-version mismatch propagates as ``ValueError`` (a stale file
    should be regenerated deliberately, never silently overwritten).  Returns
    the raw :class:`CalibratedProfile` — callers that need a
    :class:`CodecProfile` go through :func:`resolve_profile`; callers that
    need the measurement itself (e.g. fig2's time dilation) use this."""
    path = path or DEFAULT_PROFILES_PATH
    try:
        return _pick(load_profiles(path), backend, fmt)
    except (FileNotFoundError, KeyError):
        pass
    be = backend if backend not in (None, "auto") else "xla"
    cal = CalibratedProfile.measure(backend=be, source=source)
    try:
        merged = load_profiles(path)
    except FileNotFoundError:
        merged = {}
    merged[cal.key] = cal
    save_profiles(merged.values(), path)
    return cal


def resolve_profile(source: str, *, link_bw: float,
                    fixed_overhead_s: float = 0.0,
                    backend: Optional[str] = None, fmt: str = "bf16",
                    path: Optional[str] = None) -> CodecProfile:
    """Turn a profile *source* into a concrete :class:`CodecProfile`.

    ``source`` is one of:

    * ``"paper"`` — the paper's H200 datasheet constants
      (:func:`paper_profile`); the fresh-checkout default for launchers.
    * ``"measured"`` — load the calibrated ``profiles.json`` (``path=`` or
      :data:`DEFAULT_PROFILES_PATH`); when the file doesn't exist yet,
      measure a small workload NOW with :meth:`CalibratedProfile.measure`,
      save it there, and use it — so ``--profile measured`` works on a
      machine that never ran the benchmarks.
    * a path ending in ``.json`` — load exactly that profiles file (raise
      if missing: an explicit path is a claim that a calibration exists).

    ``backend`` selects which measurement to use (``None``/``"auto"``
    prefers the XLA reference entry); ``link_bw``/``fixed_overhead_s``
    parameterize the deployment's link, which is never part of a codec
    calibration."""
    if source == "paper":
        return paper_profile(link_bw, fixed_overhead_s=fixed_overhead_s)
    if source.endswith(".json"):
        return _pick(load_profiles(source), backend, fmt).profile(
            link_bw, fixed_overhead_s)
    if source == "measured":
        return resolve_calibration(path, backend=backend, fmt=fmt).profile(
            link_bw, fixed_overhead_s)
    raise ValueError(
        f"unknown profile source {source!r}; expected 'paper', 'measured', "
        "or a profiles.json path")
