"""Pipeline-overlap model for the PD transfer path (paper Appendix A).

For one pipeline chunk of raw size S with compression ratio rho, codec
throughputs G_enc/G_dec and physical link bandwidth B:

    T_enc = S / G_enc,  T_xfer = S / (rho * B),  T_dec = S / G_dec

Steady state: T_pipe = max(T_enc, T_xfer, T_dec); codec overhead is fully
hidden iff B <= B_hide = min(G_enc, G_dec) / rho.

This module also provides the additive accounting the paper uses for the
Fig. 4 transmission breakdown, and the chunked-pipeline schedule used by the
transfer engine to overlap encode / transfer / decode.
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class CodecProfile:
    """Measured or assumed codec/link characteristics (all bytes/s).

    ``source`` records provenance: ``"paper-h200"`` for the paper's datasheet
    constants, ``"measured:<backend>/<fmt>"`` for profiles calibrated from a
    real codec run (:mod:`repro.core.profile`), ``"assumed"`` for hand-built
    test fixtures.  Every scheduler/benchmark number inherits the profile it
    was charged with, so the provenance string is what makes a what-if sweep
    auditable."""

    g_enc: float          # compression throughput (vs uncompressed bytes)
    g_dec: float          # decompression throughput
    ratio: float          # compression ratio rho
    link_bw: float        # physical link bandwidth for compressed bytes
    fixed_overhead_s: float = 0.0  # per-transfer launch/setup cost
    source: str = "assumed"        # provenance (see repro.core.profile)


def stage_times(s_bytes: float, p: CodecProfile) -> Tuple[float, float, float]:
    t_enc = s_bytes / p.g_enc
    t_xfer = s_bytes / (p.ratio * p.link_bw)
    t_dec = s_bytes / p.g_dec
    return t_enc, t_xfer, t_dec


def additive_transfer_time(s_bytes: float, p: CodecProfile) -> float:
    """Paper Fig. 4 accounting: encode + compressed transfer + decode."""
    return sum(stage_times(s_bytes, p)) + p.fixed_overhead_s


def native_transfer_time(s_bytes: float, p: CodecProfile) -> float:
    return s_bytes / p.link_bw + p.fixed_overhead_s


def pipelined_transfer_time(s_bytes: float, p: CodecProfile, n_chunks: int) -> float:
    """Chunked steady-state pipeline: fill + (n-1) * bottleneck + drain."""
    if n_chunks <= 0:
        raise ValueError("n_chunks must be >= 1")
    per = s_bytes / n_chunks
    t_enc, t_xfer, t_dec = stage_times(per, p)
    bottleneck = max(t_enc, t_xfer, t_dec)
    return t_enc + t_xfer + t_dec + (n_chunks - 1) * bottleneck + p.fixed_overhead_s


def flowshop_makespan(chunk_stage_times: Sequence[Tuple[float, float, float]]
                      ) -> float:
    """3-stage flowshop recurrence over per-chunk (enc, xfer, dec) times:

        done_enc[i]  = done_enc[i-1] + T_enc[i]
        done_xfer[i] = max(done_xfer[i-1], done_enc[i])  + T_xfer[i]
        done_dec[i]  = max(done_dec[i-1], done_xfer[i]) + T_dec[i]
    """
    d_enc = d_xfer = d_dec = 0.0
    for t_enc, t_xfer, t_dec in chunk_stage_times:
        d_enc = d_enc + t_enc
        d_xfer = max(d_xfer, d_enc) + t_xfer
        d_dec = max(d_dec, d_xfer) + t_dec
    return d_dec


def pipeline_makespan(chunk_bytes: Sequence[float], p: CodecProfile) -> float:
    """Plan-aware pipeline time: the flowshop recurrence over the ACTUAL
    per-chunk raw byte sizes a :class:`~repro.serving.plan.TransferPlan`
    resolved (segments are codec-chunk aligned, so the last one is usually
    short; equal-size chunks reduce to ``pipelined_transfer_time`` exactly).
    """
    if not chunk_bytes:
        return p.fixed_overhead_s
    return flowshop_makespan([stage_times(s, p) for s in chunk_bytes]
                             ) + p.fixed_overhead_s


def expected_schedule_attempts(n_attempts: int,
                               overflow_p: float) -> Tuple[float, float]:
    """``(expected encode attempts, raw-fallback fraction)`` for a capacity
    schedule of ``n_attempts`` steps when each attempt independently overflows
    with probability ``overflow_p``.

    Attempt k+1 runs iff all k previous attempts overflowed, so the expected
    attempt count is the truncated geometric series ``sum p^k``; the schedule
    exhausts (raw fallback, full link cost) with probability ``p^K``."""
    p = min(max(overflow_p, 0.0), 1.0)
    if p <= 0.0 or n_attempts <= 0:
        return (1.0 if n_attempts > 0 else 0.0), 0.0
    return sum(p ** k for k in range(n_attempts)), p ** n_attempts


def degraded_stage_times(s_bytes: float, p: CodecProfile, *,
                         attempts: float = 1.0,
                         raw_frac: float = 0.0) -> Tuple[float, float, float]:
    """:func:`stage_times` under capacity-schedule expectations: the encoder
    re-runs ``attempts`` times on average, and a ``raw_frac`` fraction of the
    bytes exhausts the schedule — shipping raw at FULL link cost with no
    decode.  ``attempts=1, raw_frac=0`` reduces to :func:`stage_times`."""
    t_enc = attempts * s_bytes / p.g_enc
    t_xfer = s_bytes * ((1.0 - raw_frac) / (p.ratio * p.link_bw)
                        + raw_frac / p.link_bw)
    t_dec = (1.0 - raw_frac) * s_bytes / p.g_dec
    return t_enc, t_xfer, t_dec


def hiding_bandwidth(p: CodecProfile) -> float:
    """B_hide = min(G_enc, G_dec) / rho  (Appendix A)."""
    return min(p.g_enc, p.g_dec) / p.ratio


def speedup(s_bytes: float, p: CodecProfile, pipelined: bool = False,
            n_chunks: int = 8) -> float:
    base = native_transfer_time(s_bytes, p)
    ours = (pipelined_transfer_time(s_bytes, p, n_chunks)
            if pipelined else additive_transfer_time(s_bytes, p))
    return base / ours


def theoretical_opt_speedup(p: CodecProfile) -> float:
    """Zero codec overhead, zero escapes: speedup == rho (paper Fig. 3)."""
    return p.ratio


@dataclasses.dataclass(frozen=True)
class ChunkSchedule:
    """An explicit overlapped schedule for the transfer engine: at step t the
    engine encodes chunk t, transfers chunk t-1 and decodes chunk t-2.

    Driven by :class:`repro.serving.session.TransferSession` (both the local
    chunked path and the mesh double-buffered ppermute path iterate these
    stages) and modeled analytically by
    :meth:`repro.serving.plan.TransferPlan.estimate_time` — the flowshop
    recurrence over the plan's actual segment sizes, which is what the
    scheduler charges.  ``pipelined_transfer_time`` is the legacy equal-chunk
    closed form kept for cross-checks (equal segments reduce to it exactly)."""

    n_chunks: int

    def stages(self) -> List[Tuple[int, int, int]]:
        out = []
        for t in range(self.n_chunks + 2):
            enc = t if t < self.n_chunks else -1
            xfer = t - 1 if 0 <= t - 1 < self.n_chunks else -1
            dec = t - 2 if 0 <= t - 2 < self.n_chunks else -1
            out.append((enc, xfer, dec))
        return out
