"""SplitZip host wire codec — true variable-length byte serialization (numpy).

This is the *off-graph* path: checkpoint compression, cross-datacenter
transfer outside XLA, and the byte-accounting oracle for the in-graph codec.
It implements the paper's exact layout:

  header | sign-mantissa stream (N bytes for bf16) | packed code stream
  (ceil(code_bits*N/8) bytes) | per-chunk escape counts | escape positions
  (u16, chunk-relative) | escape values (u8)

plus an `OVERFLOW`-free guarantee: the wire format has no capacity limit
(escape arrays are exactly M entries), so it is unconditionally lossless.

Since v2 (``SZ02``) every payload carries a per-frame integrity section: the
body after the header is cut into fixed ``FRAME_BYTES`` windows and each
window gets a Fletcher-32 checksum, so corruption on the wire is *detected*
(``decode(verify=True)``) and *localized* — the receiver learns WHICH frame
is bad and can re-fetch just that window instead of the whole tensor.  The
frame table costs 4 bytes per 64 KiB (~0.006%), see ``docs/wire_format.md``
§"Integrity frames".

Everything is vectorized numpy — this codec's throughput is also what the
Table 2 benchmark measures for "SplitZip (host)".
"""

from __future__ import annotations

import dataclasses
import struct
from typing import Tuple

import numpy as np

from repro.core.codebook import FORMATS, Codebook

MAGIC = b"SZ02"
DEFAULT_CHUNK = 1024
#: integrity-frame window: one u32 Fletcher-32 checksum per 64 KiB of body
FRAME_BYTES = 64 * 1024

# magic, fmt_id, k, chunk, n_chunks, n_elements, n_integrity_frames
_HEADER = struct.Struct("<4sBBHIQI")
_FMT_IDS = {"bf16": 0, "fp8_e5m2": 1, "fp8_e4m3": 2}
_FMT_NAMES = {v: k for k, v in _FMT_IDS.items()}


class WireIntegrityError(ValueError):
    """A payload failed checksum verification.  ``frames`` lists the indices
    of the corrupted integrity frames (``FRAME_BYTES`` windows of the body),
    so a transport can re-fetch exactly those windows."""

    def __init__(self, frames):
        self.frames = tuple(frames)
        super().__init__(
            f"wire payload corrupted in integrity frame(s) {self.frames}")


def fletcher32(data) -> int:
    """Vectorized Fletcher-32 over a byte buffer (u16 words, zero-padded).

    This is the 'cheap per-chunk checksum' of the fault-tolerance layer: two
    running sums mod 65535 — one pass, no tables, SIMD-friendly — with error
    detection strength far beyond a parity byte.  Used by the wire payload's
    integrity frames and by :mod:`repro.serving.faults` to frame in-graph
    chunk payloads on the simulated wire."""
    buf = np.frombuffer(bytes(data) if isinstance(data, (bytes, bytearray))
                        else np.ascontiguousarray(data).tobytes(), np.uint8)
    if buf.size % 2:
        buf = np.concatenate([buf, np.zeros(1, np.uint8)])
    words = buf.view("<u2").astype(np.uint64)
    # closed form of the running sums: s1 = sum(w), s2 = sum_i (m-i) * w_i
    # (i 0-based), blocked so the u64 weighted sum cannot overflow
    # (65535 * block^2 < 2^64 needs block <= ~2^23 words)
    s1 = s2 = 0
    block = 1 << 20
    for off in range(0, words.size, block):
        w = words[off:off + block]
        m = w.size
        s2 = (s2 + m * s1 + int((np.arange(m, 0, -1, dtype=np.uint64) * w)
                                .sum())) % 65535
        s1 = (s1 + int(w.sum())) % 65535
    return int((s2 << 16) | s1)


def _frame_checksums(body: np.ndarray) -> np.ndarray:
    """One Fletcher-32 per ``FRAME_BYTES`` window of ``body`` (u8 array)."""
    n_frames = max(1, -(-body.size // FRAME_BYTES)) if body.size else 0
    return np.asarray([fletcher32(body[i * FRAME_BYTES:(i + 1) * FRAME_BYTES])
                       for i in range(n_frames)], dtype=np.uint32)


def n_integrity_frames(body_bytes: int) -> int:
    return max(1, -(-body_bytes // FRAME_BYTES)) if body_bytes else 0


def _bitpack(codes: np.ndarray, code_bits: int) -> np.ndarray:
    """Pack an array of small ints into a dense bitstream (LSB-first)."""
    if code_bits == 8:
        return codes.astype(np.uint8)
    if code_bits == 4:
        n = codes.shape[0]
        if n % 2:
            codes = np.concatenate([codes, np.zeros(1, codes.dtype)])
        lo = codes[0::2].astype(np.uint8)
        hi = codes[1::2].astype(np.uint8)
        return (lo | (hi << 4)).astype(np.uint8)
    # generic path (3-bit for top-8, etc.)
    bits = np.unpackbits(
        codes.astype(np.uint8)[:, None], axis=1, count=8, bitorder="little"
    )[:, :code_bits]
    return np.packbits(bits.reshape(-1), bitorder="little")


def _bitunpack(buf: np.ndarray, n: int, code_bits: int) -> np.ndarray:
    if code_bits == 8:
        return buf[:n]
    if code_bits == 4:
        lo = buf & 0xF
        hi = buf >> 4
        out = np.empty(buf.shape[0] * 2, dtype=np.uint8)
        out[0::2] = lo
        out[1::2] = hi
        return out[:n]
    bits = np.unpackbits(buf, bitorder="little")[: n * code_bits]
    bits = bits.reshape(n, code_bits)
    pad = np.zeros((n, 8 - code_bits), dtype=np.uint8)
    return np.packbits(np.concatenate([bits, pad], axis=1), axis=1, bitorder="little").ravel()


@dataclasses.dataclass(frozen=True)
class WireStats:
    n_elements: int
    n_escapes: int
    payload_bytes: int
    raw_bytes: int

    @property
    def escape_rate(self) -> float:
        return self.n_escapes / max(1, self.n_elements)

    @property
    def ratio(self) -> float:
        return self.raw_bytes / max(1, self.payload_bytes)


def encode(bits: np.ndarray, codebook: Codebook, chunk: int = DEFAULT_CHUNK) -> Tuple[bytes, WireStats]:
    """Serialize a raw-bit tensor (u16 for bf16, u8 for fp8) to wire bytes."""
    spec = FORMATS[codebook.fmt]
    flat = np.ascontiguousarray(bits).view(spec["npdtype"]).ravel()
    n = flat.shape[0]
    mbits, ebits = spec["mbits"], spec["ebits"]

    e = ((flat.astype(np.uint32) >> mbits) & ((1 << ebits) - 1)).astype(np.uint8)
    a = (((flat.astype(np.uint32) >> ebits) & (1 << mbits)) | (flat & ((1 << mbits) - 1))).astype(np.uint8)

    enc_table = codebook.encode_table().astype(np.uint8)
    member = codebook.member_table()
    code = enc_table[e]            # dummy 0 for escapes (overwritten below? no — dense stays)
    is_esc = ~member[e]
    code[is_esc] = 0               # dummy code, paper §3.4

    packed = _bitpack(code, codebook.code_bits)
    a_packed = _bitpack(a, mbits + 1)  # 8 bits for bf16 (fast path), 3/4 for fp8

    # chunked escapes
    n_chunks = (n + chunk - 1) // chunk
    esc_idx = np.flatnonzero(is_esc)
    esc_chunk = (esc_idx // chunk).astype(np.int64)
    esc_pos = (esc_idx % chunk).astype(np.uint16)
    esc_val = e[esc_idx]
    counts = np.bincount(esc_chunk, minlength=n_chunks).astype(np.uint32)

    cb_bytes = np.asarray(codebook.exponents, dtype=np.uint8).tobytes()
    body = b"".join([a_packed.tobytes(), packed.tobytes(),
                     counts.tobytes(), esc_pos.tobytes(), esc_val.tobytes()])
    frames = _frame_checksums(np.frombuffer(body, np.uint8))
    header = _HEADER.pack(MAGIC, _FMT_IDS[codebook.fmt], codebook.k, chunk,
                          n_chunks, n, frames.size)
    payload = b"".join([header, cb_bytes, frames.tobytes(), body])
    stats = WireStats(
        n_elements=n,
        n_escapes=int(esc_idx.size),
        payload_bytes=len(payload),
        raw_bytes=n * spec["bits"] // 8,
    )
    return payload, stats


def verify_payload(payload: bytes) -> Tuple[int, ...]:
    """Recompute the body's per-frame Fletcher-32 sums against the stored
    frame table.  Returns the indices of MISMATCHED frames (empty == intact).
    Cost is one linear pass over the body — measured (verify-on vs -off
    decode) as a ``BENCH_codec.json`` row."""
    magic, _, k, _, _, _, n_frames = _HEADER.unpack_from(payload, 0)
    if magic != MAGIC:
        raise ValueError("bad SplitZip magic")
    off = _HEADER.size + k
    stored = np.frombuffer(payload, np.uint32, n_frames, off)
    body = np.frombuffer(payload, np.uint8, -1, off + 4 * n_frames)
    return tuple(int(i) for i in range(n_frames)
                 if int(stored[i]) != fletcher32(
                     body[i * FRAME_BYTES:(i + 1) * FRAME_BYTES]))


def decode(payload: bytes, verify: bool = False) -> np.ndarray:
    """Wire bytes -> raw-bit tensor (bit-exact).

    ``verify=True`` checks the integrity-frame table before touching the
    body and raises :class:`WireIntegrityError` (carrying the corrupted
    frame indices) instead of decoding garbage."""
    magic, fmt_id, k, chunk, n_chunks, n, n_frames = _HEADER.unpack_from(payload, 0)
    if magic != MAGIC:
        raise ValueError("bad SplitZip magic")
    if verify:
        bad = verify_payload(payload)
        if bad:
            raise WireIntegrityError(bad)
    fmt = _FMT_NAMES[fmt_id]
    spec = FORMATS[fmt]
    off = _HEADER.size
    cb_exps = np.frombuffer(payload, np.uint8, k, off); off += k
    off += 4 * n_frames                  # the integrity-frame table
    mbits = spec["mbits"]
    a_bits = mbits + 1
    n_a_bytes = n if a_bits == 8 else ((n + 1) // 2 if a_bits == 4 else (n * a_bits + 7) // 8)
    a_buf = np.frombuffer(payload, np.uint8, n_a_bytes, off); off += n_a_bytes
    a = _bitunpack(a_buf, n, a_bits)
    code_bits = max(1, int(np.ceil(np.log2(max(2, k)))))
    n_code_bytes = (n + 1) // 2 if code_bits == 4 else (n * code_bits + 7) // 8
    packed = np.frombuffer(payload, np.uint8, n_code_bytes, off); off += n_code_bytes
    counts = np.frombuffer(payload, np.uint32, n_chunks, off); off += 4 * n_chunks
    m = int(counts.sum())
    esc_pos = np.frombuffer(payload, np.uint16, m, off); off += 2 * m
    esc_val = np.frombuffer(payload, np.uint8, m, off); off += m

    code = _bitunpack(packed, n, code_bits)
    dec_table = np.zeros(1 << code_bits, dtype=np.uint8)
    dec_table[: len(cb_exps)] = cb_exps
    e = dec_table[code]

    if m:
        chunk_ids = np.repeat(np.arange(n_chunks, dtype=np.int64), counts.astype(np.int64))
        flat_idx = chunk_ids * chunk + esc_pos.astype(np.int64)
        e[flat_idx] = esc_val

    sign = (a.astype(np.uint32) >> mbits) & 1
    out = (sign << (spec["bits"] - 1)) | (e.astype(np.uint32) << mbits) | (a & ((1 << mbits) - 1))
    return out.astype(spec["npdtype"])


def payload_bytes_model(n: int, m: int, fmt: str = "bf16", k: int = 16, chunk: int = DEFAULT_CHUNK) -> int:
    """Analytic size: must equal len(encode(...)[0]). Used for cross-checks."""
    spec = FORMATS[fmt]
    code_bits = max(1, int(np.ceil(np.log2(max(2, k)))))
    n_chunks = (n + chunk - 1) // chunk
    n_code_bytes = (n + 1) // 2 if code_bits == 4 else (n * code_bits + 7) // 8
    a_bits = spec["mbits"] + 1
    n_a_bytes = n if a_bits == 8 else ((n + 1) // 2 if a_bits == 4 else (n * a_bits + 7) // 8)
    body = n_a_bytes + n_code_bytes + 4 * n_chunks + 3 * m
    return _HEADER.size + k + 4 * n_integrity_frames(body) + body
