"""SplitZip on FP8 (paper Appendix B).

E5M2: 5-bit exponent -> top-16 (4-bit codes, preferred) or top-8 (3-bit).
E4M3: 4-bit exponent -> only top-8 (3-bit) is meaningful; a 4-bit code would
not shrink the exponent at all.

The generic machinery in ``codebook``/``codec``/``wire`` already supports both
formats via ``fmt=``; this module pins down the paper's recommended settings
and the per-variant size model, so callers don't re-derive them.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import codec
from repro.core.codebook import FORMATS, Codebook, calibrate

# paper Appendix B: preferred settings per format
RECOMMENDED = {
    "bf16": dict(k=16),
    "fp8_e5m2": dict(k=16),   # highest ratio AND lowest escape rate (Table 8)
    "fp8_e4m3": dict(k=8),    # 4-bit codes would not compress a 4-bit exponent
}


def recommended_k(fmt: str) -> int:
    return RECOMMENDED[fmt]["k"]


def calibrate_fp8(tensors, fmt: str = "fp8_e5m2", k: int | None = None) -> Codebook:
    return calibrate(tensors, k=k or recommended_k(fmt), fmt=fmt)


def ratio_vs_native(fmt: str, k: int, escape_rate: float) -> float:
    """Compression ratio against the same-format native payload."""
    return codec.theoretical_ratio(fmt, k, escape_rate)


def ratio_vs_bf16(fmt: str, k: int, escape_rate: float) -> float:
    """Paper Table 8 also reports ratio against the BF16 baseline: FP8 already
    halves the payload, so multiply by bf16_bits/fp8_bits."""
    native = ratio_vs_native(fmt, k, escape_rate)
    return native * (16.0 / FORMATS[fmt]["bits"])


@dataclasses.dataclass(frozen=True)
class Fp8Variant:
    fmt: str
    k: int

    @property
    def code_bits(self) -> int:
        return max(1, int(np.ceil(np.log2(max(2, self.k)))))


VARIANTS = (
    Fp8Variant("fp8_e4m3", 8),
    Fp8Variant("fp8_e5m2", 8),
    Fp8Variant("fp8_e5m2", 16),
)
