"""SplitZip core: calibration, in-graph codec, wire codec, FP8, pipeline
model, and the pluggable codec-backend registry (``core/backend.py``)."""

from repro.core.backend import (  # noqa: F401
    CodecBackend,
    available_backends,
    get_backend,
    register_backend,
)
from repro.core.codebook import (  # noqa: F401
    Codebook,
    calibrate,
    codebook_from_histogram,
    coverage,
    escape_rate,
    exponent_entropy,
    exponent_histogram,
    topk_coverage,
)
from repro.core.codec import (  # noqa: F401
    CompressedTensor,
    compressed_bytes,
    compression_ratio,
    decode,
    encode,
    theoretical_ratio,
)
