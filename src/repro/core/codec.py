"""SplitZip in-graph codec (paper §3.2) — static-shape, jit/shard-friendly.

This is the codec that lives *inside* JAX programs (serving graphs, transfer
engines, gradient compression).  XLA requires static shapes, so the paper's
variable-length escape stream becomes a fixed-capacity per-chunk buffer plus a
per-tensor ``ok`` flag; callers (e.g. the transfer engine) fall back to raw
transfer when ``ok`` is False, so the system is unconditionally lossless.
Exact variable-length byte accounting is analytic (``compressed_bytes``) and
is cross-checked against the host wire codec in tests.

Layout for a tensor of N elements (N padded to a chunk multiple):

  sign_mantissa : u8[N]              exact `a_i` bytes (dense stream 1)
  packed        : u8[N//2]           two 4-bit codes per byte (dense stream 2)
  esc_pos       : u16[C, cap]        chunk-relative escape positions
  esc_val       : u8[C, cap]         raw escaped exponents
  esc_count     : i32[C]             true escapes per chunk (may exceed cap)
  ok            : bool[]             no chunk overflowed its escape capacity

TPU adaptation (DESIGN.md §2): encode membership/code assignment uses
broadcast-compare against the 16 codebook entries instead of a 256-byte LUT
gather; decode uses a one-hot × codebook contraction instead of a 16-entry
gather.  Both are VPU-shaped: fixed-width integer compares and reductions.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codebook import FORMATS, Codebook

DEFAULT_CHUNK = 1024  # paper §4.1: "chunked escape value with chunk size 1024"
DEFAULT_CAP = 64      # escape capacity per chunk (6.25%; paper's ε ≈ 0.16%)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CompressedTensor:
    """Pytree carrying the SplitZip streams for one tensor."""

    sign_mantissa: jax.Array  # u8[N]
    packed: jax.Array         # u8[N//2] (nibble-packed, k<=16) or u8[N] (k>16)
    esc_pos: jax.Array        # u16[C, cap]
    esc_val: jax.Array        # u8[C, cap]
    esc_count: jax.Array      # i32[C]
    ok: jax.Array             # bool[]

    # static metadata
    shape: tuple = dataclasses.field(metadata=dict(static=True))
    dtype: str = dataclasses.field(metadata=dict(static=True))
    fmt: str = dataclasses.field(metadata=dict(static=True))
    exponents: tuple = dataclasses.field(metadata=dict(static=True))
    chunk: int = dataclasses.field(metadata=dict(static=True))
    cap: int = dataclasses.field(metadata=dict(static=True))
    # 'chunked' (paper layout) or 'global' (two-level compaction, beyond-paper)
    layout: str = dataclasses.field(default="chunked", metadata=dict(static=True))

    @property
    def codebook(self) -> Codebook:
        return Codebook(fmt=self.fmt, exponents=self.exponents)

    @property
    def n_elements(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1

    @property
    def n_padded(self) -> int:
        return self.sign_mantissa.shape[0]


# ---------------------------------------------------------------------------
# bit plumbing
# ---------------------------------------------------------------------------

def _uint_dtype(fmt: str):
    return jnp.uint16 if FORMATS[fmt]["bits"] == 16 else jnp.uint8


def to_bits(x: jax.Array, fmt: str = "bf16") -> jax.Array:
    """Bitcast a float tensor to its unsigned container type."""
    want = _uint_dtype(fmt)
    if x.dtype in (jnp.uint16, jnp.uint8):
        return x.astype(want)
    return jax.lax.bitcast_convert_type(x, want)


def from_bits(bits: jax.Array, dtype) -> jax.Array:
    if bits.dtype == jnp.dtype(dtype):
        return bits
    return jax.lax.bitcast_convert_type(bits, dtype)


def split_fields(bits: jax.Array, fmt: str) -> Tuple[jax.Array, jax.Array]:
    """bits -> (exponent u8, sign_mantissa u8).  Paper §3.2 exactly (bf16):
    e = (x >> 7) & 0xff ;  a = ((x >> 8) & 0x80) | (x & 0x7f)."""
    s = FORMATS[fmt]
    ebits, mbits = s["ebits"], s["mbits"]
    b = bits.astype(jnp.uint32)
    e = (b >> mbits) & ((1 << ebits) - 1)
    a = ((b >> ebits) & (1 << mbits)) | (b & ((1 << mbits) - 1))
    return e.astype(jnp.uint8), a.astype(jnp.uint8)


def join_fields(e: jax.Array, a: jax.Array, fmt: str) -> jax.Array:
    """(exponent, sign_mantissa) -> container bits.  Paper §3.2:
    x = ((a & 0x80) << 8) | (e << 7) | (a & 0x7f)   (bf16 instance)."""
    s = FORMATS[fmt]
    ebits, mbits, bits = s["ebits"], s["mbits"], s["bits"]
    ei = e.astype(jnp.uint32)
    ai = a.astype(jnp.uint32)
    sign = (ai >> mbits) & 1
    out = (sign << (bits - 1)) | (ei << mbits) | (ai & ((1 << mbits) - 1))
    return out.astype(_uint_dtype(fmt))


# ---------------------------------------------------------------------------
# dense path: code assignment via broadcast-compare (TPU-friendly, no gather)
# ---------------------------------------------------------------------------

def assign_codes(e: jax.Array, exponents: tuple) -> Tuple[jax.Array, jax.Array]:
    """exponent byte -> (code u8, member bool).

    Compare against each codebook entry; the code is the index of the matching
    entry (codebook entries are unique so at most one compare fires).  Escapes
    get the dummy code 0 (paper §3.4) and are fixed by sparse correction.
    """
    cb = jnp.asarray(exponents, dtype=jnp.uint8)          # [K]
    eq = e[..., None] == cb                                # [..., K]
    member = jnp.any(eq, axis=-1)
    idx = jnp.arange(len(exponents), dtype=jnp.uint8)
    code = jnp.sum(eq.astype(jnp.uint8) * idx, axis=-1)   # 0 when no match
    return code, member


def decode_codes(code: jax.Array, exponents: tuple) -> jax.Array:
    """code -> exponent via one-hot × codebook contraction (gather-free)."""
    cb = jnp.asarray(exponents, dtype=jnp.uint8)
    k = len(exponents)
    onehot = code[..., None] == jnp.arange(k, dtype=code.dtype)
    return jnp.sum(onehot.astype(jnp.uint8) * cb, axis=-1)


def pack_nibbles(code: jax.Array) -> jax.Array:
    """[N] 4-bit codes -> [N//2] bytes; element 2i low nibble, 2i+1 high."""
    lo = code[0::2].astype(jnp.uint8)
    hi = code[1::2].astype(jnp.uint8)
    return lo | (hi << 4)


def unpack_nibbles(packed: jax.Array) -> jax.Array:
    lo = packed & 0xF
    hi = packed >> 4
    return jnp.stack([lo, hi], axis=-1).reshape(-1)


# ---------------------------------------------------------------------------
# escape collection: per-chunk cumsum compaction (stream compaction on TPU)
# ---------------------------------------------------------------------------

def collect_escapes(
    e: jax.Array, member: jax.Array, chunk: int, cap: int
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Compact escape (position, value) pairs into fixed-capacity buffers.

    The GPU version is a warp-level stream compaction; on TPU we express the
    same thing as an exclusive cumsum (ranks) + bounded scatter per chunk.
    Padding entries carry position == chunk (scattered with mode='drop' on the
    decode side).  Returns (esc_pos u16[C,cap], esc_val u8[C,cap],
    esc_count i32[C], ok bool[]).
    """
    n = e.shape[0]
    c = n // chunk
    e2 = e.reshape(c, chunk)
    is_esc = ~member.reshape(c, chunk)
    rank = jnp.cumsum(is_esc.astype(jnp.int32), axis=-1) - 1  # rank within chunk
    esc_count = is_esc.sum(axis=-1).astype(jnp.int32)
    ok = jnp.all(esc_count <= cap)

    pos_in_chunk = jnp.arange(chunk, dtype=jnp.int32)[None, :]
    # scatter target column: rank where escape (and within capacity), else OOB
    col = jnp.where(is_esc & (rank < cap), rank, cap)
    rows = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32)[:, None], (c, chunk))

    esc_pos = jnp.full((c, cap), chunk, dtype=jnp.uint16)
    esc_val = jnp.zeros((c, cap), dtype=jnp.uint8)
    esc_pos = esc_pos.at[rows, col].set(
        jnp.broadcast_to(pos_in_chunk, (c, chunk)).astype(jnp.uint16), mode="drop"
    )
    esc_val = esc_val.at[rows, col].set(e2.astype(jnp.uint8), mode="drop")
    return esc_pos, esc_val, esc_count, ok


def scatter_escapes(
    e_decoded: jax.Array, esc_pos: jax.Array, esc_val: jax.Array, chunk: int
) -> jax.Array:
    """Sparse correction: overwrite decoded exponents at escape positions."""
    c, cap = esc_pos.shape
    base = (jnp.arange(c, dtype=jnp.int32) * chunk)[:, None]
    pos = esc_pos.astype(jnp.int32)
    flat = jnp.where(pos < chunk, base + pos, e_decoded.shape[0])  # OOB -> drop
    return e_decoded.at[flat.reshape(-1)].set(esc_val.reshape(-1), mode="drop")


# ---------------------------------------------------------------------------
# two-level (global) escape compaction — BEYOND-PAPER (EXPERIMENTS.md §Perf)
#
# The paper's chunked escape buffers become, in-graph, static u16/u8 arrays of
# shape [chunks, cap]; `cap` must absorb the WORST single chunk, so the static
# wire overhead is chunks*cap*3 bytes even when almost every slot is padding.
# A single per-tensor buffer only needs to absorb the TOTAL escape count
# (tight by concentration), cutting in-graph transfer overhead ~10x at equal
# overflow risk.  Positions widen to u32 (5 bytes/escape instead of 3) —
# a good trade because the buffer shrinks far more than entries grow.
# ---------------------------------------------------------------------------

def collect_escapes_global(
    e: jax.Array, member: jax.Array, total_cap: int
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Compact escapes into one per-tensor buffer via global cumsum ranks.

    Returns (esc_pos u32[1, total_cap] global element indices, esc_val
    u8[1, total_cap], esc_count i32[1], ok bool[]).  Padding entries carry
    position == N (scattered with mode='drop')."""
    n = e.shape[0]
    is_esc = ~member
    rank = jnp.cumsum(is_esc.astype(jnp.int32)) - 1
    esc_count = is_esc.sum().astype(jnp.int32)
    ok = esc_count <= total_cap
    idx = jnp.where(is_esc & (rank < total_cap), rank, total_cap)
    esc_pos = jnp.full((total_cap,), n, dtype=jnp.uint32).at[idx].set(
        jnp.arange(n, dtype=jnp.uint32), mode="drop")
    esc_val = jnp.zeros((total_cap,), dtype=jnp.uint8).at[idx].set(
        e.astype(jnp.uint8), mode="drop")
    return esc_pos[None], esc_val[None], esc_count[None], ok


def scatter_escapes_global(
    e_decoded: jax.Array, esc_pos: jax.Array, esc_val: jax.Array
) -> jax.Array:
    """Sparse correction for the global layout (positions are element indices)."""
    pos = esc_pos.reshape(-1).astype(jnp.int32)  # padding == N -> dropped
    return e_decoded.at[pos].set(esc_val.reshape(-1), mode="drop")


def compact_chunked_to_global(
    esc_pos_c: jax.Array, esc_val_c: jax.Array, esc_count_c: jax.Array,
    chunk: int, total_cap: int, n: int,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Second-level compaction: per-chunk escape buffers -> one global buffer.

    Consumes the PER-CHUNK buffers and counts the fused Pallas encode kernel
    already produced (esc_pos_c u16[C, cap1], esc_val_c u8[C, cap1],
    esc_count_c i32[C] true counts) instead of recomputing the escape mask
    over the full stream — this XLA pass touches only ``C × cap1`` entries
    (~cap1/chunk of the stream), not N elements.  Entries stay in position
    order, so when nothing is dropped the output is bit-identical to
    :func:`collect_escapes_global` on the same data.

    ``ok`` is the conjunction of the global capacity check (total escapes <=
    ``total_cap``) and the first-level one (no chunk exceeded ``cap1``): a
    chunk that overflowed its level-1 buffer already lost escapes, so the
    tensor must take the raw fallback even if the total would have fit.
    This is (slightly) more conservative than the single-pass global
    reference, never less lossless.
    """
    c, cap1 = esc_pos_c.shape
    cnt = jnp.minimum(esc_count_c, cap1)               # entries present
    jj = jnp.arange(cap1, dtype=jnp.int32)[None, :]
    valid = jj < cnt[:, None]
    offsets = (jnp.cumsum(cnt) - cnt)[:, None]         # exclusive over chunks
    rank = offsets + jj                                # global rank per entry
    gpos = (jnp.arange(c, dtype=jnp.uint32)[:, None] * chunk
            + esc_pos_c.astype(jnp.uint32))
    idx = jnp.where(valid & (rank < total_cap), rank, total_cap).reshape(-1)
    esc_pos = jnp.full((total_cap,), n, dtype=jnp.uint32).at[idx].set(
        gpos.reshape(-1), mode="drop")
    esc_val = jnp.zeros((total_cap,), dtype=jnp.uint8).at[idx].set(
        esc_val_c.reshape(-1), mode="drop")
    total = jnp.sum(esc_count_c).astype(jnp.int32)
    ok = (total <= total_cap) & jnp.all(esc_count_c <= cap1)
    return esc_pos[None], esc_val[None], total[None], ok


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def _pad_to_chunk(flat: jax.Array, chunk: int, pad_bits) -> jax.Array:
    n = flat.shape[0]
    pad = (-n) % chunk
    if pad:
        flat = jnp.concatenate([flat, jnp.full((pad,), pad_bits, dtype=flat.dtype)])
    return flat


def default_global_cap(n: int, budget: float = 0.01) -> int:
    """Static per-tensor escape capacity for layout='global': a 1% escape
    budget — 6x the paper's WORST layer-wise escape rate (1.23%, Fig. 5 V-cache
    tail is close) and 60x its mean (0.16%) — rounded up to a lane-aligned
    size.  Still ~4x less wire overhead than the chunked layout's per-chunk
    capacity, which must absorb the worst single chunk rather than the mean."""
    return max(128, int(-(-n * budget // 128)) * 128)


def encode(
    x: jax.Array,
    codebook: Codebook,
    chunk: int = DEFAULT_CHUNK,
    cap: int = DEFAULT_CAP,
    layout: str = "chunked",
) -> CompressedTensor:
    """SplitZip encode (paper §3.2, encoding path).

    Stage 1 (dense): split fields, assign 4-bit codes via compare-select,
    pack nibbles, store sign-mantissa exactly.
    Stage 2 (sparse): compact uncovered exponents into escape buffers —
    per-chunk (paper layout) or one per-tensor buffer (layout='global',
    beyond-paper; `cap` is then the TOTAL capacity, default from
    `default_global_cap`).
    """
    fmt = codebook.fmt
    orig_shape, orig_dtype = x.shape, x.dtype
    bits = to_bits(x, fmt).reshape(-1)
    # Pad with the most frequent exponent pattern => padding never escapes.
    pad_e = codebook.exponents[0]
    pad_bits = np.uint64(pad_e) << FORMATS[fmt]["mbits"]
    bits = _pad_to_chunk(bits, chunk, jnp.asarray(pad_bits, dtype=bits.dtype))

    e, a = split_fields(bits, fmt)
    code, member = assign_codes(e, codebook.exponents)
    packed = pack_nibbles(code) if codebook.k <= 16 else code
    if layout == "global":
        cap = default_global_cap(bits.shape[0]) if cap == DEFAULT_CAP else cap
        esc_pos, esc_val, esc_count, ok = collect_escapes_global(e, member, cap)
    else:
        esc_pos, esc_val, esc_count, ok = collect_escapes(e, member, chunk, cap)
    return CompressedTensor(
        sign_mantissa=a,
        packed=packed,
        esc_pos=esc_pos,
        esc_val=esc_val,
        esc_count=esc_count,
        ok=ok,
        shape=tuple(orig_shape),
        dtype=str(orig_dtype),
        fmt=fmt,
        exponents=tuple(codebook.exponents),
        chunk=chunk,
        cap=cap,
        layout=layout,
    )


def decode_to_bits(ct: CompressedTensor) -> jax.Array:
    """SplitZip decode to the FLAT container bit stream (length n_elements):
    dense unpack + LUT + reassemble, then sparse overwrite.  The transfer
    engine consumes bits directly (it ships bit streams); ``decode`` adds
    only the reshape + bitcast back to the original dtype."""
    code = unpack_nibbles(ct.packed) if len(ct.exponents) <= 16 else ct.packed
    e = decode_codes(code, ct.exponents)
    if ct.layout == "global":
        e = scatter_escapes_global(e, ct.esc_pos, ct.esc_val)
    else:
        e = scatter_escapes(e, ct.esc_pos, ct.esc_val, ct.chunk)
    bits = join_fields(e, ct.sign_mantissa, ct.fmt)
    return bits[:ct.n_elements]


def decode(ct: CompressedTensor) -> jax.Array:
    """SplitZip decode: dense unpack + LUT + reassemble, then sparse overwrite."""
    bits = decode_to_bits(ct).reshape(ct.shape)
    return from_bits(bits, jnp.dtype(ct.dtype))


def roundtrip_ok(x: jax.Array, ct: CompressedTensor) -> jax.Array:
    """Bit-level equality check (float == would fail on NaN)."""
    return jnp.all(to_bits(x, ct.fmt) == to_bits(decode(ct), ct.fmt))


# ---------------------------------------------------------------------------
# byte accounting (paper §3.2 size model; DESIGN.md §1 item 4 for the 3M term)
# ---------------------------------------------------------------------------

def compressed_bytes(ct: CompressedTensor) -> jax.Array:
    """Exact wire bytes for this tensor under the paper's layout:
    N sign-mantissa + N/2 codes + 3 bytes per escape (5 for layout='global',
    whose positions are u32 element indices).  Uses the TRUE element count
    (chunk padding is an in-graph artifact the wire format never ships;
    padding uses the top-1 exponent so it can never escape)."""
    s = FORMATS[ct.fmt]
    n = ct.n_elements
    dense = n * (1 + s["mbits"]) / 8.0  # sign+mantissa bits
    k = len(ct.exponents)
    code_bits = max(1, int(np.ceil(np.log2(max(2, k)))))
    codes = n * code_bits / 8.0
    per_escape = 5.0 if ct.layout == "global" else 3.0
    esc = per_escape * jnp.sum(ct.esc_count)
    return dense + codes + esc


def static_stream_bytes(ct: CompressedTensor) -> int:
    """Bytes the IN-GRAPH streams actually occupy (and actually cross a mesh
    axis when transferred with collectives): static escape buffers are shipped
    at full capacity, padding included.  This is what the two-level global
    layout optimizes — see EXPERIMENTS.md §Perf."""
    return int(ct.sign_mantissa.size * 1 + ct.packed.size * 1
               + ct.esc_pos.size * ct.esc_pos.dtype.itemsize
               + ct.esc_val.size * 1 + ct.esc_count.size * 4 + 1)


def raw_bytes(ct: CompressedTensor) -> float:
    return ct.n_elements * FORMATS[ct.fmt]["bits"] / 8.0


def compression_ratio(ct: CompressedTensor) -> jax.Array:
    return raw_bytes(ct) / compressed_bytes(ct)


def theoretical_ratio(fmt: str = "bf16", k: int = 16, escape_rate: float = 0.0) -> float:
    """ρ = 2 / (3/2 + 3ε) for bf16/top-16; generalized per format/k."""
    s = FORMATS[fmt]
    code_bits = max(1, int(np.ceil(np.log2(max(2, k)))))
    per_elem_bytes = (1 + s["mbits"]) / 8.0 + code_bits / 8.0 + 3.0 * escape_rate
    return (s["bits"] / 8.0) / per_elem_bytes


# ---------------------------------------------------------------------------
# Top-15 + sentinel variant (paper §3.4 / Table 6 ablation)
# ---------------------------------------------------------------------------

SENTINEL = 15


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SentinelCompressed:
    sign_mantissa: jax.Array
    packed: jax.Array
    esc_val: jax.Array      # u8[C, cap] escape values in occurrence order
    esc_count: jax.Array    # i32[C]
    ok: jax.Array
    shape: tuple = dataclasses.field(metadata=dict(static=True))
    dtype: str = dataclasses.field(metadata=dict(static=True))
    fmt: str = dataclasses.field(metadata=dict(static=True))
    exponents: tuple = dataclasses.field(metadata=dict(static=True))  # 15 entries
    chunk: int = dataclasses.field(metadata=dict(static=True))
    cap: int = dataclasses.field(metadata=dict(static=True))


def encode_sentinel(
    x: jax.Array, codebook: Codebook, chunk: int = DEFAULT_CHUNK, cap: int = DEFAULT_CAP
) -> SentinelCompressed:
    """Top-15 + escape-token design: code 15 marks an escape; escape *values*
    are stored in occurrence order (no positions — the decoder finds sentinels
    in the dense stream).  Saves 2 bytes/escape but makes decode irregular."""
    exps = tuple(codebook.exponents[:15])
    fmt = codebook.fmt
    orig_shape, orig_dtype = x.shape, x.dtype
    bits = to_bits(x, fmt).reshape(-1)
    pad_bits = np.uint64(exps[0]) << FORMATS[fmt]["mbits"]
    bits = _pad_to_chunk(bits, chunk, jnp.asarray(pad_bits, dtype=bits.dtype))
    e, a = split_fields(bits, fmt)
    code, member = assign_codes(e, exps)
    code = jnp.where(member, code, jnp.uint8(SENTINEL))
    packed = pack_nibbles(code)
    # values-only compaction, occurrence order per chunk
    _, esc_val, esc_count, ok = collect_escapes(e, member, chunk, cap)
    return SentinelCompressed(
        sign_mantissa=a, packed=packed, esc_val=esc_val, esc_count=esc_count,
        ok=ok, shape=tuple(orig_shape), dtype=str(orig_dtype), fmt=fmt,
        exponents=exps, chunk=chunk, cap=cap,
    )


def decode_sentinel(ct: SentinelCompressed) -> jax.Array:
    """Irregular decode path: every element must inspect the code stream for
    the sentinel, rank sentinels per chunk, and gather from the value stream.
    This models the paper's measured 3.5× decode slowdown structurally."""
    code = unpack_nibbles(ct.packed)
    is_esc = code == SENTINEL
    e = decode_codes(jnp.where(is_esc, 0, code), ct.exponents)
    c = ct.esc_val.shape[0]
    chunk = ct.chunk
    is_esc2 = is_esc.reshape(c, chunk)
    rank = jnp.cumsum(is_esc2.astype(jnp.int32), axis=-1) - 1
    rank = jnp.clip(rank, 0, ct.cap - 1)
    vals = jnp.take_along_axis(ct.esc_val, rank.astype(jnp.int32), axis=-1)
    e = jnp.where(is_esc2, vals, e.reshape(c, chunk)).reshape(-1).astype(jnp.uint8)
    bits = join_fields(e, ct.sign_mantissa, ct.fmt)
    n = int(np.prod(ct.shape)) if ct.shape else 1
    return from_bits(bits[:n].reshape(ct.shape), jnp.dtype(ct.dtype))


def sentinel_bytes(ct: SentinelCompressed) -> jax.Array:
    """N + N/2 + 1 byte per escape (values only)."""
    s = FORMATS[ct.fmt]
    n = ct.sign_mantissa.shape[0]
    return n * (1 + s["mbits"]) / 8.0 + n * 0.5 + 1.0 * jnp.sum(ct.esc_count)


# ---------------------------------------------------------------------------
# Dynamic (per-call) calibration variant (paper §4.3.5 ablation)
# ---------------------------------------------------------------------------

def dynamic_topk_exponents(bits: jax.Array, fmt: str = "bf16", k: int = 16) -> jax.Array:
    """Online histogram + top-k selection (the expensive path the paper's
    pre-calibration avoids).  Returns the top-k exponents as a traced array —
    usable with `encode_with_dynamic_codebook` below."""
    s = FORMATS[fmt]
    e, _ = split_fields(bits.reshape(-1), fmt)
    hist = jnp.zeros((1 << s["ebits"],), jnp.int32).at[e.astype(jnp.int32)].add(1)
    _, top = jax.lax.top_k(hist, k)
    return top.astype(jnp.uint8)


def encode_with_dynamic_codebook(
    x: jax.Array, fmt: str = "bf16", k: int = 16,
    chunk: int = DEFAULT_CHUNK, cap: int = DEFAULT_CAP,
):
    """Dynamic-codebook encode: rebuild the codebook per input (slow path).

    Returns (streams tuple, codebook array).  Used only by the Table 7
    ablation; the production path is `encode` with an offline Codebook.
    """
    bits = to_bits(x, fmt).reshape(-1)
    cb = dynamic_topk_exponents(bits, fmt, k)
    pad = (-bits.shape[0]) % chunk
    if pad:
        padv = (cb[0].astype(jnp.uint32) << FORMATS[fmt]["mbits"]).astype(bits.dtype)
        bits = jnp.concatenate([bits, jnp.full((pad,), 0, bits.dtype) + padv])
    e, a = split_fields(bits, fmt)
    eq = e[..., None] == cb
    member = jnp.any(eq, axis=-1)
    code = jnp.sum(eq.astype(jnp.uint8) * jnp.arange(k, dtype=jnp.uint8), axis=-1)
    packed = pack_nibbles(code)
    esc_pos, esc_val, esc_count, ok = collect_escapes(e, member, chunk, cap)
    return (a, packed, esc_pos, esc_val, esc_count, ok), cb


def decode_with_dynamic_codebook(streams, cb, shape, dtype, fmt="bf16",
                                 chunk: int = DEFAULT_CHUNK):
    a, packed, esc_pos, esc_val, esc_count, ok = streams
    code = unpack_nibbles(packed)
    k = cb.shape[0]
    onehot = code[..., None] == jnp.arange(k, dtype=code.dtype)
    e = jnp.sum(onehot.astype(jnp.uint8) * cb, axis=-1)
    e = scatter_escapes(e, esc_pos, esc_val, chunk)
    bits = join_fields(e, a, fmt)
    n = int(np.prod(shape)) if shape else 1
    return from_bits(bits[:n].reshape(shape), jnp.dtype(dtype))
