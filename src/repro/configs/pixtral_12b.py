"""Pixtral-12B — ViT frontend (stub) + Mistral-Nemo-style text backbone
[hf:mistralai/Pixtral-12B-2409; unverified].

Per the assignment, the vision frontend is a STUB: ``input_specs()`` provides
precomputed patch embeddings; the transformer backbone is fully real.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1000000.0,
    frontend="vision_patches",
    frontend_dim=1024,   # pixtral ViT output width before the adapter
    frontend_len=256,    # patches per image at the assigned shapes
    source="hf:mistralai/Pixtral-12B-2409; unverified",
)
