"""Mamba2-2.7B — SSD (state-space duality), attention-free
[arXiv:2405.21060; unverified].

Sub-quadratic: runs long_500k (decode state is (heads, head_dim, d_state),
independent of context length).  SplitZip compresses the transferred SSM +
conv state instead of K/V (DESIGN.md §4).
"""

from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,         # attention-free
    num_kv_heads=0,
    d_ff=0,              # no separate MLP; SSD block carries the capacity
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                  n_groups=1, chunk=256),
    source="arXiv:2405.21060; unverified",
)
