"""Qwen3-MoE-235B-A22B — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf]."""

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,          # per assignment: MoE expert FFN width
    vocab_size=151936,
    rope_theta=1000000.0,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1536),
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)
