"""HuBERT-XLarge — encoder-only audio transformer [arXiv:2106.07447; unverified].

Per the assignment, the conv waveform frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings.  Encoder-only => no decode shapes; its
"serving" path is encode-and-ship (the encoder output is what crosses the PD
boundary, and what SplitZip compresses — DESIGN.md §4).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,     # MHA
    head_dim=80,
    d_ff=5120,
    vocab_size=504,      # masked-unit prediction targets
    encoder_only=True,
    frontend="audio_frames",
    frontend_dim=512,    # w2v2-style conv feature dim before projection
    source="arXiv:2106.07447; unverified",
)
