"""RecurrentGemma-9B — RG-LRU + local attention, 2:1 pattern
[arXiv:2402.19427; unverified].

Sub-quadratic: runs the long_500k cell (recurrent state + 2048-window cache
are both sequence-length-independent at decode).
"""

from repro.configs.base import ArchConfig, HybridConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,      # MQA in the local-attention layers
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    rope_theta=10000.0,
    hybrid=HybridConfig(
        pattern=("rglru", "rglru", "local_attn"),
        window=2048,
        lru_width=4096,
        conv_width=4,
    ),
    source="arXiv:2402.19427; unverified",
)
