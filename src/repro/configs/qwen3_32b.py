"""Qwen3-32B — the paper's main codec/ablation evaluation model (§4.1).

Not part of the assigned 10-arch pool; included because every SplitZip
table/figure except Fig. 3 uses its KV tensors, so the benchmark suite needs
the config to generate authentic-geometry KV activations.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    rope_theta=1000000.0,
    source="hf:Qwen/Qwen3-32B; paper §4.1",
)
