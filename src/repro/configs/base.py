"""Architecture + shape configuration system.

Every assigned architecture is one `ArchConfig` in `repro/configs/<id>.py`,
selectable by ``--arch <id>`` in the launchers.  Shapes (train_4k /
prefill_32k / decode_32k / long_500k) are `ShapeConfig`s; applicability of a
shape to an arch is decided by `cells()` (DESIGN.md §4 skip rules).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 128
    top_k: int = 8
    d_ff_expert: int = 1536          # per-expert FFN hidden
    capacity_factor: float = 1.25    # dispatch slot headroom


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    n_groups: int = 1
    chunk: int = 256                 # SSD chunk length


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    # RecurrentGemma / Griffin: repeating (recurrent, recurrent, attention)
    pattern: Tuple[str, ...] = ("rglru", "rglru", "local_attn")
    window: int = 2048
    lru_width: Optional[int] = None  # defaults to d_model
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | vlm | hybrid | audio | ssm
    num_layers: int
    d_model: int
    num_heads: int                   # 0 for attention-free
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    encoder_only: bool = False       # hubert: no decode phase
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # modality frontend stubs (DESIGN.md §4): precomputed embeddings
    frontend: Optional[str] = None   # None | 'vision_patches' | 'audio_frames'
    frontend_dim: int = 0            # dim of precomputed frontend features
    frontend_len: int = 256          # frontend positions per example
    source: str = ""

    # ---- derived -----------------------------------------------------------
    @property
    def attention_free(self) -> bool:
        return self.ssm is not None

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context (500k) serving is in scope (DESIGN.md §4)."""
        return self.ssm is not None or self.hybrid is not None

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, l = self.d_model, self.num_layers
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings and not self.encoder_only:
            n += d * self.vocab_size
        if self.ssm is not None:
            di = self.ssm.expand * d
            heads = di // self.ssm.head_dim
            per = (d * (2 * di + 2 * self.ssm.n_groups * self.ssm.d_state + heads)
                   + di * d + 3 * heads + di * self.ssm.conv_width)
            n += l * per
            return n
        hd = self.head_dim
        if self.mla is not None:
            m = self.mla
            per_attn = (d * m.q_lora_rank
                        + m.q_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                        + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                        + m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                        + self.num_heads * m.v_head_dim * d)
        else:
            per_attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
                + self.num_heads * hd * d
        if self.moe is not None:
            per_ffn = (d * self.moe.num_experts
                       + self.moe.num_experts * 3 * d * self.moe.d_ff_expert)
        else:
            per_ffn = 3 * d * self.d_ff
        if self.hybrid is not None:
            h = self.hybrid
            lru = h.lru_width or d
            n_rec = sum(1 for i in range(l) if h.pattern[i % len(h.pattern)] == "rglru")
            n_att = l - n_rec
            per_rec = d * lru * 2 + lru * d + lru * h.conv_width + 3 * lru + per_ffn
            per_att = per_attn + per_ffn
            n += n_rec * per_rec + n_att * per_att
            return n
        n += l * (per_attn + per_ffn)
        return n

    def active_param_count(self) -> int:
        """MoE: only top_k of num_experts fire per token."""
        if self.moe is None:
            return self.param_count()
        d, l = self.d_model, self.num_layers
        dense = self.param_count() - l * self.moe.num_experts * 3 * d * self.moe.d_ff_expert
        return dense + l * self.moe.top_k * 3 * d * self.moe.d_ff_expert

    def with_layers(self, num_layers: int) -> "ArchConfig":
        """Same config at a different depth (dry-run cost extrapolation).
        For hybrid archs, pass a multiple of the block pattern length."""
        return dataclasses.replace(self, num_layers=num_layers)

    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-reduced",
            family=self.family,
            num_layers=min(self.num_layers, 2),
            d_model=128,
            num_heads=min(self.num_heads, 4) if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            d_ff=256,
            vocab_size=128,
            head_dim=32,
            encoder_only=self.encoder_only,
            frontend=self.frontend,
            frontend_dim=min(self.frontend_dim, 64) if self.frontend_dim else 0,
            frontend_len=8,
        )
        if self.moe is not None:
            # capacity_factor high enough to be dropless at smoke-test sizes,
            # so decode-vs-forward consistency is exact
            kw["moe"] = MoEConfig(num_experts=8, top_k=2, d_ff_expert=64,
                                  capacity_factor=8.0)
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                  qk_nope_head_dim=16, qk_rope_head_dim=8,
                                  v_head_dim=16)
        if self.ssm is not None:
            kw["ssm"] = SSMConfig(d_state=16, head_dim=16, expand=2,
                                  conv_width=4, chunk=8)
        if self.hybrid is not None:
            kw["hybrid"] = HybridConfig(window=8, lru_width=128)
            kw["num_layers"] = 3  # one full (rglru, rglru, local_attn) pattern
        return ArchConfig(**kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = (
    "minitron-4b",
    "smollm-135m",
    "llama3.2-3b",
    "minicpm3-4b",
    "qwen3-moe-235b-a22b",
    "qwen3-moe-30b-a3b",
    "pixtral-12b",
    "recurrentgemma-9b",
    "hubert-xlarge",
    "mamba2-2.7b",
)

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}
_MODULES["qwen3-32b"] = "repro.configs.qwen3_32b"  # paper's own eval model


def get_config(arch: str) -> ArchConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """DESIGN.md §4 skip rules.  Returns (applicable, reason_if_not)."""
    if shape.kind == "decode" and cfg.encoder_only:
        return False, "encoder-only arch has no autoregressive decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "524k context requires sub-quadratic attention (SSM/hybrid only)"
    return True, ""


def cells(arch_ids=ARCH_IDS):
    """All live (arch, shape) dry-run cells."""
    out = []
    for a in arch_ids:
        cfg = get_config(a)
        for s in SHAPES.values():
            ok, _ = shape_applicable(cfg, s)
            if ok:
                out.append((a, s.name))
    return out
