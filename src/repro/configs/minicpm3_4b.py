"""MiniCPM3-4B — MLA (multi-head latent attention) [hf:openbmb/MiniCPM3-4B; hf].

The latent KV cache (kv_lora_rank + rope dim per token) is itself the object
SplitZip compresses on the PD transfer path — MLA's lossy rank reduction and
SplitZip's lossless exponent coding compose (DESIGN.md §4).
"""

from repro.configs.base import ArchConfig, MLAConfig

CONFIG = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,   # per assignment table; MLA replaces the KV projection
    head_dim=64,
    d_ff=6400,
    vocab_size=73448,
    rope_theta=10000.0,
    mla=MLAConfig(
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    source="hf:openbmb/MiniCPM3-4B; hf",
)
