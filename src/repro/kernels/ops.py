"""Jit'd wrappers around the SplitZip Pallas kernels.

``encode``/``decode`` here are drop-in replacements for
:mod:`repro.core.codec`'s pure-XLA versions: the dense paths run through
`pl.pallas_call` kernels while escape collection / sparse correction stay in
XLA (paper's two-stage structure).  On non-TPU backends the kernels run in
``interpret=True`` mode (Python semantics of the kernel body), which is how
this repo validates them on CPU; on TPU they compile to Mosaic.

Both escape layouts of the core codec are supported: ``layout='chunked'``
(the paper's per-chunk buffers) and ``layout='global'`` (two-level per-tensor
compaction) — only the XLA compaction stage differs, the kernels are shared.
The serving path reaches these wrappers through the ``pallas`` entry of the
:mod:`repro.core.backend` registry, never by importing this module directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec as core_codec
from repro.core.codebook import FORMATS, Codebook
from repro.kernels import splitzip_decode, splitzip_encode


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _auto_interpret(interpret):
    return (not _on_tpu()) if interpret is None else interpret


def _block_rows(rows: int, want: int) -> int:
    """Largest divisor of ``rows`` that is <= want (grid must tile exactly)."""
    br = min(want, rows)
    while rows % br:
        br -= 1
    return max(br, 1)


def encode(
    x: jax.Array,
    codebook: Codebook,
    chunk: int = core_codec.DEFAULT_CHUNK,
    cap: int = core_codec.DEFAULT_CAP,
    layout: str = "chunked",
    block_rows: int = splitzip_encode.DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
) -> core_codec.CompressedTensor:
    """SplitZip encode with the Pallas dense kernel."""
    fmt = codebook.fmt
    orig_shape, orig_dtype = x.shape, x.dtype
    bits = core_codec.to_bits(x, fmt).reshape(-1)
    pad_e = codebook.exponents[0]
    pad_bits = jnp.asarray(np.uint64(pad_e) << FORMATS[fmt]["mbits"], dtype=bits.dtype)
    bits = core_codec._pad_to_chunk(bits, chunk, pad_bits)
    rows = bits.shape[0] // chunk
    bits2 = bits.reshape(rows, chunk)

    a, packed, is_esc = splitzip_encode.encode_dense(
        bits2,
        tuple(codebook.exponents),
        fmt=fmt,
        chunk=chunk,
        block_rows=_block_rows(rows, block_rows),
        interpret=_auto_interpret(interpret),
    )
    e, _ = core_codec.split_fields(bits, fmt)
    member = ~(is_esc.reshape(-1).astype(bool))
    if layout == "global":
        if cap == core_codec.DEFAULT_CAP:
            cap = core_codec.default_global_cap(bits.shape[0])
        esc_pos, esc_val, esc_count, ok = core_codec.collect_escapes_global(
            e, member, cap)
    else:
        esc_pos, esc_val, esc_count, ok = core_codec.collect_escapes(
            e, member, chunk, cap)
    return core_codec.CompressedTensor(
        sign_mantissa=a.reshape(-1),
        packed=packed.reshape(-1),
        esc_pos=esc_pos,
        esc_val=esc_val,
        esc_count=esc_count,
        ok=ok,
        shape=tuple(orig_shape),
        dtype=str(orig_dtype),
        fmt=fmt,
        exponents=tuple(codebook.exponents),
        chunk=chunk,
        cap=cap,
        layout=layout,
    )


def decode(
    ct: core_codec.CompressedTensor,
    block_rows: int = splitzip_decode.DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
) -> jax.Array:
    """SplitZip decode with the Pallas dense kernel + XLA sparse correction."""
    chunk = ct.chunk
    rows = ct.n_padded // chunk
    packed2 = ct.packed.reshape(rows, chunk // 2)
    a2 = ct.sign_mantissa.reshape(rows, chunk)
    bits2 = splitzip_decode.decode_dense(
        packed2,
        a2,
        tuple(ct.exponents),
        fmt=ct.fmt,
        chunk=chunk,
        block_rows=_block_rows(rows, block_rows),
        interpret=_auto_interpret(interpret),
    )
    # sparse correction: rebuild exponent field only at escape positions
    bits = bits2.reshape(-1)
    spec = FORMATS[ct.fmt]
    mbits, ebits = spec["mbits"], spec["ebits"]
    e = ((bits.astype(jnp.int32) >> mbits) & ((1 << ebits) - 1)).astype(jnp.uint8)
    if ct.layout == "global":
        e = core_codec.scatter_escapes_global(e, ct.esc_pos, ct.esc_val)
    else:
        e = core_codec.scatter_escapes(e, ct.esc_pos, ct.esc_val, chunk)
    bits = core_codec.join_fields(e, ct.sign_mantissa, ct.fmt)
    n = ct.n_elements
    return core_codec.from_bits(bits[:n].reshape(ct.shape), jnp.dtype(ct.dtype))
