"""Jit'd wrappers around the single-pass fused SplitZip Pallas kernels.

``encode``/``decode`` here are drop-in replacements for
:mod:`repro.core.codec`'s pure-XLA versions.  Encode emits the complete
``CompressedTensor`` streams (dense + compacted escapes + true per-chunk
counts) from ONE ``pallas_call``; decode consumes the escape buffers inside
the dense kernel and emits final container bits — no post-kernel full-stream
pass (field re-extract, cumsum, scatter, join) remains on either side.  The
pre-fusion structure survives in :mod:`repro.kernels.twostage` for A/B
comparison (``PallasBackend(fused=False)``) and for escape capacities above
``MAX_FUSED_CAP``, where unrolling the in-kernel compaction loop would
dominate the kernel.

On non-TPU backends the kernels run in ``interpret=True`` mode (Python
semantics of the kernel body), which is how this repo validates them on CPU;
on TPU they compile to Mosaic.

Both escape layouts of the core codec are supported.  ``layout='chunked'``
(the paper's per-chunk buffers) is fully fused end-to-end.  ``layout='global'``
(two-level per-tensor compaction) keeps a bounded XLA second level: encode
compacts the kernel's per-chunk buffers into the global buffer — consuming
the kernel's per-row counts, never recomputing the escape mask over the
stream (:func:`repro.core.codec.compact_chunked_to_global`) — and decode
patches escape positions directly into the kernel's output bits, touching
only the ~0.16% escaped elements instead of re-extracting and rejoining the
whole stream.

The serving path reaches these wrappers through the ``pallas`` entry of the
:mod:`repro.core.backend` registry, never by importing this module directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec as core_codec
from repro.core.codebook import FORMATS, Codebook
from repro.kernels import splitzip_decode, splitzip_encode, twostage
from repro.kernels.splitzip_encode import MAX_FUSED_CAP, fit_block_rows


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _auto_interpret(interpret):
    return (not _on_tpu()) if interpret is None else interpret


def encode(
    x: jax.Array,
    codebook: Codebook,
    chunk: int = core_codec.DEFAULT_CHUNK,
    cap: int = core_codec.DEFAULT_CAP,
    layout: str = "chunked",
    block_rows: int = splitzip_encode.DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
    fused: bool = True,
) -> core_codec.CompressedTensor:
    """SplitZip encode with the single-pass fused Pallas kernel."""
    interp = _auto_interpret(interpret)
    if not fused or (layout != "global" and cap > MAX_FUSED_CAP):
        # two-stage A/B path, or a capacity too large to unroll in-kernel
        return twostage.encode(x, codebook, chunk=chunk, cap=cap,
                               layout=layout, block_rows=block_rows,
                               interpret=interp)
    fmt = codebook.fmt
    orig_shape, orig_dtype = x.shape, x.dtype
    bits = core_codec.to_bits(x, fmt).reshape(-1)
    pad_e = codebook.exponents[0]
    pad_bits = jnp.asarray(np.uint64(pad_e) << FORMATS[fmt]["mbits"], dtype=bits.dtype)
    bits = core_codec._pad_to_chunk(bits, chunk, pad_bits)
    rows = bits.shape[0] // chunk
    bits2 = bits.reshape(rows, chunk)

    kcap = cap if layout != "global" else min(chunk, MAX_FUSED_CAP)
    a, packed, esc_pos_c, esc_val_c, cnt = splitzip_encode.encode_fused(
        bits2,
        tuple(codebook.exponents),
        fmt=fmt,
        chunk=chunk,
        cap=kcap,
        block_rows=fit_block_rows(rows, block_rows),
        interpret=interp,
    )
    esc_count = cnt.reshape(-1)
    if layout == "global":
        if cap == core_codec.DEFAULT_CAP:
            cap = core_codec.default_global_cap(bits.shape[0])
        # bounded second level over C×cap1 entries (not the full stream)
        esc_pos, esc_val, esc_count, ok = core_codec.compact_chunked_to_global(
            esc_pos_c, esc_val_c, esc_count, chunk, cap, bits.shape[0])
    else:
        esc_pos, esc_val = esc_pos_c, esc_val_c
        ok = jnp.all(esc_count <= cap)  # O(C) reduction over the counts
    return core_codec.CompressedTensor(
        sign_mantissa=a.reshape(-1),
        packed=packed.reshape(-1),
        esc_pos=esc_pos,
        esc_val=esc_val,
        esc_count=esc_count,
        ok=ok,
        shape=tuple(orig_shape),
        dtype=str(orig_dtype),
        fmt=fmt,
        exponents=tuple(codebook.exponents),
        chunk=chunk,
        cap=cap,
        layout=layout,
    )


def _patch_escape_bits(bits: jax.Array,
                       ct: core_codec.CompressedTensor) -> jax.Array:
    """Sparse bit-level correction for layouts the kernel can't consume
    per-row (global buffer / oversized caps): patch the exponent field of the
    kernel's output bits at escape positions only — a bounded gather/scatter
    over the ≤cap escape entries, never a full-stream pass."""
    spec = FORMATS[ct.fmt]
    mbits, ebits, width = spec["mbits"], spec["ebits"], spec["bits"]
    n_pad = bits.shape[0]
    if ct.layout == "global":
        flat = ct.esc_pos.reshape(-1).astype(jnp.int32)  # padding == n_pad
    else:
        c = ct.esc_pos.shape[0]
        base = (jnp.arange(c, dtype=jnp.int32) * ct.chunk)[:, None]
        pos = ct.esc_pos.astype(jnp.int32)               # padding == chunk
        flat = jnp.where(pos < ct.chunk, base + pos, n_pad).reshape(-1)
    val = ct.esc_val.reshape(-1).astype(bits.dtype)
    cur = bits[jnp.minimum(flat, n_pad - 1)]
    keep = jnp.asarray(((1 << width) - 1) ^ (((1 << ebits) - 1) << mbits),
                       dtype=bits.dtype)
    patched = (cur & keep) | (val << mbits)
    return bits.at[flat].set(patched, mode="drop")


def decode_bits(
    ct: core_codec.CompressedTensor,
    block_rows: int = splitzip_decode.DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
    fused: bool = True,
) -> jax.Array:
    """Fused decode to the FLAT container bit stream (length n_elements)."""
    interp = _auto_interpret(interpret)
    if not fused:
        return twostage.decode_to_bits(ct, block_rows=block_rows,
                                       interpret=interp)
    chunk = ct.chunk
    rows = ct.n_padded // chunk
    packed2 = ct.packed.reshape(rows, chunk // 2)
    a2 = ct.sign_mantissa.reshape(rows, chunk)
    br = fit_block_rows(rows, block_rows)
    if ct.layout == "chunked" and ct.cap <= MAX_FUSED_CAP:
        # fully fused: the kernel applies the sparse correction and emits
        # final bits; the clipped per-row counts bound its slot loop
        cnt = jnp.minimum(ct.esc_count, ct.cap).astype(jnp.int32)
        bits2 = splitzip_decode.decode_fused(
            packed2, a2, ct.esc_pos, ct.esc_val, cnt.reshape(rows, 1),
            tuple(ct.exponents), fmt=ct.fmt, chunk=chunk,
            block_rows=br, interpret=interp)
        return bits2.reshape(-1)[:ct.n_elements]
    bits2 = splitzip_decode.decode_dense(
        packed2, a2, tuple(ct.exponents), fmt=ct.fmt, chunk=chunk,
        block_rows=br, interpret=interp)
    bits = _patch_escape_bits(bits2.reshape(-1), ct)
    return bits[:ct.n_elements]


def decode(
    ct: core_codec.CompressedTensor,
    block_rows: int = splitzip_decode.DEFAULT_BLOCK_ROWS,
    interpret: bool | None = None,
    fused: bool = True,
) -> jax.Array:
    """SplitZip decode with the single-pass fused Pallas kernel."""
    bits = decode_bits(ct, block_rows=block_rows, interpret=interpret,
                       fused=fused)
    return core_codec.from_bits(bits.reshape(ct.shape), jnp.dtype(ct.dtype))
