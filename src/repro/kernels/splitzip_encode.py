"""Pallas TPU kernel: SplitZip single-pass fused encode (paper §3.2).

``encode_fused`` emits the complete per-chunk compressed streams — field
split, codebook lookup, nibble packing, AND the sparse escape compaction —
from one ``pallas_call``.  The paper describes a two-stage encode ("a
separate escape-collection stage keeps the common path simple and regular");
that structure survives *inside* the kernel as two phases over the same VMEM
tile, so the bit stream is read from HBM exactly once and no post-kernel
full-stream pass remains.  The pre-fusion dense-only kernel is kept as
``encode_dense`` for the two-stage A/B path (:mod:`repro.kernels.twostage`).

TPU adaptation (DESIGN.md §2): the GPU version gathers through a 256-byte
encode LUT; a per-lane byte gather is not VPU-shaped, so we bake the 16
calibrated exponents in as compile-time scalars and evaluate 16 broadcast
compares per element.  All arithmetic is int32 (native VPU width); inputs and
outputs are narrow integer streams.

In-kernel escape compaction (the fused stage 2) is gather/scatter-free:

  rank   : per-row inclusive prefix sum of the escape mask (log2(chunk)
           shift-add steps — Hillis-Steele, VPU-shaped, no lax.cumsum
           dependency in Mosaic),
  slot j : ``esc_pos[r, j] = chunk - Σ_c (chunk - c)·[rank masked == j+1]``
           and ``esc_val[r, j] = Σ_c e[r, c]·[rank masked == j+1]`` — one
           compare + two masked reductions per capacity slot.  A row with
           fewer than j+1 escapes contributes an empty mask, so the slot
           naturally lands on the padding convention (pos == chunk, val == 0).

The slot loop is statically unrolled to ``cap`` iterations but predicated by
``pl.when(j < max escape count in this block)``: at the paper's escape rates
(ε ≈ 0.16%, ~2 escapes per 1024-chunk) only a handful of slots execute, so
the fused stage adds ~O(blockmax) VPU passes — comparable to the 16-compare
dense stage — instead of cap passes.  Capacities above ``MAX_FUSED_CAP`` are
not fused (the unroll would dominate); :mod:`repro.kernels.ops` routes those
to the two-stage path.

Tiling: the flat bit stream is viewed as (rows, CHUNK) with CHUNK = the
escape-chunk size (1024 = 8 sublanes × 128 lanes, hardware-aligned).  Each
grid step processes BLOCK_ROWS rows; with BLOCK_ROWS = 256 the working set is
  in  : 256×1024×4B (i32 upcast of the u16 bits)     = 1.0 MiB
  out : a (1B) + packed (0.5B) + escapes (~3B·cap/chunk) = 0.6 MiB
comfortably inside a v5e core's ~16 MiB VMEM with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.codebook import FORMATS

DEFAULT_BLOCK_ROWS = 256

#: Largest per-chunk escape capacity the fused kernel will unroll; above
#: this the compaction loop would dominate the kernel and the two-stage
#: path wins (see kernels/ops.py dispatch).
MAX_FUSED_CAP = 128


def fit_block_rows(rows: int, want: int) -> int:
    """Largest divisor of ``rows`` that is <= want (grid must tile exactly)."""
    br = min(want, rows)
    while rows % br:
        br -= 1
    return max(br, 1)


def _split_and_code(x, *, exponents, mbits, ebits):
    """Shared dense phase: field split + compare-select code assignment."""
    e = (x >> mbits) & ((1 << ebits) - 1)
    a = ((x >> ebits) & (1 << mbits)) | (x & ((1 << mbits) - 1))
    code = jnp.zeros_like(e)
    member = jnp.zeros(e.shape, dtype=jnp.bool_)
    for idx, ce in enumerate(exponents):  # static unroll, K <= 16
        hit = e == ce
        code = jnp.where(hit, idx, code)
        member = member | hit
    return e, a, code, member


def _pack_pairs(code):
    """Pack two 4-bit codes per byte: (R, C) -> (R, C//2, 2) -> lo | hi<<4."""
    r, c = code.shape
    pairs = code.reshape(r, c // 2, 2)
    return (pairs[..., 0] | (pairs[..., 1] << 4)).astype(jnp.uint8)


def _inclusive_cumsum_lanes(x, chunk):
    """Hillis-Steele inclusive prefix sum along the lane (last) axis.

    log2(chunk) shift-add steps on (rows, chunk) int32 — expressed as
    pad+slice so it lowers on Mosaic without relying on lax.cumsum support.
    """
    s = x
    d = 1
    while d < chunk:
        s = s + jnp.pad(s, ((0, 0), (d, 0)))[:, :chunk]
        d *= 2
    return s


def _encode_kernel(bits_ref, a_ref, packed_ref, esc_ref, *, exponents, mbits, ebits):
    x = bits_ref[...].astype(jnp.int32)
    _, a, code, member = _split_and_code(
        x, exponents=exponents, mbits=mbits, ebits=ebits)
    a_ref[...] = a.astype(jnp.uint8)
    esc_ref[...] = (~member).astype(jnp.uint8)
    packed_ref[...] = _pack_pairs(code)


def _encode_fused_kernel(
    bits_ref, a_ref, packed_ref, esc_pos_ref, esc_val_ref, esc_cnt_ref,
    *, exponents, mbits, ebits, chunk, cap,
):
    x = bits_ref[...].astype(jnp.int32)
    e, a, code, member = _split_and_code(
        x, exponents=exponents, mbits=mbits, ebits=ebits)
    a_ref[...] = a.astype(jnp.uint8)
    packed_ref[...] = _pack_pairs(code)

    # ---- fused stage 2: per-row escape compaction, gather/scatter-free ----
    r = x.shape[0]
    is_esc = (~member).astype(jnp.int32)
    s = _inclusive_cumsum_lanes(is_esc, chunk)      # rank+1 at each escape
    count = s[:, chunk - 1:chunk]                   # (r, 1) TRUE per-row count
    esc_cnt_ref[...] = count.astype(jnp.int32)
    se = s * is_esc                                 # 0 off-escape, rank+1 on

    # padding convention first (pos == chunk -> dropped on decode, val == 0);
    # slots j >= the block's max count keep it without executing their pass
    esc_pos_ref[...] = jnp.full((r, cap), chunk, dtype=jnp.uint16)
    esc_val_ref[...] = jnp.zeros((r, cap), dtype=jnp.uint8)

    blockmax = jnp.max(count)
    # chunk - c per lane: one masked reduction gives both the position and
    # the padding fallback (empty mask -> pos = chunk) without a gather
    wpos = chunk - jax.lax.broadcasted_iota(jnp.int32, (r, chunk), 1)
    for j in range(cap):  # static unroll; predicated off beyond blockmax
        @pl.when(j < blockmax)
        def _(j=j):
            m = (se == j + 1).astype(jnp.int32)
            pos_j = chunk - jnp.sum(wpos * m, axis=-1, keepdims=True)
            val_j = jnp.sum(e * m, axis=-1, keepdims=True)
            esc_pos_ref[:, j:j + 1] = pos_j.astype(jnp.uint16)
            esc_val_ref[:, j:j + 1] = val_j.astype(jnp.uint8)


@functools.partial(
    jax.jit, static_argnames=("exponents", "fmt", "chunk", "block_rows", "interpret")
)
def encode_dense(
    bits: jax.Array,
    exponents: tuple,
    fmt: str = "bf16",
    chunk: int = 1024,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """Dense-only encode of a (rows, chunk) bit tensor (two-stage A/B path).

    Returns (sign_mantissa u8[rows,chunk], packed u8[rows,chunk//2],
    is_escape u8[rows,chunk]); escape compaction happens outside (XLA).
    """
    spec = FORMATS[fmt]
    rows, c = bits.shape
    if c != chunk:
        raise ValueError(f"expected trailing dim == chunk ({chunk}), got {c}")
    br = min(block_rows, rows)
    if rows % br:
        raise ValueError(f"block_rows ({br}) must divide rows ({rows})")
    grid = (rows // br,)
    kernel = functools.partial(
        _encode_kernel,
        exponents=tuple(int(e) for e in exponents),
        mbits=spec["mbits"],
        ebits=spec["ebits"],
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, chunk), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, chunk), lambda i: (i, 0)),
            pl.BlockSpec((br, chunk // 2), lambda i: (i, 0)),
            pl.BlockSpec((br, chunk), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, chunk), jnp.uint8),
            jax.ShapeDtypeStruct((rows, chunk // 2), jnp.uint8),
            jax.ShapeDtypeStruct((rows, chunk), jnp.uint8),
        ],
        interpret=interpret,
    )(bits)


@functools.partial(
    jax.jit,
    static_argnames=("exponents", "fmt", "chunk", "cap", "block_rows", "interpret"),
)
def encode_fused(
    bits: jax.Array,
    exponents: tuple,
    fmt: str = "bf16",
    chunk: int = 1024,
    cap: int = 64,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """Single-pass fused encode of a (rows, chunk) bit tensor.

    One ``pallas_call`` returns the complete per-chunk streams:
    (sign_mantissa u8[rows,chunk], packed u8[rows,chunk//2],
    esc_pos u16[rows,cap], esc_val u8[rows,cap], esc_count i32[rows,1]).
    ``esc_count`` is the TRUE per-row escape count (may exceed ``cap``;
    entries beyond ``cap`` are dropped, matching
    :func:`repro.core.codec.collect_escapes`).
    """
    spec = FORMATS[fmt]
    rows, c = bits.shape
    if c != chunk:
        raise ValueError(f"expected trailing dim == chunk ({chunk}), got {c}")
    if cap > MAX_FUSED_CAP:
        raise ValueError(
            f"cap ({cap}) exceeds MAX_FUSED_CAP ({MAX_FUSED_CAP}); use the "
            "two-stage path (repro.kernels.twostage) for oversized capacities")
    br = min(block_rows, rows)
    if rows % br:
        raise ValueError(f"block_rows ({br}) must divide rows ({rows})")
    grid = (rows // br,)
    kernel = functools.partial(
        _encode_fused_kernel,
        exponents=tuple(int(e) for e in exponents),
        mbits=spec["mbits"],
        ebits=spec["ebits"],
        chunk=chunk,
        cap=cap,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, chunk), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, chunk), lambda i: (i, 0)),
            pl.BlockSpec((br, chunk // 2), lambda i: (i, 0)),
            pl.BlockSpec((br, cap), lambda i: (i, 0)),
            pl.BlockSpec((br, cap), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, chunk), jnp.uint8),
            jax.ShapeDtypeStruct((rows, chunk // 2), jnp.uint8),
            jax.ShapeDtypeStruct((rows, cap), jnp.uint16),
            jax.ShapeDtypeStruct((rows, cap), jnp.uint8),
            jax.ShapeDtypeStruct((rows, 1), jnp.int32),
        ],
        interpret=interpret,
    )(bits)
