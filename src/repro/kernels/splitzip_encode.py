"""Pallas TPU kernel: SplitZip dense encode path (paper §3.2, stage 1).

The kernel implements the *dense* transformation — field split, codebook
lookup, nibble packing, escape-mask emission — over VMEM tiles.  The sparse
escape *collection* (stage 2) is deliberately outside the kernel (XLA cumsum +
bounded scatter), mirroring the paper's two-stage encode: "Using a separate
escape-collection stage keeps the common path simple and regular."

TPU adaptation (DESIGN.md §2): the GPU version gathers through a 256-byte
encode LUT; a per-lane byte gather is not VPU-shaped, so we bake the 16
calibrated exponents in as compile-time scalars and evaluate 16 broadcast
compares per element.  All arithmetic is int32 (native VPU width); inputs and
outputs are narrow integer streams.

Tiling: the flat bit stream is viewed as (rows, CHUNK) with CHUNK = the
escape-chunk size (1024 = 8 sublanes × 128 lanes, hardware-aligned).  Each
grid step processes BLOCK_ROWS rows; with BLOCK_ROWS = 256 the working set is
  in  : 256×1024×4B (i32 upcast of the u16 bits)   = 1.0 MiB
  out : a (1B) + packed (0.5B) + esc mask (1B)      = 0.64 MiB
comfortably inside a v5e core's ~16 MiB VMEM with double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.codebook import FORMATS

DEFAULT_BLOCK_ROWS = 256


def _encode_kernel(bits_ref, a_ref, packed_ref, esc_ref, *, exponents, mbits, ebits):
    x = bits_ref[...].astype(jnp.int32)
    # field split: e = (x >> mbits) & emask ; a = sign-in-bit-mbits | mantissa
    e = (x >> mbits) & ((1 << ebits) - 1)
    a = ((x >> ebits) & (1 << mbits)) | (x & ((1 << mbits) - 1))
    a_ref[...] = a.astype(jnp.uint8)

    # compare-select code assignment: 16 broadcast compares, escapes -> code 0
    code = jnp.zeros_like(e)
    member = jnp.zeros(e.shape, dtype=jnp.bool_)
    for idx, ce in enumerate(exponents):  # static unroll, K <= 16
        hit = e == ce
        code = jnp.where(hit, idx, code)
        member = member | hit
    esc_ref[...] = (~member).astype(jnp.uint8)

    # pack two 4-bit codes per byte: (R, C) -> (R, C//2, 2) -> lo | hi<<4
    r, c = code.shape
    pairs = code.reshape(r, c // 2, 2)
    packed_ref[...] = (pairs[..., 0] | (pairs[..., 1] << 4)).astype(jnp.uint8)


@functools.partial(
    jax.jit, static_argnames=("exponents", "fmt", "chunk", "block_rows", "interpret")
)
def encode_dense(
    bits: jax.Array,
    exponents: tuple,
    fmt: str = "bf16",
    chunk: int = 1024,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """Dense encode of a (rows, chunk) bit tensor.

    Returns (sign_mantissa u8[rows,chunk], packed u8[rows,chunk//2],
    is_escape u8[rows,chunk]).
    """
    spec = FORMATS[fmt]
    rows, c = bits.shape
    if c != chunk:
        raise ValueError(f"expected trailing dim == chunk ({chunk}), got {c}")
    br = min(block_rows, rows)
    if rows % br:
        raise ValueError(f"rows ({rows}) must divide block_rows ({br})")
    grid = (rows // br,)
    kernel = functools.partial(
        _encode_kernel,
        exponents=tuple(int(e) for e in exponents),
        mbits=spec["mbits"],
        ebits=spec["ebits"],
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, chunk), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((br, chunk), lambda i: (i, 0)),
            pl.BlockSpec((br, chunk // 2), lambda i: (i, 0)),
            pl.BlockSpec((br, chunk), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, chunk), jnp.uint8),
            jax.ShapeDtypeStruct((rows, chunk // 2), jnp.uint8),
            jax.ShapeDtypeStruct((rows, chunk), jnp.uint8),
        ],
        interpret=interpret,
    )(bits)
