"""Two-stage Pallas codec path: dense kernels + XLA escape compaction.

This is the pre-fusion structure — the dense transformation runs in a Pallas
kernel while escape collection / sparse correction are separate XLA passes
over the full stream (the paper's literal two-stage description).  It is kept
for A/B benchmarking against the fused single-pass path
(:mod:`repro.kernels.ops`, ``PallasBackend(fused=False)``) and as the
dispatch target for escape capacities above
:data:`repro.kernels.splitzip_encode.MAX_FUSED_CAP`, where unrolling the
in-kernel compaction loop would dominate the kernel.

Cost model (why the fused path exists): per codec call this path re-reads
the full bit stream to re-derive the exponent field (encode: ``split_fields``
after the kernel already computed it; decode: re-extract before the scatter),
then runs cumsum + scatter / scatter + ``join_fields`` as additional
full-tensor HBM round-trips — three-plus extra stream passes and XLA launches
that the fused kernels eliminate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec as core_codec
from repro.core.codebook import FORMATS, Codebook
from repro.kernels import splitzip_decode, splitzip_encode
from repro.kernels.splitzip_encode import fit_block_rows


def encode(
    x: jax.Array,
    codebook: Codebook,
    chunk: int = core_codec.DEFAULT_CHUNK,
    cap: int = core_codec.DEFAULT_CAP,
    layout: str = "chunked",
    block_rows: int = splitzip_encode.DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> core_codec.CompressedTensor:
    """Two-stage encode: Pallas dense kernel + XLA escape collection."""
    fmt = codebook.fmt
    orig_shape, orig_dtype = x.shape, x.dtype
    bits = core_codec.to_bits(x, fmt).reshape(-1)
    pad_e = codebook.exponents[0]
    pad_bits = jnp.asarray(np.uint64(pad_e) << FORMATS[fmt]["mbits"], dtype=bits.dtype)
    bits = core_codec._pad_to_chunk(bits, chunk, pad_bits)
    rows = bits.shape[0] // chunk
    bits2 = bits.reshape(rows, chunk)

    a, packed, is_esc = splitzip_encode.encode_dense(
        bits2,
        tuple(codebook.exponents),
        fmt=fmt,
        chunk=chunk,
        block_rows=fit_block_rows(rows, block_rows),
        interpret=interpret,
    )
    # stage 2 (XLA): full-stream field re-extract + cumsum + bounded scatter
    e, _ = core_codec.split_fields(bits, fmt)
    member = ~(is_esc.reshape(-1).astype(bool))
    if layout == "global":
        if cap == core_codec.DEFAULT_CAP:
            cap = core_codec.default_global_cap(bits.shape[0])
        esc_pos, esc_val, esc_count, ok = core_codec.collect_escapes_global(
            e, member, cap)
    else:
        esc_pos, esc_val, esc_count, ok = core_codec.collect_escapes(
            e, member, chunk, cap)
    return core_codec.CompressedTensor(
        sign_mantissa=a.reshape(-1),
        packed=packed.reshape(-1),
        esc_pos=esc_pos,
        esc_val=esc_val,
        esc_count=esc_count,
        ok=ok,
        shape=tuple(orig_shape),
        dtype=str(orig_dtype),
        fmt=fmt,
        exponents=tuple(codebook.exponents),
        chunk=chunk,
        cap=cap,
        layout=layout,
    )


def decode_to_bits(
    ct: core_codec.CompressedTensor,
    block_rows: int = splitzip_decode.DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """Two-stage decode to flat bits: dense kernel + XLA sparse correction."""
    chunk = ct.chunk
    rows = ct.n_padded // chunk
    packed2 = ct.packed.reshape(rows, chunk // 2)
    a2 = ct.sign_mantissa.reshape(rows, chunk)
    bits2 = splitzip_decode.decode_dense(
        packed2,
        a2,
        tuple(ct.exponents),
        fmt=ct.fmt,
        chunk=chunk,
        block_rows=fit_block_rows(rows, block_rows),
        interpret=interpret,
    )
    # stage 2 (XLA): re-extract the exponent field over the full stream,
    # scatter the escapes, and reassemble — three more full-stream passes
    bits = bits2.reshape(-1)
    spec = FORMATS[ct.fmt]
    mbits, ebits = spec["mbits"], spec["ebits"]
    e = ((bits.astype(jnp.int32) >> mbits) & ((1 << ebits) - 1)).astype(jnp.uint8)
    if ct.layout == "global":
        e = core_codec.scatter_escapes_global(e, ct.esc_pos, ct.esc_val)
    else:
        e = core_codec.scatter_escapes(e, ct.esc_pos, ct.esc_val, chunk)
    bits = core_codec.join_fields(e, ct.sign_mantissa, ct.fmt)
    return bits[:ct.n_elements]


def decode(
    ct: core_codec.CompressedTensor,
    block_rows: int = splitzip_decode.DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """Two-stage decode: dense Pallas kernel + XLA sparse correction."""
    bits = decode_to_bits(ct, block_rows=block_rows, interpret=interpret)
    return core_codec.from_bits(bits.reshape(ct.shape), jnp.dtype(ct.dtype))
