"""Fused Pallas attention over splitzip-compressed KV pages (ROADMAP item 1).

The decode worker keeps its KV cache *compressed at rest* (models/kvpool.py:
fixed-size, codec-chunk-aligned pages holding the splitzip streams — dense
sign-mantissa + nibble-packed exponent codes + a page-level sparse escape
list).  This kernel is the consumer: one ``pallas_call`` per attention layer
walks a sequence's page table with **scalar prefetch** (the page id read from
SMEM feeds the BlockSpec index map, so each grid step DMAs exactly one
physical page's streams into VMEM), decodes the K and V tiles **in
register** — dense exponent-stream load via the `splitzip_decode` machinery
(`_unpack_and_lut` + `_assemble`) plus the predicated per-slot escape patch —
and runs the standard flash accumulation (f32 m/l/acc scratch) over the
decoded tiles.  HBM traffic for the K/V streams is therefore the *compressed*
bytes (~1.51 B/elem vs 2 B raw); raw bf16 K/V never exists in HBM.

Shapes and conventions:

* grid = (B, P) with P = max pages per sequence; the page axis is the
  innermost (sequential) axis, accumulating into scratch like the ``ki`` loop
  of ``kernels/flash_attention.py``.
* pages are always FULL (``tokens_per_page`` tokens): decode-time growth
  lands in a raw tail page attended separately (``tail_partials``) and merged
  with ``merge_partials`` — so no intra-page length masking is needed, only
  the per-row valid-page count ``n_full = cache_len // tokens_per_page``.
* the kernel returns UN-normalized partials ``(acc, m, l)`` so the caller can
  merge the tail (and the just-appended token) before the single normalize.
* causal semantics: queries sit at absolute positions
  ``cache_len - nq + 1 + j``; full pages hold positions ``< n_full * Tp <=
  cache_len``, so for single-token decode (nq == 1) every admitted page is
  visible and the mask is a no-op; for multi-token (speculative) queries the
  in-kernel mask ``t_pos <= q_pos`` applies.
* escape-capacity overflow never reaches this kernel: admission/flush demote
  the batch to a raw-resident ``DecodeState`` (rehydrate-then-
  ``flash_attention``) before any page with more than ``cap`` escapes exists
  (see ``DisaggregatedEngine`` resident wiring).

Like every kernel in this repo the parity surface is interpret mode on CPU;
real-TPU lane/sublane alignment of the (nq, H) output tiles is tracked under
ROADMAP "hardware validation".
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.codebook import FORMATS
from repro.kernels.splitzip_decode import _assemble, _unpack_and_lut

NEG_INF = -1e30


def _bits_uint(fmt: str):
    return jnp.uint16 if FORMATS[fmt]["bits"] == 16 else jnp.uint8


def _float_dtype(fmt: str):
    if FORMATS[fmt]["bits"] == 16:
        return jnp.bfloat16
    return jnp.float8_e5m2 if fmt == "fp8_e5m2" else jnp.float8_e4m3fn


# ---------------------------------------------------------------------------
# in-register page decode (the splitzip_decode machinery, page-level escapes)
# ---------------------------------------------------------------------------

def _decode_page_tile(packed_ref, sm_ref, pos_ref, val_ref, cnt_ref, bits_sc,
                      *, exponents, mbits, bits_width, chunk, cap):
    """Decode ONE page's streams into ``bits_sc`` and return the bit tile.

    Dense phase: nibble unpack + one-hot codebook contraction + bit assembly
    (identical math to ``splitzip_decode._decode_fused_kernel``).  Sparse
    phase: the page-level escape list — ``cap`` statically-unrolled slots,
    predicated by ``pl.when(j < count)`` so only occupied slots execute; slot
    ``j`` broadcasts its page-relative position across the (rows, lanes) tile
    and selects the exponent field where ``row == pos // chunk and lane ==
    pos % chunk`` (padding entries carry ``pos == page_elems`` and can never
    match)."""
    packed = packed_ref[0].astype(jnp.int32)          # (pc, chunk//2)
    a = sm_ref[0].astype(jnp.int32)                   # (pc, chunk)
    e = _unpack_and_lut(packed, exponents=exponents)
    pc = a.shape[0]                                   # scratch may be taller
    bits_sc[0:pc, :] = _assemble(e, a, mbits=mbits, bits_width=bits_width)

    cnt = cnt_ref[0, 0]
    row = jax.lax.broadcasted_iota(jnp.int32, (pc, chunk), 0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (pc, chunk), 1)
    keep = ((1 << bits_width) - 1) ^ (((1 << (bits_width - mbits - 1)) - 1)
                                      << mbits)
    for j in range(cap):  # static unroll; predicated off beyond the count
        @pl.when(j < cnt)
        def _(j=j):
            p = pos_ref[0, j].astype(jnp.int32)       # page-relative
            v = val_ref[0, j].astype(jnp.int32)
            hit = (row == p // chunk) & (lane == p % chunk)
            cur = bits_sc[0:pc, :]
            bits_sc[0:pc, :] = jnp.where(hit, (cur & keep) | (v << mbits),
                                         cur)
    return bits_sc[0:pc, :]


def _bits_to_float(bits, fmt: str):
    """(rows, chunk) i32 bit tile -> f32 values."""
    u = bits.astype(_bits_uint(fmt))
    return jax.lax.bitcast_convert_type(u, _float_dtype(fmt)).astype(
        jnp.float32)


# ---------------------------------------------------------------------------
# the fused paged-GQA kernel
# ---------------------------------------------------------------------------

def _paged_gqa_kernel(
    # scalar prefetch
    pt_k, pt_v, lens,
    # tensor inputs
    q_ref,
    k_sm, k_packed, k_pos, k_val, k_cnt,
    v_sm, v_packed, v_pos, v_val, v_cnt,
    # outputs
    acc_ref, m_ref, l_ref,
    # scratch
    bits_sc, m_sc, l_sc, acc_sc,
    *, exponents, mbits, bits_width, chunk, cap_k, cap_v, tokens_per_page,
    hkv, head_dim, dv, causal, scale, fmt,
):
    b = pl.program_id(0)
    p = pl.program_id(1)
    n_pages = pl.num_programs(1)
    nq, h = q_ref.shape[1], q_ref.shape[2]
    g = h // hkv

    @pl.when(p == 0)
    def _():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    n_full = lens[b, 0]
    cache_len = lens[b, 1]

    @pl.when(p < n_full)
    def _():
        k_bits = _decode_page_tile(k_packed, k_sm, k_pos, k_val, k_cnt,
                                   bits_sc, exponents=exponents, mbits=mbits,
                                   bits_width=bits_width, chunk=chunk,
                                   cap=cap_k)
        k_tile = _bits_to_float(k_bits, fmt).reshape(
            tokens_per_page, hkv, head_dim)
        v_bits = _decode_page_tile(v_packed, v_sm, v_pos, v_val, v_cnt,
                                   bits_sc, exponents=exponents, mbits=mbits,
                                   bits_width=bits_width, chunk=chunk,
                                   cap=cap_v)
        v_tile = _bits_to_float(v_bits, fmt).reshape(tokens_per_page, hkv, dv)

        q = q_ref[0].astype(jnp.float32).reshape(nq, hkv, g, head_dim)
        s = jnp.einsum("qhgd,thd->qhgt", q, k_tile,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            t_pos = p * tokens_per_page + jnp.arange(tokens_per_page)
            q_pos = cache_len - (nq - 1) + jnp.arange(nq)
            mask = t_pos[None, :] <= q_pos[:, None]          # (nq, Tp)
            s = jnp.where(mask[:, None, None, :], s, NEG_INF)

        m_prev = m_sc[...]                                   # (nq, hkv, g)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        pexp = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + pexp.sum(axis=-1)
        pv = jnp.einsum("qhgt,thd->qhgd", pexp, v_tile,
                        preferred_element_type=jnp.float32)
        acc_sc[...] = acc_sc[...] * corr[..., None] + pv
        m_sc[...] = m_new

    @pl.when(p == n_pages - 1)
    def _():
        acc_ref[0] = acc_sc[...].reshape(nq, h, dv)
        m_ref[0] = m_sc[...].reshape(nq, h)
        l_ref[0] = l_sc[...].reshape(nq, h)


def _stream_specs(pc, chunk, cap, table):
    """BlockSpecs for one paged leaf's five stream arrays, indexed through a
    scalar-prefetched page table (``table`` picks which prefetch ref)."""
    def page(b, p, ptk, ptv, lens):
        t = ptk if table == 0 else ptv
        return (jnp.maximum(t[b, p], 0), 0, 0)

    def page2(b, p, ptk, ptv, lens):
        t = ptk if table == 0 else ptv
        return (jnp.maximum(t[b, p], 0), 0)

    return [
        pl.BlockSpec((1, pc, chunk), page),           # sign_mantissa
        pl.BlockSpec((1, pc, chunk // 2), page),      # packed
        pl.BlockSpec((1, cap), page2),                # esc_pos
        pl.BlockSpec((1, cap), page2),                # esc_val
        pl.BlockSpec((1, 1), page2),                  # esc_cnt
    ]


@functools.partial(
    jax.jit,
    static_argnames=("exponents", "fmt", "chunk", "tokens_per_page", "hkv",
                     "causal", "scale", "interpret"),
)
def paged_gqa_attention(
    q: jax.Array,                   # (B, nq, H, hd)
    k_streams, v_streams,           # 5-tuples: sm, packed, pos, val, cnt
    page_table_k: jax.Array,        # (B, P) i32; -1 = unmapped
    page_table_v: jax.Array,
    cache_len: jax.Array,           # (B,) i32 tokens covered by pages+tail
    *, exponents: tuple, fmt: str = "bf16", chunk: int,
    tokens_per_page: int, hkv: int, causal: bool = True,
    scale: float | None = None, interpret: bool = True,
):
    """Fused attention over compressed pages -> un-normalized partials.

    Returns ``(acc, m, l)`` with ``acc (B, nq, H, dv) f32``, ``m/l (B, nq, H)
    f32`` covering the FULL pages only (``cache_len // tokens_per_page`` per
    row); merge the raw tail page via :func:`tail_partials` +
    :func:`merge_partials`, then :func:`finalize`."""
    spec = FORMATS[fmt]
    b, nq, h, hd = q.shape
    n_pages_max = page_table_k.shape[1]
    k_sm, k_packed, k_pos, k_val, k_cnt = k_streams
    v_sm, v_packed, v_pos, v_val, v_cnt = v_streams
    # K and V have independent page geometry (dv may differ from head_dim):
    # per-leaf page_chunks and escape caps feed each leaf's BlockSpecs and
    # the kernel's static escape unroll.
    pc_k, pc_v = k_sm.shape[1], v_sm.shape[1]
    cap_k, cap_v = k_pos.shape[1], v_pos.shape[1]
    m_per_tok_v = (pc_v * v_sm.shape[2]) // tokens_per_page
    dv = m_per_tok_v // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    n_full = cache_len // tokens_per_page
    lens = jnp.stack([n_full, cache_len], axis=1).astype(jnp.int32)

    kernel = functools.partial(
        _paged_gqa_kernel,
        exponents=tuple(int(e) for e in exponents), mbits=spec["mbits"],
        bits_width=spec["bits"], chunk=chunk, cap_k=cap_k, cap_v=cap_v,
        tokens_per_page=tokens_per_page, hkv=hkv, head_dim=hd, dv=dv,
        causal=causal, scale=float(scale), fmt=fmt,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, n_pages_max),
        in_specs=[
            pl.BlockSpec((1, nq, h, hd), lambda b_, p_, *s: (b_, 0, 0, 0)),
            *_stream_specs(pc_k, chunk, cap_k, table=0),
            *_stream_specs(pc_v, chunk, cap_v, table=1),
        ],
        out_specs=[
            pl.BlockSpec((1, nq, h, dv), lambda b_, p_, *s: (b_, 0, 0, 0)),
            pl.BlockSpec((1, nq, h), lambda b_, p_, *s: (b_, 0, 0)),
            pl.BlockSpec((1, nq, h), lambda b_, p_, *s: (b_, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((max(pc_k, pc_v), chunk), jnp.int32),
            pltpu.VMEM((nq, hkv, h // hkv), jnp.float32),
            pltpu.VMEM((nq, hkv, h // hkv), jnp.float32),
            pltpu.VMEM((nq, hkv, h // hkv, dv), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, nq, h, dv), jnp.float32),
            jax.ShapeDtypeStruct((b, nq, h), jnp.float32),
            jax.ShapeDtypeStruct((b, nq, h), jnp.float32),
        ],
        interpret=interpret,
    )(page_table_k, page_table_v, lens, q,
      k_sm, k_packed, k_pos, k_val, k_cnt,
      v_sm, v_packed, v_pos, v_val, v_cnt)


# ---------------------------------------------------------------------------
# the fused paged-MLA kernel (absorbed-form decode over latent pages)
# ---------------------------------------------------------------------------

def _paged_mla_kernel(
    pt_ckv, pt_kr, lens,
    ql_ref, qr_ref,
    c_sm, c_packed, c_pos, c_val, c_cnt,
    r_sm, r_packed, r_pos, r_val, r_cnt,
    acc_ref, m_ref, l_ref,
    bits_sc, m_sc, l_sc, acc_sc,
    *, exponents, mbits, bits_width, chunk, cap_c, cap_r, tokens_per_page,
    kv_rank, rope_dim, causal, scale, fmt,
):
    b = pl.program_id(0)
    p = pl.program_id(1)
    n_pages = pl.num_programs(1)
    nq, h = ql_ref.shape[1], ql_ref.shape[2]

    @pl.when(p == 0)
    def _():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    n_full = lens[b, 0]
    cache_len = lens[b, 1]

    @pl.when(p < n_full)
    def _():
        c_bits = _decode_page_tile(c_packed, c_sm, c_pos, c_val, c_cnt,
                                   bits_sc, exponents=exponents, mbits=mbits,
                                   bits_width=bits_width, chunk=chunk,
                                   cap=cap_c)
        ckv = _bits_to_float(c_bits, fmt).reshape(tokens_per_page, kv_rank)
        r_bits = _decode_page_tile(r_packed, r_sm, r_pos, r_val, r_cnt,
                                   bits_sc, exponents=exponents, mbits=mbits,
                                   bits_width=bits_width, chunk=chunk,
                                   cap=cap_r)
        krope = _bits_to_float(r_bits, fmt).reshape(tokens_per_page, rope_dim)

        ql = ql_ref[0].astype(jnp.float32)                 # (nq, H, r)
        qr = qr_ref[0].astype(jnp.float32)                 # (nq, H, p)
        s = (jnp.einsum("qhr,tr->qht", ql, ckv,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("qhp,tp->qht", qr, krope,
                          preferred_element_type=jnp.float32)) * scale
        if causal:
            t_pos = p * tokens_per_page + jnp.arange(tokens_per_page)
            q_pos = cache_len - (nq - 1) + jnp.arange(nq)
            mask = t_pos[None, :] <= q_pos[:, None]
            s = jnp.where(mask[:, None, :], s, NEG_INF)

        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        pexp = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + pexp.sum(axis=-1)
        pv = jnp.einsum("qht,tr->qhr", pexp, ckv,
                        preferred_element_type=jnp.float32)
        acc_sc[...] = acc_sc[...] * corr[..., None] + pv
        m_sc[...] = m_new

    @pl.when(p == n_pages - 1)
    def _():
        acc_ref[0] = acc_sc[...]
        m_ref[0] = m_sc[...]
        l_ref[0] = l_sc[...]


@functools.partial(
    jax.jit,
    static_argnames=("exponents", "fmt", "chunk", "tokens_per_page",
                     "causal", "scale", "interpret"),
)
def paged_mla_attention(
    q_lat: jax.Array,               # (B, nq, H, kv_rank) absorbed query
    q_rope: jax.Array,              # (B, nq, H, rope_dim)
    ckv_streams, krope_streams,     # 5-tuples
    page_table_ckv: jax.Array, page_table_krope: jax.Array,
    cache_len: jax.Array,
    *, exponents: tuple, fmt: str = "bf16", chunk: int,
    tokens_per_page: int, scale: float, causal: bool = True,
    interpret: bool = True,
):
    """Absorbed-form MLA attention over compressed latent pages.

    Scores are ``q_lat . ckv + q_rope . krope``; the context is accumulated
    over the decoded ``ckv`` tile, so ``acc`` is latent-space ``(B, nq, H,
    kv_rank)`` and the caller applies the ``w_v``/``wo`` up-projections after
    the tail merge (exactly ``mla.mla_decode``'s structure)."""
    spec = FORMATS[fmt]
    b, nq, h, kv_rank = q_lat.shape
    rope_dim = q_rope.shape[-1]
    n_pages_max = page_table_ckv.shape[1]
    c_sm = ckv_streams[0]
    r_sm = krope_streams[0]
    # ckv and krope have independent page geometry (kv_lora_rank vs
    # qk_rope_head_dim): per-leaf page_chunks AND escape caps — using ckv's
    # cap for krope would read past the krope escape arrays.
    pc_c, pc_r = c_sm.shape[1], r_sm.shape[1]
    cap_c = ckv_streams[2].shape[1]
    cap_r = krope_streams[2].shape[1]
    n_full = cache_len // tokens_per_page
    lens = jnp.stack([n_full, cache_len], axis=1).astype(jnp.int32)

    kernel = functools.partial(
        _paged_mla_kernel,
        exponents=tuple(int(e) for e in exponents), mbits=spec["mbits"],
        bits_width=spec["bits"], chunk=chunk, cap_c=cap_c, cap_r=cap_r,
        tokens_per_page=tokens_per_page, kv_rank=kv_rank, rope_dim=rope_dim,
        causal=causal, scale=float(scale), fmt=fmt,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, n_pages_max),
        in_specs=[
            pl.BlockSpec((1, nq, h, kv_rank), lambda b_, p_, *s: (b_, 0, 0, 0)),
            pl.BlockSpec((1, nq, h, rope_dim), lambda b_, p_, *s: (b_, 0, 0, 0)),
            *_stream_specs(pc_c, chunk, cap_c, table=0),
            *_stream_specs(pc_r, chunk, cap_r, table=1),
        ],
        out_specs=[
            pl.BlockSpec((1, nq, h, kv_rank), lambda b_, p_, *s: (b_, 0, 0, 0)),
            pl.BlockSpec((1, nq, h), lambda b_, p_, *s: (b_, 0, 0)),
            pl.BlockSpec((1, nq, h), lambda b_, p_, *s: (b_, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((max(pc_c, pc_r), chunk), jnp.int32),
            pltpu.VMEM((nq, h), jnp.float32),
            pltpu.VMEM((nq, h), jnp.float32),
            pltpu.VMEM((nq, h, kv_rank), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, nq, h, kv_rank), jnp.float32),
            jax.ShapeDtypeStruct((b, nq, h), jnp.float32),
            jax.ShapeDtypeStruct((b, nq, h), jnp.float32),
        ],
        interpret=interpret,
    )(page_table_ckv, page_table_krope, lens, q_lat, q_rope,
      *ckv_streams, *krope_streams)


# ---------------------------------------------------------------------------
# tail partials + softmax-partial merge (shared by both families)
# ---------------------------------------------------------------------------

def tail_partials(s: jax.Array, v: jax.Array, valid: jax.Array):
    """Un-normalized flash partials for the raw tail page.

    ``s``: (B, nq, ..., T) f32 scores (already scaled), ``v``: (B, T, dv) or
    (B, T, hkv, dv) values, ``valid``: (B, T) bool.  Returns (acc, m, l)
    shaped like the kernel partials so :func:`merge_partials` composes."""
    extra = s.ndim - 3                                     # dims between nq and T
    vm = valid.reshape(valid.shape[0], *([1] * (extra + 1)), valid.shape[1])
    s = jnp.where(vm, s, NEG_INF)
    m = s.max(axis=-1)
    pexp = jnp.exp(s - m[..., None])
    l = pexp.sum(axis=-1)
    if v.ndim == 3:                                        # (B, T, dv) latent
        acc = jnp.einsum("bqht,btd->bqhd", pexp, v,
                         preferred_element_type=jnp.float32)
    else:                                                  # (B, T, hkv, dv)
        acc = jnp.einsum("bqhgt,bthd->bqhgd", pexp, v,
                         preferred_element_type=jnp.float32)
    return acc, m, l


def merge_partials(a, b):
    """Combine two un-normalized flash partials (acc, m, l)."""
    acc_a, m_a, l_a = a
    acc_b, m_b, l_b = b
    m = jnp.maximum(m_a, m_b)
    ca = jnp.exp(m_a - m)
    cb = jnp.exp(m_b - m)
    return (acc_a * ca[..., None] + acc_b * cb[..., None],
            m, l_a * ca + l_b * cb)


def finalize(acc, l, dtype=jnp.bfloat16):
    return (acc / jnp.maximum(l[..., None], 1e-30)).astype(dtype)
