"""Pallas TPU kernel: SplitZip dense decode path (paper §3.2, decode).

Unpacks two 4-bit codes per byte, maps each through the 16-entry codebook
(baked in as compile-time scalars — a one-hot select chain instead of a
gather), and reassembles the BF16/FP8 bit pattern with the exact
sign-mantissa stream.  The sparse escape overwrite happens *outside* the
kernel (XLA scatter at escape positions), exactly mirroring the paper's
"dense lookup path + separate sparse overwrite" structure that its Table 6
ablation shows is 3.5× faster than sentinel-style in-stream detection.

Tiling mirrors the encode kernel: (BLOCK_ROWS, CHUNK) tiles, CHUNK = 1024
lanes-aligned, everything int32 on the VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.codebook import FORMATS

DEFAULT_BLOCK_ROWS = 256


def _decode_kernel(packed_ref, a_ref, bits_ref, *, exponents, mbits, bits_width):
    packed = packed_ref[...].astype(jnp.int32)
    a = a_ref[...].astype(jnp.int32)

    # unpack: byte j holds codes (2j | 2j+1<<4) -> interleave back to (R, C)
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    r, half = packed.shape
    code = jnp.stack([lo, hi], axis=-1).reshape(r, half * 2)

    # one-hot × codebook contraction (no gather): e = Σ_k [code==k]·c_k
    e = jnp.zeros_like(code)
    for idx, ce in enumerate(exponents):  # static unroll, K <= 16
        e = jnp.where(code == idx, ce, e)

    # reassemble: x = (sign << (bits-1)) | (e << mbits) | mantissa
    sign = (a >> mbits) & 1
    out = (sign << (bits_width - 1)) | (e << mbits) | (a & ((1 << mbits) - 1))
    bits_ref[...] = out.astype(bits_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("exponents", "fmt", "chunk", "block_rows", "interpret")
)
def decode_dense(
    packed: jax.Array,
    sign_mantissa: jax.Array,
    exponents: tuple,
    fmt: str = "bf16",
    chunk: int = 1024,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """Dense decode to container bits: (rows, chunk//2) packed + (rows, chunk)
    sign-mantissa -> (rows, chunk) u16/u8 bit patterns (escapes still dummy)."""
    spec = FORMATS[fmt]
    rows, c = sign_mantissa.shape
    if c != chunk or packed.shape != (rows, chunk // 2):
        raise ValueError("stream shapes inconsistent with chunk")
    br = min(block_rows, rows)
    if rows % br:
        raise ValueError(f"rows ({rows}) must divide block_rows ({br})")
    grid = (rows // br,)
    out_dtype = jnp.uint16 if spec["bits"] == 16 else jnp.uint8
    kernel = functools.partial(
        _decode_kernel,
        exponents=tuple(int(e) for e in exponents),
        mbits=spec["mbits"],
        bits_width=spec["bits"],
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, chunk // 2), lambda i: (i, 0)),
            pl.BlockSpec((br, chunk), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, chunk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, chunk), out_dtype),
        interpret=interpret,
    )(packed, sign_mantissa)
