"""Pallas TPU kernel: SplitZip single-pass fused decode (paper §3.2).

``decode_fused`` unpacks two 4-bit codes per byte, maps each through the
16-entry codebook (baked in as compile-time scalars — a one-hot select chain
instead of a gather), reassembles the BF16/FP8 bit pattern with the exact
sign-mantissa stream, AND applies the sparse escape correction — all inside
one ``pallas_call`` that emits the final container bits.  The paper's "dense
lookup path + separate sparse overwrite" structure (its Table 6 ablation
shows it 3.5× faster than sentinel-style in-stream detection) survives as
two phases over the same VMEM tile; no post-kernel re-extract → scatter →
join-fields pass over the full stream remains.

The in-kernel correction is scatter-free: capacity slot j broadcasts its
per-row ``(pos, val)`` pair across the lane axis and predicated-selects the
exponent field where ``lane == pos`` — padding entries carry ``pos == chunk``
and can never match.  The slot loop is statically unrolled to ``cap`` but
predicated by ``pl.when(j < max per-row count in this block)`` (the per-row
counts arrive as a kernel input — the encode kernel already computed them),
so at the paper's escape rates only a handful of slots execute.

``decode_dense`` (the pre-fusion dense-only kernel) is kept for the
two-stage A/B path and for layouts whose correction stays outside the kernel
(``layout='global'`` and oversized capacities — see kernels/ops.py).

Tiling mirrors the encode kernel: (BLOCK_ROWS, CHUNK) tiles, CHUNK = 1024
lanes-aligned, everything int32 on the VPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.codebook import FORMATS

DEFAULT_BLOCK_ROWS = 256


def _unpack_and_lut(packed, *, exponents):
    """Shared dense phase: nibble unpack + one-hot × codebook contraction."""
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    r, half = packed.shape
    code = jnp.stack([lo, hi], axis=-1).reshape(r, half * 2)
    e = jnp.zeros_like(code)
    for idx, ce in enumerate(exponents):  # static unroll, K <= 16
        e = jnp.where(code == idx, ce, e)
    return e


def _assemble(e, a, *, mbits, bits_width):
    """x = (sign << (bits-1)) | (e << mbits) | mantissa."""
    sign = (a >> mbits) & 1
    return (sign << (bits_width - 1)) | (e << mbits) | (a & ((1 << mbits) - 1))


def _decode_kernel(packed_ref, a_ref, bits_ref, *, exponents, mbits, bits_width):
    packed = packed_ref[...].astype(jnp.int32)
    a = a_ref[...].astype(jnp.int32)
    e = _unpack_and_lut(packed, exponents=exponents)
    bits_ref[...] = _assemble(e, a, mbits=mbits, bits_width=bits_width
                              ).astype(bits_ref.dtype)


def _decode_fused_kernel(
    packed_ref, a_ref, esc_pos_ref, esc_val_ref, esc_cnt_ref, bits_ref,
    *, exponents, mbits, bits_width, chunk, cap,
):
    packed = packed_ref[...].astype(jnp.int32)
    a = a_ref[...].astype(jnp.int32)
    e = _unpack_and_lut(packed, exponents=exponents)
    bits_ref[...] = _assemble(e, a, mbits=mbits, bits_width=bits_width
                              ).astype(bits_ref.dtype)

    # ---- fused sparse correction: predicated per-slot exponent overwrite ---
    r = a.shape[0]
    blockmax = jnp.max(esc_cnt_ref[...])
    lane = jax.lax.broadcasted_iota(jnp.int32, (r, chunk), 1)
    keep = ((1 << bits_width) - 1) ^ (((1 << (bits_width - mbits - 1)) - 1)
                                      << mbits)  # clears the exponent field
    for j in range(cap):  # static unroll; predicated off beyond blockmax
        @pl.when(j < blockmax)
        def _(j=j):
            pos_j = esc_pos_ref[:, j:j + 1].astype(jnp.int32)  # padding: chunk
            val_j = esc_val_ref[:, j:j + 1].astype(jnp.int32)
            hit = lane == pos_j                # (r, chunk); never hits padding
            cur = bits_ref[...].astype(jnp.int32)
            bits_ref[...] = jnp.where(
                hit, (cur & keep) | (val_j << mbits), cur
            ).astype(bits_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("exponents", "fmt", "chunk", "block_rows", "interpret")
)
def decode_dense(
    packed: jax.Array,
    sign_mantissa: jax.Array,
    exponents: tuple,
    fmt: str = "bf16",
    chunk: int = 1024,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """Dense decode to container bits: (rows, chunk//2) packed + (rows, chunk)
    sign-mantissa -> (rows, chunk) u16/u8 bit patterns (escapes still dummy)."""
    spec = FORMATS[fmt]
    rows, c = sign_mantissa.shape
    if c != chunk or packed.shape != (rows, chunk // 2):
        raise ValueError("stream shapes inconsistent with chunk")
    br = min(block_rows, rows)
    if rows % br:
        raise ValueError(f"block_rows ({br}) must divide rows ({rows})")
    grid = (rows // br,)
    out_dtype = jnp.uint16 if spec["bits"] == 16 else jnp.uint8
    kernel = functools.partial(
        _decode_kernel,
        exponents=tuple(int(e) for e in exponents),
        mbits=spec["mbits"],
        bits_width=spec["bits"],
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, chunk // 2), lambda i: (i, 0)),
            pl.BlockSpec((br, chunk), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, chunk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, chunk), out_dtype),
        interpret=interpret,
    )(packed, sign_mantissa)


@functools.partial(
    jax.jit,
    static_argnames=("exponents", "fmt", "chunk", "block_rows", "interpret"),
)
def decode_fused(
    packed: jax.Array,
    sign_mantissa: jax.Array,
    esc_pos: jax.Array,
    esc_val: jax.Array,
    esc_count: jax.Array,
    exponents: tuple,
    fmt: str = "bf16",
    chunk: int = 1024,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
):
    """Single-pass fused decode to FINAL container bits.

    (rows, chunk//2) packed + (rows, chunk) sign-mantissa +
    (rows, cap) esc_pos u16 / esc_val u8 + (rows, 1) esc_count i32 (clipped
    to cap by the caller) -> (rows, chunk) u16/u8 bit patterns with the
    sparse correction already applied.
    """
    spec = FORMATS[fmt]
    rows, c = sign_mantissa.shape
    cap = esc_pos.shape[1]
    if c != chunk or packed.shape != (rows, chunk // 2):
        raise ValueError("stream shapes inconsistent with chunk")
    if esc_pos.shape != (rows, cap) or esc_val.shape != (rows, cap) \
            or esc_count.shape != (rows, 1):
        raise ValueError("escape stream shapes inconsistent with rows/cap")
    br = min(block_rows, rows)
    if rows % br:
        raise ValueError(f"block_rows ({br}) must divide rows ({rows})")
    grid = (rows // br,)
    out_dtype = jnp.uint16 if spec["bits"] == 16 else jnp.uint8
    kernel = functools.partial(
        _decode_fused_kernel,
        exponents=tuple(int(e) for e in exponents),
        mbits=spec["mbits"],
        bits_width=spec["bits"],
        chunk=chunk,
        cap=cap,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, chunk // 2), lambda i: (i, 0)),
            pl.BlockSpec((br, chunk), lambda i: (i, 0)),
            pl.BlockSpec((br, cap), lambda i: (i, 0)),
            pl.BlockSpec((br, cap), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, chunk), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, chunk), out_dtype),
        interpret=interpret,
    )(packed, sign_mantissa, esc_pos, esc_val, esc_count)
