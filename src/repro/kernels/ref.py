"""Pure-jnp oracles for the SplitZip Pallas kernels.

Kernel-equivalent signatures so tests can `assert_allclose` (bit equality —
these are integer streams) against `splitzip_encode.encode_dense` /
`splitzip_decode.decode_dense` across shape/dtype sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.codebook import FORMATS


def encode_dense_ref(bits: jax.Array, exponents: tuple, fmt: str = "bf16"):
    """(rows, chunk) bits -> (sign_mantissa, packed, is_escape)."""
    spec = FORMATS[fmt]
    mbits, ebits = spec["mbits"], spec["ebits"]
    x = bits.astype(jnp.int32)
    e = (x >> mbits) & ((1 << ebits) - 1)
    a = ((x >> ebits) & (1 << mbits)) | (x & ((1 << mbits) - 1))

    cb = jnp.asarray(exponents, dtype=jnp.int32)
    eq = e[..., None] == cb
    member = jnp.any(eq, axis=-1)
    code = jnp.sum(eq.astype(jnp.int32) * jnp.arange(len(exponents)), axis=-1)

    r, c = code.shape
    pairs = code.reshape(r, c // 2, 2)
    packed = (pairs[..., 0] | (pairs[..., 1] << 4)).astype(jnp.uint8)
    return a.astype(jnp.uint8), packed, (~member).astype(jnp.uint8)


def decode_dense_ref(packed: jax.Array, sign_mantissa: jax.Array,
                     exponents: tuple, fmt: str = "bf16"):
    """(rows, chunk//2) packed + (rows, chunk) sign-mantissa -> container bits."""
    spec = FORMATS[fmt]
    mbits, width = spec["mbits"], spec["bits"]
    p = packed.astype(jnp.int32)
    a = sign_mantissa.astype(jnp.int32)
    lo, hi = p & 0xF, (p >> 4) & 0xF
    r, half = p.shape
    code = jnp.stack([lo, hi], axis=-1).reshape(r, half * 2)
    cb = jnp.asarray(exponents, dtype=jnp.int32)
    onehot = code[..., None] == jnp.arange(len(exponents))
    e = jnp.sum(onehot.astype(jnp.int32) * cb, axis=-1)
    sign = (a >> mbits) & 1
    out = (sign << (width - 1)) | (e << mbits) | (a & ((1 << mbits) - 1))
    return out.astype(jnp.uint16 if width == 16 else jnp.uint8)


# ---------------------------------------------------------------------------
# flash attention oracle (direct softmax; materializes S×S — small shapes only)
# ---------------------------------------------------------------------------

def attention_ref(q, k, v, *, causal=True, scale=None):
    """(B, Sq, H, D) x (B, Skv, Hkv, D[v]) GQA attention, f32 math."""
    import numpy as np
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    g = h // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kf) * scale
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p, vf)
    return o.reshape(b, sq, h, vf.shape[-1]).astype(q.dtype)
