"""Flash attention as a Pallas TPU kernel (EXPERIMENTS.md §Perf Cell A).

Why this kernel exists: the XLA prefill attention materializes the per-block
score chain through HBM (on CPU-XLA even the reductions are unfused), which
is the dominant byte term of every long-context prefill cell in §Roofline.
A fused kernel keeps Q·Kᵀ, the online-softmax state and P·V in VMEM; its HBM
traffic is exactly q+k+v+o.

TPU mapping:
  * grid = (B·H, Sq/blk_q, Skv/blk_k), last axis fastest => sequential
    accumulation over KV blocks per (head, q-block) with carried VMEM
    scratch (m, l, acc) — the canonical TPU flash schedule.
  * BlockSpecs tile Q (blk_q, d), K/V (blk_k, d) into VMEM; GQA is handled
    in the K/V index maps (query head h reads kv head h // group).
  * MXU-aligned tiles: blk_q, blk_k multiples of 128 by default; working set
    at (256, 512, d=128): q 64 KB + k/v 256 KB + scores 512 KB + acc 128 KB
    ≈ 1 MB — comfortably inside the 16 MB VMEM budget.
  * Causal masking via position iota; blocks strictly above the diagonal
    short-circuit through @pl.when (visited but skipped).

Validated on CPU in interpret mode against the jnp oracle (ref.py) across
shapes/dtypes/causality — see tests/test_flash_attention.py.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLK_Q = 256
DEFAULT_BLK_K = 512
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, blk_q: int, blk_k: int,
            nk: int, seq_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * blk_q
    k_start = ki * blk_k

    # skip blocks strictly above the causal diagonal (no query attends there)
    @pl.when((k_start <= q_start + blk_q - 1) if causal else (ki >= 0))
    def _step():
        q = q_ref[0].astype(jnp.float32)                    # (blk_q, d)
        k = k_ref[0].astype(jnp.float32)                    # (blk_k, d)
        v = v_ref[0].astype(jnp.float32)                    # (blk_k, dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (blk_q, blk_k)

        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_pos < seq_kv                               # kv padding
        if causal:
            q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            mask &= q_pos >= k_pos
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                  # (blk_q, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                               # (blk_q, blk_k)
        corr = jnp.exp(m_prev - m_new)                       # (blk_q, 1)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "scale", "blk_q", "blk_k", "interpret"))
def flash_attention(
    q: jax.Array,                  # (B, Sq, H, D)
    k: jax.Array,                  # (B, Skv, Hkv, D)
    v: jax.Array,                  # (B, Skv, Hkv, Dv)
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    blk_q: int = DEFAULT_BLK_Q,
    blk_k: int = DEFAULT_BLK_K,
    interpret: bool = True,        # Mosaic on TPU; Python semantics on CPU
) -> jax.Array:
    """Fused multi-head attention; value head dim may differ (MLA)."""
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    dv = v.shape[-1]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)

    blk_q = min(blk_q, sq)
    blk_k = min(blk_k, skv)
    pad_q = (-sq) % blk_q
    pad_k = (-skv) % blk_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v

    # head-major layout: (B*H, S, d) queries, (B*Hkv, S, d) keys/values
    qh = qp.transpose(0, 2, 1, 3).reshape(b * h, sq + pad_q, d)
    kh = kp.transpose(0, 2, 1, 3).reshape(b * hkv, skv + pad_k, d)
    vh = vp.transpose(0, 2, 1, 3).reshape(b * hkv, skv + pad_k, dv)

    nq = (sq + pad_q) // blk_q
    nk = (skv + pad_k) // blk_k

    def kv_head(i):   # query-head program index -> kv-head row
        bb, hh = i // h, (i % h) // g
        return bb * hkv + hh

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, blk_q=blk_q,
                          blk_k=blk_k, nk=nk, seq_kv=skv),
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, blk_q, d), lambda i, j, t: (i, j, 0)),
            pl.BlockSpec((1, blk_k, d), lambda i, j, t: (kv_head(i), t, 0)),
            pl.BlockSpec((1, blk_k, dv), lambda i, j, t: (kv_head(i), t, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, dv), lambda i, j, t: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq + pad_q, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),   # m: running row max
            pltpu.VMEM((blk_q, 1), jnp.float32),   # l: running denominator
            pltpu.VMEM((blk_q, dv), jnp.float32),  # acc: running numerator
        ],
        interpret=interpret,
    )(qh, kh, vh)

    out = out[:, :sq].reshape(b, h, sq, dv).transpose(0, 2, 1, 3)
    return out


def hbm_bytes(b, sq, skv, h, hkv, d, dv, bytes_per_el=2) -> int:
    """Analytic HBM traffic of the fused kernel: q + k + v + o only."""
    return bytes_per_el * (b * sq * h * d + b * skv * hkv * (d + dv)
                           + b * sq * h * dv)


def flops(b, sq, skv, h, d, dv, causal=True) -> float:
    """2 matmuls; causal ≈ half the S² area."""
    area = sq * skv * (0.5 if causal else 1.0)
    return 2.0 * b * h * area * (d + dv)
