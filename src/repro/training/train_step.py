"""Sharded training step: loss -> grads -> (optionally compressed) reduction
-> AdamW update.  One jitted program per (arch, mesh, flags) combination.

Two gradient-sync modes:

* ``grad_compress=False`` (paper-faithful baseline): global-batch loss, XLA
  inserts the gradient all-reduce over (pod, data) automatically.
* ``grad_compress=True`` (beyond-paper): pod-partial gradients via vmap over a
  pod-split batch (XLA still reduces over 'data' on fast ICI), then the
  cross-pod hop runs SplitZip-compressed over DCN
  (training/grad_compress.py).
"""

from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.codebook import Codebook
from repro.distributed.sharding import ShardingPolicy, constrain, use_policy
from repro.models import model as M
from repro.training import grad_compress as GC
from repro.training import optimizer as OPT


class TrainState(NamedTuple):
    params: dict
    opt: OPT.AdamWState


def init_state(cfg: ArchConfig, key) -> TrainState:
    params = M.init_params(cfg, key)
    return TrainState(params=params, opt=OPT.init(params))


def abstract_state(cfg: ArchConfig) -> TrainState:
    return jax.eval_shape(lambda: init_state(cfg, jax.random.PRNGKey(0)))


def make_train_step(
    cfg: ArchConfig,
    opt_cfg: OPT.AdamWConfig = OPT.AdamWConfig(),
    policy: Optional[ShardingPolicy] = None,
    *,
    grad_compress: bool = False,
    grad_codebook: Codebook = GC.DEFAULT_GRAD_CODEBOOK,
    kv_block: int = 1024,
    remat: bool = True,
):
    """Returns train_step(state, batch) -> (state, metrics).  Not yet jitted —
    the launcher jits with in/out shardings from the policy."""
    mesh = policy.mesh if policy is not None else None
    n_pod = mesh.shape.get("pod", 1) if mesh is not None else 1

    def loss_of(params, batch):
        total, (ce, aux) = M.loss_fn(params, batch, cfg, kv_block=kv_block,
                                     remat=remat)
        return total, (ce, aux)

    def step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        with use_policy(policy):
            if grad_compress and n_pod > 1:
                # pod-split the batch: (B, ...) -> (n_pod, B/n_pod, ...)
                def split(x):
                    return x.reshape(n_pod, x.shape[0] // n_pod, *x.shape[1:])
                batch_p = jax.tree.map(split, batch)

                def pod_loss(params, b):
                    return loss_of(params, b)

                (totals, (ces, auxs)), grads_stacked = jax.vmap(
                    jax.value_and_grad(pod_loss, has_aux=True),
                    in_axes=(None, 0))(state.params, batch_p)
                grads = GC.compressed_cross_pod_mean(
                    grads_stacked, mesh, codebook=grad_codebook)
                total = jnp.mean(totals)
                ce, aux = jnp.mean(ces), jnp.mean(auxs)
            else:
                (total, (ce, aux)), grads = jax.value_and_grad(
                    loss_of, has_aux=True)(state.params, batch)

            params, opt, om = OPT.update(opt_cfg, grads, state.opt, state.params)
            metrics = {"loss": total, "ce": ce, "aux": aux, **om}
            return TrainState(params=params, opt=opt), metrics

    return step


def jit_train_step(step_fn, policy: ShardingPolicy, state_abstract: TrainState,
                   batch_abstract: Dict, donate: bool = True):
    """AOT-compile the step with explicit in/out shardings."""
    mesh = policy.mesh
    state_sh = TrainState(
        params=policy.param_sharding(state_abstract.params),
        opt=OPT.AdamWState(
            step=NamedSharding(mesh, P()),
            m=policy.param_sharding(state_abstract.opt.m),
            v=policy.param_sharding(state_abstract.opt.v),
        ),
    )
    batch_sh = jax.tree.map(
        lambda x: NamedSharding(
            mesh, policy.spec_for_activation("tokens", tuple(x.shape))),
        batch_abstract)
    metrics_sh = None  # replicated by default
    jitted = jax.jit(
        step_fn,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, metrics_sh),
        donate_argnums=(0,) if donate else (),
    )
    return jitted, (state_sh, batch_sh)
