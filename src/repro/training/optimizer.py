"""AdamW built from scratch in JAX (no optax dependency).

State layout mirrors the params pytree (m, v per leaf) so the sharding rules
apply transparently — optimizer state shards exactly like the parameters.
Moments are fp32 regardless of param dtype (bf16 master-less training with
fp32 optimizer state, the standard large-scale recipe).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # () int32
    m: Any                   # pytree like params, fp32
    v: Any                   # pytree like params, fp32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def _decay_mask(path) -> bool:
    """No weight decay on norms / biases / 1-D params (standard recipe)."""
    leaf = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
    return not any(s in leaf for s in ("norm", "bias", "lam", "dt_bias", "A_log", "D"))


def update(cfg: AdamWConfig, grads, state: AdamWState, params
           ) -> Tuple[Any, AdamWState, dict]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                         state.m, grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state.v, grads)

    flat_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_m = jax.tree.leaves(new_m)
    flat_v = jax.tree.leaves(new_v)
    out = []
    for (path, p), m, v in zip(flat_p, flat_m, flat_v):
        mhat = m / bc1
        vhat = v / bc2
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _decay_mask(path):
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        out.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
    new_params = jax.tree_util.tree_unflatten(treedef, out)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step=step, m=new_m, v=new_v), metrics
