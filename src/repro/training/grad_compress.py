"""Compressed cross-pod gradient all-reduce (beyond-paper feature).

Hierarchical DP at multi-pod scale: within a pod, gradient reduction rides
the fast ICI (XLA's automatic all-reduce); *across* pods it crosses slow DCN.
Since pod-level gradients are bf16, the SplitZip codec applies verbatim —
**lossless**, so unlike lossy gradient compression (top-k, 1-bit Adam, ...)
it changes no optimization semantics; the only numerics are the same bf16
adds any all-reduce performs.

This module is a thin policy layer over the bulk-data plane: the caller
produces *pod-partial* gradients with a leading pod dim (via vmap over a
pod-split batch — see train_step.py), a cached
:class:`~repro.serving.plan.TransferPlan` routes each leaf (bf16 above
``MIN_COMPRESS_ELEMS`` -> splitzip stream, everything else raw), and the
:class:`~repro.serving.session.TransferSession` collective executor
(``session.ring_reduce``) runs the rotating-ring ppermute exchange over the
compressed streams (n_pod - 1 hops, decode + fp32 accumulate per hop).  The
ppermute operand bytes in the lowered HLO shrink by ~1/rho vs a raw DCN
all-reduce — the number the roofline's collective term scores.  No codec or
wire calls live here (CI-grep-guarded); per-step accounting surfaces as
:class:`~repro.serving.plan.TransferStats` in ``last_stats``.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

# default gradient codebook: bf16 gradients of normalized networks
# concentrate in small-magnitude exponents — the same sub-bias band as the
# shared activation fallback.  Refreshed by calibrate_on_grads.
from repro.core.codebook import (Codebook,
                                 DEFAULT_BF16_CODEBOOK as DEFAULT_GRAD_CODEBOOK)
from repro.core.profile import resolve_profile
from repro.serving.plan import TransferConfig, TransferPlan, TransferStats

# Leaves smaller than this ship raw — codec framing would not pay for
# itself.  Applied per ring participant via TransferConfig.min_compress_elems.
MIN_COMPRESS_ELEMS = 16384

#: TransferStats of the most recent ``compressed_cross_pod_mean`` exchange
#: (None until the first multi-pod call; single-pod meshes never hit DCN).
last_stats: Optional[TransferStats] = None

_SESSIONS: Dict[Tuple, Any] = {}


def gradient_transfer_config(codebook: Codebook = DEFAULT_GRAD_CODEBOOK,
                             compress: bool = True) -> TransferConfig:
    """Routing policy for gradient pytrees: bf16 leaves at or above
    ``MIN_COMPRESS_ELEMS`` ride the splitzip stream, small/odd-dtype leaves
    go raw, and fp32 stays raw (the in-graph ring cannot ship a hi/lo split
    — and losslessness must not depend on it)."""
    return TransferConfig(codebook=codebook, enabled=compress,
                          compress_fp32=False,
                          min_compress_elems=MIN_COMPRESS_ELEMS)


def calibrate_on_grads(grads, k: int = 16) -> Codebook:
    """Offline calibration pass over a representative gradient pytree."""
    import numpy as np
    from repro.core import codebook as cbm
    leaves = [np.asarray(jax.lax.bitcast_convert_type(
        g.astype(jnp.bfloat16), jnp.uint16)).ravel()
        for g in jax.tree.leaves(grads)]
    return cbm.calibrate(leaves, k=k)


def _session(grads_stacked, mesh: Mesh, codebook: Codebook, compress: bool):
    """Session cache: the plan is a property of (structure, mesh, policy),
    not of the step — the compiled ring fns inside the session amortize
    across the whole training run."""
    leaves, treedef = jax.tree_util.tree_flatten(grads_stacked)
    key = (treedef, tuple((tuple(x.shape), str(x.dtype)) for x in leaves),
           mesh, codebook, compress)
    sess = _SESSIONS.get(key)
    if sess is None:
        plan = TransferPlan.build(
            grads_stacked, gradient_transfer_config(codebook, compress),
            mesh=mesh, specs=tuple(P("pod") for _ in leaves))
        sess = plan.session()
        _SESSIONS[key] = sess
    return sess


def compressed_cross_pod_mean(grads_stacked, mesh: Mesh,
                              codebook: Codebook = DEFAULT_GRAD_CODEBOOK,
                              compress: bool = True):
    """(n_pod, ...)-stacked pod-partial grads -> pod-replicated mean grads.

    Input leaves are sharded P('pod', *param_spec); output leaves drop the pod
    dim and are replicated across pods (every pod computed the same sum)."""
    global last_stats
    if "pod" not in mesh.shape:
        # single-pod mesh: nothing to exchange, just average the leading dim
        return jax.tree.map(lambda g: jnp.mean(g.astype(jnp.float32), axis=0)
                            .astype(g.dtype), grads_stacked)
    sess = _session(grads_stacked, mesh, codebook, compress)
    out = sess.ring_reduce(grads_stacked, axis="pod", mean=True)
    last_stats = sess.last_stats
    return out


def cross_pod_wire_bytes(grads, n_pod: int = 2, compress: bool = True,
                         profile: str = "paper",
                         codebook: Codebook = DEFAULT_GRAD_CODEBOOK,
                         link_bw: float = 1.0) -> float:
    """Analytic DCN bytes per step for the ring exchange (for reports).

    The byte classes come from the gradient plan's route table and the
    compression ratio from the resolved codec profile (paper Table 2 or a
    calibration artifact) — not a hard-coded guess."""
    plan = TransferPlan.build(grads, gradient_transfer_config(
        codebook, compress), granularity="tensor")
    ratio = (resolve_profile(profile, link_bw=link_bw).ratio
             if compress else 1.0)
    return plan.collective_wire_bytes(ratio, n_hops=n_pod - 1)
