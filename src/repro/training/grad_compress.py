"""Compressed cross-pod gradient all-reduce (beyond-paper feature).

Hierarchical DP at multi-pod scale: within a pod, gradient reduction rides
the fast ICI (XLA's automatic all-reduce); *across* pods it crosses slow DCN.
Since pod-level gradients are bf16, the SplitZip codec applies verbatim —
**lossless**, so unlike lossy gradient compression (top-k, 1-bit Adam, ...)
it changes no optimization semantics; the only numerics are the same bf16
adds any all-reduce performs.

Mechanics: the caller produces *pod-partial* gradients with a leading pod dim
(via vmap over a pod-split batch — see train_step.py).  ``compressed_cross_pod_mean``
runs a shard_map over the mesh: each pod encodes its partial, a rotating-ring
exchange moves only the **compressed streams** over the pod axis (n_pod - 1
hops), each hop decodes + accumulates in fp32.  The ppermute operand bytes in
the lowered HLO shrink by ~1/rho vs a raw DCN all-reduce — this is the number
the roofline's collective term scores.

Leaves smaller than ``min_compress_elems`` ship raw (codec framing would not
pay for itself).
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map

from repro.core import codec as C
# default gradient codebook: bf16 gradients of normalized networks
# concentrate in small-magnitude exponents — the same sub-bias band as the
# shared activation fallback.  Refreshed by calibrate_on_grads.
from repro.core.codebook import (Codebook,
                                 DEFAULT_BF16_CODEBOOK as DEFAULT_GRAD_CODEBOOK)

MIN_COMPRESS_ELEMS = 16384


def calibrate_on_grads(grads, k: int = 16) -> Codebook:
    """Offline calibration pass over a representative gradient pytree."""
    import numpy as np
    from repro.core import codebook as cbm
    leaves = [np.asarray(jax.lax.bitcast_convert_type(
        g.astype(jnp.bfloat16), jnp.uint16)).ravel()
        for g in jax.tree.leaves(grads)]
    return cbm.calibrate(leaves, k=k)


def _ring_exchange_sum(x: jax.Array, codebook: Codebook, n_pod: int,
                       compress: bool) -> jax.Array:
    """Inside shard_map: rotate this pod's contribution around the ring,
    accumulating in fp32.  x: the local pod-partial gradient (bf16)."""
    perm = [(i, (i + 1) % n_pod) for i in range(n_pod)]
    acc = x.astype(jnp.float32)
    rotating = x
    for _ in range(n_pod - 1):
        if compress:
            ct = C.encode(rotating, codebook)
            moved = jax.tree.map(
                lambda s: jax.lax.ppermute(s, "pod", perm), ct)
            rotating = C.decode(moved)
        else:
            rotating = jax.lax.ppermute(rotating, "pod", perm)
        acc = acc + rotating.astype(jnp.float32)
    return acc


def compressed_cross_pod_mean(grads_stacked, mesh: Mesh,
                              codebook: Codebook = DEFAULT_GRAD_CODEBOOK,
                              compress: bool = True):
    """(n_pod, ...)-stacked pod-partial grads -> pod-replicated mean grads.

    Input leaves are sharded P('pod', *param_spec); output leaves drop the pod
    dim and are replicated across pods (every pod computed the same sum)."""
    if "pod" not in mesh.shape:
        # single-pod mesh: nothing to exchange, just average the leading dim
        return jax.tree.map(lambda g: jnp.mean(g.astype(jnp.float32), axis=0)
                            .astype(g.dtype), grads_stacked)
    n_pod = mesh.shape["pod"]

    leaves = jax.tree.leaves(grads_stacked)
    treedef = jax.tree_util.tree_structure(grads_stacked)

    in_specs = tuple(P("pod") for _ in leaves)
    out_specs = tuple(P() for _ in leaves)

    def body(*local_leaves):
        out = []
        for lf in local_leaves:
            x = lf[0]  # local pod slice, leading dim 1
            do_compress = compress and x.size >= MIN_COMPRESS_ELEMS \
                and x.dtype == jnp.bfloat16
            total = _ring_exchange_sum(x.astype(jnp.bfloat16), codebook,
                                       n_pod, do_compress)
            out.append((total / n_pod).astype(x.dtype))
        return tuple(out)

    summed = shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)(*leaves)
    return jax.tree_util.tree_unflatten(treedef, summed)


def cross_pod_wire_bytes(grads, ratio: float = 4 / 3, n_pod: int = 2,
                         compress: bool = True) -> float:
    """Analytic DCN bytes per step for the ring exchange (for reports)."""
    total = sum(g.size * 2 for g in jax.tree.leaves(grads))  # bf16 bytes
    per_hop = total / ratio if compress else total
    return per_hop * (n_pod - 1)
