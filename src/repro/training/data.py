"""Deterministic synthetic data pipeline (wikitext-like token statistics).

Offline container => no real corpora; the pipeline synthesizes token streams
with a Zipfian unigram distribution + short-range repetition structure, which
is what matters for (a) exercising the training loop at full shapes and
(b) producing KV activations with realistic exponent statistics for the
codec benchmarks.  Fully deterministic in (seed, step) so checkpoint-resume
reproduces the exact batch sequence — required by the fault-tolerance tests.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    zipf_a: float = 1.2          # unigram exponent
    repeat_p: float = 0.25       # P(copy a recent token) — adds structure


def _zipf_logits(vocab: int, a: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-a)
    return np.log(p / p.sum())


class SyntheticTokenStream:
    """Stateless batch generator: batch_at(step) is pure in (seed, step)."""

    def __init__(self, cfg: ArchConfig, shape: ShapeConfig, data_cfg: DataConfig = DataConfig()):
        self.cfg = cfg
        self.shape = shape
        self.data_cfg = data_cfg
        self._logits = jnp.asarray(_zipf_logits(cfg.vocab_size, data_cfg.zipf_a),
                                   jnp.float32)

    def batch_at(self, step: int, batch: int | None = None,
                 seq: int | None = None) -> Dict[str, jax.Array]:
        b = batch or self.shape.global_batch
        s = seq or self.shape.seq_len
        key = jax.random.fold_in(jax.random.PRNGKey(self.data_cfg.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        cfg = self.cfg

        if cfg.frontend == "audio_frames":
            frames = jax.random.normal(k1, (b, s, cfg.frontend_dim), jnp.bfloat16)
            labels = jax.random.categorical(k2, jnp.broadcast_to(
                self._logits, (b, s, cfg.vocab_size)))
            return {"frames": frames, "labels": labels.astype(jnp.int32)}

        s_text = s - cfg.frontend_len if cfg.frontend == "vision_patches" else s
        toks = jax.random.categorical(
            k1, jnp.broadcast_to(self._logits, (b, s_text + 1, cfg.vocab_size)))
        # short-range repetition: with prob repeat_p copy the token 1..8 back
        lag = jax.random.randint(k2, toks.shape, 1, 9)
        idx = jnp.maximum(jnp.arange(s_text + 1)[None, :] - lag, 0)
        copied = jnp.take_along_axis(toks, idx, axis=1)
        mask = jax.random.bernoulli(k3, self.data_cfg.repeat_p, toks.shape)
        toks = jnp.where(mask, copied, toks).astype(jnp.int32)

        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.frontend == "vision_patches":
            out["patches"] = jax.random.normal(
                jax.random.fold_in(k1, 7), (b, cfg.frontend_len, cfg.frontend_dim),
                jnp.bfloat16)
        return out

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
