"""Checkpoint save/restore with SplitZip wire compression.

Layout: one directory per step, one ``.szc`` blob per pytree leaf (SplitZip
wire format for bf16 leaves — ~25% smaller, bit-exact — raw npy bytes for
everything else) plus a JSON manifest with the treedef, shapes, dtypes, a
payload checksum per leaf, and the data-pipeline cursor.  Atomic via
write-to-temp + rename.  ``latest_step``/``restore`` implement the
fault-tolerance resume path; integrity failures fall back to the previous
checkpoint (tested by corrupting blobs).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import wire
from repro.core.codebook import Codebook

# checkpoint codec codebook: calibrated once on model-weight statistics;
# weights/optimizer bf16 state shares the activation exponent concentration.
CKPT_CODEBOOK = Codebook(fmt="bf16", exponents=tuple(range(113, 129)))

MANIFEST = "manifest.json"


def _leaf_paths(tree) -> Tuple[list, Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((key, leaf))
    return out, treedef


def _checksum(b: bytes) -> str:
    return hashlib.sha256(b).hexdigest()[:16]


def save(directory: str, step: int, tree, extra: Optional[Dict] = None,
         codebook: Codebook = CKPT_CODEBOOK) -> str:
    """Atomically write checkpoint for ``step``; returns the final path."""
    flat, _ = _leaf_paths(tree)
    final = os.path.join(directory, f"step_{step:010d}")
    os.makedirs(directory, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    try:
        for i, (key, leaf) in enumerate(flat):
            arr = np.asarray(leaf)
            fname = f"leaf_{i:05d}.szc"
            if arr.dtype == jnp.bfloat16:
                bits = np.asarray(
                    jax.lax.bitcast_convert_type(jnp.asarray(leaf), jnp.uint16))
                payload, stats = wire.encode(bits.ravel(), codebook)
                enc = "splitzip-bf16"
                ratio = stats.ratio
            else:
                payload = arr.tobytes()
                enc = "raw"
                ratio = 1.0
            with open(os.path.join(tmp, fname), "wb") as f:
                f.write(payload)
            manifest["leaves"][key] = {
                "file": fname, "enc": enc, "shape": list(arr.shape),
                "dtype": str(leaf.dtype), "checksum": _checksum(payload),
                "ratio": ratio,
            }
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


class CheckpointCorrupt(RuntimeError):
    pass


def _load_dir(path: str, tree_like) -> Tuple[Any, Dict]:
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    flat, treedef = _leaf_paths(tree_like)
    leaves = []
    for key, like in flat:
        meta = manifest["leaves"].get(key)
        if meta is None:
            raise CheckpointCorrupt(f"missing leaf {key}")
        with open(os.path.join(path, meta["file"]), "rb") as f:
            payload = f.read()
        if _checksum(payload) != meta["checksum"]:
            raise CheckpointCorrupt(f"checksum mismatch for {key}")
        shape = tuple(meta["shape"])
        if meta["enc"] == "splitzip-bf16":
            bits = wire.decode(payload).reshape(shape)
            arr = jax.lax.bitcast_convert_type(jnp.asarray(bits), jnp.bfloat16)
        else:
            arr = jnp.asarray(np.frombuffer(
                payload, dtype=np.dtype(meta["dtype"])).reshape(shape))
        leaves.append(arr.astype(like.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]


def steps_available(directory: str) -> list:
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        if d.startswith("step_"):
            out.append(int(d.split("_")[1]))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = steps_available(directory)
    return steps[-1] if steps else None


def restore(directory: str, tree_like, step: Optional[int] = None
            ) -> Tuple[Any, Dict, int]:
    """Load ``step`` (default latest); on corruption, fall back to the
    previous checkpoint (fault-tolerance requirement).  Returns
    (tree, extra, step_loaded)."""
    steps = steps_available(directory)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    candidates = [s for s in steps if step is None or s == step]
    for s in reversed(candidates):
        path = os.path.join(directory, f"step_{s:010d}")
        try:
            tree, extra = _load_dir(path, tree_like)
            return tree, extra, s
        except CheckpointCorrupt:
            continue
    raise CheckpointCorrupt(f"all candidate checkpoints corrupt in {directory}")


def checkpoint_bytes(directory: str, step: int) -> int:
    path = os.path.join(directory, f"step_{step:010d}")
    return sum(os.path.getsize(os.path.join(path, f)) for f in os.listdir(path))
