"""Checkpoint save/restore: a thin wrapper over the bulk-data plane.

Layout: one directory per step, one ``.szc`` SZ02 wire frame per pytree
leaf plus the plan-derived JSON manifest — written by the
:class:`~repro.serving.session.TransferSession` persistent executor
(``session.save``/``session.load``; normative format in
docs/wire_format.md §9).  This module only adds the step-directory
convention and the corruption-fallback policy: integrity failures
(:class:`~repro.core.wire.WireIntegrityError` after the plan's re-fetch
budget, truncated directories, structure drift) fall back to the previous
checkpoint.  Atomicity, Fletcher-32 verification, fault-injection hooks,
and :class:`~repro.serving.plan.TransferStats` accounting all come from
the session — there is no codec, wire, or hash code here by design
(CI-grep-guarded).
"""

from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

import jax

from repro.core.codebook import Codebook
from repro.core.wire import WireIntegrityError
from repro.serving.plan import TransferConfig, TransferPlan, TransferStats
from repro.serving.session import (PERSIST_MANIFEST, TransferIntegrityError,
                                   TransferSession)

# checkpoint codec codebook: calibrated once on model-weight statistics;
# weights/optimizer bf16 state shares the activation exponent concentration.
CKPT_CODEBOOK = Codebook(fmt="bf16", exponents=tuple(range(113, 129)))

MANIFEST = PERSIST_MANIFEST


class CheckpointCorrupt(RuntimeError):
    pass


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:010d}")


class Checkpointer:
    """Session-backed checkpoint manager.

    One :class:`TransferPlan` per state structure (cached across calls — the
    plan is a property of the model, not of the step), executed by the
    persistent executor.  ``faults=`` / ``verify=`` thread straight into the
    session, so recovery drills run the same re-fetch machinery production
    would.  ``stats`` aggregates :class:`TransferStats` across every
    save/restore this manager ran (``refetches`` and ``refetch_wire_bytes``
    accumulate even for candidate steps that were ultimately abandoned)."""

    def __init__(self, directory: str, *, codebook: Codebook = CKPT_CODEBOOK,
                 compress_fp32: bool = True, faults=None):
        self.directory = directory
        self.tc = TransferConfig(codebook=codebook, backend="wire",
                                 compress_fp32=compress_fp32)
        self.faults = faults
        self._sessions: Dict[Any, TransferSession] = {}
        self.stats = TransferStats(chunk_wire_bytes=[], chunk_ok=[],
                                   raw_passthrough_bytes=0.0, n_elements=0)

    def _session(self, tree) -> TransferSession:
        flat, treedef = jax.tree_util.tree_flatten(tree)
        key = (treedef, tuple((tuple(x.shape), str(x.dtype)) for x in flat))
        sess = self._sessions.get(key)
        if sess is None:
            plan = TransferPlan.build(tree, self.tc)
            sess = plan.session(faults=self.faults)
            self._sessions[key] = sess
        return sess

    def _merge(self, s: Optional[TransferStats]) -> None:
        if s is None:
            return
        agg = self.stats
        agg.raw_passthrough_bytes += s.raw_passthrough_bytes
        agg.fp32_lo_wire_bytes += s.fp32_lo_wire_bytes
        agg.fp8_wire_bytes += s.fp8_wire_bytes
        agg.verify_failures += s.verify_failures
        agg.refetches += s.refetches
        agg.raw_refetches += s.raw_refetches
        agg.refetch_wire_bytes += s.refetch_wire_bytes
        agg.faults_injected += s.faults_injected
        agg.fault_delay_s += s.fault_delay_s
        agg.n_elements = s.n_elements
        agg.leaf_wire_bytes.update(s.leaf_wire_bytes)
        agg.leaf_ok.update(s.leaf_ok)

    def save(self, step: int, tree, extra: Optional[Dict] = None) -> str:
        """Atomically write the checkpoint for ``step``; returns its path."""
        sess = self._session(tree)
        path = sess.save(_step_dir(self.directory, step), tree,
                         extra=extra or {})
        self._merge(sess.last_stats)
        return path

    def restore(self, tree_like, step: Optional[int] = None
                ) -> Tuple[Any, Dict, int]:
        """Load ``step`` (default latest), bit-exactly; on corruption —
        persistent integrity failure past the session's re-fetch budget,
        missing files, structure drift — fall back to the previous
        checkpoint.  Returns ``(tree, extra, step_loaded)``."""
        steps = steps_available(self.directory)
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {self.directory}")
        sess = self._session(tree_like)
        candidates = [s for s in steps if step is None or s == step]
        for s in reversed(candidates):
            try:
                tree, extra = sess.load(_step_dir(self.directory, s))
                self._merge(sess.last_stats)
                return tree, extra, s
            except (WireIntegrityError, TransferIntegrityError, OSError,
                    KeyError, ValueError):
                self._merge(sess.last_stats)
                continue
        raise CheckpointCorrupt(
            f"all candidate checkpoints corrupt in {self.directory}")


# -- module-level convenience API (one-shot managers) ------------------------

def save(directory: str, step: int, tree, extra: Optional[Dict] = None,
         codebook: Codebook = CKPT_CODEBOOK) -> str:
    """Atomically write checkpoint for ``step``; returns the final path."""
    return Checkpointer(directory, codebook=codebook).save(step, tree, extra)


def restore(directory: str, tree_like, step: Optional[int] = None
            ) -> Tuple[Any, Dict, int]:
    """Load ``step`` (default latest); on corruption, fall back to the
    previous checkpoint (fault-tolerance requirement).  Returns
    (tree, extra, step_loaded)."""
    return Checkpointer(directory).restore(tree_like, step)


def steps_available(directory: str) -> list:
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        if d.startswith("step_"):
            out.append(int(d.split("_")[1]))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = steps_available(directory)
    return steps[-1] if steps else None


def checkpoint_bytes(directory: str, step: int) -> int:
    path = _step_dir(directory, step)
    return sum(os.path.getsize(os.path.join(path, f)) for f in os.listdir(path))
