"""Fault tolerance for 1000+-node posture: failure detection, checkpoint-
based restart, straggler mitigation, and an orchestration loop that survives
injected faults (tested in tests/test_fault_tolerance.py).

On a real multi-pod deployment these hooks bind to the cluster manager
(heartbeats over DCN, jax.distributed); in this repo the *logic* is real and
driven by an injectable clock/failure source so every policy is unit-testable
on CPU.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional


@dataclasses.dataclass
class WorkerHealth:
    worker_id: int
    last_heartbeat: float
    step_times: List[float] = dataclasses.field(default_factory=list)
    alive: bool = True


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    heartbeat_timeout_s: float = 60.0
    straggler_factor: float = 2.0      # step_time > factor * median => straggler
    straggler_window: int = 8
    max_restarts: int = 16
    checkpoint_every: int = 50


class FailureDetector:
    """Heartbeat + straggler detection over a worker fleet."""

    def __init__(self, n_workers: int, cfg: FaultConfig, clock=time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.workers = {i: WorkerHealth(i, clock()) for i in range(n_workers)}

    def heartbeat(self, worker_id: int, step_time: Optional[float] = None):
        w = self.workers[worker_id]
        w.last_heartbeat = self.clock()
        # a renewed heartbeat REVIVES a worker previously declared dead (the
        # process restarted, or the partition healed); consumers that cached
        # a newly_dead() report see the revival on their next poll
        w.alive = True
        if step_time is not None:
            w.step_times.append(step_time)
            if len(w.step_times) > self.cfg.straggler_window:
                w.step_times.pop(0)

    def timed_out(self) -> List[int]:
        """PURE detection: alive workers whose heartbeat has lapsed.  No
        state changes — repeated calls agree until a heartbeat or a
        :meth:`newly_dead` transition intervenes."""
        now = self.clock()
        return [w.worker_id for w in self.workers.values()
                if w.alive
                and now - w.last_heartbeat > self.cfg.heartbeat_timeout_s]

    def newly_dead(self) -> List[int]:
        """Detection + state transition: marks every timed-out worker dead
        and returns them.  Each death is reported exactly once (until a
        renewed heartbeat revives the worker)."""
        out = self.timed_out()
        for wid in out:
            self.workers[wid].alive = False
        return out

    def dead_workers(self) -> List[int]:
        """ALL currently-dead workers (idempotent).  This used to mutate
        ``alive`` as a detection side effect, so a second poll within one
        timeout window returned [] and the caller believed the fleet had
        healed; detection now lives in :meth:`timed_out`/:meth:`newly_dead`
        and this is a pure view (lapsed heartbeats are swept in first so
        single-method pollers still observe deaths)."""
        self.newly_dead()
        return sorted(w.worker_id for w in self.workers.values()
                      if not w.alive)

    def stragglers(self) -> List[int]:
        med = self._median_step_time()
        if med is None:
            return []
        out = []
        for w in self.workers.values():
            if not w.alive or not w.step_times:
                continue
            recent = sum(w.step_times[-3:]) / min(3, len(w.step_times))
            if recent > self.cfg.straggler_factor * med:
                out.append(w.worker_id)
        return out

    def _median_step_time(self) -> Optional[float]:
        all_means = [sum(w.step_times) / len(w.step_times)
                     for w in self.workers.values() if w.alive and w.step_times]
        if not all_means:
            return None
        s = sorted(all_means)
        return s[len(s) // 2]

    def alive_count(self) -> int:
        return sum(1 for w in self.workers.values() if w.alive)


@dataclasses.dataclass
class RunReport:
    steps_completed: int
    restarts: int
    failures_seen: int
    stragglers_mitigated: int
    final_loss: Optional[float] = None
    # Aggregated TransferStats when save/restore ran through a session-backed
    # Checkpointer (refetches, verify failures, wire bytes — the recovery
    # path's delivery is verified AND accounted, not best-effort).
    transfer_stats: Optional[Any] = None


class ResilientTrainer:
    """Checkpoint-restart training driver.

    ``step_fn(state, step_idx) -> (state, metrics)`` is the jitted step;
    ``save_fn(step, state)`` / ``restore_fn() -> (state, step)`` are bare
    closures, OR pass ``checkpointer=`` (a
    :class:`repro.distributed.checkpoint.Checkpointer`) and both bind to the
    bulk-data plane's persistent executor — recovery then inherits verified
    delivery (Fletcher-32 + re-fetch budget + previous-step fallback) and
    surfaces the accumulated :class:`TransferStats` on the
    :class:`RunReport`.  ``fault_source(step) -> Optional[str]`` lets tests
    inject 'crash' / 'straggler:<id>' events deterministically.
    """

    def __init__(self, step_fn, save_fn=None, restore_fn=None,
                 cfg: FaultConfig = FaultConfig(),
                 detector: Optional[FailureDetector] = None,
                 fault_source: Optional[Callable[[int], Optional[str]]] = None,
                 *, checkpointer=None):
        if checkpointer is not None and (save_fn or restore_fn):
            raise ValueError("pass save_fn/restore_fn or checkpointer=, "
                             "not both")
        if checkpointer is None and (save_fn is None or restore_fn is None):
            raise ValueError("need save_fn+restore_fn or checkpointer=")
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.checkpointer = checkpointer
        self.cfg = cfg
        self.detector = detector
        self.fault_source = fault_source or (lambda s: None)

    def _save(self, step: int, state) -> None:
        if self.checkpointer is not None:
            self.checkpointer.save(step, state)
        else:
            self.save_fn(step, state)

    def _restore(self, state_like, init_state):
        if self.checkpointer is None:
            return self.restore_fn()
        from repro.distributed.checkpoint import CheckpointCorrupt
        try:
            tree, _extra, step = self.checkpointer.restore(state_like)
            return tree, step
        except FileNotFoundError:
            # crashed before the first checkpoint: cold restart
            return init_state, 0
        except CheckpointCorrupt:
            # every candidate exhausted its re-fetch budget; the stats
            # already carry the verify failures — cold restart is the only
            # semantically safe continuation
            return init_state, 0

    def run(self, state, total_steps: int) -> RunReport:
        restarts = failures = mitigated = 0
        step = 0
        loss = None
        init_state = state
        while step < total_steps:
            fault = self.fault_source(step)
            if fault == "crash":
                failures += 1
                restarts += 1
                if restarts > self.cfg.max_restarts:
                    raise RuntimeError("restart budget exhausted")
                state, step = self._restore(state, init_state)
                continue
            if fault and fault.startswith("straggler"):
                # deadline-based mitigation: drop the straggler's microbatch
                # contribution this step (gradient is an equal-weight mean of
                # the survivors) rather than stalling the whole fleet
                mitigated += 1
            state, metrics = self.step_fn(state, step)
            loss = float(metrics.get("loss", float("nan"))) if metrics else None
            step += 1
            if step % self.cfg.checkpoint_every == 0 or step == total_steps:
                self._save(step, state)
        return RunReport(steps_completed=step, restarts=restarts,
                         failures_seen=failures, stragglers_mitigated=mitigated,
                         final_loss=loss,
                         transfer_stats=(self.checkpointer.stats
                                         if self.checkpointer is not None
                                         else None))
