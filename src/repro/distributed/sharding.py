"""Divisibility-aware sharding rules for params, activations and caches.

Production posture (DESIGN.md §5): mesh axes are ``(pod, data, model)`` (DCN ×
ICI × ICI).  DP runs over (pod, data); TP/EP over model.  Rules shard a tensor
dim on an axis only when the dim divides the axis size — otherwise the dim is
replicated (e.g. minitron's 24 heads never shard over model=16; its attention
falls back to sequence sharding via the activation rules).

Model code never names mesh axes directly: it calls ``constrain(x, kind)``,
which is a no-op unless a :class:`ShardingPolicy` is active (smoke tests run
without one; jitted programs install one via ``use_policy``).
"""

from __future__ import annotations

import contextlib
import dataclasses
import re
import threading
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

DP_AXES = ("pod", "data")  # flattened data-parallel axes (present subset used)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        if a in mesh.shape:
            n *= mesh.shape[a]
    return n


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Resolves logical shard requests against a concrete mesh."""

    mesh: Mesh
    # how to shard attention activations when heads don't divide 'model':
    #   'seq'  — shard the sequence dim over model (sequence parallelism)
    #   'none' — replicate over model
    attn_fallback: str = "seq"
    # ZeRO-3/FSDP: additionally shard params + optimizer state over 'data'
    # (within-pod ICI; pods stay pure DP so no param gathers cross the DCN).
    # XLA inserts the per-layer all-gather at use sites.
    fsdp: bool = False
    # constrain MoE dispatch intermediates (token buffers over dp, expert
    # buffers over model) instead of letting GSPMD guess — see models/moe.py
    moe_dispatch_sharding: bool = False
    # PD-disaggregated serving: the 'pod' axis separates prefill/decode
    # workers, so activations/caches shard over 'data' only (replicated over
    # 'pod'); the pod axis is reserved for the KV-transfer DCN hop.
    pd_disaggregated: bool = False

    def dp_axes(self) -> Tuple[str, ...]:
        axes = DP_AXES if not self.pd_disaggregated else ("data",)
        return tuple(a for a in axes if a in self.mesh.shape)

    def fsdp_axes(self) -> Tuple[str, ...]:
        return ("data",) if ("data" in self.mesh.shape and self.fsdp) else ()

    def dp_size(self) -> int:
        return _axis_size(self.mesh, self.dp_axes())

    def tp_size(self) -> int:
        return _axis_size(self.mesh, "model")

    # -- helpers ---------------------------------------------------------------
    def _maybe(self, dim: int, axes):
        """axes if dim divides their product (and dim is concrete), else None.

        Singleton axis tuples are unwrapped to the bare name: P(('data',),) and
        P('data',) are semantically identical but compare unequal on jax
        versions that don't normalize PartitionSpec entries."""
        n = _axis_size(self.mesh, axes)
        if n > 1 and dim % n == 0:
            if isinstance(axes, tuple) and len(axes) == 1:
                return axes[0]
            return axes
        return None

    def spec_for_activation(self, kind: str, shape: Tuple[int, ...]) -> P:
        dp = self.dp_axes()
        tp = "model" if "model" in self.mesh.shape else None
        if kind == "btd":            # (B, S, D) hidden states
            b = self._maybe(shape[0], dp)
            return P(b, None, None)
        if kind == "btd_seq":        # (B, S, D) sequence-sharded over model
            b = self._maybe(shape[0], dp)
            s = self._maybe(shape[1], tp)
            return P(b, s, None)
        if kind == "bthd":           # (B, S, H, hd) attention activations
            b = self._maybe(shape[0], dp)
            h = self._maybe(shape[2], tp)
            if h is not None:
                return P(b, None, h, None)
            if self.attn_fallback == "seq":
                s = self._maybe(shape[1], tp)
                return P(b, s, None, None)
            return P(b, None, None, None)
        if kind == "logits":         # (B, S, V) or (B, V)
            b = self._maybe(shape[0], dp)
            v = self._maybe(shape[-1], tp)
            spec = [b] + [None] * (len(shape) - 2) + [v]
            return P(*spec)
        if kind == "kvcache":        # (B, S, Hkv, hd) or (B, S, r)
            b = self._maybe(shape[0], dp)
            s = self._maybe(shape[1], tp)
            spec = [b, s] + [None] * (len(shape) - 2)
            return P(*spec)
        if kind == "state":          # (B, ...) recurrent states
            b = self._maybe(shape[0], dp)
            return P(*([b] + [None] * (len(shape) - 1)))
        if kind == "tokens":         # (B, S) int
            b = self._maybe(shape[0], dp)
            return P(*([b] + [None] * (len(shape) - 1)))
        # --- MoE dispatch intermediates (models/moe.py) ----------------------
        if kind == "moe_td":         # (T, D) flattened token stream
            if not self.moe_dispatch_sharding:
                return None
            t = self._maybe(shape[0], dp)
            return P(t, None)
        if kind == "moe_te":         # (T, E) router probs/logits
            if not self.moe_dispatch_sharding:
                return None
            t = self._maybe(shape[0], dp)
            return P(t, None)
        if kind == "moe_ecd":        # (E, C, D) expert compute buffers
            if not self.moe_dispatch_sharding:
                return None
            e = self._maybe(shape[0], tp)
            return P(e, None, None)
        if kind == "moe_ecf":        # (E, C, F) expert hidden activations
            if not self.moe_dispatch_sharding:
                return None
            e = self._maybe(shape[0], tp)
            return P(e, None, None)
        raise KeyError(f"unknown activation kind {kind!r}")

    def spec_for_cache(self, name: str, shape: Tuple[int, ...]) -> P:
        """Layer-stacked inference caches (see models/kvcache.py layouts)."""
        dp = self.dp_axes()
        tp = "model" if "model" in self.mesh.shape else None
        leaf = name.split("/")[-1]
        if leaf in ("k", "v", "ckv", "krope"):      # (L, B, S, ...)
            b = self._maybe(shape[1], dp)
            s = self._maybe(shape[2], tp)
            return P(None, b, s, *([None] * (len(shape) - 3)))
        if leaf == "ssm":                            # (L, B, H, P, N)
            b = self._maybe(shape[1], dp)
            h = self._maybe(shape[2], tp)
            return P(None, b, h, None, None)
        if leaf == "conv":                           # (L, B, W-1, C)
            b = self._maybe(shape[1], dp)
            c = self._maybe(shape[3], tp)
            return P(None, b, None, c)
        if leaf in ("attn_k", "attn_v"):             # (nt, B, W, Hkv, hd)
            b = self._maybe(shape[1], dp)
            h = self._maybe(shape[3], tp)
            return P(None, b, None, h, None)
        if leaf == "rec_h":                          # (nt, 2, B, U)
            b = self._maybe(shape[2], dp)
            u = self._maybe(shape[3], tp)
            return P(None, None, b, u)
        if leaf == "rec_conv":                       # (nt, 2, B, cw-1, U)
            b = self._maybe(shape[2], dp)
            u = self._maybe(shape[4], tp)
            return P(None, None, b, None, u)
        if leaf == "extra_h":                        # (ne, B, U)
            b = self._maybe(shape[1], dp)
            u = self._maybe(shape[2], tp)
            return P(None, b, u)
        if leaf == "extra_conv":                     # (ne, B, cw-1, U)
            b = self._maybe(shape[1], dp)
            u = self._maybe(shape[3], tp)
            return P(None, b, None, u)
        # unknown cache leaf: batch-only
        return P(*([None] + [self._maybe(shape[1], dp)] +
                   [None] * (len(shape) - 2))) if len(shape) > 1 else P(None)

    def cache_specs(self, cache):
        """Pytree of PartitionSpecs matching ``cache`` (arrays or SDS).

        This is what a mesh-targeted :class:`~repro.serving.plan.TransferPlan`
        consumes as ``specs=``: the plan resolves the per-leaf shard layout
        once at build time instead of re-deriving it per transfer call."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
        out = []
        for path, leaf in flat:
            name = "/".join(_key_str(k) for k in path)
            out.append(self.spec_for_cache(name, tuple(leaf.shape)))
        return jax.tree_util.tree_unflatten(treedef, out)

    def cache_sharding(self, cache):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            self.cache_specs(cache),
                            is_leaf=lambda x: isinstance(x, P))

    # -- parameter rules ---------------------------------------------------------
    # matched against the '/'-joined param path, first hit wins
    PARAM_RULES = (
        # (regex, dims-spec builder name)
        (re.compile(r"(embed|tok_embed)$"), "vocab_row"),        # (V, D)
        (re.compile(r"lm_head$"), "vocab_col"),                  # (D, V)
        (re.compile(r"w[qkv]$"), "heads_mid"),                   # (D, H, hd)
        (re.compile(r"wo$"), "heads_first"),                     # (H, hd, D)
        (re.compile(r"w_(gate|up)$"), "ff_col"),                 # (D, F)
        (re.compile(r"w_down$"), "ff_row"),                      # (F, D)
        (re.compile(r"w_gate_up$"), "expert"),                   # (E, D, 2F)
        (re.compile(r"router$"), "replicate"),
        (re.compile(r"wq_a$|wkv_a$"), "ff_col"),                 # (D, r)
        (re.compile(r"wq_b$|wkv_b$"), "mla_b"),                  # (r, H, ·)
        (re.compile(r"in_proj$"), "ff_col"),                     # (D, K)
        (re.compile(r"out_proj$|w_out$"), "ff_row"),             # (K, D)
        (re.compile(r"w_gate_branch$|w_in$"), "ff_col"),
        (re.compile(r"w_a$|w_x$"), "lru_sq"),                    # (U, U)
        (re.compile(r"frontend_proj$"), "ff_col"),
    )

    def spec_for_param(self, path: str, shape: Tuple[int, ...]) -> P:
        tp = "model" if "model" in self.mesh.shape else None
        # leading layer-stack dim (scan over layers) is never sharded
        lead = ()
        if path.startswith("layers/") or "/stack/" in path or path.startswith("triples/"):
            lead = (None,)
            shape = shape[1:]
        kind = "replicate"
        leaf = path.split("/")[-1]
        for rx, k in self.PARAM_RULES:
            if rx.search(leaf):
                kind = k
                break
        def mk(*spec):
            if self.fsdp:
                spec = self._add_fsdp(spec, shape)
            return P(*(lead + spec))
        if len(shape) == 0:
            return mk()
        if kind == "vocab_row":
            return mk(self._maybe(shape[0], tp), *([None] * (len(shape) - 1)))
        if kind == "vocab_col":
            return mk(*([None] * (len(shape) - 1)), self._maybe(shape[-1], tp))
        if kind == "heads_mid" and len(shape) == 3:
            h = self._maybe(shape[1], tp)
            return mk(None, h, None)
        if kind == "heads_first" and len(shape) == 3:
            h = self._maybe(shape[0], tp)
            return mk(h, None, None)
        if kind == "ff_col":
            return mk(*([None] * (len(shape) - 1)), self._maybe(shape[-1], tp))
        if kind == "ff_row":
            return mk(self._maybe(shape[0], tp), *([None] * (len(shape) - 1)))
        if kind == "expert":
            return mk(self._maybe(shape[0], tp), *([None] * (len(shape) - 1)))
        if kind == "mla_b" and len(shape) == 3:
            h = self._maybe(shape[1], tp)
            return mk(None, h, None)
        if kind == "lru_sq":
            return mk(*([None] * (len(shape) - 1)), self._maybe(shape[-1], tp))
        return mk(*([None] * len(shape)))

    def _add_fsdp(self, spec, shape):
        """ZeRO-3: place 'data' on the largest still-unsharded divisible dim.
        Leaves too-small params (norm scales, biases) replicated — the cost
        of gathering them is larger than the memory they hold."""
        axes = self.fsdp_axes()
        n = _axis_size(self.mesh, axes)
        if n <= 1:
            return spec
        spec = list(spec) + [None] * (len(shape) - len(spec))
        cands = [i for i, s in enumerate(spec)
                 if s is None and i < len(shape) and shape[i] % n == 0
                 and shape[i] >= 4 * n]
        if cands:
            best = max(cands, key=lambda i: shape[i])
            spec[best] = axes if len(axes) > 1 else axes[0]
        return tuple(spec)

    def param_sharding(self, params) -> "jax.tree_util.PyTreeDef":
        """Pytree of NamedShardings matching ``params`` (arrays or SDS)."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        out = []
        for path, leaf in flat:
            pstr = "/".join(_key_str(k) for k in path)
            spec = self.spec_for_param(pstr, tuple(leaf.shape))
            out.append(NamedSharding(self.mesh, spec))
        return jax.tree_util.tree_unflatten(treedef, out)

    def param_specs(self, params):
        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        out = []
        for path, leaf in flat:
            pstr = "/".join(_key_str(k) for k in path)
            out.append(self.spec_for_param(pstr, tuple(leaf.shape)))
        return jax.tree_util.tree_unflatten(treedef, out)


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


# ---------------------------------------------------------------------------
# thread-local policy + constrain()
# ---------------------------------------------------------------------------

def current_policy() -> Optional[ShardingPolicy]:
    return getattr(_STATE, "policy", None)


@contextlib.contextmanager
def use_policy(policy: Optional[ShardingPolicy]):
    prev = current_policy()
    _STATE.policy = policy
    try:
        yield
    finally:
        _STATE.policy = prev


def constrain(x: jax.Array, kind: str) -> jax.Array:
    """Apply an activation sharding constraint if a policy is active."""
    pol = current_policy()
    if pol is None:
        return x
    spec = pol.spec_for_activation(kind, tuple(x.shape))
    if spec is None:  # policy declines to constrain this kind
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(pol.mesh, spec))


def constrain_tree(tree, kind: str):
    pol = current_policy()
    if pol is None:
        return tree
    return jax.tree.map(lambda x: constrain(x, kind), tree)
