"""Elastic scaling: legal mesh enumeration + re-mesh planning after capacity
changes (node loss / scale-up), preserving DP/TP semantics.

A (pod, data, model) mesh is *legal* for an arch/shape when
  - global_batch % (pod*data) == 0            (DP divisibility)
  - the model's TP-shardable dims tolerate 'model' (the divisibility-aware
    rules replicate what doesn't divide, so any model size is legal, but we
    prefer meshes that keep FFN/vocab sharded)
Re-mesh = pick the best legal mesh for the surviving chip count, then
checkpoint-restore resharding (parameters are saved shard-agnostically).
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.codebook import DEFAULT_BF16_CODEBOOK, Codebook
from repro.launch.mesh import make_mesh
from repro.serving.plan import TransferConfig, TransferPlan, TransferStats


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    score: float

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def _divisors(n: int) -> List[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def legal_meshes(n_chips: int, cfg: ArchConfig, shape: ShapeConfig,
                 multi_pod: bool = False, n_pods: int = 1) -> List[MeshPlan]:
    """Enumerate (data, model) splits of n_chips (per pod), scored."""
    plans = []
    per_pod = n_chips // n_pods if multi_pod else n_chips
    for model in _divisors(per_pod):
        data = per_pod // model
        dp = data * (n_pods if multi_pod else 1)
        # DP divisibility: every replica needs a non-empty, equal batch
        # slice.  (This must also reject dp > global_batch — those meshes
        # would give some replicas a zero per-replica batch.)
        if shape.global_batch % dp != 0:
            continue
        score = 0.0
        # prefer: FFN sharded, vocab sharded, heads sharded, batch not over-split
        if cfg.d_ff and cfg.d_ff % model == 0:
            score += 2.0
        if cfg.vocab_size % model == 0:
            score += 1.5
        if cfg.num_heads and cfg.num_heads % model == 0:
            score += 1.0
        # mild preference for more TP on big models (memory), more DP on small
        big = cfg.param_count() > 8e9
        score += 0.01 * (model if big else data)
        if multi_pod:
            plans.append(MeshPlan((n_pods, data, model),
                                  ("pod", "data", "model"), score))
        else:
            plans.append(MeshPlan((data, model), ("data", "model"), score))
    return sorted(plans, key=lambda p: -p.score)


def replan_after_failure(current: MeshPlan, surviving_chips: int,
                         cfg: ArchConfig, shape: ShapeConfig) -> Optional[MeshPlan]:
    """Best legal mesh at the surviving capacity (None if impossible)."""
    multi = "pod" in current.axes
    n_pods = current.shape[0] if multi else 1
    if multi and surviving_chips < n_pods:
        multi, n_pods = False, 1
    # round down to a power-of-two-ish usable chip count for clean meshes
    usable = surviving_chips
    while usable > 0:
        plans = legal_meshes(usable, cfg, shape, multi_pod=multi, n_pods=n_pods)
        if plans:
            return plans[0]
        usable -= 1
    return None


def reshard(state, old_mesh_plan: Optional[MeshPlan],
            new_mesh_plan: MeshPlan, *, shardings=None,
            codebook: Codebook = DEFAULT_BF16_CODEBOOK,
            compress_fp32: bool = True, faults=None, verify: bool = False
            ) -> Tuple[Any, TransferStats]:
    """Ship ``state`` from ``old_mesh_plan``'s configuration onto
    ``new_mesh_plan``'s mesh through the bulk-data plane: one
    :class:`TransferPlan` over the state pytree, host-staged splitzip
    streams via the session's tensor executor (bit-exact; fp32 rides the
    hi/lo split), then ``device_put`` onto the new mesh.  The old mesh may
    already be gone (that's the point — after a node loss the state is only
    host-addressable), so the hop never touches old-mesh collectives.

    ``shardings``: optional pytree of :class:`NamedSharding` matching
    ``state``; defaults to replicated on the new mesh (the training step's
    own ``ShardingPolicy`` re-shards parameters lazily on first use).
    ``faults=`` / ``verify=`` thread into the session so recovery drills
    exercise the wire-integrity re-fetch path.  Returns
    ``(state_on_new_mesh, TransferStats)``."""
    if new_mesh_plan.n_devices > jax.device_count():
        raise ValueError(
            f"new mesh {new_mesh_plan.shape} needs {new_mesh_plan.n_devices} "
            f"devices; only {jax.device_count()} visible")
    tc = TransferConfig(codebook=codebook, backend="wire",
                        compress_fp32=compress_fp32)
    sess = TransferPlan.build(state, tc).session(faults=faults, verify=verify)
    if shardings is None:
        mesh = make_mesh(new_mesh_plan.shape, new_mesh_plan.axes)
        repl = NamedSharding(mesh, P())
        shardings = jax.tree.map(lambda _: repl, state)
    out = sess.reshard(state, shardings)
    return out, sess.last_stats


@dataclasses.dataclass
class ElasticEvent:
    step: int
    kind: str                 # 'shrink' | 'grow'
    chips_delta: int


def simulate_elastic_run(events: List[ElasticEvent], start_chips: int,
                         cfg: ArchConfig, shape: ShapeConfig) -> List[MeshPlan]:
    """Drive replanning through a capacity-change schedule; returns the mesh
    history (used by tests + the elasticity example)."""
    chips = start_chips
    plan = legal_meshes(chips, cfg, shape)[0]
    history = [plan]
    for ev in sorted(events, key=lambda e: e.step):
        chips = max(1, chips + ev.chips_delta)
        nxt = replan_after_failure(plan, chips, cfg, shape)
        if nxt is None:
            raise RuntimeError(f"no legal mesh at {chips} chips")
        plan = nxt
        history.append(plan)
    return history
