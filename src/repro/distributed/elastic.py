"""Elastic scaling: legal mesh enumeration + re-mesh planning after capacity
changes (node loss / scale-up), preserving DP/TP semantics.

A (pod, data, model) mesh is *legal* for an arch/shape when
  - global_batch % (pod*data) == 0            (DP divisibility)
  - the model's TP-shardable dims tolerate 'model' (the divisibility-aware
    rules replicate what doesn't divide, so any model size is legal, but we
    prefer meshes that keep FFN/vocab sharded)
Re-mesh = pick the best legal mesh for the surviving chip count, then
checkpoint-restore resharding (parameters are saved shard-agnostically).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    score: float

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def _divisors(n: int) -> List[int]:
    return [d for d in range(1, n + 1) if n % d == 0]


def legal_meshes(n_chips: int, cfg: ArchConfig, shape: ShapeConfig,
                 multi_pod: bool = False, n_pods: int = 1) -> List[MeshPlan]:
    """Enumerate (data, model) splits of n_chips (per pod), scored."""
    plans = []
    per_pod = n_chips // n_pods if multi_pod else n_chips
    for model in _divisors(per_pod):
        data = per_pod // model
        dp = data * (n_pods if multi_pod else 1)
        if shape.global_batch % dp and shape.global_batch >= dp:
            continue
        score = 0.0
        # prefer: FFN sharded, vocab sharded, heads sharded, batch not over-split
        if cfg.d_ff and cfg.d_ff % model == 0:
            score += 2.0
        if cfg.vocab_size % model == 0:
            score += 1.5
        if cfg.num_heads and cfg.num_heads % model == 0:
            score += 1.0
        if shape.global_batch % dp == 0 and shape.global_batch // dp >= 1:
            score += 1.0
        # mild preference for more TP on big models (memory), more DP on small
        big = cfg.param_count() > 8e9
        score += 0.01 * (model if big else data)
        if multi_pod:
            plans.append(MeshPlan((n_pods, data, model),
                                  ("pod", "data", "model"), score))
        else:
            plans.append(MeshPlan((data, model), ("data", "model"), score))
    return sorted(plans, key=lambda p: -p.score)


def replan_after_failure(current: MeshPlan, surviving_chips: int,
                         cfg: ArchConfig, shape: ShapeConfig) -> Optional[MeshPlan]:
    """Best legal mesh at the surviving capacity (None if impossible)."""
    multi = "pod" in current.axes
    n_pods = current.shape[0] if multi else 1
    if multi and surviving_chips < n_pods:
        multi, n_pods = False, 1
    # round down to a power-of-two-ish usable chip count for clean meshes
    usable = surviving_chips
    while usable > 0:
        plans = legal_meshes(usable, cfg, shape, multi_pod=multi, n_pods=n_pods)
        if plans:
            return plans[0]
        usable -= 1
    return None


@dataclasses.dataclass
class ElasticEvent:
    step: int
    kind: str                 # 'shrink' | 'grow'
    chips_delta: int


def simulate_elastic_run(events: List[ElasticEvent], start_chips: int,
                         cfg: ArchConfig, shape: ShapeConfig) -> List[MeshPlan]:
    """Drive replanning through a capacity-change schedule; returns the mesh
    history (used by tests + the elasticity example)."""
    chips = start_chips
    plan = legal_meshes(chips, cfg, shape)[0]
    history = [plan]
    for ev in sorted(events, key=lambda e: e.step):
        chips = max(1, chips + ev.chips_delta)
        nxt = replan_after_failure(plan, chips, cfg, shape)
        if nxt is None:
            raise RuntimeError(f"no legal mesh at {chips} chips")
        plan = nxt
        history.append(plan)
    return history
