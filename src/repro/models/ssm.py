"""Mamba-2 block: SSD (state-space duality) chunked scan + recurrent decode.

Implements the SSD algorithm of arXiv:2405.21060 (§6): the sequence is split
into chunks; intra-chunk terms are batched matmuls (MXU-friendly), inter-chunk
terms reduce to a tiny state recurrence (lax.scan over chunks with carry
(B, H, P, N)).  Decode is the exact single-step SSM recurrence.

The transferred "KV cache" for PD serving is (ssm_state, conv_state) — both
bf16, both SplitZip-compressible (DESIGN.md §4).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models import scanctl
from repro.models.layers import rms_norm


class SSMState(NamedTuple):
    ssm: jax.Array        # (B, H, P, N) fp32 recurrent state
    conv: jax.Array       # (B, conv_width-1, conv_channels) rolling buffer


def _dims(d_model: int, cfg: SSMConfig):
    d_inner = cfg.expand * d_model
    heads = d_inner // cfg.head_dim
    conv_ch = d_inner + 2 * cfg.n_groups * cfg.d_state
    return d_inner, heads, conv_ch


def init_mamba2(key, d_model: int, cfg: SSMConfig):
    ks = jax.random.split(key, 6)
    d_inner, heads, conv_ch = _dims(d_model, cfg)
    proj_out = 2 * d_inner + 2 * cfg.n_groups * cfg.d_state + heads
    s = d_model ** -0.5
    return {
        "in_proj": (jax.random.normal(ks[0], (d_model, proj_out)) * s).astype(jnp.bfloat16),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_ch)) * 0.1).astype(jnp.bfloat16),
        "conv_b": jnp.zeros((conv_ch,), jnp.bfloat16),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, heads)).astype(jnp.float32),
        "D": jnp.ones((heads,), jnp.float32),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "norm": jnp.ones((d_inner,), jnp.bfloat16),
        "out_proj": (jax.random.normal(ks[2], (d_inner, d_model)) * d_inner ** -0.5).astype(jnp.bfloat16),
    }


def _split_proj(zxbcdt, d_inner, n_groups, d_state, heads):
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner: 2 * d_inner + 2 * n_groups * d_state]
    dt = zxbcdt[..., 2 * d_inner + 2 * n_groups * d_state:]
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv over seq: (B, S, C) with (W, C) taps."""
    width = w.shape[0]
    pads = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pads[:, i: i + xbc.shape[1], :] * w[i] for i in range(width))
    return jax.nn.silu(out + b)


def segsum_exp(dacs: jax.Array) -> jax.Array:
    """exp(Σ decay) lower-triangular matrix within a chunk.

    dacs: (..., L, H) inclusive cumsum of dA.  Returns (..., L, L, H) with
    entry [i, j] = exp(dacs_i - dacs_j) for i >= j else 0."""
    li = dacs[..., :, None, :] - dacs[..., None, :, :]
    l_ = dacs.shape[-2]
    mask = jnp.tril(jnp.ones((l_, l_), bool), 0)
    # mask BEFORE exp: upper-triangular entries are large-positive and would
    # overflow to inf (NaN gradients through the 0-multiply)
    li = jnp.where(mask[..., :, :, None], li, -jnp.inf)
    return jnp.exp(li)


def ssd_scan(x, dt, a_log, b_mat, c_mat, cfg: SSMConfig,
             initial_state=None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.

    x: (B, S, H, P)   inputs per head
    dt: (B, S, H)     softplus'd step sizes
    a_log: (H,)       A = -exp(a_log)
    b_mat/c_mat: (B, S, G, N)
    Returns (y (B, S, H, P), final_state (B, H, P, N))."""
    bsz, s, h, p_ = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    q = cfg.chunk
    pad = (-s) % q
    if pad:
        # zero-dt padding steps are exact no-ops: dA = 0 => decay 1, input 0
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s_pad = s + pad
    nc = s_pad // q
    rep = h // g

    a = -jnp.exp(a_log)                                   # (H,) negative
    da = dt * a                                           # (B, S, H)
    xd = x * dt[..., None]                                # discretized input

    # reshape into chunks
    xc = xd.reshape(bsz, nc, q, h, p_)
    dac = da.reshape(bsz, nc, q, h)
    bc = jnp.repeat(b_mat.reshape(bsz, nc, q, g, n), rep, axis=3)   # (B,C,Q,H,N)
    cc = jnp.repeat(c_mat.reshape(bsz, nc, q, g, n), rep, axis=3)

    dacs = jnp.cumsum(dac, axis=2)                        # (B, C, Q, H)

    # 1) intra-chunk (diagonal blocks): Y_ii = (C_i B_j^T ∘ L_ij) X_j
    cb = jnp.einsum("bclhn,bcmhn->bclmh", cc, bc, preferred_element_type=jnp.float32)
    l_mat = segsum_exp(dacs)                              # (B, C, Q, Q, H)
    y_diag = jnp.einsum("bclmh,bcmhp->bclhp", (cb * l_mat).astype(x.dtype), xc,
                        preferred_element_type=jnp.float32)

    # 2) chunk states: right factors B^T diag(decay) X
    decay_states = jnp.exp(dacs[:, :, -1:, :] - dacs)     # (B, C, Q, H)
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn",
                        bc, decay_states.astype(bc.dtype), xc,
                        preferred_element_type=jnp.float32)

    # 3) inter-chunk recurrence (small carry, lax.scan over chunks)
    chunk_decay = jnp.exp(dacs[:, :, -1, :])              # (B, C, H)
    s0 = (jnp.zeros((bsz, h, p_, n), jnp.float32)
          if initial_state is None else initial_state.astype(jnp.float32))

    def step(carry, inp):
        st, dec = inp                                     # (B,H,P,N), (B,H)
        prev = carry
        new = prev * dec[:, :, None, None] + st
        return new, prev                                  # emit state BEFORE this chunk

    final, prev_states = scanctl.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # (B, C, H, P, N)

    # 4) inter-chunk output: Y_off = C_i · S_prev · exp(dacs)
    state_decay = jnp.exp(dacs)                           # decay from chunk start
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp",
                       cc, prev_states.astype(cc.dtype), state_decay.astype(cc.dtype),
                       preferred_element_type=jnp.float32)

    y = (y_diag + y_off).reshape(bsz, s_pad, h, p_)[:, :s]
    return y.astype(x.dtype), final


def mamba2_forward(p, x, cfg: SSMConfig, d_model: int,
                   initial_state: SSMState | None = None
                   ) -> Tuple[jax.Array, SSMState]:
    """Full-sequence Mamba-2 block: (B, S, D) -> (B, S, D) + final state."""
    d_inner, heads, conv_ch = _dims(d_model, cfg)
    bsz, s, _ = x.shape
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])
    z, xbc, dt = _split_proj(zxbcdt, d_inner, cfg.n_groups, cfg.d_state, heads)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :d_inner].reshape(bsz, s, heads, cfg.head_dim)
    b_mat = xbc[..., d_inner: d_inner + cfg.n_groups * cfg.d_state] \
        .reshape(bsz, s, cfg.n_groups, cfg.d_state)
    c_mat = xbc[..., d_inner + cfg.n_groups * cfg.d_state:] \
        .reshape(bsz, s, cfg.n_groups, cfg.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

    init = initial_state.ssm if initial_state is not None else None
    y, final = ssd_scan(xs, dt, p["A_log"], b_mat, c_mat, cfg, initial_state=init)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xs
    y = y.reshape(bsz, s, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])

    # conv state for decode continuation: last (width-1) PRE-conv xBC inputs
    zxbcdt_tail = zxbcdt[:, -(cfg.conv_width - 1):, :]
    _, xbc_tail, _ = _split_proj(zxbcdt_tail, d_inner, cfg.n_groups, cfg.d_state, heads)
    return out, SSMState(ssm=final, conv=xbc_tail)


def mamba2_decode(p, x, state: SSMState, cfg: SSMConfig, d_model: int
                  ) -> Tuple[jax.Array, SSMState]:
    """Single-token recurrence: x (B, 1, D)."""
    d_inner, heads, conv_ch = _dims(d_model, cfg)
    bsz = x.shape[0]
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["in_proj"])[:, 0]   # (B, K)
    z, xbc_new, dt = _split_proj(zxbcdt, d_inner, cfg.n_groups, cfg.d_state, heads)

    # causal conv over the rolling window
    window = jnp.concatenate([state.conv, xbc_new[:, None, :]], axis=1)  # (B, W, C)
    conv_out = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(conv_out)
    xs = xbc[..., :d_inner].reshape(bsz, heads, cfg.head_dim)
    b_vec = xbc[..., d_inner: d_inner + cfg.n_groups * cfg.d_state] \
        .reshape(bsz, cfg.n_groups, cfg.d_state)
    c_vec = xbc[..., d_inner + cfg.n_groups * cfg.d_state:] \
        .reshape(bsz, cfg.n_groups, cfg.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, H)

    a = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * a)                                          # (B, H)
    rep = heads // cfg.n_groups
    bh = jnp.repeat(b_vec, rep, axis=1)                           # (B, H, N)
    ch = jnp.repeat(c_vec, rep, axis=1)
    xd = (xs * dt[..., None]).astype(jnp.float32)
    new_ssm = state.ssm * da[:, :, None, None] + \
        jnp.einsum("bhp,bhn->bhpn", xd, bh.astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", new_ssm, ch.astype(jnp.float32))
    y = y + p["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(bsz, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bk,kd->bd", y, p["out_proj"])[:, None, :]
    return out, SSMState(ssm=new_ssm, conv=window[:, 1:, :])
