"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

The KV cache is the *compressed latent*: per token only
(kv_lora_rank + qk_rope_head_dim) values — this is what crosses the PD
boundary and what SplitZip compresses (DESIGN.md §4).

Prefill uses the naive expanded form (latent -> per-head K/V, chunked
attention).  Decode uses the **absorbed form**: the k_nope projection is
folded into the query and the v projection into the output, so per-step cost
is O(S · kv_lora_rank) instead of re-expanding the whole cache.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MLAConfig
from repro.distributed.sharding import constrain
from repro.models.layers import NEG_INF, apply_rope, chunked_attention, rms_norm


def init_mla(key, d_model: int, num_heads: int, cfg: MLAConfig):
    ks = jax.random.split(key, 6)
    qk_dim = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    s = d_model ** -0.5
    return {
        "wq_a": (jax.random.normal(ks[0], (d_model, cfg.q_lora_rank)) * s).astype(jnp.bfloat16),
        "q_norm": jnp.ones((cfg.q_lora_rank,), jnp.bfloat16),
        "wq_b": (jax.random.normal(ks[1], (cfg.q_lora_rank, num_heads, qk_dim))
                 * cfg.q_lora_rank ** -0.5).astype(jnp.bfloat16),
        "wkv_a": (jax.random.normal(ks[2], (d_model, cfg.kv_lora_rank + cfg.qk_rope_head_dim))
                  * s).astype(jnp.bfloat16),
        "kv_norm": jnp.ones((cfg.kv_lora_rank,), jnp.bfloat16),
        "wkv_b": (jax.random.normal(
            ks[3], (cfg.kv_lora_rank, num_heads, cfg.qk_nope_head_dim + cfg.v_head_dim))
            * cfg.kv_lora_rank ** -0.5).astype(jnp.bfloat16),
        "wo": (jax.random.normal(ks[4], (num_heads, cfg.v_head_dim, d_model))
               * (num_heads * cfg.v_head_dim) ** -0.5).astype(jnp.bfloat16),
    }


def _queries(p, x, positions, cfg: MLAConfig, theta: float):
    q_lat = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_norm"])
    q = jnp.einsum("bsr,rhk->bshk", q_lat, p["wq_b"])
    q_nope = q[..., : cfg.qk_nope_head_dim]
    q_rope = apply_rope(q[..., cfg.qk_nope_head_dim:], positions, theta)
    return q_nope, q_rope


def _latent_kv(p, x, positions, cfg: MLAConfig, theta: float):
    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv = rms_norm(kv[..., : cfg.kv_lora_rank], p["kv_norm"])
    k_rope = kv[..., cfg.kv_lora_rank:]                         # (B, S, rope)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_prefill(p, x, positions, cfg: MLAConfig, theta: float,
                kv_block: int = 1024) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence attention; returns (out, (c_kv, k_rope)) latent cache."""
    b, s, d = x.shape
    h = p["wq_b"].shape[1]
    q_nope, q_rope = _queries(p, x, positions, cfg, theta)
    c_kv, k_rope = _latent_kv(p, x, positions, cfg, theta)

    kv = jnp.einsum("bsr,rhk->bshk", c_kv, p["wkv_b"])
    k_nope = kv[..., : cfg.qk_nope_head_dim]
    v = constrain(kv[..., cfg.qk_nope_head_dim:], "bthd")
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, cfg.qk_rope_head_dim))],
        axis=-1)
    # MHA heads (40) don't divide the model axis: the bthd rule falls back to
    # sequence sharding — without it GSPMD replicates the whole score chain
    # on every model shard (16x waste; EXPERIMENTS.md §Perf Cell A).
    k = constrain(k, "bthd")
    q = constrain(jnp.concatenate([q_nope, q_rope], axis=-1), "bthd")
    o = constrain(chunked_attention(q, k, v, causal=True, kv_block=kv_block),
                  "bthd")
    out = jnp.einsum("bshv,hvd->bsd", o, p["wo"])
    return out, (c_kv, k_rope)


def mla_decode(p, x, cache_ckv, cache_krope, cache_len, cfg: MLAConfig,
               theta: float) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Absorbed-form decode over the latent cache.

    x: (B, 1, D); cache_ckv: (B, S, kv_r); cache_krope: (B, S, rope)."""
    b = x.shape[0]
    positions = cache_len[:, None]
    q_nope, q_rope = _queries(p, x, positions, cfg, theta)      # (B,1,H,·)
    c_new, kr_new = _latent_kv(p, x, positions, cfg, theta)     # (B,1,kv_r/rope)

    cache_ckv = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(c, n, (i, 0)))(
        cache_ckv, c_new, cache_len)
    cache_krope = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(c, n, (i, 0)))(
        cache_krope, kr_new, cache_len)

    w_knope = p["wkv_b"][..., : cfg.qk_nope_head_dim]            # (r, H, nope)
    w_v = p["wkv_b"][..., cfg.qk_nope_head_dim:]                 # (r, H, v)

    # absorb: q_lat[h] = q_nope[h] @ w_knope[:, h, :].T  -> latent-space query
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_knope)
    scale = 1.0 / np.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
    sc = (jnp.einsum("bqhr,bsr->bqhs", q_lat, cache_ckv) +
          jnp.einsum("bqhp,bsp->bqhs", q_rope, cache_krope)).astype(jnp.float32)
    sc = sc * scale
    s_len = cache_ckv.shape[1]
    valid = jnp.arange(s_len)[None, :] < (cache_len + 1)[:, None]
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    prob = jax.nn.softmax(sc, axis=-1)
    ctx_lat = jnp.einsum("bqhs,bsr->bqhr", prob.astype(cache_ckv.dtype), cache_ckv)
    o = jnp.einsum("bqhr,rhv->bqhv", ctx_lat, w_v)
    out = jnp.einsum("bqhv,hvd->bqd", o, p["wo"])
    return out, (cache_ckv, cache_krope)
