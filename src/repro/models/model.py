"""Model assembly for all 10 assigned architectures (+ paper's Qwen3-32B).

One code path per family, all sharing the layer library:

  dense/moe/vlm/audio : scan-over-layers pre-norm transformer (GQA attention,
                        SwiGLU or MoE FFN); vlm/audio get stub frontends
  mla                 : scan-over-layers with MLA attention (latent KV cache)
  ssm                 : scan-over-layers Mamba-2 (SSD)
  hybrid              : scan over (rglru, rglru, local_attn) triples + leftover

Public API: init_params / abstract_params / forward / loss_fn / prefill /
decode_step / make_inputs / input_specs.  Everything is jit-friendly;
activation sharding is requested via repro.distributed.sharding.constrain
(no-op outside a policy context).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import scanctl
from repro.models import ssm as SSM
from repro.models.kvcache import DecodeState, init_cache, n_triples_extra


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def _init_dense_layer(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    p = {
        "norm1": L.init_rms_norm(cfg.d_model),
        "norm2": L.init_rms_norm(cfg.d_model),
    }
    if cfg.mla is not None:
        p["attn"] = MLA.init_mla(k1, cfg.d_model, cfg.num_heads, cfg.mla)
    elif cfg.ssm is None:
        p["attn"] = L.init_attention(k1, cfg.d_model, cfg.num_heads,
                                     cfg.num_kv_heads, cfg.head_dim)
    if cfg.ssm is not None:
        p["mixer"] = SSM.init_mamba2(k1, cfg.d_model, cfg.ssm)
        del p["norm2"]
    elif cfg.moe is not None:
        p["ffn"] = MOE.init_moe(k2, cfg.d_model, cfg.moe)
    else:
        p["ffn"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff)
    return p


def _init_triple(key, cfg: ArchConfig):
    h = cfg.hybrid
    u = h.lru_width or cfg.d_model
    ks = jax.random.split(key, 6)
    return {
        "rec": {
            "block": jax.vmap(lambda k: RG.init_rglru_block(
                k, cfg.d_model, u, h.conv_width))(ks[:2]),
            "norm": jnp.ones((2, cfg.d_model), jnp.bfloat16),
            "mlp": jax.vmap(lambda k: L.init_mlp(k, cfg.d_model, cfg.d_ff))(ks[2:4]),
            "norm_mlp": jnp.ones((2, cfg.d_model), jnp.bfloat16),
        },
        "attn": {
            "block": L.init_attention(ks[4], cfg.d_model, cfg.num_heads,
                                      cfg.num_kv_heads, cfg.head_dim),
            "norm": L.init_rms_norm(cfg.d_model),
            "mlp": L.init_mlp(ks[5], cfg.d_model, cfg.d_ff),
            "norm_mlp": L.init_rms_norm(cfg.d_model),
        },
    }


def init_params(cfg: ArchConfig, key: jax.Array) -> Dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p: Dict = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_size, d)) * 0.02).astype(jnp.bfloat16),
        "final_norm": L.init_rms_norm(d),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(ks[1], (d, cfg.vocab_size)) * 0.02).astype(jnp.bfloat16)
    if cfg.frontend is not None:
        p["frontend_proj"] = (jax.random.normal(
            ks[2], (cfg.frontend_dim, d)) * cfg.frontend_dim ** -0.5).astype(jnp.bfloat16)
    if cfg.hybrid is not None:
        nt, ne = n_triples_extra(cfg)
        tkeys = jax.random.split(ks[3], nt)
        p["triples"] = jax.vmap(lambda k: _init_triple(k, cfg))(tkeys)
        if ne:
            ekeys = jax.random.split(ks[4], ne)
            u = cfg.hybrid.lru_width or d
            p["extra"] = jax.vmap(lambda k: {
                "block": RG.init_rglru_block(k, d, u, cfg.hybrid.conv_width),
                "norm": L.init_rms_norm(d),
                "mlp": L.init_mlp(jax.random.fold_in(k, 1), d, cfg.d_ff),
                "norm_mlp": L.init_rms_norm(d),
            })(ekeys)
    else:
        lkeys = jax.random.split(ks[3], cfg.num_layers)
        p["layers"] = jax.vmap(lambda k: _init_dense_layer(k, cfg))(lkeys)
    return p


def abstract_params(cfg: ArchConfig) -> Dict:
    """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed_inputs(params, batch: Dict, cfg: ArchConfig) -> jax.Array:
    if cfg.frontend == "audio_frames":
        x = jnp.einsum("bsf,fd->bsd", batch["frames"].astype(jnp.bfloat16),
                       params["frontend_proj"])
        return constrain(x, "btd")
    tok = params["embed"][batch["tokens"]]  # gather over vocab-sharded table
    if cfg.frontend == "vision_patches":
        patches = jnp.einsum("bpf,fd->bpd", batch["patches"].astype(jnp.bfloat16),
                             params["frontend_proj"])
        tok = jnp.concatenate([patches, tok], axis=1)
    return constrain(tok, "btd")


def lm_logits(params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])
    return constrain(logits, "logits")


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _dense_layer_fwd(cfg: ArchConfig, lp, x, positions, kv_block=1024):
    """One transformer layer; returns (x, cache_entries, aux)."""
    h = L.rms_norm(x, lp["norm1"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.ssm is not None:
        mix_out, state = SSM.mamba2_forward(lp["mixer"], h, cfg.ssm, cfg.d_model)
        x = constrain(x + mix_out, "btd")
        return x, state, aux
    if cfg.mla is not None:
        attn_out, kv = MLA.mla_prefill(lp["attn"], h, positions, cfg.mla,
                                       cfg.rope_theta, kv_block=kv_block)
    else:
        q, k, v = L.attention_qkv(lp["attn"], h, positions, cfg.rope_theta)
        q = constrain(q, "bthd")
        k = constrain(k, "bthd")
        v = constrain(v, "bthd")
        o = L.chunked_attention(q, k, v, causal=not cfg.encoder_only,
                                kv_block=kv_block)
        attn_out = L.attention_out(lp["attn"], o)
        kv = (k, v)
    x = x + attn_out
    h2 = L.rms_norm(x, lp["norm2"], cfg.norm_eps)
    if cfg.moe is not None:
        ffn_out, aux = MOE.moe_ffn(lp["ffn"], h2, cfg.moe)
    else:
        ffn_out = L.mlp(lp["ffn"], h2)
    x = constrain(x + ffn_out, "btd")
    return x, kv, aux


def _triple_fwd(cfg: ArchConfig, tp, x, positions, window, kv_block=1024):
    """One (rglru, rglru, local_attn) hybrid triple; returns cache entries."""
    rec_states = []
    for i in range(2):
        sub = jax.tree.map(lambda a: a[i], tp["rec"])
        h = L.rms_norm(x, sub["norm"], cfg.norm_eps)
        out, st = RG.recurrent_block_forward(sub["block"], h)
        x = x + out
        h2 = L.rms_norm(x, sub["norm_mlp"], cfg.norm_eps)
        x = constrain(x + L.mlp(sub["mlp"], h2), "btd")
        rec_states.append(st)
    ap = tp["attn"]
    h = L.rms_norm(x, ap["norm"], cfg.norm_eps)
    q, k, v = L.attention_qkv(ap["block"], h, positions, cfg.rope_theta)
    q = constrain(q, "bthd")
    k = constrain(k, "bthd")
    v = constrain(v, "bthd")
    o = L.chunked_attention(q, k, v, causal=True, window=window, kv_block=kv_block)
    x = x + L.attention_out(ap["block"], o)
    h2 = L.rms_norm(x, ap["norm_mlp"], cfg.norm_eps)
    x = constrain(x + L.mlp(ap["mlp"], h2), "btd")
    w = min(window, k.shape[1])
    cache = {
        "attn_k": k[:, -w:], "attn_v": v[:, -w:],
        "rec_h": jnp.stack([s["h"] for s in rec_states]),
        "rec_conv": jnp.stack([s["conv"] for s in rec_states]),
    }
    return x, cache


def forward(params, batch: Dict, cfg: ArchConfig, *, kv_block: int = 1024,
            remat: bool = False, collect_cache: bool = False,
            logits_positions: str = "all"):
    """Full-sequence forward.  Returns (logits, cache_or_None, aux_loss).

    ``logits_positions='last'`` projects only the final position through the
    LM head — prefill needs just the first sampled token, and the full
    (B, S, V) logits chain is the single largest non-attention tensor in
    long-context prefill (EXPERIMENTS.md §Perf Cell A)."""
    x = embed_inputs(params, batch, cfg)
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]

    if cfg.hybrid is not None:
        window = cfg.hybrid.window

        def triple_step(carry, tp):
            h, _ = _triple_fwd(cfg, tp, carry, positions, window, kv_block)[0], None
            return h, None

        def triple_step_cache(carry, tp):
            h, cache = _triple_fwd(cfg, tp, carry, positions, window, kv_block)
            return h, cache

        step = triple_step_cache if collect_cache else triple_step
        if remat:
            step = jax.checkpoint(step)
        x, tcaches = scanctl.scan(step, x, params["triples"])
        extra_states = []
        ne = n_triples_extra(cfg)[1]
        for i in range(ne):
            ep = jax.tree.map(lambda a: a[i], params["extra"])
            h = L.rms_norm(x, ep["norm"], cfg.norm_eps)
            out, st = RG.recurrent_block_forward(ep["block"], h)
            x = x + out
            h2 = L.rms_norm(x, ep["norm_mlp"], cfg.norm_eps)
            x = constrain(x + L.mlp(ep["mlp"], h2), "btd")
            extra_states.append(st)
        cache = None
        if collect_cache:
            cache = dict(tcaches)
            if extra_states:
                cache["extra_h"] = jnp.stack([s["h"] for s in extra_states])
                cache["extra_conv"] = jnp.stack([s["conv"] for s in extra_states])
            else:
                cache["extra_h"] = jnp.zeros((0, b, x.shape[-1]), jnp.float32)
                cache["extra_conv"] = jnp.zeros(
                    (0, b, cfg.hybrid.conv_width - 1, x.shape[-1]), x.dtype)
        if logits_positions == "last":
            x = x[:, -1:]
        return lm_logits(params, x, cfg), cache, jnp.zeros((), jnp.float32)

    def layer_step(carry, lp):
        h, cache, aux = _dense_layer_fwd(cfg, lp, carry, positions, kv_block)
        return h, (cache if collect_cache else None, aux)

    step = jax.checkpoint(layer_step) if remat else layer_step
    x, (caches, auxs) = scanctl.scan(step, x, params["layers"])
    aux = jnp.sum(auxs)
    if logits_positions == "last":
        x = x[:, -1:]
    cache = None
    if collect_cache:
        if cfg.ssm is not None:
            cache = {"ssm": caches.ssm, "conv": caches.conv}
        elif cfg.mla is not None:
            cache = {"ckv": caches[0], "krope": caches[1]}
        else:
            cache = {"k": caches[0], "v": caches[1]}
    return lm_logits(params, x, cfg), cache, aux


def loss_fn(params, batch: Dict, cfg: ArchConfig, *, kv_block: int = 1024,
            remat: bool = True, aux_weight: float = 0.01):
    logits, _, aux = forward(params, batch, cfg, kv_block=kv_block, remat=remat)
    labels = batch["labels"]
    if cfg.frontend == "vision_patches":
        # frontend positions are prepended; score text positions only
        logits = logits[:, -labels.shape[1]:]
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lp, labels[..., None], axis=-1)[..., 0]
    loss = -jnp.mean(ll)
    return loss + aux_weight * aux, (loss, aux)


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def prefill(params, batch: Dict, cfg: ArchConfig, *, max_seq: Optional[int] = None,
            kv_block: int = 1024) -> Tuple[jax.Array, DecodeState]:
    """Run the full prompt; return (last-position logits, decode state).

    For cache-positional families (dense/mla) the cache is padded to
    ``max_seq`` slots so decode can continue in place.

    Ragged batches: ``batch["lengths"]`` (B,) marks each row's true prompt
    length; rows are right-padded to a common S.  Causal attention keeps each
    row's valid prefix independent of its padding, so the fix is purely
    positional: last-token logits are gathered at ``lengths - 1`` (not at the
    padded position S-1) and ``cache_len`` starts at ``lengths`` (decode then
    overwrites the padding slots row by row).  Recurrent families (ssm /
    hybrid) absorb padding into their state and reject ragged input."""
    lengths = batch.get("lengths")
    if lengths is not None:
        if cfg.ssm is not None or cfg.hybrid is not None:
            raise ValueError(
                f"{cfg.name}: ragged prefill (batch['lengths']) needs a "
                "cache-positional family (dense/mla); recurrent state "
                "absorbs right-padding")
        if cfg.frontend is not None or cfg.encoder_only:
            raise ValueError("ragged prefill is token-decoder only")
    logits, cache, _ = forward(
        params, batch, cfg, kv_block=kv_block, collect_cache=True,
        logits_positions="all" if (cfg.encoder_only or lengths is not None)
        else "last")
    if cfg.frontend == "vision_patches":
        s = batch["tokens"].shape[1] + cfg.frontend_len
        b = batch["tokens"].shape[0]
    elif cfg.frontend == "audio_frames":
        s = batch["frames"].shape[1]
        b = batch["frames"].shape[0]
    else:
        b, s = batch["tokens"].shape
    if cfg.encoder_only:
        return logits, DecodeState(cache={}, cache_len=jnp.full((b,), s, jnp.int32))

    max_seq = max_seq or s
    if cfg.ssm is None and cfg.hybrid is None and max_seq > s:
        pad = max_seq - s
        def pad_seq(x):  # (L, B, S, ...) -> pad S
            widths = [(0, 0)] * x.ndim
            widths[2] = (0, pad)
            return jnp.pad(x, widths)
        cache = jax.tree.map(pad_seq, cache)
    if lengths is not None:
        lengths = jnp.asarray(lengths, jnp.int32)
        last = jnp.take_along_axis(
            logits, (lengths - 1)[:, None, None], axis=1)[:, 0]
        return last, DecodeState(cache=cache, cache_len=lengths)
    return logits[:, -1], DecodeState(
        cache=cache, cache_len=jnp.full((b,), s, jnp.int32))


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------

def _windowed_decode(ap, x, k_cache, v_cache, cache_len, cfg):
    """Sliding-window decode with a right-aligned shift-insert cache."""
    w = k_cache.shape[1]
    positions = cache_len[:, None]
    q = jnp.einsum("bsd,dhk->bshk", x, ap["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, ap["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, ap["wv"])
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    k_cache = jnp.concatenate([k_cache[:, 1:], k], axis=1)
    v_cache = jnp.concatenate([v_cache[:, 1:], v], axis=1)
    n_valid = jnp.minimum(cache_len + 1, w)                     # (B,)
    mask = jnp.arange(w)[None, :] >= (w - n_valid)[:, None]
    b, _, h, dq = q.shape
    g = h // k_cache.shape[2]
    qg = q.reshape(b, 1, k_cache.shape[2], g, dq)
    sc = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k_cache,
                    preferred_element_type=jnp.float32) / np.sqrt(dq)
    sc = jnp.where(mask[:, None, None, None, :], sc, L.NEG_INF)
    p_ = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p_.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, 1, h, dq).astype(x.dtype)
    return L.attention_out(ap, o), k_cache, v_cache


def decode_step(params, tokens: jax.Array, state: DecodeState, cfg: ArchConfig
                ) -> Tuple[jax.Array, DecodeState]:
    """One autoregressive step.  tokens: (B, 1) int32 -> logits (B, V)."""
    if cfg.encoder_only:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    x = params["embed"][tokens]
    x = constrain(x, "btd")
    cache_len = state.cache_len
    cache = state.cache

    if cfg.hybrid is not None:
        window = cache["attn_k"].shape[2]

        def triple_step(carry, xs):
            h = carry
            tp, ck, cv, rh, rc = xs
            new_rh, new_rc = [], []
            for i in range(2):
                sub = jax.tree.map(lambda a: a[i], tp["rec"])
                hh = L.rms_norm(h, sub["norm"], cfg.norm_eps)
                out, st = RG.recurrent_block_step(
                    sub["block"], hh, {"h": rh[i], "conv": rc[i]})
                h = h + out
                hh2 = L.rms_norm(h, sub["norm_mlp"], cfg.norm_eps)
                h = h + L.mlp(sub["mlp"], hh2)
                new_rh.append(st["h"]); new_rc.append(st["conv"])
            ap = tp["attn"]
            hh = L.rms_norm(h, ap["norm"], cfg.norm_eps)
            attn_out, ck, cv = _windowed_decode(ap["block"], hh, ck, cv, cache_len, cfg)
            h = h + attn_out
            hh2 = L.rms_norm(h, ap["norm_mlp"], cfg.norm_eps)
            h = h + L.mlp(ap["mlp"], hh2)
            return h, (ck, cv, jnp.stack(new_rh), jnp.stack(new_rc))

        x, (cks, cvs, rhs, rcs) = scanctl.scan(
            triple_step, x,
            (params["triples"], cache["attn_k"], cache["attn_v"],
             cache["rec_h"], cache["rec_conv"]))
        new_cache = dict(cache, attn_k=cks, attn_v=cvs, rec_h=rhs, rec_conv=rcs)
        ne = cache["extra_h"].shape[0]
        eh, ec = [], []
        for i in range(ne):
            ep = jax.tree.map(lambda a: a[i], params["extra"])
            hh = L.rms_norm(x, ep["norm"], cfg.norm_eps)
            out, st = RG.recurrent_block_step(
                ep["block"], hh, {"h": cache["extra_h"][i], "conv": cache["extra_conv"][i]})
            x = x + out
            hh2 = L.rms_norm(x, ep["norm_mlp"], cfg.norm_eps)
            x = x + L.mlp(ep["mlp"], hh2)
            eh.append(st["h"]); ec.append(st["conv"])
        if ne:
            new_cache["extra_h"] = jnp.stack(eh)
            new_cache["extra_conv"] = jnp.stack(ec)
    elif cfg.ssm is not None:
        def layer_step(carry, xs):
            lp, s_ssm, s_conv = xs
            h = L.rms_norm(carry, lp["norm1"], cfg.norm_eps)
            out, st = SSM.mamba2_decode(lp["mixer"], h, SSM.SSMState(s_ssm, s_conv),
                                        cfg.ssm, cfg.d_model)
            return carry + out, (st.ssm, st.conv)

        x, (ssms, convs) = scanctl.scan(
            layer_step, x, (params["layers"], cache["ssm"], cache["conv"]))
        new_cache = {"ssm": ssms, "conv": convs}
    elif cfg.mla is not None:
        def layer_step(carry, xs):
            lp, ckv, krope = xs
            h = L.rms_norm(carry, lp["norm1"], cfg.norm_eps)
            out, (ckv, krope) = MLA.mla_decode(lp["attn"], h, ckv, krope,
                                               cache_len, cfg.mla, cfg.rope_theta)
            h2 = L.rms_norm(carry + out, lp["norm2"], cfg.norm_eps)
            y = carry + out + (MOE.moe_ffn(lp["ffn"], h2, cfg.moe)[0]
                               if cfg.moe else L.mlp(lp["ffn"], h2))
            return constrain(y, "btd"), (ckv, krope)

        x, (ckvs, kropes) = scanctl.scan(
            layer_step, x, (params["layers"], cache["ckv"], cache["krope"]))
        new_cache = {"ckv": ckvs, "krope": kropes}
    else:
        def layer_step(carry, xs):
            lp, ck, cv = xs
            h = L.rms_norm(carry, lp["norm1"], cfg.norm_eps)
            out, (ck, cv) = L.decode_attention_block(
                lp["attn"], h, ck, cv, cache_len, cfg.rope_theta)
            y = carry + out
            h2 = L.rms_norm(y, lp["norm2"], cfg.norm_eps)
            ffn = (MOE.moe_ffn(lp["ffn"], h2, cfg.moe)[0] if cfg.moe
                   else L.mlp(lp["ffn"], h2))
            return constrain(y + ffn, "btd"), (ck, cv)

        x, (cks, cvs) = scanctl.scan(
            layer_step, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": cks, "v": cvs}

    logits = lm_logits(params, x, cfg)[:, -1]
    return logits, DecodeState(cache=new_cache, cache_len=cache_len + 1)


def resident_decode_step(params, tokens: jax.Array, state, cfg: ArchConfig,
                         *, interpret: bool = True):
    """One autoregressive step over a compressed-resident cache.

    ``state`` is a ``kvpool.ResidentState``: the prefix lives as splitzip
    pages consumed directly by the fused Pallas attention kernel (one
    ``pallas_call`` per layer), and the step only grows the raw tail pages —
    the compressed pool is read-only here and tail flushes/recompression are
    host-side between steps (``KVPool.flush_full_tails``).  Dense-GQA and MLA
    families only; others decode raw-resident."""
    import dataclasses

    from repro.models import kvpool as KVP

    if cfg.encoder_only:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    g = state.geom
    x = params["embed"][tokens]
    x = constrain(x, "btd")
    cache_len = state.cache_len

    if cfg.mla is not None:
        cl, rl = state.leaves["ckv"], state.leaves["krope"]
        c_streams, r_streams = cl.streams(), rl.streams()
        fmt = g.leaf("ckv").fmt

        def layer_step(carry, xs):
            lp, pt_c, pt_r, tc, tr = xs
            h = L.rms_norm(carry, lp["norm1"], cfg.norm_eps)
            out, (tc, tr) = KVP.paged_mla_decode(
                lp["attn"], h, c_streams, r_streams, pt_c, pt_r, tc, tr,
                cache_len, cfg.mla, cfg.rope_theta, geom=g, fmt=fmt,
                interpret=interpret)
            h2 = L.rms_norm(carry + out, lp["norm2"], cfg.norm_eps)
            y = carry + out + (MOE.moe_ffn(lp["ffn"], h2, cfg.moe)[0]
                               if cfg.moe else L.mlp(lp["ffn"], h2))
            return constrain(y, "btd"), (tc, tr)

        x, (tcs, trs) = scanctl.scan(
            layer_step, x, (params["layers"], cl.page_table, rl.page_table,
                            cl.tail, rl.tail))
        new_leaves = {"ckv": dataclasses.replace(cl, tail=tcs),
                      "krope": dataclasses.replace(rl, tail=trs)}
    elif cfg.ssm is None and cfg.hybrid is None:
        kl, vl = state.leaves["k"], state.leaves["v"]
        k_streams, v_streams = kl.streams(), vl.streams()
        fmt = g.leaf("k").fmt

        def layer_step(carry, xs):
            lp, pt_k, pt_v, tk, tv = xs
            h = L.rms_norm(carry, lp["norm1"], cfg.norm_eps)
            out, (tk, tv) = KVP.paged_decode_attention_block(
                lp["attn"], h, k_streams, v_streams, pt_k, pt_v, tk, tv,
                cache_len, cfg.rope_theta, geom=g, fmt=fmt,
                interpret=interpret)
            y = carry + out
            h2 = L.rms_norm(y, lp["norm2"], cfg.norm_eps)
            ffn = (MOE.moe_ffn(lp["ffn"], h2, cfg.moe)[0] if cfg.moe
                   else L.mlp(lp["ffn"], h2))
            return constrain(y + ffn, "btd"), (tk, tv)

        x, (tks, tvs) = scanctl.scan(
            layer_step, x, (params["layers"], kl.page_table, vl.page_table,
                            kl.tail, vl.tail))
        new_leaves = {"k": dataclasses.replace(kl, tail=tks),
                      "v": dataclasses.replace(vl, tail=tvs)}
    else:
        raise ValueError(
            f"{cfg.name}: resident-compressed decode supports dense-GQA and "
            "MLA caches; ssm/hybrid decode raw-resident")

    logits = lm_logits(params, x, cfg)[:, -1]
    return logits, dataclasses.replace(
        state, leaves=new_leaves, cache_len=cache_len + 1)


# ---------------------------------------------------------------------------
# inputs (real + abstract)
# ---------------------------------------------------------------------------

def make_inputs(cfg: ArchConfig, shape: ShapeConfig, key=None, batch=None,
                seq=None) -> Dict:
    """Concrete input batch (smoke tests use reduced cfg + small shape)."""
    b = batch or shape.global_batch
    s = seq or shape.seq_len
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    out: Dict = {}
    if cfg.frontend == "audio_frames":
        out["frames"] = jax.random.normal(k1, (b, s, cfg.frontend_dim), jnp.bfloat16)
        out["labels"] = jax.random.randint(k2, (b, s), 0, cfg.vocab_size)
        return out
    if cfg.frontend == "vision_patches":
        s_text = s - cfg.frontend_len
        out["patches"] = jax.random.normal(k1, (b, cfg.frontend_len, cfg.frontend_dim),
                                           jnp.bfloat16)
        out["tokens"] = jax.random.randint(k2, (b, s_text), 0, cfg.vocab_size)
        out["labels"] = jax.random.randint(k3, (b, s_text), 0, cfg.vocab_size)
        return out
    out["tokens"] = jax.random.randint(k1, (b, s), 0, cfg.vocab_size)
    out["labels"] = jax.random.randint(k2, (b, s), 0, cfg.vocab_size)
    return out


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
    b, s = shape.global_batch, shape.seq_len
    f32, bf16, i32 = jnp.float32, jnp.bfloat16, jnp.int32
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    out = {}
    if cfg.frontend == "audio_frames":
        out["frames"] = jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), bf16)
        out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        return out
    if cfg.frontend == "vision_patches":
        out["patches"] = jax.ShapeDtypeStruct((b, cfg.frontend_len, cfg.frontend_dim), bf16)
        out["tokens"] = jax.ShapeDtypeStruct((b, s - cfg.frontend_len), i32)
        out["labels"] = jax.ShapeDtypeStruct((b, s - cfg.frontend_len), i32)
        return out
    out["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    out["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    return out


def abstract_state(cfg: ArchConfig, batch: int, max_seq: int) -> DecodeState:
    """Abstract DecodeState for decode-shape dry-runs (cache at seq_len)."""
    cache = jax.eval_shape(lambda: init_cache(cfg, batch, max_seq))
    return DecodeState(
        cache=cache,
        cache_len=jax.ShapeDtypeStruct((batch,), jnp.int32),
    )
