"""Shared transformer layers: RMSNorm, RoPE, chunked (flash-style) attention,
GQA/MQA attention blocks, sliding-window attention, SwiGLU MLP.

All attention paths are memory-efficient by construction: scores are never
materialized at (S, S) — prefill/train attention scans over KV blocks with an
online softmax (the standard flash recurrence), so the 32k-prefill cells lower
within HBM.  Decode attends over the full cache in one pass (scores are
(B, H, 1, S), which is small).

Compute dtype is bf16 with fp32 softmax statistics and accumulators.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import scanctl


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def init_rms_norm(d: int) -> jax.Array:
    return jnp.ones((d,), jnp.bfloat16)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) rotated by position; positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d, theta), jnp.float32)        # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs           # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                                 # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked attention (flash-style online softmax, pure JAX; lowers to scan)
# ---------------------------------------------------------------------------

NEG_INF = -1e30

# performance overrides installed by the dry-run/launchers (EXPERIMENTS.md
# §Perf): score_dtype bf16 halves the dominant score-chain HBM traffic at a
# ~2-decimal attention-weight precision cost; kv_block trades scan trip count
# against carried-accumulator rewrite traffic.
_ATTN_OVERRIDES = threading.local()


@contextlib.contextmanager
def attn_overrides(score_dtype=None, kv_block=None):
    prev = getattr(_ATTN_OVERRIDES, "cfg", {})
    _ATTN_OVERRIDES.cfg = {k: v for k, v in
                           dict(score_dtype=score_dtype,
                                kv_block=kv_block).items() if v is not None}
    try:
        yield
    finally:
        _ATTN_OVERRIDES.cfg = prev


def _attn_override(key, default):
    return getattr(_ATTN_OVERRIDES, "cfg", {}).get(key, default)


def chunked_attention(
    q: jax.Array,                 # (B, Sq, H, D)
    k: jax.Array,                 # (B, Skv, Hkv, D)
    v: jax.Array,                 # (B, Skv, Hkv, D)
    *,
    causal: bool,
    q_offset: int | jax.Array = 0,   # absolute position of q[0]
    window: Optional[int] = None,    # sliding-window width (None = full)
    kv_block: int = 1024,
    scale: Optional[float] = None,
) -> jax.Array:
    """Memory-efficient attention: scan over KV blocks, never materialize SxS.

    Value head dim may differ from the q/k head dim (MLA)."""
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    dv = v.shape[-1]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    score_dtype = _attn_override("score_dtype", jnp.float32)
    kv_block = _attn_override("kv_block", kv_block)
    kv_block = min(kv_block, skv)
    kv_valid = skv
    pad = (-skv) % kv_block
    if pad:  # pad keys; padded positions are masked out below
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        skv += pad
    nblk = skv // kv_block

    qg = q.reshape(b, sq, hkv, g, d)
    q_pos = q_offset + jnp.arange(sq)

    kb = k.reshape(b, nblk, kv_block, hkv, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblk, kv_block, hkv, dv).transpose(1, 0, 2, 3, 4)

    def step(carry, blk):
        m, l, acc = carry
        k_blk, v_blk, blk_idx = blk
        k_pos = blk_idx * kv_block + jnp.arange(kv_block)
        # scores in score_dtype (bf16 override halves the dominant HBM
        # traffic; bf16 has f32 range so NEG_INF masking still works);
        # m/l/acc accumulators stay f32 for numerical stability.
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k_blk,
                       preferred_element_type=score_dtype) * scale
        mask = jnp.broadcast_to(k_pos[None, :] < kv_valid, (sq, kv_block))
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask[None, :, None, None, :], s,
                      jnp.asarray(NEG_INF, score_dtype))
        m_new = jnp.maximum(m, s.max(axis=-1).astype(jnp.float32))
        p = jnp.exp(s - m_new.astype(score_dtype)[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1, dtype=jnp.float32)
        pv = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(v_blk.dtype), v_blk,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, hkv, g), jnp.float32)
    a0 = jnp.zeros((b, sq, hkv, g, dv), jnp.float32)
    (m, l, acc), _ = scanctl.scan(step, (m0, l0, a0),
                                  (kb, vb, jnp.arange(nblk)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, h, dv).astype(q.dtype)


def decode_attention(
    q: jax.Array,                 # (B, 1, H, D)
    k_cache: jax.Array,           # (B, S, Hkv, D)
    v_cache: jax.Array,
    cache_len: jax.Array,         # (B,) valid prefix length (q at cache_len-1.. ok)
    *,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Single-token attention over the full cache (one pass; no blocking)."""
    b, sq, h, d = q.shape
    _, s, hkv, _ = k_cache.shape
    dv = v_cache.shape[-1]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    qg = q.reshape(b, sq, hkv, g, d)
    sc = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k_cache,
                    preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(s)
    valid = pos[None, :] < cache_len[:, None]                   # (B, S)
    if window is not None:
        valid &= pos[None, :] >= (cache_len[:, None] - window)
    sc = jnp.where(valid[:, None, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------

def init_attention(key, d_model, num_heads, num_kv_heads, head_dim):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d_model ** -0.5
    return {
        "wq": (jax.random.normal(k1, (d_model, num_heads, head_dim)) * s).astype(jnp.bfloat16),
        "wk": (jax.random.normal(k2, (d_model, num_kv_heads, head_dim)) * s).astype(jnp.bfloat16),
        "wv": (jax.random.normal(k3, (d_model, num_kv_heads, head_dim)) * s).astype(jnp.bfloat16),
        "wo": (jax.random.normal(k4, (num_heads, head_dim, d_model)) * s).astype(jnp.bfloat16),
    }


def attention_qkv(p, x, positions, theta):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    return q, k, v


def attention_out(p, o):
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def full_attention_block(p, x, positions, theta, *, causal=True, window=None,
                         kv_block=1024):
    q, k, v = attention_qkv(p, x, positions, theta)
    o = chunked_attention(q, k, v, causal=causal, window=window, kv_block=kv_block)
    return attention_out(p, o), (k, v)


def decode_attention_block(p, x, cache_k, cache_v, cache_len, theta, *,
                           window=None):
    """x: (B, 1, D); writes the new kv at cache_len, attends over prefix+self."""
    positions = cache_len[:, None]  # new token position == current length
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    b = x.shape[0]
    idx = cache_len  # (B,)
    cache_k = jax.vmap(lambda c, kk, i: jax.lax.dynamic_update_slice(
        c, kk, (i, 0, 0)))(cache_k, k, idx)
    cache_v = jax.vmap(lambda c, vv, i: jax.lax.dynamic_update_slice(
        c, vv, (i, 0, 0)))(cache_v, v, idx)
    o = decode_attention(q, cache_k, cache_v, cache_len + 1, window=window)
    return attention_out(p, o), (cache_k, cache_v)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d_model, d_ff):
    k1, k2, k3 = jax.random.split(key, 3)
    s = d_model ** -0.5
    return {
        "w_gate": (jax.random.normal(k1, (d_model, d_ff)) * s).astype(jnp.bfloat16),
        "w_up": (jax.random.normal(k2, (d_model, d_ff)) * s).astype(jnp.bfloat16),
        "w_down": (jax.random.normal(k3, (d_ff, d_model)) * (d_ff ** -0.5)).astype(jnp.bfloat16),
    }


def mlp(p, x):
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, p["w_down"])
