"""Scan wrapper with a cost-accounting mode that unrolls every loop.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, regardless of
trip count, so any scan-over-layers (or scan-over-KV-blocks / SSD-chunks)
model under-reports FLOPs/bytes/collectives by ~the trip count.  The dry-run
therefore measures costs on *unrolled, reduced-depth* builds (see
``repro.launch.dryrun``: compile at L1 and L2 layers with every scan unrolled,
then extrapolate linearly in L) while memory/compile proofs still use the
production scanned build.

All model-side ``lax.scan`` calls go through :func:`scan` so the dry-run can
flip them to ``unroll=True`` without touching model code.
"""

from __future__ import annotations

import contextlib
import threading

import jax

_STATE = threading.local()


def cost_mode_active() -> bool:
    return getattr(_STATE, "unroll_all", False)


@contextlib.contextmanager
def cost_mode(on: bool = True):
    """Within this context, every model scan is fully unrolled (cost
    accounting builds only — never use for real execution or memory proofs:
    unrolling changes buffer liveness and blows up HLO size)."""
    prev = cost_mode_active()
    _STATE.unroll_all = on
    try:
        yield
    finally:
        _STATE.unroll_all = prev


def scan(f, init, xs, length=None, unroll=1):
    """``jax.lax.scan`` that honours the cost-accounting mode."""
    if cost_mode_active():
        unroll = True
    return jax.lax.scan(f, init, xs, length=length, unroll=unroll)
