"""Paged compressed-resident KV pool (ROADMAP item 1, ISSUE 8 tentpole).

The transfer plane already ships KV as splitzip streams; this module keeps
them compressed **at rest in HBM** on the decode worker.  Storage is paged:

* a *page* covers ``tokens_per_page`` tokens of ONE leaf stream (one
  ``(layer, batch)`` row of a cache leaf).  The token count is chosen so the
  page's element count is a multiple of the codec chunk for every
  compressible leaf in the cache — pages are **codec-chunk-aligned**, so a
  page's streams are a contiguous, self-contained slice of the wire
  ``CompressedTensor`` streams and admission is pure reshape + scatter, with
  **no rehydration** (``admit_from_wire``).
* per page and per leaf the pool holds the two dense streams plus a
  page-level sparse escape list (positions rebased from chunk-relative to
  page-relative and compacted into ``page_escape_cap`` slots — the wire's
  per-chunk capacity is a transfer-overflow bound, the page capacity is a
  residency bound; either can overflow independently, and overflow always
  demotes to raw residency rather than lossy storage).
* a per-``(layer, batch)`` **page table** maps logical page index → physical
  page id (−1 = unmapped); physical pages come from a host-side free-list.
* decode-time growth appends raw tokens to a per-row **tail page** in the
  container dtype; when a row's tail fills (``cache_len % tokens_per_page ==
  0``) the host flushes it through the registered codec backend
  (``flush_full_tails``) into fresh pages.  The attention kernel
  (``kernels/splitzip_attention.py``) therefore only ever sees FULL
  compressed pages + a raw tail, and the decode *step* never touches the
  codec's decompress path (CI grep-guards this).

``KVPool`` is the host-side owner (free-list, geometry, demotion);
``ResidentState`` is the pytree that jitted decode steps consume.  Bytes
accounting (``hbm_bytes`` vs ``raw_bytes``) backs the scheduler's
HBM-derived decode-slot capacity and ``benchmarks/fig6_resident_capacity``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codebook import FORMATS, Codebook
from repro.core.backend import CodecBackend

# Default raw-payload bytes per page per leaf.  32 KiB ≅ 128 tokens for the
# benchmark GQA arch (m = 128 elem/token) and keeps the per-page escape
# metadata overhead under 1.2% of payload; benchmarks/table5_granularity.py
# sweeps this knob (8K..128K) and 32K sits on the ratio/throughput knee.
DEFAULT_PAGE_BYTES = 32 * 1024

# One page-level escape slot per 256 payload elements (0.39% of elements).
# The paper's calibrated escape rate is ~0.16%, so pages overflow only on
# genuinely escape-heavy tensors, which demote to raw residency.
ESC_SLOT_PER_ELEMS = 256


# ---------------------------------------------------------------------------
# geometry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LeafGeometry:
    """Static page geometry of one compressible cache leaf."""

    key: str                 # leaf key, e.g. "k" / "v" / "ckv" / "krope"
    shape: tuple             # full cache shape (L, B, S, *token_dims)
    dtype: str               # container dtype name ("bfloat16", ...)
    fmt: str                 # codec format ("bf16", "fp8_e5m2", ...)
    m: int                   # elements per token (= prod(token_dims))
    page_elems: int          # tokens_per_page * m (multiple of chunk)
    page_chunks: int         # page_elems // chunk
    escape_cap: int          # page-level escape slots
    n_pages: int             # physical pages in this leaf's pool


@dataclasses.dataclass(frozen=True)
class PoolGeometry:
    """Static geometry shared by the pool, the kernel, and the docs model."""

    tokens_per_page: int
    chunk: int
    max_pages: int           # logical pages per (layer, batch) row
    n_layers: int
    batch: int
    max_seq: int
    exponents: tuple
    leaves: Tuple[LeafGeometry, ...]

    def leaf(self, key: str) -> LeafGeometry:
        for lg in self.leaves:
            if lg.key == key:
                return lg
        raise KeyError(key)


def _token_elems(shape: tuple) -> int:
    return int(np.prod(shape[3:])) if len(shape) > 3 else 1


def tokens_per_page_for(cache: Dict[str, jax.Array], chunk: int,
                        page_bytes: int = DEFAULT_PAGE_BYTES) -> int:
    """Largest chunk-aligned token count per page under the byte budget.

    Alignment: a page of ``Tp`` tokens of a leaf with ``m`` elements/token
    holds ``Tp * m`` elements; that is a multiple of ``chunk`` for every
    leaf iff ``Tp`` is a multiple of ``lcm_over_leaves(chunk / gcd(chunk,
    m))``."""
    align = 1
    m_max, itemsize_max = 1, 1
    for leaf in cache.values():
        m = _token_elems(leaf.shape)
        align = math.lcm(align, chunk // math.gcd(chunk, m))
        m_max = max(m_max, m)
        itemsize_max = max(itemsize_max, jnp.dtype(leaf.dtype).itemsize)
    target = max(1, page_bytes // (itemsize_max * m_max))
    return max(align, (target // align) * align)


class ResidencyError(RuntimeError):
    """Raised when a stream cannot be admitted/kept compressed-resident.

    The engine catches this and demotes the batch to raw residency (the
    rehydrate-then-``flash_attention`` fallback) — never lossy storage."""


# ---------------------------------------------------------------------------
# pytrees
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PagedLeaf:
    """Device arrays of one leaf's page pool.

    Streams are indexed by physical page id; ``page_table`` is (L, B, P)
    logical→physical (−1 unmapped); ``tail`` is the raw growth page."""

    sign_mantissa: jax.Array   # u8 (n_pages, page_chunks, chunk)
    packed: jax.Array          # u8 (n_pages, page_chunks, chunk // 2)
    esc_pos: jax.Array         # u16 (n_pages, escape_cap), pad = page_elems
    esc_val: jax.Array         # u8 (n_pages, escape_cap)
    esc_cnt: jax.Array         # i32 (n_pages, 1)
    page_table: jax.Array      # i32 (L, B, P)
    tail: jax.Array            # dtype (L, B, tokens_per_page, m)

    def streams(self):
        return (self.sign_mantissa, self.packed, self.esc_pos, self.esc_val,
                self.esc_cnt)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ResidentState:
    """What a jitted resident decode step consumes/returns.

    The page pools are read-only inside a step; only ``tail`` rows and
    ``cache_len`` change (flushes happen host-side between steps)."""

    leaves: Dict[str, PagedLeaf]
    cache_len: jax.Array       # (B,) i32
    geom: PoolGeometry = dataclasses.field(metadata=dict(static=True))


# ---------------------------------------------------------------------------
# stream math (page-level escape rebase/compaction; pure jnp, vectorized)
# ---------------------------------------------------------------------------

def _page_escapes(pos_c, val_c, cnt_c, *, chunk: int, page_chunks: int,
                  cap_page: int):
    """Per-chunk escape buffers -> page-level buffers.

    Inputs are (..., page_chunks, cap_chunk) position/value and (...,
    page_chunks) TRUE counts; positions are chunk-relative with padding ==
    chunk.  Outputs are (..., cap_page) page-relative (padding ==
    page_elems) plus (...,) page counts.  Counts are true sums, so a page
    whose total (or any chunk clipped by the wire cap) exceeds capacity is
    detectable by the caller."""
    lead = pos_c.shape[:-2]
    cap_c = pos_c.shape[-1]
    page_elems = chunk * page_chunks
    pos_c = pos_c.astype(jnp.int32)
    valid = pos_c < chunk                                    # occupied slots
    clipped = jnp.minimum(cnt_c, cap_c)
    # destination slot = exclusive running count of prior chunks + own rank
    base = jnp.cumsum(clipped, axis=-1) - clipped            # (..., pc)
    rank = jnp.broadcast_to(jnp.arange(cap_c), pos_c.shape)
    dest = base[..., None] + rank                            # (..., pc, cap)
    dest = jnp.where(valid, dest, cap_page)                  # drop padding
    dest = jnp.minimum(dest, cap_page)                       # drop overflow
    chunk_base = (jnp.arange(page_chunks) * chunk)[..., None]
    pos_page = jnp.where(valid, pos_c + chunk_base, page_elems)

    # scatter along the last axis, batched over the leading dims via 2D view
    n_lead = int(np.prod(lead)) if lead else 1
    dest2 = dest.reshape(n_lead, -1)
    pos2 = pos_page.reshape(n_lead, -1)
    val2 = val_c.reshape(n_lead, -1)
    rows = jnp.broadcast_to(jnp.arange(n_lead)[:, None], dest2.shape)
    out_pos = jnp.full((n_lead, cap_page + 1), page_elems, jnp.int32)
    out_val = jnp.zeros((n_lead, cap_page + 1), jnp.uint8)
    out_pos = out_pos.at[rows, dest2].set(pos2, mode="drop")
    out_val = out_val.at[rows, dest2].set(val2.astype(jnp.uint8), mode="drop")
    out_pos = out_pos[:, :cap_page].reshape(*lead, cap_page)
    out_val = out_val[:, :cap_page].reshape(*lead, cap_page)
    cnt_page = cnt_c.sum(axis=-1).astype(jnp.int32)          # true totals
    return out_pos.astype(jnp.uint16), out_val, cnt_page


def _paged_views(ct, lg: LeafGeometry, geom: PoolGeometry):
    """Reshape a CompressedTensor's flat streams into per-page views.

    Valid because streams are flat row-major over the (L, B, S, *tok) leaf:
    the (l, b) sub-stream is contiguous and S*m % page_elems == 0.  Returns
    (sm, packed, pos, val, cnt) with leading dims (L, B, P_logical)."""
    L_, B, S = lg.shape[0], lg.shape[1], lg.shape[2]
    P = S // geom.tokens_per_page
    pc, chunk = lg.page_chunks, geom.chunk
    sm = ct.sign_mantissa.reshape(L_, B, P, pc, chunk)
    packed = ct.packed.reshape(L_, B, P, pc, chunk // 2)
    pos = ct.esc_pos.reshape(L_, B, P, pc, ct.cap)
    val = ct.esc_val.reshape(L_, B, P, pc, ct.cap)
    cnt = ct.esc_count.reshape(L_, B, P, pc)
    return sm, packed, pos, val, cnt


def _decode_pool_pages(leaf: PagedLeaf, lg: LeafGeometry,
                       geom: PoolGeometry) -> jax.Array:
    """All physical pages -> container bits (n_pages, page_elems).

    Host/fallback path only (rehydrate, tests) — the decode step itself uses
    the fused kernel."""
    spec = FORMATS[lg.fmt]
    mbits, bits_width = spec["mbits"], spec["bits"]
    npg = leaf.sign_mantissa.shape[0]
    pe = lg.page_elems
    packed = leaf.packed.reshape(npg, pe // 2).astype(jnp.int32)
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    code = jnp.stack([lo, hi], axis=-1).reshape(npg, pe)
    e = jnp.zeros_like(code)
    for i, exp in enumerate(geom.exponents):
        e = jnp.where(code == i, exp, e)
    a = leaf.sign_mantissa.reshape(npg, pe).astype(jnp.int32)
    sign = (a >> mbits) & 1
    bits = (sign << (bits_width - 1)) | (e << mbits) | (a & ((1 << mbits) - 1))
    # patch page-level escapes
    keep = ((1 << bits_width) - 1) ^ (((1 << (bits_width - mbits - 1)) - 1)
                                      << mbits)
    cap = leaf.esc_pos.shape[1]
    slot = jnp.arange(cap)
    pos = leaf.esc_pos.astype(jnp.int32)
    occupied = slot[None, :] < leaf.esc_cnt            # (npg, cap)
    pos = jnp.where(occupied, pos, pe)
    rows = jnp.broadcast_to(jnp.arange(npg)[:, None], pos.shape)
    old = jnp.take_along_axis(bits, jnp.minimum(pos, pe - 1), axis=1)
    new = (old & keep) | (leaf.esc_val.astype(jnp.int32) << mbits)
    return bits.at[rows, pos].set(jnp.where(occupied, new, 0), mode="drop")


# ---------------------------------------------------------------------------
# the pool
# ---------------------------------------------------------------------------

class KVPool:
    """Host-side owner of the paged compressed KV pool.

    Not a pytree: holds the free-list and geometry, and mutates a
    ``ResidentState`` between jitted steps.  One physical-page namespace per
    leaf (leaves have different stream widths, so pages are not shared)."""

    def __init__(self, geom: PoolGeometry, backend: CodecBackend,
                 codebook: Codebook):
        self.geom = geom
        self.backend = backend
        self.codebook = codebook
        if tuple(codebook.exponents) != tuple(geom.exponents):
            raise ValueError("codebook/geometry exponent mismatch")
        self._free: Dict[str, list] = {
            lg.key: list(range(lg.n_pages - 1, -1, -1)) for lg in geom.leaves}
        self.state = ResidentState(
            leaves={lg.key: self._empty_leaf(lg) for lg in geom.leaves},
            cache_len=jnp.zeros((geom.batch,), jnp.int32),
            geom=geom)

    # -- construction ------------------------------------------------------

    @classmethod
    def for_cache(cls, cache: Dict[str, jax.Array], codebook: Codebook,
                  backend: CodecBackend, *, chunk: int,
                  page_bytes: int = DEFAULT_PAGE_BYTES,
                  compressible: Optional[Dict[str, str]] = None) -> "KVPool":
        """Build a pool sized for ``cache`` (dict of (L, B, S, ...) leaves).

        ``compressible`` maps leaf key -> codec fmt (default: every bf16
        leaf as "bf16", fp8 leaves as their format).  S must be a multiple
        of the derived ``tokens_per_page`` (the engine rounds ``max_seq``
        up before building the pool)."""
        if compressible is None:
            compressible = {}
            for k, v in cache.items():
                if v.dtype == jnp.bfloat16:
                    compressible[k] = "bf16"
                elif v.dtype == jnp.float8_e5m2:
                    compressible[k] = "fp8_e5m2"
        if len(codebook.exponents) > 16:
            raise ResidencyError("resident pool requires a nibble-packed "
                                 "(k<=16) codebook")
        tp = tokens_per_page_for(
            {k: cache[k] for k in compressible}, chunk, page_bytes)
        first = next(iter(compressible))
        L_, B, S = cache[first].shape[:3]
        if S % tp:
            raise ResidencyError(
                f"max_seq {S} not a multiple of tokens_per_page {tp}")
        P = S // tp
        leaves = []
        for k in compressible:
            arr = cache[k]
            m = _token_elems(arr.shape)
            pe = tp * m
            leaves.append(LeafGeometry(
                key=k, shape=tuple(arr.shape), dtype=str(arr.dtype),
                fmt=compressible[k], m=m, page_elems=pe,
                page_chunks=pe // chunk,
                escape_cap=max(8, pe // ESC_SLOT_PER_ELEMS),
                n_pages=L_ * B * P))
        geom = PoolGeometry(
            tokens_per_page=tp, chunk=chunk, max_pages=P, n_layers=L_,
            batch=B, max_seq=S, exponents=tuple(codebook.exponents),
            leaves=tuple(leaves))
        return cls(geom, backend, codebook)

    def _empty_leaf(self, lg: LeafGeometry) -> PagedLeaf:
        g = self.geom
        return PagedLeaf(
            sign_mantissa=jnp.zeros((lg.n_pages, lg.page_chunks, g.chunk),
                                    jnp.uint8),
            packed=jnp.zeros((lg.n_pages, lg.page_chunks, g.chunk // 2),
                             jnp.uint8),
            esc_pos=jnp.full((lg.n_pages, lg.escape_cap), lg.page_elems,
                             jnp.uint16),
            esc_val=jnp.zeros((lg.n_pages, lg.escape_cap), jnp.uint8),
            esc_cnt=jnp.zeros((lg.n_pages, 1), jnp.int32),
            page_table=jnp.full((g.n_layers, g.batch, g.max_pages), -1,
                                jnp.int32),
            tail=jnp.zeros((g.n_layers, g.batch, g.tokens_per_page, lg.m),
                           jnp.dtype(lg.dtype)))

    # -- free-list ---------------------------------------------------------

    def _alloc(self, key: str, n: int) -> np.ndarray:
        free = self._free[key]
        if len(free) < n:
            raise ResidencyError(f"leaf {key!r}: pool exhausted "
                                 f"({n} pages requested, {len(free)} free)")
        return np.array([free.pop() for _ in range(n)], np.int32)

    def _release(self, key: str, ids) -> None:
        self._free[key].extend(int(i) for i in ids)

    def free_pages(self, key: str) -> int:
        return len(self._free[key])

    def allocated_pages(self, key: str) -> int:
        return self.geom.leaf(key).n_pages - len(self._free[key])

    # -- admission (zero-rehydration) --------------------------------------

    def admit_from_wire(self, comp: Dict[str, object],
                        cache_len: jax.Array) -> ResidentState:
        """Map received ``CompressedTensor`` streams into pages.

        No rehydration: pages are contiguous stream slices, so admission is
        reshape + page-escape compaction + scatter by physical page id.
        Only the sub-page tail region (``cache_len % tokens_per_page``
        tokens per row) passes through the backend's bounded decode — one
        page-group per (layer, row), never the full cache.  Raises
        :class:`ResidencyError` (caller demotes) on any unsupported stream
        shape or page-escape overflow."""
        g = self.geom
        cache_len = jnp.asarray(cache_len, jnp.int32)
        lens = np.asarray(cache_len)
        n_full = lens // g.tokens_per_page
        leaves = {}
        for lg in g.leaves:
            ct = comp.get(lg.key)
            if ct is None:
                raise ResidencyError(
                    f"leaf {lg.key!r} arrived raw (codec fallback); "
                    "cannot admit compressed-resident")
            if getattr(ct, "layout", None) != "chunked":
                raise ResidencyError(f"leaf {lg.key!r}: layout "
                                     f"{getattr(ct, 'layout', None)!r} "
                                     "not admissible (need 'chunked')")
            if ct.chunk != g.chunk or tuple(ct.exponents) != g.exponents:
                raise ResidencyError(
                    f"leaf {lg.key!r}: wire chunk/codebook mismatch")
            if tuple(ct.shape) != lg.shape:
                raise ResidencyError(
                    f"leaf {lg.key!r}: wire shape {ct.shape} != pool shape "
                    f"{lg.shape}")
            leaves[lg.key] = self._admit_leaf(ct, lg, lens, n_full)
        self.state = ResidentState(leaves=leaves, cache_len=cache_len,
                                   geom=g)
        return self.state

    def _admit_leaf(self, ct, lg: LeafGeometry, lens: np.ndarray,
                    n_full: np.ndarray) -> PagedLeaf:
        g = self.geom
        leaf = self._empty_leaf(lg)
        sm, packed, pos_c, val_c, cnt_c = _paged_views(ct, lg, g)
        pos_pg, val_pg, cnt_pg = _page_escapes(
            pos_c, val_c, cnt_c, chunk=g.chunk, page_chunks=lg.page_chunks,
            cap_page=lg.escape_cap)

        # admitted (l, b, p) triples: every layer, rows' full pages only
        idx_l, idx_b, idx_p = [], [], []
        for b in range(g.batch):
            for p in range(int(n_full[b])):
                for l in range(g.n_layers):
                    idx_l.append(l)
                    idx_b.append(b)
                    idx_p.append(p)
        if idx_l:
            idx_l = np.array(idx_l)
            idx_b = np.array(idx_b)
            idx_p = np.array(idx_p)
            cnts = np.asarray(cnt_pg)[idx_l, idx_b, idx_p]
            if (cnts > lg.escape_cap).any():
                raise ResidencyError(
                    f"leaf {lg.key!r}: page escape overflow "
                    f"(max {int(cnts.max())} > cap {lg.escape_cap})")
            pids = self._alloc(lg.key, len(idx_l))
            leaf = dataclasses.replace(
                leaf,
                sign_mantissa=leaf.sign_mantissa.at[pids].set(
                    sm[idx_l, idx_b, idx_p]),
                packed=leaf.packed.at[pids].set(packed[idx_l, idx_b, idx_p]),
                esc_pos=leaf.esc_pos.at[pids].set(pos_pg[idx_l, idx_b, idx_p]),
                esc_val=leaf.esc_val.at[pids].set(val_pg[idx_l, idx_b, idx_p]),
                esc_cnt=leaf.esc_cnt.at[pids, 0].set(
                    cnt_pg[idx_l, idx_b, idx_p]),
                page_table=leaf.page_table.at[idx_l, idx_b, idx_p].set(pids))

        # tail: bounded decode of ONE page-group per (layer, row)
        tail = leaf.tail
        if (lens % g.tokens_per_page).any():
            tail = self._decode_wire_tail(ct, lg, n_full)
        return dataclasses.replace(leaf, tail=tail)

    def _decode_wire_tail(self, ct, lg: LeafGeometry,
                          n_full: np.ndarray) -> jax.Array:
        """Gather each (layer, row)'s tail page-group chunks into a small
        CompressedTensor and decode it through the registered backend."""
        import repro.core.codec as C  # host path; step path never does this
        g = self.geom
        L_, B = g.n_layers, g.batch
        pc, chunk = lg.page_chunks, g.chunk
        chunks_per_row = (lg.shape[2] * lg.m) // chunk       # S*m/chunk
        # chunk index of each (l, b) row's tail group start
        start = (np.arange(L_)[:, None] * B + np.arange(B)[None, :]) \
            * chunks_per_row + np.minimum(
                n_full[None, :], g.max_pages - 1) * pc
        gather = (start[..., None] + np.arange(pc)).reshape(-1)  # (L*B*pc,)
        n_chunks_total = ct.sign_mantissa.shape[0] // chunk
        sm = ct.sign_mantissa.reshape(n_chunks_total, chunk)[gather]
        packed = ct.packed.reshape(n_chunks_total, chunk // 2)[gather]
        sub = C.CompressedTensor(
            sign_mantissa=sm.reshape(-1), packed=packed.reshape(-1),
            esc_pos=ct.esc_pos[gather], esc_val=ct.esc_val[gather],
            esc_count=ct.esc_count[gather],
            ok=jnp.asarray(True),
            shape=(L_ * B * pc * chunk,), dtype=lg.dtype, fmt=lg.fmt,
            exponents=g.exponents, chunk=chunk, cap=ct.cap, layout="chunked")
        vals = self.backend.decode(sub)
        return vals.reshape(L_, B, g.tokens_per_page, lg.m)

    # -- decode-time growth ------------------------------------------------

    def flush_full_tails(self, state: ResidentState) -> ResidentState:
        """Recompress rows whose tail page just filled into fresh pages.

        Host-side, between steps.  A row needs flushing when its logical
        page ``cache_len // Tp - 1`` is still unmapped but fully covered.
        Encodes the whole tail leaf once per call (amortized: a row flushes
        every ``tokens_per_page`` steps) and scatters only the needy rows.
        Page-escape overflow raises :class:`ResidencyError` → demotion."""
        g = self.geom
        lens = np.asarray(state.cache_len)
        full_page = lens // g.tokens_per_page - 1            # (B,)
        table0 = np.asarray(state.leaves[g.leaves[0].key].page_table)
        rows = [b for b in range(g.batch)
                if lens[b] > 0 and lens[b] % g.tokens_per_page == 0
                and table0[0, b, full_page[b]] < 0]
        if not rows:
            self.state = state
            return state
        rows_np = np.array(rows)
        # Phase 1: encode + overflow-check EVERY leaf before touching the
        # free-list, so a failed flush leaves the pool exactly as it was
        # (no leaked pages when a later leaf overflows).
        staged = []
        for lg in g.leaves:
            leaf = state.leaves[lg.key]
            ct = self.backend.encode(
                leaf.tail.reshape(-1), self.codebook, chunk=g.chunk,
                cap=lg.escape_cap, layout="chunked")
            pc = lg.page_chunks
            sm = ct.sign_mantissa.reshape(g.n_layers, g.batch, pc, g.chunk)
            packed = ct.packed.reshape(g.n_layers, g.batch, pc, g.chunk // 2)
            pos_c = ct.esc_pos.reshape(g.n_layers, g.batch, pc, -1)
            val_c = ct.esc_val.reshape(g.n_layers, g.batch, pc, -1)
            cnt_c = ct.esc_count.reshape(g.n_layers, g.batch, pc)
            pos_pg, val_pg, cnt_pg = _page_escapes(
                pos_c, val_c, cnt_c, chunk=g.chunk, page_chunks=pc,
                cap_page=lg.escape_cap)
            idx_l = np.repeat(np.arange(g.n_layers), len(rows))
            idx_b = np.tile(rows_np, g.n_layers)
            idx_p = full_page[idx_b]
            cnts = np.asarray(cnt_pg)[idx_l, idx_b]
            if (cnts > lg.escape_cap).any():
                raise ResidencyError(
                    f"leaf {lg.key!r}: tail recompress escape overflow "
                    f"(max {int(cnts.max())} > cap {lg.escape_cap})")
            staged.append((lg, sm, packed, pos_pg, val_pg, cnt_pg,
                           idx_l, idx_b, idx_p))
        # Phase 2: allocate + scatter; if a later leaf's allocation exhausts
        # the pool, return the pages already popped for earlier leaves.
        new_leaves = dict(state.leaves)
        alloced = []
        try:
            for (lg, sm, packed, pos_pg, val_pg, cnt_pg,
                 idx_l, idx_b, idx_p) in staged:
                leaf = state.leaves[lg.key]
                pids = self._alloc(lg.key, len(idx_l))
                alloced.append((lg.key, pids))
                new_leaves[lg.key] = dataclasses.replace(
                    leaf,
                    sign_mantissa=leaf.sign_mantissa.at[pids].set(
                        sm[idx_l, idx_b]),
                    packed=leaf.packed.at[pids].set(packed[idx_l, idx_b]),
                    esc_pos=leaf.esc_pos.at[pids].set(pos_pg[idx_l, idx_b]),
                    esc_val=leaf.esc_val.at[pids].set(val_pg[idx_l, idx_b]),
                    esc_cnt=leaf.esc_cnt.at[pids, 0].set(
                        cnt_pg[idx_l, idx_b]),
                    page_table=leaf.page_table.at[idx_l, idx_b, idx_p].set(
                        pids))
        except ResidencyError:
            for key, pids in alloced:
                self._release(key, pids)
            raise
        self.state = dataclasses.replace(state, leaves=new_leaves)
        return self.state

    # -- fallback / teardown ----------------------------------------------

    def rehydrate(self, state: Optional[ResidentState] = None
                  ) -> Dict[str, jax.Array]:
        """Reconstruct the raw cache dict (bit-exact; demotion/tests).

        Unmapped pages and tokens beyond ``cache_len`` come back zero-filled
        (matching ``init_cache``'s zero padding)."""
        state = state or self.state
        g = self.geom
        out = {}
        for lg in g.leaves:
            leaf = state.leaves[lg.key]
            bits = _decode_pool_pages(leaf, lg, g)           # (npg, pe)
            zero = jnp.zeros((1, lg.page_elems), bits.dtype)
            bits = jnp.concatenate([bits, zero], axis=0)     # id −1 → zeros
            pages = bits[leaf.page_table]                    # (L, B, P, pe)
            spec = FORMATS[lg.fmt]
            u = pages.astype(jnp.uint16 if spec["bits"] == 16 else jnp.uint8)
            vals = jax.lax.bitcast_convert_type(u, jnp.dtype(lg.dtype))
            vals = vals.reshape(g.n_layers, g.batch, g.max_pages,
                                g.tokens_per_page, lg.m)
            # splice each row's tail page over its first unmapped slot.  A
            # row at a page boundary (cache_len % Tp == 0) whose just-filled
            # page cache_len//Tp - 1 is still UNMAPPED (a flush failed before
            # the page table was written) holds that page's data only in the
            # tail: splice the FULL tail there, not an empty one at n_full —
            # otherwise demotion would silently zero tokens_per_page tokens.
            n_full = state.cache_len // g.tokens_per_page    # (B,)
            tail_tok = state.cache_len % g.tokens_per_page   # (B,)
            L_, B = g.n_layers, g.batch
            prev = jnp.maximum(n_full - 1, 0)
            prev_pid = jnp.take_along_axis(
                leaf.page_table,
                jnp.broadcast_to(prev[None, :, None], (L_, B, 1)),
                axis=2)[..., 0]                              # (L, B)
            pending = ((tail_tok[None, :] == 0) & (n_full[None, :] > 0)
                       & (prev_pid < 0))                     # (L, B)
            eff_page = jnp.where(pending, prev[None, :], n_full[None, :])
            eff_tok = jnp.where(pending, g.tokens_per_page,
                                tail_tok[None, :])           # (L, B)
            t_idx = jnp.arange(g.tokens_per_page)
            tail_mask = (t_idx[None, None, :] < eff_tok[..., None])
            tail = jnp.where(tail_mask[..., None], leaf.tail, 0)
            p_idx = jnp.arange(g.max_pages)
            is_tail_page = (p_idx[None, None, :] == eff_page[..., None])
            vals = jnp.where(is_tail_page[..., None, None],
                             tail[:, :, None], vals)
            out[lg.key] = vals.reshape(g.n_layers, g.batch,
                                       g.max_seq, *lg.shape[3:])
        return out

    def free_rows(self, rows) -> None:
        """Return all physical pages of the given batch rows to the
        free-list and unmap them (sequence eviction)."""
        g = self.geom
        new_leaves = {}
        for lg in g.leaves:
            leaf = self.state.leaves[lg.key]
            table = np.asarray(leaf.page_table)
            pt = leaf.page_table
            for b in rows:
                ids = table[:, b, :].reshape(-1)
                self._release(lg.key, ids[ids >= 0])
                pt = pt.at[:, b, :].set(-1)
            new_leaves[lg.key] = dataclasses.replace(leaf, page_table=pt)
        self.state = dataclasses.replace(self.state, leaves=new_leaves)

    # -- accounting --------------------------------------------------------

    def page_bytes(self, lg: LeafGeometry) -> int:
        """HBM bytes of ONE physical page (streams + escape metadata)."""
        return (lg.page_elems + lg.page_elems // 2
                + lg.escape_cap * 3 + 4)

    def hbm_bytes(self, *, allocated_only: bool = False) -> int:
        """Resident footprint: page pools (+ tables + tails)."""
        g = self.geom
        total = 0
        for lg in g.leaves:
            n = (self.allocated_pages(lg.key) if allocated_only
                 else lg.n_pages)
            total += n * self.page_bytes(lg)
            total += g.n_layers * g.batch * g.max_pages * 4   # page table
            total += (g.n_layers * g.batch * g.tokens_per_page * lg.m
                      * jnp.dtype(lg.dtype).itemsize)          # tail
        return total

    def raw_bytes(self) -> int:
        """What the same cache costs raw-resident."""
        g = self.geom
        return sum(g.n_layers * g.batch * g.max_seq * lg.m
                   * jnp.dtype(lg.dtype).itemsize for lg in g.leaves)

    def resident_ratio(self) -> float:
        """raw / resident — the capacity multiplier fig6 measures."""
        return self.raw_bytes() / self.hbm_bytes()


# ---------------------------------------------------------------------------
# decode-step glue (one fused pallas_call per attention layer)
# ---------------------------------------------------------------------------

def _append_tail(tail: jax.Array, new: jax.Array, t: jax.Array) -> jax.Array:
    """Write each row's new token into its tail page at slot ``t`` (B,)."""
    return jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(
        c, n, (i, 0)))(tail, new.astype(tail.dtype), t)


def paged_decode_attention_block(p, x, k_streams, v_streams, pt_k, pt_v,
                                 tail_k, tail_v, cache_len, theta, *,
                                 geom: PoolGeometry, fmt: str = "bf16",
                                 interpret: bool = True):
    """Mirror of ``layers.decode_attention_block`` with a compressed prefix.

    The prefix (``cache_len // Tp`` full pages) is attended by the fused
    kernel directly over the splitzip streams; the new token is appended to
    the raw tail page and the tail partials merge in plain jnp.  Stream
    arrays are per-leaf pools shared by every layer; ``pt_*``/``tail_*`` are
    THIS layer's page-table rows (B, P) and tail pages (B, Tp, m).

    Returns ``(attn_out, (tail_k, tail_v))`` — the compressed pool is
    read-only inside the step; only tails grow (flushes are host-side)."""
    from repro.kernels import splitzip_attention as SA
    from repro.models import layers as Ly

    tp = geom.tokens_per_page
    positions = cache_len[:, None]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = Ly.apply_rope(q, positions, theta)
    k = Ly.apply_rope(k, positions, theta)
    b, _, hkv, hd = k.shape
    h = q.shape[2]
    g = h // hkv
    dv = v.shape[-1]
    t = cache_len % tp
    tail_k = _append_tail(tail_k, k.reshape(b, 1, hkv * hd), t)
    tail_v = _append_tail(tail_v, v.reshape(b, 1, hkv * dv), t)

    scale = 1.0 / np.sqrt(hd)
    acc, m, l = SA.paged_gqa_attention(
        q, k_streams, v_streams, pt_k, pt_v, cache_len,
        exponents=geom.exponents, fmt=fmt, chunk=geom.chunk,
        tokens_per_page=tp, hkv=hkv, causal=True, scale=scale,
        interpret=interpret)
    acc = acc.reshape(b, 1, hkv, g, dv)
    m = m.reshape(b, 1, hkv, g)
    l = l.reshape(b, 1, hkv, g)

    tk = tail_k.reshape(b, tp, hkv, hd).astype(jnp.float32)
    tv = tail_v.reshape(b, tp, hkv, dv).astype(jnp.float32)
    qf = q.astype(jnp.float32).reshape(b, 1, hkv, g, hd)
    s_t = jnp.einsum("bqhgd,bthd->bqhgt", qf, tk,
                     preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(tp)[None, :] < (t + 1)[:, None]   # incl. the new token
    part = SA.merge_partials((acc, m, l), SA.tail_partials(s_t, tv, valid))
    o = SA.finalize(part[0], part[2], dtype=x.dtype).reshape(b, 1, h, dv)
    return Ly.attention_out(p, o), (tail_k, tail_v)


def paged_mla_decode(p, x, ckv_streams, kr_streams, pt_c, pt_r,
                     tail_c, tail_r, cache_len, cfg, theta, *,
                     geom: PoolGeometry, fmt: str = "bf16",
                     interpret: bool = True):
    """Mirror of ``mla.mla_decode`` over compressed latent pages.

    Scores/context run in the latent space inside the kernel (absorbed
    form); the ``w_v``/``wo`` up-projections apply after the tail merge."""
    from repro.kernels import splitzip_attention as SA
    from repro.models import mla as M

    tp = geom.tokens_per_page
    b = x.shape[0]
    positions = cache_len[:, None]
    q_nope, q_rope = M._queries(p, x, positions, cfg, theta)     # (B,1,H,·)
    c_new, kr_new = M._latent_kv(p, x, positions, cfg, theta)    # (B,1,r/p)
    t = cache_len % tp
    tail_c = _append_tail(tail_c, c_new, t)
    tail_r = _append_tail(tail_r, kr_new, t)

    w_knope = p["wkv_b"][..., : cfg.qk_nope_head_dim]            # (r, H, n)
    w_v = p["wkv_b"][..., cfg.qk_nope_head_dim:]                 # (r, H, v)
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_knope)
    scale = 1.0 / np.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)

    acc, m, l = SA.paged_mla_attention(
        q_lat, q_rope, ckv_streams, kr_streams, pt_c, pt_r, cache_len,
        exponents=geom.exponents, fmt=fmt, chunk=geom.chunk,
        tokens_per_page=tp, scale=scale, causal=True, interpret=interpret)

    tc = tail_c.astype(jnp.float32)                              # (B,Tp,r)
    tr = tail_r.astype(jnp.float32)
    qlf = q_lat.astype(jnp.float32)
    qrf = q_rope.astype(jnp.float32)
    s_t = (jnp.einsum("bqhr,btr->bqht", qlf, tc,
                      preferred_element_type=jnp.float32)
           + jnp.einsum("bqhp,btp->bqht", qrf, tr,
                        preferred_element_type=jnp.float32)) * scale
    valid = jnp.arange(tp)[None, :] < (t + 1)[:, None]
    part = SA.merge_partials((acc, m, l), SA.tail_partials(s_t, tc, valid))
    ctx_lat = SA.finalize(part[0], part[2], dtype=tail_c.dtype)  # (B,1,H,r)
    o = jnp.einsum("bqhr,rhv->bqhv", ctx_lat, w_v)
    out = jnp.einsum("bqhv,hvd->bqd", o, p["wo"])
    return out, (tail_c, tail_r)


def bytes_per_token_resident(m: int, tokens_per_page: int,
                             *, chunk: int = 1024,
                             esc_slot_per_elems: int = ESC_SLOT_PER_ELEMS
                             ) -> float:
    """Analytic HBM bytes/token of the paged resident format (DESIGN.md
    capacity model): 1.5 B/elem dense streams (sign-mantissa byte + packed
    nibble) + page escape metadata, independent of the source dtype.  ``m``
    is compressed elements per token (all compressible leaves summed)."""
    pe = tokens_per_page * m
    cap = max(8, pe // esc_slot_per_elems)
    return (pe + pe // 2 + cap * 3 + 4) / tokens_per_page
