"""Per-family inference cache structures.

The cache is *the* object SplitZip exists for: it is produced by prefill
workers, crosses the PD boundary compressed, and is consumed by decode
workers.  Every family stores its state stacked over layers (leading dim =
layer-stack) so the whole cache is one pytree the transfer engine can map
the codec over.

  dense/moe/vlm : k, v           (L, B, S, Hkv, hd)        bf16
  mla           : ckv, krope     (L, B, S, r) / (L, B, S, p) bf16
  ssm           : ssm, conv      (L, B, H, P, N) fp32 / (L, B, W-1, C) bf16
  hybrid        : attn k/v (windowed, right-aligned) + rglru h/conv
  audio         : none (encoder-only; the shipped artifact is the encoder
                  output itself)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DecodeState:
    """Raw decode-worker state.

    ``cache_len`` is PER ROW: a mixed-length (ragged) batch right-pads each
    row to the padded sequence length, and every consumer — decode
    attention's validity mask, transfer accounting, the resident pool's
    page tables — must read the per-row length, never the padded S.
    ``models.model.prefill`` builds it from ``batch['lengths']`` (scoring
    each row's logits at its own last real token).  The compressed-resident
    analogue is :class:`repro.models.kvpool.ResidentState`, which carries
    the same (B,) vector next to page tables instead of a raw cache."""
    cache: dict
    cache_len: jax.Array  # (B,) int32 — valid prefix length per row

    def valid_mask(self, max_seq: Optional[int] = None) -> jax.Array:
        """(B, S) bool — True where the cache holds a real token.  Only
        meaningful for families with a sequence axis (dense/moe/vlm/mla);
        S defaults to the cache's own sequence length."""
        if max_seq is None:
            max_seq = max(v.shape[2] for v in self.cache.values()
                          if v.ndim >= 3)
        return jnp.arange(max_seq)[None, :] < self.cache_len[:, None]


def n_triples_extra(cfg: ArchConfig):
    pat = len(cfg.hybrid.pattern)
    return cfg.num_layers // pat, cfg.num_layers % pat


def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=jnp.bfloat16) -> dict:
    l, b, s = cfg.num_layers, batch, max_seq
    if cfg.ssm is not None:
        d_inner = cfg.ssm.expand * cfg.d_model
        heads = d_inner // cfg.ssm.head_dim
        conv_ch = d_inner + 2 * cfg.ssm.n_groups * cfg.ssm.d_state
        return {
            "ssm": jnp.zeros((l, b, heads, cfg.ssm.head_dim, cfg.ssm.d_state), jnp.float32),
            "conv": jnp.zeros((l, b, cfg.ssm.conv_width - 1, conv_ch), dtype),
        }
    if cfg.hybrid is not None:
        nt, ne = n_triples_extra(cfg)
        w = min(cfg.hybrid.window, max_seq)
        u = cfg.hybrid.lru_width or cfg.d_model
        cw = cfg.hybrid.conv_width
        return {
            "attn_k": jnp.zeros((nt, b, w, cfg.num_kv_heads, cfg.head_dim), dtype),
            "attn_v": jnp.zeros((nt, b, w, cfg.num_kv_heads, cfg.head_dim), dtype),
            "rec_h": jnp.zeros((nt, 2, b, u), jnp.float32),
            "rec_conv": jnp.zeros((nt, 2, b, cw - 1, u), dtype),
            "extra_h": jnp.zeros((ne, b, u), jnp.float32),
            "extra_conv": jnp.zeros((ne, b, cw - 1, u), dtype),
        }
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "ckv": jnp.zeros((l, b, s, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((l, b, s, m.qk_rope_head_dim), dtype),
        }
    if cfg.encoder_only:
        return {}
    return {
        "k": jnp.zeros((l, b, s, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((l, b, s, cfg.num_kv_heads, cfg.head_dim), dtype),
    }


def cache_bytes(cache: dict) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def transferable_leaves(cache: dict):
    """(path, leaf) pairs the transfer engine compresses (bf16) vs ships raw
    (fp32 recurrent states — see DESIGN.md: the bf16 codec extends to fp32 as
    a beyond-paper variant, tracked separately)."""
    flat = jax.tree_util.tree_flatten_with_path(cache)[0]
    comp, raw = [], []
    for path, leaf in flat:
        (comp if leaf.dtype == jnp.bfloat16 else raw).append((path, leaf))
    return comp, raw
