"""Mixture-of-Experts FFN with sort-based capacity dispatch (Qwen3-MoE style).

Expert parallelism: the expert buffers carry a leading ``num_experts`` axis
that the sharding rules place on the mesh 'model' axis (128 experts / 16-way
TP = 8 experts per shard).  Dispatch is the XLA-friendly sort + bounded
scatter formulation: O(T·k) memory (no (T, E, C) one-hot), lowers to
argsort + scatter + two batched einsums, and SPMD inserts the all-to-all-ish
collectives at the dp→ep boundary.

Tokens beyond an expert's capacity are dropped (standard capacity-factor
semantics); the router's load-balancing auxiliary loss keeps drops rare.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.distributed.sharding import constrain


def init_moe(key, d_model: int, cfg: MoEConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    e, f = cfg.num_experts, cfg.d_ff_expert
    s_in = d_model ** -0.5
    return {
        "router": (jax.random.normal(k1, (d_model, e)) * s_in).astype(jnp.float32),
        "w_gate_up": (jax.random.normal(k2, (e, d_model, 2 * f)) * s_in).astype(jnp.bfloat16),
        "w_down": (jax.random.normal(k3, (e, f, d_model)) * (f ** -0.5)).astype(jnp.bfloat16),
    }


def capacity(num_tokens: int, cfg: MoEConfig) -> int:
    c = int(num_tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for layout friendliness


def moe_ffn(p, x: jax.Array, cfg: MoEConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    k = cfg.top_k
    e = cfg.num_experts
    cap = capacity(t, cfg)
    xf = constrain(x.reshape(t, d), "moe_td")

    # --- routing ------------------------------------------------------------
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = constrain(jax.nn.softmax(logits, axis=-1), "moe_te")  # (T, E)
    gate, expert_idx = jax.lax.top_k(probs, k)                   # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)  # qwen3 renorm

    # load-balance aux loss: E * Σ_e f_e · p_e  (Switch Transformer form)
    me = probs.mean(axis=0)                                       # (E,)
    onehot_top1 = jax.nn.one_hot(expert_idx[:, 0], e, dtype=jnp.float32)
    fe = onehot_top1.mean(axis=0)
    aux = e * jnp.sum(fe * me)

    # --- sort-based dispatch --------------------------------------------------
    flat_e = expert_idx.reshape(-1)                               # (T*k,)
    order = jnp.argsort(flat_e)                                   # stable
    e_sorted = flat_e[order]
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(t * k, dtype=jnp.int32) - starts[e_sorted]
    within = rank < cap
    slot = jnp.where(within, e_sorted * cap + rank, e * cap)      # OOB -> drop
    token_of = order // k                                          # source token

    buf = jnp.zeros((e * cap, d), x.dtype).at[slot].set(xf[token_of], mode="drop")
    h = constrain(buf.reshape(e, cap, d), "moe_ecd")

    # --- expert compute (batched over the expert axis; EP-sharded) -----------
    gu = constrain(jnp.einsum("ecd,edf->ecf", h, p["w_gate_up"]), "moe_ecf")
    g, u = jnp.split(gu, 2, axis=-1)
    act = jax.nn.silu(g) * u
    out = constrain(jnp.einsum("ecf,efd->ecd", act, p["w_down"]), "moe_ecd")
    out = out.reshape(e * cap, d)

    # --- combine ---------------------------------------------------------------
    y_sorted = out.at[slot].get(mode="fill", fill_value=0)        # dropped -> 0
    gate_sorted = gate.reshape(-1)[order].astype(x.dtype)
    contrib = y_sorted * gate_sorted[:, None]
    yf = constrain(jnp.zeros((t, d), x.dtype).at[token_of].add(contrib),
                   "moe_td")
    return yf.reshape(b, s, d), aux


def moe_ffn_dense_ref(p, x: jax.Array, cfg: MoEConfig) -> jax.Array:
    """O(T·E) reference (computes every expert for every token, then masks).
    Only for correctness tests on tiny configs."""
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    gu = jnp.einsum("td,edf->etf", xf, p["w_gate_up"])
    g, u = jnp.split(gu, 2, axis=-1)
    y_all = jnp.einsum("etf,efd->etd", jax.nn.silu(g) * u, p["w_down"])  # (E,T,D)
    weights = jnp.zeros((t, cfg.num_experts), jnp.float32)
    for j in range(cfg.top_k):
        weights = weights + jax.nn.one_hot(expert_idx[:, j], cfg.num_experts) * gate[:, j:j + 1]
    yf = jnp.einsum("etd,te->td", y_all, weights.astype(x.dtype))
    return yf.reshape(b, s, d)
