"""Griffin / RecurrentGemma recurrent block: conv1d + RG-LRU.

RG-LRU (arXiv:2402.19427):
    r_t = σ(W_a x_t + b_a)                      (recurrence gate)
    i_t = σ(W_x x_t + b_x)                      (input gate)
    a_t = exp(-c · softplus(Λ) · r_t),  c = 8
    h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)

Prefill/train evaluates the linear recurrence with
``jax.lax.associative_scan`` (log-depth, TPU-friendly); decode is the exact
one-step update.  The recurrent state (B, lru_width) is the sequence-length-
independent "KV cache" for the PD transfer path.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

RGLRU_C = 8.0


def init_rglru_block(key, d_model: int, lru_width: int, conv_width: int):
    ks = jax.random.split(key, 6)
    s = d_model ** -0.5
    su = lru_width ** -0.5
    return {
        "w_gate_branch": (jax.random.normal(ks[0], (d_model, lru_width)) * s).astype(jnp.bfloat16),
        "w_in": (jax.random.normal(ks[1], (d_model, lru_width)) * s).astype(jnp.bfloat16),
        "conv_w": (jax.random.normal(ks[2], (conv_width, lru_width)) * 0.1).astype(jnp.bfloat16),
        "conv_b": jnp.zeros((lru_width,), jnp.bfloat16),
        "w_a": (jax.random.normal(ks[3], (lru_width, lru_width)) * su).astype(jnp.bfloat16),
        "b_a": jnp.zeros((lru_width,), jnp.float32),
        "w_x": (jax.random.normal(ks[4], (lru_width, lru_width)) * su).astype(jnp.bfloat16),
        "b_x": jnp.zeros((lru_width,), jnp.float32),
        # Λ init so that a ∈ (0.9, 0.999) at r=1 (Griffin appendix)
        "lam": jnp.asarray(
            jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, lru_width)) / RGLRU_C)),
            jnp.float32),
        "w_out": (jax.random.normal(ks[5], (lru_width, d_model)) * su).astype(jnp.bfloat16),
    }


def _gates(p, x):
    """x: (..., lru) post-conv activations -> (log_a, gated_input) fp32."""
    r = jax.nn.sigmoid(jnp.einsum("...u,uv->...v", x, p["w_a"]).astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("...u,uv->...v", x, p["w_x"]).astype(jnp.float32) + p["b_x"])
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) in fp32, numerically guarded
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * i * x.astype(jnp.float32)


def rglru_scan(p, x: jax.Array, h0: jax.Array | None = None
               ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, lru) -> (h (B, S, lru), final state (B, lru))."""
    a, b = _gates(p, x)                     # (B, S, U) each, fp32

    if h0 is not None:
        # fold the initial state in as a virtual step 0
        a = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([h0.astype(jnp.float32)[:, None], b], axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    if h0 is not None:
        hh = hh[:, 1:]
    return hh.astype(x.dtype), hh[:, -1]


def rglru_step(p, x_t: jax.Array, h: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x_t: (B, lru), h: (B, lru) -> (out, new_h)."""
    a, b = _gates(p, x_t)
    new_h = a * h.astype(jnp.float32) + b
    return new_h.astype(x_t.dtype), new_h


def _causal_conv(x, w, b):
    width = w.shape[0]
    pads = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    return sum(pads[:, i: i + x.shape[1], :] * w[i] for i in range(width)) + b


def recurrent_block_forward(p, x: jax.Array, state=None
                            ) -> Tuple[jax.Array, dict]:
    """Griffin recurrent block over a full sequence.

    state (for continuation / transfer): {"h": (B, U) fp32,
    "conv": (B, conv_width-1, U) rolling pre-conv inputs}."""
    gate = jax.nn.gelu(jnp.einsum("bsd,du->bsu", x, p["w_gate_branch"]))
    u = jnp.einsum("bsd,du->bsu", x, p["w_in"])
    uc = _causal_conv(u, p["conv_w"], p["conv_b"])
    h0 = state["h"] if state is not None else None
    hseq, h_last = rglru_scan(p, uc, h0=h0)
    y = hseq * gate
    out = jnp.einsum("bsu,ud->bsd", y, p["w_out"])
    width = p["conv_w"].shape[0]
    new_state = {"h": h_last, "conv": u[:, -(width - 1):, :]}
    return out, new_state


def recurrent_block_step(p, x: jax.Array, state: dict) -> Tuple[jax.Array, dict]:
    """Single decode step: x (B, 1, D)."""
    gate = jax.nn.gelu(jnp.einsum("bsd,du->bsu", x, p["w_gate_branch"]))[:, 0]
    u = jnp.einsum("bsd,du->bsu", x, p["w_in"])[:, 0]              # (B, U)
    window = jnp.concatenate([state["conv"], u[:, None, :]], axis=1)
    uc = jnp.einsum("bwu,wu->bu", window, p["conv_w"]) + p["conv_b"]
    h_out, h_new = rglru_step(p, uc, state["h"])
    y = h_out * gate
    out = jnp.einsum("bu,ud->bd", y, p["w_out"])[:, None, :]
    return out, {"h": h_new, "conv": window[:, 1:, :]}
