import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver builds the jitted step (train_step for train
shapes, prefill_step for prefill shapes, serve_step for decode shapes) with
the production sharding policy, calls ``.lower(...).compile()`` against
ShapeDtypeStruct inputs (no allocation), and records:

  - memory_analysis()  (per-device bytes — proves it fits 16 GB v5e HBM)
  - cost_analysis()    (FLOPs / bytes for the roofline)
  - collective bytes   (parsed from post-SPMD HLO)

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--out out.json]

Results are cached per cell in benchmarks/results/dryrun/ so the full sweep
is resumable.
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.analysis import roofline as RL
from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, cells, get_config, shape_applicable
from repro.distributed.sharding import ShardingPolicy, use_policy
from repro.launch.mesh import describe, make_production_mesh
from repro.models import model as M
from repro.training import optimizer as OPT
from repro.training import train_step as TS

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


def _cell_id(arch: str, shape: str, multi_pod: bool, variant: str = "base") -> str:
    return f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}__{variant}"


# policy knobs per variant (see EXPERIMENTS.md §Perf for the iteration log)
POLICY_VARIANTS = {
    "base": {},
    "noremat": {},
    "gradcomp": {},
    "fsdp": dict(fsdp=True),
    "moe": dict(moe_dispatch_sharding=True),
    "fsdp_moe": dict(fsdp=True, moe_dispatch_sharding=True),
    # PD-transfer variants (prefill shapes, multi-pod mesh): prefill + KV
    # handoff across the pod axis — raw / paper-chunked / global SplitZip
    "xfer_raw": dict(pd_disaggregated=True),
    "xfer_chunked": dict(pd_disaggregated=True),
    "xfer_global": dict(pd_disaggregated=True),
    # isolated KV handoff (no prefill compute): the paper's codec path alone,
    # so the DCN collective-permute bytes are exactly the wire payload
    "xferonly_raw": dict(pd_disaggregated=True),
    "xferonly_chunked": dict(pd_disaggregated=True),
    "xferonly_global": dict(pd_disaggregated=True),
    "xferonly_tight": dict(pd_disaggregated=True),
    "xferonly_fp32": dict(pd_disaggregated=True),
    # per-chunk ppermute with double-buffering (TransferPlan n_chunks > 1)
    "xferonly_pipelined": dict(pd_disaggregated=True),
    # attention perf variants (EXPERIMENTS.md §Perf Cell A)
    "attn_bf16": {},
    "attn_kv4096": {},
    "attn_bf16_kv4096": {},
}

# attention-knob overrides per variant (threaded through models/layers.py)
ATTN_VARIANTS = {
    "attn_bf16": dict(score_dtype="bfloat16"),
    "attn_kv4096": dict(kv_block=4096),
    "attn_bf16_kv4096": dict(score_dtype="bfloat16", kv_block=4096),
}


def make_policy(mesh, variant: str) -> ShardingPolicy:
    return ShardingPolicy(mesh, **POLICY_VARIANTS.get(variant, {}))


def _cost_dict(compiled) -> dict:
    """compiled.cost_analysis() returns a dict on new jax and a one-element
    list of dicts on the 0.4.x line this repo pins — normalize to a dict."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def _variant_ctx(variant: str):
    """Tracing-time context for attention-knob variants."""
    kw = ATTN_VARIANTS.get(variant)
    if not kw:
        import contextlib
        return contextlib.nullcontext()
    from repro.models import layers as LAY
    kw = dict(kw)
    if "score_dtype" in kw:
        kw["score_dtype"] = jnp.dtype(kw["score_dtype"])
    return LAY.attn_overrides(**kw)


def _transfer_config(variant: str):
    from repro.core.codebook import DEFAULT_BF16_CODEBOOK as cb
    from repro.serving import transfer as T
    if variant.endswith("_raw"):
        return T.TransferConfig(codebook=cb, enabled=False)
    if variant.endswith("_chunked"):
        return T.TransferConfig(codebook=cb, chunk=1024, cap=64)
    if variant.endswith("_fp32"):
        # beyond-paper: also hi/lo-split-compress fp32 recurrent states
        return T.TransferConfig(codebook=cb, layout="global",
                                global_budget=0.0025, compress_fp32=True)
    if variant.endswith("_pipelined"):
        # chunked mesh path: per-chunk ppermute, double-buffered
        return T.TransferConfig(codebook=cb, chunk=1024, cap=64, n_chunks=8)
    if variant.endswith("_tight"):
        # 0.25% escape budget: 16x the paper's mean escape rate; overflow
        # still detected per tensor and falls back to raw
        return T.TransferConfig(codebook=cb, layout="global",
                                global_budget=0.0025)
    return T.TransferConfig(codebook=cb, layout="global")


def build_lowerable(cfg: ArchConfig, shape: ShapeConfig, policy: ShardingPolicy,
                    variant: str = "base"):
    """Returns (jitted_fn, example_args) ready for .lower(*args)."""
    mesh = policy.mesh
    from jax.sharding import NamedSharding, PartitionSpec as P

    remat = variant != "noremat"

    if variant.startswith("xferonly"):
        # isolated paper pipeline: cache in -> SplitZip -> DCN hop -> cache out
        if "pod" not in mesh.shape:
            raise ValueError("transfer variants need the multi-pod mesh")
        from repro.serving.plan import TransferPlan
        tc = _transfer_config(variant)
        state_abs = M.abstract_state(cfg, shape.global_batch, shape.seq_len)
        cache_abs = state_abs.cache
        specs = policy.cache_specs(cache_abs)
        cache_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                                is_leaf=lambda x: isinstance(x, P))
        # the plan resolves routes/segments/specs once, from abstract shapes
        session = TransferPlan.build(cache_abs, tc, mesh=mesh,
                                     specs=specs).session()

        def fn(cache):
            with use_policy(policy):
                return session.transfer(cache, select_dst=False)

        jitted = jax.jit(fn, in_shardings=(cache_sh,))
        return jitted, (cache_abs,)

    if variant.startswith("xfer"):
        # paper's own pipeline: prefill -> SplitZip -> DCN hop -> decode pod
        if shape.kind != "prefill":
            raise ValueError("transfer variants apply to prefill shapes")
        if "pod" not in mesh.shape:
            raise ValueError("transfer variants need the multi-pod mesh")
        from repro.serving.plan import TransferPlan
        from repro.serving.prefill import prefill_step
        tc = _transfer_config(variant)

        params_abs = M.abstract_params(cfg)
        params_sh = policy.param_sharding(params_abs)
        batch_abs = M.input_specs(cfg, shape)
        batch_sh = jax.tree.map(
            lambda x: NamedSharding(mesh, policy.spec_for_activation(
                "tokens", tuple(x.shape))), batch_abs)

        def fn(params, batch):
            with use_policy(policy):
                out = prefill_step(params, batch, cfg, max_seq=shape.seq_len)
                cache = out.state.cache
                # plan built at trace time (shapes are static): one build
                # per compilation, executed by the session inside the jit
                session = TransferPlan.build(
                    cache, tc, mesh=mesh,
                    specs=policy.cache_specs(cache)).session()
                moved = session.transfer(cache)
                return out.first_token, moved

        jitted = jax.jit(fn, in_shardings=(params_sh, batch_sh))
        return jitted, (params_abs, batch_abs)

    if shape.kind == "train":
        step = TS.make_train_step(cfg, OPT.AdamWConfig(), policy,
                                  grad_compress=(variant == "gradcomp"),
                                  remat=remat)
        state_abs = TS.abstract_state(cfg)
        batch_abs = M.input_specs(cfg, shape)
        jitted, (state_sh, batch_sh) = TS.jit_train_step(step, policy,
                                                         state_abs, batch_abs)
        return jitted, (state_abs, batch_abs)

    params_abs = M.abstract_params(cfg)
    params_sh = policy.param_sharding(params_abs)

    if shape.kind == "prefill":
        from repro.serving.prefill import prefill_step

        def fn(params, batch):
            with use_policy(policy):
                return prefill_step(params, batch, cfg, max_seq=shape.seq_len)

        batch_abs = M.input_specs(cfg, shape)
        batch_sh = jax.tree.map(
            lambda x: NamedSharding(mesh, policy.spec_for_activation(
                "tokens", tuple(x.shape))), batch_abs)
        jitted = jax.jit(fn, in_shardings=(params_sh, batch_sh))
        return jitted, (params_abs, batch_abs)

    # decode: serve_step over a full-length cache
    from repro.models.kvcache import DecodeState
    from repro.serving.decode import serve_step

    state_abs = M.abstract_state(cfg, shape.global_batch, shape.seq_len)
    cache_sh = policy.cache_sharding(state_abs.cache)
    state_sh = DecodeState(cache=cache_sh,
                           cache_len=NamedSharding(mesh, P()))
    tok_abs = M.input_specs(cfg, shape)["tokens"]
    tok_sh = NamedSharding(mesh, policy.spec_for_activation(
        "tokens", tuple(tok_abs.shape)))

    def fn(params, tokens, state):
        with use_policy(policy):
            return serve_step(params, tokens, state, cfg)

    jitted = jax.jit(fn, in_shardings=(params_sh, tok_sh, state_sh),
                     donate_argnums=(2,))
    return jitted, (params_abs, tok_abs, state_abs)


def _extrapolation_depths(cfg: ArchConfig):
    """(L1, L2) reduced depths for the unrolled cost builds."""
    if cfg.hybrid is not None:
        pat = len(cfg.hybrid.pattern)
        return pat, 2 * pat
    return 2, 4


def measure_costs(cfg: ArchConfig, shape: ShapeConfig, policy: ShardingPolicy,
                  variant: str) -> dict:
    """flops / bytes / per-kind collective bytes, everything-unrolled build.

    XLA cost_analysis counts `while` bodies once (see models/scanctl.py), so
    a scanned 94-layer model reports ~1 layer of work.  We compile twice at
    reduced depths L1 < L2 with every scan unrolled and extrapolate linearly
    in L: per-layer compute, per-layer params (optimizer), per-layer
    collectives all scale with L; embed/head/loss are the intercept."""
    from repro.models import scanctl

    def one(cfg_l):
        with scanctl.cost_mode(True), _variant_ctx(variant):
            jitted, args = build_lowerable(cfg_l, shape, policy, variant)
            compiled = jitted.lower(*args).compile()
        cost = _cost_dict(compiled)
        colls = RL.collective_bytes_from_hlo(compiled.as_text())
        return {
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "colls": colls,
        }

    L = cfg.num_layers
    l1, l2 = _extrapolation_depths(cfg)
    if L <= l2:  # shallow enough to measure directly
        m = one(cfg.with_layers(L))
        m["depths"] = [L]
        return m
    m1, m2 = one(cfg.with_layers(l1)), one(cfg.with_layers(l2))

    def lerp(a, b):
        return a + (b - a) * (L - l1) / (l2 - l1)

    return {
        "flops": lerp(m1["flops"], m2["flops"]),
        "bytes": lerp(m1["bytes"], m2["bytes"]),
        "colls": {k: lerp(m1["colls"][k], m2["colls"][k])
                  for k in m1["colls"]},
        "depths": [l1, l2],
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             variant: str = "base", cache: bool = True) -> dict:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    cid = _cell_id(arch, shape_name, multi_pod, variant)
    cpath = os.path.join(RESULTS_DIR, cid + ".json")
    if cache and os.path.exists(cpath):
        with open(cpath) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        result = {"cell": cid, "status": "skipped", "reason": why}
        with open(cpath, "w") as f:
            json.dump(result, f)
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = make_policy(mesh, variant)
    t0 = time.time()
    try:
        with _variant_ctx(variant):
            jitted, args = build_lowerable(cfg, shape, policy, variant)
            lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        cost = _cost_dict(compiled)
        try:
            mem = compiled.memory_analysis()
            mem_stats = {
                "peak_bytes": getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                - getattr(mem, "alias_size_in_bytes", 0),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "generated_code_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
            }
        except Exception:
            mem_stats = {}

        hlo = compiled.as_text()
        chips = mesh.devices.size
        # scan-raw numbers (while bodies counted once — kept for reference)
        raw_report = RL.build_report(arch, shape, describe(mesh), chips,
                                     {k: cost.get(k, 0.0) for k in
                                      ("flops", "bytes accessed")},
                                     hlo, cfg, mem_stats)
        # corrected costs: unrolled reduced-depth builds, extrapolated in L
        t0c = time.time()
        meas = measure_costs(cfg, shape, policy, variant)
        t_cost = time.time() - t0c
        report = RL.build_report(arch, shape, describe(mesh), chips,
                                 {"flops": meas["flops"],
                                  "bytes accessed": meas["bytes"]},
                                 hlo, cfg, mem_stats, colls=meas["colls"])
        result = {
            "cell": cid, "status": "ok",
            "t_lower_s": t_lower, "t_compile_s": t_compile,
            "t_costmeasure_s": t_cost,
            "mesh": describe(mesh),
            "memory": mem_stats,
            "cost": {k: float(v) for k, v in cost.items()
                     if isinstance(v, (int, float))},
            "cost_extrapolation_depths": meas.get("depths"),
            "roofline": report.to_dict(),
            "roofline_scanraw": raw_report.to_dict(),
        }
    except Exception as e:  # a failure here is a bug in the system
        result = {"cell": cid, "status": "error",
                  "error": f"{type(e).__name__}: {e}",
                  "trace": traceback.format_exc()[-2000:]}
    with open(cpath, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--out")
    args = ap.parse_args()

    if args.all:
        todo = [(a, s) for (a, s) in cells()]
    else:
        todo = [(args.arch, args.shape)]

    results = []
    for arch, shape in todo:
        r = run_cell(arch, shape, args.multi_pod, args.variant,
                     cache=not args.no_cache)
        status = r["status"]
        extra = ""
        if status == "ok":
            rl = r["roofline"]
            extra = (f" bottleneck={rl['bottleneck']}"
                     f" frac={rl['roofline_fraction']:.3f}"
                     f" mem/chip={(r['memory'].get('peak_bytes') or 0)/2**30:.2f}GiB"
                     f" compile={r['t_compile_s']:.0f}s")
        elif status == "error":
            extra = " " + r["error"][:160]
        print(f"[{status:>7}] {r['cell']}{extra}", flush=True)
        results.append(r)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    n_err = sum(1 for r in results if r["status"] == "error")
    print(f"done: {len(results)} cells, {n_err} errors")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
