"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then calls this.

Topology mapping (TPU v5e posture): 'model' on the innermost ICI ring (TP
collectives are latency-critical), 'data' on the remaining ICI dims, 'pod'
over DCN.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (elastic replans, tests on small device counts)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def describe(mesh) -> str:
    return " × ".join(f"{k}={v}" for k, v in mesh.shape.items()) + \
        f"  ({len(mesh.devices.ravel())} chips)"
