"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the full disaggregated pipeline in local mode: calibrate a SplitZip
codebook on this model's real KV activations, then prefill -> compressed
transfer -> decode for a batch of synthetic prompts, reporting transfer
ratio, codec health, and (analytic) transfer-time speedup under a chosen
link bandwidth.

``--codec-backend`` selects the codec implementation from the registry
(``auto`` | ``xla`` | ``pallas`` | ``wire``; ``auto`` — the default —
resolves to the fused Pallas kernels on TPU and the XLA reference
elsewhere); ``--n-chunks`` > 1 switches the transfer stage to the chunked
pipelined engine and reports per-chunk wire bytes; ``--compress-fp32``
routes fp32 recurrent states through the plan's hi/lo split (folded into
the chunked stream).  The engine resolves all of this ONCE into a
``TransferPlan`` (printed at the end as the per-leaf routing table) and
executes it through a ``TransferSession`` on every transfer.

``--profile`` selects the codec-profile source for the analytic transfer
report (:mod:`repro.core.profile`): ``paper`` (the H200 datasheet
constants, the fresh-checkout default), ``measured`` (the calibrated
``benchmarks/results/profiles.json``, measuring a small workload on the
spot when none exists), or an explicit ``profiles.json`` path.  The
resolved provenance is printed with the report, so "speedup at N Gb/s"
always says which cost model produced it.  See DESIGN.md's operator guide
for the full flag walk-through.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ShapeConfig, get_config
from repro.core import codebook as cbm
from repro.core.backend import available_backends
from repro.core.profile import resolve_profile
from repro.models import model as M
from repro.serving.engine import DisaggregatedEngine


def calibrate_on_model(cfg, params, seq=32, batch=2) -> cbm.Codebook:
    """Paper §3.3: one-time calibration on representative KV tensors."""
    shape = ShapeConfig("calib", seq_len=seq, global_batch=batch, kind="train")
    prompt = {k: v for k, v in M.make_inputs(cfg, shape, seq=seq).items()
              if k != "labels"}
    _, state = M.prefill(params, prompt, cfg, max_seq=seq)
    leaves = [np.asarray(jax.lax.bitcast_convert_type(x, jnp.uint16)).ravel()
              for x in jax.tree.leaves(state.cache) if x.dtype == jnp.bfloat16]
    if not leaves:
        return cbm.DEFAULT_BF16_CODEBOOK
    return cbm.calibrate(leaves, k=16)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--link-gbps", type=float, default=100.0,
                    help="simulated PD link (Gbit/s) for the analytic report")
    ap.add_argument("--no-compress", action="store_true")
    ap.add_argument("--codec-backend", default="auto",
                    choices=sorted(available_backends()),
                    help="codec backend registry key (core/backend.py); "
                         "'auto' resolves to the fused pallas kernels on "
                         "TPU, xla elsewhere")
    ap.add_argument("--n-chunks", type=int, default=1,
                    help=">1 => chunked pipelined transfer engine")
    ap.add_argument("--compress-fp32", action="store_true",
                    help="hi/lo-split-compress fp32 recurrent states "
                         "(SSM/RG-LRU) through the plan's fp32_hilo route")
    ap.add_argument("--profile", default="paper",
                    help="codec profile source for the analytic report: "
                         "'paper' (H200 datasheet constants), 'measured' "
                         "(calibrated benchmarks/results/profiles.json; "
                         "measures a small workload now if absent), or a "
                         "profiles.json path")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.encoder_only:
        raise SystemExit(f"{cfg.name} is encoder-only; use the hubert "
                         "encode-and-ship example instead")

    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cb = calibrate_on_model(cfg, params)
    print(f"calibrated top-16 exponents: {cb.exponents}")

    profile = resolve_profile(args.profile,
                              link_bw=args.link_gbps * 1e9 / 8,
                              backend=args.codec_backend)
    eng = DisaggregatedEngine(cfg, params, cb,
                              compress=not args.no_compress,
                              backend=args.codec_backend,
                              n_chunks=args.n_chunks,
                              compress_fp32=args.compress_fp32,
                              profile=profile)

    shape = ShapeConfig("serve", seq_len=args.prompt_len,
                        global_batch=args.batch, kind="prefill")
    prompt = {k: v for k, v in
              M.make_inputs(cfg, shape, seq=args.prompt_len).items()
              if k != "labels"}
    t0 = time.time()
    out = eng.generate(prompt, num_steps=args.new_tokens,
                       max_seq=args.prompt_len + args.new_tokens + 1)
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s (CPU wall clock)")
    print(f"cache raw bytes      : {eng.stats.raw_cache_bytes:,.0f}")
    print(f"cache wire bytes     : {eng.stats.wire_bytes:,.0f}")
    print(f"transfer ratio       : {eng.stats.transfer_ratio:.3f}x")
    print(f"codec ok (no overflow): {eng.stats.codec_ok}")
    resolved = eng.tc.get_backend().name
    print(f"codec backend        : {args.codec_backend}"
          + (f" (resolved: {resolved})" if args.codec_backend == "auto" else ""))
    print(eng.describe_plan())
    if eng.stats.chunk_retries:
        print(f"capacity schedule    : {eng.stats.chunk_retries} units "
              f"retried, {eng.stats.chunk_retry_steps} extra encode attempts")
    if eng.stats.chunk_wire_bytes:
        per = eng.stats.chunk_wire_bytes
        print(f"pipelined chunks     : {len(per)} shipped "
              f"(requested {args.n_chunks}; alignment to the codec chunk can "
              f"produce fewer) — per-chunk wire bytes "
              f"min={min(per):,.0f} max={max(per):,.0f}")
    rep = eng.transfer_report()
    if rep:
        print(f"analytic transfer    : native {rep.t_native*1e3:.2f} ms -> "
              f"splitzip {rep.t_splitzip*1e3:.2f} ms "
              f"({rep.speedup:.3f}x at {args.link_gbps:.0f} Gb/s, "
              f"profile: {profile.source})")


if __name__ == "__main__":
    main()
