"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs real steps on the available devices (CPU smoke scale by default; the
same driver pjit-compiles on TPU meshes).  Wires together: config system,
synthetic data pipeline, sharded train step, SplitZip-compressed
checkpointing, fault-tolerant resume, and optional compressed cross-pod
gradient sync.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig, get_config
from repro.distributed import checkpoint as CKPT
from repro.distributed.sharding import ShardingPolicy
from repro.launch.mesh import make_mesh
from repro.training import grad_compress as GC
from repro.training import optimizer as OPT
from repro.training import train_step as TS
from repro.training.data import SyntheticTokenStream


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--mesh", default="", help="e.g. '2,2' => data=2,model=2")
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeConfig("cli", seq_len=args.seq, global_batch=args.batch,
                        kind="train")

    policy = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split(","))
        axes = ("pod", "data", "model")[-len(dims):]
        policy = ShardingPolicy(make_mesh(dims, axes))

    opt_cfg = OPT.AdamWConfig(lr=args.lr, total_steps=max(args.steps, 2),
                              warmup_steps=max(args.steps // 10, 1))
    step_fn = jax.jit(TS.make_train_step(cfg, opt_cfg, policy,
                                         grad_compress=args.grad_compress,
                                         kv_block=min(args.seq, 1024)))
    data = SyntheticTokenStream(cfg, shape)

    # one Checkpointer for the whole run: the TransferPlan (and its session)
    # is built once per state structure, and every save/restore accumulates
    # into one TransferStats surface
    ckpt = CKPT.Checkpointer(args.ckpt_dir) if args.ckpt_dir else None

    start_step = 0
    state = TS.init_state(cfg, jax.random.PRNGKey(0))
    if args.resume and ckpt and CKPT.latest_step(args.ckpt_dir) is not None:
        state, extra, start_step = ckpt.restore(state)
        print(f"resumed from step {start_step}")

    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = data.batch_at(step)
        state, metrics = step_fn(state, batch)
        if step % args.log_every == 0:
            print(f"step {step:5d}  loss {float(metrics['loss']):.4f}  "
                  f"ce {float(metrics['ce']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
        if ckpt and (step + 1) % args.ckpt_every == 0:
            path = ckpt.save(step + 1, state, extra={"arch": cfg.name})
            print(f"checkpointed -> {path}")
    dt = time.time() - t0
    tok = (args.steps - start_step) * args.batch * args.seq
    print(f"done: {args.steps - start_step} steps, {tok / max(dt, 1e-9):.0f} tok/s")
    if ckpt is not None:
        s = ckpt.stats
        print(f"checkpoint plane: {s.wire_bytes:.0f} wire bytes  "
              f"refetches {s.refetches}  verify_failures {s.verify_failures}")
    if args.grad_compress and GC.last_stats is not None:
        g = GC.last_stats
        print(f"gradient plane (per step): {g.wire_bytes:.0f} wire bytes  "
              f"raw ring fallbacks {g.raw_refetches}")


if __name__ == "__main__":
    main()
