"""Flash-attention Pallas kernel vs the jnp oracle (interpret mode on CPU).

Sweeps shapes (incl. GQA groupings, MLA-style dv != d, non-divisible sequence
lengths that exercise padding) and dtypes, causal and bidirectional, plus a
seeded random-shape sweep (hypothesis-free so collection never depends on an
optional package).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import attention_ref
from repro.models import layers as L

TOL = {jnp.bfloat16: 3e-2, jnp.float32: 2e-5}


def make(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape), dtype)


def check(b, sq, skv, h, hkv, d, dv, dtype, causal, blk_q=64, blk_k=64):
    q = make((b, sq, h, d), dtype, 1)
    k = make((b, skv, hkv, d), dtype, 2)
    v = make((b, skv, hkv, dv), dtype, 3)
    out = flash_attention(q, k, v, causal=causal, blk_q=blk_q, blk_k=blk_k)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


class TestShapes:
    @pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
    @pytest.mark.parametrize("causal", [True, False])
    def test_mha(self, dtype, causal):
        check(2, 128, 128, 4, 4, 64, 64, dtype, causal)

    @pytest.mark.parametrize("g", [2, 4, 8])
    def test_gqa_groups(self, g):
        check(1, 128, 128, 8, 8 // g, 32, 32, jnp.bfloat16, True)

    def test_mqa(self):
        check(2, 128, 128, 8, 1, 64, 64, jnp.bfloat16, True)

    def test_mla_value_dim(self):
        # MLA: value head dim differs from qk head dim
        check(1, 128, 128, 4, 4, 96, 64, jnp.bfloat16, True)

    @pytest.mark.parametrize("sq", [65, 100, 127, 200])
    def test_ragged_seq_padding(self, sq):
        check(1, sq, sq, 4, 2, 32, 32, jnp.bfloat16, True)

    def test_cross_attention_lengths(self):
        check(1, 64, 192, 4, 2, 32, 32, jnp.bfloat16, False)

    @pytest.mark.parametrize("blk", [(32, 32), (64, 128), (128, 64)])
    def test_block_shapes(self, blk):
        check(1, 256, 256, 4, 2, 32, 32, jnp.bfloat16, True,
              blk_q=blk[0], blk_k=blk[1])


class TestTailBlocks:
    """Boundary-shape pinning tests (ISSUE 8 satellite).

    The suspected tail-block masking bug — q/kv lengths that leave a
    partial final block, where an unmasked padding lane could leak into the
    softmax — did NOT reproduce under any of these probes: the kernel masks
    the ragged tail correctly for every (seq % blk) residue class,
    including the hardest cases (residue 1, blk-1, and a kv tail shorter
    than one block).  Kept as regression pins so a future refactor of the
    tail masking cannot break these silently."""

    # residues 1 and blk-1 on both axes, plus a sub-block kv tail
    @pytest.mark.parametrize("sq,skv,blk_q,blk_k", [
        (65, 65, 64, 64),      # residue 1 on both axes
        (127, 127, 64, 64),    # residue blk-1
        (64, 65, 64, 64),      # exact q blocks, kv residue 1
        (65, 64, 64, 64),      # q residue 1, exact kv blocks
        (100, 33, 64, 32),     # kv tail of one lane past a block
        (33, 100, 32, 64),
        (16, 16, 64, 64),      # whole sequence smaller than one block
        (1, 200, 64, 64),      # single-query decode shape, ragged kv
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_partial_tail_blocks(self, sq, skv, blk_q, blk_k, causal):
        if causal and sq != skv:
            pytest.skip("causal path assumes aligned q/kv positions")
        check(1, sq, skv, 4, 2, 32, 32, jnp.bfloat16, causal,
              blk_q=blk_q, blk_k=blk_k)

    def test_tail_block_ignores_padding_values(self):
        """Poison the padded kv region with huge values: the output over
        the valid prefix must be unchanged (padding fully masked)."""
        sq = skv = 65                              # one ragged tail block
        q = make((1, sq, 4, 32), jnp.float32, 1)
        k = make((1, skv, 2, 32), jnp.float32, 2)
        v = make((1, skv, 2, 32), jnp.float32, 3)
        base = flash_attention(q, k, v, causal=True, blk_q=64, blk_k=64)
        # the kernel pads internally; poison by extending with huge values
        # and re-truncating the VALID region must not change
        kp = jnp.concatenate([k, jnp.full((1, 63, 2, 32), 1e4, k.dtype)], 1)
        vp = jnp.concatenate([v, jnp.full((1, 63, 2, 32), 1e4, v.dtype)], 1)
        qp = jnp.concatenate([q, jnp.zeros((1, 63, 4, 32), q.dtype)], 1)
        ext = flash_attention(qp, kp, vp, causal=True, blk_q=64, blk_k=64)
        np.testing.assert_allclose(
            np.asarray(base, np.float32), np.asarray(ext[:, :sq], np.float32),
            atol=2e-5, rtol=2e-5)


class TestConsistency:
    def test_matches_chunked_attention(self):
        """The XLA path (models/layers.chunked_attention) and the kernel are
        independent implementations; they must agree."""
        q = make((2, 128, 8, 64), jnp.bfloat16, 5)
        k = make((2, 128, 2, 64), jnp.bfloat16, 6)
        v = make((2, 128, 2, 64), jnp.bfloat16, 7)
        a = flash_attention(q, k, v, causal=True, blk_q=64, blk_k=64)
        b = L.chunked_attention(q, k, v, causal=True, kv_block=64)
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=3e-2, rtol=3e-2)

    def test_numerical_stability_large_scores(self):
        # online softmax must not overflow on large logits
        q = make((1, 64, 2, 32), jnp.float32, 8) * 30
        k = make((1, 64, 2, 32), jnp.float32, 9) * 30
        v = make((1, 64, 2, 32), jnp.float32, 10)
        out = flash_attention(q, k, v, causal=True, blk_q=32, blk_k=32)
        assert bool(jnp.all(jnp.isfinite(out)))
        ref = attention_ref(q, k, v, causal=True)
        # online (two-pass) softmax reorders f32 ops; at |logit| ~ 900 the
        # divergence vs the direct oracle is ~5e-4 — finite and stable
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("seed", range(10))
def test_random_shapes(seed):
    """Seeded stand-in for the former hypothesis property test: random
    (batch, seq, heads, group, head-dim, causality) combinations."""
    rng = np.random.default_rng(seed)
    b = int(rng.integers(1, 3))
    sq = int(rng.integers(8, 97))
    h = int(rng.choice([2, 4, 8]))
    g = int(rng.choice([1, 2]))
    d = int(rng.choice([16, 32]))
    causal = bool(rng.integers(0, 2))
    hkv = max(1, h // g)
    h = hkv * g
    check(b, sq, sq, h, hkv, d, d, jnp.float32, causal, blk_q=32, blk_k=32)
