"""ISSUE 10: fleet-scale serving — the property-based invariant harness.

The cluster scheduler (N prefill x M decode over heterogeneous links, routed
placement, prefix-aware delta transfer) is pinned by properties that must
hold on EVERY topology x policy x router x trace combination, not by
hand-picked examples:

* **termination / no starvation** — every submitted request reaches exactly
  one terminal state; completed requests generated their full token budget.
* **per-link conservation** — each link's busy counter equals the sum of its
  transfers' occupancy intervals, and those intervals are pairwise disjoint
  (a link is a serial resource; double-booking it would fabricate
  bandwidth).
* **wire decomposition** — ``transfer_bytes + prefix_hit_bytes`` equals the
  full raw size of everything that crossed (or was elided from) the wire:
  prefix hits are accounted, never dropped.
* **submission-order determinism** — the event engine's output is a function
  of the trace, not of ``submit()`` call order.
* **1x1x1 degeneration** — a single-prefill / single-link / single-decode
  cluster reproduces the legacy (pre-cluster) scheduler configuration
  bit-identically for every link policy, per-request field by field.

The harness sweeps ``>= 200`` seeded scenarios drawn from the full cross
product; every scenario is reproducible from its printed parameters.
"""

import random

import pytest

from repro.core.pipeline import CodecProfile
from repro.serving.cluster import (ClusterConfig, LinkSpec, PrefixDirectory,
                                   resolve_cluster)
from repro.serving.policy import available_policies
from repro.serving.router import Router, available_routers, get_router
from repro.serving.scheduler import (DisaggregatedScheduler, Request,
                                     SchedulerConfig, summarize)
from repro.serving.traces import (DEFAULT_TENANTS, TenantClass, TraceConfig,
                                  generate_trace)

KV_BYTES_TOK = 2 * 32 * 8 * 128 * 2
PROF = CodecProfile(g_enc=613.3e9, g_dec=2181.8e9, ratio=1.324, link_bw=25e9)
TERMINAL = ("completed", "shed", "failed-over")


def _cfg(**kw):
    base = dict(kv_bytes_per_token=KV_BYTES_TOK, profile=PROF, compress=True,
                prefill_time_per_token=1e-7, decode_time_per_step=1e-4,
                max_prefill_batch=4, max_decode_slots=64)
    base.update(kw)
    return SchedulerConfig(**base)


def _run(cfg, reqs):
    s = DisaggregatedScheduler(cfg)
    for r in reqs:
        s.submit(r)
    return s, s.run()


def _trace(seed, n=10, session_p=0.0):
    return generate_trace(TraceConfig(
        seed=seed, n_requests=n, session_p=session_p, prompt_min=16,
        prompt_max=512, mean_burst_gap_s=2e-4, burst_spread_s=2e-5,
        followup_tokens=(8, 64),
        tenants=(TenantClass("interactive", 0.5, 0.05, (1, 4)),
                 TenantClass("batch", 0.5, 1.0, (2, 8)))))


def _fields(r):
    return (r.rid, r.state, r.worker, r.prefill_done, r.link_start,
            r.transfer_done, r.admit_time, r.first_token_time, r.finish_time,
            r.tokens_out, r.failovers, r.retries, tuple(r.link_history),
            tuple(r.link_ids))


# ---------------------------------------------------------------------------
# the invariants
# ---------------------------------------------------------------------------

def _check_terminal(done, n, ctx):
    assert len(done) == n, f"{ctx}: {n - len(done)} requests not terminal"
    for r in done:
        assert r.state in TERMINAL, f"{ctx}: rid {r.rid} state {r.state!r}"
        if r.state == "completed":
            assert r.tokens_out == r.max_new_tokens, \
                f"{ctx}: rid {r.rid} produced {r.tokens_out}" \
                f"/{r.max_new_tokens} tokens"
            # (admit_time may precede transfer_done under 'spec' links)
            assert r.finish_time >= r.transfer_done >= r.link_start \
                >= r.prefill_done >= r.arrival, \
                f"{ctx}: rid {r.rid} lifecycle out of order"
            assert r.admit_time >= r.prefill_done, \
                f"{ctx}: rid {r.rid} admitted before prefill"


def _check_links(sched, done, ctx):
    per = [[] for _ in range(len(sched.link_busy_by_link))]
    for r in done:
        assert len(r.link_ids) == len(r.link_history), \
            f"{ctx}: rid {r.rid} link_ids/link_history length mismatch"
        for li, iv in zip(r.link_ids, r.link_history):
            per[li].append(iv)
    for li, ivals in enumerate(per):
        ivals.sort()
        drift = abs(sched.link_busy_by_link[li]
                    - sum(b - a for a, b in ivals))
        assert drift < 1e-9, f"{ctx}: link {li} drifted by {drift}"
        for (_, b), (a, _) in zip(ivals, ivals[1:]):
            assert b <= a + 1e-12, f"{ctx}: link {li} intervals overlap"
    total = abs(sched.link_busy_s - sum(sched.link_busy_by_link))
    assert total < 1e-9, f"{ctx}: per-link busy does not sum to the total"


def _check_wire_decomposition(sched, done, ctx):
    expected = sum(r.prompt_len * KV_BYTES_TOK * len(r.link_history)
                   for r in done)
    got = sched.transfer_bytes + sched.prefix_hit_bytes
    assert abs(got - expected) <= 1e-6 * max(expected, 1.0), \
        f"{ctx}: shipped + hit = {got}, expected {expected}"


# ---------------------------------------------------------------------------
# the >= 200-scenario sweep
# ---------------------------------------------------------------------------

def _topologies():
    pols = available_policies()
    mk = lambda i, bw: LinkSpec(policy=pols[i % len(pols)], bw_scale=bw)
    return [
        ClusterConfig(n_prefill=1, n_decode=1, links=(mk(0, 1.0),)),
        ClusterConfig(n_prefill=2, n_decode=3,
                      links=(mk(0, 1.0), mk(1, 0.5))),
        ClusterConfig(n_prefill=1, n_decode=2,
                      links=(mk(1, 1.0), mk(2, 0.25), mk(3, 2.0))),
        ClusterConfig(n_prefill=3, n_decode=1, links=(mk(4, 0.5),)),
        ClusterConfig(n_prefill=2, n_decode=2,
                      links=(mk(2, 1.0), mk(2, 1.0)),
                      prefix_cache_bytes=float(KV_BYTES_TOK) * 4096),
    ]


def _scenarios():
    """The seeded cross product: topology x router x trace seed x warmth.
    5 topologies x 4+ routers x 5 seeds x 2 session modes >= 200."""
    out = []
    for ti, topo in enumerate(_topologies()):
        for router in available_routers():
            for seed in range(5):
                for session_p in (0.0, 0.5):
                    out.append((ti, router, seed, session_p))
    return out


def test_scenario_count_is_at_least_200():
    assert len(_scenarios()) >= 200


def test_invariants_over_all_scenarios():
    topos = _topologies()
    for ti, router, seed, session_p in _scenarios():
        topo = topos[ti]
        cluster = ClusterConfig(
            n_prefill=topo.n_prefill, n_decode=topo.n_decode,
            links=topo.links, router=router,
            prefix_cache_bytes=topo.prefix_cache_bytes)
        ctx = f"topo={ti} router={router} seed={seed} warm={session_p}"
        reqs = _trace(seed, session_p=session_p)
        sched, done = _run(_cfg(cluster=cluster), reqs)
        _check_terminal(done, len(reqs), ctx)
        _check_links(sched, done, ctx)
        _check_wire_decomposition(sched, done, ctx)
        if session_p == 0.0 or topo.prefix_cache_bytes is None:
            assert sched.prefix_hit_bytes == 0.0, \
                f"{ctx}: prefix hits without a warm trace and a cache"


def test_submission_order_determinism():
    """The event engine's output is a function of the trace alone: shuffling
    submit() order changes nothing, field for field."""
    topo = _topologies()[1]
    for seed in range(3):
        reqs = _trace(seed, session_p=0.5)
        cluster = ClusterConfig(n_prefill=topo.n_prefill,
                                n_decode=topo.n_decode, links=topo.links,
                                router="transfer-aware",
                                prefix_cache_bytes=float(KV_BYTES_TOK) * 8192)
        _, a = _run(_cfg(cluster=cluster), reqs)
        shuffled = _trace(seed, session_p=0.5)
        random.Random(seed).shuffle(shuffled)
        _, b = _run(_cfg(cluster=cluster), shuffled)
        fa = sorted(map(_fields, a))
        fb = sorted(map(_fields, b))
        assert fa == fb, f"seed {seed}: submission order changed the run"


# ---------------------------------------------------------------------------
# 1x1x1 degeneration: the cluster model nests the legacy scheduler exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", available_policies())
def test_1x1x1_degenerates_to_legacy_bit_identical(policy):
    reqs = lambda: [Request(rid=i, arrival=i * 1e-4,
                            prompt_len=(1024, 128, 4096, 512)[i % 4],
                            max_new_tokens=4,
                            deadline=i * 1e-4 + (0.5 if i % 3 else 0.05))
                    for i in range(12)]
    _, legacy = _run(_cfg(policy=policy), reqs())
    cluster = ClusterConfig(n_prefill=1, n_decode=1,
                            links=(LinkSpec(policy=policy),),
                            router="transfer-aware")
    _, fleet = _run(_cfg(cluster=cluster), reqs())
    assert sorted(map(_fields, legacy)) == sorted(map(_fields, fleet))
    assert summarize(legacy) == summarize(fleet)


@pytest.mark.parametrize("n_workers", [2, 3])
def test_legacy_router_reproduces_multiworker_legacy(n_workers):
    """router='legacy' + N decode workers on one link is EXACTLY the PR-6
    multi-worker scheduler: placement at admission, not at routing."""
    reqs = lambda: [Request(rid=i, arrival=i * 1e-4,
                            prompt_len=(2048, 256)[i % 2], max_new_tokens=4)
                    for i in range(10)]
    _, legacy = _run(_cfg(policy="sjf", n_decode_workers=n_workers,
                          max_decode_slots=2 * n_workers), reqs())
    cluster = ClusterConfig(n_prefill=1, n_decode=n_workers,
                            links=(LinkSpec(policy="sjf"),), router="legacy")
    _, fleet = _run(_cfg(cluster=cluster,
                         max_decode_slots=2 * n_workers), reqs())
    assert sorted(map(_fields, legacy)) == sorted(map(_fields, fleet))


def test_legacy_config_resolves_to_legacy_cluster():
    cfg = _cfg(policy="edf", n_decode_workers=3)
    c = resolve_cluster(cfg)
    assert (c.n_prefill, c.n_decode, c.n_links) == (1, 3, 1)
    assert c.router == "legacy" and c.links[0].policy == "edf"
    explicit = ClusterConfig(n_prefill=2, n_decode=2)
    assert resolve_cluster(_cfg(cluster=explicit)) is explicit


# ---------------------------------------------------------------------------
# routing behaviour
# ---------------------------------------------------------------------------

def test_transfer_aware_router_prefers_fast_idle_link():
    """With one full-rate and one crippled link, the transfer-aware router
    must put a lone request on the fast link."""
    cluster = ClusterConfig(n_prefill=1, n_decode=1,
                            links=(LinkSpec(bw_scale=0.01), LinkSpec()),
                            router="transfer-aware")
    sched, done = _run(_cfg(cluster=cluster),
                       [Request(rid=0, arrival=0.0, prompt_len=4096,
                                max_new_tokens=1)])
    assert done[0].link_ids == [1]
    assert sched.link_busy_by_link[0] == 0.0


def test_transfer_aware_router_balances_decode_load():
    """Simultaneous identical requests spread over decode workers instead of
    piling onto worker 0 (queue-depth term of the placement cost)."""
    cluster = ClusterConfig(n_prefill=1, n_decode=3, links=(LinkSpec(),),
                            router="transfer-aware")
    reqs = [Request(rid=i, arrival=0.0, prompt_len=1024, max_new_tokens=8)
            for i in range(6)]
    _, done = _run(_cfg(cluster=cluster, decode_time_per_step=5e-2), reqs)
    assert len({r.worker for r in done}) > 1


def test_round_robin_router_cycles():
    cluster = ClusterConfig(n_prefill=1, n_decode=2,
                            links=(LinkSpec(), LinkSpec()),
                            router="round-robin")
    reqs = [Request(rid=i, arrival=i * 1e-5, prompt_len=256,
                    max_new_tokens=1) for i in range(8)]
    sched, done = _run(_cfg(cluster=cluster), reqs)
    assert {r.worker for r in done} == {0, 1}
    assert all(b > 0 for b in sched.link_busy_by_link)


def test_router_registry():
    assert set(available_routers()) >= {"legacy", "transfer-aware",
                                        "round-robin", "least-loaded"}
    assert isinstance(get_router("transfer-aware"), Router)
    with pytest.raises(KeyError):
        get_router("no-such-router")


# ---------------------------------------------------------------------------
# sim-side prefix directory
# ---------------------------------------------------------------------------

def _warm_cluster(cache_bytes):
    return ClusterConfig(n_prefill=1, n_decode=2, links=(LinkSpec(),),
                         router="transfer-aware",
                         prefix_cache_bytes=cache_bytes)


def test_warm_trace_hits_prefix_cache_cold_does_not():
    warm = _trace(3, n=24, session_p=0.8)
    cold = _trace(3, n=24, session_p=0.0)
    s_warm, _ = _run(_cfg(cluster=_warm_cluster(1 << 40)), warm)
    s_cold, _ = _run(_cfg(cluster=_warm_cluster(1 << 40)), cold)
    assert s_warm.prefix_hit_bytes > 0
    assert s_cold.prefix_hit_bytes == 0.0
    assert s_warm.transfer_bytes + s_warm.prefix_hit_bytes == pytest.approx(
        sum(r.prompt_len * KV_BYTES_TOK for r in warm))


def test_prefix_hits_reduce_shipped_bytes_on_same_trace():
    warm = lambda: _trace(5, n=24, session_p=0.8)
    s_on, _ = _run(_cfg(cluster=_warm_cluster(1 << 40)), warm())
    s_off, _ = _run(_cfg(cluster=_warm_cluster(None)), warm())
    assert s_on.transfer_bytes < s_off.transfer_bytes
    assert s_on.transfer_bytes + s_on.prefix_hit_bytes == pytest.approx(
        s_off.transfer_bytes)


def test_prefix_directory_lru_eviction_under_pressure():
    d = PrefixDirectory(n_workers=1, capacity_bytes=100.0)
    d.insert(0, session=1, tokens=30, bytes_per_token=1.0)
    d.insert(0, session=2, tokens=30, bytes_per_token=1.0)
    d.insert(0, session=3, tokens=30, bytes_per_token=1.0)
    assert d.resident_bytes(0) == 90.0
    d.hit_tokens(0, 1)                      # pure lookup: no LRU touch
    d.insert(0, session=1, tokens=35, bytes_per_token=1.0)  # refresh 1
    d.insert(0, session=4, tokens=30, bytes_per_token=1.0)  # evicts 2
    assert d.hit_tokens(0, 2) == 0
    assert d.hit_tokens(0, 1) == 35
    assert d.evictions >= 1
    # a single entry larger than the whole budget never sticks
    d.insert(0, session=9, tokens=500, bytes_per_token=1.0)
    assert d.hit_tokens(0, 9) == 0


def test_prefix_directory_is_per_worker():
    d = PrefixDirectory(n_workers=2)
    d.insert(0, session=7, tokens=100, bytes_per_token=2.0)
    assert d.hit_tokens(0, 7) == 100
    assert d.hit_tokens(1, 7) == 0
    d.drop_worker(0)
    assert d.hit_tokens(0, 7) == 0


def test_sim_prefix_eviction_under_hbm_pressure():
    """A directory sized to ~2 sessions on a many-session trace must evict;
    the run still terminates and conserves, hits just get rarer."""
    warm = lambda: _trace(7, n=32, session_p=0.8)
    tiny = float(KV_BYTES_TOK) * 600        # a couple of small sessions
    s_tiny, done = _run(_cfg(cluster=_warm_cluster(tiny)), warm())
    s_big, _ = _run(_cfg(cluster=_warm_cluster(1 << 40)), warm())
    _check_terminal(done, 32, "tiny-cache")
    assert s_tiny.prefix_dir.evictions > 0
    assert s_tiny.prefix_hit_bytes <= s_big.prefix_hit_bytes


# ---------------------------------------------------------------------------
# trace generator properties
# ---------------------------------------------------------------------------

def test_trace_is_seed_deterministic_and_sorted():
    a = _trace(9, n=40, session_p=0.5)
    b = _trace(9, n=40, session_p=0.5)
    assert [(r.arrival, r.prompt_len, r.session, r.prefix_len, r.tenant,
             r.deadline, r.max_new_tokens) for r in a] == \
           [(r.arrival, r.prompt_len, r.session, r.prefix_len, r.tenant,
             r.deadline, r.max_new_tokens) for r in b]
    assert all(x.arrival <= y.arrival for x, y in zip(a, a[1:]))
    assert [r.rid for r in a] == list(range(40))


def test_trace_sessions_share_prefixes():
    reqs = _trace(11, n=60, session_p=0.7)
    cont = [r for r in reqs if r.prefix_len > 0]
    assert cont, "no session continuations in a warm trace"
    for r in cont:
        assert r.session >= 0 and r.prompt_len > r.prefix_len


def test_trace_draws_all_tenants():
    reqs = generate_trace(TraceConfig(seed=2, n_requests=64))
    names = {r.tenant for r in reqs}
    assert names == {t.name for t in DEFAULT_TENANTS}
    for r in reqs:
        t = next(t for t in DEFAULT_TENANTS if t.name == r.tenant)
        assert r.deadline == pytest.approx(r.arrival + t.slo_s)
        assert t.new_tokens[0] <= r.max_new_tokens <= t.new_tokens[1]


def test_cluster_config_validation():
    with pytest.raises(ValueError):
        ClusterConfig(n_prefill=0, n_decode=1)
    with pytest.raises(ValueError):
        ClusterConfig(n_prefill=1, n_decode=1, links=())
    with pytest.raises(ValueError):
        LinkSpec(bw_scale=0.0)
    with pytest.raises(KeyError):
        # unknown router keys surface at scheduler construction
        DisaggregatedScheduler(_cfg(cluster=ClusterConfig(router="nope")))
