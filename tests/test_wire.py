"""Wire codec: byte-exact serialization, size model, cross-codec agreement."""

import numpy as np
import pytest

from repro.core import codebook as cbm
from repro.core import wire


def _realistic_bits(n, seed=0):
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(n) * np.exp(rng.standard_normal(n))).astype(np.float32)
    return (x.view(np.uint32) >> 16).astype(np.uint16)


@pytest.mark.parametrize("fmt,k", [("bf16", 16), ("bf16", 8), ("fp8_e5m2", 16),
                                   ("fp8_e5m2", 8), ("fp8_e4m3", 8)])
def test_roundtrip_and_size_model(fmt, k):
    rng = np.random.default_rng(5)
    if fmt == "bf16":
        bits = _realistic_bits(50_001, seed=5)
    else:
        bits = rng.integers(0, 256, 50_001).astype(np.uint8)
    cb = cbm.calibrate([bits], k=k, fmt=fmt)
    payload, stats = wire.encode(bits, cb)
    assert np.array_equal(wire.decode(payload), bits)
    assert wire.payload_bytes_model(stats.n_elements, stats.n_escapes, fmt, k) == len(payload)


def test_bf16_ratio_near_four_thirds():
    bits = _realistic_bits(1 << 20, seed=6)
    cb = cbm.calibrate([bits], k=16)
    _, stats = wire.encode(bits, cb)
    assert 1.25 < stats.ratio < 4 / 3 + 1e-6


def test_wire_matches_ingraph_byte_accounting():
    """Wire payload minus fixed header (incl. the integrity-frame table) ==
    in-graph analytic bytes."""
    import jax
    import jax.numpy as jnp
    from repro.core import codec

    bits = _realistic_bits(64 * 1024, seed=7)
    cb = cbm.calibrate([bits], k=16)
    payload, stats = wire.encode(bits, cb)
    x = jax.lax.bitcast_convert_type(jnp.asarray(bits), jnp.bfloat16)
    ct = codec.encode(x, cb, cap=1024)
    ingraph = float(codec.compressed_bytes(ct))
    n_frames = wire._HEADER.unpack_from(payload, 0)[6]
    header = (wire._HEADER.size + cb.k + 4 * n_frames
              + 4 * (64 * 1024 // wire.DEFAULT_CHUNK))
    assert ingraph == pytest.approx(len(payload) - header)


@pytest.mark.parametrize("seed", range(20))
def test_wire_roundtrip_arbitrary_bytes(seed):
    """Seeded stand-in for the former hypothesis property test: ANY byte
    buffer (uniform random sizes and contents, worst-case escape rates under
    a deliberately tiny codebook) must roundtrip byte-exactly."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 2048))
    bits = rng.integers(0, 1 << 16, n).astype(np.uint16)
    cb = cbm.Codebook(fmt="bf16", exponents=tuple(range(16)))
    payload, _ = wire.encode(bits, cb)
    assert np.array_equal(wire.decode(payload), bits)


def test_empty_tensor():
    cb = cbm.Codebook(fmt="bf16", exponents=tuple(range(16)))
    payload, stats = wire.encode(np.zeros(0, np.uint16), cb)
    assert np.array_equal(wire.decode(payload), np.zeros(0, np.uint16))
    assert stats.n_elements == 0
