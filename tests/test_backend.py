"""Codec-backend parity + chunked pipelined transfer tests.

The backend registry (repro.core.backend) promises that every backend is a
bit-exact implementation of the same logical codec; these tests pin that
down across xla / pallas (interpret) / wire on bf16 and fp8 inputs including
NaN / Inf / subnormal patterns, and check that the chunked pipelined
transfer engine produces caches bit-identical to the unchunked path with
correct per-chunk wire accounting.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import backend as B
from repro.core import codebook as cbm
from repro.core import codec as C
from repro.serving import transfer as T
from repro.serving.scheduler import (DisaggregatedScheduler, Request,
                                     SchedulerConfig, summarize)
from repro.core.pipeline import CodecProfile

BACKENDS = ("xla", "pallas", "wire")
BF16_CB = cbm.Codebook(fmt="bf16", exponents=tuple(range(118, 134)))
FP8_CB = cbm.Codebook(fmt="fp8_e5m2", exponents=tuple(range(8, 24)))

# bf16 specials: quiet/payload NaN, ±Inf, ±0, subnormals, max/min finite
BF16_SPECIALS = np.array(
    [0x7FC0, 0x7FC1, 0xFFC0, 0x7F80, 0xFF80, 0x0000, 0x8000,
     0x0001, 0x8001, 0x7F7F, 0xFF7F, 0x0080, 0xFFFF, 0x7FFF],
    dtype=np.uint16)
# fp8 e5m2 specials: NaNs (0x7D-0x7F), ±Inf (0x7C/0xFC), ±0, subnormals
FP8_SPECIALS = np.array(
    [0x7D, 0x7E, 0x7F, 0xFD, 0x7C, 0xFC, 0x00, 0x80, 0x01, 0x81, 0x03,
     0x7B, 0xFB, 0xFF],
    dtype=np.uint8)


def _bits_of(x, fmt):
    return C.to_bits(x, fmt)


def _bf16_input(seed=0, n=8192, specials=True):
    rng = np.random.default_rng(seed)
    bits = np.array(jax.lax.bitcast_convert_type(
        jnp.asarray(rng.standard_normal(n).astype(np.float32)
                    * np.exp(rng.standard_normal(n))).astype(jnp.bfloat16),
        jnp.uint16))
    if specials:
        pos = rng.choice(n, size=min(n // 4, 4 * BF16_SPECIALS.size),
                         replace=False)
        bits[pos] = np.tile(BF16_SPECIALS, -(-pos.size // BF16_SPECIALS.size)
                            )[: pos.size]
    return jax.lax.bitcast_convert_type(jnp.asarray(bits), jnp.bfloat16)


def _fp8_bits(seed=0, n=4096):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 256, n).astype(np.uint8)
    pos = rng.choice(n, size=4 * FP8_SPECIALS.size, replace=False)
    bits[pos] = np.tile(FP8_SPECIALS, 4)
    return jnp.asarray(bits)


class TestRegistry:
    def test_builtin_backends_registered(self):
        for name in BACKENDS:
            assert name in B.available_backends()
            assert B.get_backend(name).name == name

    def test_unknown_backend_raises(self):
        with pytest.raises(KeyError):
            B.get_backend("does-not-exist")

    def test_register_custom_backend(self):
        class Fake(B.XlaBackend):
            name = "fake"
        B.register_backend("fake", Fake)
        try:
            assert B.get_backend("fake").name == "fake"
        finally:
            B._REGISTRY.pop("fake", None)
            B._INSTANCES.pop("fake", None)

    def test_wire_backend_rejected_inside_shard_map_path(self):
        assert not B.get_backend("wire").jittable
        assert B.get_backend("xla").jittable
        assert B.get_backend("pallas").jittable


class TestBackendParity:
    """All backends must produce bit-identical roundtrips on the same data."""

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_bf16_roundtrip_with_specials(self, backend):
        x = _bf16_input(seed=1)
        be = B.get_backend(backend)
        y = be.decode(be.encode(x, BF16_CB, cap=1024))
        np.testing.assert_array_equal(
            np.asarray(_bits_of(x, "bf16")),
            np.asarray(_bits_of(jnp.asarray(y).reshape(x.shape), "bf16")))

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_fp8_roundtrip_with_specials(self, backend):
        bits = _fp8_bits(seed=2)
        be = B.get_backend(backend)
        ct = be.encode(bits, FP8_CB, cap=1024)
        y = be.decode(ct)
        np.testing.assert_array_equal(
            np.asarray(bits),
            np.asarray(_bits_of(jnp.asarray(y).reshape(bits.shape),
                                "fp8_e5m2")))

    def test_ingraph_backends_produce_identical_streams(self):
        """xla and pallas are the SAME layout, not merely both lossless."""
        x = _bf16_input(seed=3, n=16384)
        ct_x = B.get_backend("xla").encode(x, BF16_CB)
        ct_p = B.get_backend("pallas").encode(x, BF16_CB)
        for lx, lp in zip(jax.tree.leaves(ct_x), jax.tree.leaves(ct_p)):
            np.testing.assert_array_equal(np.asarray(lx), np.asarray(lp))

    @pytest.mark.parametrize("backend", ("xla", "pallas"))
    def test_global_layout_parity(self, backend):
        x = _bf16_input(seed=4, n=8192)
        be = B.get_backend(backend)
        ct = be.encode(x, BF16_CB, layout="global", cap=8192)
        assert ct.layout == "global"
        assert bool(be.ok(ct))
        y = be.decode(ct)
        np.testing.assert_array_equal(
            np.asarray(_bits_of(x, "bf16")),
            np.asarray(_bits_of(jnp.asarray(y).reshape(x.shape), "bf16")))

    def test_wire_backend_always_ok(self):
        # all-escape input: in-graph ok goes False, wire has no capacity limit
        bits = jnp.full((4096,), np.uint16(7 << 7), dtype=jnp.uint16)
        x = jax.lax.bitcast_convert_type(bits, jnp.bfloat16)
        cb = cbm.Codebook(fmt="bf16", exponents=tuple(range(118, 134)))
        assert not bool(B.get_backend("xla").ok(
            B.get_backend("xla").encode(x, cb, cap=8)))
        ct_w = B.get_backend("wire").encode(x, cb, cap=8)
        assert B.get_backend("wire").ok(ct_w) is True
        np.testing.assert_array_equal(
            np.asarray(bits),
            np.asarray(_bits_of(B.get_backend("wire").decode(ct_w), "bf16")))


def _toy_cache(seed=0):
    rng = np.random.default_rng(seed)
    def kv(shape):
        x = rng.normal(size=shape) * rng.choice([0.25, 1.0, 4.0], size=shape)
        return jnp.asarray(x, dtype=jnp.bfloat16)
    return {"k": kv((4, 2, 128, 4, 32)), "v": kv((4, 2, 128, 4, 32)),
            "ssm": jnp.asarray(rng.normal(size=(4, 8, 16)), jnp.float32)}


def _cache_cb(cache):
    leaves = [np.asarray(jax.lax.bitcast_convert_type(x, jnp.uint16)).ravel()
              for x in jax.tree.leaves(cache) if x.dtype == jnp.bfloat16]
    return cbm.calibrate(leaves, k=16)


def _assert_bit_identical(a_tree, b_tree):
    for a, b in zip(jax.tree.leaves(a_tree), jax.tree.leaves(b_tree)):
        w = {2: jnp.uint16, 4: jnp.uint32}[a.dtype.itemsize]
        np.testing.assert_array_equal(
            np.asarray(jax.lax.bitcast_convert_type(a, w)),
            np.asarray(jax.lax.bitcast_convert_type(b, w)))


class TestChunkedPipelinedTransfer:
    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("n_chunks", (1, 3, 8))
    def test_chunked_matches_unchunked_bit_exact(self, backend, n_chunks):
        cache = _toy_cache(seed=5)
        cb = _cache_cb(cache)
        tc = T.TransferConfig(codebook=cb, backend=backend, n_chunks=n_chunks)
        out, stats = T.transfer_cache_chunked(cache, tc)
        _assert_bit_identical(cache, out)
        assert len(stats.chunk_wire_bytes) == n_chunks
        assert stats.all_ok
        # wire accounting: compressed chunks beat raw, fp32 leaf ships raw
        bf16_raw = sum(x.size * 2 for x in jax.tree.leaves(cache)
                       if x.dtype == jnp.bfloat16)
        assert sum(stats.chunk_wire_bytes) < bf16_raw
        assert stats.raw_passthrough_bytes == 4 * 4 * 8 * 16

    def test_per_chunk_raw_fallback_stays_lossless(self):
        """Adversarial bits + tiny capacity: overflowing chunks ship raw and
        are charged raw bytes; the cache still reassembles bit-exactly."""
        rng = np.random.default_rng(6)
        # half the stream escapes everything (uniform bits), half compresses
        bad = rng.integers(0, 1 << 16, 8 * 1024).astype(np.uint16)
        good = np.full(8 * 1024, np.uint16(120 << 7), dtype=np.uint16)
        cache = {"a": jax.lax.bitcast_convert_type(jnp.asarray(bad),
                                                   jnp.bfloat16),
                 "b": jax.lax.bitcast_convert_type(jnp.asarray(good),
                                                   jnp.bfloat16)}
        cb = cbm.Codebook(fmt="bf16", exponents=tuple(range(118, 134)))
        tc = T.TransferConfig(codebook=cb, cap=4, n_chunks=4)
        out, stats = T.transfer_cache_chunked(cache, tc)
        _assert_bit_identical(cache, out)
        assert not stats.all_ok and any(stats.chunk_ok)
        for okc, wb in zip(stats.chunk_ok, stats.chunk_wire_bytes):
            if not okc:  # raw fallback chunk: charged exactly raw bf16 bytes
                assert wb == pytest.approx(2 * 4 * 1024)

    def test_engine_chunked_parity_and_per_chunk_stats(self):
        """Acceptance: DisaggregatedEngine.transfer with n_chunks=8 returns a
        bit-identical cache to the unchunked path, and EngineStats reports
        per-chunk wire bytes."""
        from repro.configs.base import ShapeConfig, get_config
        from repro.models import model as M
        from repro.serving.engine import DisaggregatedEngine

        cfg = get_config("smollm-135m").reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        shape = ShapeConfig("smoke", seq_len=24, global_batch=2, kind="train")
        prompt = {k: v for k, v in M.make_inputs(cfg, shape, seq=16).items()
                  if k != "labels"}
        _, state = M.prefill(params, prompt, cfg, max_seq=24)
        cb = _cache_cb(state.cache)

        eng1 = DisaggregatedEngine(cfg, params, cb, compress=True)
        eng8 = DisaggregatedEngine(cfg, params, cb, compress=True, n_chunks=8)
        out1 = eng1.transfer(state)
        out8 = eng8.transfer(state)
        _assert_bit_identical(out1.cache, out8.cache)
        _assert_bit_identical(state.cache, out8.cache)
        assert eng1.stats.chunk_wire_bytes == []
        assert len(eng8.stats.chunk_wire_bytes) >= 2
        assert sum(eng8.stats.chunk_wire_bytes) <= eng8.stats.wire_bytes
        assert eng8.stats.wire_bytes < eng8.stats.raw_cache_bytes
        # end-to-end generation through the pipelined transfer stays exact
        toks8 = eng8.generate(prompt, num_steps=4, max_seq=24)
        toks1 = eng1.generate(prompt, num_steps=4, max_seq=24)
        np.testing.assert_array_equal(np.asarray(toks8), np.asarray(toks1))


class TestWireBytesAccounting:
    """Unit tests for the per-tensor raw-fallback accounting (the former
    ``* 0 + ok`` hack, now a plain ``jnp.where``)."""

    def test_ok_tensor_charged_compressed_bytes(self):
        x = _bf16_input(seed=7, n=4096, specials=False)
        cb = cbm.calibrate(
            [np.asarray(jax.lax.bitcast_convert_type(x, jnp.uint16))], k=16)
        comp, raw = T.compress_cache({"x": x}, T.TransferConfig(codebook=cb))
        total = float(T.compressed_wire_bytes(comp, raw))
        assert total == pytest.approx(
            float(C.compressed_bytes(comp["x"])))

    def test_overflowed_tensor_charged_raw_bytes(self):
        bits = jnp.full((4096,), np.uint16(7 << 7), dtype=jnp.uint16)
        x = jax.lax.bitcast_convert_type(bits, jnp.bfloat16)
        cb = cbm.Codebook(fmt="bf16", exponents=tuple(range(118, 134)))
        tc = T.TransferConfig(codebook=cb, cap=4)
        comp, raw = T.compress_cache({"x": x}, tc)
        assert not bool(comp["x"].ok)
        assert float(T.compressed_wire_bytes(comp, raw)) == pytest.approx(
            2.0 * 4096)  # raw bf16 bytes, not the (useless) compressed size

    def test_fp32_hi_overflow_falls_back_to_raw_leaf(self):
        """An overflowed fp32 hi-half must ship the WHOLE fp32 leaf raw
        (drop the lo-half entry, restore the original leaf) — regression
        test for the KeyError on '#hi'-suffixed comp keys."""
        import dataclasses as dc
        from repro.configs.base import get_config
        from repro.models.kvcache import DecodeState
        from repro.serving.engine import DisaggregatedEngine

        rng = np.random.default_rng(9)
        cache = {"s": jnp.asarray(rng.normal(size=(4096,)), jnp.float32)}
        bad_cb = cbm.Codebook(fmt="bf16", exponents=tuple(range(16)))
        eng = DisaggregatedEngine(get_config("smollm-135m").reduced(), None,
                                  bad_cb, compress=True, cap=2)
        eng.tc = dc.replace(eng.tc, compress_fp32=True)
        state = DecodeState(cache=cache, cache_len=jnp.zeros((1,), jnp.int32))
        out = eng.transfer(state)
        _assert_bit_identical(cache, out.cache)
        assert not eng.stats.codec_ok
        # charged raw fp32 bytes (hi raw u16 + lo raw u16 == 4 bytes/elem)
        assert eng.stats.wire_bytes == pytest.approx(4.0 * 4096)

    def test_backend_mismatch_is_corrected_per_object(self):
        """decompress_cache with the wrong backend= argument still decodes:
        dispatch follows the compressed object's type, not the argument."""
        x = _bf16_input(seed=8, n=2048, specials=False)
        cb = cbm.calibrate(
            [np.asarray(jax.lax.bitcast_convert_type(x, jnp.uint16))], k=16)
        comp, raw = T.compress_cache(
            {"x": x}, T.TransferConfig(codebook=cb, backend="wire"))
        out = T.decompress_cache(comp, raw, {"x": x})  # default 'xla' arg
        _assert_bit_identical({"x": x}, out)
        assert float(T.compressed_wire_bytes(comp, raw)) == pytest.approx(
            float(T.compressed_wire_bytes(comp, raw, backend="wire")))

    def test_mixed_tree_sums_per_tensor(self):
        good_bits = jnp.full((2048,), np.uint16(120 << 7), dtype=jnp.uint16)
        bad_bits = jnp.full((2048,), np.uint16(7 << 7), dtype=jnp.uint16)
        cache = {"good": jax.lax.bitcast_convert_type(good_bits, jnp.bfloat16),
                 "bad": jax.lax.bitcast_convert_type(bad_bits, jnp.bfloat16),
                 "raw32": jnp.zeros((100,), jnp.float32)}
        cb = cbm.Codebook(fmt="bf16", exponents=tuple(range(118, 134)))
        comp, raw = T.compress_cache(cache, T.TransferConfig(codebook=cb,
                                                             cap=4))
        total = float(T.compressed_wire_bytes(comp, raw))
        expect = (float(C.compressed_bytes(comp["good"]))  # ok -> compressed
                  + 2.0 * 2048                             # overflow -> raw
                  + 400.0)                                 # fp32 passthrough
        assert total == pytest.approx(expect)


class TestPipelinedSchedulerModel:
    def _run(self, compress, n_chunks=1):
        sched = DisaggregatedScheduler(SchedulerConfig(
            kv_bytes_per_token=2 * 32 * 8 * 128 * 2,
            profile=CodecProfile(g_enc=613.3e9, g_dec=2181.8e9, ratio=1.324,
                                 link_bw=87.5e9),
            compress=compress, n_chunks=n_chunks))
        for i in range(16):
            sched.submit(Request(rid=i, arrival=i * 1e-3, prompt_len=16384,
                                 max_new_tokens=16))
        return summarize(sched.run())

    def test_pipelined_beats_additive_when_codec_visible(self):
        # at 87.5 GB/s the additive codec time is non-negligible; the chunked
        # pipeline hides most of it behind the link
        additive = self._run(True, n_chunks=1)
        pipelined = self._run(True, n_chunks=8)
        assert pipelined["mean_ttft_s"] < additive["mean_ttft_s"]

    def test_pipelined_still_beats_native(self):
        native = self._run(False)
        pipelined = self._run(True, n_chunks=8)
        assert pipelined["mean_ttft_s"] < native["mean_ttft_s"]
