"""Compressed-resident KV tests (ISSUE 8).

What the paged pool + fused attention promise, pinned here:

1. **Losslessness** — ``KVPool.admit_from_wire`` followed by ``rehydrate``
   is bit-identical to the original cache, for dense-GQA and MLA streams,
   escape-bearing tensors, ragged (mixed-length) batches, and across
   tail-page growth + recompression (``flush_full_tails``).  The fused
   kernel's in-register page decode is pinned bitwise against the same
   pages decoded outside the kernel (integer ops, arch-independent), so the
   attention consumes EXACTLY the values a rehydrate would produce; the
   attention partials themselves are compared at f32 round-off tolerance
   (dot-product summation order inside ``pallas_call`` is not guaranteed to
   match an einsum's).
2. **Zero-rehydration admission** — admission never routes the full stream
   through the backend decoder: only the sub-page tail region (bounded by
   one page per (layer, row)) may be decoded.
3. **Pool invariants** — free-list accounting across admit/grow/free,
   escape-overflow and pool-exhaustion demotion (``ResidencyError``), and
   the one-``pallas_call``-per-layer structure of the resident decode step.
4. **Engine integration** — ``resident='compressed'`` serves end-to-end,
   demotes gracefully (bit-identical to raw-resident serving when it does),
   and the scheduler's HBM-derived slot budget reflects the footprint win.
5. **Ragged decode** (satellite): mixed-length prefill scores each row at
   its own last real token and decodes correctly from per-row cache_len.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import codebook as cbm
from repro.core.backend import resolve_backend
from repro.kernels import splitzip_attention as SA
from repro.models import kvpool as KVP
from repro.models import model as M
from repro.serving.engine import DisaggregatedEngine
from repro.serving.plan import TransferConfig, TransferPlan
from repro.serving.scheduler import SchedulerConfig
from repro.serving.session import encode_leaves

CHUNK = 1024


def _calibrate(cache):
    bits = np.concatenate(
        [np.asarray(jax.lax.bitcast_convert_type(v, jnp.uint16)).ravel()
         for v in cache.values() if v.dtype == jnp.bfloat16])
    return cbm.calibrate(bits, k=16, fmt="bf16")


def _dense_cache(L=2, B=2, S=64, hkv=2, hd=32, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return {
        "k": jnp.asarray(rng.standard_normal((L, B, S, hkv, hd)) * scale,
                         jnp.bfloat16),
        "v": jnp.asarray(rng.standard_normal((L, B, S, hkv, hd)) * scale,
                         jnp.bfloat16),
    }


def _encode(cache, cb, backend="xla"):
    tc = TransferConfig(codebook=cb, chunk=CHUNK, backend=backend)
    plan = TransferPlan.build(cache, tc)
    return encode_leaves(plan, cache)


def _pool_for(cache, cb, page_bytes=2048):
    backend = resolve_backend("xla", require_jittable=True)
    return KVP.KVPool.for_cache(cache, cb, backend, chunk=CHUNK,
                                page_bytes=page_bytes)


def _append_rows(pool, rs, rng, grown=None, values=None):
    """Append one token to every (layer, row) tail and advance cache_len.

    ``values`` maps leaf key -> (L, B, m) override of the random draw (used
    to inject escape-heavy data into one leaf); ``grown`` (dict of f32
    copies of the original cache) records the appended values for bit-exact
    comparison after rehydrate."""
    g = pool.geom
    tp = g.tokens_per_page
    lens = np.asarray(rs.cache_len)
    for lg in g.leaves:
        key, m = lg.key, lg.m
        leaf = rs.leaves[key]
        new = (values or {}).get(key)
        if new is None:
            new = jnp.asarray(
                rng.standard_normal((g.n_layers, g.batch, m)), jnp.bfloat16)
        t = rs.cache_len % tp
        tail = leaf.tail
        for layer in range(g.n_layers):
            tail = tail.at[layer].set(KVP._append_tail(
                tail[layer], new[layer][:, None, :], t))
        rs = dataclasses.replace(rs, leaves={
            **rs.leaves, key: dataclasses.replace(leaf, tail=tail)})
        if grown is not None:
            for row in range(g.batch):
                grown[key][:, row, lens[row]] = np.asarray(
                    new[:, row], np.float32).reshape(
                        g.n_layers, *grown[key].shape[3:])
    return dataclasses.replace(
        rs, cache_len=jnp.asarray(lens + 1, jnp.int32))


def _assert_cache_equal(a, b, lens=None):
    """Bitwise equality, optionally restricted to each row's valid prefix."""
    for key in a:
        xa = np.asarray(jax.lax.bitcast_convert_type(a[key], jnp.uint16))
        xb = np.asarray(jax.lax.bitcast_convert_type(b[key], jnp.uint16))
        if lens is not None:
            for row, n in enumerate(np.asarray(lens)):
                np.testing.assert_array_equal(
                    xa[:, row, :n], xb[:, row, :n], err_msg=key)
        else:
            np.testing.assert_array_equal(xa, xb, err_msg=key)


# ---------------------------------------------------------------------------
# pool: admit / rehydrate / grow / free
# ---------------------------------------------------------------------------

class TestPool:
    def test_admit_rehydrate_bit_exact_ragged(self):
        """Mixed-length admission (full pages, page-boundary, mid-page,
        mid-chunk rows) rehydrates bit-identically; unmapped tail region
        stays zero; free-list accounting matches the page count."""
        cache = _dense_cache(L=2, B=3, S=64)
        cb = _calibrate(cache)
        pool = _pool_for(cache, cb)
        tp = pool.geom.tokens_per_page
        assert 64 % tp == 0 and tp >= 1
        lens = jnp.asarray([64, 2 * tp, tp // 2 + 1], jnp.int32)
        comp, _ = _encode(cache, cb)
        rs = pool.admit_from_wire(comp, lens)

        reh = pool.rehydrate(rs)
        _assert_cache_equal(reh, cache, lens)
        # pages wholly past the row's tail page are unmapped -> zero
        # (within the tail page, positions past cache_len are unspecified:
        # the wire tail decodes at chunk granularity)
        for key in reh:
            x = np.asarray(reh[key], np.float32)
            for row, n in enumerate(np.asarray(lens)):
                nxt = (n // tp + 1) * tp
                if nxt < 64:
                    assert not x[:, row, nxt:].any()

        n_full = np.asarray(lens) // tp
        want = 2 * int(n_full.sum())           # L * sum(full pages)
        for key in ("k", "v"):
            assert pool.allocated_pages(key) == want

    def test_admission_decodes_at_most_the_tail(self):
        """Zero-rehydration: the backend decoder sees only sub-page tails
        (bounded by page_elems per call), never the full stream."""
        cache = _dense_cache(L=2, B=2, S=256)
        cb = _calibrate(cache)
        pool = _pool_for(cache, cb)
        tp = pool.geom.tokens_per_page

        decoded = []
        real = pool.backend

        class Counting:
            def __getattr__(self, name):
                return getattr(real, name)

            def decode(self, ct):
                decoded.append(int(np.prod(ct.shape)))
                return real.decode(ct)

        pool.backend = Counting()
        comp, _ = _encode(cache, cb)
        lens = jnp.asarray([256, tp + tp // 2], jnp.int32)
        rs = pool.admit_from_wire(comp, lens)
        pool.backend = real

        total = sum(int(np.prod(v.shape)) for v in cache.values())
        # bounded: one page-group per (layer, row) per leaf, batched into a
        # single small decode — never the full stream
        g = pool.geom
        bound = g.n_layers * g.batch * max(lg.page_elems for lg in g.leaves)
        assert decoded and all(n <= bound for n in decoded)
        assert sum(decoded) < total // 4
        _assert_cache_equal(pool.rehydrate(rs), cache, lens)

    def test_tail_growth_and_recompress_bit_exact(self):
        """Decode-time growth: tokens appended to the raw tail page, flushed
        into fresh compressed pages at each boundary — including a page
        that is part admission-tail, part appended — stay bit-exact."""
        cache = _dense_cache(L=2, B=2, S=64)
        cb = _calibrate(cache)
        pool = _pool_for(cache, cb)
        tp = pool.geom.tokens_per_page
        start = np.array([tp + tp // 2, tp - 1])   # both mid-page
        comp, _ = _encode(cache, cb)
        rs = pool.admit_from_wire(comp, jnp.asarray(start, jnp.int32))

        rng = np.random.default_rng(7)
        grown = {k: np.asarray(v, np.float32).copy() for k, v in cache.items()}
        lens = start.copy()
        before = {k: pool.allocated_pages(k) for k in ("k", "v")}
        for _ in range(tp + 2):                    # crosses >=1 boundary/row
            for key in ("k", "v"):
                leaf = rs.leaves[key]
                m = pool.geom.leaf(key).m
                new = jnp.asarray(
                    rng.standard_normal((2, 2, m)), jnp.bfloat16)  # (L,B,m)
                t = rs.cache_len % tp
                tail = leaf.tail                 # (L,B,Tp,m): append per layer
                for layer in range(2):
                    tail = tail.at[layer].set(KVP._append_tail(
                        tail[layer], new[layer][:, None, :], t))
                rs = dataclasses.replace(rs, leaves={
                    **rs.leaves, key: dataclasses.replace(leaf, tail=tail)})
                for row in range(2):
                    grown[key][:, row, lens[row]] = np.asarray(
                        new[:, row], np.float32).reshape(2, *grown[key].shape[3:])
            lens += 1
            rs = dataclasses.replace(
                rs, cache_len=jnp.asarray(lens, jnp.int32))
            rs = pool.flush_full_tails(rs)

        reh = pool.rehydrate(rs)
        for key in reh:
            got = np.asarray(reh[key], np.float32)
            for row in range(2):
                np.testing.assert_array_equal(
                    got[:, row, :lens[row]], grown[key][:, row, :lens[row]],
                    err_msg=key)
        # every crossed boundary allocated exactly L pages per leaf
        crossed = sum((lens[r] // tp) - (start[r] // tp) for r in range(2))
        for key in ("k", "v"):
            assert pool.allocated_pages(key) - before[key] == 2 * crossed

    def test_failed_flush_rehydrates_full_tail_page(self):
        """A ResidencyError inside flush_full_tails strikes when a row's
        just-filled logical page is still unmapped and its data lives ONLY
        in the tail.  Demotion (rehydrate) must splice the FULL tail at that
        page index — zeroing it would silently lose tokens_per_page tokens
        of KV (REVIEW: 'bit-exact demotion' violation)."""
        cache = _dense_cache(L=2, B=2, S=64)
        cb = _calibrate(cache)
        pool = _pool_for(cache, cb)
        tp = pool.geom.tokens_per_page
        start = np.array([tp - 1, tp // 2])        # row 0 one short of a page
        comp, _ = _encode(cache, cb)
        rs = pool.admit_from_wire(comp, jnp.asarray(start, jnp.int32))

        rng = np.random.default_rng(9)
        grown = {k: np.asarray(v, np.float32).copy()
                 for k, v in cache.items()}
        rs = _append_rows(pool, rs, rng, grown)    # row 0's tail is now FULL
        lens = start + 1
        assert int(lens[0]) % tp == 0

        def boom(key, n):
            raise KVP.ResidencyError("injected flush failure")

        orig_alloc, pool._alloc = pool._alloc, boom
        with pytest.raises(KVP.ResidencyError):
            pool.flush_full_tails(rs)
        pool._alloc = orig_alloc

        reh = pool.rehydrate(rs)
        for key in reh:
            got = np.asarray(reh[key], np.float32)
            for row in range(2):
                np.testing.assert_array_equal(
                    got[:, row, :lens[row]], grown[key][:, row, :lens[row]],
                    err_msg=f"{key} row {row}")

    def test_failed_flush_leaves_free_list_intact(self):
        """A flush that fails partway must not leak free-list pages: escape
        overflow is checked for ALL leaves before any allocation, and an
        exhaustion on a later leaf returns the earlier leaves' pages.  The
        pool stays fully usable afterwards (REVIEW)."""
        cache = _dense_cache(L=2, B=2, S=64)
        cb = _calibrate(cache)
        pool = _pool_for(cache, cb)
        g = pool.geom
        tp = g.tokens_per_page
        start = np.array([tp - 1, tp - 1])
        comp, _ = _encode(cache, cb)
        rs = pool.admit_from_wire(comp, jnp.asarray(start, jnp.int32))
        rng = np.random.default_rng(10)
        grown = {k: np.asarray(v, np.float32).copy()
                 for k, v in cache.items()}

        # (a) escape overflow on the LATER leaf ("v"): "k" encodes clean
        # first but must not have allocated anything when "v" raises
        hot = jnp.full((g.n_layers, g.batch, g.leaf("v").m), 1e30,
                       jnp.bfloat16)                # every element escapes
        bad = _append_rows(pool, rs, rng, values={"v": hot})
        free_before = {k: pool.free_pages(k) for k in ("k", "v")}
        with pytest.raises(KVP.ResidencyError, match="escape"):
            pool.flush_full_tails(bad)
        assert {k: pool.free_pages(k) for k in ("k", "v")} == free_before

        # (b) pool exhaustion on the later leaf: "k"'s fresh pages must be
        # returned when "v"'s allocation fails
        rs = _append_rows(pool, rs, rng, grown)
        stash, pool._free["v"] = pool._free["v"], []
        with pytest.raises(KVP.ResidencyError, match="exhausted"):
            pool.flush_full_tails(rs)
        assert pool.free_pages("k") == free_before["k"]
        pool._free["v"] = stash

        # (c) the same flush now succeeds and the pool rehydrates bit-exact
        rs = pool.flush_full_tails(rs)
        lens = start + 1
        reh = pool.rehydrate(rs)
        for key in reh:
            got = np.asarray(reh[key], np.float32)
            for row in range(2):
                np.testing.assert_array_equal(
                    got[:, row, :lens[row]], grown[key][:, row, :lens[row]],
                    err_msg=f"{key} row {row}")

    def test_free_rows_returns_pages(self):
        cache = _dense_cache(L=2, B=2, S=64)
        cb = _calibrate(cache)
        pool = _pool_for(cache, cb)
        comp, _ = _encode(cache, cb)
        pool.admit_from_wire(comp, jnp.asarray([64, 64], jnp.int32))
        held = pool.allocated_pages("k")
        assert held > 0
        pool.free_rows([0])
        assert pool.allocated_pages("k") == held // 2
        pool.free_rows([1])
        assert pool.allocated_pages("k") == 0
        # pool is reusable after a full free
        rs = pool.admit_from_wire(comp, jnp.asarray([64, 32], jnp.int32))
        _assert_cache_equal(pool.rehydrate(rs), cache,
                            jnp.asarray([64, 32]))

    def test_escape_overflow_raises_residency_error(self):
        """A page whose true escape count exceeds its slot budget must NOT
        be admitted silently-lossy: ResidencyError -> engine demotes.

        ~2%% of elements escape: comfortably under the wire's per-chunk cap
        (the stream still arrives compressed) but well over the page-level
        budget (page_elems / ESC_SLOT_PER_ELEMS slots)."""
        cache = _dense_cache(L=1, B=1, S=64)
        rng = np.random.default_rng(13)
        k = np.asarray(cache["k"], np.float32).ravel()
        hot = rng.choice(k.size, size=k.size // 50, replace=False)
        k[hot] = 1e30                              # exponent far out of band
        cache["k"] = jnp.asarray(k.reshape(cache["k"].shape), jnp.bfloat16)
        cb = _calibrate({"v": cache["v"]})         # calibrated without spikes
        pool = _pool_for(cache, cb)
        comp, _ = _encode(cache, cb)
        assert hasattr(comp["k"], "esc_count"), "stream must arrive compressed"
        with pytest.raises(KVP.ResidencyError, match="escape"):
            pool.admit_from_wire(comp, jnp.asarray([64], jnp.int32))

    def test_pool_exhaustion_raises(self):
        cache = _dense_cache(L=2, B=2, S=64)
        cb = _calibrate(cache)
        pool = _pool_for(cache, cb)
        comp, _ = _encode(cache, cb)
        pool.admit_from_wire(comp, jnp.asarray([64, 64], jnp.int32))
        # every page is held; a second admission must exhaust the free-list
        with pytest.raises(KVP.ResidencyError):
            pool.admit_from_wire(comp, jnp.asarray([64, 64], jnp.int32))

    def test_capacity_model_vs_measured(self):
        """bytes_per_token_resident (the DESIGN.md capacity model) matches
        the pool's own page accounting."""
        cache = _dense_cache(L=2, B=2, S=64)
        cb = _calibrate(cache)
        pool = _pool_for(cache, cb)
        g = pool.geom
        for lg in g.leaves:
            got = pool.page_bytes(lg) / g.tokens_per_page
            want = KVP.bytes_per_token_resident(lg.m, g.tokens_per_page,
                                                chunk=g.chunk)
            assert abs(got - want) < 1e-9


# ---------------------------------------------------------------------------
# fused attention over pages
# ---------------------------------------------------------------------------

class TestFusedAttention:
    def _admitted(self, S=64, lens=None, seed=0):
        cfg = get_config("smollm-135m").reduced()
        cache = _dense_cache(L=cfg.num_layers, B=2, S=S,
                             hkv=cfg.num_kv_heads, hd=cfg.head_dim, seed=seed)
        cb = _calibrate(cache)
        pool = _pool_for(cache, cb)
        tp = pool.geom.tokens_per_page
        if lens is None:
            lens = jnp.asarray([S, S - tp // 2], jnp.int32)
        comp, _ = _encode(cache, cb)
        rs = pool.admit_from_wire(comp, lens)
        return cfg, cache, pool, rs, lens

    def test_in_kernel_decode_bit_exact(self):
        """The values the kernel attends over are EXACTLY the rehydrated
        cache: pool pages decoded by the same machinery compare bitwise
        against the original bf16 bit patterns, escapes included."""
        cfg, cache, pool, rs, lens = self._admitted()
        g = pool.geom
        tp = g.tokens_per_page
        for key in ("k", "v"):
            lg = g.leaf(key)
            bits = KVP._decode_pool_pages(rs.leaves[key], lg, g)
            src = np.asarray(jax.lax.bitcast_convert_type(
                cache[key], jnp.uint16)).reshape(
                    lg.shape[0], lg.shape[1], -1)
            table = np.asarray(rs.leaves[key].page_table)
            for (layer, row, p), pid in np.ndenumerate(table):
                if pid < 0:
                    continue
                page = np.asarray(bits[pid], np.uint16)
                want = src[layer, row,
                           p * lg.page_elems:(p + 1) * lg.page_elems]
                np.testing.assert_array_equal(page, want)

    def test_kernel_partials_vs_mirror(self):
        """Fused kernel partials vs an identical-op-order jnp mirror over
        the rehydrated pages (f32 round-off only: pallas dot ordering)."""
        cfg, cache, pool, rs, lens = self._admitted()
        g = pool.geom
        tp = g.tokens_per_page
        B, hkv, hd, H = 2, cfg.num_kv_heads, cfg.head_dim, cfg.num_heads
        grp = H // hkv
        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.bfloat16)
        kl, vl = rs.leaves["k"], rs.leaves["v"]
        acc, m, l = SA.paged_gqa_attention(
            q, kl.streams(), vl.streams(), kl.page_table[0],
            vl.page_table[0], rs.cache_len, exponents=g.exponents,
            chunk=g.chunk, tokens_per_page=tp, hkv=hkv, interpret=True)

        reh = pool.rehydrate(rs)
        kf, vf = reh["k"][0], reh["v"][0]
        scale = 1.0 / np.sqrt(hd)
        n_full = np.asarray(lens) // tp
        qr = q.reshape(B, 1, hkv, grp, hd).astype(jnp.float32)
        accs, ms, ls = [], [], []
        for b in range(B):
            mm = jnp.full((1, hkv, grp), SA.NEG_INF, jnp.float32)
            ll = jnp.zeros((1, hkv, grp), jnp.float32)
            aa = jnp.zeros((1, hkv, grp, hd), jnp.float32)
            for p in range(int(n_full[b])):
                kt = kf[b, p * tp:(p + 1) * tp].astype(jnp.float32)
                vt = vf[b, p * tp:(p + 1) * tp].astype(jnp.float32)
                s = jnp.einsum("qhgd,thd->qhgt", qr[b], kt,
                               preferred_element_type=jnp.float32) * scale
                m_new = jnp.maximum(mm, s.max(axis=-1))
                pexp = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(mm - m_new)
                ll = ll * corr + pexp.sum(axis=-1)
                aa = aa * corr[..., None] + jnp.einsum(
                    "qhgt,thd->qhgd", pexp, vt,
                    preferred_element_type=jnp.float32)
                mm = m_new
            accs.append(aa.reshape(1, H, hd))
            ms.append(mm.reshape(1, H))
            ls.append(ll.reshape(1, H))
        np.testing.assert_array_equal(np.asarray(m), np.stack(ms))
        np.testing.assert_allclose(np.asarray(l), np.stack(ls),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(acc), np.stack(accs),
                                   rtol=1e-5, atol=1e-6)

    def test_gqa_kernel_v_geometry_differs(self):
        """dv != head_dim: V pages carry their OWN page_chunks and escape
        cap.  The kernel must consume V's geometry for the V streams —
        reusing K's reads the wrong block shape / past the V escape arrays
        (REVIEW)."""
        L, B, S, hkv, hd, dv = 1, 2, 128, 2, 32, 16
        rng = np.random.default_rng(21)
        cache = {
            "k": jnp.asarray(rng.standard_normal((L, B, S, hkv, hd)),
                             jnp.bfloat16),
            "v": jnp.asarray(rng.standard_normal((L, B, S, hkv, dv)),
                             jnp.bfloat16),
        }
        cb = _calibrate(cache)
        pool = _pool_for(cache, cb, page_bytes=8192)
        g = pool.geom
        assert g.leaf("k").page_chunks != g.leaf("v").page_chunks
        assert g.leaf("k").escape_cap != g.leaf("v").escape_cap
        tp = g.tokens_per_page
        lens = jnp.asarray([S, S - tp], jnp.int32)
        comp, _ = _encode(cache, cb)
        rs = pool.admit_from_wire(comp, lens)
        _assert_cache_equal(pool.rehydrate(rs), cache, lens)

        H, grp = 2 * hkv, 2
        q = jnp.asarray(rng.standard_normal((B, 1, H, hd)), jnp.bfloat16)
        kl, vl = rs.leaves["k"], rs.leaves["v"]
        acc, m, l = SA.paged_gqa_attention(
            q, kl.streams(), vl.streams(), kl.page_table[0],
            vl.page_table[0], rs.cache_len, exponents=g.exponents,
            chunk=g.chunk, tokens_per_page=tp, hkv=hkv, interpret=True)
        assert acc.shape == (B, 1, H, dv)

        reh = pool.rehydrate(rs)
        kf, vf = reh["k"][0], reh["v"][0]
        scale = 1.0 / np.sqrt(hd)
        n_full = np.asarray(lens) // tp
        qr = q.reshape(B, 1, hkv, grp, hd).astype(jnp.float32)
        for b in range(B):
            mm = jnp.full((1, hkv, grp), SA.NEG_INF, jnp.float32)
            ll = jnp.zeros((1, hkv, grp), jnp.float32)
            aa = jnp.zeros((1, hkv, grp, dv), jnp.float32)
            for p in range(int(n_full[b])):
                kt = kf[b, p * tp:(p + 1) * tp].astype(jnp.float32)
                vt = vf[b, p * tp:(p + 1) * tp].astype(jnp.float32)
                s = jnp.einsum("qhgd,thd->qhgt", qr[b], kt,
                               preferred_element_type=jnp.float32) * scale
                m_new = jnp.maximum(mm, s.max(axis=-1))
                pexp = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(mm - m_new)
                ll = ll * corr + pexp.sum(axis=-1)
                aa = aa * corr[..., None] + jnp.einsum(
                    "qhgt,thd->qhgd", pexp, vt,
                    preferred_element_type=jnp.float32)
                mm = m_new
            np.testing.assert_array_equal(
                np.asarray(m[b]), np.asarray(mm.reshape(1, H)))
            np.testing.assert_allclose(
                np.asarray(l[b]), np.asarray(ll.reshape(1, H)),
                rtol=1e-6, atol=1e-6)
            np.testing.assert_allclose(
                np.asarray(acc[b]), np.asarray(aa.reshape(1, H, dv)),
                rtol=1e-5, atol=1e-6)

    def test_one_pallas_call_per_layer(self):
        """Resident decode step structure: exactly one ``pallas_call`` in
        the per-layer scan body, and no codec decode primitives."""
        cfg, cache, pool, rs, lens = self._admitted()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        tok = jnp.zeros((2, 1), jnp.int32)
        jaxpr = jax.make_jaxpr(
            lambda p, t, s: M.resident_decode_step(p, t, s, cfg,
                                                   interpret=True)
        )(params, tok, rs)
        txt = str(jaxpr)
        assert txt.count("pallas_call") == 1  # one per scanned layer

    def test_decode_step_matches_raw_across_page_boundary(self):
        """Same-token resident vs raw decode: logits agree to bf16
        accumulation tolerance across steps that cross a page boundary
        (raw decode_attention accumulates in bf16, the fused path in f32).
        The cache is model-generated (a real prefill) — a synthetic +-4
        sigma cache amplifies the accumulation-order difference through
        softmax far beyond anything a trained/initialized model produces."""
        cfg = get_config("smollm-135m").reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(11)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 24)),
                           jnp.int32)
        lens = jnp.asarray([24, 17], jnp.int32)
        _, st0 = M.prefill(params, {"tokens": toks, "lengths": lens}, cfg,
                           max_seq=64)
        cb = _calibrate(st0.cache)
        pool = _pool_for(st0.cache, cb)
        tp = pool.geom.tokens_per_page
        comp, _ = _encode(st0.cache, cb)
        rs = pool.admit_from_wire(comp, st0.cache_len)
        st_raw, st_res = st0, rs
        for step in range(tp // 2 + 2):            # row 1 crosses a boundary
            tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 1)),
                              jnp.int32)
            lr, st_raw = M.decode_step(params, tok, st_raw, cfg)
            lc, st_res = M.resident_decode_step(params, tok, st_res, cfg,
                                                interpret=True)
            a = np.asarray(lr, np.float32)
            b = np.asarray(lc, np.float32)
            # raw decode_attention accumulates probs*v in bf16; the fused
            # path accumulates in f32 — on a synthetic +-4 sigma bf16 cache
            # the layered amplification reaches a few percent of the scale
            scale = max(1e-3, float(np.abs(a).max()))
            assert float(np.abs(a - b).max()) < 0.12 * scale, f"step {step}"
            st_res = pool.flush_full_tails(st_res)


class TestFusedAttentionMLA:
    def test_mla_decode_matches_raw(self):
        """Absorbed-MLA resident decode vs mla_decode over the rehydrated
        cache, across a page boundary."""
        cfg = get_config("minicpm3-4b").reduced()
        from repro.models.kvcache import DecodeState, init_cache
        params = M.init_params(cfg, jax.random.PRNGKey(1))
        rng = np.random.default_rng(5)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 40)), jnp.int32)
        lens = jnp.asarray([40, 29], jnp.int32)
        _, st0 = M.prefill(params, {"tokens": toks, "lengths": lens}, cfg,
                           max_seq=256)
        cb = _calibrate(st0.cache)
        pool = _pool_for(st0.cache, cb, page_bytes=4096)
        tp = pool.geom.tokens_per_page
        assert 256 % tp == 0
        comp, _ = _encode(st0.cache, cb)
        rs = pool.admit_from_wire(comp, st0.cache_len)
        _assert_cache_equal(pool.rehydrate(rs), st0.cache, lens)

        st_raw, st_res = st0, rs
        steps = tp - 40 + 3 if tp >= 40 else 3     # row 0 crosses a boundary
        for step in range(min(steps, 16)):
            tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 1)),
                              jnp.int32)
            lr, st_raw = M.decode_step(params, tok, st_raw, cfg)
            lc, st_res = M.resident_decode_step(params, tok, st_res, cfg,
                                                interpret=True)
            a = np.asarray(lr, np.float32)
            b = np.asarray(lc, np.float32)
            # raw decode_attention accumulates probs*v in bf16; the fused
            # path accumulates in f32 — on a synthetic +-4 sigma bf16 cache
            # the layered amplification reaches a few percent of the scale
            scale = max(1e-3, float(np.abs(a).max()))
            assert float(np.abs(a - b).max()) < 0.12 * scale, f"step {step}"
            st_res = pool.flush_full_tails(st_res)

    def test_mla_kernel_per_leaf_escape_caps(self):
        """kv_lora_rank != qk_rope_head_dim gives the two MLA leaves
        different page_chunks AND escape caps; the kernel must use each
        leaf's own cap for its escape BlockSpecs/unroll — taking both from
        ckv reads past the krope escape arrays (REVIEW)."""
        L, B, S, r, p_dim, H = 1, 2, 128, 128, 32, 4
        rng = np.random.default_rng(23)
        cache = {
            "ckv": jnp.asarray(rng.standard_normal((L, B, S, r)),
                               jnp.bfloat16),
            "krope": jnp.asarray(rng.standard_normal((L, B, S, p_dim)),
                                 jnp.bfloat16),
        }
        cb = _calibrate(cache)
        pool = _pool_for(cache, cb, page_bytes=16384)
        g = pool.geom
        assert g.leaf("ckv").page_chunks != g.leaf("krope").page_chunks
        assert g.leaf("ckv").escape_cap != g.leaf("krope").escape_cap
        tp = g.tokens_per_page
        lens = jnp.asarray([S, S - tp], jnp.int32)
        comp, _ = _encode(cache, cb)
        rs = pool.admit_from_wire(comp, lens)
        _assert_cache_equal(pool.rehydrate(rs), cache, lens)

        q_lat = jnp.asarray(rng.standard_normal((B, 1, H, r)), jnp.bfloat16)
        q_rope = jnp.asarray(rng.standard_normal((B, 1, H, p_dim)),
                             jnp.bfloat16)
        scale = 1.0 / np.sqrt(r + p_dim)
        cl, rl = rs.leaves["ckv"], rs.leaves["krope"]
        acc, m, l = SA.paged_mla_attention(
            q_lat, q_rope, cl.streams(), rl.streams(), cl.page_table[0],
            rl.page_table[0], rs.cache_len, exponents=g.exponents,
            chunk=g.chunk, tokens_per_page=tp, scale=scale, interpret=True)
        assert acc.shape == (B, 1, H, r)

        reh = pool.rehydrate(rs)
        cf, rf = reh["ckv"][0], reh["krope"][0]
        n_full = np.asarray(lens) // tp
        qlf = q_lat.astype(jnp.float32)
        qrf = q_rope.astype(jnp.float32)
        for b in range(B):
            mm = jnp.full((1, H), SA.NEG_INF, jnp.float32)
            ll = jnp.zeros((1, H), jnp.float32)
            aa = jnp.zeros((1, H, r), jnp.float32)
            for p in range(int(n_full[b])):
                ct = cf[b, p * tp:(p + 1) * tp].astype(jnp.float32)
                rt = rf[b, p * tp:(p + 1) * tp].astype(jnp.float32)
                s = (jnp.einsum("qhr,tr->qht", qlf[b], ct,
                                preferred_element_type=jnp.float32)
                     + jnp.einsum("qhp,tp->qht", qrf[b], rt,
                                  preferred_element_type=jnp.float32)) * scale
                m_new = jnp.maximum(mm, s.max(axis=-1))
                pexp = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(mm - m_new)
                ll = ll * corr + pexp.sum(axis=-1)
                aa = aa * corr[..., None] + jnp.einsum(
                    "qht,tr->qhr", pexp, ct,
                    preferred_element_type=jnp.float32)
                mm = m_new
            np.testing.assert_array_equal(np.asarray(m[b]), np.asarray(mm))
            np.testing.assert_allclose(np.asarray(l[b]), np.asarray(ll),
                                       rtol=1e-6, atol=1e-6)
            np.testing.assert_allclose(np.asarray(acc[b]), np.asarray(aa),
                                       rtol=1e-5, atol=1e-6)

    def test_mla_one_pallas_call_per_layer(self):
        cfg = get_config("minicpm3-4b").reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(1))
        rng = np.random.default_rng(5)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 40)), jnp.int32)
        _, st0 = M.prefill(params, {"tokens": toks}, cfg, max_seq=256)
        cb = _calibrate(st0.cache)
        pool = _pool_for(st0.cache, cb, page_bytes=4096)
        comp, _ = _encode(st0.cache, cb)
        rs = pool.admit_from_wire(comp, st0.cache_len)
        jaxpr = jax.make_jaxpr(
            lambda p, t, s: M.resident_decode_step(p, t, s, cfg,
                                                   interpret=True)
        )(params, jnp.zeros((2, 1), jnp.int32), rs)
        assert str(jaxpr).count("pallas_call") == 1


# ---------------------------------------------------------------------------
# engine + scheduler integration
# ---------------------------------------------------------------------------

class TestEngineResident:
    def _setup(self, arch="smollm-135m", seed=0):
        cfg = get_config(arch).reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(seed))
        rng = np.random.default_rng(seed)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 24)),
                           jnp.int32)
        _, st = M.prefill(params, {"tokens": toks}, cfg, max_seq=32)
        cb = _calibrate(st.cache)
        return cfg, params, {"tokens": toks}, cb

    def test_resident_generate_serves(self):
        cfg, params, batch, cb = self._setup()
        eng = DisaggregatedEngine(cfg, params, cb, resident="compressed",
                                  page_bytes=2048)
        out = eng.generate(batch, num_steps=6, max_seq=64)
        assert out.shape == (2, 7)             # first token + 6 steps
        assert eng.stats.resident_admits == 1
        assert eng.stats.resident_demotions == 0
        assert eng.stats.resident_ratio > 0

    def test_resident_generate_default_max_seq_stays_resident(self):
        """generate() without max_seq must derive a page-aligned default
        (prompt + first token + steps, rounded up) — prefill's raw-prompt
        default is not page-aligned and used to silently demote every
        batch that didn't pass max_seq explicitly."""
        cfg, params, batch, cb = self._setup()
        eng = DisaggregatedEngine(cfg, params, cb, resident="compressed",
                                  page_bytes=2048)
        out = eng.generate(batch, num_steps=6)   # no max_seq on purpose
        assert out.shape == (2, 7)
        assert eng.stats.resident_admits == 1
        assert eng.stats.resident_demotions == 0

    def test_demotion_is_bit_identical_to_raw(self):
        """A stream the pool cannot admit (here: an out-of-band codebook
        making every element escape) demotes to raw residency; the served
        tokens must then be BIT-identical to the raw-resident engine."""
        cfg, params, batch, _ = self._setup()
        bad = cbm.Codebook(fmt="bf16", exponents=tuple(range(16)))
        eng_res = DisaggregatedEngine(cfg, params, bad, resident="compressed",
                                      page_bytes=2048)
        eng_raw = DisaggregatedEngine(cfg, params, bad, resident="raw")
        out_res = eng_res.generate(batch, num_steps=6, max_seq=64)
        out_raw = eng_raw.generate(batch, num_steps=6, max_seq=64)
        assert eng_res.stats.resident_demotions == 1
        np.testing.assert_array_equal(np.asarray(out_res),
                                      np.asarray(out_raw))

    def test_flush_failure_midstream_matches_raw_tokens(self):
        """A ResidencyError raised by flush_full_tails MID-GENERATION (the
        just-filled page's data still only in the tail) demotes losslessly:
        the whole served sequence must match the raw-resident path.  Before
        the rehydrate fix, demotion at a flush boundary zeroed a full page
        of KV and decode silently continued on garbage (REVIEW, high)."""
        from repro.serving import decode as D

        cfg = get_config("smollm-135m").reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(17)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 24)),
                           jnp.int32)
        _, st0 = M.prefill(params, {"tokens": toks}, cfg, max_seq=64)
        cb = _calibrate(st0.cache)
        pool = _pool_for(st0.cache, cb)
        tp = pool.geom.tokens_per_page
        comp, _ = _encode(st0.cache, cb)
        rs = pool.admit_from_wire(comp, st0.cache_len)

        # fail the FIRST flush that actually has a full unmapped tail page
        orig = pool.flush_full_tails
        state = {"failed": False}

        def failing(st):
            lens_ = np.asarray(st.cache_len)
            table0 = np.asarray(
                st.leaves[pool.geom.leaves[0].key].page_table)
            needs = any(
                lens_[b] > 0 and lens_[b] % tp == 0
                and table0[0, b, lens_[b] // tp - 1] < 0
                for b in range(lens_.shape[0]))
            if needs and not state["failed"]:
                state["failed"] = True
                raise KVP.ResidencyError("injected flush failure")
            return orig(st)

        pool.flush_full_tails = failing
        first = jnp.asarray(rng.integers(0, cfg.vocab_size, (2,)),
                            jnp.int32)
        n = tp + 4                                 # crosses >=1 boundary
        toks_res, _, demoted = D.resident_decode_loop(
            params, first, rs, pool, cfg, n)
        assert demoted and state["failed"]
        toks_raw, _ = D.decode_loop(params, first, st0, cfg, n)
        np.testing.assert_array_equal(np.asarray(toks_res),
                                      np.asarray(toks_raw))

    def test_hbm_derived_decode_slots(self):
        """SchedulerConfig.derived_decode_slots: the compressed-resident
        footprint buys >= 1.25x the slots of raw at the same HBM budget."""
        m = 2 * 2 * 8 * 64                       # L * kv * Hkv * hd
        raw_bpt = 2.0 * m
        comp_bpt = KVP.bytes_per_token_resident(m, 1024)
        base = dict(hbm_bytes_per_worker=1 << 30, slot_tokens=4096)
        raw = SchedulerConfig(resident_bytes_per_token=raw_bpt, **base)
        comp = SchedulerConfig(resident_bytes_per_token=comp_bpt, **base)
        s_raw, s_comp = raw.derived_decode_slots(), comp.derived_decode_slots()
        assert s_comp / s_raw >= 1.25
        # the fleet multiplies; the flat budget survives when unset
        two = SchedulerConfig(resident_bytes_per_token=comp_bpt,
                              n_decode_workers=2, **base)
        assert two.derived_decode_slots() == 2 * s_comp
        assert SchedulerConfig(max_decode_slots=7).derived_decode_slots() == 7
        with pytest.raises(ValueError):
            SchedulerConfig(hbm_bytes_per_worker=1 << 30).derived_decode_slots()
        # a budget that fits no slot must raise, not silently floor to 1
        # per worker (that would over-commit the stated HBM budget)
        with pytest.raises(ValueError, match="fits no"):
            SchedulerConfig(hbm_bytes_per_worker=1024,
                            resident_bytes_per_token=raw_bpt,
                            slot_tokens=4096).derived_decode_slots()


# ---------------------------------------------------------------------------
# ragged (mixed-length) batches — satellite of ISSUE 8
# ---------------------------------------------------------------------------

class TestRaggedLengths:
    def test_prefill_scores_each_row_at_its_own_length(self):
        """Batched ragged prefill == each row prefilled solo: the logits
        must come from every row's OWN last real token, and the decode
        continuation from its own cache_len — not the padded length."""
        cfg = get_config("smollm-135m").reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(2)
        full = rng.integers(0, cfg.vocab_size, (2, 20))
        lens = np.array([20, 13])
        toks = full.copy()
        toks[1, 13:] = 0                          # right-padding
        logits, st = M.prefill(
            params, {"tokens": jnp.asarray(toks, jnp.int32),
                     "lengths": jnp.asarray(lens, jnp.int32)},
            cfg, max_seq=32)
        np.testing.assert_array_equal(np.asarray(st.cache_len), lens)

        for row in range(2):
            solo = jnp.asarray(full[row:row + 1, :lens[row]], jnp.int32)
            lr, sr = M.prefill(params, {"tokens": solo}, cfg, max_seq=32)
            a = np.asarray(logits[row], np.float32)
            b = np.asarray(lr[0], np.float32)
            np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-2)

        # decode continues from per-row lengths: batched next tokens match
        # the solo continuations
        tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        l2, _ = M.decode_step(params, tok0[:, None], st, cfg)
        for row in range(2):
            solo = jnp.asarray(full[row:row + 1, :lens[row]], jnp.int32)
            lr, sr = M.prefill(params, {"tokens": solo}, cfg, max_seq=32)
            ls, _ = M.decode_step(
                params, jnp.argmax(lr, -1).astype(jnp.int32)[:, None], sr, cfg)
            np.testing.assert_allclose(
                np.asarray(l2[row], np.float32),
                np.asarray(ls[0], np.float32), rtol=2e-2, atol=2e-2)

    def test_prefill_rejects_ragged_recurrent_families(self):
        cfg = get_config("mamba2-2.7b").reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        toks = jnp.zeros((2, 8), jnp.int32)
        with pytest.raises(ValueError):
            M.prefill(params, {"tokens": toks,
                               "lengths": jnp.asarray([8, 5])}, cfg,
                      max_seq=16)

    def test_valid_mask(self):
        from repro.models.kvcache import DecodeState
        cache = _dense_cache(L=1, B=2, S=8)
        st = DecodeState(cache=cache, cache_len=jnp.asarray([8, 3]))
        mask = np.asarray(st.valid_mask())
        assert mask.shape == (2, 8)
        assert mask[0].all() and mask[1, :3].all() and not mask[1, 3:].any()
