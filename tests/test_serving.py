"""End-to-end disaggregated serving tests: the paper's Table 9 invariant —
serving THROUGH the compressed transfer produces bit-identical results to
serving without it — plus transfer accounting and scheduler behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, ShapeConfig, get_config
from repro.core import codebook as cbm
from repro.core.pipeline import CodecProfile
from repro.models import model as M
from repro.serving import transfer as T
from repro.serving.engine import DisaggregatedEngine
from repro.serving.scheduler import DisaggregatedScheduler, Request, SchedulerConfig, summarize

SHAPE = ShapeConfig("smoke", seq_len=24, global_batch=2, kind="train")


def _kv_codebook(cache):
    leaves = [np.asarray(jax.lax.bitcast_convert_type(x, jnp.uint16)).ravel()
              for x in jax.tree.leaves(cache) if x.dtype == jnp.bfloat16]
    if not leaves:
        return cbm.Codebook(fmt="bf16", exponents=tuple(range(112, 128)))
    return cbm.calibrate(leaves, k=16)


@pytest.mark.parametrize("arch", ["smollm-135m", "qwen3-moe-30b-a3b",
                                  "minicpm3-4b", "mamba2-2.7b",
                                  "recurrentgemma-9b"])
def test_generation_identical_with_and_without_compression(arch):
    """Table 9: exact output match through the compressed PD boundary."""
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = M.make_inputs(cfg, SHAPE, seq=16)
    prompt = {k: v for k, v in batch.items() if k != "labels"}

    # calibrate on this model's actual cache exponents (paper §3.3)
    _, state0 = M.prefill(params, prompt, cfg, max_seq=24)
    cb = _kv_codebook(state0.cache)

    eng_c = DisaggregatedEngine(cfg, params, cb, compress=True)
    eng_n = DisaggregatedEngine(cfg, params, cb, compress=False)
    out_c = eng_c.generate(prompt, num_steps=6, max_seq=24)
    out_n = eng_n.generate(prompt, num_steps=6, max_seq=24)

    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out_n))
    assert eng_c.stats.codec_ok
    # compression actually reduced the wire bytes (bf16 leaves exist)
    has_bf16 = any(x.dtype == jnp.bfloat16 for x in jax.tree.leaves(state0.cache))
    if has_bf16:
        assert eng_c.stats.wire_bytes < eng_c.stats.raw_cache_bytes
        assert eng_n.stats.wire_bytes == eng_n.stats.raw_cache_bytes


def test_overflow_falls_back_to_raw_and_stays_lossless():
    """Adversarial distribution + tiny escape capacity: the per-tensor raw
    fallback must keep the generation identical (unconditional losslessness;
    DESIGN.md §2) while wire accounting charges raw bytes."""
    cfg = get_config("smollm-135m").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = M.make_inputs(cfg, SHAPE, seq=16)
    prompt = {k: v for k, v in batch.items() if k != "labels"}

    # deliberately mis-calibrated codebook: most exponents escape
    bad_cb = cbm.Codebook(fmt="bf16", exponents=tuple(range(16)))
    eng_c = DisaggregatedEngine(cfg, params, bad_cb, compress=True, cap=4)
    eng_n = DisaggregatedEngine(cfg, params, bad_cb, compress=False)
    out_c = eng_c.generate(prompt, num_steps=6, max_seq=24)
    out_n = eng_n.generate(prompt, num_steps=6, max_seq=24)

    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out_n))
    assert not eng_c.stats.codec_ok          # overflow was detected
    # fallback shipped raw: no byte reduction on the overflowed tensors
    assert eng_c.stats.wire_bytes >= eng_c.stats.raw_cache_bytes


def test_fp32_state_compression_bit_exact():
    """Beyond-paper fp32 codec (hi/lo split): SSM/RG-LRU recurrent states are
    fp32, which the paper's bf16-only codec skips entirely.  The hi u16 half
    has the BF16 bit layout, so the same codebook compresses it losslessly."""
    rng = np.random.default_rng(3)
    cache = {"ssm": jnp.asarray(rng.normal(size=(4, 2, 8, 16, 32)), jnp.float32),
             "k": jnp.asarray(rng.normal(size=(4, 2, 64, 2, 16)), jnp.bfloat16)}
    cb = cbm.Codebook(fmt="bf16", exponents=tuple(range(115, 131)))
    tc = T.TransferConfig(codebook=cb, layout="global", compress_fp32=True,
                          global_budget=0.05)
    comp, raw = T.compress_cache(cache, tc)
    assert "ssm#hi" in comp and "ssm#lo" in raw   # split happened
    out = T.decompress_cache(comp, raw, cache)
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(out)):
        w = jnp.uint32 if a.dtype == jnp.float32 else jnp.uint16
        np.testing.assert_array_equal(
            np.asarray(jax.lax.bitcast_convert_type(a, w)),
            np.asarray(jax.lax.bitcast_convert_type(b, w)))
    # wire accounting: fp32 leaf now ships < raw bytes
    wire = float(T.compressed_wire_bytes(comp, raw))
    assert wire < T.raw_wire_bytes(cache)


def test_cache_roundtrip_bit_exact_all_leaves():
    cfg = get_config("llama3.2-3b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    prompt = {k: v for k, v in M.make_inputs(cfg, SHAPE, seq=16).items()
              if k != "labels"}
    _, state = M.prefill(params, prompt, cfg, max_seq=16)
    cb = _kv_codebook(state.cache)
    tc = T.TransferConfig(codebook=cb)
    comp, raw = T.compress_cache(state.cache, tc)
    back = T.decompress_cache(comp, raw, state.cache)
    for a, b in zip(jax.tree.leaves(state.cache), jax.tree.leaves(back)):
        if a.dtype == jnp.bfloat16:
            np.testing.assert_array_equal(
                np.asarray(jax.lax.bitcast_convert_type(a, jnp.uint16)),
                np.asarray(jax.lax.bitcast_convert_type(b, jnp.uint16)))
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_wire_bytes_close_to_four_thirds():
    cfg = get_config("llama3.2-3b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    prompt = {k: v for k, v in M.make_inputs(cfg, SHAPE, seq=16).items()
              if k != "labels"}
    _, state = M.prefill(params, prompt, cfg, max_seq=16)
    cb = _kv_codebook(state.cache)
    comp, raw = T.compress_cache(state.cache, T.TransferConfig(codebook=cb))
    wire = float(T.compressed_wire_bytes(comp, raw))
    rawb = T.raw_wire_bytes(state.cache)
    assert 1.2 < rawb / wire <= 4 / 3 + 1e-6


def test_transfer_report_matches_paper_structure():
    # paper Fig. 4 at 64K: compressed transfer dominates, codec is minor
    # (paper reports 92.9% / 5.7% / 1.4%; our additive model with the paper's
    # own throughput+bandwidth constants gives ~80/15/4 — same structure)
    raw = 1.75e9
    # RoCE 4x200G regime: transfer dominates, codec visible but minor
    p_fast = CodecProfile(g_enc=613.3e9, g_dec=2181.8e9, ratio=1.324, link_bw=87.5e9)
    rep = T.transfer_report(raw, raw / 1.324, p_fast)
    assert rep.speedup > 1.0
    assert rep.t_transfer / rep.t_splitzip > 0.75
    assert (rep.t_encode + rep.t_decode) / rep.t_splitzip < 0.25
    # 100GbE-class inter-cluster regime: codec fully amortized, speedup ≈ ρ
    p_slow = CodecProfile(g_enc=613.3e9, g_dec=2181.8e9, ratio=1.324, link_bw=12.5e9)
    rep2 = T.transfer_report(raw, raw / 1.324, p_slow)
    assert rep2.speedup > 1.25
    assert rep2.t_transfer / rep2.t_splitzip > 0.95


class TestScheduler:
    def _cfg(self, compress):
        return SchedulerConfig(
            kv_bytes_per_token=2 * 32 * 8 * 128 * 2,
            profile=CodecProfile(g_enc=613.3e9, g_dec=2181.8e9, ratio=1.324,
                                 link_bw=12.5e9),
            compress=compress,
        )

    def _run(self, compress, n=32, prompt=16384):
        s = DisaggregatedScheduler(self._cfg(compress))
        for i in range(n):
            s.submit(Request(rid=i, arrival=i * 1e-3, prompt_len=prompt,
                             max_new_tokens=32))
        return summarize(s.run())

    def test_compression_improves_ttft_and_throughput_when_link_bound(self):
        with_c = self._run(True)
        without = self._run(False)
        assert with_c["mean_ttft_s"] < without["mean_ttft_s"]
        assert with_c["throughput_req_s"] >= without["throughput_req_s"]

    def test_all_requests_complete(self):
        out = self._run(True, n=10)
        assert out["n"] == 10
