"""End-to-end disaggregated serving tests: the paper's Table 9 invariant —
serving THROUGH the compressed transfer produces bit-identical results to
serving without it — plus transfer accounting and scheduler behaviour."""

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, ShapeConfig, get_config
from repro.core import codebook as cbm
from repro.core.pipeline import (CodecProfile, additive_transfer_time,
                                 native_transfer_time, pipelined_transfer_time)
from repro.models import model as M
from repro.serving import transfer as T
from repro.serving.engine import DisaggregatedEngine
from repro.serving.plan import TransferConfig, TransferPlan
from repro.serving.scheduler import DisaggregatedScheduler, Request, SchedulerConfig, summarize

SHAPE = ShapeConfig("smoke", seq_len=24, global_batch=2, kind="train")


def _kv_codebook(cache):
    leaves = [np.asarray(jax.lax.bitcast_convert_type(x, jnp.uint16)).ravel()
              for x in jax.tree.leaves(cache) if x.dtype == jnp.bfloat16]
    if not leaves:
        return cbm.Codebook(fmt="bf16", exponents=tuple(range(112, 128)))
    return cbm.calibrate(leaves, k=16)


@pytest.mark.parametrize("arch", ["smollm-135m", "qwen3-moe-30b-a3b",
                                  "minicpm3-4b", "mamba2-2.7b",
                                  "recurrentgemma-9b"])
def test_generation_identical_with_and_without_compression(arch):
    """Table 9: exact output match through the compressed PD boundary."""
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = M.make_inputs(cfg, SHAPE, seq=16)
    prompt = {k: v for k, v in batch.items() if k != "labels"}

    # calibrate on this model's actual cache exponents (paper §3.3)
    _, state0 = M.prefill(params, prompt, cfg, max_seq=24)
    cb = _kv_codebook(state0.cache)

    eng_c = DisaggregatedEngine(cfg, params, cb, compress=True)
    eng_n = DisaggregatedEngine(cfg, params, cb, compress=False)
    out_c = eng_c.generate(prompt, num_steps=6, max_seq=24)
    out_n = eng_n.generate(prompt, num_steps=6, max_seq=24)

    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out_n))
    assert eng_c.stats.codec_ok
    # compression actually reduced the wire bytes (bf16 leaves exist)
    has_bf16 = any(x.dtype == jnp.bfloat16 for x in jax.tree.leaves(state0.cache))
    if has_bf16:
        assert eng_c.stats.wire_bytes < eng_c.stats.raw_cache_bytes
        assert eng_n.stats.wire_bytes == eng_n.stats.raw_cache_bytes


def test_overflow_falls_back_to_raw_and_stays_lossless():
    """Adversarial distribution + tiny escape capacity: the per-tensor raw
    fallback must keep the generation identical (unconditional losslessness;
    DESIGN.md §2) while wire accounting charges raw bytes."""
    cfg = get_config("smollm-135m").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = M.make_inputs(cfg, SHAPE, seq=16)
    prompt = {k: v for k, v in batch.items() if k != "labels"}

    # deliberately mis-calibrated codebook: most exponents escape
    bad_cb = cbm.Codebook(fmt="bf16", exponents=tuple(range(16)))
    eng_c = DisaggregatedEngine(cfg, params, bad_cb, compress=True, cap=4)
    eng_n = DisaggregatedEngine(cfg, params, bad_cb, compress=False)
    out_c = eng_c.generate(prompt, num_steps=6, max_seq=24)
    out_n = eng_n.generate(prompt, num_steps=6, max_seq=24)

    np.testing.assert_array_equal(np.asarray(out_c), np.asarray(out_n))
    assert not eng_c.stats.codec_ok          # overflow was detected
    # fallback shipped raw: no byte reduction on the overflowed tensors
    assert eng_c.stats.wire_bytes >= eng_c.stats.raw_cache_bytes


def test_fp32_state_compression_bit_exact():
    """Beyond-paper fp32 codec (hi/lo split): SSM/RG-LRU recurrent states are
    fp32, which the paper's bf16-only codec skips entirely.  The hi u16 half
    has the BF16 bit layout, so the same codebook compresses it losslessly."""
    rng = np.random.default_rng(3)
    cache = {"ssm": jnp.asarray(rng.normal(size=(4, 2, 8, 16, 32)), jnp.float32),
             "k": jnp.asarray(rng.normal(size=(4, 2, 64, 2, 16)), jnp.bfloat16)}
    cb = cbm.Codebook(fmt="bf16", exponents=tuple(range(115, 131)))
    tc = T.TransferConfig(codebook=cb, layout="global", compress_fp32=True,
                          global_budget=0.05)
    comp, raw = T.compress_cache(cache, tc)
    assert "ssm#hi" in comp and "ssm#lo" in raw   # split happened
    out = T.decompress_cache(comp, raw, cache)
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(out)):
        w = jnp.uint32 if a.dtype == jnp.float32 else jnp.uint16
        np.testing.assert_array_equal(
            np.asarray(jax.lax.bitcast_convert_type(a, w)),
            np.asarray(jax.lax.bitcast_convert_type(b, w)))
    # wire accounting: fp32 leaf now ships < raw bytes
    wire = float(T.compressed_wire_bytes(comp, raw))
    assert wire < T.raw_wire_bytes(cache)


def test_cache_roundtrip_bit_exact_all_leaves():
    cfg = get_config("llama3.2-3b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    prompt = {k: v for k, v in M.make_inputs(cfg, SHAPE, seq=16).items()
              if k != "labels"}
    _, state = M.prefill(params, prompt, cfg, max_seq=16)
    cb = _kv_codebook(state.cache)
    tc = T.TransferConfig(codebook=cb)
    comp, raw = T.compress_cache(state.cache, tc)
    back = T.decompress_cache(comp, raw, state.cache)
    for a, b in zip(jax.tree.leaves(state.cache), jax.tree.leaves(back)):
        if a.dtype == jnp.bfloat16:
            np.testing.assert_array_equal(
                np.asarray(jax.lax.bitcast_convert_type(a, jnp.uint16)),
                np.asarray(jax.lax.bitcast_convert_type(b, jnp.uint16)))
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_wire_bytes_close_to_four_thirds():
    cfg = get_config("llama3.2-3b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(2))
    prompt = {k: v for k, v in M.make_inputs(cfg, SHAPE, seq=16).items()
              if k != "labels"}
    _, state = M.prefill(params, prompt, cfg, max_seq=16)
    cb = _kv_codebook(state.cache)
    comp, raw = T.compress_cache(state.cache, T.TransferConfig(codebook=cb))
    wire = float(T.compressed_wire_bytes(comp, raw))
    rawb = T.raw_wire_bytes(state.cache)
    assert 1.2 < rawb / wire <= 4 / 3 + 1e-6


def test_transfer_report_matches_paper_structure():
    # paper Fig. 4 at 64K: compressed transfer dominates, codec is minor
    # (paper reports 92.9% / 5.7% / 1.4%; our additive model with the paper's
    # own throughput+bandwidth constants gives ~80/15/4 — same structure)
    raw = 1.75e9
    # RoCE 4x200G regime: transfer dominates, codec visible but minor
    p_fast = CodecProfile(g_enc=613.3e9, g_dec=2181.8e9, ratio=1.324, link_bw=87.5e9)
    rep = T.transfer_report(raw, raw / 1.324, p_fast)
    assert rep.speedup > 1.0
    assert rep.t_transfer / rep.t_splitzip > 0.75
    assert (rep.t_encode + rep.t_decode) / rep.t_splitzip < 0.25
    # 100GbE-class inter-cluster regime: codec fully amortized, speedup ≈ ρ
    p_slow = CodecProfile(g_enc=613.3e9, g_dec=2181.8e9, ratio=1.324, link_bw=12.5e9)
    rep2 = T.transfer_report(raw, raw / 1.324, p_slow)
    assert rep2.speedup > 1.25
    assert rep2.t_transfer / rep2.t_splitzip > 0.95


class TestScheduler:
    def _cfg(self, compress):
        return SchedulerConfig(
            kv_bytes_per_token=2 * 32 * 8 * 128 * 2,
            profile=CodecProfile(g_enc=613.3e9, g_dec=2181.8e9, ratio=1.324,
                                 link_bw=12.5e9),
            compress=compress,
        )

    def _run(self, compress, n=32, prompt=16384):
        s = DisaggregatedScheduler(self._cfg(compress))
        for i in range(n):
            s.submit(Request(rid=i, arrival=i * 1e-3, prompt_len=prompt,
                             max_new_tokens=32))
        return summarize(s.run())

    def test_compression_improves_ttft_and_throughput_when_link_bound(self):
        with_c = self._run(True)
        without = self._run(False)
        assert with_c["mean_ttft_s"] < without["mean_ttft_s"]
        assert with_c["throughput_req_s"] >= without["throughput_req_s"]

    def test_all_requests_complete(self):
        out = self._run(True, n=10)
        assert out["n"] == 10


KV_BYTES_TOK = 2 * 32 * 8 * 128 * 2
PROF = CodecProfile(g_enc=613.3e9, g_dec=2181.8e9, ratio=1.324, link_bw=25e9)


class TestEventDrivenScheduler:
    """ISSUE 4 invariants suite: link-occupancy conservation, FIFO
    serialization, decode-aware TTFT, plan-aware vs legacy charging,
    event-queue determinism."""

    def _cfg(self, **kw):
        base = dict(kv_bytes_per_token=KV_BYTES_TOK, profile=PROF,
                    compress=True)
        base.update(kw)
        return SchedulerConfig(**base)

    def _run(self, cfg, reqs):
        s = DisaggregatedScheduler(cfg)
        for r in reqs:
            s.submit(r)
        return s, s.run()

    def test_link_occupied_exactly_once_no_double_charge(self):
        """Regression: the old drain loop re-iterated decode-blocked requests
        every pass, advancing t_link and overwriting transfer_done.  With one
        decode slot and slow decode, every request must still occupy the link
        exactly once, back-to-back."""
        cfg = self._cfg(max_decode_slots=1, decode_time_per_step=0.05)
        reqs = [Request(rid=i, arrival=0.0, prompt_len=16384, max_new_tokens=8)
                for i in range(6)]
        s, done = self._run(cfg, reqs)
        assert len(done) == 6
        ivs = sorted((r.link_start, r.transfer_done) for r in done)
        durs = [b - a for a, b in ivs]
        for (a0, b0), (a1, b1) in zip(ivs, ivs[1:]):
            assert a1 >= b0 - 1e-12          # never overlapping
        # conservation: total occupancy == sum of the single charges; equal
        # prompts => equal charges; the backlog never inflated the link
        assert s.link_busy_s == pytest.approx(sum(durs))
        assert max(durs) == pytest.approx(min(durs))
        assert ivs[-1][1] - ivs[0][0] == pytest.approx(sum(durs))

    def test_fifo_link_serialization(self):
        cfg = self._cfg(max_prefill_batch=2)
        reqs = [Request(rid=i, arrival=i * 1e-3, prompt_len=8192,
                        max_new_tokens=4) for i in range(8)]
        s, done = self._run(cfg, reqs)
        order = sorted(done, key=lambda r: r.link_start)
        pf = [r.prefill_done for r in order]
        assert pf == sorted(pf)              # FIFO by prefill completion
        for a, b in zip(order, order[1:]):
            assert b.link_start >= a.transfer_done - 1e-12

    def test_ttft_waits_for_decode_worker(self):
        """Regression: first_token_time used to be transfer_done + one step,
        ignoring decode-worker occupancy.  With a single busy slot the second
        request's first token must wait for the slot AND the step boundary."""
        cfg = SchedulerConfig(max_decode_slots=1, decode_time_per_step=1.0,
                              prefill_time_per_token=0.0, profile=None)
        a = Request(rid=0, arrival=0.0, prompt_len=4, max_new_tokens=3)
        b = Request(rid=1, arrival=0.0, prompt_len=4, max_new_tokens=2)
        _, done = self._run(cfg, [a, b])
        by = {r.rid: r for r in done}
        assert by[0].first_token_time == pytest.approx(1.0)
        assert by[0].finish_time == pytest.approx(3.0)
        # b's transfer finished at t=0, but the only slot is busy until t=3:
        # first token at 4.0, NOT transfer_done + decode_time_per_step = 1.0
        assert by[1].transfer_done == pytest.approx(0.0)
        assert by[1].first_token_time == pytest.approx(4.0)
        assert by[1].finish_time == pytest.approx(5.0)

    def test_zero_new_tokens_terminates(self):
        """Regression: max_new_tokens <= 0 made steps == 0 in the old stage-3
        drain and the loop never terminated; such budgets are clamped to one
        decoded token (TTFT needs a first token)."""
        for bad in (0, -3):
            s, done = self._run(self._cfg(), [
                Request(rid=0, arrival=0.0, prompt_len=1024,
                        max_new_tokens=bad)])
            assert len(done) == 1
            assert done[0].tokens_out == 1
            assert done[0].finish_time > done[0].transfer_done

    def test_plan_built_once_per_bucket_and_reused(self):
        cfg = self._cfg(bucket_tokens=1024)
        reqs = [Request(rid=i, arrival=0.0, prompt_len=pl, max_new_tokens=2)
                for i, pl in enumerate([1000, 1024, 512, 4096])]
        s, done = self._run(cfg, reqs)
        assert len(done) == 4
        assert set(s.plans) == {1024, 4096}  # 1000/1024/512 share one plan
        assert all(isinstance(p, TransferPlan) for p in s.plans.values())

    def test_plan_aware_matches_legacy_when_chunks_equal(self):
        """Acceptance: plan-aware charging must agree EXACTLY with the legacy
        equal-chunk model when the plan's segments are equal-sized (and with
        the additive/native accounting at tensor granularity)."""
        bytes_ = 16384 * KV_BYTES_TOK        # stream divides evenly: 8 equal
        req = lambda: Request(rid=0, arrival=0.0, prompt_len=16384,
                              max_new_tokens=1)
        s, done = self._run(self._cfg(n_chunks=8), [req()])
        plan = s.plans[16384]
        assert len({seg.n_elements for seg in plan.segments}) == 1
        dur = done[0].transfer_done - done[0].link_start
        assert dur == pytest.approx(pipelined_transfer_time(bytes_, PROF, 8),
                                    rel=1e-12)
        _, done = self._run(self._cfg(n_chunks=1), [req()])
        dur = done[0].transfer_done - done[0].link_start
        assert dur == pytest.approx(additive_transfer_time(bytes_, PROF),
                                    rel=1e-12)
        _, done = self._run(self._cfg(compress=False), [req()])
        dur = done[0].transfer_done - done[0].link_start
        assert dur == pytest.approx(native_transfer_time(bytes_, PROF),
                                    rel=1e-12)

    def test_plan_estimate_diverges_with_short_tail_segment(self):
        """Acceptance: when chunk alignment produces a short last segment the
        flowshop over ACTUAL sizes must diverge from the equal-chunk model."""
        cb = cbm.Codebook(fmt="bf16", exponents=tuple(range(112, 128)))
        p = PROF
        # 2560 elements, 2 chunks: ceil-split 1280 aligns up to 2048 =>
        # segments [2048, 512] — unequal
        plan = TransferPlan.build(
            {"kv": jax.ShapeDtypeStruct((2560,), jnp.bfloat16)},
            TransferConfig(codebook=cb, n_chunks=2))
        assert [seg.n_elements for seg in plan.segments] == [2048, 512]
        est = plan.estimate_time(p)
        legacy = pipelined_transfer_time(2.0 * 2560, p, 2)
        assert abs(est - legacy) / legacy > 1e-9
        # equal segments reduce to the legacy model exactly
        plan_eq = TransferPlan.build(
            {"kv": jax.ShapeDtypeStruct((4096,), jnp.bfloat16)},
            TransferConfig(codebook=cb, n_chunks=2))
        assert plan_eq.estimate_time(p) == pytest.approx(
            pipelined_transfer_time(2.0 * 4096, p, 2), rel=1e-12)

    def test_overflow_expectation_inflates_charge(self):
        """Expected capacity-schedule retries / raw fallbacks make the charge
        strictly larger — extra encode attempts, fallback at full link cost."""
        cb = cbm.Codebook(fmt="bf16", exponents=tuple(range(112, 128)))
        plan = TransferPlan.build(
            {"kv": jax.ShapeDtypeStruct((8192,), jnp.bfloat16)},
            TransferConfig(codebook=cb, n_chunks=4))
        attempts, raw_frac = plan.expected_attempts(0.3)
        k = len(plan.schedule_for(plan.segments[0].n_elements,
                                  plan.segments[0].cap))
        assert attempts == pytest.approx(sum(0.3 ** i for i in range(k)))
        assert raw_frac == pytest.approx(0.3 ** k)
        assert plan.estimate_time(PROF, overflow_p=0.3) > plan.estimate_time(PROF)
        # and the scheduler passes it through to the charged duration
        req = lambda: Request(rid=0, arrival=0.0, prompt_len=16384,
                              max_new_tokens=1)
        _, base = self._run(self._cfg(n_chunks=4), [req()])
        _, slow = self._run(self._cfg(n_chunks=4, overflow_p=0.5), [req()])
        assert (slow[0].transfer_done - slow[0].link_start) > \
            (base[0].transfer_done - base[0].link_start)

    def test_event_queue_determinism_under_interleaved_arrivals(self):
        """Identical request sets submitted in any order produce identical
        per-request timings (queues are rid-tie-broken, same-timestamp events
        fully drain before dispatch)."""
        rng = random.Random(7)

        def make():
            arrivals = [0.0, 0.0, 0.0, 1e-3, 1e-3, 2e-3, 2e-3, 2e-3, 5e-3,
                        5e-3, 8e-3, 8e-3]
            return [Request(rid=i, arrival=a, prompt_len=4096 * (1 + i % 3),
                            max_new_tokens=2 + i % 4)
                    for i, a in enumerate(arrivals)]

        def snap(order):
            cfg = self._cfg(max_prefill_batch=3, max_decode_slots=2,
                            decode_time_per_step=1e-3)
            _, done = self._run(cfg, order)
            return {r.rid: (r.prefill_done, r.link_start, r.transfer_done,
                            r.admit_time, r.first_token_time, r.finish_time)
                    for r in done}

        base = snap(make())
        for _ in range(3):
            order = make()
            rng.shuffle(order)
            assert snap(order) == base

    def test_p99_nearest_rank(self):
        """Regression: the floor index int(0.99 * (n-1)) underestimated the
        tail; nearest-rank (ceil) picks the true max for n=10 distinct TTFTs."""
        done = [Request(rid=i, arrival=0.0, prompt_len=1, max_new_tokens=1,
                        first_token_time=float(i + 1), finish_time=10.0,
                        tokens_out=1) for i in range(10)]
        out = summarize(done)
        assert out["p99_ttft_s"] == 10.0     # old floor index gave 9.0
        # n=100: nearest rank = 99th value
        done = [Request(rid=i, arrival=0.0, prompt_len=1, max_new_tokens=1,
                        first_token_time=float(i + 1), finish_time=100.0,
                        tokens_out=1) for i in range(100)]
        assert summarize(done)["p99_ttft_s"] == 99.0

    def test_zero_decode_slots_fails_loudly(self):
        """Misconfigurations that strand requests (admission can never
        happen) must raise, not return a silently partial done list."""
        s = DisaggregatedScheduler(self._cfg(max_decode_slots=0))
        s.submit(Request(rid=0, arrival=0.0, prompt_len=1024,
                         max_new_tokens=1))
        with pytest.raises(RuntimeError, match="never completed"):
            s.run()

    def test_engine_plan_requires_kv_bytes_per_token(self):
        """A pre-built plan with the default kv_bytes_per_token == 0 would
        silently charge every prompt length the plan's build-time bytes."""
        cb = cbm.Codebook(fmt="bf16", exponents=tuple(range(112, 128)))
        plan = TransferPlan.build(
            {"kv": jax.ShapeDtypeStruct((4096,), jnp.bfloat16)},
            TransferConfig(codebook=cb))
        with pytest.raises(ValueError, match="kv_bytes_per_token"):
            DisaggregatedScheduler(SchedulerConfig(plan=plan, profile=PROF))

    def test_fp8_sidecar_raw_fallback_charged_at_full_link(self):
        """overflow_p must degrade the fp8 sidecar's wire cost too: the
        schedule-exhausted fraction ships raw at full link bandwidth."""
        cb = cbm.Codebook(fmt="bf16", exponents=tuple(range(112, 128)))
        plan = TransferPlan.build(
            {"a": jax.ShapeDtypeStruct((4096,), jnp.float8_e5m2)},
            TransferConfig(codebook=cb))
        est = plan.estimate_time(PROF, overflow_p=1.0)
        assert est > plan.estimate_time(PROF)
        assert est >= 4096 / PROF.link_bw   # full link cost, no ratio

    def test_engine_hands_plan_to_scheduler(self):
        """DisaggregatedEngine.scheduler_config: the scheduler charges through
        the SAME TransferPlan object the engine's session executes."""
        cfg = get_config("smollm-135m").reduced()
        cb = cbm.Codebook(fmt="bf16", exponents=tuple(range(112, 128)))
        eng = DisaggregatedEngine(cfg, None, cb, compress=True,
                                  profile=PROF)
        cache = {"k": jnp.zeros((2, 1, 8, 2, 16), jnp.bfloat16),
                 "v": jnp.zeros((2, 1, 8, 2, 16), jnp.bfloat16)}
        eng._session_for(cache)              # resolves the plan once
        sc = eng.scheduler_config(kv_bytes_per_token=KV_BYTES_TOK)
        assert sc.plan is eng.plan and sc.profile is PROF
        s, done = self._run(sc, [Request(rid=0, arrival=0.0, prompt_len=16384,
                                         max_new_tokens=2)])
        assert not s.plans                   # no bucket plans: engine's used
        dur = done[0].transfer_done - done[0].link_start
        # tensor-granularity plan, pure-bf16 cache: additive accounting scaled
        # to this prompt's bytes
        assert dur == pytest.approx(
            additive_transfer_time(16384 * KV_BYTES_TOK, PROF), rel=1e-9)
