"""Unit tests for sharding rules: TP baseline, FSDP (ZeRO-3), MoE dispatch
constraints, PD-disaggregated dp axes.  Uses an abstract 2x2(x2) mesh — no
compiles, just spec resolution."""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import pytest  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.distributed.sharding import ShardingPolicy  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402


@pytest.fixture(scope="module")
def mesh3():
    return make_mesh((2, 2, 2), ("pod", "data", "model"))


@pytest.fixture(scope="module")
def mesh2():
    return make_mesh((4, 2), ("data", "model"))


class TestFSDP:
    def test_params_gain_data_axis(self, mesh2):
        base = ShardingPolicy(mesh2)
        fsdp = ShardingPolicy(mesh2, fsdp=True)
        # FFN weight (D, F): TP on F; FSDP adds data on D
        s_base = base.spec_for_param("ffn/w_gate", (512, 2048))
        s_fsdp = fsdp.spec_for_param("ffn/w_gate", (512, 2048))
        assert s_base == P(None, "model")
        assert s_fsdp == P("data", "model")

    def test_scan_stacked_leading_dim_never_sharded(self, mesh2):
        fsdp = ShardingPolicy(mesh2, fsdp=True)
        s = fsdp.spec_for_param("layers/ffn/w_gate", (16, 512, 2048))
        assert s == P(None, "data", "model")

    def test_small_params_stay_replicated(self, mesh2):
        fsdp = ShardingPolicy(mesh2, fsdp=True)
        # norm scale of 8 elements: gathering costs more than it saves
        assert fsdp.spec_for_param("layers/norm1/scale", (16, 8)) == P(None, None)

    def test_indivisible_dims_not_sharded(self, mesh2):
        fsdp = ShardingPolicy(mesh2, fsdp=True)
        s = fsdp.spec_for_param("ffn/w_gate", (509, 2048))  # 509 prime
        assert s == P(None, "model")

    def test_expert_weights(self, mesh2):
        fsdp = ShardingPolicy(mesh2, fsdp=True)
        # (E, D, 2F): EP on E, FSDP picks the largest remaining dim
        s = fsdp.spec_for_param("layers/ffn/w_gate_up", (8, 128, 512, 1024))
        assert s == P(None, "model", None, "data")

    def test_opt_state_shards_like_params(self, mesh2):
        from repro.configs.base import get_config
        from repro.training import train_step as TS
        cfg = get_config("smollm-135m").reduced()
        fsdp = ShardingPolicy(mesh2, fsdp=True)
        st = TS.abstract_state(cfg)
        psh = fsdp.param_sharding(st.params)
        # m/v mirror params => FSDP applies to optimizer state for free
        flat_p = jax.tree.leaves(psh)
        assert any("data" in str(s.spec) for s in flat_p)


class TestMoEDispatchKinds:
    def test_disabled_by_default(self, mesh2):
        pol = ShardingPolicy(mesh2)
        assert pol.spec_for_activation("moe_ecd", (8, 64, 128)) is None

    def test_enabled(self, mesh2):
        pol = ShardingPolicy(mesh2, moe_dispatch_sharding=True)
        assert pol.spec_for_activation("moe_ecd", (8, 64, 128)) == \
            P("model", None, None)
        assert pol.spec_for_activation("moe_td", (4096, 128)) == P("data", None)
        assert pol.spec_for_activation("moe_te", (4096, 8)) == P("data", None)

    def test_indivisible_experts_replicate(self, mesh2):
        pol = ShardingPolicy(mesh2, moe_dispatch_sharding=True)
        assert pol.spec_for_activation("moe_ecd", (7, 64, 128)) == \
            P(None, None, None)


class TestPDDisaggregation:
    def test_dp_axes_exclude_pod(self, mesh3):
        assert ShardingPolicy(mesh3).dp_axes() == ("pod", "data")
        assert ShardingPolicy(mesh3, pd_disaggregated=True).dp_axes() == \
            ("data",)

    def test_activation_batch_not_pod_sharded(self, mesh3):
        pol = ShardingPolicy(mesh3, pd_disaggregated=True)
        spec = pol.spec_for_activation("btd", (8, 128, 64))
        assert spec == P(("data",), None, None) or spec == P("data", None, None)


class TestFSDPTrainStepCompiles:
    def test_reduced_train_step_lowers_with_fsdp(self, mesh2):
        """End-to-end: FSDP train step lowers+compiles on the 4x2 mesh."""
        from repro.configs.base import ShapeConfig, get_config
        from repro.launch.dryrun import build_lowerable
        cfg = get_config("smollm-135m").reduced()
        shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
        pol = ShardingPolicy(mesh2, fsdp=True)
        jitted, args = build_lowerable(cfg, shape, pol)
        compiled = jitted.lower(*args).compile()
        assert compiled.cost_analysis() is not None
