"""ISSUE 10: prefix-aware delta transfer + failover re-send, execution side.

``TransferSession.transfer_delta`` ships only the segments/sidecars whose
sender-side bits changed since the session's previous turn; everything else
is re-used from the receiver's resident copy and accounted in
``prefix_hit_bytes``.  The properties pinned here:

* **bit identity** — a delta transfer's result equals a full transfer of the
  same cache, bitwise, on every route (splitzip stream, fp32 hi/lo, fp8
  sidecar, raw passthrough), cold or warm, with or without fault injection.
* **cold = full** — an unknown session id hits nothing and ships everything.
* **delta saves wire** — an unchanged prefix crosses the wire zero times;
  shipped + hit bytes decompose to exactly the full-transfer wire bytes of
  a cold send.
* **eviction** — ``PrefixIndex`` is LRU-by-bytes; an evicted session's next
  transfer is cold (correct, just unaided).
* **failover re-send** — ``resend_last``/``DisaggregatedEngine.resend_cache``
  rebuild a dead decode worker's state bit-identically from the retained
  payload, wired end-to-end through the scheduler's ``on_failover`` hook.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codebook as cbm
from repro.core.pipeline import CodecProfile
from repro.serving.cluster import ClusterConfig, LinkSpec
from repro.serving.faults import FaultPlan, WorkerKill
from repro.serving.plan import TransferConfig, TransferPlan
from repro.serving.session import PrefixIndex, TransferSession
from repro.serving.scheduler import (DisaggregatedScheduler, Request,
                                     SchedulerConfig)


def _bf16(shape, seed):
    r = np.random.default_rng(seed)
    x = r.standard_normal(shape) * np.exp(r.standard_normal(shape))
    return jnp.asarray(x.astype(np.float32)).astype(jnp.bfloat16)


@pytest.fixture(scope="module")
def routed_cache():
    """A cache exercising every route: bf16 k/v (splitzip stream), a big
    fp32 leaf (hi/lo), a float8 leaf (fp8 sidecar), int ids (raw)."""
    r = np.random.default_rng(3)
    cache = {
        "k": _bf16((2, 64, 64), 1),
        "v": _bf16((2, 64, 64), 2),
        "f32": jnp.asarray(r.standard_normal((32, 64)), jnp.float32),
        "f8": jnp.asarray(r.standard_normal((32, 32)),
                          jnp.float32).astype(jnp.float8_e4m3fn),
        "ids": jnp.arange(64, dtype=jnp.int32),
    }
    bits = np.asarray(jax.lax.bitcast_convert_type(cache["k"],
                                                   jnp.uint16)).ravel()
    return cache, cbm.calibrate([bits], k=16)


def _plan(cache, cb, n_chunks=4, **kw):
    return TransferPlan.build(cache, TransferConfig(
        codebook=cb, n_chunks=n_chunks, compress_fp32=True, **kw))


def _eq(a, b):
    for k in a:
        assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), k


def _mutate_tail(cache, seed=5):
    """A next-turn cache: identical prefix, perturbed suffix on every route."""
    r = np.random.default_rng(seed)
    out = dict(cache)
    k = np.asarray(cache["k"]).copy()
    k[-1, -8:, :] = r.standard_normal(k[-1, -8:, :].shape).astype(k.dtype)
    out["k"] = jnp.asarray(k)
    f32 = np.asarray(cache["f32"]).copy()
    f32[-1, :] += 1.0
    out["f32"] = jnp.asarray(f32)
    f8 = np.asarray(cache["f8"]).copy()
    f8[-1, :] = np.float64(1.5)
    out["f8"] = jnp.asarray(f8).astype(jnp.float8_e4m3fn)
    out["ids"] = cache["ids"] + 1
    return out


class TestTransferDelta:
    def test_plan_covers_every_route(self, routed_cache):
        cache, cb = routed_cache
        routes = {r.route for r in _plan(cache, cb).routes}
        assert routes == {"splitzip", "fp32_hilo", "fp8", "raw"}

    def test_cold_delta_equals_full_transfer(self, routed_cache):
        cache, cb = routed_cache
        plan = _plan(cache, cb)
        full = plan.session().transfer(cache)
        sess = plan.session()
        sess.enable_prefix_cache()
        out = sess.transfer_delta(cache, session_id=0)
        _eq(out, full)
        _eq(out, cache)
        st = sess.last_stats
        assert st.prefix_hit_bytes == 0.0
        ref = plan.session()
        ref.transfer(cache)
        assert st.wire_bytes == pytest.approx(ref.last_stats.wire_bytes)

    def test_unchanged_cache_ships_zero_bytes(self, routed_cache):
        cache, cb = routed_cache
        sess = _plan(cache, cb).session()
        sess.enable_prefix_cache()
        sess.transfer_delta(cache, session_id=0)
        out = sess.transfer_delta(cache, session_id=0)
        _eq(out, cache)
        st = sess.last_stats
        assert st.wire_bytes == 0.0
        assert st.prefix_hit_bytes > 0

    def test_warm_delta_bit_identical_and_cheaper(self, routed_cache):
        cache, cb = routed_cache
        plan = _plan(cache, cb)
        sess = plan.session()
        sess.enable_prefix_cache()
        sess.transfer_delta(cache, session_id=0)
        cold_wire = sess.last_stats.wire_bytes

        turn2 = _mutate_tail(cache)
        out = sess.transfer_delta(turn2, session_id=0)
        _eq(out, turn2)
        full = plan.session().transfer(turn2)
        _eq(out, full)
        st = sess.last_stats
        assert 0 < st.wire_bytes < cold_wire
        assert st.prefix_hit_bytes > 0
        # every route's changed piece actually shipped
        assert any(w > 0 for w in st.chunk_wire_bytes)
        assert st.fp32_lo_wire_bytes > 0
        assert st.fp8_wire_bytes > 0
        assert st.raw_passthrough_bytes > 0

    def test_sessions_are_isolated(self, routed_cache):
        """Another session id never hits this session's resident prefix."""
        cache, cb = routed_cache
        sess = _plan(cache, cb).session()
        sess.enable_prefix_cache()
        sess.transfer_delta(cache, session_id=0)
        out = sess.transfer_delta(cache, session_id=1)
        _eq(out, cache)
        assert sess.last_stats.prefix_hit_bytes == 0.0

    def test_delta_under_fault_injection_stays_bit_identical(self,
                                                             routed_cache):
        cache, cb = routed_cache
        sess = _plan(cache, cb).session(
            verify=True, faults=FaultPlan(seed=9, corrupt_p=0.3, drop_p=0.1))
        sess.enable_prefix_cache()
        a = sess.transfer_delta(cache, session_id=0)
        turn2 = _mutate_tail(cache)
        b = sess.transfer_delta(turn2, session_id=0)
        _eq(a, cache)
        _eq(b, turn2)
        assert sess._channel.injected >= 1

    def test_fp32_and_fp8_hits_are_bitwise_not_numeric(self, routed_cache):
        """NaN payloads and negative zeros still delta correctly: the shadow
        comparison runs on bytes, so nan != nan never forces a miss and
        -0.0 == 0.0 never fakes a hit."""
        cache, cb = routed_cache
        f32 = np.asarray(cache["f32"]).copy()
        f32[0, 0] = np.nan
        f32[0, 1] = -0.0
        c1 = dict(cache, f32=jnp.asarray(f32))
        sess = _plan(c1, cb).session()
        sess.enable_prefix_cache()
        sess.transfer_delta(c1, session_id=0)
        sess.transfer_delta(c1, session_id=0)       # NaN must still hit
        assert sess.last_stats.fp32_lo_wire_bytes == 0.0
        f32b = f32.copy()
        f32b[0, 1] = 0.0        # -0.0 -> +0.0: sign lives in the HI half,
        c2 = dict(c1, f32=jnp.asarray(f32b))        # so a STREAM miss
        out = sess.transfer_delta(c2, session_id=0)
        assert any(w > 0 for w in sess.last_stats.chunk_wire_bytes)
        assert np.signbit(np.asarray(out["f32"]))[0, 1] == False  # noqa: E712
        # a low-mantissa bit flip touches ONLY the raw lo sidecar
        u = f32b.view(np.uint32).copy()
        u[1, 0] ^= np.uint32(1)
        c3 = dict(c1, f32=jnp.asarray(u.view(np.float32)))
        out = sess.transfer_delta(c3, session_id=0)
        assert sess.last_stats.fp32_lo_wire_bytes > 0.0
        assert np.array_equal(np.asarray(out["f32"]).view(np.uint32),
                              u, equal_nan=False)

    def test_delta_requires_chunked_path_and_enablement(self, routed_cache):
        cache, cb = routed_cache
        with pytest.raises(ValueError, match="chunked"):
            _plan(cache, cb, n_chunks=1).session().enable_prefix_cache()
        sess = _plan(cache, cb).session()
        with pytest.raises(RuntimeError, match="enable_prefix_cache"):
            sess.transfer_delta(cache, session_id=0)


class TestPrefixIndexEviction:
    def test_lru_eviction_under_pressure(self, routed_cache):
        cache, cb = routed_cache
        sess = _plan(cache, cb).session()
        entry_sz = 0
        probe = _plan(cache, cb).session()
        idx0 = probe.enable_prefix_cache()
        probe.transfer_delta(cache, session_id=0)
        entry_sz = idx0.resident_bytes
        assert entry_sz > 0

        idx = sess.enable_prefix_cache(capacity_bytes=2.5 * entry_sz)
        for sid in range(4):
            sess.transfer_delta(cache, session_id=sid)
        assert len(idx) == 2
        assert idx.evictions == 2
        assert idx.sessions() == [2, 3]     # LRU order: oldest evicted
        # the evicted session is cold again — correct, just unaided
        sess.transfer_delta(cache, session_id=0)
        assert sess.last_stats.prefix_hit_bytes == 0.0
        # ...and the still-resident one hits
        sess.transfer_delta(cache, session_id=3)
        assert sess.last_stats.prefix_hit_bytes > 0

    def test_single_entry_over_budget_never_sticks(self, routed_cache):
        cache, cb = routed_cache
        sess = _plan(cache, cb).session()
        idx = sess.enable_prefix_cache(capacity_bytes=16.0)
        sess.transfer_delta(cache, session_id=0)
        assert len(idx) == 0 and idx.evictions == 1
        assert idx.resident_bytes == 0.0

    def test_capacity_validation(self):
        with pytest.raises(ValueError):
            PrefixIndex(capacity_bytes=0.0)
        with pytest.raises(ValueError):
            PrefixIndex(capacity_bytes=-1.0)


class TestResendLast:
    def test_resend_bit_identical_and_same_wire(self, routed_cache):
        cache, cb = routed_cache
        sess = _plan(cache, cb, n_chunks=1).session(retain_last=True)
        out1 = sess.transfer(cache)
        w1 = sess.last_stats.wire_bytes
        out2 = sess.resend_last()
        _eq(out1, cache)
        _eq(out2, cache)
        assert sess.last_stats.wire_bytes == pytest.approx(w1)
        assert sess.calls == 2
        assert sess.total_wire_bytes == pytest.approx(2 * w1)

    def test_resend_under_faults_recovers(self, routed_cache):
        cache, cb = routed_cache
        sess = _plan(cache, cb, n_chunks=1).session(
            retain_last=True, verify=True,
            faults=FaultPlan(seed=3, corrupt_p=0.2))
        sess.transfer(cache)
        out = sess.resend_last()
        _eq(out, cache)

    def test_resend_guard_rails(self, routed_cache):
        cache, cb = routed_cache
        with pytest.raises(RuntimeError, match="retain_last"):
            _plan(cache, cb, n_chunks=1).session().resend_last()
        with pytest.raises(ValueError, match="tensor"):
            _plan(cache, cb, n_chunks=4).session(
                retain_last=True).resend_last()


class TestEngineFailoverResend:
    def _setup(self):
        from repro.configs.base import get_config
        from repro.models import model as M
        cfg = get_config("smollm-135m").reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        toks = jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab_size, (2, 16)), jnp.int32)
        _, st = M.prefill(params, {"tokens": toks}, cfg, max_seq=24)
        leaves = [l for l in jax.tree_util.tree_leaves(st.cache)
                  if l.dtype == jnp.bfloat16]
        bits = np.concatenate([
            np.asarray(jax.lax.bitcast_convert_type(l, jnp.uint16)).ravel()
            for l in leaves])
        return cfg, params, st, cbm.calibrate([bits], k=16)

    def test_engine_resend_is_bitwise_identical(self):
        from repro.serving.engine import DisaggregatedEngine
        cfg, params, st, cb = self._setup()
        eng = DisaggregatedEngine(cfg, params, cb, retain_for_failover=True)
        first = eng.transfer(st)
        again = eng.resend_cache(st)
        fa = jax.tree_util.tree_leaves(first.cache)
        fb = jax.tree_util.tree_leaves(again.cache)
        assert all(np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(fa, fb))
        assert eng.stats.failover_resends == 1

    def test_scheduler_failover_triggers_engine_resend(self):
        """The PR-9 gap, closed end to end: a decode-worker kill makes the
        scheduler fire ``on_failover``, which drives a REAL engine-side
        re-send of the cached compressed stream — and the re-sent state is
        bitwise what the dead worker held."""
        from repro.serving.engine import DisaggregatedEngine
        cfg, params, st, cb = self._setup()
        eng = DisaggregatedEngine(cfg, params, cb, retain_for_failover=True)
        baseline = eng.transfer(st)          # what the dead worker held

        resent = []

        def on_failover(req):
            resent.append((req.rid, eng.resend_cache(st)))

        prof = CodecProfile(g_enc=613.3e9, g_dec=2181.8e9, ratio=1.324,
                            link_bw=25e9)
        sched = DisaggregatedScheduler(SchedulerConfig(
            kv_bytes_per_token=2048, profile=prof, compress=True,
            prefill_time_per_token=0.0, decode_time_per_step=1e-3,
            max_prefill_batch=4,
            cluster=ClusterConfig(n_prefill=1, n_decode=2,
                                  links=(LinkSpec(),),
                                  router="transfer-aware"),
            faults=FaultPlan(seed=1, worker_kills=(
                WorkerKill(worker=0, at=5e-3),)),
            heartbeat_timeout_s=1e-3,
            on_failover=on_failover))
        for i in range(4):
            sched.submit(Request(rid=i, arrival=0.0, prompt_len=1024,
                                 max_new_tokens=64))
        done = sched.run()
        assert sched.failovers > 0
        assert resent, "scheduler failover never reached the engine hook"
        assert eng.stats.failover_resends == len(resent)
        for _, state in resent:
            fa = jax.tree_util.tree_leaves(baseline.cache)
            fb = jax.tree_util.tree_leaves(state.cache)
            assert all(np.array_equal(np.asarray(a), np.asarray(b))
                       for a, b in zip(fa, fb))
        assert all(r.state in ("completed", "shed", "failed-over")
                   for r in done)
