"""Pallas kernel sweeps: kernel output must bit-match the pure-jnp oracle
(interpret=True on CPU; same kernels compile to Mosaic on TPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codebook as cbm
from repro.core import codec as core_codec
from repro.kernels import ops, ref, splitzip_decode, splitzip_encode

CODEBOOK = tuple(range(118, 134))  # 16 unique exponents


def _bits(rows, chunk, seed, mode="realistic"):
    rng = np.random.default_rng(seed)
    if mode == "uniform":
        return jnp.asarray(rng.integers(0, 1 << 16, (rows, chunk)).astype(np.uint16))
    x = rng.standard_normal((rows, chunk)) * np.exp(rng.standard_normal((rows, chunk)))
    xb = jnp.asarray(x.astype(np.float32), dtype=jnp.bfloat16)
    return jax.lax.bitcast_convert_type(xb, jnp.uint16)


@pytest.mark.parametrize("rows,chunk,block_rows", [
    (1, 1024, 1),
    (8, 1024, 4),
    (8, 1024, 8),
    (64, 1024, 16),
    (12, 512, 3),
    (4, 2048, 2),
])
@pytest.mark.parametrize("mode", ["realistic", "uniform"])
def test_encode_kernel_matches_ref(rows, chunk, block_rows, mode):
    bits = _bits(rows, chunk, seed=rows * chunk, mode=mode)
    a_k, p_k, m_k = splitzip_encode.encode_dense(
        bits, CODEBOOK, chunk=chunk, block_rows=block_rows)
    a_r, p_r, m_r = ref.encode_dense_ref(bits, CODEBOOK)
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
    np.testing.assert_array_equal(np.asarray(m_k), np.asarray(m_r))


@pytest.mark.parametrize("rows,chunk,block_rows", [
    (1, 1024, 1), (8, 1024, 4), (64, 1024, 16), (12, 512, 3), (4, 2048, 2),
])
def test_decode_kernel_matches_ref(rows, chunk, block_rows):
    bits = _bits(rows, chunk, seed=7 + rows)
    a, p, _ = ref.encode_dense_ref(bits, CODEBOOK)
    d_k = splitzip_decode.decode_dense(p, a, CODEBOOK, chunk=chunk, block_rows=block_rows)
    d_r = ref.decode_dense_ref(p, a, CODEBOOK)
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))


@pytest.mark.parametrize("k", [4, 8, 16])
def test_codebook_size_sweep(k):
    cb = tuple(range(120, 120 + k))
    bits = _bits(8, 1024, seed=k)
    a_k, p_k, m_k = splitzip_encode.encode_dense(bits, cb, block_rows=4)
    a_r, p_r, m_r = ref.encode_dense_ref(bits, cb)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
    np.testing.assert_array_equal(np.asarray(m_k), np.asarray(m_r))


@pytest.mark.parametrize("fmt,seed", [("bf16", 0), ("fp8_e5m2", 1)])
def test_fp8_and_bf16_dense_paths(fmt, seed):
    rng = np.random.default_rng(seed)
    if fmt == "bf16":
        bits = _bits(4, 1024, seed)
        cb = CODEBOOK
    else:
        bits = jnp.asarray(rng.integers(0, 256, (4, 1024)).astype(np.uint8))
        cb = tuple(range(8, 24))  # 16 of the 32 e5m2 exponents
    a_k, p_k, m_k = splitzip_encode.encode_dense(bits, cb, fmt=fmt, block_rows=2)
    a_r, p_r, m_r = ref.encode_dense_ref(bits, cb, fmt=fmt)
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
    d_k = splitzip_decode.decode_dense(p_k, a_k, cb, fmt=fmt, block_rows=2)
    d_r = ref.decode_dense_ref(p_r, a_r, cb, fmt=fmt)
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_r))


class TestOpsEndToEnd:
    def test_ops_equals_core_codec_streams(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.standard_normal(16384).astype(np.float32), dtype=jnp.bfloat16)
        cb = cbm.Codebook(fmt="bf16", exponents=CODEBOOK)
        ct_kernel = ops.encode(x, cb)
        ct_core = core_codec.encode(x, cb)
        for lk, lc in zip(jax.tree.leaves(ct_kernel), jax.tree.leaves(ct_core)):
            np.testing.assert_array_equal(np.asarray(lk), np.asarray(lc))

    def test_ops_roundtrip_bits_exact(self):
        rng = np.random.default_rng(4)
        x = jnp.asarray((rng.standard_normal(40960) * 5).astype(np.float32), dtype=jnp.bfloat16)
        cb = cbm.Codebook(fmt="bf16", exponents=CODEBOOK)
        y = ops.decode(ops.encode(x, cb, cap=1024))
        xb = jax.lax.bitcast_convert_type(x, jnp.uint16)
        yb = jax.lax.bitcast_convert_type(y, jnp.uint16)
        assert bool(jnp.all(xb == yb))

    def test_lowers_for_tpu_without_execution(self):
        """Kernels must lower (interpret=False) even though we can't run them
        on CPU — this is the TPU-targeting proof for the codec path."""
        cb = cbm.Codebook(fmt="bf16", exponents=CODEBOOK)
        bits = jax.ShapeDtypeStruct((64, 1024), jnp.uint16)
        try:
            lowered = jax.jit(
                lambda b: splitzip_encode.encode_dense(
                    b, cb.exponents, interpret=False)
            ).lower(bits)
            assert "custom_call" in lowered.as_text() or "tpu" in lowered.as_text().lower()
        except Exception:
            pytest.skip("pallas TPU lowering unavailable on this backend")
