"""Unit + property tests for the in-graph SplitZip codec (bit-exactness)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codebook as cbm
from repro.core import codec


def bits_of(x):
    return jax.lax.bitcast_convert_type(x, jnp.uint16)


def make_bf16(n, seed=0, scale_spread=1.0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n) * np.exp(scale_spread * rng.standard_normal(n))
    return jnp.asarray(x.astype(np.float32), dtype=jnp.bfloat16)


@pytest.fixture(scope="module")
def calib_codebook():
    x = make_bf16(1 << 16, seed=42)
    return cbm.calibrate([np.asarray(bits_of(x))], k=16)


class TestRoundtrip:
    @pytest.mark.parametrize("n", [1024, 4096, 100_000, 1 << 20])
    def test_roundtrip_bits_exact(self, calib_codebook, n):
        x = make_bf16(n, seed=n)
        ct = codec.encode(x, calib_codebook)
        y = codec.decode(ct)
        assert bool(jnp.all(bits_of(x) == bits_of(y)))

    @pytest.mark.parametrize("shape", [(32, 32), (4, 8, 64), (2, 3, 5, 64)])
    def test_nd_shapes(self, calib_codebook, shape):
        x = make_bf16(int(np.prod(shape))).reshape(shape)
        y = codec.decode(codec.encode(x, calib_codebook))
        assert y.shape == shape
        assert bool(jnp.all(bits_of(x) == bits_of(y)))

    def test_non_chunk_multiple_length(self, calib_codebook):
        x = make_bf16(1024 + 333)
        y = codec.decode(codec.encode(x, calib_codebook))
        assert bool(jnp.all(bits_of(x) == bits_of(y)))

    def test_special_values(self, calib_codebook):
        # NaN (quiet + payload), ±Inf, ±0, subnormals, max/min
        patterns = np.array(
            [0x7FC0, 0x7FC1, 0xFFC0, 0x7F80, 0xFF80, 0x0000, 0x8000,
             0x0001, 0x8001, 0x7F7F, 0xFF7F, 0x0080, 0xFFFF, 0x7FFF],
            dtype=np.uint16,
        )
        bits = jnp.asarray(np.tile(patterns, 100))
        x = jax.lax.bitcast_convert_type(bits, jnp.bfloat16)
        ct = codec.encode(x, calib_codebook, cap=1024)
        y = codec.decode(ct)
        assert bool(jnp.all(bits_of(x) == bits_of(y)))

    def test_all_escape_input_with_capacity(self, calib_codebook):
        # every element escapes; capacity == chunk keeps it lossless
        esc_exp = next(e for e in range(256) if e not in calib_codebook.exponents)
        bits = jnp.full((2048,), np.uint16(esc_exp << 7), dtype=jnp.uint16)
        x = jax.lax.bitcast_convert_type(bits, jnp.bfloat16)
        ct = codec.encode(x, calib_codebook, cap=1024)
        assert bool(ct.ok)
        assert bool(jnp.all(bits_of(x) == bits_of(codec.decode(ct))))

    def test_overflow_flag_set(self, calib_codebook):
        esc_exp = next(e for e in range(256) if e not in calib_codebook.exponents)
        bits = jnp.full((2048,), np.uint16(esc_exp << 7), dtype=jnp.uint16)
        x = jax.lax.bitcast_convert_type(bits, jnp.bfloat16)
        ct = codec.encode(x, calib_codebook, cap=8)
        assert not bool(ct.ok)  # transfer engine must fall back to raw

    def test_jit_roundtrip(self, calib_codebook):
        enc = jax.jit(lambda x: codec.encode(x, calib_codebook))
        dec = jax.jit(codec.decode)
        x = make_bf16(8192)
        assert bool(jnp.all(bits_of(x) == bits_of(dec(enc(x)))))


@pytest.mark.parametrize("seed", range(25))
def test_arbitrary_u16_patterns(seed):
    """Seeded stand-in for the former hypothesis property test: ANY u16 bit
    pattern roundtrips bit-exactly (cap == chunk so capacity never
    overflows).  Uniform random bits are near-worst-case for the codebook."""
    cb = cbm.Codebook(fmt="bf16", exponents=tuple(range(120, 136)))
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 600))
    bits = jnp.asarray(rng.integers(0, 1 << 16, n).astype(np.uint16))
    x = jax.lax.bitcast_convert_type(bits, jnp.bfloat16)
    ct = codec.encode(x, cb, chunk=256, cap=256)
    y = codec.decode(ct)
    assert bool(jnp.all(bits == bits_of(y)))


@pytest.mark.parametrize("seed", range(15))
def test_ratio_formula(seed):
    """compressed_bytes matches the paper's B = N(3/2) + 3M exactly."""
    cb = cbm.Codebook(fmt="bf16", exponents=tuple(range(120, 136)))
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 2000))
    bits = jnp.asarray(rng.integers(0, 1 << 16, n).astype(np.uint16))
    x = jax.lax.bitcast_convert_type(bits, jnp.bfloat16)
    ct = codec.encode(x, cb, chunk=256, cap=256)
    m = int(jnp.sum(ct.esc_count))
    expected = n * 1.5 + 3 * m
    assert float(codec.compressed_bytes(ct)) == pytest.approx(expected)


class TestSentinelVariant:
    def test_roundtrip(self, calib_codebook):
        x = make_bf16(4096, seed=7)
        st_ = codec.encode_sentinel(x, calib_codebook)
        y = codec.decode_sentinel(st_)
        assert bool(jnp.all(bits_of(x) == bits_of(y)))

    def test_metadata_smaller_than_explicit(self, calib_codebook):
        # paper Table 6: sentinel ratio slightly higher (1.331 vs 1.324)
        x = make_bf16(1 << 17, seed=9, scale_spread=2.0)
        ct = codec.encode(x, calib_codebook)
        st_ = codec.encode_sentinel(x, calib_codebook)
        if int(jnp.sum(st_.esc_count)) > 0:
            assert float(codec.sentinel_bytes(st_)) <= float(codec.compressed_bytes(ct))


class TestDynamicCodebook:
    def test_roundtrip_and_matches_offline_on_calib_data(self):
        x = make_bf16(1 << 15, seed=11)
        streams, dcb = codec.encode_with_dynamic_codebook(x)
        y = codec.decode_with_dynamic_codebook(streams, dcb, x.shape, "bfloat16")
        assert bool(jnp.all(bits_of(x) == bits_of(y)))
        # dynamic top-16 covers the data exactly as well as an offline calib
        # on the same data *without* the ensure_zero production tweak
        # (sets may differ only on tied counts, so compare coverage not sets)
        offline = cbm.calibrate([np.asarray(bits_of(x))], k=16, ensure_zero=False)
        hist = cbm.exponent_histogram(np.asarray(bits_of(x)))
        cov_dyn = hist[np.asarray(dcb)].sum() / hist.sum()
        cov_off = hist[list(offline.exponents)].sum() / hist.sum()
        assert cov_dyn == pytest.approx(cov_off, abs=1e-9)
        # and at least as well as the deployed (ensure_zero) codebook
        deployed = cbm.calibrate([np.asarray(bits_of(x))], k=16)
        cov_dep = hist[list(deployed.exponents)].sum() / hist.sum()
        assert cov_dyn >= cov_dep - 1e-9


class TestGlobalLayout:
    """Two-level (global) escape compaction — beyond-paper in-graph layout."""

    @pytest.mark.parametrize("n", [1024, 4096, 1024 + 333, 1 << 17])
    def test_roundtrip_bits_exact(self, calib_codebook, n):
        # heavy-tailed data => give explicit capacity (the engine's fallback
        # path covers the ok=False case; see test_overflow_flag)
        x = make_bf16(n, seed=n + 1, scale_spread=2.0)
        ct = codec.encode(x, calib_codebook, layout="global", cap=n)
        assert ct.layout == "global"
        assert bool(ct.ok)
        assert bool(jnp.all(bits_of(x) == bits_of(codec.decode(ct))))

    def test_default_budget_covers_calib_like_data(self, calib_codebook):
        # data matching the calibration distribution stays within the 1%
        # default budget (paper's measured escape rate: 0.16%)
        x = make_bf16(1 << 17, seed=11)
        ct = codec.encode(x, calib_codebook, layout="global")
        assert bool(ct.ok)
        assert bool(jnp.all(bits_of(x) == bits_of(codec.decode(ct))))

    def test_matches_chunked_decode(self, calib_codebook):
        n = 1 << 15
        x = make_bf16(n, seed=3, scale_spread=3.0)
        yc = codec.decode(codec.encode(x, calib_codebook, cap=1024))
        yg = codec.decode(codec.encode(x, calib_codebook, layout="global",
                                       cap=n))
        assert bool(jnp.all(bits_of(yc) == bits_of(yg)))

    def test_static_stream_bytes_smaller(self, calib_codebook):
        # the whole point: in-graph streams (what collectives actually move)
        # shrink vs the per-chunk layout at equal-or-better overflow safety
        x = make_bf16(1 << 18, seed=5)
        c = codec.encode(x, calib_codebook, chunk=1024, cap=64)
        g = codec.encode(x, calib_codebook, layout="global")
        assert codec.static_stream_bytes(g) < codec.static_stream_bytes(c)
        # and within ~3% of the analytic variable-length size
        assert codec.static_stream_bytes(g) < 1.03 * float(
            codec.compressed_bytes(g)) + 64

    def test_overflow_flag(self, calib_codebook):
        esc_exp = next(e for e in range(256)
                       if e not in calib_codebook.exponents)
        bits = jnp.full((1 << 15,), np.uint16(esc_exp << 7), dtype=jnp.uint16)
        x = jax.lax.bitcast_convert_type(bits, jnp.bfloat16)
        ct = codec.encode(x, calib_codebook, layout="global")
        assert not bool(ct.ok)
        # with enough capacity it stays lossless
        ct2 = codec.encode(x, calib_codebook, layout="global", cap=1 << 15)
        assert bool(ct2.ok)
        assert bool(jnp.all(bits == bits_of(codec.decode(ct2))))

    def test_jit_roundtrip(self, calib_codebook):
        enc = jax.jit(lambda x: codec.encode(x, calib_codebook,
                                             layout="global"))
        x = make_bf16(8192, seed=9)
        assert bool(jnp.all(bits_of(x) == bits_of(codec.decode(enc(x)))))


@pytest.mark.parametrize("seed", range(20))
def test_global_layout_arbitrary_u16(seed):
    """Seeded stand-in for the former hypothesis property test: the global
    layout roundtrips ANY u16 pattern when capacity covers the worst case
    (cap == n)."""
    cb = cbm.Codebook(fmt="bf16", exponents=tuple(range(120, 136)))
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 600))
    bits = jnp.asarray(rng.integers(0, 1 << 16, n).astype(np.uint16))
    x = jax.lax.bitcast_convert_type(bits, jnp.bfloat16)
    ct = codec.encode(x, cb, chunk=256, cap=max(256, n), layout="global")
    assert bool(jnp.all(bits == bits_of(codec.decode(ct))))


class TestTheory:
    def test_rho_limit(self):
        assert codec.theoretical_ratio("bf16", 16, 0.0) == pytest.approx(4 / 3)

    def test_rho_formula_matches_paper(self):
        # paper: rho = 2 / (3/2 + 3*eps)
        for eps in [0.0, 0.0016, 0.0789]:
            assert codec.theoretical_ratio("bf16", 16, eps) == pytest.approx(
                2 / (1.5 + 3 * eps)
            )

    def test_top8_worse_when_escapes_explode(self):
        # paper Table 3: top-8 ratio 1.038 < top-16 1.324 because eps jumps
        assert codec.theoretical_ratio("bf16", 8, 0.0789) < codec.theoretical_ratio(
            "bf16", 16, 0.0016
        )
