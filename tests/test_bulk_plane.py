"""The bulk-data plane (multi_layer_refactor acceptance): checkpoint,
elastic resharding, resilient training, and gradient compression all ride
TransferPlan/TransferSession — persistent executor (save/load SZ02 frames +
manifest), collective executor (compressed ring all-reduce), reshard hop,
and the consumer seams: corrupt-frame fallback is bit-exact, ring gradients
match jnp.mean bitwise, reshard round-trips a train state, and recovery
surfaces non-zero TransferStats.refetches under injected faults."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.smollm_135m import CONFIG as SMOLLM
from repro.core import codebook as cbm
from repro.core.profile import PAPER_RATIO
from repro.core.wire import WireIntegrityError
from repro.distributed import checkpoint as CKPT
from repro.distributed import elastic as EL
from repro.distributed.fault_tolerance import FaultConfig, ResilientTrainer
from repro.serving.faults import FaultPlan
from repro.serving.plan import TransferConfig, TransferPlan
from repro.training import grad_compress as GC


def _train_state(seed=0):
    """bf16 params + fp32 optimizer moments + int step: all three persistent
    routes in one pytree."""
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(96, 64)), jnp.bfloat16),
                   "tiny": jnp.asarray(rng.normal(size=(4,)), jnp.bfloat16)},
        "opt": {"m": jnp.asarray(rng.normal(size=(96, 64)), jnp.float32)},
        "step": jnp.asarray(11, jnp.int32),
    }


def _assert_bit_identical(a_tree, b_tree):
    for a, b in zip(jax.tree.leaves(a_tree), jax.tree.leaves(b_tree)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
        assert a.dtype == b.dtype and a.shape == b.shape


def _subprocess_env():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(src) + os.pathsep + env.get(
        "PYTHONPATH", "")
    return env


# ---------------------------------------------------------------------------
# persistent executor: session.save / session.load
# ---------------------------------------------------------------------------

class TestPersistentExecutor:
    def test_roundtrip_all_routes_bit_exact(self, tmp_path):
        state = _train_state()
        tc = TransferConfig(codebook=CKPT.CKPT_CODEBOOK, backend="wire",
                            compress_fp32=True, min_compress_elems=64)
        sess = TransferPlan.build(state, tc).session()
        sess.save(str(tmp_path / "ck"), state, extra={"note": "x"})
        tree, extra = sess.load(str(tmp_path / "ck"))
        _assert_bit_identical(tree, state)
        assert extra == {"note": "x"}
        s = sess.last_stats
        # routes: w -> splitzip stream, m -> fp32 hi/lo, tiny -> raw (below
        # min_compress_elems), step -> raw
        assert s.leaf_ok.get("params/w") is True
        assert s.fp32_lo_wire_bytes > 0
        assert s.raw_passthrough_bytes > 0

    def test_min_compress_elems_routes_small_leaves_raw(self):
        state = _train_state()
        tc = TransferConfig(codebook=CKPT.CKPT_CODEBOOK, backend="wire",
                            min_compress_elems=64)
        plan = TransferPlan.build(state, tc)
        routes = {r.key: r.route for r in plan.routes}
        assert routes["params/tiny"] == "raw"      # 4 elems < 64
        assert routes["params/w"] == "splitzip"

    def test_corrupt_frame_raises_and_publishes_stats(self, tmp_path):
        state = _train_state()
        tc = TransferConfig(codebook=CKPT.CKPT_CODEBOOK, backend="wire",
                            compress_fp32=True)
        sess = TransferPlan.build(state, tc).session()
        path = sess.save(str(tmp_path / "ck"), state)
        fname = max((f for f in os.listdir(path) if f.endswith(".szc")),
                    key=lambda f: os.path.getsize(os.path.join(path, f)))
        fpath = os.path.join(path, fname)
        blob = bytearray(open(fpath, "rb").read())
        blob[len(blob) // 2] ^= 0x55
        open(fpath, "wb").write(bytes(blob))
        with pytest.raises(WireIntegrityError):
            sess.load(path)
        # the abandoned load still accounts: the fallback policy upstream
        # (distributed/checkpoint.py) aggregates these
        assert sess.last_stats.verify_failures > 0
        assert False in sess.last_stats.leaf_ok.values()

    def test_injected_wire_faults_heal_via_refetch(self, tmp_path):
        state = _train_state()
        tc = TransferConfig(codebook=CKPT.CKPT_CODEBOOK, backend="wire",
                            compress_fp32=True)
        sess = TransferPlan.build(state, tc).session(
            faults=FaultPlan(corrupt_chunks=(0,), persistent_attempts=1))
        sess.save(str(tmp_path / "ck"), state)
        tree, _ = sess.load(str(tmp_path / "ck"))
        _assert_bit_identical(tree, state)
        assert sess.last_stats.refetches > 0
        assert sess.last_stats.faults_injected > 0


# ---------------------------------------------------------------------------
# checkpoint seam: corrupt one frame -> falls back to previous step bit-exactly
# ---------------------------------------------------------------------------

class TestCheckpointFallback:
    def test_corrupt_checkpoint_falls_back_bit_exactly(self, tmp_path):
        d = str(tmp_path)
        good, bad = _train_state(seed=1), _train_state(seed=2)
        ck = CKPT.Checkpointer(d)
        ck.save(10, good, extra={"arch": "a"})
        ck.save(20, bad)
        target = os.path.join(d, "step_0000000020")
        fname = max((f for f in os.listdir(target) if f.endswith(".szc")),
                    key=lambda f: os.path.getsize(os.path.join(target, f)))
        fpath = os.path.join(target, fname)
        blob = bytearray(open(fpath, "rb").read())
        blob[len(blob) // 2] ^= 0xFF
        open(fpath, "wb").write(bytes(blob))
        tree, extra, step = ck.restore(good)
        assert step == 10 and extra == {"arch": "a"}
        _assert_bit_identical(tree, good)
        # the abandoned candidate's verify failures surface on the manager
        assert ck.stats.verify_failures > 0

    def test_all_candidates_corrupt_raises(self, tmp_path):
        d = str(tmp_path)
        state = _train_state()
        ck = CKPT.Checkpointer(d)
        ck.save(5, state)
        target = os.path.join(d, "step_0000000005")
        for f in os.listdir(target):
            if f.endswith(".szc"):
                open(os.path.join(target, f), "wb").write(b"junk")
        with pytest.raises(CKPT.CheckpointCorrupt):
            ck.restore(state)

    def test_module_level_api_roundtrip(self, tmp_path):
        d = str(tmp_path)
        state = _train_state(seed=3)
        CKPT.save(d, 1, state)
        CKPT.save(d, 2, state, extra={"k": 1})
        assert CKPT.steps_available(d) == [1, 2]
        assert CKPT.latest_step(d) == 2
        assert CKPT.checkpoint_bytes(d, 2) > 0
        tree, extra, step = CKPT.restore(d, state)
        assert step == 2 and extra == {"k": 1}
        _assert_bit_identical(tree, state)


# ---------------------------------------------------------------------------
# resilient-training seam: recovery is verified AND accounted
# ---------------------------------------------------------------------------

class TestResilientTrainerStats:
    def test_recovery_surfaces_refetches_under_faultplan(self, tmp_path):
        ck = CKPT.Checkpointer(
            str(tmp_path),
            faults=FaultPlan(corrupt_chunks=(0,), persistent_attempts=1))

        def step_fn(state, step):
            return jax.tree.map(lambda x: x + 1, state), {"loss": float(step)}

        fired = set()

        def faults(step):
            if step in {7, 12} and step not in fired:
                fired.add(step)
                return "crash"
            return None

        tr = ResilientTrainer(
            step_fn, cfg=FaultConfig(max_restarts=4, checkpoint_every=5),
            fault_source=faults, checkpointer=ck)
        rep = tr.run({"w": jnp.zeros((64, 64), jnp.bfloat16)}, 20)
        assert rep.steps_completed == 20 and rep.restarts == 2
        assert rep.transfer_stats is not None
        assert rep.transfer_stats.refetches > 0
        assert rep.transfer_stats.verify_failures > 0
        assert rep.transfer_stats.wire_bytes > 0

    def test_closure_api_unchanged(self):
        saves = []
        state0 = {"w": 0}

        def step_fn(state, step):
            return state, {"loss": 0.0}

        tr = ResilientTrainer(step_fn, lambda s, st: saves.append(s),
                              lambda: (state0, 0),
                              FaultConfig(max_restarts=4, checkpoint_every=5))
        rep = tr.run(state0, 6)
        assert rep.steps_completed == 6
        assert rep.transfer_stats is None
        assert saves == [5, 6]


# ---------------------------------------------------------------------------
# elastic seam: legal_meshes divisibility + reshard round-trip
# ---------------------------------------------------------------------------

class TestLegalMeshes:
    def test_rejects_dp_exceeding_global_batch(self):
        """Regression: global_batch=4 on 8 chips must not admit dp=8 (zero
        per-replica batch).  Every surviving mesh has a non-empty, equal
        per-replica slice."""
        shape = ShapeConfig(name="t", seq_len=128, global_batch=4,
                            kind="train")
        plans = EL.legal_meshes(8, SMOLLM, shape)
        assert plans, "some legal mesh must survive (model-parallel splits)"
        for p in plans:
            dp = p.shape[0]
            assert shape.global_batch % dp == 0
            assert dp <= shape.global_batch
        assert (8, 1) not in {p.shape for p in plans}

    def test_multi_pod_divisibility(self):
        shape = ShapeConfig(name="t", seq_len=128, global_batch=4,
                            kind="train")
        for p in EL.legal_meshes(8, SMOLLM, shape, multi_pod=True, n_pods=2):
            dp = p.shape[0] * p.shape[1]       # pod * data
            assert shape.global_batch % dp == 0


RESHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, jax.numpy as jnp, numpy as np
from repro.distributed import elastic as EL

rng = np.random.default_rng(3)
state = {"params": {"w": jnp.asarray(rng.normal(size=(256, 64)), jnp.bfloat16)},
         "opt": {"m": jnp.asarray(rng.normal(size=(256, 64)), jnp.float32)},
         "step": jnp.asarray(7, jnp.int32)}
old = EL.MeshPlan((4, 2), ("data", "model"), 0.0)
new = EL.MeshPlan((2, 2), ("data", "model"), 0.0)
out, stats = EL.reshard(state, old, new)
assert stats.wire_bytes > 0 and all(stats.leaf_ok.values())
back, _ = EL.reshard(out, new, old)
for t in (out, back):
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(state)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
mesh_axes = dict(jax.tree.leaves(out)[0].sharding.mesh.shape)
assert mesh_axes == {"data": 2, "model": 2}, mesh_axes
print("RESHARD-OK")
"""


class TestReshard:
    def test_round_trip_across_mesh_plans_subprocess(self):
        """Acceptance: a train state ships (4,2) -> (2,2) -> (4,2) through
        the bulk-data plane bit-exactly, landing on the new mesh.  Own
        process: the device-count override must precede jax init."""
        out = subprocess.run([sys.executable, "-c", RESHARD_SCRIPT],
                             capture_output=True, text=True,
                             env=_subprocess_env(), timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "RESHARD-OK" in out.stdout

    def test_rejects_oversized_mesh(self):
        state = {"w": jnp.zeros((8,), jnp.bfloat16)}
        big = EL.MeshPlan((64, 64), ("data", "model"), 0.0)
        with pytest.raises(ValueError, match="devices"):
            EL.reshard(state, None, big)


# ---------------------------------------------------------------------------
# gradient seam: ring_reduce == jnp.mean bitwise; plan-derived wire bytes
# ---------------------------------------------------------------------------

RING_PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.training import grad_compress as GC

mesh = make_mesh((4,), ("pod",))
rng = np.random.default_rng(7)
# small-integer bf16 values: fp32 ring sums are exact in any hop order, so
# the mean is bitwise order-independent and comparable to jnp.mean
grads = {"w": jnp.asarray(rng.integers(-8, 8, size=(4, 128, 40)), jnp.bfloat16),
         "b": jnp.asarray(rng.integers(-8, 8, size=(4, 48)), jnp.bfloat16),
         "big": jnp.asarray(rng.integers(-4, 4, size=(4, 65536)), jnp.bfloat16)}
ref = jax.tree.map(lambda g: jnp.mean(g.astype(jnp.float32), axis=0)
                   .astype(g.dtype), grads)
cb = GC.calibrate_on_grads(jax.tree.map(lambda g: g[0], grads))
for kwargs in ({"compress": False}, {"codebook": cb}):
    out = GC.compressed_cross_pod_mean(grads, mesh, **kwargs)
    for k in ref:
        assert np.asarray(out[k]).tobytes() == np.asarray(ref[k]).tobytes(), k
s = GC.last_stats          # stats of the calibrated/compressed exchange
assert s is not None and s.wire_bytes > 0
# only 'big' clears MIN_COMPRESS_ELEMS per participant; it rode compressed
assert s.leaf_ok == {"big": True}, s.leaf_ok
print("RING-PARITY-OK")
"""


class TestGradRing:
    def test_ring_reduce_matches_mean_bitwise_subprocess(self):
        """Acceptance: compressed ring all-reduce over 4 pods equals the
        jnp.mean all-reduce bitwise (compressed AND raw routes), with
        TransferStats surfaced."""
        out = subprocess.run([sys.executable, "-c", RING_PARITY_SCRIPT],
                             capture_output=True, text=True,
                             env=_subprocess_env(), timeout=600)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "RING-PARITY-OK" in out.stdout

    def test_cross_pod_wire_bytes_plan_derived(self):
        rng = np.random.default_rng(0)
        grads = {"w": jnp.asarray(rng.normal(size=(512, 64)), jnp.bfloat16),
                 "tiny": jnp.asarray(rng.normal(size=(8,)), jnp.bfloat16)}
        total = sum(g.size * 2 for g in jax.tree.leaves(grads))
        raw = GC.cross_pod_wire_bytes(grads, n_pod=3, compress=False)
        assert raw == pytest.approx(total * 2)          # 2 hops, no ratio
        est = GC.cross_pod_wire_bytes(grads, n_pod=3)
        # big leaf at the profile ratio, tiny leaf raw (route threshold)
        expected = (512 * 64 * 2 / PAPER_RATIO + 8 * 2) * 2
        assert est == pytest.approx(expected)
        assert est < raw
