"""Fault-tolerant transfer plane: detector semantics, trainer restart
budget, wire-integrity recovery, failover accounting, and overload shedding
(ISSUE 7).  Everything runs on CPU from seeded fault plans."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codebook as cbm
from repro.core import wire
from repro.core.pipeline import CodecProfile
from repro.distributed.fault_tolerance import (FailureDetector, FaultConfig,
                                               ResilientTrainer)
from repro.serving.cluster import ClusterConfig, LinkSpec
from repro.serving.faults import (FaultChannel, FaultPlan, LinkBrownout,
                                  WorkerKill, available_fault_plans,
                                  get_fault_plan, resolve_faults)
from repro.serving.plan import TransferConfig, TransferPlan
from repro.serving.scheduler import (DisaggregatedScheduler, Request,
                                     SchedulerConfig, summarize)
from repro.serving.session import TransferIntegrityError


# ---------------------------------------------------------------------------
# FailureDetector: pure detection vs transition, revival
# ---------------------------------------------------------------------------

class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _detector(n=3, timeout=1.0):
    clk = _Clock()
    det = FailureDetector(n, FaultConfig(heartbeat_timeout_s=timeout),
                          clock=clk)
    return det, clk


def test_timed_out_is_pure():
    det, clk = _detector()
    clk.t = 2.0
    assert det.timed_out() == [0, 1, 2]
    # repeated PURE detection agrees — no state was mutated
    assert det.timed_out() == [0, 1, 2]
    assert det.alive_count() == 3


def test_newly_dead_reports_each_death_once():
    det, clk = _detector()
    clk.t = 2.0
    assert det.newly_dead() == [0, 1, 2]
    assert det.newly_dead() == []          # transition happened exactly once
    assert det.alive_count() == 0


def test_dead_workers_is_idempotent():
    """The historical bug: dead_workers() mutated ``alive`` during detection,
    so a second poll within one timeout window returned [] and callers
    believed the fleet had healed."""
    det, clk = _detector()
    clk.t = 2.0
    assert det.dead_workers() == [0, 1, 2]
    assert det.dead_workers() == [0, 1, 2]   # still dead on the second poll


def test_revival_on_renewed_heartbeat():
    det, clk = _detector()
    clk.t = 2.0
    assert det.newly_dead() == [0, 1, 2]
    clk.t = 2.5
    det.heartbeat(1)
    assert det.alive_count() == 1
    assert det.dead_workers() == [0, 2]
    # the revived worker can die AGAIN and is reported again
    clk.t = 5.0
    assert det.newly_dead() == [1]


def test_partial_timeouts():
    det, clk = _detector()
    clk.t = 0.9
    det.heartbeat(2)
    clk.t = 1.5
    assert det.timed_out() == [0, 1]
    assert det.dead_workers() == [0, 1]


def test_straggler_detection():
    det, clk = _detector()
    for _ in range(6):
        det.heartbeat(0, step_time=1.0)
        det.heartbeat(1, step_time=1.0)
        det.heartbeat(2, step_time=5.0)      # 5x the median -> straggler
    assert det.stragglers() == [2]


# ---------------------------------------------------------------------------
# ResilientTrainer: crash-restart budget, checkpoint cadence
# ---------------------------------------------------------------------------

def _trainer(fault_source, cfg=None, saves=None):
    saves = saves if saves is not None else []
    ckpt = {"state": 0, "step": 0}

    def step_fn(state, step):
        return state + 1, {"loss": float(step)}

    def save_fn(step, state):
        saves.append(step)
        ckpt["state"], ckpt["step"] = state, step

    def restore_fn():
        return ckpt["state"], ckpt["step"]

    cfg = cfg or FaultConfig(max_restarts=4, checkpoint_every=5)
    return ResilientTrainer(step_fn, save_fn, restore_fn, cfg,
                            fault_source=fault_source), saves


def test_trainer_recovers_from_crashes():
    crash_at = {7, 12}
    fired = set()

    def faults(step):
        if step in crash_at and step not in fired:
            fired.add(step)
            return "crash"
        return None

    trainer, saves = _trainer(faults)
    report = trainer.run(0, 20)
    assert report.steps_completed == 20
    assert report.restarts == 2
    assert report.failures_seen == 2


def test_trainer_restart_budget_exhausts_loudly():
    trainer, _ = _trainer(lambda s: "crash" if s == 3 else None,
                          cfg=FaultConfig(max_restarts=2, checkpoint_every=5))
    # the crash repeats forever (restore lands before step 3 every time):
    # the budget must trip instead of looping silently
    with pytest.raises(RuntimeError, match="restart budget"):
        trainer.run(0, 10)


def test_trainer_checkpoint_cadence():
    trainer, saves = _trainer(lambda s: None,
                              cfg=FaultConfig(checkpoint_every=4))
    trainer.run(0, 10)
    assert saves == [4, 8, 10]     # every 4 steps plus the final step


def test_trainer_straggler_mitigation_counts():
    trainer, _ = _trainer(lambda s: "straggler:2" if s in (1, 5) else None)
    report = trainer.run(0, 8)
    assert report.stragglers_mitigated == 2
    assert report.steps_completed == 8


# ---------------------------------------------------------------------------
# FaultPlan: determinism, channel framing
# ---------------------------------------------------------------------------

def test_fault_plan_is_deterministic_and_order_independent():
    plan = FaultPlan(seed=42, corrupt_p=0.3, drop_p=0.2)
    coords = [(u, c, a) for u in range(3) for c in range(8) for a in range(3)]
    ref = {x: plan.chunk_fault(*x) for x in coords}
    # same draws in any evaluation order, and from a fresh equal-seed plan
    for x in reversed(coords):
        assert plan.chunk_fault(*x) == ref[x]
        assert FaultPlan(seed=42, corrupt_p=0.3, drop_p=0.2).chunk_fault(*x) \
            == ref[x]
    # a different seed gives a different fault pattern
    other = {x: FaultPlan(seed=43, corrupt_p=0.3, drop_p=0.2).chunk_fault(*x)
             for x in coords}
    assert other != ref


def test_fault_plan_attempt_rerolls_and_caps():
    plan = FaultPlan(seed=1, corrupt_p=0.5)
    faults = [plan.chunk_fault(0, 0, a) for a in range(plan.max_attempt)]
    assert any(f is None for f in faults)        # re-rolls eventually clear
    # randomized faults stop at max_attempt: the terminal raw re-fetch of an
    # adversarial-rate plan can always land
    assert plan.chunk_fault(0, 0, plan.max_attempt) is None


def test_explicit_chunk_faults_clear_after_persistent_attempts():
    plan = FaultPlan(seed=0, corrupt_chunks=(2,), persistent_attempts=2)
    assert plan.chunk_fault(0, 2, 0) == "corrupt"
    assert plan.chunk_fault(0, 2, 1) == "corrupt"
    assert plan.chunk_fault(0, 2, 2) is None


def test_brownout_wall_clock_integration():
    plan = FaultPlan(brownouts=(LinkBrownout(start=1.0, stop=2.0, factor=0.5),))
    # 1s of nominal link time dispatched at t=0.5: 0.5s at full rate, the
    # remaining 0.5s of work at half rate -> done at 0.5 + 0.5 + 1.0
    assert plan.link_wall_clock(0.5, 1.0) == pytest.approx(2.0)
    # entirely outside the brownout: unchanged
    assert plan.link_wall_clock(3.0, 1.0) == pytest.approx(4.0)
    # rate at a point in/out of the interval
    assert plan.link_rate(1.5) == 0.5 and plan.link_rate(2.5) == 1.0


def test_fault_registry_mirrors_backend_registry():
    assert "chaos" in available_fault_plans()
    assert isinstance(get_fault_plan("chaos"), FaultPlan)
    assert resolve_faults(None) is None
    assert resolve_faults("chaos").worker_kills
    p = FaultPlan(seed=5)
    assert resolve_faults(p) is p
    with pytest.raises(KeyError):
        get_fault_plan("nope")


def test_channel_checksum_catches_injected_corruption():
    from repro.core.backend import get_backend
    be = get_backend("wire")
    bits = np.random.default_rng(0).integers(0, 1 << 16, 4096).astype(np.uint16)
    cb = cbm.calibrate([bits], k=16)
    comp = be.encode(jax.lax.bitcast_convert_type(jnp.asarray(bits),
                                                  jnp.bfloat16), cb)
    ch = FaultChannel(be.checksum, FaultPlan(seed=3, corrupt_chunks=(0,)))
    frame = ch.ship(comp, uid=0, chunk=0, attempt=0)
    _, intact = ch.deliver(frame)
    assert not intact and ch.injected == 1
    # re-ship past the persistent window: intact, and the payload survives
    frame2 = ch.ship(comp, uid=0, chunk=0, attempt=1)
    payload2, intact2 = ch.deliver(frame2)
    assert intact2
    assert np.array_equal(wire.decode(payload2.payload), bits)


# ---------------------------------------------------------------------------
# session-level wire integrity (the tentpole's recovery guarantee)
# ---------------------------------------------------------------------------

def _bf16(shape, seed):
    r = np.random.default_rng(seed)
    x = (r.standard_normal(shape) * np.exp(r.standard_normal(shape)))
    return jnp.asarray(x.astype(np.float32)).astype(jnp.bfloat16)


@pytest.fixture(scope="module")
def small_cache():
    cache = {"k": _bf16((2, 32, 64), 1), "v": _bf16((2, 32, 64), 2),
             "scale": jnp.ones((2,), jnp.float32)}
    bits = np.asarray(jax.lax.bitcast_convert_type(cache["k"],
                                                   jnp.uint16)).ravel()
    return cache, cbm.calibrate([bits], k=16)


def _assert_cache_equal(out, cache):
    for k in cache:
        assert np.array_equal(np.asarray(out[k]), np.asarray(cache[k])), k


@pytest.mark.parametrize("n_chunks", [1, 4])
def test_corrupted_chunk_recovers_bit_identical(small_cache, n_chunks):
    """The acceptance property: a corrupted chunk is detected, re-fetched,
    and the decoded KV is bit-identical to the fault-free transfer."""
    cache, cb = small_cache
    plan = TransferPlan.build(cache, TransferConfig(codebook=cb,
                                                    n_chunks=n_chunks))
    sess = plan.session(verify=True,
                        faults=FaultPlan(seed=3, corrupt_chunks=(0,)))
    out = sess.transfer(cache)
    _assert_cache_equal(out, cache)
    st = sess.last_stats
    assert st.verify_failures >= 1 and st.refetches >= 1
    assert st.faults_injected >= 1
    assert st.refetch_wire_bytes > 0


def test_unverified_corruption_flows_through(small_cache):
    """Without verify the corruption decodes to garbage — the exact hazard
    the checksum frame exists to close."""
    cache, cb = small_cache
    plan = TransferPlan.build(cache, TransferConfig(codebook=cb, n_chunks=4))
    sess = plan.session(faults=FaultPlan(seed=3, corrupt_chunks=(1,)))
    out = sess.transfer(cache)
    assert any(not np.array_equal(np.asarray(out[k]), np.asarray(cache[k]))
               for k in cache)
    assert sess._channel.injected >= 1


def test_drop_and_corrupt_recover_under_random_faults(small_cache):
    cache, cb = small_cache
    plan = TransferPlan.build(cache, TransferConfig(codebook=cb, n_chunks=4))
    sess = plan.session(verify=True,
                        faults=FaultPlan(seed=9, corrupt_p=0.3, drop_p=0.1))
    for _ in range(3):                      # several transfers, same session
        _assert_cache_equal(sess.transfer(cache), cache)


def test_seeded_session_faults_are_deterministic(small_cache):
    cache, cb = small_cache
    plan = TransferPlan.build(cache, TransferConfig(codebook=cb, n_chunks=4))
    mk = lambda: plan.session(verify=True,
                              faults=FaultPlan(seed=9, corrupt_p=0.3,
                                               drop_p=0.1))
    a, b = mk(), mk()
    oa, ob = a.transfer(cache), b.transfer(cache)
    _assert_cache_equal(oa, ob)
    assert a.last_stats.verify_failures == b.last_stats.verify_failures
    assert a.last_stats.refetches == b.last_stats.refetches
    assert a.last_stats.faults_injected == b.last_stats.faults_injected


def test_tensor_path_split_send_recv_verify_knob(small_cache):
    cache, cb = small_cache
    plan = TransferPlan.build(cache, TransferConfig(codebook=cb,
                                                    compress_fp32=True))
    sess = plan.session(faults=FaultPlan(seed=5, corrupt_chunks=(0,)))
    sess.send(cache)
    out = sess.recv(verify=True)            # per-call knob on recv
    _assert_cache_equal(out, cache)
    assert sess.last_stats.verify_failures >= 1


def test_verify_knob_rejects_unframed_session(small_cache):
    cache, cb = small_cache
    plan = TransferPlan.build(cache, TransferConfig(codebook=cb))
    sess = plan.session()                   # no channel: nothing was framed
    with pytest.raises(ValueError, match="unframed"):
        sess.transfer(cache, verify=True)


def test_persistent_adversary_fails_loud(small_cache):
    cache, cb = small_cache
    plan = TransferPlan.build(cache, TransferConfig(codebook=cb, n_chunks=2))
    sess = plan.session(verify=True,
                        faults=FaultPlan(seed=1, corrupt_chunks=(0,),
                                         persistent_attempts=64))
    with pytest.raises(TransferIntegrityError):
        sess.transfer(cache)


# ---------------------------------------------------------------------------
# scheduler failure semantics
# ---------------------------------------------------------------------------

_PROFILE = CodecProfile(g_enc=80e9, g_dec=120e9, link_bw=4e9, ratio=1.33,
                        fixed_overhead_s=1e-4)


def _requests(n=12, seed=0, budget=(8, 32)):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, arrival=float(rng.uniform(0, 0.05)),
                    prompt_len=int(rng.integers(256, 4096)),
                    max_new_tokens=int(rng.integers(*budget)))
            for i in range(n)]


def _cfg(**kw):
    base = dict(max_prefill_batch=4, max_decode_slots=8,
                kv_bytes_per_token=80_000, profile=_PROFILE, n_chunks=4)
    base.update(kw)
    return SchedulerConfig(**base)


def _check_conservation(sched, done):
    """Link accounting invariants across any fault pattern: total charged
    busy time equals the sum over EVERY occupancy interval (failover
    re-fetches included), and the intervals are pairwise disjoint."""
    ivals = sorted(i for r in done for i in r.link_history)
    assert abs(sched.link_busy_s
               - sum(b - a for a, b in ivals)) < 1e-9
    for (_, stop), (start, _) in zip(ivals, ivals[1:]):
        assert stop <= start + 1e-12


def test_worker_death_failover_conserves_accounting():
    fp = FaultPlan(seed=7, worker_kills=(WorkerKill(worker=0, at=0.1),))
    sched = DisaggregatedScheduler(_cfg(n_decode_workers=2, faults=fp,
                                        heartbeat_timeout_s=0.01))
    for r in _requests():
        sched.submit(r)
    done = sched.run()
    assert sched.failovers > 0
    assert all(r.state in ("completed", "failed-over") for r in done)
    assert all(r.tokens_out >= r.max_new_tokens for r in done)
    # each failover is exactly one extra link occupancy
    assert all(len(r.link_history) == 1 + r.retries for r in done)
    _check_conservation(sched, done)
    out = summarize(done)
    assert out["n_failed_over"] >= 1 and out["n"] == 12


def test_failed_over_requests_keep_emitted_tokens():
    fp = FaultPlan(seed=7, worker_kills=(WorkerKill(worker=0, at=0.1),))
    sched = DisaggregatedScheduler(_cfg(n_decode_workers=2, faults=fp,
                                        heartbeat_timeout_s=0.01))
    for r in _requests():
        sched.submit(r)
    done = sched.run()
    for r in done:
        if r.state == "failed-over":
            # TTFT was set by the FIRST admission, before the failover
            assert r.first_token_time < r.link_history[-1][0]


def test_worker_revival_restores_capacity():
    fp = FaultPlan(seed=2, worker_kills=(
        WorkerKill(worker=0, at=0.1, revive_at=0.2),))
    sched = DisaggregatedScheduler(_cfg(n_decode_workers=1, faults=fp,
                                        heartbeat_timeout_s=0.01))
    for r in _requests():
        sched.submit(r)
    done = sched.run()                      # completes despite 1-worker kill
    assert len(done) == 12
    _check_conservation(sched, done)


def test_permanent_total_death_fails_loud():
    fp = FaultPlan(seed=2, worker_kills=(WorkerKill(worker=0, at=0.1),))
    sched = DisaggregatedScheduler(_cfg(n_decode_workers=1, faults=fp,
                                        heartbeat_timeout_s=0.01))
    for r in _requests():
        sched.submit(r)
    with pytest.raises(RuntimeError, match="never completed"):
        sched.run()


def test_brownout_stretches_held_link_time():
    fp = FaultPlan(brownouts=(LinkBrownout(start=0.0, stop=10.0, factor=0.25),))
    slow = DisaggregatedScheduler(_cfg(faults=fp))
    fast = DisaggregatedScheduler(_cfg())
    for r in _requests():
        slow.submit(r)
    for r in _requests():
        fast.submit(r)
    done_slow, done_fast = slow.run(), fast.run()
    _check_conservation(slow, done_slow)
    _check_conservation(fast, done_fast)
    # the same bytes at 1/4 rate hold the link measurably longer
    assert slow.link_busy_s > 2 * fast.link_busy_s


def test_edf_sheds_minimal_infeasible_set():
    """Only provably-lost requests are shed: exactly the ones whose deadline
    cannot be met even by immediate dispatch.  FIFO serves everyone but
    (necessarily) misses those same deadlines."""
    def mk(n=16):
        rs = _requests(n, seed=3)
        for i, r in enumerate(rs):
            # every 4th deadline is infeasible by construction (far below
            # any possible transfer + decode-step time); the rest are lax
            r.deadline = r.arrival + (1e-4 if i % 4 == 0 else 10.0)
        return rs

    shed_sched = DisaggregatedScheduler(_cfg(policy="edf-shed"))
    for r in mk():
        shed_sched.submit(r)
    done = shed_sched.run()
    shed_rids = {r.rid for r in done if r.state == "shed"}
    assert shed_rids == {r.rid for r in mk() if r.deadline - r.arrival < 1.0}
    assert all(r.state in ("completed", "shed") for r in done)
    _check_conservation(shed_sched, done)

    fifo_sched = DisaggregatedScheduler(_cfg(policy="fifo"))
    for r in mk():
        fifo_sched.submit(r)
    fifo_done = fifo_sched.run()
    assert all(r.state == "completed" for r in fifo_done)   # FIFO never sheds
    # FIFO burned link time on those requests anyway and still missed them
    for r in fifo_done:
        if r.rid in shed_rids:
            assert r.first_token_time > r.deadline
    # shedding freed the link: survivors' TTFT is no worse in aggregate
    shed_served = {r.rid: r for r in done if r.state != "shed"}
    fifo_ttft = sum(r.first_token_time - r.arrival for r in fifo_done
                    if r.rid in shed_served)
    edf_ttft = sum(r.first_token_time - r.arrival
                   for r in shed_served.values())
    assert edf_ttft <= fifo_ttft + 1e-9


def test_shed_infeasible_override_flag():
    rs = _requests(8, seed=4)
    for r in rs:
        r.deadline = r.arrival + 1e-4       # all infeasible
    sched = DisaggregatedScheduler(_cfg(policy="fifo", shed_infeasible=True))
    for r in rs:
        sched.submit(r)
    done = sched.run()
    assert summarize(done) == {"n": 0, "n_shed": 8.0, "n_failed_over": 0.0,
                               "n_failovers": 0.0, "n_retries": 0.0}


def test_failover_budget_exhaustion_sheds():
    # kill/revive the only worker in a tight loop so residents fail over
    # repeatedly; max_refetches=0 sheds on the FIRST failover
    fp = FaultPlan(seed=1, worker_kills=(
        WorkerKill(worker=0, at=0.1, revive_at=0.15),))
    sched = DisaggregatedScheduler(_cfg(n_decode_workers=1, faults=fp,
                                        heartbeat_timeout_s=0.01,
                                        max_refetches=0))
    for r in _requests():
        sched.submit(r)
    done = sched.run()
    assert sched.sheds > 0
    assert all(r.state in ("completed", "shed") for r in done)
    assert len(done) == 12                  # everyone is terminal somewhere


def test_fault_free_config_unchanged_by_failure_plane():
    """n_decode_workers=1, no faults: the failure machinery must be inert —
    identical summaries to a pre-failure-plane run shape."""
    a = DisaggregatedScheduler(_cfg())
    b = DisaggregatedScheduler(_cfg(n_decode_workers=1, faults=None))
    for r in _requests():
        a.submit(r)
    for r in _requests():
        b.submit(r)
    assert summarize(a.run()) == summarize(b.run())


# ---------------------------------------------------------------------------
# the acceptance chaos scenario (ISSUE 7)
# ---------------------------------------------------------------------------

def test_chaos_end_to_end(small_cache):
    """1% chunk corruption + one decode worker killed mid-run + a link
    brownout: the run completes, surviving requests' KV is bit-identical to
    the fault-free run, and the scheduler accounting is conserved with every
    request terminal in exactly one state."""
    cache, cb = small_cache
    chaos = FaultPlan(seed=7, corrupt_p=0.01,
                      worker_kills=(WorkerKill(worker=0, at=0.05),),
                      brownouts=(LinkBrownout(start=0.05, stop=0.3,
                                              factor=0.5),))

    # data plane: repeated verified transfers under 1% corruption are
    # bit-identical to the fault-free output
    plan = TransferPlan.build(cache, TransferConfig(codebook=cb, n_chunks=8))
    fault_free = plan.session().transfer(cache)
    sess = plan.session(verify=True, faults=chaos)
    injected = 0
    for _ in range(8):
        out = sess.transfer(cache)
        _assert_cache_equal(out, fault_free)
        injected += sess.last_stats.faults_injected
    assert injected >= 1                    # the 1% rate actually fired

    # control plane: kill + brownout; every request terminal in exactly one
    # of completed/shed/failed-over, occupancy intervals disjoint
    sched = DisaggregatedScheduler(_cfg(n_decode_workers=2, faults=chaos,
                                        heartbeat_timeout_s=0.01))
    reqs = _requests(16, seed=11)
    for r in reqs:
        sched.submit(r)
    done = sched.run()
    assert len(done) == len(reqs)
    assert all(r.state in ("completed", "shed", "failed-over") for r in done)
    assert sched.failovers >= 1
    _check_conservation(sched, done)
    out = summarize(done)
    assert out["n"] + out["n_shed"] == len(reqs)


# ---------------------------------------------------------------------------
# fleet chaos: prefill-tier kills and per-link brownouts (ISSUE 10)
# ---------------------------------------------------------------------------

def _check_links_by_link(sched, done):
    """Per-link refinement of _check_conservation: each link's occupancy
    intervals are disjoint and sum to that link's charged busy time."""
    by_link = {}
    for r in done:
        assert len(r.link_ids) == len(r.link_history)
        for li, ival in zip(r.link_ids, r.link_history):
            by_link.setdefault(li, []).append(ival)
    for li, ivals in by_link.items():
        ivals.sort()
        assert abs(sched.link_busy_by_link[li]
                   - sum(b - a for a, b in ivals)) < 1e-9
        for (_, stop), (start, _) in zip(ivals, ivals[1:]):
            assert stop <= start + 1e-12


def test_prefill_worker_kill_mid_prefill_reroutes():
    """Killing one of two prefill workers while its batch is in flight
    re-routes the stranded requests to the survivor: every request still
    reaches a terminal state with its full token budget, the re-route is
    counted in prefill_failovers, and link accounting stays conserved."""
    cluster = ClusterConfig(n_prefill=2, n_decode=2, links=(LinkSpec(),),
                            router="transfer-aware")
    # arrivals land in [0, 0.05], so at t=20 ms both prefill workers are
    # deep in their batch queues and the kill strands an in-flight batch
    fp = FaultPlan(seed=3, worker_kills=(
        WorkerKill(worker=0, at=0.02, role="prefill"),))
    sched = DisaggregatedScheduler(_cfg(cluster=cluster, faults=fp,
                                        heartbeat_timeout_s=0.001))
    reqs = _requests(16, seed=5)
    for r in reqs:
        sched.submit(r)
    done = sched.run()
    assert len(done) == len(reqs)
    assert sched.prefill_failovers > 0
    assert all(r.state in ("completed", "failed-over") for r in done)
    assert all(r.tokens_out >= r.max_new_tokens for r in done)
    _check_conservation(sched, done)
    _check_links_by_link(sched, done)


def test_per_link_brownout_shifts_traffic():
    """A brownout pinned to link 1 of a two-link fleet: the transfer-aware
    router shifts traffic onto the healthy link while the brownout holds,
    per-link conservation still closes, and pinning the brownout to one link
    leaves the fleet strictly better off than degrading both."""
    def fleet(faults):
        cluster = ClusterConfig(n_prefill=1, n_decode=2,
                                links=(LinkSpec(), LinkSpec()),
                                router="transfer-aware")
        sched = DisaggregatedScheduler(_cfg(cluster=cluster, faults=faults,
                                            heartbeat_timeout_s=0.01))
        for r in _requests(24, seed=9):
            sched.submit(r)
        done = sched.run()
        assert all(r.state == "completed" for r in done)
        # global disjointness does not apply with two parallel links —
        # conservation is per link, plus the per-link sums closing the total
        _check_links_by_link(sched, done)
        assert abs(sched.link_busy_s - sum(sched.link_busy_by_link)) < 1e-9
        return sched, done

    browned = FaultPlan(seed=4, brownouts=(
        LinkBrownout(start=0.0, stop=10.0, factor=0.1, link=1),))
    everywhere = FaultPlan(seed=4, brownouts=(
        LinkBrownout(start=0.0, stop=10.0, factor=0.1),))

    def counts(done):
        c = [0, 0]
        for r in done:
            for li in r.link_ids:
                c[li] += 1
        return c

    s_fault, d_fault = fleet(browned)
    s_clean, d_clean = fleet(None)
    s_both, d_both = fleet(everywhere)

    # the plan-estimate router sees link 1's degraded bandwidth and shifts
    # traffic onto the healthy link (busy SECONDS are the wrong metric here:
    # the browned link holds 10x longer per transfer, so count transfers)
    cf, cc = counts(d_fault), counts(d_clean)
    assert cf[0] > cf[1]
    assert cf[0] - cf[1] > cc[0] - cc[1]    # a real shift, not the baseline skew
    # fault-free, the same trace spreads across both links
    assert all(c > 0 for c in cc)
    # a single browned link beats the same brownout applied fleet-wide
    assert (summarize(d_fault)["p99_ttft_s"]
            <= summarize(d_both)["p99_ttft_s"] + 1e-12)
